//! E-T8: regenerate Table 8 (Eqns 10-11) and benchmark the catalog
//! evaluation + allocation (Eqns 3-4) machinery.

use mfnn::bench::Suite;
use mfnn::hw::FpgaDevice;
use mfnn::perf::catalog::CATALOG;
use mfnn::report::{f, Table};

fn main() {
    let mut t = Table::new(vec!["FPGA", "R Mb/s (Eqn 10)", "F Mb/s/CAD (Eqn 11)", "F paper"])
        .with_title("Table 8 reproduction")
        .numeric();
    let paper = [561.84, 634.63, 521.17, 538.32, 692.12, 516.85, 300.08, 272.80, 279.26];
    for (p, f_pub) in CATALOG.iter().zip(paper) {
        t.row(vec![
            p.name.into(),
            f(p.ddr_throughput_mbps(), 2),
            f(p.perf_cost_paper(), 2),
            f(f_pub, 2),
        ]);
    }
    print!("{}", t.render());
    let best = CATALOG
        .iter()
        .max_by(|a, b| a.perf_cost().partial_cmp(&b.perf_cost()).unwrap())
        .unwrap();
    assert_eq!(best.name, "XC7S75-2");
    println!("argmax F: {} (matches the paper's selection)\n", best.name);

    let mut suite = Suite::new("table8");
    suite.bench("catalog_eval_all_parts", |b| {
        b.iter_with_elements(CATALOG.len() as u64, || {
            CATALOG.iter().map(|p| p.perf_cost()).sum::<f64>()
        })
    });
    suite.bench("allocation_eqn3_eqn4_all_parts", |b| {
        b.iter_with_elements(CATALOG.len() as u64, || {
            CATALOG.iter().map(|p| FpgaDevice::new(p).mvm_groups).sum::<u32>()
        })
    });
    suite.finish();
}
