//! E-T1/E-T2/E-F3 benches: Matrix Assembler throughput — parsing +
//! lowering assembly, instruction encode/decode rates, and microcode
//! generation rates.

use mfnn::asm::lower_file;
use mfnn::bench::Suite;
use mfnn::isa::{Instruction, Microcode, Opcode, Width};
use mfnn::assembler::microcode_gen;

const NET: &str = "
NET bench
FIXED 10 saturate
INPUT x 16 15
WEIGHT w0 15 32
BIAS b0 32
ACT a0 relu shift=5 mode=clamp interp=1
MLP h x w0 b0 a0
WEIGHT w1 32 10
BIAS b1 10
ACT a1 identity shift=5 mode=clamp interp=1
MLP out h w1 b1 a1
OUTPUT out
TARGET y 16 10
TRAIN lr=0.0078125
";

fn main() {
    let mut suite = Suite::new("assembler");
    suite.bench("parse_and_lower_train_net", |b| {
        b.iter(|| lower_file(NET).unwrap())
    });
    let nets = lower_file(NET).unwrap();
    let p = &nets[0].mlp.program;
    println!(
        "lowered train net: {} waves, {} lane-ops",
        p.waves().count(),
        p.total_lane_ops()
    );
    suite.bench("program_validate", |b| b.iter(|| p.check().unwrap()));
    suite.bench("encode_instruction_stream", |b| {
        b.iter(|| p.encode(Width::W32, 16, 4).unwrap())
    });
    suite.bench("instruction_encode_decode_w32", |b| {
        let i = Instruction::new(Opcode::VectorDotProduct, 3, 17, 1024);
        b.iter_with_elements(1, || {
            let raw = i.encode(Width::W32).unwrap();
            Instruction::decode(raw, Width::W32).unwrap()
        })
    });
    suite.bench("microcode_roundtrip", |b| {
        let words = microcode_gen::mvm_batch(Opcode::VectorAddition, 512, 4).unwrap();
        b.iter_with_elements(words.len() as u64, || {
            words.iter().map(|w| Microcode::decode(w.encode()).cycles as u64).sum::<u64>()
        })
    });
    suite.bench("microcode_gen_batch_512x4", |b| {
        b.iter(|| microcode_gen::mvm_batch(Opcode::VectorDotProduct, 512, 4).unwrap())
    });
    suite.finish();
}
