//! E-ABL: ablations over the design choices DESIGN.md §3 calls out —
//! narrowing mode (wrap = paper, saturate = ours), LUT addressing
//! (wrap = paper, clamp = ours), LUT interpolation, and fraction bits.
//! Measures final training accuracy on blobs after a fixed step budget.

use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::{ActKind, AddrMode};
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::{TrainConfig, Trainer};
use mfnn::report::{f, Table};
use mfnn::util::Rng;

fn run_config(name: &str, fixed: FixedSpec, lut: LutParams, t: &mut Table) {
    let spec = MlpSpec::from_dims(
        name, &[8, 16, 4], ActKind::Relu, ActKind::Identity, fixed, lut,
    )
    .unwrap();
    let (train, test) = dataset::blobs(320, 4, 8, 77).split(0.8, &mut Rng::new(77));
    let quick = std::env::var("MFNN_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let steps = if quick { 40 } else { 200 };
    let cfg = TrainConfig { batch: 16, lr: 1.0 / 128.0, steps, seed: 9, log_every: 50 };
    match Trainer::build(spec, FpgaDevice::selected(), cfg) {
        Ok(mut tr) => {
            let report = tr.train(&train).unwrap();
            let (acc, _) = tr.evaluate(&test).unwrap();
            t.row(vec![
                name.into(),
                format!("Q{}.{}", 16 - fixed.frac_bits, fixed.frac_bits),
                format!("{:?}", fixed.round),
                format!("{:?}", lut.mode),
                lut.interp.to_string(),
                f(report.curve.last().unwrap().loss, 4),
                f(acc, 3),
            ]);
        }
        Err(e) => {
            t.row(vec![
                name.into(), "-".into(), "-".into(), "-".into(), "-".into(),
                "-".into(), format!("error: {e}"),
            ]);
        }
    }
}

fn main() {
    let mut t = Table::new(vec![
        "config", "format", "narrow", "lut addr", "interp", "final loss", "accuracy",
    ])
        .with_title("ablation: datapath/LUT design choices (blobs, fixed step budget)")
        .numeric();
    // Paper-faithful everything: Q8.7, wrap narrowing, wrap LUT, no interp.
    run_config("paper_q8.7_wrap", FixedSpec::q(7),
        LutParams { shift: 7, mode: AddrMode::Wrap, interp: false }, &mut t);
    // + clamp addressing only
    run_config("q8.7_wrap_clamplut", FixedSpec::q(7),
        LutParams { shift: 2, mode: AddrMode::Clamp, interp: false }, &mut t);
    // + saturating narrowing
    run_config("q8.7_sat_clamplut", FixedSpec::q(7).saturating(),
        LutParams { shift: 2, mode: AddrMode::Clamp, interp: false }, &mut t);
    // + interpolation
    run_config("q8.7_sat_interp", FixedSpec::q(7).saturating(),
        LutParams { shift: 2, mode: AddrMode::Clamp, interp: true }, &mut t);
    // + finer format (the training default)
    run_config("q5.10_sat_interp", FixedSpec::q(10).saturating(),
        LutParams { shift: 5, mode: AddrMode::Clamp, interp: true }, &mut t);
    // format sensitivity
    run_config("q3.12_sat_interp", FixedSpec::q(12).saturating(),
        LutParams { shift: 7, mode: AddrMode::Clamp, interp: true }, &mut t);
    print!("{}", t.render());
    println!("reading: on an easy separable task every configuration can reach high");
    println!("accuracy, but wrap narrowing is fragile (larger batches/lr overflow the");
    println!("summed gradients and diverge — see DESIGN.md §3); saturating narrowing +");
    println!("finer formats give markedly lower final loss and stable training, which");
    println!("is why the training default is Q5.10/saturate/clamp/interp.");
}
