//! Serving-path benchmarks: single-board vs pooled serving across the
//! batch ladder (1 / 8 / 32). Wall-clock timings measure the simulator;
//! the **simulated**-cycle throughput of each configuration — the number
//! that is comparable across machines and PRs — is recorded in the
//! suite's JSON `notes` (requests per simulated second, cycles per
//! request, and the pooled+batched vs single-board-batch-1 speedup,
//! which the serving acceptance criterion requires to be ≥ 2×). A final
//! degraded-mode scenario re-runs pool4_b8 under a survivable injected
//! fault plan and records the throughput ratio vs the clean run.
//!
//! Run: `cargo bench --bench bench_serving` (writes
//! `BENCH_serving.json` at the repo root; `MFNN_BENCH_QUICK=1` for CI).

use mfnn::bench::{Bencher, Suite};
use mfnn::fixed::FixedSpec;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::serve::{open_loop, seeded_params, ServeFaultPlan, ServeReport, SynthRequest};
use mfnn::{Artifact, CompileOptions, Compiler, ServeConfig, Server};
use std::sync::Arc;

/// The datapath format every bench net uses.
fn fixed() -> FixedSpec {
    FixedSpec::q(10).saturating()
}

/// Three small distinct nets with seeded parameters (the serve-sim mix).
#[allow(clippy::type_complexity)]
fn fleet(
    compiler: &Compiler,
    max_batch: usize,
) -> Vec<(Arc<Artifact>, Vec<Vec<i16>>, Vec<Vec<i16>>)> {
    [[4usize, 16, 3], [6, 12, 4], [3, 10, 2]]
        .iter()
        .enumerate()
        .map(|(j, dims)| {
            let spec = MlpSpec::from_dims(
                &format!("bench{j}"),
                dims,
                ActKind::Relu,
                ActKind::Identity,
                fixed(),
                LutParams::training(fixed()),
            )
            .unwrap();
            let (w, b) = seeded_params(&spec, 0xBE7C4 + j as u64);
            let artifact =
                compiler.compile_spec(&spec, &CompileOptions::serving(max_batch)).unwrap();
            (artifact, w, b)
        })
        .collect()
}

/// Run one saturated (open-loop, mean gap 1 cycle) workload against a
/// fresh server and return its metrics.
fn run_workload(
    compiler: &Compiler,
    boards: usize,
    max_batch: usize,
    workload: &[SynthRequest],
    faults: &ServeFaultPlan,
) -> ServeReport {
    let mut server = Server::open(ServeConfig {
        boards,
        max_batch,
        // batch-1 configs flush instantly; batched ones wait briefly
        max_wait_cycles: if max_batch == 1 { 0 } else { 64 },
        // admit the entire workload even while every board is busy
        queue_cap: workload.len() + 1,
        faults: faults.clone(),
        ..ServeConfig::default()
    })
    .unwrap();
    let nets = fleet(compiler, max_batch);
    for (artifact, w, b) in &nets {
        server.register(Arc::clone(artifact), w, b).unwrap();
    }
    for q in workload {
        server.submit_at(q.at, q.net, &q.row).unwrap();
    }
    server.drain().unwrap();
    server.report()
}

fn main() {
    let mut suite = Suite::new("serving");
    let requests = if suite.is_quick() { 64 } else { 256 };
    let compiler = Compiler::new();
    let in_dims = [4usize, 6, 3];
    let workload = open_loop(requests, 0, 1, &in_dims, fixed());

    // (name, boards, max_batch) — the single-board batch ladder plus the
    // pooled configuration the acceptance criterion compares against.
    let scenarios: &[(&str, usize, usize)] = &[
        ("single_board_b1", 1, 1),
        ("single_board_b8", 1, 8),
        ("single_board_b32", 1, 32),
        ("pool4_b8", 4, 8),
        ("pool4_b32", 4, 32),
    ];
    let mut sim_rps = Vec::new();
    let clean = ServeFaultPlan::none();
    for &(name, boards, max_batch) in scenarios {
        let report = run_workload(&compiler, boards, max_batch, &workload, &clean);
        assert_eq!(
            report.total_completed() as usize,
            requests,
            "{name}: dropped requests in a bench workload"
        );
        sim_rps.push((name, report.requests_per_sim_s()));
        suite.note(&format!("sim_rps_{name}"), format!("{:.1}", report.requests_per_sim_s()));
        suite.note(
            &format!("sim_cycles_per_req_{name}"),
            format!("{:.1}", report.cycles_per_request()),
        );
        suite.bench(name, |b: &mut Bencher| {
            b.iter_with_elements(requests as u64, || {
                run_workload(&compiler, boards, max_batch, &workload, &clean)
            });
        });
    }
    let base = sim_rps.iter().find(|(n, _)| *n == "single_board_b1").unwrap().1;
    let pooled = sim_rps.iter().find(|(n, _)| *n == "pool4_b32").unwrap().1;
    suite.note("sim_speedup_pool4_b32_vs_single_b1", format!("{:.2}", pooled / base));

    // Degraded mode: the pool4_b8 configuration under a survivable
    // injected fault plan (stalls, corruptions within the hedged-retry
    // budget, deaths that spare board 0). No request may be lost —
    // without deadlines every admitted row must still complete — and
    // the throughput ratio vs the clean run quantifies the cost of
    // quarantine + hedged retries.
    let faults = ServeFaultPlan::survivable(0xC405, 4, ServeConfig::default().max_retries);
    let chaos = run_workload(&compiler, 4, 8, &workload, &faults);
    assert_eq!(
        chaos.total_completed() as usize,
        requests,
        "pool4_b8_chaos: lost requests under a survivable fault plan"
    );
    let clean_b8 = sim_rps.iter().find(|(n, _)| *n == "pool4_b8").unwrap().1;
    suite.note("sim_rps_pool4_b8_chaos", format!("{:.1}", chaos.requests_per_sim_s()));
    suite.note(
        "degraded_mode_throughput_ratio",
        format!("{:.2}", chaos.requests_per_sim_s() / clean_b8),
    );
    suite.bench("pool4_b8_chaos", |b: &mut Bencher| {
        b.iter_with_elements(requests as u64, || run_workload(&compiler, 4, 8, &workload, &faults));
    });
    suite.finish();
}
