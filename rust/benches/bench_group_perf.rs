//! E-W1 / E-W2 (DESIGN.md): the §4.1 per-group performance model —
//! the three published worked examples, an `E(N_I)`/`R(N_I)` sweep, and
//! a cross-check of the analytic model against the *structural*
//! cycle-accurate simulator's measured per-op cycles, plus the
//! simulator's own wall-clock speed (simulated cycles per second).

use mfnn::bench::Suite;
use mfnn::fixed::FixedSpec;
use mfnn::hw::mvm::Mvm;
use mfnn::hw::actpro::ActPro;
use mfnn::isa::MvmOp;
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::perf::group::{OpClass, PerfModel};
use mfnn::report::{f, Table};

fn main() {
    let m = PerfModel::paper();

    // ---- published worked examples ----
    let published = [
        ("vector addition", OpClass::Elementwise, 0.501, 3.95e8, 6320.0),
        ("vector dot product", OpClass::Reduction, 0.505, 3.99e8, 6384.0),
        ("activation function", OpClass::Activation, 0.401, 3.18e8, 5088.0),
    ];
    let mut t = Table::new(vec!["op", "T_RUN", "T_all", "E ours", "E pub", "P ours", "P pub", "R ours", "R pub"])
        .with_title("sec 4.1 worked examples at N_I=1024 (Eqns 5-9)")
        .numeric();
    for (name, class, e_pub, p_pub, r_pub) in published {
        let g = m.group_perf(class, 1024);
        t.row(vec![
            name.into(),
            g.t_run.to_string(),
            g.t_all.to_string(),
            f(g.e_paper(), 3),
            f(e_pub, 3),
            format!("{:.3e}", g.p),
            format!("{p_pub:.2e}"),
            f(g.r, 0),
            f(r_pub, 0),
        ]);
    }
    print!("{}", t.render());

    // ---- E(N_I) / R(N_I) sweep (the figure the equations imply) ----
    let mut t = Table::new(vec!["N_I", "E add", "E dot", "E act", "R add Mb/s", "R dot", "R act"])
        .with_title("efficiency/throughput sweep over iteration count")
        .numeric();
    for n_i in [1u64, 4, 16, 64, 256, 1024, 4096] {
        let ga = m.group_perf(OpClass::Elementwise, n_i);
        let gd = m.group_perf(OpClass::Reduction, n_i);
        let gc = m.group_perf(OpClass::Activation, n_i);
        t.row(vec![
            n_i.to_string(),
            f(ga.e, 3), f(gd.e, 3), f(gc.e, 3),
            f(ga.r, 0), f(gd.r, 0), f(gc.r, 0),
        ]);
    }
    print!("{}", t.render());

    // ---- structural sim cross-check: measured C_RUN per op ----
    let fixed = FixedSpec::PAPER;
    let mut t = Table::new(vec!["op", "len", "C_RUN model", "C_RUN structural sim"])
        .with_title("analytic C_RUN vs cycle-accurate simulator")
        .numeric();
    let mut mvm = Mvm::new(fixed);
    mvm.load_column(false, &vec![3; 512]);
    mvm.load_column(true, &vec![2; 512]);
    mvm.run_op(MvmOp::VecAdd, 512, false);
    t.row(vec!["vec add".into(), "512".into(), "519".into(), mvm.last_op_cycles().to_string()]);
    mvm.run_op(MvmOp::VecDot, 512, false);
    t.row(vec!["vec dot".into(), "512".into(), "519".into(), mvm.last_op_cycles().to_string()]);
    let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Wrap, 7);
    let mut ap = ActPro::new(lut);
    ap.load_input(&vec![64; 1024]);
    ap.run(1024);
    t.row(vec!["activation".into(), "1024".into(), "517".into(), ap.last_op_cycles().to_string()]);
    print!("{}", t.render());

    // ---- simulator speed (host wall-clock) ----
    let mut suite = Suite::new("group_perf");
    suite.bench("structural_mvm_vec_add_512 (simulated cycles/iter=520)", |b| {
        let mut m = Mvm::new(fixed);
        m.load_column(false, &vec![3; 512]);
        m.load_column(true, &vec![2; 512]);
        b.iter_with_elements(520, || m.run_op(MvmOp::VecAdd, 512, false))
    });
    suite.bench("structural_mvm_vec_dot_512", |b| {
        let mut m = Mvm::new(fixed);
        m.load_column(false, &vec![3; 512]);
        m.load_column(true, &vec![2; 512]);
        b.iter_with_elements(520, || m.run_op(MvmOp::VecDot, 512, false))
    });
    suite.bench("structural_actpro_1024", |b| {
        let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Wrap, 7);
        let mut a = ActPro::new(lut);
        a.load_input(&vec![64; 1024]);
        b.iter_with_elements(518, || a.run(1024))
    });
    let t = suite.finish();
    let _ = t;
    println!("(throughput column = simulated cycles per host second)");
}
