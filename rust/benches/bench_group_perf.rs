//! E-W1 / E-W2 (DESIGN.md): the §4.1 per-group performance model —
//! the three published worked examples, an `E(N_I)`/`R(N_I)` sweep, and
//! a cross-check of the analytic model against the *structural*
//! cycle-accurate simulator's measured per-op cycles, plus the
//! simulator's own wall-clock speed (simulated cycles per second).

use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
use mfnn::bench::Suite;
use mfnn::fixed::FixedSpec;
use mfnn::hw::actpro::ActPro;
use mfnn::hw::mvm::Mvm;
use mfnn::hw::{FastSim, FpgaDevice, MemPlan};
use mfnn::isa::{MvmOp, Opcode};
use mfnn::nn::graph::{Conv2dGeom, GraphSpec, INPUT};
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::perf::group::{OpClass, PerfModel};
use mfnn::report::{f, Table};
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Session, Target};

/// A Matrix-Machine-sized workload: `lanes` dot products of `len`-lane
/// strided operands feeding an activation over the results (fusable),
/// followed by a wide elementwise wave — the shape of one MLP layer's
/// forward pass.
fn layer_program(lanes: usize, len: usize) -> (Program, usize, Vec<i16>) {
    let s = FixedSpec::PAPER;
    let mut p = Program::new("layer", s);
    let x = p.buffer("x", lanes, len, BufKind::Input);
    let w = p.buffer("w", len, lanes, BufKind::Weight);
    let z = p.buffer("z", lanes, 1, BufKind::Temp);
    let o = p.buffer("o", lanes, len, BufKind::Output);
    let lut = p.lut(ActLut::build(ActKind::Relu, false, s, AddrMode::Clamp, 7));
    let dots: Vec<LaneOp> = (0..lanes)
        .map(|i| LaneOp {
            a: View::contiguous(x, i * len, len),
            b: Some(View { buf: w, offset: i, len, stride: lanes }),
            out: View::contiguous(z, i, 1),
        })
        .collect();
    p.steps.push(Step::Wave(Wave {
        op: Opcode::VectorDotProduct,
        vec_len: len,
        lut: None,
        lanes: dots,
    }));
    p.steps.push(Step::LoadLut(lut));
    p.steps.push(Step::Wave(Wave {
        op: Opcode::ActivationFunction,
        vec_len: lanes,
        lut: Some(lut),
        lanes: vec![LaneOp { a: View::all(z, lanes), b: None, out: View::all(z, lanes) }],
    }));
    let mults: Vec<LaneOp> = (0..lanes)
        .map(|i| LaneOp {
            a: View::contiguous(x, i * len, len),
            b: Some(View::contiguous(x, ((i + 1) % lanes) * len, len)),
            out: View::contiguous(o, i * len, len),
        })
        .collect();
    p.steps.push(Step::Wave(Wave {
        op: Opcode::ElementMultiplication,
        vec_len: len,
        lut: None,
        lanes: mults,
    }));
    let mut r = Rng::new(4242);
    let data: Vec<i16> = (0..lanes * len).map(|_| r.gen_range_i64(-4000, 4000) as i16).collect();
    (p, x, data)
}

fn main() {
    let m = PerfModel::paper();

    // ---- published worked examples ----
    let published = [
        ("vector addition", OpClass::Elementwise, 0.501, 3.95e8, 6320.0),
        ("vector dot product", OpClass::Reduction, 0.505, 3.99e8, 6384.0),
        ("activation function", OpClass::Activation, 0.401, 3.18e8, 5088.0),
    ];
    let mut t = Table::new(vec![
        "op", "T_RUN", "T_all", "E ours", "E pub", "P ours", "P pub", "R ours", "R pub",
    ])
        .with_title("sec 4.1 worked examples at N_I=1024 (Eqns 5-9)")
        .numeric();
    for (name, class, e_pub, p_pub, r_pub) in published {
        let g = m.group_perf(class, 1024);
        t.row(vec![
            name.into(),
            g.t_run.to_string(),
            g.t_all.to_string(),
            f(g.e_paper(), 3),
            f(e_pub, 3),
            format!("{:.3e}", g.p),
            format!("{p_pub:.2e}"),
            f(g.r, 0),
            f(r_pub, 0),
        ]);
    }
    print!("{}", t.render());

    // ---- E(N_I) / R(N_I) sweep (the figure the equations imply) ----
    let mut t = Table::new(vec!["N_I", "E add", "E dot", "E act", "R add Mb/s", "R dot", "R act"])
        .with_title("efficiency/throughput sweep over iteration count")
        .numeric();
    for n_i in [1u64, 4, 16, 64, 256, 1024, 4096] {
        let ga = m.group_perf(OpClass::Elementwise, n_i);
        let gd = m.group_perf(OpClass::Reduction, n_i);
        let gc = m.group_perf(OpClass::Activation, n_i);
        t.row(vec![
            n_i.to_string(),
            f(ga.e, 3), f(gd.e, 3), f(gc.e, 3),
            f(ga.r, 0), f(gd.r, 0), f(gc.r, 0),
        ]);
    }
    print!("{}", t.render());

    // ---- structural sim cross-check: measured C_RUN per op ----
    let fixed = FixedSpec::PAPER;
    let mut t = Table::new(vec!["op", "len", "C_RUN model", "C_RUN structural sim"])
        .with_title("analytic C_RUN vs cycle-accurate simulator")
        .numeric();
    let mut mvm = Mvm::new(fixed);
    mvm.load_column(false, &vec![3; 512]);
    mvm.load_column(true, &vec![2; 512]);
    mvm.run_op(MvmOp::VecAdd, 512, false);
    t.row(vec!["vec add".into(), "512".into(), "519".into(), mvm.last_op_cycles().to_string()]);
    mvm.run_op(MvmOp::VecDot, 512, false);
    t.row(vec!["vec dot".into(), "512".into(), "519".into(), mvm.last_op_cycles().to_string()]);
    let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Wrap, 7);
    let mut ap = ActPro::new(lut);
    ap.load_input(&vec![64; 1024]);
    ap.run(1024);
    t.row(vec!["activation".into(), "1024".into(), "517".into(), ap.last_op_cycles().to_string()]);
    print!("{}", t.render());

    // ---- simulator speed (host wall-clock) ----
    let mut suite = Suite::new("group_perf");
    suite.bench("structural_mvm_vec_add_512 (simulated cycles/iter=520)", |b| {
        let mut m = Mvm::new(fixed);
        m.load_column(false, &vec![3; 512]);
        m.load_column(true, &vec![2; 512]);
        b.iter_with_elements(520, || m.run_op(MvmOp::VecAdd, 512, false))
    });
    suite.bench("structural_mvm_vec_dot_512", |b| {
        let mut m = Mvm::new(fixed);
        m.load_column(false, &vec![3; 512]);
        m.load_column(true, &vec![2; 512]);
        b.iter_with_elements(520, || m.run_op(MvmOp::VecDot, 512, false))
    });
    suite.bench("structural_actpro_1024", |b| {
        let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Wrap, 7);
        let mut a = ActPro::new(lut);
        a.load_input(&vec![64; 1024]);
        b.iter_with_elements(518, || a.run(1024))
    });

    // ---- compiled session hot path vs the sequential reference ----
    // The pre-plan training loop executed waves through the sequential
    // FastSim interpreter (re-resolving views and re-boxing cycle
    // closures per step); the session opens the program's compiled
    // ExecPlan (views pre-resolved, dot→act fused, independent lanes on
    // the worker pool). Same numerics — the median ratio of these two
    // benchmarks is the headline speedup tracked in
    // BENCH_group_perf.json.
    let (lanes, len) = if suite.is_quick() { (128, 64) } else { (512, 256) };
    let (p, x, data) = layer_program(lanes, len);
    p.check().expect("bench program must validate");
    let lane_ops = p.total_lane_ops();
    let tag = format!("{lanes}x{len}");
    suite.bench(&format!("ref_fastsim_layer_{tag}"), |b| {
        let mut sim = FastSim::new(&p);
        sim.set_buffer(x, &data);
        let waves: Vec<&Wave> = p.waves().collect();
        b.iter_with_elements(lane_ops, || {
            for &w in &waves {
                sim.exec_wave(&p, w);
            }
        })
    });
    let device = FpgaDevice::selected();
    let compiler = Compiler::new();
    let artifact = compiler.compile_program(&p).expect("bench artifact");
    let plan = artifact.plan_for(&device);
    eprintln!(
        "  (plan: {} fused, {} parallel waves, pool={} threads)",
        plan.fused_waves(),
        plan.parallel_waves(),
        plan.pool_threads()
    );
    let mut session =
        Session::open(artifact.clone(), Target::Board(device)).expect("bench session");
    let hx = artifact.tensor("x").expect("x handle");
    session.write(&hx, &data).expect("bind x");
    suite.bench(&format!("plan_layer_{tag}"), |b| {
        b.iter_with_elements(lane_ops, || session.step().cycles)
    });

    // ---- operator-graph scenarios: one CNN and one transformer block
    // through Compiler::compile_graph and the same session hot path ----
    let gfixed = FixedSpec::q(9).saturating();
    let geom = Conv2dGeom { in_h: 8, in_w: 8, in_c: 1, out_c: 8, kh: 3, kw: 3, stride: 1 };
    let mut conv = GraphSpec::new("conv", 64, gfixed, LutParams::training(gfixed));
    let c = conv.conv2d(INPUT, geom);
    let ca = conv.activation(c, ActKind::Relu);
    conv.linear(ca, 10);

    let (seq, d) = (8, 8);
    let mut xfmr = GraphSpec::new("transformer_block", seq * d, gfixed, LutParams::training(gfixed));
    let att = xfmr.attention(INPUT, seq, d);
    let r1 = xfmr.add(att, INPUT);
    let n1 = xfmr.normalization(r1, d);
    let f1 = xfmr.linear(n1, seq * d);
    let fa = xfmr.activation(f1, ActKind::Relu);
    let f2 = xfmr.linear(fa, seq * d);
    let r2 = xfmr.add(f2, n1);
    xfmr.normalization(r2, d);

    let batch = if suite.is_quick() { 2 } else { 8 };
    for spec in [&conv, &xfmr] {
        let artifact = compiler
            .compile_graph(spec, &CompileOptions::inference(batch))
            .expect("graph bench artifact");
        let lane_ops = artifact.program().total_lane_ops();
        let mut session =
            Session::open(artifact.clone(), Target::Board(device)).expect("graph session");
        let mut r = Rng::new(77);
        for dcl in spec.param_decls().expect("bench graphs validate") {
            let w: Vec<i16> = (0..dcl.rows * dcl.cols)
                .map(|_| gfixed.from_f64((r.gen_f64() - 0.5) * 0.5))
                .collect();
            let bv: Vec<i16> =
                (0..dcl.cols).map(|_| gfixed.from_f64((r.gen_f64() - 0.5) * 0.25)).collect();
            session.write(&artifact.tensor(&dcl.wname).expect("w handle"), &w).expect("bind w");
            session.write(&artifact.tensor(&dcl.bname).expect("b handle"), &bv).expect("bind b");
        }
        let qx: Vec<i16> = (0..batch * spec.input_dim())
            .map(|_| gfixed.from_f64(r.gen_f64() - 0.5))
            .collect();
        session.write(&artifact.tensor("x").expect("x handle"), &qx).expect("bind x");
        suite.bench(&format!("graph_{}_b{batch} ({lane_ops} lane-ops)", spec.name), |b| {
            b.iter_with_elements(lane_ops, || session.step().cycles)
        });
        // Static memory planner (DESIGN.md §Memory planner): the
        // lane-reuse layout these scenarios would run under with
        // `CompileOptions::with_memory_plan()` — bit-identical execution
        // (enforced by the memplan fuzz family) at a lower peak
        // lane/BRAM footprint than the default packed arena.
        let mp = MemPlan::build(artifact.program());
        suite.note(
            &format!("memplan_{}_b{batch}", spec.name),
            format!(
                "packed {} lanes / {} BRAM18 -> planned {} lanes / {} BRAM18 (saved {} lanes)",
                mp.packed_lanes(),
                mp.packed_bram(),
                mp.peak_lanes(),
                mp.peak_bram(),
                mp.saved_lanes(),
            ),
        );
    }

    // Planner note for a paper-style MLP training step (the same net the
    // `mfnn plan --report` table leads with): backward-pass temporaries
    // are where interval-based lane reuse pays most.
    let fixed10 = FixedSpec::q(10).saturating();
    let mlp = MlpSpec::from_dims(
        "mlp_16_32_32_10",
        &[16, 32, 32, 10],
        ActKind::Relu,
        ActKind::Identity,
        fixed10,
        LutParams::training(fixed10),
    )
    .expect("bench mlp spec");
    let lowered =
        mfnn::nn::graph::lower_mlp_train(&mlp, batch, 1.0 / 128.0).expect("bench mlp train");
    let mp = MemPlan::build(&lowered.program);
    suite.note(
        &format!("memplan_{}_train_b{batch}", mlp.name),
        format!(
            "packed {} lanes / {} BRAM18 -> planned {} lanes / {} BRAM18 (saved {} lanes)",
            mp.packed_lanes(),
            mp.packed_bram(),
            mp.peak_lanes(),
            mp.peak_bram(),
            mp.saved_lanes(),
        ),
    );

    let t = suite.finish();
    let _ = t;
    println!("(throughput column = simulated cycles per host second)");
}
