//! E-SCALE: cluster makespan across the paper's three scheduling regimes
//! — the multi-FPGA scaling claim of §2. Reports simulated makespan
//! (the modelled hardware's time) and host wall-clock (simulator cost).

use mfnn::cluster::{run_cluster, ClusterConfig, Job};
use mfnn::fixed::FixedSpec;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::report::{f, Table};
use mfnn::util::Rng;
use std::sync::Arc;

fn mk_jobs(m: usize, steps: usize) -> Vec<Job> {
    let fixed = FixedSpec::q(10).saturating();
    (0..m)
        .map(|i| {
            let seed = 500 + i as u64;
            let spec = MlpSpec::from_dims(
                &format!("j{i}"), &[15, 24, 10], ActKind::Relu, ActKind::Identity,
                fixed, LutParams::training(fixed)).unwrap();
            let (train, test) = dataset::mini_digits(240, seed).split(0.8, &mut Rng::new(seed));
            Job {
                name: format!("j{i}"), spec,
                cfg: TrainConfig { batch: 16, lr: 1.0 / 128.0, steps, seed, log_every: 100 },
                train_data: Arc::new(train), test_data: Arc::new(test),
            }
        })
        .collect()
}

fn main() {
    let quick = std::env::var("MFNN_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let steps = if quick { 20 } else { 80 };
    let mut t = Table::new(vec!["M", "F", "mode", "sim makespan ms", "Σsteps/s sim", "host wall ms"])
        .with_title(format!("cluster scaling sweep ({steps} steps/job)"))
        .numeric();
    for (m, fb) in [(1usize, 1usize), (2, 1), (4, 1), (8, 1), (4, 2), (4, 4), (2, 4), (1, 4)] {
        let jobs = mk_jobs(m, steps);
        let cfg = ClusterConfig { boards: fb, sync_every: 20, ..Default::default() };
        let r = run_cluster(&cfg, &jobs).unwrap();
        let total_steps: usize = r.results.iter().map(|x| x.steps).sum();
        t.row(vec![
            m.to_string(),
            fb.to_string(),
            format!("{:?}", r.placement.mode),
            f(r.makespan_s * 1e3, 2),
            f(total_steps as f64 / r.makespan_s, 0),
            f(r.wall_s * 1e3, 1),
        ]);
    }
    print!("{}", t.render());
    println!("shape checks: M>F rows scale makespan ~M/F; F>M rows trade bus sync for compute.");
}
