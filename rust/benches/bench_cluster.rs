//! E-SCALE: cluster makespan across the paper's three scheduling regimes
//! — the multi-FPGA scaling claim of §2 — driven through the session
//! front door. Reports simulated makespan (the modelled hardware's time)
//! and host wall-clock (simulator cost).

use mfnn::bench::Suite;
use mfnn::cluster::{ring_sync_cost, star_sync_cost, ClusterConfig, SyncPolicy, SystemBus};
use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::report::{f, Table};
use mfnn::session::NetJob;
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Session, Target};
use std::sync::Arc;

const LR: f64 = 1.0 / 128.0;

fn mk_jobs(compiler: &Compiler, m: usize, steps: usize) -> Vec<NetJob> {
    let fixed = FixedSpec::q(10).saturating();
    (0..m)
        .map(|i| {
            let seed = 500 + i as u64;
            let spec = MlpSpec::from_dims(
                &format!("j{i}"), &[15, 24, 10], ActKind::Relu, ActKind::Identity,
                fixed, LutParams::training(fixed)).unwrap();
            let artifact =
                compiler.compile_spec(&spec, &CompileOptions::training(16, LR)).unwrap();
            let (train, test) = dataset::mini_digits(240, seed).split(0.8, &mut Rng::new(seed));
            NetJob {
                artifact,
                cfg: TrainConfig { batch: 16, lr: LR, steps, seed, log_every: 100 },
                train: Arc::new(train), test: Arc::new(test),
                resume: None,
            }
        })
        .collect()
}

fn main() {
    let compiler = Compiler::new();
    let quick = std::env::var("MFNN_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let steps = if quick { 20 } else { 80 };
    let mut t =
        Table::new(vec!["M", "F", "mode", "sim makespan ms", "Σsteps/s sim", "host wall ms"])
        .with_title(format!("cluster scaling sweep ({steps} steps/job)"))
        .numeric();
    for (m, fb) in [(1usize, 1usize), (2, 1), (4, 1), (8, 1), (4, 2), (4, 4), (2, 4), (1, 4)] {
        let jobs = mk_jobs(&compiler, m, steps);
        let cfg = ClusterConfig { boards: fb, sync_every: 20, ..Default::default() };
        let r = Session::train_many(&cfg, &jobs).unwrap();
        let total_steps: usize = r.results.iter().map(|x| x.steps).sum();
        t.row(vec![
            m.to_string(),
            fb.to_string(),
            format!("{:?}", r.placement.mode),
            f(r.makespan_s * 1e3, 2),
            f(total_steps as f64 / r.makespan_s, 0),
            f(r.wall_s * 1e3, 1),
        ]);
    }
    print!("{}", t.render());
    println!("shape checks: M>F rows scale makespan ~M/F; F>M rows trade bus sync for compute.");

    // ---- per-board hot path: one SGD train step / one evaluation ----
    // This is the loop every board-target session spends its life in;
    // its median is the train-step number tracked in BENCH_cluster.json.
    let mut suite = Suite::new("cluster");
    let job = mk_jobs(&compiler, 1, 1).pop().unwrap();
    let mut session =
        Session::open(Arc::clone(&job.artifact), Target::Board(FpgaDevice::selected()))
            .expect("bench session");
    let mut cfg = job.cfg.clone();
    cfg.steps = 1;
    let warm = session.train(&job.train, &cfg).expect("warmup step");
    let step_lane_ops = warm.stats.lane_ops;
    suite.bench("train_step_15-24-10_b16", |b| {
        b.iter_with_elements(step_lane_ops, || {
            session.train(&job.train, &cfg).unwrap().stats.cycles
        })
    });
    let warm_eval = session.evaluate(&job.test).expect("warmup eval");
    suite.bench("evaluate_48rows_b16", |b| {
        b.iter_with_elements(warm_eval.stats.lane_ops, || {
            session.evaluate(&job.test).unwrap().accuracy
        })
    });

    // ---- sync-policy scaling curves (BENCH_cluster.json "notes") ----
    // Per-collective bus cost of one weight sync of the bench net under
    // each policy, from the deterministic cost model, for group sizes
    // far beyond what the simulator can run in CI: star serialises
    // (k+1)·P through the leader endpoint, the ring pipelines 2(k−1)
    // chunks of P/k per board — ~O(k·P) vs ~O(P)/board makespan.
    let p_bytes =
        job.artifact.spec().expect("MLP bench artifact").param_bytes();
    let bus = SystemBus::default();
    suite.note("sync_param_bytes", p_bytes);
    for k in [2usize, 4, 8, 16, 32, 64] {
        let star = star_sync_cost(k, p_bytes, &bus);
        let ring = ring_sync_cost(k, p_bytes, &bus);
        suite.note(&format!("sync_cycles_star_f{k}"), star.cycles);
        suite.note(&format!("sync_cycles_ring_f{k}"), ring.cycles);
        suite.note(&format!("sync_bytes_star_f{k}"), star.bytes);
        suite.note(&format!("sync_bytes_ring_f{k}"), ring.bytes);
    }
    // Measured end-to-end divided runs at the small group sizes CI can
    // afford — one job over F boards per policy. Star and ring report
    // identical trained state (asserted by tests/sync_policy.rs); the
    // notes track what each pays on the modeled bus for it, and how
    // many collectives bounded staleness actually performs.
    let policies = [
        SyncPolicy::Star,
        SyncPolicy::Ring,
        SyncPolicy::BoundedStale { max_lag: 1 },
    ];
    for fb in [2usize, 4] {
        for sync in policies {
            let jobs = mk_jobs(&compiler, 1, steps);
            let cfg = ClusterConfig { boards: fb, sync_every: 20, sync, ..Default::default() };
            let r = Session::train_many(&cfg, &jobs).unwrap();
            let tag = format!("divided_f{fb}_{}", sync.name());
            suite.note(&format!("{tag}_sync_rounds"), r.metrics.sync_rounds);
            suite.note(&format!("{tag}_sync_cycles"), r.metrics.sync_cycles);
            suite.note(&format!("{tag}_bus_bytes"), r.metrics.bus_bytes);
            suite.note(&format!("{tag}_makespan_us"), f(r.makespan_s * 1e6, 1));
        }
    }
    suite.finish();
}
