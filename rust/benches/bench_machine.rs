//! The Matrix Machine hot path (§Perf): fast-simulator throughput on the
//! waves MLP training is made of — forward dots, backprop outer-product
//! dots, elementwise updates, LUT activations — and whole train-step
//! rates. Throughput is lane-ops per host second (the quantity the perf
//! pass optimises; see EXPERIMENTS.md §Perf).

use mfnn::bench::Suite;
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::graph::{lower_mlp_forward as lower_forward, lower_mlp_train as lower_train_step};
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::util::Rng;

fn spec(dims: &[usize]) -> MlpSpec {
    let fixed = FixedSpec::q(10).saturating();
    MlpSpec::from_dims(
        "bench",
        dims,
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap()
}

fn bind_random(m: &mut MatrixMachine, p: &mfnn::assembler::Program, seed: u64) {
    let mut r = Rng::new(seed);
    for b in p.buffers.clone() {
        use mfnn::assembler::BufKind::*;
        if matches!(b.kind, Input | Weight | Bias | Target) {
            let data: Vec<i16> = (0..b.len()).map(|_| r.gen_range_i64(-800, 800) as i16).collect();
            m.bind_named(&b.name, &data).unwrap();
        }
    }
}

fn main() {
    let device = FpgaDevice::selected();
    let mut suite = Suite::new("machine");

    // forward pass throughput at three scales
    for dims in [vec![15, 16, 10], vec![64, 64, 32], vec![128, 256, 64]] {
        let s = spec(&dims);
        let batch = 16;
        let h = lower_forward(&s, batch).unwrap();
        let lane_ops = h.program.total_lane_ops();
        let mut m = MatrixMachine::new(device, &h.program).unwrap();
        bind_random(&mut m, &h.program, 1);
        suite.bench(
            &format!("fwd_{}x{}x{}_b{batch} ({lane_ops} lane-ops)", dims[0], dims[1], dims[2]),
            |b| b.iter_with_elements(lane_ops, || m.execute()),
        );
    }

    // train step throughput
    for dims in [vec![15, 16, 10], vec![64, 64, 32]] {
        let s = spec(&dims);
        let batch = 16;
        let h = lower_train_step(&s, batch, 1.0 / 128.0).unwrap();
        let lane_ops = h.program.total_lane_ops();
        let mut m = MatrixMachine::new(device, &h.program).unwrap();
        bind_random(&mut m, &h.program, 2);
        suite.bench(
            &format!("train_{}x{}x{}_b{batch} ({lane_ops} lane-ops)", dims[0], dims[1], dims[2]),
            |b| b.iter_with_elements(lane_ops, || m.execute()),
        );
    }
    suite.finish();
    println!("(throughput = fixed-point lane-ops per host second through the full machine model)");
}
