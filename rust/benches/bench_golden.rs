//! E-GOLD perf: the PJRT-executed JAX/Pallas golden model vs the Rust
//! fast simulator on identical MLP train steps — the "CPU baseline vs
//! accelerator model" comparison of the paper's §1, scaled to this
//! testbed. Requires `make artifacts`.

use mfnn::bench::Suite;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::graph::lower_mlp_train as lower_train_step;
use mfnn::runtime::{GoldenModel, Runtime};
use mfnn::util::Rng;

fn main() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.toml").exists() {
        eprintln!("skipping bench_golden: run `make artifacts` first");
        return;
    }
    let g = GoldenModel::open(&dir).expect("open artifacts");
    let h = lower_train_step(&g.spec, g.batch, g.lr).unwrap();
    let lane_ops = h.program.total_lane_ops();
    let fsp = g.spec.fixed;
    let mut r = Rng::new(5);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| fsp.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    let ws: Vec<Vec<i16>> = g.spec.layers.iter().map(|l| rand(l.inputs * l.outputs, 1.2)).collect();
    let bs: Vec<Vec<i16>> = g.spec.layers.iter().map(|l| rand(l.outputs, 0.4)).collect();
    let x = rand(g.batch * g.spec.input_dim(), 2.0);
    let y = rand(g.batch * g.spec.output_dim(), 1.0);

    let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
    m.bind_named("x", &x).unwrap();
    m.bind_named("y", &y).unwrap();
    for l in 0..g.spec.layers.len() {
        m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
        m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
    }

    let mut suite = Suite::new("golden");
    suite.bench(&format!("sim_train_step ({lane_ops} lane-ops)"), |b| {
        b.iter_with_elements(lane_ops, || m.execute())
    });
    suite.bench("golden_pjrt_train_step", |b| {
        b.iter_with_elements(lane_ops, || g.train_step(&x, &y, &ws, &bs).unwrap())
    });
    suite.bench("golden_pjrt_forward", |b| {
        b.iter_with_elements(lane_ops, || g.forward(&x, &ws, &bs).unwrap())
    });
    suite.finish();
    println!("(same numerical work; sim also charges the hardware cycle model)");
}
