//! Cross-module property-based tests (mini prop framework — DESIGN.md
//! S27): coordinator invariants (routing/placement, cycle accounting,
//! program state), ISA encodings over their full domains, fixed-point
//! algebra, and semantics preservation of the optimiser on random
//! programs.

use mfnn::assembler::optimizer;
use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
use mfnn::cluster::schedule;
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine, MemPlan, PlanError};
use mfnn::isa::{Instruction, Microcode, Opcode, Width};
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::prop::{check, Gen};
use mfnn::util::Rng;

#[test]
fn instruction_words_roundtrip_over_full_fields() {
    let g = Gen::new(
        |r: &mut Rng| {
            let width = if r.gen_bool(0.5) { Width::W32 } else { Width::W48 };
            let op = *r.choose(&Opcode::ALL);
            let max_g = width.max_groups() as i64;
            let a = r.gen_range_i64(0, max_g - 1) as u16;
            let b = r.gen_range_i64(a as i64, max_g - 1) as u16;
            let iters = r.gen_range_i64(0, width.max_iterations() as i64) as u32;
            (width, Instruction::new(op, a, b, iters))
        },
        |_| Vec::new(),
    );
    check("instruction_roundtrip", g, |&(width, i)| {
        let raw = i.encode(width).unwrap();
        raw >> width.bits() == 0 && Instruction::decode(raw, width).unwrap() == i
    });
}

#[test]
fn microcode_decode_is_total_inverse_of_encode() {
    let g = Gen::new(|r: &mut Rng| r.next_u32(), |_| Vec::new());
    check("microcode_total", g, |&w| Microcode::decode(w).encode() == w);
}

#[test]
fn fixed_point_algebra() {
    let pair = Gen::pair(Gen::i16s(), Gen::i16s());
    // wrap add/sub are exact inverses
    check("wrap_add_sub_inverse", pair.clone(), |&(a, b)| {
        let s = FixedSpec::q(7);
        s.sub(s.add(a, b), b) == a
    });
    // multiplication commutes in both modes
    check("mul_commutes", pair.clone(), |&(a, b)| {
        let w = FixedSpec::q(10);
        let sat = w.saturating();
        w.mul(a, b) == w.mul(b, a) && sat.mul(a, b) == sat.mul(b, a)
    });
    // saturating ops never wrap signs on same-sign overflow
    check("saturate_is_monotone_at_edges", pair, |&(a, b)| {
        let s = FixedSpec::q(7).saturating();
        let sum = a as i64 + b as i64;
        let got = s.add(a, b) as i64;
        got == sum.clamp(i16::MIN as i64, i16::MAX as i64)
    });
}

#[test]
fn dot_equals_sum_of_products_wideaccumulator() {
    let g = Gen::vec(Gen::pair(Gen::i16s(), Gen::i16s()), 1, 64);
    check("dot_linear", g, |pairs: &Vec<(i16, i16)>| {
        let s = FixedSpec::q(7);
        let (a, b): (Vec<i16>, Vec<i16>) = pairs.iter().cloned().unzip();
        let wide: i64 = pairs.iter().map(|&(x, y)| x as i64 * y as i64).sum();
        s.dot(&a, &b) == s.narrow(wide >> 7)
    });
}

#[test]
fn lut_clamp_is_monotone_for_monotone_activations() {
    // ReLU/sigmoid/tanh are monotone; a clamped (non-wrapping) LUT must
    // preserve that lane-wise for any shift.
    for kind in [ActKind::Relu, ActKind::Sigmoid, ActKind::Tanh] {
        let g = Gen::pair(Gen::i16s(), Gen::i16s());
        check(&format!("lut_monotone_{}", kind.name()), g, move |&(a, b)| {
            let lut = ActLut::build(kind, false, FixedSpec::q(10), AddrMode::Clamp, 5)
                .with_interp();
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            lut.apply_scalar(lo) <= lut.apply_scalar(hi)
        });
    }
}

#[test]
fn placement_covers_all_jobs_and_boards() {
    let g = Gen::pair(Gen::int_range(1, 40), Gen::int_range(1, 40));
    check("placement_total", g, |&(m, f)| {
        let p = schedule(m as usize, f as usize);
        // every job placed exactly once per board it owns; total queue
        // entries == total group memberships; no empty queues when M ≥ F.
        let memberships: usize = p.groups.iter().map(|g| g.len()).sum();
        let queued: usize = p.queues.iter().map(|q| q.len()).sum();
        memberships == queued
            && p.groups.iter().all(|g| !g.is_empty())
            && (m < f || p.queues.iter().all(|q| !q.is_empty()))
    });
}

/// Random valid single-wave programs for optimiser/machine properties.
fn random_program(r: &mut Rng) -> (Program, Vec<Vec<i16>>) {
    let fixed = if r.gen_bool(0.5) { FixedSpec::q(7) } else { FixedSpec::q(10).saturating() };
    let n = 4 + r.gen_range(40) as usize;
    let mut p = Program::new("prop", fixed);
    let nb = 3 + r.gen_range(3) as usize;
    let mut data = Vec::new();
    for i in 0..nb {
        p.buffer(&format!("b{i}"), n, 1, if i == 0 { BufKind::Input } else { BufKind::Output });
        data.push((0..n).map(|_| r.gen_i16()).collect());
    }
    let waves = 2 + r.gen_range(6) as usize;
    for _ in 0..waves {
        let op = *r.choose(&[
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
        ]);
        let a = r.gen_range(nb as u64) as usize;
        let b = r.gen_range(nb as u64) as usize;
        let o = 1 + r.gen_range((nb - 1) as u64) as usize;
        p.steps.push(Step::Wave(Wave {
            op,
            vec_len: n,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(a, n),
                b: Some(View::all(b, n)),
                out: View::all(o, n),
            }],
        }));
    }
    (p, data)
}

#[test]
fn optimizer_preserves_machine_state_on_random_programs() {
    let mut rng = Rng::new(0xBEEF);
    for _case in 0..40 {
        let (p, data) = random_program(&mut rng);
        p.check().unwrap();
        let mut opt = p.clone();
        optimizer::optimize(&mut opt);
        opt.check().expect("optimised program must stay valid");
        let run = |prog: &Program| -> Vec<Vec<i16>> {
            let mut m = MatrixMachine::new(FpgaDevice::selected(), prog).unwrap();
            for (i, d) in data.iter().enumerate() {
                m.write_id(i, d).unwrap();
            }
            m.execute();
            (0..data.len()).map(|i| m.read_id(i).to_vec()).collect()
        };
        assert_eq!(run(&p), run(&opt), "optimiser changed observable state");
    }
}

#[test]
fn machine_cycle_accounting_is_additive_and_deterministic() {
    let mut rng = Rng::new(0xFACE);
    for _case in 0..25 {
        let (p, data) = random_program(&mut rng);
        let mut m1 = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        let mut m2 = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        for (i, d) in data.iter().enumerate() {
            m1.write_id(i, d).unwrap();
            m2.write_id(i, d).unwrap();
        }
        let s1 = m1.execute();
        let s2 = m2.execute();
        assert_eq!(s1, s2, "same program+data must cost the same");
        assert_eq!(
            s1.cycles,
            s1.dma_cycles + s1.compute_cycles + s1.lut_cycles + s1.ring_cycles,
            "cycle breakdown must sum to the total"
        );
    }
}

#[test]
fn fixed_rescale_is_floor_division_for_signed_products() {
    // The documented product rounding rule (fixed/mod.rs): the Q.2F→Q.F
    // rescale is an arithmetic shift, i.e. FLOOR division by 2^F — so
    // negative products round toward −∞ and mul(a,b) vs -mul(-a,b) can
    // differ by at most one ULP. Holds for mul and dot, under both wrap
    // and saturate narrowing, and FLOAT_TOL absorbs the bias elsewhere.
    for spec in [FixedSpec::q(7), FixedSpec::q(10), FixedSpec::q(10).saturating()] {
        let two_f = 1i64 << spec.frac_bits;
        check(
            &format!("mul_floor_q{}", spec.frac_bits),
            Gen::pair(Gen::int_range(-32768, 32767), Gen::int_range(-32768, 32767)),
            |&(a, b)| {
                let (a, b) = (a as i16, b as i16);
                let wide = a as i64 * b as i64;
                let floor = spec.narrow(wide.div_euclid(two_f));
                let anti = spec.mul(a, b) as i64 + spec.mul((-(a as i32)) as i16, b) as i64;
                // exact floor semantics + the ≤ 1 ULP asymmetry bound
                // (checked away from the wrap/saturate range edges)
                spec.mul(a, b) == floor
                    && (a == i16::MIN || wide.abs() >= (1 << 22) || anti.abs() <= 1)
            },
        );
        let mut r = Rng::new(0xD07 + spec.frac_bits as u64);
        for _ in 0..200 {
            let n = 1 + r.gen_range(16) as usize;
            let a: Vec<i16> = (0..n).map(|_| (r.gen_i16() / 4)).collect();
            let b: Vec<i16> = (0..n).map(|_| (r.gen_i16() / 4)).collect();
            let acc = spec.dot_acc(&a, &b);
            assert_eq!(
                spec.dot(&a, &b),
                spec.narrow(acc.div_euclid(two_f)),
                "dot is not floor division at Q{}",
                spec.frac_bits
            );
        }
    }
}

/// Random valid programs with `Temp` scratch buffers for the memory
/// planner properties: buffer 0 is the input, the last the output,
/// everything between scratch. Operand draws may read a temp before any
/// write (exercising the planner's pinning rule) and destination draws
/// never target the input.
fn random_temp_program(r: &mut Rng) -> Program {
    let n = 4 + r.gen_range(24) as usize;
    let mut p = Program::new("memprop", FixedSpec::q(10).saturating());
    let nt = 2 + r.gen_range(4) as usize;
    p.buffer("x", n, 1, BufKind::Input);
    for i in 0..nt {
        p.buffer(&format!("t{i}"), n, 1, BufKind::Temp);
    }
    p.buffer("o", n, 1, BufKind::Output);
    let nb = nt + 2;
    let waves = 2 + r.gen_range(8) as usize;
    for _ in 0..waves {
        let op = *r.choose(&[
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
        ]);
        let a = r.gen_range(nb as u64) as usize;
        let b = r.gen_range(nb as u64) as usize;
        let o = 1 + r.gen_range((nb - 1) as u64) as usize;
        p.steps.push(Step::Wave(Wave {
            op,
            vec_len: n,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(a, n),
                b: Some(View::all(b, n)),
                out: View::all(o, n),
            }],
        }));
    }
    p
}

#[test]
fn memplan_overlapping_intervals_never_share_lanes() {
    // The planner's soundness invariant: two buffers may occupy
    // overlapping lane ranges only if their live intervals are disjoint
    // (and the planned arena never exceeds the packed one).
    let mut rng = Rng::new(0x3E3);
    for _case in 0..120 {
        let p = random_temp_program(&mut rng);
        p.check().unwrap();
        let mp = MemPlan::build(&p);
        assert!(mp.peak_lanes() <= mp.packed_lanes());
        let layout = mp.layout();
        let iv = mp.intervals();
        for i in 0..layout.len() {
            for j in i + 1..layout.len() {
                let (bi, li) = layout[i];
                let (bj, lj) = layout[j];
                let lanes_overlap = bi < bj + lj && bj < bi + li;
                assert!(
                    !(lanes_overlap && iv[i].overlaps(&iv[j])),
                    "buffers {i} and {j} share lanes while live together: \
                     {:?}/{:?} at {:?}/{:?}",
                    iv[i],
                    iv[j],
                    layout[i],
                    layout[j]
                );
            }
        }
    }
}

#[test]
fn memplan_intervals_cover_every_reference() {
    // Completeness: recompute every buffer reference by walking the
    // schedule; the planner's interval must cover each one.
    let mut rng = Rng::new(0xC0F);
    for _case in 0..120 {
        let p = random_temp_program(&mut rng);
        let mp = MemPlan::build(&p);
        let iv = mp.intervals();
        for (s, step) in p.steps.iter().enumerate() {
            let mut refs: Vec<usize> = Vec::new();
            match step {
                Step::LoadDram(b) | Step::StoreDram(b) => refs.push(*b),
                Step::LoadLut(_) => {}
                Step::Wave(w) => {
                    for l in &w.lanes {
                        refs.push(l.a.buf);
                        if let Some(b) = &l.b {
                            refs.push(b.buf);
                        }
                        refs.push(l.out.buf);
                    }
                }
            }
            for b in refs {
                assert!(
                    iv[b].covers(s),
                    "buffer {b} referenced at step {s} outside its interval {:?}",
                    iv[b]
                );
            }
        }
    }
}

#[test]
fn memplan_exceeds_board_iff_demand_exceeds_capacity() {
    // Board-fit contract, exhaustive over small capacities: ExceedsBoard
    // fires exactly when the planned peak demand exceeds the capacity,
    // and the typed error reports the demand and a valid split point.
    let mut rng = Rng::new(0xB0A);
    for _case in 0..25 {
        let p = random_temp_program(&mut rng);
        let mp = MemPlan::build(&p);
        for cap in 0..=mp.packed_lanes() + 2 {
            match mp.require_fit("prop-board", cap) {
                Ok(()) => assert!(mp.peak_lanes() <= cap),
                Err(PlanError::ExceedsBoard { demand, capacity, split_step, .. }) => {
                    assert!(mp.peak_lanes() > cap);
                    assert_eq!(demand, mp.peak_lanes());
                    assert_eq!(capacity, cap);
                    assert!(split_step < mp.steps(), "split point must be a schedule step");
                }
            }
        }
    }
}

#[test]
fn asm_parser_never_panics_on_mutated_sources() {
    // Fuzz-lite: random mutations of a valid source must parse or fail
    // with an error, never panic.
    const BASE: &str = concat!(
        "NET a\nFIXED 10 saturate\nINPUT x 4 2\nWEIGHT w 2 3\nBIAS b 3\n",
        "ACT k relu shift=5 mode=clamp interp=1\nMLP o x w b k\nOUTPUT o\n",
        "TARGET y 4 3\nTRAIN lr=0.0078125\n"
    );
    let mut rng = Rng::new(0xF00);
    for _ in 0..300 {
        let mut s: Vec<u8> = BASE.bytes().collect();
        for _ in 0..1 + rng.gen_range(6) {
            let i = rng.gen_range(s.len() as u64) as usize;
            match rng.gen_range(3) {
                0 => s[i] = rng.gen_range(128) as u8,
                1 => {
                    s.remove(i);
                }
                _ => s.insert(i, b' '),
            }
        }
        if let Ok(text) = String::from_utf8(s) {
            let _ = mfnn::asm::lower_file(&text); // must not panic
        }
    }
}

#[test]
fn checker_intervals_bound_real_execution() {
    // Interval soundness (DESIGN.md §Static analysis): for random raw
    // programs, every lane value FastSim leaves behind must fall inside
    // the final range the static checker certified under the matching
    // host envelope (the generator binds data within ±6000) — and none
    // of those programs may draw a Standard-level diagnostic.
    use mfnn::analysis::{check_program, CheckLevel, CheckOptions};
    use mfnn::hw::FastSim;
    use mfnn::testkit::gen;
    check("interval_soundness", gen::program_case(), |c| {
        let (p, binds) = c.build();
        let opts = CheckOptions::new(CheckLevel::Standard).with_host_bound(6000);
        let report = check_program(&p, &opts);
        if !report.is_clean() {
            return false;
        }
        let mut sim = FastSim::new(&p);
        for (id, data) in &binds {
            sim.set_buffer(*id, data);
        }
        for step in &p.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(&p, w);
            }
        }
        report.ranges.iter().enumerate().all(|(b, ranges)| {
            sim.buffer(b)
                .iter()
                .zip(ranges)
                .all(|(&v, r)| (v as i64) >= r.0 && (v as i64) <= r.1)
        })
    });
}
