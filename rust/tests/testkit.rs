//! Acceptance tests for the differential-fuzzing testkit (ISSUE 3):
//! generated cases agree at every fidelity level, a planted divergence
//! is caught → shrunk → reproduced from its printed seed, and the corpus
//! snapshots replay clean.

use mfnn::testkit::{self, Family, FuzzOptions};

fn opts(cases: usize, seed: u64) -> FuzzOptions {
    FuzzOptions { cases, seed, ..FuzzOptions::default() }
}

#[test]
fn generated_cases_have_zero_divergences() {
    // Bounded smoke of the acceptance run (`mfnn fuzz --cases 64 --seed 0`
    // is the CI/CLI version of this): every case, every family, every
    // applicable fidelity level.
    let report = testkit::fuzz(&opts(4, 0));
    assert!(report.ok(), "{}", report.render());
    assert_eq!(report.cases, 4);
    assert_eq!(report.families, Family::ALL.len());
}

#[test]
fn planted_divergence_is_caught_shrunk_and_reproduced() {
    let o = FuzzOptions {
        cases: 1,
        seed: 7,
        plant_divergence: true,
        max_shrink_steps: 40,
        ..FuzzOptions::default()
    };
    let report = testkit::fuzz(&o);
    assert!(!report.ok(), "planted divergence was not caught");
    let f = report
        .failures
        .iter()
        .find(|f| f.family == Family::Net)
        .expect("plant lives in the net family");
    // caught at a bit-exact level, with the seed that replays it
    assert!(f.divergence.contains("fused_plan"), "{}", f.divergence);
    assert_eq!(f.seed, 7, "case 0 must run at the base seed for exact replay");
    assert!(f.reproduced, "failure did not reproduce from printed seed {}", f.seed);
    // shrinking bottoms out at a minimal net (the plant diverges for
    // every case, so greedy shrinking reaches the 1→1 net unless the
    // original already was minimal)
    assert!(f.shrunk.len() <= f.original.len(), "shrunk case grew: {f:?}");
    assert!(report.render().contains("mfnn fuzz --cases 1 --seed 7"));
    // the same seed with the plant disabled is clean — the divergence was
    // the planted one, not a real regression
    let clean = testkit::fuzz(&opts(1, 7));
    assert!(clean.ok(), "{}", clean.render());
}

#[test]
fn corpus_case_seeds_replay_clean() {
    let text = include_str!("corpus/cases.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "corpus unexpectedly small");
    assert!(entries.iter().any(|(f, _)| *f == Family::Net));
    assert!(entries.iter().any(|(f, _)| *f == Family::Program));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_graph_seeds_replay_clean() {
    // The CI graph smoke (`mfnn fuzz --family graph --cases 8`) plus
    // this pinned corpus: generated operator graphs (residual / gated /
    // CNN / transformer-block) must agree across every fidelity level.
    let text = include_str!("corpus/graph.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "graph corpus unexpectedly small");
    assert!(entries.iter().all(|(f, _)| *f == Family::Graph));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_fault_seeds_replay_clean() {
    let text = include_str!("corpus/faults.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(!entries.is_empty());
    assert!(entries.iter().all(|(f, _)| *f == Family::Fault));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_recovery_seeds_replay_clean() {
    // The CI recovery smoke (`mfnn fuzz --family recovery --cases 8`)
    // plus this pinned corpus: survivable fault plans must complete
    // bit-identically to the fault-free run.
    let text = include_str!("corpus/recovery.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "recovery corpus unexpectedly small");
    assert!(entries.iter().all(|(f, _)| *f == Family::Recovery));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_serve_chaos_seeds_replay_clean() {
    // The CI chaos smoke (`mfnn fuzz --family serve-chaos --cases 8`)
    // plus this pinned corpus: survivable serving fault plans must
    // terminate every admitted request as a completion or a typed drop,
    // bit-identical to the batch-1 reference and replay-deterministic.
    let text = include_str!("corpus/serve_chaos.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "serve-chaos corpus unexpectedly small");
    assert!(entries.iter().all(|(f, _)| *f == Family::ServeChaos));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_memplan_seeds_replay_clean() {
    // The CI memplan smoke (`mfnn fuzz --family memplan --cases 8`) plus
    // this pinned corpus: the static memory planner must be
    // behaviour-invisible — bit-identical outputs and RunStats with the
    // lane-reuse layout on vs off, planned arena never larger.
    let text = include_str!("corpus/memplan.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "memplan corpus unexpectedly small");
    assert!(entries.iter().all(|(f, _)| *f == Family::Memplan));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn corpus_check_seeds_replay_clean() {
    // The CI checker smoke (`mfnn fuzz --family check --cases 8`) plus
    // this pinned corpus: every planted defect must be flagged and every
    // checker-clean program must execute within its certified ranges.
    let text = include_str!("corpus/check.seeds");
    let entries = testkit::parse_corpus(text).unwrap();
    assert!(entries.len() >= 8, "check corpus unexpectedly small");
    assert!(entries.iter().all(|(f, _)| *f == Family::Check));
    let report = testkit::replay_corpus(&entries, &FuzzOptions::default());
    assert!(report.ok(), "{}", report.render());
}

#[test]
fn check_generator_reaches_every_defect_variant() {
    use mfnn::testkit::gen::{self, CheckDefect};
    use mfnn::util::Rng;
    let g = gen::check_case();
    let (mut undef, mut ovf, mut ring, mut haz, mut clean) = (false, false, false, false, false);
    for i in 0..64 {
        match g.sample(&mut Rng::new(testkit::case_seed(0, i))).defect {
            CheckDefect::UndefinedRead => undef = true,
            CheckDefect::Overflow => ovf = true,
            CheckDefect::RingOverrun => ring = true,
            CheckDefect::Hazard => haz = true,
            CheckDefect::Clean(_) => clean = true,
        }
    }
    assert!(
        undef && ovf && ring && haz && clean,
        "defect sweep incomplete: undef={undef} ovf={ovf} ring={ring} haz={haz} clean={clean}"
    );
}

#[test]
fn every_placement_mode_is_reachable_by_the_generator() {
    // The M×F sweep must actually exercise all three §2 placements
    // within a modest case budget.
    use mfnn::testkit::gen;
    use mfnn::util::Rng;
    let g = gen::fuzz_case();
    let (mut one, mut seq, mut div) = (false, false, false);
    for i in 0..64 {
        let c = g.sample(&mut Rng::new(testkit::case_seed(0, i)));
        match c.jobs.cmp(&c.boards) {
            std::cmp::Ordering::Equal => one = true,
            std::cmp::Ordering::Greater => seq = true,
            std::cmp::Ordering::Less => div = true,
        }
    }
    assert!(one && seq && div, "placement sweep incomplete: 1:1={one} seq={seq} div={div}");
}

#[test]
fn fault_generator_reaches_every_fault_kind() {
    use mfnn::testkit::gen;
    use mfnn::util::Rng;
    let g = gen::fault_case();
    let (mut kills, mut corrupts, mut delays, mut reorders) = (0, 0, 0, 0);
    for i in 0..128 {
        let c = g.sample(&mut Rng::new(testkit::case_seed(1, i)));
        kills += c.plan.kills.len();
        corrupts += c.plan.corruptions.len();
        delays += c.plan.delays.len();
        reorders += c.plan.reorders.len();
    }
    assert!(
        kills > 0 && corrupts > 0 && delays > 0 && reorders > 0,
        "fault sweep incomplete: kills={kills} corrupts={corrupts} \
         delays={delays} reorders={reorders}"
    );
}
