//! Operator-graph IR end-to-end (DESIGN.md §Operator IR): the MlpSpec
//! migration is observationally perfect — identical outputs *and*
//! identical cycle accounting against the frozen legacy lowering — and
//! graph-native nets (a small CNN and a transformer block) compile,
//! train, infer, evaluate and serve through the production
//! Compiler → Artifact → Session → Server stack bit-exactly.

use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::dataset::Dataset;
use mfnn::nn::graph::{Conv2dGeom, GraphSpec, INPUT};
use mfnn::nn::lowering::{legacy_lower_forward, legacy_lower_train_step, LoweredMlp};
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::serve::{ServeConfig, Server};
use mfnn::session::{Artifact, CompileOptions, Compiler, Session, Target};
use mfnn::testkit::{Differ, GraphArch, GraphCase};
use mfnn::util::Rng;
use std::sync::Arc;

// ---------------------------------------------------------------------
// MlpSpec through the graph path ≡ legacy lowering, incl. cycle stats.
// ---------------------------------------------------------------------

#[test]
fn mlp_outputs_and_cycle_stats_match_legacy_lowering() {
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "bitident",
        &[6, 8, 4],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let device = FpgaDevice::selected();
    let mut rng = Rng::new(0xB17);
    let mut rand = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| fixed.from_f64((rng.gen_f64() - 0.5) * amp)).collect()
    };
    let ws: Vec<Vec<i16>> = spec.layers.iter().map(|l| rand(l.inputs * l.outputs, 1.0)).collect();
    let bs: Vec<Vec<i16>> = spec.layers.iter().map(|l| rand(l.outputs, 0.4)).collect();
    let x = rand(3 * 6, 2.0);
    let y = rand(3 * 4, 1.0);

    let run = |h: &LoweredMlp, with_y: bool| {
        let mut m = MatrixMachine::new(device, &h.program).unwrap();
        m.bind_named("x", &x[..h.batch * 6]).unwrap();
        if with_y {
            m.bind_named("y", &y[..h.batch * 4]).unwrap();
        }
        for l in 0..spec.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        let stats = m.execute();
        let mut state = vec![m.read_named("o1").unwrap().to_vec()];
        if with_y {
            state.push(m.read_named("loss").unwrap().to_vec());
            for l in 0..spec.layers.len() {
                state.push(m.read_named(&format!("w{l}")).unwrap().to_vec());
                state.push(m.read_named(&format!("b{l}")).unwrap().to_vec());
            }
        }
        (state, stats)
    };

    // Forward, batch 3.
    let g = mfnn::nn::graph::lower_mlp_forward(&spec, 3).unwrap();
    let l = legacy_lower_forward(&spec, 3).unwrap();
    let (g_out, g_stats) = run(&g, false);
    let (l_out, l_stats) = run(&l, false);
    assert_eq!(g_out, l_out, "forward outputs diverge");
    assert_eq!(g_stats, l_stats, "forward cycle stats diverge");

    // Train step, batch 3: outputs, loss, updated params, cycle stats.
    let g = mfnn::nn::graph::lower_mlp_train(&spec, 3, 1.0 / 64.0).unwrap();
    let l = legacy_lower_train_step(&spec, 3, 1.0 / 64.0).unwrap();
    let (g_state, g_stats) = run(&g, true);
    let (l_state, l_stats) = run(&l, true);
    assert_eq!(g_state, l_state, "train-step state diverges");
    assert_eq!(g_stats, l_stats, "train-step cycle stats diverge");
}

// ---------------------------------------------------------------------
// Graph-native nets through the production stack.
// ---------------------------------------------------------------------

fn write_params(
    session: &mut Session,
    artifact: &Arc<Artifact>,
    spec: &GraphSpec,
    qw: &[Vec<i16>],
    qb: &[Vec<i16>],
) {
    for (i, d) in spec.param_decls().unwrap().iter().enumerate() {
        for (name, data) in [(&d.wname, &qw[i]), (&d.bname, &qb[i])] {
            let h = artifact.tensor(name).unwrap();
            session.write(&h, data).unwrap();
        }
    }
}

/// Train `spec` on a synthetic dataset, then assert the trained
/// parameters produce bit-identical outputs through (a) the trainable
/// session's own forward instance, (b) a fresh batch-1 inference
/// artifact, and (c) the batched serving runtime.
fn train_infer_evaluate_serve(spec: &GraphSpec, seed: u64) {
    let in_dim = spec.input_dim();
    let classes = spec.output_dim();
    let mut rng = Rng::new(seed);
    let n = 24;
    let x: Vec<Vec<f64>> =
        (0..n).map(|_| (0..in_dim).map(|_| rng.gen_f64() * 2.0 - 1.0).collect()).collect();
    let y: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mut v = vec![0.0; classes];
            v[i % classes] = 1.0;
            v
        })
        .collect();
    let ds = Dataset { x, y, classes, name: format!("{}-synthetic", spec.name) };

    let device = FpgaDevice::selected();
    let compiler = Compiler::new();
    let cfg = TrainConfig { batch: 4, lr: 1.0 / 64.0, steps: 8, seed, log_every: 4 };
    let art = compiler.compile_graph(spec, &CompileOptions::training(cfg.batch, cfg.lr)).unwrap();
    let mut session = Session::open(Arc::clone(&art), Target::Board(device)).expect("open");
    session.train(&ds, &cfg).expect("train");
    let ev = session.evaluate(&ds).expect("evaluate");
    assert!((0.0..=1.0).contains(&ev.accuracy), "accuracy {}", ev.accuracy);
    let (qw, qb) = session.weights().expect("trained params");

    let fixed = spec.fixed;
    let rows: Vec<Vec<i16>> = ds.x.iter().take(3).map(|x| fixed.encode_vec(x)).collect();

    // Batch-1 reference with the trained parameters.
    let a1 = compiler.compile_graph(spec, &CompileOptions::inference(1)).unwrap();
    let mut reference = Session::open(Arc::clone(&a1), Target::Board(device)).unwrap();
    write_params(&mut reference, &a1, spec, &qw, &qb);
    let want: Vec<Vec<i16>> =
        rows.iter().map(|r| reference.infer(r).expect("reference infer").output).collect();

    // (a) The trainable session's forward instance agrees to the bit.
    for (r, w) in rows.iter().zip(&want) {
        assert_eq!(&session.infer(r).expect("trained infer").output, w, "trained-session infer");
    }

    // (c) The serving runtime returns the same bits per request,
    // through micro-batching and the forward batch ladder.
    let srv = compiler.compile_graph(spec, &CompileOptions::serving(4)).unwrap();
    let scfg = ServeConfig {
        boards: 2,
        device: device.part.name.to_string(),
        max_batch: 4,
        ..ServeConfig::default()
    };
    let mut server = Server::open(scfg).expect("server open");
    let nid = server.register(Arc::clone(&srv), &qw, &qb).expect("register");
    for (i, r) in rows.iter().enumerate() {
        server.submit_at(i as u64 * 3, nid, r).expect("submit");
    }
    server.drain().expect("drain");
    let mut got = server.take_completions();
    got.sort_by_key(|c| c.id);
    assert_eq!(got.len(), rows.len(), "one completion per request");
    for (c, w) in got.iter().zip(&want) {
        assert_eq!(&c.output, w, "served request {} diverged from batch-1 infer", c.id);
    }
}

#[test]
fn cnn_trains_infers_evaluates_and_serves() {
    // 4×4 single-channel images → 2×2 conv (3 maps) → ReLU → classifier.
    let fixed = FixedSpec::q(9).saturating();
    let geom = Conv2dGeom { in_h: 4, in_w: 4, in_c: 1, out_c: 3, kh: 2, kw: 2, stride: 1 };
    let mut s = GraphSpec::new("tiny_cnn", 16, fixed, LutParams::training(fixed));
    let c = s.conv2d(INPUT, geom);
    let a = s.activation(c, ActKind::Relu);
    s.linear(a, 3);
    train_infer_evaluate_serve(&s, 0xC2201);
}

#[test]
fn transformer_block_trains_infers_evaluates_and_serves() {
    // Pre-head transformer block over 3 tokens of width 2: attention +
    // residual + norm, two-layer FFN + residual + norm, linear head.
    let fixed = FixedSpec::q(8).saturating();
    let (seq, d) = (3, 2);
    let mut s = GraphSpec::new("tiny_xfmr", seq * d, fixed, LutParams::training(fixed));
    let att = s.attention(INPUT, seq, d);
    let r1 = s.add(att, INPUT);
    let n1 = s.normalization(r1, d);
    let f1 = s.linear(n1, seq * d);
    let fa = s.activation(f1, ActKind::Relu);
    let f2 = s.linear(fa, seq * d);
    let r2 = s.add(f2, n1);
    let n2 = s.normalization(r2, d);
    s.linear(n2, 3);
    train_infer_evaluate_serve(&s, 0x7F02);
}

// ---------------------------------------------------------------------
// Every generated-graph architecture through the differential ladder.
// ---------------------------------------------------------------------

#[test]
fn generated_graph_cases_agree_across_fidelity_levels() {
    let differ = Differ::default();
    for arch in
        [GraphArch::Residual, GraphArch::Gated, GraphArch::Cnn, GraphArch::TransformerBlock]
    {
        let c = GraphCase {
            seed: 0xE2E,
            arch,
            dim: 3,
            hidden: 2,
            act: ActKind::Relu,
            frac_bits: 9,
            batch: 2,
        };
        if let Err(div) = differ.run_graph(&c) {
            panic!("{arch:?}: {div:?}");
        }
    }
}
