//! Session front-door integration tests (ISSUE 2 acceptance):
//!
//! * `Session::infer` is **bit-identical** — outputs and cycle stats —
//!   to the legacy `MatrixMachine` structurally-verified path on
//!   randomized networks.
//! * The artifact cache really is compile-once: a second compile of the
//!   same net returns the same `Arc`, and a second open of the same
//!   `(net, device)` pair does not rebuild the `ExecPlan`.
//! * Typed-handle diagnostics: unknown tensors suggest near misses,
//!   foreign handles and shape mismatches are rejected, train configs
//!   must match the compiled artifact.

use mfnn::cluster::ClusterConfig;
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::dataset;
use mfnn::nn::graph::lower_mlp_forward as lower_forward;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::session::{CompileOptions, Compiler, Error, NetJob, Session, Target};
use mfnn::util::Rng;
use std::sync::Arc;

fn random_spec(r: &mut Rng, name: &str) -> (MlpSpec, usize) {
    let fixed =
        if r.gen_bool(0.5) { FixedSpec::PAPER } else { FixedSpec::q(10).saturating() };
    let n_layers = 1 + r.gen_range(2) as usize;
    let mut dims = vec![1 + r.gen_range(12) as usize];
    for _ in 0..n_layers {
        dims.push(1 + r.gen_range(20) as usize);
    }
    let spec = MlpSpec::from_dims(
        name,
        &dims,
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let batch = 1 + r.gen_range(16) as usize;
    (spec, batch)
}

fn rand_q(r: &mut Rng, f: FixedSpec, n: usize, amp: f64) -> Vec<i16> {
    (0..n).map(|_| f.from_f64((r.gen_f64() - 0.5) * amp)).collect()
}

#[test]
fn session_infer_bit_identical_to_legacy_verified_path() {
    let compiler = Compiler::new();
    let device = FpgaDevice::selected();
    let mut r = Rng::new(0xA11CE);
    for case in 0..6u64 {
        let (spec, batch) = random_spec(&mut r, &format!("net{case}"));
        let f = spec.fixed;
        let artifact = compiler.compile_spec(&spec, &CompileOptions::inference(batch)).unwrap();
        let mut s = Session::open(Arc::clone(&artifact), Target::Board(device)).unwrap();

        // identical random parameters on both paths
        let ws: Vec<Vec<i16>> = spec
            .layers
            .iter()
            .map(|l| rand_q(&mut r, f, l.inputs * l.outputs, 1.2))
            .collect();
        let bs: Vec<Vec<i16>> =
            spec.layers.iter().map(|l| rand_q(&mut r, f, l.outputs, 0.4)).collect();
        let qx = rand_q(&mut r, f, batch * spec.input_dim(), 2.0);

        for l in 0..spec.layers.len() {
            s.write(&artifact.tensor(&format!("w{l}")).unwrap(), &ws[l]).unwrap();
            s.write(&artifact.tensor(&format!("b{l}")).unwrap(), &bs[l]).unwrap();
        }
        let inf = s.infer(&qx).unwrap();

        // legacy path: hand-lowered program on a hand-built machine,
        // executed with full structural verification
        let lowered = lower_forward(&spec, batch).unwrap();
        let mut m = MatrixMachine::new(device, &lowered.program).unwrap();
        m.bind_named("x", &qx).unwrap();
        for l in 0..spec.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        let legacy_stats = m.execute_verified().unwrap();
        let last = spec.layers.len() - 1;
        let legacy_out = m.read_named(&format!("o{last}")).unwrap().to_vec();

        assert_eq!(inf.output, legacy_out, "case {case}: outputs diverge");
        assert_eq!(inf.stats.cycles, legacy_stats.cycles, "case {case}: cycles diverge");
        assert_eq!(inf.stats, legacy_stats, "case {case}: run stats diverge");
    }
}

#[test]
fn artifact_cache_compiles_once_per_net_and_device() {
    let compiler = Compiler::new();
    let mut r = Rng::new(0xCAFE);
    let (spec, batch) = random_spec(&mut r, "cached");
    let opts = CompileOptions::inference(batch);

    // same spec + options ⇒ same artifact Arc
    let a1 = compiler.compile_spec(&spec, &opts).unwrap();
    let a2 = compiler.compile_spec(&spec, &opts).unwrap();
    assert!(Arc::ptr_eq(&a1, &a2), "artifact was rebuilt");
    assert_eq!(compiler.cached(), 1);

    // first plan build is cached; a second open / plan request returns
    // the same compiled ExecPlan
    let device = FpgaDevice::selected();
    let p1 = a1.plan_for(&device);
    let _s1 = Session::open(Arc::clone(&a1), Target::Board(device)).unwrap();
    let _s2 = Session::open(Arc::clone(&a2), Target::Board(device)).unwrap();
    let p2 = a2.plan_for(&device);
    assert!(Arc::ptr_eq(&p1, &p2), "plan was rebuilt for the same (net, device)");

    // a different device gets its own plan
    let other = FpgaDevice::by_name("XC7S50-1").unwrap();
    assert!(!Arc::ptr_eq(&p1, &a1.plan_for(&other)));

    // different options ⇒ different artifact
    let a3 = compiler.compile_spec(&spec, &CompileOptions::inference(batch + 1)).unwrap();
    assert!(!Arc::ptr_eq(&a1, &a3));

    // asm source caches too
    const SRC: &str = "
NET cachedasm
INPUT x 4 2
WEIGHT w 2 2
BIAS b 2
ACT a relu
MLP o x w b a
OUTPUT o
";
    let b1 = compiler.compile_asm_net(SRC).unwrap();
    let b2 = compiler.compile_asm_net(SRC).unwrap();
    assert!(Arc::ptr_eq(&b1, &b2), "asm artifact was rebuilt");
}

#[test]
fn typed_handle_diagnostics() {
    let compiler = Compiler::new();
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "diag",
        &[4, 8, 2],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let artifact = compiler.compile_spec(&spec, &CompileOptions::inference(4)).unwrap();
    let mut s =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();

    // unknown tensor: near miss suggests the real name
    let err = artifact.tensor("w9").unwrap_err();
    assert!(matches!(err, Error::UnknownTensor { .. }));
    let msg = err.to_string();
    assert!(msg.contains("did you mean"), "no suggestion in {msg:?}");

    // shape mismatch carries the declared shape
    let w0 = artifact.tensor("w0").unwrap();
    assert_eq!((w0.rows(), w0.cols(), w0.len()), (4, 8, 32));
    let err = s.write(&w0, &[0i16; 3]).unwrap_err();
    assert!(matches!(err, Error::ShapeMismatch { expect: 32, got: 3, .. }), "{err}");

    // a handle from another artifact is rejected
    let other = compiler.compile_spec(&spec, &CompileOptions::inference(8)).unwrap();
    let foreign = other.tensor("w0").unwrap();
    assert!(matches!(s.write(&foreign, &[0i16; 32]), Err(Error::ForeignHandle { .. })));

    // train config must match the compiled artifact
    let trainable = compiler
        .compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0))
        .unwrap();
    let mut ts = Session::open(trainable, Target::Board(FpgaDevice::selected())).unwrap();
    let ds = dataset::blobs(64, 2, 4, 5);
    let bad = TrainConfig { batch: 16, lr: 1.0 / 128.0, steps: 1, seed: 1, log_every: 1 };
    assert!(matches!(
        ts.train(&ds, &bad),
        Err(Error::ConfigMismatch { what: "batch", .. })
    ));
    let bad = TrainConfig { batch: 8, lr: 1.0 / 64.0, steps: 1, seed: 1, log_every: 1 };
    assert!(matches!(ts.train(&ds, &bad), Err(Error::ConfigMismatch { what: "lr", .. })));
    // inference-only artifacts cannot train
    let cfg = TrainConfig { batch: 4, lr: 1.0 / 128.0, steps: 1, seed: 1, log_every: 1 };
    assert!(matches!(s.train(&ds, &cfg), Err(Error::Unsupported { verb: "train", .. })));
}

#[test]
fn board_session_trains_and_evaluates_like_the_engine() {
    let compiler = Compiler::new();
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "blobs3",
        &[4, 16, 3],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let ds = dataset::blobs(256, 3, 4, 1234);
    let (train, test) = ds.split(0.8, &mut Rng::new(5));
    let cfg = TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 150, seed: 42, log_every: 10 };
    let artifact =
        compiler.compile_spec(&spec, &CompileOptions::training(16, 1.0 / 256.0)).unwrap();
    let mut s = Session::open(artifact, Target::Board(FpgaDevice::selected())).unwrap();
    let before = s.evaluate(&test).unwrap();
    let report = s.train(&train, &cfg).unwrap();
    let after = s.evaluate(&test).unwrap();
    assert!(
        after.accuracy > 0.85 && after.accuracy > before.accuracy,
        "accuracy {} → {}",
        before.accuracy,
        after.accuracy
    );
    assert_eq!(report.boards, vec![0]);
    assert_eq!(report.sync_rounds, 0);
    assert!(report.stats.cycles > 0 && report.sim_seconds > 0.0);
    let first = report.curve.first().unwrap().loss;
    let last = report.curve.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} → {last}");
}

#[test]
fn evaluate_before_train_uses_seedless_zero_weights() {
    // An opened trainable session with no writes and no train yet has
    // all-zero parameters; evaluate must still run (and be uninformative).
    let compiler = Compiler::new();
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "zero",
        &[2, 4, 2],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let artifact =
        compiler.compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0)).unwrap();
    let mut s = Session::open(artifact, Target::Board(FpgaDevice::selected())).unwrap();
    let ds = dataset::xor(30, 2); // 30 % 8 != 0: exercises the partial chunk
    let e = s.evaluate(&ds).unwrap();
    assert!((0.0..=1.0).contains(&e.accuracy));
    assert!(e.stats.cycles > 0);
}

#[test]
fn cluster_session_trains_divided_and_adopts_weights() {
    let compiler = Compiler::new();
    let fixed = FixedSpec::q(10).saturating();
    let spec = MlpSpec::from_dims(
        "dp",
        &[4, 16, 3],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let ds = dataset::blobs(192, 3, 4, 77);
    let (train, test) = ds.split(0.75, &mut Rng::new(77));
    let artifact =
        compiler.compile_spec(&spec, &CompileOptions::training(16, 1.0 / 256.0)).unwrap();
    let ccfg = ClusterConfig { boards: 3, sync_every: 15, ..Default::default() };
    let mut s = Session::open(artifact, Target::Cluster(ccfg)).unwrap();
    let cfg = TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 60, seed: 9, log_every: 10 };
    let report = s.train(&train, &cfg).unwrap();
    assert_eq!(report.boards, vec![0, 1, 2], "1 net on 3 boards must divide");
    assert_eq!(report.sync_rounds, 4, "60 steps / sync_every 15");
    assert!(report.sim_seconds > 0.0);
    // the averaged weights were adopted: local evaluation reflects the
    // cluster training
    let e = s.evaluate(&test).unwrap();
    assert!(e.accuracy > 0.7, "divided training reached only {}", e.accuracy);
    // inference runs locally on the adopted weights
    let out = s.infer(&train.encode_rows(0..16, fixed)).unwrap();
    assert_eq!(out.output.len(), 16 * 3);
}

#[test]
fn train_many_runs_the_m_by_f_matrix() {
    let compiler = Compiler::new();
    let fixed = FixedSpec::q(10).saturating();
    let mk = |name: &str, seed: u64| {
        let spec = MlpSpec::from_dims(
            name,
            &[4, 16, 3],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let (train, test) = dataset::blobs(192, 3, 4, seed).split(0.75, &mut Rng::new(seed));
        NetJob {
            artifact: compiler
                .compile_spec(&spec, &CompileOptions::training(16, 1.0 / 256.0))
                .unwrap(),
            cfg: TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 40, seed, log_every: 10 },
            train: Arc::new(train),
            test: Arc::new(test),
            resume: None,
        }
    };
    let cfg = ClusterConfig { boards: 2, ..Default::default() };
    let report = Session::train_many(&cfg, &[mk("a", 1), mk("b", 2)]).unwrap();
    assert_eq!(report.results.len(), 2);
    assert!(report.results.iter().all(|r| r.steps == 40));
    assert!(report.makespan_s > 0.0);
    // compile-once held across the fleet: both jobs' artifacts cached
    assert!(compiler.cached() >= 2);
}

#[test]
fn raw_program_artifacts_step_with_handles() {
    use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
    use mfnn::isa::Opcode;
    let mut p = Program::new("raw", FixedSpec::PAPER);
    let a = p.buffer("a", 16, 1, BufKind::Input);
    let o = p.buffer("o", 16, 1, BufKind::Output);
    p.steps.push(Step::Wave(Wave {
        op: Opcode::VectorAddition,
        vec_len: 16,
        lut: None,
        lanes: vec![LaneOp {
            a: View::all(a, 16),
            b: Some(View::all(a, 16)),
            out: View::all(o, 16),
        }],
    }));
    let compiler = Compiler::new();
    let artifact = compiler.compile_program(&p).unwrap();
    let mut s =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();
    let ha = artifact.tensor("a").unwrap();
    let ho = artifact.tensor("o").unwrap();
    let data: Vec<i16> = (0..16).collect();
    s.write(&ha, &data).unwrap();
    let st = s.step();
    assert!(st.cycles > 0);
    let doubled: Vec<i16> = data.iter().map(|v| v * 2).collect();
    assert_eq!(s.read(&ho).unwrap(), doubled);
    // net-shaped verbs are cleanly unavailable
    assert!(matches!(s.infer(&data), Err(Error::Unsupported { verb: "infer", .. })));
}
