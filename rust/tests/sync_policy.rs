//! Weight-sync policy acceptance tests (DESIGN.md §Cluster): the ring
//! all-reduce must be **bit-identical** to the star gather/average/
//! broadcast on every M×F grid point — weights, biases, loss curves,
//! accuracy, stats, and checkpoints (modulo the recorded policy tag) —
//! while costing asymptotically less on the modeled bus; bounded
//! staleness with a zero lag budget must degenerate to star exactly;
//! and resuming a checkpoint on the wrong topology or under the wrong
//! policy must be a typed error.

use mfnn::cluster::leader::{execute, Job};
use mfnn::cluster::{
    ring_sync_cost, star_sync_cost, ClusterConfig, RecoveryPolicy, SyncPolicy, SystemBus,
};
use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::{CompileOptions, Compiler, Session, Target, TrainOptions};
use std::sync::Arc;

const LR: f64 = 1.0 / 128.0;

fn spec(name: &str) -> MlpSpec {
    let fixed = FixedSpec::q(10).saturating();
    MlpSpec::from_dims(
        name,
        &[2, 5, 2],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap()
}

fn mk_job(name: &str, seed: u64, steps: usize) -> Job {
    // Train and test share one dataset: blob centers are seed-derived,
    // so a differently-seeded test set would have different clusters.
    let ds = Arc::new(dataset::blobs(48, 2, 2, seed));
    Job {
        name: name.into(),
        spec: spec(name),
        cfg: TrainConfig { batch: 8, lr: LR, steps, seed, log_every: 4 },
        train_data: Arc::clone(&ds),
        test_data: ds,
        initial: None,
        resume: None,
    }
}

fn cfg(boards: usize, sync: SyncPolicy) -> ClusterConfig {
    ClusterConfig {
        boards,
        sync_every: 4,
        sync,
        recovery: RecoveryPolicy::checkpointed(4),
        ..Default::default()
    }
}

#[test]
fn ring_is_bit_identical_to_star_on_every_m_by_f_grid_point() {
    // The tentpole acceptance property, exhaustively: for every M×F in
    // 1..=8 × 1..=8 — covering all three placement modes (sequential
    // M>F, one-to-one M=F, divided M<F) — the ring all-reduce produces
    // the same trained state as the star default, bit for bit. Ring's
    // reduce-scatter sums each lane fully in i32 before the single
    // truncating divide, so associativity of the fixed-point average is
    // asserted here rather than assumed. Checkpoints must agree on
    // everything except the recorded policy tag itself.
    for boards in 1..=8usize {
        for jobs_n in 1..=8usize {
            let jobs: Vec<Job> = (0..jobs_n)
                .map(|j| mk_job(&format!("g{j}"), 90 + j as u64, 8))
                .collect();
            let star = execute(&cfg(boards, SyncPolicy::Star), &jobs).unwrap();
            let ring = execute(&cfg(boards, SyncPolicy::Ring), &jobs).unwrap();
            let at = format!("M={jobs_n} F={boards}");
            assert_eq!(star.placement, ring.placement, "placement differs at {at}");
            for (s, r) in star.results.iter().zip(&ring.results) {
                assert_eq!(s.weights, r.weights, "weights differ at {at} job {:?}", s.name);
                assert_eq!(s.biases, r.biases, "biases differ at {at} job {:?}", s.name);
                assert_eq!(s.curve, r.curve, "curves differ at {at} job {:?}", s.name);
                assert_eq!(s.accuracy, r.accuracy, "accuracy differs at {at}");
                assert_eq!(s.stats, r.stats, "stats differ at {at}");
                assert_eq!(
                    s.checkpoints.len(),
                    r.checkpoints.len(),
                    "checkpoint count differs at {at}"
                );
                for (cs, cr) in s.checkpoints.iter().zip(&r.checkpoints) {
                    assert_eq!(cr.sync, SyncPolicy::Ring, "ring checkpoint mistagged at {at}");
                    let mut retagged = cr.clone();
                    retagged.sync = SyncPolicy::Star;
                    assert_eq!(
                        *cs, retagged,
                        "checkpoints differ beyond the policy tag at {at}"
                    );
                }
            }
            if jobs_n < boards {
                // Divided placement actually synced, and the ring paid
                // for it on the modeled bus.
                assert!(star.metrics.sync_rounds > 0, "no syncs at divided {at}");
                assert_eq!(star.metrics.sync_rounds, ring.metrics.sync_rounds, "{at}");
                assert!(ring.metrics.sync_cycles > 0, "free ring sync at {at}");
            }
        }
    }
}

#[test]
fn bounded_stale_zero_lag_degenerates_to_star_exactly() {
    // `BoundedStale { max_lag: 0 }` never has lag budget to spend, so
    // every boundary performs the star collective — the whole report,
    // including bus accounting, must be identical.
    for (boards, jobs_n) in [(2, 1), (3, 1), (5, 2), (4, 4), (3, 6)] {
        let jobs: Vec<Job> = (0..jobs_n)
            .map(|j| mk_job(&format!("z{j}"), 7 + j as u64, 12))
            .collect();
        let star = execute(&cfg(boards, SyncPolicy::Star), &jobs).unwrap();
        let zero =
            execute(&cfg(boards, SyncPolicy::BoundedStale { max_lag: 0 }), &jobs).unwrap();
        let at = format!("M={jobs_n} F={boards}");
        for (s, z) in star.results.iter().zip(&zero.results) {
            assert_eq!(s.weights, z.weights, "{at}");
            assert_eq!(s.biases, z.biases, "{at}");
            assert_eq!(s.curve, z.curve, "{at}");
            assert_eq!(s.stats, z.stats, "{at}");
        }
        assert_eq!(star.metrics.sync_rounds, zero.metrics.sync_rounds, "{at}");
        assert_eq!(star.metrics.sync_cycles, zero.metrics.sync_cycles, "{at}");
        assert_eq!(star.metrics.bus_bytes, zero.metrics.bus_bytes, "{at}");
        assert_eq!(star.makespan_s, zero.makespan_s, "{at}");
    }
}

#[test]
fn bounded_stale_trains_through_skipped_collectives() {
    // A positive lag budget skips collectives (fewer sync rounds than
    // star) but the final boundary always syncs, the run replays
    // deterministically, and the job still learns the blobs.
    let jobs = vec![mk_job("bs", 42, 24)];
    let stale = SyncPolicy::BoundedStale { max_lag: 2 };
    let star = execute(&cfg(3, SyncPolicy::Star), &jobs).unwrap();
    let r1 = execute(&cfg(3, stale), &jobs).unwrap();
    let r2 = execute(&cfg(3, stale), &jobs).unwrap();
    assert!(
        r1.metrics.sync_rounds < star.metrics.sync_rounds,
        "lag budget {} vs {} never skipped a collective",
        r1.metrics.sync_rounds,
        star.metrics.sync_rounds
    );
    assert_eq!(r1.results[0].weights, r2.results[0].weights, "stale run nondeterministic");
    assert_eq!(r1.results[0].curve, r2.results[0].curve, "stale curve nondeterministic");
    assert!(r1.results[0].accuracy > 0.5, "stale run failed to learn: {}", r1.results[0].accuracy);
}

#[test]
fn ring_cost_scales_per_board_while_star_scales_with_the_group() {
    // The cost-model scaling claim for F up to 64: with a
    // bandwidth-dominated payload, star serialises k+1 full-parameter
    // transfers through the leader endpoint (O(k·P)) while the ring
    // moves 2(k−1) chunks of P/k per board concurrently (~O(P)/board) —
    // so the star/ring cycle ratio must grow monotonically with k.
    let bus = SystemBus::default();
    let p_bytes = 1 << 20; // 1 MiB of parameters: transfer ≫ latency
    let mut last_ratio = 0.0f64;
    for k in [2usize, 4, 8, 16, 32, 64] {
        let star = star_sync_cost(k, p_bytes, &bus);
        let ring = ring_sync_cost(k, p_bytes, &bus);
        assert!(
            ring.cycles < star.cycles,
            "ring {} !< star {} at k={k}",
            ring.cycles,
            star.cycles
        );
        let ratio = star.cycles as f64 / ring.cycles as f64;
        assert!(ratio > last_ratio, "star/ring ratio fell to {ratio:.2} at k={k}");
        last_ratio = ratio;
    }
    // By k=64 the modeled advantage is over an order of magnitude.
    assert!(last_ratio > 10.0, "only {last_ratio:.2}× at k=64");
}

fn session(name: &str, target: Target) -> Session {
    let compiler = Compiler::new();
    let artifact =
        compiler.compile_spec(&spec(name), &CompileOptions::training(8, LR)).unwrap();
    Session::open(artifact, target).unwrap()
}

#[test]
fn resume_on_a_different_board_count_is_a_typed_error() {
    // Regression for the RunIdentity gap: v1 checkpoints did not record
    // the cluster's board count F, so a snapshot cut on 2 boards could
    // silently resume on 3 where the divided schedule differs.
    let ds = dataset::blobs(96, 2, 2, 5);
    let c = TrainConfig { batch: 8, lr: LR, steps: 16, seed: 11, log_every: 4 };
    let two = ClusterConfig { boards: 2, sync_every: 4, ..Default::default() };
    let mut s = session("topo", Target::Cluster(two));
    let (_, ckpts) = s.train_with(&ds, &c, &TrainOptions::checkpoint_every(8)).unwrap();
    let ck = ckpts[0].clone();
    assert_eq!(ck.boards, 2, "checkpoint did not record F");
    let three = ClusterConfig { boards: 3, sync_every: 4, ..Default::default() };
    let mut other = session("topo", Target::Cluster(three));
    let err = other.train_with(&ds, &c, &TrainOptions::resume(ck)).unwrap_err();
    assert!(matches!(err, mfnn::Error::Checkpoint(_)), "{err}");
    assert!(err.to_string().contains("board"), "untyped topology error: {err}");
}

#[test]
fn resume_under_a_different_sync_policy_is_a_typed_error() {
    let ds = dataset::blobs(96, 2, 2, 5);
    let c = TrainConfig { batch: 8, lr: LR, steps: 16, seed: 13, log_every: 4 };
    let ring = ClusterConfig { boards: 2, sync_every: 4, sync: SyncPolicy::Ring, ..Default::default() };
    let mut s = session("policy", Target::Cluster(ring.clone()));
    let (_, ckpts) = s.train_with(&ds, &c, &TrainOptions::checkpoint_every(8)).unwrap();
    let ck = ckpts[0].clone();
    assert_eq!(ck.sync, SyncPolicy::Ring, "checkpoint did not record the policy");
    // Same topology, different policy: typed, names both policies.
    let star = ClusterConfig { boards: 2, sync_every: 4, ..Default::default() };
    let mut other = session("policy", Target::Cluster(star));
    let err = other.train_with(&ds, &c, &TrainOptions::resume(ck.clone())).unwrap_err();
    assert!(matches!(err, mfnn::Error::Checkpoint(_)), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("ring") && msg.contains("star"), "unhelpful policy error: {msg}");
    // The matching policy still resumes cleanly (and bit-exactly).
    let mut full = session("policy", Target::Cluster(ring.clone()));
    let (want, _) = full.train_with(&ds, &c, &TrainOptions::default()).unwrap();
    let mut resumed = session("policy", Target::Cluster(ring));
    let (got, _) =
        resumed.train_with(&ds, &c, &TrainOptions::resume(ck)).unwrap();
    assert_eq!(resumed.weights().unwrap(), full.weights().unwrap());
    assert_eq!(got.curve, want.curve);
}
