//! Golden tests for the static program checker (DESIGN.md §Static
//! analysis): one field-exact diagnostic per kind, zero diagnostics on
//! every compiler-emitted golden program, 100% catch rate on planted
//! defects, and the session wiring (`CompileOptions::with_checks`).

use mfnn::analysis::{check_program, CheckError, CheckLevel, CheckOptions, Diagnostic, Severity};
use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, PROCS_PER_GROUP};
use mfnn::isa::Opcode;
use mfnn::nn::graph::{
    lower_graph_forward, lower_mlp_forward, lower_mlp_train, Conv2dGeom, GraphSpec, INPUT,
};
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::testkit::gen::{self, CheckCase, CheckDefect};
use mfnn::testkit::{case_seed, Differ};
use mfnn::util::Rng;
use mfnn::{CompileOptions, Compiler, Error};
use std::sync::Arc;

/// One wave: `out = a + b` over whole buffers.
fn add_wave(a: usize, b: usize, out: usize, n: usize) -> Step {
    Step::Wave(Wave {
        op: Opcode::VectorAddition,
        vec_len: n,
        lut: None,
        lanes: vec![LaneOp { a: View::all(a, n), b: Some(View::all(b, n)), out: View::all(out, n) }],
    })
}

#[test]
fn undefined_read_is_flagged_field_exact() {
    let mut p = Program::new("t", FixedSpec::PAPER);
    let t = p.buffer("scratch", 4, 1, BufKind::Temp);
    let o = p.buffer("out", 4, 1, BufKind::Output);
    p.steps.push(add_wave(t, t, o, 4));
    let r = check_program(&p, &CheckOptions::new(CheckLevel::Standard));
    assert_eq!(
        r.diagnostics,
        vec![Diagnostic::UndefinedRead {
            step: 0,
            op: Opcode::VectorAddition,
            lane_idx: 0,
            buf: "scratch".into(),
            lane: 0,
        }]
    );
    assert_eq!(r.error_count(), 1);
}

#[test]
fn guaranteed_overflow_is_flagged_field_exact() {
    // Wrap-mode add of 30000+30000: every execution wraps.
    let mut p = Program::new("t", FixedSpec::q(7));
    let c = p.const_buffer("big", vec![30000; 4]);
    let o = p.buffer("out", 4, 1, BufKind::Output);
    p.steps.push(add_wave(c, c, o, 4));
    let r = check_program(&p, &CheckOptions::new(CheckLevel::Standard));
    assert_eq!(
        r.diagnostics,
        vec![Diagnostic::GuaranteedOverflow {
            step: 0,
            op: Opcode::VectorAddition,
            lane_idx: 0,
            bound: (60000, 60000),
        }]
    );
    assert!(r.clone().into_result().is_err());
}

#[test]
fn possible_wrap_is_a_strict_only_warning() {
    // Full-envelope inputs may (but need not) wrap a Wrap-mode add.
    let mut p = Program::new("t", FixedSpec::q(7));
    let x = p.buffer("x", 4, 1, BufKind::Input);
    let o = p.buffer("out", 4, 1, BufKind::Output);
    p.steps.push(add_wave(x, x, o, 4));
    let std = check_program(&p, &CheckOptions::new(CheckLevel::Standard));
    assert!(std.is_clean(), "{:?}", std.diagnostics);
    let strict = check_program(&p, &CheckOptions::new(CheckLevel::Strict));
    assert_eq!(
        strict.diagnostics,
        vec![Diagnostic::PossibleWrap {
            step: 0,
            op: Opcode::VectorAddition,
            lane_idx: 0,
            // The default host envelope is ±i16::MAX (symmetric).
            bound: (-2 * i16::MAX as i64, 2 * i16::MAX as i64),
        }]
    );
    assert_eq!(strict.diagnostics[0].severity(), Severity::Warning);
}

#[test]
fn possible_saturation_is_a_strict_only_warning() {
    let mut p = Program::new("t", FixedSpec::q(7).saturating());
    let c = p.const_buffer("big", vec![30000; 4]);
    let o = p.buffer("out", 4, 1, BufKind::Output);
    p.steps.push(add_wave(c, c, o, 4));
    assert!(check_program(&p, &CheckOptions::new(CheckLevel::Standard)).is_clean());
    let strict = check_program(&p, &CheckOptions::new(CheckLevel::Strict));
    assert_eq!(
        strict.diagnostics,
        vec![Diagnostic::PossibleSaturation {
            step: 0,
            op: Opcode::VectorAddition,
            lane_idx: 0,
            bound: (60000, 60000),
        }]
    );
}

#[test]
fn lut_domain_exceeded_is_flagged_with_shifted_bound() {
    let fixed = FixedSpec::q(7);
    let mut p = Program::new("t", fixed);
    let c = p.const_buffer("x", vec![4000; 4]);
    let o = p.buffer("out", 4, 1, BufKind::Output);
    // Wrap-mode addressing with shift 0: address 4000 aliases the table.
    let lut = p.lut(ActLut::build(ActKind::Tanh, false, fixed, AddrMode::Wrap, 0));
    p.steps.push(Step::LoadLut(lut));
    p.steps.push(Step::Wave(Wave {
        op: Opcode::ActivationFunction,
        vec_len: 4,
        lut: Some(lut),
        lanes: vec![LaneOp { a: View::all(c, 4), b: None, out: View::all(o, 4) }],
    }));
    assert!(check_program(&p, &CheckOptions::new(CheckLevel::Standard)).is_clean());
    let strict = check_program(&p, &CheckOptions::new(CheckLevel::Strict));
    assert_eq!(
        strict.diagnostics,
        vec![Diagnostic::LutDomainExceeded { step: 1, lut: 0, shifted: (4000, 4000) }]
    );
}

/// A dot wave wide enough to activate `groups` MVM groups.
fn wide_dot(groups: usize) -> Program {
    let w = groups * PROCS_PER_GROUP;
    let mut p = Program::new("t", FixedSpec::PAPER);
    let x = p.buffer("x", w, 1, BufKind::Input);
    let o = p.buffer("o", w, 1, BufKind::Output);
    p.steps.push(Step::Wave(Wave {
        op: Opcode::VectorDotProduct,
        vec_len: 1,
        lut: None,
        lanes: (0..w)
            .map(|i| LaneOp {
                a: View::contiguous(x, i, 1),
                b: Some(View::contiguous(x, i, 1)),
                out: View::contiguous(o, i, 1),
            })
            .collect(),
    }));
    p
}

#[test]
fn ring_overrun_is_flagged_field_exact() {
    let p = wide_dot(2);
    let opts = CheckOptions::new(CheckLevel::Standard).with_ring_capacity(1);
    let r = check_program(&p, &opts);
    assert_eq!(
        r.diagnostics,
        vec![Diagnostic::RingOverrun { step: 0, demand: 2, capacity: 1 }]
    );
}

#[test]
fn ring_at_exact_capacity_warns_of_zero_headroom() {
    let p = wide_dot(3);
    // host_bound 4 keeps the tiny dot products out of the interval
    // pass's warning range so the ring finding is the only diagnostic.
    let opts = CheckOptions::new(CheckLevel::Strict).with_ring_capacity(3).with_host_bound(4);
    let r = check_program(&p, &opts);
    assert_eq!(
        r.diagnostics,
        vec![Diagnostic::RingAtCapacity { step: 0, peak: 3, capacity: 3 }]
    );
    assert_eq!(r.ring_peak, 3);
    // One more slot of headroom and the same schedule is clean.
    let roomy = check_program(
        &p,
        &CheckOptions::new(CheckLevel::Strict).with_ring_capacity(4).with_host_bound(4),
    );
    assert!(roomy.is_clean(), "{:?}", roomy.diagnostics);
}

#[test]
fn cross_lane_raw_hazard_is_order_dependent() {
    // Lane 1 reads the arena address lane 0 writes (packed layout:
    // x at 0..2, y at 2..4, so y[0] = address 2).
    let mut p = Program::new("t", FixedSpec::PAPER);
    let x = p.buffer("x", 2, 1, BufKind::Input);
    let y = p.buffer("y", 2, 1, BufKind::Output);
    p.steps.push(Step::Wave(Wave {
        op: Opcode::VectorAddition,
        vec_len: 1,
        lut: None,
        lanes: vec![
            LaneOp {
                a: View::contiguous(x, 0, 1),
                b: Some(View::contiguous(x, 0, 1)),
                out: View::contiguous(y, 0, 1),
            },
            LaneOp {
                a: View::contiguous(y, 0, 1),
                b: Some(View::contiguous(x, 1, 1)),
                out: View::contiguous(y, 1, 1),
            },
        ],
    }));
    assert!(check_program(&p, &CheckOptions::new(CheckLevel::Standard)).is_clean());
    let strict =
        check_program(&p, &CheckOptions::new(CheckLevel::Strict).with_host_bound(4));
    assert_eq!(
        strict.diagnostics,
        vec![Diagnostic::OrderDependent { step: 0, lanes: (0, 1), addr: 2, hazard: "RAW" }]
    );
}

/// The golden compiler-emitted programs `mfnn lint` sweeps: paper-style
/// MLP forward + training step, graph CNN, transformer block.
fn golden_programs(batch: usize) -> Vec<Program> {
    let fixed = FixedSpec::q(10).saturating();
    let mlp = MlpSpec::from_dims(
        "mlp_16_32_32_10",
        &[16, 32, 32, 10],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let gfixed = FixedSpec::q(9).saturating();
    let geom = Conv2dGeom { in_h: 8, in_w: 8, in_c: 1, out_c: 8, kh: 3, kw: 3, stride: 1 };
    let mut conv = GraphSpec::new("cnn_8x8", 64, gfixed, LutParams::training(gfixed));
    let c = conv.conv2d(INPUT, geom);
    let ca = conv.activation(c, ActKind::Relu);
    conv.linear(ca, 10);
    let (seq, d) = (8, 8);
    let mut xfmr =
        GraphSpec::new("transformer_block", seq * d, gfixed, LutParams::training(gfixed));
    let att = xfmr.attention(INPUT, seq, d);
    let r1 = xfmr.add(att, INPUT);
    let n1 = xfmr.normalization(r1, d);
    let f1 = xfmr.linear(n1, seq * d);
    let fa = xfmr.activation(f1, ActKind::Relu);
    let f2 = xfmr.linear(fa, seq * d);
    let r2 = xfmr.add(f2, n1);
    xfmr.normalization(r2, d);
    vec![
        lower_mlp_forward(&mlp, batch).unwrap().program,
        lower_mlp_train(&mlp, batch, 1.0 / 128.0).unwrap().program,
        lower_graph_forward(&conv, batch).unwrap().program,
        lower_graph_forward(&xfmr, batch).unwrap().program,
    ]
}

#[test]
fn golden_programs_check_clean_at_standard() {
    // The acceptance gate behind `mfnn lint`: zero diagnostics on every
    // compiler-emitted golden program, with every plan claim certified.
    for p in golden_programs(4) {
        let r = check_program(&p, &CheckOptions::new(CheckLevel::Standard));
        assert!(r.is_clean(), "{}: {:?}", p.name, r.diagnostics);
        assert_eq!(r.hazard_skipped, 0, "{}: hazard claims skipped", p.name);
        assert!(r.waves > 0 && r.lane_ops > 0, "{}: nothing analysed", p.name);
    }
}

#[test]
fn sampled_raw_programs_check_clean_at_standard() {
    // False-positive rate 0 over the fuzzer's raw-program generator
    // (its bindings stay within ±6000).
    let g = gen::program_case();
    for i in 0..32 {
        let c = g.sample(&mut Rng::new(case_seed(11, i)));
        let (p, _) = c.build();
        let opts = CheckOptions::new(CheckLevel::Standard).with_host_bound(6000);
        let r = check_program(&p, &opts);
        assert!(r.is_clean(), "case {i}: {:?} on {c:?}", r.diagnostics);
    }
}

#[test]
fn every_planted_defect_is_caught() {
    // Catch rate 100%: `Differ::run_check` fails a planted case iff the
    // checker misses the planted kind.
    let differ = Differ::new(FpgaDevice::selected());
    for seed in 0..8u64 {
        for defect in [
            CheckDefect::UndefinedRead,
            CheckDefect::Overflow,
            CheckDefect::RingOverrun,
            CheckDefect::Hazard,
        ] {
            let case = CheckCase { seed, defect: defect.clone() };
            differ
                .run_check(&case)
                .unwrap_or_else(|d| panic!("seed {seed} {defect:?}: {d}"));
        }
    }
}

#[test]
fn compile_with_checks_attaches_reports_and_splits_the_cache() {
    let fixed = FixedSpec::q(8).saturating();
    let spec = MlpSpec::from_dims(
        "wired",
        &[4, 6, 2],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap();
    let compiler = Compiler::new();
    let plain = compiler.compile_spec(&spec, &CompileOptions::inference(4)).unwrap();
    assert!(plain.check_reports().is_empty());
    let opts = CompileOptions::inference(4).with_checks(CheckLevel::Standard);
    let checked = compiler.compile_spec(&spec, &opts).unwrap();
    assert_eq!(checked.check_reports().len(), 1);
    assert!(checked.check_reports()[0].is_clean());
    assert!(!Arc::ptr_eq(&plain, &checked), "check level must split the cache key");
    let again = compiler.compile_spec(&spec, &opts).unwrap();
    assert!(Arc::ptr_eq(&checked, &again), "same options must hit the cache");
    // Training artifacts carry one report per compiled program.
    let topts = CompileOptions::training(4, 1.0 / 64.0).with_checks(CheckLevel::Standard);
    let trained = compiler.compile_spec(&spec, &topts).unwrap();
    assert_eq!(trained.check_reports().len(), 2);
    assert!(trained.check_reports().iter().all(|r| r.is_clean()));
}

#[test]
fn check_errors_surface_as_typed_session_errors() {
    let err = CheckError {
        program: "bad".into(),
        errors: vec![Diagnostic::RingOverrun { step: 2, demand: 4, capacity: 1 }],
    };
    let e: Error = err.into();
    match e {
        Error::Check(inner) => {
            assert_eq!(inner.program, "bad");
            assert_eq!(inner.errors.len(), 1);
            assert!(inner.to_string().contains("step 2"));
        }
        other => panic!("expected Error::Check, got {other:?}"),
    }
}
