//! End-to-end integration: assembly text → session compiler → simulated
//! multi-FPGA cluster training → accuracy; plus the VHDL bundle for the
//! same net. Exercises every layer of the stack through the unified
//! session front door.

use mfnn::assembler::vhdl;
use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::perf::catalog::FpgaPart;
use mfnn::session::{CompileOptions, Compiler, NetJob, Session, Target};
use mfnn::util::Rng;
use std::sync::Arc;

const NET: &str = "
NET digits
FIXED 10 saturate
INPUT img 16 15
WEIGHT w0 15 24
BIAS b0 24
ACT a0 relu shift=5 mode=clamp interp=1
MLP h img w0 b0 a0
WEIGHT w1 24 10
BIAS b1 10
ACT a1 identity shift=5 mode=clamp interp=1
MLP scores h w1 b1 a1
OUTPUT scores
TARGET labels 16 10
TRAIN lr=0.00390625
";

#[test]
fn assembly_to_training_step_runs() {
    let compiler = Compiler::new();
    let artifact = compiler.compile_asm_net(NET).unwrap();
    assert!(artifact.trainable());
    assert_eq!(artifact.lr(), Some(0.00390625));
    let mut s = Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected()))
        .unwrap();
    let f = artifact.fixed();
    let mut r = Rng::new(11);
    let mut q = |n: usize, amp: f64| -> Vec<i16> {
        (0..n).map(|_| f.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    // typed handles keep the user's assembly-level names
    for (name, len, amp) in [
        ("img", 16 * 15, 2.0),
        ("labels", 16 * 10, 1.0),
        ("w0", 15 * 24, 1.0),
        ("b0", 24, 0.2),
        ("w1", 24 * 10, 1.0),
        ("b1", 10, 0.2),
    ] {
        let h = artifact.tensor(name).unwrap();
        assert_eq!(h.len(), len, "{name}");
        s.write(&h, &q(len, amp)).unwrap();
    }
    let w0 = artifact.tensor("w0").unwrap();
    let w_before = s.read(&w0).unwrap();
    let stats = s.step();
    assert!(stats.cycles > 0);
    assert_ne!(s.read(&w0).unwrap(), w_before, "SGD update must change weights");
    // the same net generates a VHDL bundle with its instruction ROM
    let bundle = vhdl::generate(FpgaPart::selected(), Some(artifact.program()));
    let gc = bundle.file("global_controller.vhd").unwrap();
    assert!(gc.contains("VECTOR_DOT_PRODUCT"));
}

#[test]
fn cluster_trains_mini_digits_to_accuracy() {
    // The E-E2E experiment in miniature (the full run lives in
    // examples/train_cluster.rs): 2 MLPs on 2 boards, mini-digits,
    // dispatched through Session::train_many.
    let fixed = FixedSpec::q(10).saturating();
    let compiler = Compiler::new();
    let mk = |name: &str, seed: u64| {
        let spec = MlpSpec::from_dims(
            name,
            &[15, 24, 10],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let artifact =
            compiler.compile_spec(&spec, &CompileOptions::training(16, 1.0 / 128.0)).unwrap();
        let (train, test) = dataset::mini_digits(400, seed).split(0.8, &mut Rng::new(seed));
        NetJob {
            artifact,
            cfg: TrainConfig { batch: 16, lr: 1.0 / 128.0, steps: 400, seed, log_every: 50 },
            train: Arc::new(train),
            test: Arc::new(test),
            resume: None,
        }
    };
    let cfg = mfnn::cluster::ClusterConfig { boards: 2, ..Default::default() };
    let report = Session::train_many(&cfg, &[mk("net_a", 1), mk("net_b", 2)]).unwrap();
    for jr in &report.results {
        assert!(
            jr.accuracy > 0.8,
            "{} reached only {:.2} accuracy; curve: {:?}",
            jr.name,
            jr.accuracy,
            jr.curve
        );
        let first = jr.curve.first().unwrap().loss;
        let last = jr.curve.last().unwrap().loss;
        assert!(last < first, "{}: loss {first} → {last}", jr.name);
    }
    assert!(report.makespan_s > 0.0);
}
