//! End-to-end integration: assembly text → Matrix Assembler → simulated
//! multi-FPGA cluster training → accuracy; plus the VHDL bundle for the
//! same net. Exercises every layer of the stack in one flow.

use mfnn::asm::lower_file;
use mfnn::assembler::vhdl;
use mfnn::cluster::{run_cluster, ClusterConfig, Job};
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::perf::catalog::FpgaPart;
use mfnn::util::Rng;
use std::sync::Arc;

const NET: &str = "
NET digits
FIXED 10 saturate
INPUT img 16 15
WEIGHT w0 15 24
BIAS b0 24
ACT a0 relu shift=5 mode=clamp interp=1
MLP h img w0 b0 a0
WEIGHT w1 24 10
BIAS b1 10
ACT a1 identity shift=5 mode=clamp interp=1
MLP scores h w1 b1 a1
OUTPUT scores
TARGET labels 16 10
TRAIN lr=0.00390625
";

#[test]
fn assembly_to_training_step_runs() {
    let nets = lower_file(NET).unwrap();
    let net = &nets[0];
    assert!(net.train);
    let p = &net.mlp.program;
    let mut m = MatrixMachine::new(FpgaDevice::selected(), p).unwrap();
    let f = net.spec.fixed;
    let mut r = Rng::new(11);
    let q = |n: usize, amp: f64, r: &mut Rng| -> Vec<i16> {
        (0..n).map(|_| f.from_f64((r.gen_f64() - 0.5) * amp)).collect()
    };
    m.bind(p, "img", &q(16 * 15, 2.0, &mut r)).unwrap();
    m.bind(p, "labels", &q(16 * 10, 1.0, &mut r)).unwrap();
    m.bind(p, "w0", &q(15 * 24, 1.0, &mut r)).unwrap();
    m.bind(p, "b0", &q(24, 0.2, &mut r)).unwrap();
    m.bind(p, "w1", &q(24 * 10, 1.0, &mut r)).unwrap();
    m.bind(p, "b1", &q(10, 0.2, &mut r)).unwrap();
    let w_before = m.read(p, "w0").unwrap();
    let stats = m.run(p).unwrap();
    assert!(stats.cycles > 0);
    assert_ne!(m.read(p, "w0").unwrap(), w_before, "SGD update must change weights");
    // the same net generates a VHDL bundle with its instruction ROM
    let bundle = vhdl::generate(FpgaPart::selected(), Some(p));
    let gc = bundle.file("global_controller.vhd").unwrap();
    assert!(gc.contains("VECTOR_DOT_PRODUCT"));
}

#[test]
fn cluster_trains_mini_digits_to_accuracy() {
    // The E-E2E experiment in miniature (the full run lives in
    // examples/train_cluster.rs): 2 MLPs on 2 boards, mini-digits.
    let fixed = FixedSpec::q(10).saturating();
    let mk = |name: &str, seed: u64| {
        let spec = MlpSpec::from_dims(
            name,
            &[15, 24, 10],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let (train, test) = dataset::mini_digits(400, seed).split(0.8, &mut Rng::new(seed));
        Job {
            name: name.into(),
            spec,
            cfg: TrainConfig { batch: 16, lr: 1.0 / 128.0, steps: 400, seed, log_every: 50 },
            train_data: Arc::new(train),
            test_data: Arc::new(test),
        }
    };
    let cfg = ClusterConfig { boards: 2, ..Default::default() };
    let report = run_cluster(&cfg, &[mk("net_a", 1), mk("net_b", 2)]).unwrap();
    for jr in &report.results {
        assert!(
            jr.accuracy > 0.8,
            "{} reached only {:.2} accuracy; curve: {:?}",
            jr.name,
            jr.accuracy,
            jr.curve
        );
        let first = jr.curve.first().unwrap().loss;
        let last = jr.curve.last().unwrap().loss;
        assert!(last < first, "{}: loss {first} → {last}", jr.name);
    }
    assert!(report.makespan_s > 0.0);
}
