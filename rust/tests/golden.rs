//! Rust simulator ↔ JAX/Pallas golden model **bit-exactness** (E-GOLD in
//! DESIGN.md).
//!
//! The artifacts (`make artifacts`) contain the quantised MLP forward
//! pass and a full SGD training step lowered from JAX (calling the L1
//! Pallas kernel) to HLO text. These tests execute them through PJRT
//! from Rust and assert the simulated Matrix Machine produces *identical
//! int16 bits* — activations, loss lane, and updated weights.

use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::nn::graph::{lower_mlp_forward as lower_forward, lower_mlp_train as lower_train_step};
use mfnn::nn::mlp::MlpSpec;
use mfnn::runtime::{GoldenModel, Runtime};
use mfnn::util::Rng;

fn golden() -> Option<GoldenModel> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.toml").exists() {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    Some(GoldenModel::open(&dir).expect("open golden model"))
}

fn rand_params(spec: &MlpSpec, seed: u64) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
    let mut r = Rng::new(seed);
    let f = spec.fixed;
    let ws = spec
        .layers
        .iter()
        .map(|l| {
            (0..l.inputs * l.outputs)
                .map(|_| f.from_f64((r.gen_f64() - 0.5) * 1.2))
                .collect()
        })
        .collect();
    let bs = spec
        .layers
        .iter()
        .map(|l| (0..l.outputs).map(|_| f.from_f64((r.gen_f64() - 0.5) * 0.4)).collect())
        .collect();
    (ws, bs)
}

fn rand_x(g: &GoldenModel, seed: u64, dim: usize, amp: f64) -> Vec<i16> {
    let mut r = Rng::new(seed);
    (0..g.batch * dim).map(|_| g.spec.fixed.from_f64((r.gen_f64() - 0.5) * amp)).collect()
}

#[test]
fn forward_bit_exact_sim_vs_golden() {
    let Some(g) = golden() else { return };
    let h = lower_forward(&g.spec, g.batch).expect("lower fwd");
    for trial in 0..5u64 {
        let (ws, bs) = rand_params(&g.spec, 100 + trial);
        let x = rand_x(&g, 200 + trial, g.spec.input_dim(), 2.0);

        // simulated Matrix Machine
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("x", &x).unwrap();
        for l in 0..g.spec.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        m.execute();
        let last = g.spec.layers.len() - 1;
        let sim_out = m.read_named(&format!("o{last}")).unwrap().to_vec();

        // golden JAX/Pallas artifact via PJRT
        let gold_out = g.forward(&x, &ws, &bs).expect("golden forward");
        assert_eq!(sim_out, gold_out, "trial {trial}: forward outputs diverge");
    }
}

#[test]
fn train_step_bit_exact_sim_vs_golden() {
    let Some(g) = golden() else { return };
    let h = lower_train_step(&g.spec, g.batch, g.lr).expect("lower train");
    for trial in 0..3u64 {
        let (ws, bs) = rand_params(&g.spec, 300 + trial);
        let x = rand_x(&g, 400 + trial, g.spec.input_dim(), 2.0);
        let y = rand_x(&g, 500 + trial, g.spec.output_dim(), 1.0);

        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("x", &x).unwrap();
        m.bind_named("y", &y).unwrap();
        for l in 0..g.spec.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        m.execute();
        let last = g.spec.layers.len() - 1;
        let sim_out = m.read_named(&format!("o{last}")).unwrap().to_vec();
        let sim_loss = m.read_named("loss").unwrap().to_vec()[0];

        let step = g.train_step(&x, &y, &ws, &bs).expect("golden train step");
        assert_eq!(sim_out, step.out, "trial {trial}: outputs diverge");
        assert_eq!(sim_loss, step.loss, "trial {trial}: loss lanes diverge");
        for l in 0..g.spec.layers.len() {
            let sim_w = m.read_named(&format!("w{l}")).unwrap().to_vec();
            let sim_b = m.read_named(&format!("b{l}")).unwrap().to_vec();
            assert_eq!(sim_w, step.weights[l], "trial {trial}: layer {l} weights diverge");
            assert_eq!(sim_b, step.biases[l], "trial {trial}: layer {l} biases diverge");
        }
    }
}

#[test]
fn multi_step_training_stays_bit_exact() {
    // Weights evolve identically over several chained steps — any
    // single-bit divergence would compound and be caught here.
    let Some(g) = golden() else { return };
    let h = lower_train_step(&g.spec, g.batch, g.lr).expect("lower train");
    let (mut ws, mut bs) = rand_params(&g.spec, 900);
    let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
    for l in 0..g.spec.layers.len() {
        m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
        m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
    }
    for step in 0..4u64 {
        let x = rand_x(&g, 1000 + step, g.spec.input_dim(), 2.0);
        let y = rand_x(&g, 2000 + step, g.spec.output_dim(), 1.0);
        m.bind_named("x", &x).unwrap();
        m.bind_named("y", &y).unwrap();
        m.execute();
        let gold = g.train_step(&x, &y, &ws, &bs).unwrap();
        for l in 0..g.spec.layers.len() {
            ws[l] = gold.weights[l].clone();
            bs[l] = gold.biases[l].clone();
            assert_eq!(
                m.read_named(&format!("w{l}")).unwrap().to_vec(),
                ws[l],
                "step {step}, layer {l}"
            );
        }
    }
}
