//! Acceptance tests of the multi-tenant batched serving runtime
//! (DESIGN.md §Serving): bit-exactness of batched serving vs sequential
//! `Session::infer`, determinism, backpressure, padding/metrics
//! semantics, multi-tenant routing, and the pooled+batched ≥ 2×
//! single-board-batch-1 simulated-throughput criterion.

use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::serve::{open_loop, seeded_params, Completion, ServeConfig, ServeError, Server};
use mfnn::util::Rng;
use mfnn::{Artifact, CompileOptions, Compiler, Session, Target};
use std::sync::Arc;

fn fixed() -> FixedSpec {
    FixedSpec::q(10).saturating()
}

fn mk_spec(name: &str, dims: &[usize]) -> MlpSpec {
    let f = fixed();
    MlpSpec::from_dims(name, dims, ActKind::Relu, ActKind::Identity, f, LutParams::training(f))
        .unwrap()
}

/// A batch-1 session with explicit parameters — the sequential serving
/// reference every batched output must match bit-for-bit.
fn reference_session(
    compiler: &Compiler,
    spec: &MlpSpec,
    w: &[Vec<i16>],
    b: &[Vec<i16>],
) -> (Arc<Artifact>, Session) {
    let artifact = compiler.compile_spec(spec, &CompileOptions::inference(1)).unwrap();
    let mut session =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();
    for l in 0..spec.layers.len() {
        let hw = artifact.tensor(&format!("w{l}")).unwrap();
        let hb = artifact.tensor(&format!("b{l}")).unwrap();
        session.write(&hw, &w[l]).unwrap();
        session.write(&hb, &b[l]).unwrap();
    }
    (artifact, session)
}

#[test]
fn batched_serving_is_bit_identical_to_sequential_infer() {
    // 11 staggered requests over a 2-board pool with an 8-bucket ladder:
    // full batches, a padded partial batch, every output bit-exact.
    let compiler = Compiler::new();
    let spec = mk_spec("bits", &[4, 12, 3]);
    let (w, b) = seeded_params(&spec, 0xF00);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);

    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 8,
        max_wait_cycles: 16,
        queue_cap: 64,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();

    let mut r = Rng::new(0xB17);
    let rows: Vec<Vec<i16>> = (0..11)
        .map(|_| (0..4).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for (i, row) in rows.iter().enumerate() {
        server.submit_at(i as u64 * 3, nid, row).unwrap();
    }
    let makespan = server.drain().unwrap();
    assert!(makespan > 0);
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 11);
    for (i, c) in comps.iter().enumerate() {
        let want = reference.infer(&rows[i]).unwrap().output;
        assert_eq!(c.output, want, "request {i} diverged (bucket {})", c.bucket);
        assert!(c.completed > c.submitted || c.submitted == c.dispatched);
    }
    let report = server.report();
    assert_eq!(report.total_completed(), 11);
    assert_eq!(report.total_rejected(), 0);
}

#[test]
fn session_server_serves_a_trained_net_bit_exactly() {
    // Train through the Session front door, open a server with
    // Session::server, and check a full bucket of served rows equals one
    // batched Session::infer of the same rows.
    let compiler = Compiler::new();
    let spec = mk_spec("trained", &[2, 8, 2]);
    let artifact =
        compiler.compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0)).unwrap();
    let mut session =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();
    let ds = dataset::xor(64, 3);
    let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 40, seed: 9, log_every: 10 };
    session.train(&ds, &cfg).unwrap();

    let cfg = ServeConfig {
        boards: 2,
        max_batch: 8,
        max_wait_cycles: 32,
        ..ServeConfig::default()
    };
    let mut server = session.server(cfg).unwrap();
    let f = spec.fixed;
    let qx = ds.encode_rows(0..8, f);
    for i in 0..8 {
        server.submit_at(0, 0, &qx[i * 2..(i + 1) * 2]).unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    let served: Vec<i16> = comps.iter().flat_map(|c| c.output.clone()).collect();
    let want = session.infer(&qx).unwrap().output;
    assert_eq!(served, want, "served bucket diverged from batched Session::infer");
    // all 8 arrived at cycle 0 ⇒ one full 8-row batch, fill 1.0
    let report = server.report();
    assert_eq!(report.nets[0].batches, 1);
    assert!((report.nets[0].batch_fill() - 1.0).abs() < 1e-12);
}

#[test]
fn serving_is_deterministic_across_runs() {
    let compiler = Compiler::new();
    let spec = mk_spec("det", &[3, 10, 2]);
    let (w, b) = seeded_params(&spec, 42);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let workload = open_loop(48, 7, 3, &[3], fixed());
    let run = || {
        let mut server = Server::open(ServeConfig {
            boards: 3,
            max_batch: 4,
            max_wait_cycles: 8,
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
        for q in &workload {
            server.submit_at(q.at, nid, &q.row).unwrap();
        }
        server.drain().unwrap();
        let comps: Vec<Completion> = server.take_completions();
        (server.report().to_json(), comps)
    };
    let (json1, comps1) = run();
    let (json2, comps2) = run();
    assert_eq!(json1, json2, "metrics JSON must be identical across runs");
    assert_eq!(comps1.len(), comps2.len());
    for (a, c) in comps1.iter().zip(&comps2) {
        assert_eq!(a.id, c.id);
        assert_eq!(a.output, c.output);
        assert_eq!(a.completed, c.completed);
    }
}

#[test]
fn overload_is_a_typed_rejection_not_a_hang() {
    let compiler = Compiler::new();
    let spec = mk_spec("ovl", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 1);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    // queue_cap 2, high max_wait, big max_batch: the third same-cycle
    // submit must be refused with the typed error.
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 8,
        max_wait_cycles: 1_000_000,
        queue_cap: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let row = vec![0i16; 2];
    server.submit_at(0, nid, &row).unwrap();
    server.submit_at(0, nid, &row).unwrap();
    let err = server.submit_at(0, nid, &row).unwrap_err();
    assert!(
        matches!(err, ServeError::Overloaded { net: 0, depth: 2, cap: 2 }),
        "expected typed Overloaded, got {err}"
    );
    // the queued requests still complete (deadline flush) — no hang
    server.drain().unwrap();
    assert_eq!(server.take_completions().len(), 2);
    assert_eq!(server.report().nets[0].rejected, 1);
}

#[test]
fn backlog_of_formed_batches_still_triggers_overload() {
    // All boards busy: full batches leave the batcher queue but sit in
    // the server's ready backlog — admission must still refuse beyond
    // queue_cap, because the contract bounds the whole undispatched
    // backlog, not just the raw queue.
    let compiler = Compiler::new();
    let spec = mk_spec("backlog", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 3);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(2)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 2,
        max_wait_cycles: 1_000_000,
        queue_cap: 5,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let row = vec![0i16; 2];
    // requests 1–2 form a full batch that dispatches immediately (the
    // board is free); 3–6 form two batches stuck behind the busy board;
    // 7 queues. Backlog is now 5 = queue_cap, so request 8 is refused.
    for _ in 0..7 {
        server.submit_at(0, nid, &row).unwrap();
    }
    let err = server.submit_at(0, nid, &row).unwrap_err();
    assert!(
        matches!(err, ServeError::Overloaded { net: 0, depth: 5, cap: 5 }),
        "expected backlog Overloaded, got {err}"
    );
    server.drain().unwrap();
    assert_eq!(server.take_completions().len(), 7, "admitted requests must all complete");
    assert_eq!(server.report().nets[0].rejected, 1);
}

#[test]
fn typed_errors_for_bad_requests_and_clocks() {
    let compiler = Compiler::new();
    let spec = mk_spec("bad", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 2);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig::default()).unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    assert!(matches!(
        server.submit_at(0, nid + 1, &[0, 0]),
        Err(ServeError::UnknownNet(_))
    ));
    assert!(matches!(
        server.submit_at(0, nid, &[0, 0, 0]),
        Err(ServeError::BadRow { want: 2, got: 3, .. })
    ));
    server.submit_at(10, nid, &[0, 0]).unwrap();
    assert!(matches!(
        server.submit_at(3, nid, &[0, 0]),
        Err(ServeError::ClockSkew { at: 3, .. })
    ));
    // bad params at registration
    let short_w = vec![vec![0i16; 1]; 2];
    assert!(matches!(
        server.register(Arc::clone(&artifact), &short_w, &b),
        Err(ServeError::BadParams { layer: 0, what: "weights", .. })
    ));
    // bad config
    assert!(matches!(
        Server::open(ServeConfig { boards: 0, ..ServeConfig::default() }),
        Err(ServeError::Config(_))
    ));
    assert!(matches!(
        Server::open(ServeConfig { max_batch: 0, ..ServeConfig::default() }),
        Err(ServeError::Config(_))
    ));
    assert!(matches!(
        Server::open(ServeConfig { device: "nope".into(), ..ServeConfig::default() }),
        Err(ServeError::UnknownDevice(_))
    ));
}

#[test]
fn partial_batches_pad_to_the_bucket_and_record_fill() {
    let compiler = Compiler::new();
    let spec = mk_spec("pad", &[3, 6, 2]);
    let (w, b) = seeded_params(&spec, 5);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 4,
        // all 3 rows arrive at cycle 0 and flush together at the
        // deadline: one partial batch riding the 4-bucket
        max_wait_cycles: 5,
        queue_cap: 16,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let mut r = Rng::new(6);
    let rows: Vec<Vec<i16>> = (0..3)
        .map(|_| (0..3).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for row in &rows {
        server.submit_at(0, nid, row).unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 3);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.bucket, 4, "3 rows must ride the 4-bucket");
        assert_eq!(c.batch_rows, 3);
        let want = reference.infer(&rows[i]).unwrap().output;
        assert_eq!(c.output, want, "padding perturbed request {i}");
    }
    let m = &server.report().nets[0];
    assert_eq!(m.batches, 1);
    assert!((m.batch_fill() - 0.75).abs() < 1e-12);
}

#[test]
fn multi_tenant_requests_route_to_their_nets() {
    let compiler = Compiler::new();
    let spec_a = mk_spec("tenant_a", &[2, 6, 2]);
    let spec_b = mk_spec("tenant_b", &[5, 8, 3]);
    let (wa, ba) = seeded_params(&spec_a, 10);
    let (wb, bb) = seeded_params(&spec_b, 11);
    let (_, mut ref_a) = reference_session(&compiler, &spec_a, &wa, &ba);
    let (_, mut ref_b) = reference_session(&compiler, &spec_b, &wb, &bb);
    let art_a = compiler.compile_spec(&spec_a, &CompileOptions::serving(4)).unwrap();
    let art_b = compiler.compile_spec(&spec_b, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 4,
        max_wait_cycles: 4,
        queue_cap: 32,
        ..ServeConfig::default()
    })
    .unwrap();
    let na = server.register(Arc::clone(&art_a), &wa, &ba).unwrap();
    let nb = server.register(Arc::clone(&art_b), &wb, &bb).unwrap();
    let workload = open_loop(24, 3, 2, &[2, 5], fixed());
    let mut expected = Vec::new();
    for q in &workload {
        let id = server.submit_at(q.at, [na, nb][q.net], &q.row).unwrap();
        let want = if q.net == 0 {
            ref_a.infer(&q.row).unwrap().output
        } else {
            ref_b.infer(&q.row).unwrap().output
        };
        expected.push((id, q.net, want));
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), expected.len());
    for (c, (id, net, want)) in comps.iter().zip(&expected) {
        assert_eq!(c.id, *id);
        assert_eq!(c.net, [na, nb][*net]);
        assert_eq!(&c.output, want, "tenant {net} output diverged");
    }
    let report = server.report();
    assert_eq!(report.nets.len(), 2);
    assert!(report.nets[0].completed > 0 && report.nets[1].completed > 0);
    assert!(report.nets[0].latency_p50() <= report.nets[0].latency_p99());
}

#[test]
fn pooled_batched_throughput_beats_single_board_batch1_by_2x() {
    // The serving acceptance criterion, asserted on simulated cycles
    // (deterministic — safe to gate in CI): 4 boards with a 32-bucket
    // ladder must serve a saturated workload at ≥ 2× the requests/sim-s
    // of 1 board at batch 1.
    let compiler = Compiler::new();
    let spec = mk_spec("thr", &[4, 16, 3]);
    let (w, b) = seeded_params(&spec, 77);
    let workload = open_loop(128, 0, 1, &[4], fixed());
    let run = |boards: usize, max_batch: usize| {
        let artifact =
            compiler.compile_spec(&spec, &CompileOptions::serving(max_batch)).unwrap();
        let mut server = Server::open(ServeConfig {
            boards,
            max_batch,
            max_wait_cycles: if max_batch == 1 { 0 } else { 64 },
            queue_cap: workload.len() + 1,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(artifact, &w, &b).unwrap();
        for q in &workload {
            server.submit_at(q.at, nid, &q.row).unwrap();
        }
        server.drain().unwrap();
        let report = server.report();
        assert_eq!(report.total_completed(), 128);
        report.requests_per_sim_s()
    };
    let single_b1 = run(1, 1);
    let pooled_b32 = run(4, 32);
    assert!(
        pooled_b32 >= 2.0 * single_b1,
        "pooled+batched {pooled_b32:.0} req/s < 2× single-board batch-1 {single_b1:.0} req/s"
    );
}

#[test]
fn ladder_report_and_clock_accessors_are_consistent() {
    let server = Server::open(ServeConfig { max_batch: 8, ..ServeConfig::default() }).unwrap();
    assert_eq!(server.ladder(), &[1, 2, 4, 8]);
    assert_eq!(server.now(), 0);
    assert_eq!(server.device().part.name, "XC7S75-2");
    let report = server.report();
    assert_eq!(report.total_submitted(), 0);
    assert_eq!(report.makespan_cycles, 0);
}
