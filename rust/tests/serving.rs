//! Acceptance tests of the multi-tenant batched serving runtime
//! (DESIGN.md §Serving): bit-exactness of batched serving vs sequential
//! `Session::infer`, determinism, backpressure, padding/metrics
//! semantics, multi-tenant routing, and the pooled+batched ≥ 2×
//! single-board-batch-1 simulated-throughput criterion.

use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::serve::{
    open_loop, seeded_params, slo_open_loop, Completion, DropReason, ServeConfig, ServeError,
    ServeFaultPlan, Server, SubmitOptions,
};
use mfnn::util::Rng;
use mfnn::{Artifact, CompileOptions, Compiler, Session, Target};
use std::sync::Arc;

fn fixed() -> FixedSpec {
    FixedSpec::q(10).saturating()
}

fn mk_spec(name: &str, dims: &[usize]) -> MlpSpec {
    let f = fixed();
    MlpSpec::from_dims(name, dims, ActKind::Relu, ActKind::Identity, f, LutParams::training(f))
        .unwrap()
}

/// A batch-1 session with explicit parameters — the sequential serving
/// reference every batched output must match bit-for-bit.
fn reference_session(
    compiler: &Compiler,
    spec: &MlpSpec,
    w: &[Vec<i16>],
    b: &[Vec<i16>],
) -> (Arc<Artifact>, Session) {
    let artifact = compiler.compile_spec(spec, &CompileOptions::inference(1)).unwrap();
    let mut session =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();
    for l in 0..spec.layers.len() {
        let hw = artifact.tensor(&format!("w{l}")).unwrap();
        let hb = artifact.tensor(&format!("b{l}")).unwrap();
        session.write(&hw, &w[l]).unwrap();
        session.write(&hb, &b[l]).unwrap();
    }
    (artifact, session)
}

#[test]
fn batched_serving_is_bit_identical_to_sequential_infer() {
    // 11 staggered requests over a 2-board pool with an 8-bucket ladder:
    // full batches, a padded partial batch, every output bit-exact.
    let compiler = Compiler::new();
    let spec = mk_spec("bits", &[4, 12, 3]);
    let (w, b) = seeded_params(&spec, 0xF00);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);

    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 8,
        max_wait_cycles: 16,
        queue_cap: 64,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();

    let mut r = Rng::new(0xB17);
    let rows: Vec<Vec<i16>> = (0..11)
        .map(|_| (0..4).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for (i, row) in rows.iter().enumerate() {
        server.submit_at(i as u64 * 3, nid, row).unwrap();
    }
    let makespan = server.drain().unwrap();
    assert!(makespan > 0);
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 11);
    for (i, c) in comps.iter().enumerate() {
        let want = reference.infer(&rows[i]).unwrap().output;
        assert_eq!(c.output, want, "request {i} diverged (bucket {})", c.bucket);
        assert!(c.completed > c.submitted || c.submitted == c.dispatched);
    }
    let report = server.report();
    assert_eq!(report.total_completed(), 11);
    assert_eq!(report.total_rejected(), 0);
}

#[test]
fn session_server_serves_a_trained_net_bit_exactly() {
    // Train through the Session front door, open a server with
    // Session::server, and check a full bucket of served rows equals one
    // batched Session::infer of the same rows.
    let compiler = Compiler::new();
    let spec = mk_spec("trained", &[2, 8, 2]);
    let artifact =
        compiler.compile_spec(&spec, &CompileOptions::training(8, 1.0 / 128.0)).unwrap();
    let mut session =
        Session::open(Arc::clone(&artifact), Target::Board(FpgaDevice::selected())).unwrap();
    let ds = dataset::xor(64, 3);
    let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 40, seed: 9, log_every: 10 };
    session.train(&ds, &cfg).unwrap();

    let cfg = ServeConfig {
        boards: 2,
        max_batch: 8,
        max_wait_cycles: 32,
        ..ServeConfig::default()
    };
    let mut server = session.server(cfg).unwrap();
    let f = spec.fixed;
    let qx = ds.encode_rows(0..8, f);
    for i in 0..8 {
        server.submit_at(0, 0, &qx[i * 2..(i + 1) * 2]).unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    let served: Vec<i16> = comps.iter().flat_map(|c| c.output.clone()).collect();
    let want = session.infer(&qx).unwrap().output;
    assert_eq!(served, want, "served bucket diverged from batched Session::infer");
    // all 8 arrived at cycle 0 ⇒ one full 8-row batch, fill 1.0
    let report = server.report();
    assert_eq!(report.nets[0].batches, 1);
    assert!((report.nets[0].batch_fill() - 1.0).abs() < 1e-12);
}

#[test]
fn serving_is_deterministic_across_runs() {
    let compiler = Compiler::new();
    let spec = mk_spec("det", &[3, 10, 2]);
    let (w, b) = seeded_params(&spec, 42);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let workload = open_loop(48, 7, 3, &[3], fixed());
    let run = || {
        let mut server = Server::open(ServeConfig {
            boards: 3,
            max_batch: 4,
            max_wait_cycles: 8,
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
        for q in &workload {
            server.submit_at(q.at, nid, &q.row).unwrap();
        }
        server.drain().unwrap();
        let comps: Vec<Completion> = server.take_completions();
        (server.report().to_json(), comps)
    };
    let (json1, comps1) = run();
    let (json2, comps2) = run();
    assert_eq!(json1, json2, "metrics JSON must be identical across runs");
    assert_eq!(comps1.len(), comps2.len());
    for (a, c) in comps1.iter().zip(&comps2) {
        assert_eq!(a.id, c.id);
        assert_eq!(a.output, c.output);
        assert_eq!(a.completed, c.completed);
    }
}

#[test]
fn overload_is_a_typed_rejection_not_a_hang() {
    let compiler = Compiler::new();
    let spec = mk_spec("ovl", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 1);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    // queue_cap 2, high max_wait, big max_batch: the third same-cycle
    // submit must be refused with the typed error.
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 8,
        max_wait_cycles: 1_000_000,
        queue_cap: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let row = vec![0i16; 2];
    server.submit_at(0, nid, &row).unwrap();
    server.submit_at(0, nid, &row).unwrap();
    let err = server.submit_at(0, nid, &row).unwrap_err();
    assert!(
        matches!(err, ServeError::Shed { net: 0, depth: 2, cap: 2, priority: 0 }),
        "expected typed Shed, got {err}"
    );
    // the queued requests still complete (deadline flush) — no hang
    server.drain().unwrap();
    assert_eq!(server.take_completions().len(), 2);
    assert_eq!(server.report().nets[0].rejected, 1);
}

#[test]
fn backlog_of_formed_batches_still_triggers_overload() {
    // All boards busy: full batches leave the batcher queue but sit in
    // the server's ready backlog — admission must still refuse beyond
    // queue_cap, because the contract bounds the whole undispatched
    // backlog, not just the raw queue.
    let compiler = Compiler::new();
    let spec = mk_spec("backlog", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 3);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(2)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 2,
        max_wait_cycles: 1_000_000,
        queue_cap: 5,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let row = vec![0i16; 2];
    // requests 1–2 form a full batch that dispatches immediately (the
    // board is free); 3–6 form two batches stuck behind the busy board;
    // 7 queues. Backlog is now 5 = queue_cap, so request 8 is refused.
    for _ in 0..7 {
        server.submit_at(0, nid, &row).unwrap();
    }
    let err = server.submit_at(0, nid, &row).unwrap_err();
    assert!(
        matches!(err, ServeError::Shed { net: 0, depth: 5, cap: 5, priority: 0 }),
        "expected backlog Shed, got {err}"
    );
    server.drain().unwrap();
    assert_eq!(server.take_completions().len(), 7, "admitted requests must all complete");
    assert_eq!(server.report().nets[0].rejected, 1);
}

#[test]
fn typed_errors_for_bad_requests_and_clocks() {
    let compiler = Compiler::new();
    let spec = mk_spec("bad", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 2);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig::default()).unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    assert!(matches!(
        server.submit_at(0, nid + 1, &[0, 0]),
        Err(ServeError::UnknownNet(_))
    ));
    assert!(matches!(
        server.submit_at(0, nid, &[0, 0, 0]),
        Err(ServeError::BadRow { want: 2, got: 3, .. })
    ));
    server.submit_at(10, nid, &[0, 0]).unwrap();
    assert!(matches!(
        server.submit_at(3, nid, &[0, 0]),
        Err(ServeError::ClockSkew { at: 3, .. })
    ));
    // bad params at registration
    let short_w = vec![vec![0i16; 1]; 2];
    assert!(matches!(
        server.register(Arc::clone(&artifact), &short_w, &b),
        Err(ServeError::BadParams { layer: 0, what: "weights", .. })
    ));
    // bad config
    assert!(matches!(
        Server::open(ServeConfig { boards: 0, ..ServeConfig::default() }),
        Err(ServeError::Config(_))
    ));
    assert!(matches!(
        Server::open(ServeConfig { max_batch: 0, ..ServeConfig::default() }),
        Err(ServeError::Config(_))
    ));
    assert!(matches!(
        Server::open(ServeConfig { device: "nope".into(), ..ServeConfig::default() }),
        Err(ServeError::UnknownDevice(_))
    ));
}

#[test]
fn partial_batches_pad_to_the_bucket_and_record_fill() {
    let compiler = Compiler::new();
    let spec = mk_spec("pad", &[3, 6, 2]);
    let (w, b) = seeded_params(&spec, 5);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 4,
        // all 3 rows arrive at cycle 0 and flush together at the
        // deadline: one partial batch riding the 4-bucket
        max_wait_cycles: 5,
        queue_cap: 16,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let mut r = Rng::new(6);
    let rows: Vec<Vec<i16>> = (0..3)
        .map(|_| (0..3).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for row in &rows {
        server.submit_at(0, nid, row).unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 3);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.bucket, 4, "3 rows must ride the 4-bucket");
        assert_eq!(c.batch_rows, 3);
        let want = reference.infer(&rows[i]).unwrap().output;
        assert_eq!(c.output, want, "padding perturbed request {i}");
    }
    let m = &server.report().nets[0];
    assert_eq!(m.batches, 1);
    assert!((m.batch_fill() - 0.75).abs() < 1e-12);
}

#[test]
fn multi_tenant_requests_route_to_their_nets() {
    let compiler = Compiler::new();
    let spec_a = mk_spec("tenant_a", &[2, 6, 2]);
    let spec_b = mk_spec("tenant_b", &[5, 8, 3]);
    let (wa, ba) = seeded_params(&spec_a, 10);
    let (wb, bb) = seeded_params(&spec_b, 11);
    let (_, mut ref_a) = reference_session(&compiler, &spec_a, &wa, &ba);
    let (_, mut ref_b) = reference_session(&compiler, &spec_b, &wb, &bb);
    let art_a = compiler.compile_spec(&spec_a, &CompileOptions::serving(4)).unwrap();
    let art_b = compiler.compile_spec(&spec_b, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 4,
        max_wait_cycles: 4,
        queue_cap: 32,
        ..ServeConfig::default()
    })
    .unwrap();
    let na = server.register(Arc::clone(&art_a), &wa, &ba).unwrap();
    let nb = server.register(Arc::clone(&art_b), &wb, &bb).unwrap();
    let workload = open_loop(24, 3, 2, &[2, 5], fixed());
    let mut expected = Vec::new();
    for q in &workload {
        let id = server.submit_at(q.at, [na, nb][q.net], &q.row).unwrap();
        let want = if q.net == 0 {
            ref_a.infer(&q.row).unwrap().output
        } else {
            ref_b.infer(&q.row).unwrap().output
        };
        expected.push((id, q.net, want));
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), expected.len());
    for (c, (id, net, want)) in comps.iter().zip(&expected) {
        assert_eq!(c.id, *id);
        assert_eq!(c.net, [na, nb][*net]);
        assert_eq!(&c.output, want, "tenant {net} output diverged");
    }
    let report = server.report();
    assert_eq!(report.nets.len(), 2);
    assert!(report.nets[0].completed > 0 && report.nets[1].completed > 0);
    assert!(report.nets[0].latency_p50() <= report.nets[0].latency_p99());
}

#[test]
fn pooled_batched_throughput_beats_single_board_batch1_by_2x() {
    // The serving acceptance criterion, asserted on simulated cycles
    // (deterministic — safe to gate in CI): 4 boards with a 32-bucket
    // ladder must serve a saturated workload at ≥ 2× the requests/sim-s
    // of 1 board at batch 1.
    let compiler = Compiler::new();
    let spec = mk_spec("thr", &[4, 16, 3]);
    let (w, b) = seeded_params(&spec, 77);
    let workload = open_loop(128, 0, 1, &[4], fixed());
    let run = |boards: usize, max_batch: usize| {
        let artifact =
            compiler.compile_spec(&spec, &CompileOptions::serving(max_batch)).unwrap();
        let mut server = Server::open(ServeConfig {
            boards,
            max_batch,
            max_wait_cycles: if max_batch == 1 { 0 } else { 64 },
            queue_cap: workload.len() + 1,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(artifact, &w, &b).unwrap();
        for q in &workload {
            server.submit_at(q.at, nid, &q.row).unwrap();
        }
        server.drain().unwrap();
        let report = server.report();
        assert_eq!(report.total_completed(), 128);
        report.requests_per_sim_s()
    };
    let single_b1 = run(1, 1);
    let pooled_b32 = run(4, 32);
    assert!(
        pooled_b32 >= 2.0 * single_b1,
        "pooled+batched {pooled_b32:.0} req/s < 2× single-board batch-1 {single_b1:.0} req/s"
    );
}

#[test]
fn evicting_a_board_is_idempotent() {
    // Regression: a second evict of the same board must not miscount
    // alive_boards or disturb the pool — external health checks may
    // fire redundantly.
    let compiler = Compiler::new();
    let spec = mk_spec("evict2", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 13);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(2)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 2,
        max_wait_cycles: 4,
        queue_cap: 16,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    assert_eq!(server.alive_boards(), 2);
    server.evict_board(1).unwrap();
    server.evict_board(1).unwrap();
    server.evict_board(1).unwrap();
    assert_eq!(server.alive_boards(), 1, "re-evicting a dead board must not double-count");
    assert!(matches!(server.evict_board(9), Err(ServeError::Config(_))));
    // the survivor still serves, bit-exactly
    let mut r = Rng::new(3);
    let rows: Vec<Vec<i16>> = (0..4)
        .map(|_| (0..2).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for (i, row) in rows.iter().enumerate() {
        server.submit_at(i as u64, nid, row).unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 4);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.output, reference.infer(&rows[i]).unwrap().output);
    }
    assert!(server.report().boards[1].evicted);
    assert_eq!(server.report().boards[1].batches, 0);
    // killing the last board makes pool exhaustion typed, not a hang
    server.evict_board(0).unwrap();
    assert_eq!(server.alive_boards(), 0);
    assert!(matches!(
        server.submit_at(1_000_000, nid, &rows[0]),
        Err(ServeError::NoBoards { boards: 2 })
    ));
}

#[test]
fn registered_but_never_submitted_net_reports_zero_quantiles() {
    // Regression: percentile over an empty latency set must render 0,
    // not panic or index out of bounds.
    let compiler = Compiler::new();
    let spec_a = mk_spec("busy", &[2, 4, 2]);
    let spec_b = mk_spec("idle", &[3, 4, 2]);
    let (wa, ba) = seeded_params(&spec_a, 1);
    let (wb, bb) = seeded_params(&spec_b, 2);
    let art_a = compiler.compile_spec(&spec_a, &CompileOptions::serving(4)).unwrap();
    let art_b = compiler.compile_spec(&spec_b, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 4,
        max_wait_cycles: 4,
        ..ServeConfig::default()
    })
    .unwrap();
    let na = server.register(art_a, &wa, &ba).unwrap();
    let _nb = server.register(art_b, &wb, &bb).unwrap();
    server.submit_at(0, na, &[0, 0]).unwrap();
    server.drain().unwrap();
    let report = server.report();
    assert_eq!(report.nets[1].submitted, 0);
    assert_eq!(report.nets[1].latency_p50(), 0, "idle net p50 must render as 0");
    assert_eq!(report.nets[1].latency_p99(), 0, "idle net p99 must render as 0");
    // both renderings stay total
    assert!(report.render().contains("idle"));
    assert!(report.to_json().contains("\"idle\""));
}

#[test]
fn shedding_is_priority_monotone_against_an_oracle_backlog() {
    // Property (satellite of the degraded-mode contract): at capacity
    // the server sheds exactly the worst of backlog ∪ {incoming} —
    // lowest priority first, ties to the latest deadline, then the
    // newest id. In particular no request is ever shed while a strictly
    // lower-priority one remains backlogged for the same net. Verified
    // against an oracle replaying the same decision rule.
    fn worse(a: (u8, u64, u64), b: (u8, u64, u64)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && (a.1 > b.1 || (a.1 == b.1 && a.2 > b.2)))
    }
    let compiler = Compiler::new();
    let spec = mk_spec("shedp", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 21);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(64)).unwrap();
    let cap = 8usize;
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 64,
        max_wait_cycles: 1_000_000,
        queue_cap: cap,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let row = vec![0i16; 2];
    let mut r = Rng::new(0x5ED);
    let mut oracle: Vec<(u8, u64, u64)> = Vec::new(); // (priority, eff deadline, id)
    let mut expect_shed: Vec<u64> = Vec::new();
    let mut next_id = 0u64;
    for _ in 0..60 {
        let priority = r.gen_range(3) as u8;
        let deadline = if r.gen_bool(0.5) { Some(256 + r.gen_range(2048)) } else { None };
        let inc = (priority, deadline.unwrap_or(u64::MAX), next_id);
        let res = server.submit_with(0, nid, &row, SubmitOptions { priority, deadline });
        if oracle.len() >= cap {
            let worst = oracle.iter().copied().fold(inc, |acc, k| {
                if worse(k, acc) {
                    k
                } else {
                    acc
                }
            });
            if worst == inc {
                let err = res.expect_err("oracle says the incoming request is the worst");
                assert!(
                    matches!(err, ServeError::Shed { net: 0, .. }),
                    "expected Shed, got {err}"
                );
                continue;
            }
            oracle.retain(|&k| k != worst);
            expect_shed.push(worst.2);
        }
        assert_eq!(res.unwrap(), next_id, "oracle and server disagree on admission");
        oracle.push(inc);
        next_id += 1;
    }
    let admitted = next_id as usize;
    server.drain().unwrap();
    let dropped = server.take_dropped();
    let shed: Vec<u64> = dropped
        .iter()
        .filter(|d| d.reason == DropReason::Shed)
        .map(|d| d.id)
        .collect();
    assert_eq!(shed, expect_shed, "server shed different victims than the oracle");
    // every admitted request still terminates exactly once, typed
    let comps = server.take_completions();
    assert_eq!(comps.len() + dropped.len(), admitted, "silent losses");
}

#[test]
fn default_submit_options_reproduce_plain_submission_bit_for_bit() {
    // Empty fault plan + default options ⇒ degraded mode is invisible:
    // submit_with(default) must equal submit_at on outputs, timing, and
    // the metrics snapshot.
    let compiler = Compiler::new();
    let spec = mk_spec("ident", &[3, 8, 2]);
    let (w, b) = seeded_params(&spec, 99);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let workload = open_loop(32, 11, 4, &[3], fixed());
    let run = |with_opts: bool| {
        let mut server = Server::open(ServeConfig {
            boards: 2,
            max_batch: 4,
            max_wait_cycles: 8,
            queue_cap: 64,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
        for q in &workload {
            if with_opts {
                server.submit_with(q.at, nid, &q.row, SubmitOptions::default()).unwrap();
            } else {
                server.submit_at(q.at, nid, &q.row).unwrap();
            }
        }
        server.drain().unwrap();
        assert!(server.take_dropped().is_empty());
        (server.report().to_json(), server.take_completions())
    };
    let (ja, ca) = run(false);
    let (jb, cb) = run(true);
    assert_eq!(ja, jb, "metrics diverge between submit_at and default submit_with");
    assert_eq!(ca.len(), cb.len());
    for (x, y) in ca.iter().zip(&cb) {
        assert_eq!((x.id, &x.output, x.dispatched, x.completed, x.bucket),
                   (y.id, &y.output, y.dispatched, y.completed, y.bucket));
    }
}

#[test]
fn corrupted_dispatch_hedges_onto_the_healthiest_free_board() {
    let compiler = Compiler::new();
    let spec = mk_spec("hedge", &[3, 6, 2]);
    let (w, b) = seeded_params(&spec, 31);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(4)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 4,
        max_wait_cycles: 8,
        queue_cap: 16,
        // board 0's first dispatch returns a corrupted output block
        faults: ServeFaultPlan::none().corrupt(0, 0),
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let mut r = Rng::new(8);
    let rows: Vec<Vec<i16>> = (0..4)
        .map(|_| (0..3).map(|_| fixed().from_f64(r.gen_f64() * 2.0 - 1.0)).collect())
        .collect();
    for row in &rows {
        server.submit_at(0, nid, row).unwrap();
    }
    server.drain().unwrap();
    assert!(server.take_dropped().is_empty(), "a single corruption is retryable, never a drop");
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 4);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(
            c.output,
            reference.infer(&rows[i]).unwrap().output,
            "hedged output corrupted"
        );
        assert!(c.dispatched > 0, "the retry re-dispatched after the corrupt run resolved");
    }
    let report = server.report();
    assert_eq!(report.nets[0].retries, 1);
    assert_eq!(report.boards[0].strikes, 1);
    assert_eq!(report.boards[1].batches, 1, "the hedge went to the clean board");
}

#[test]
fn repeated_strikes_quarantine_the_board_and_probation_recovers() {
    let compiler = Compiler::new();
    let spec = mk_spec("quar", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 47);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(2)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 2,
        max_wait_cycles: 4,
        queue_cap: 16,
        faults: ServeFaultPlan::none().corrupt(0, 0).corrupt(0, 1),
        quarantine_after: 2,
        quarantine_cycles: 500,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let rows = [vec![100i16, -50], vec![-25i16, 75]];
    for row in &rows {
        server.submit_at(0, nid, row).unwrap();
    }
    server.drain().unwrap();
    assert!(server.take_dropped().is_empty());
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 2);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.output, reference.infer(&rows[i]).unwrap().output);
        assert!(
            c.completed >= 500,
            "the third (clean) attempt had to wait out the quarantine"
        );
    }
    let report = server.report();
    assert_eq!(report.boards[0].strikes, 2);
    assert_eq!(report.boards[0].quarantines, 1);
    assert_eq!(report.nets[0].retries, 2);
    assert!(!report.boards[0].evicted, "quarantine is probation, not death");
}

#[test]
fn a_killed_board_redistributes_its_batch_without_burning_retries() {
    let compiler = Compiler::new();
    let spec = mk_spec("kill", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 53);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(2)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 2,
        max_batch: 2,
        max_wait_cycles: 4,
        queue_cap: 16,
        // board 0 dies taking its first batch: nothing ran, the batch
        // redistributes to board 1 without consuming retry budget
        faults: ServeFaultPlan::none().kill(0, 0),
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let rows = [vec![10i16, 20], vec![-30i16, 40]];
    for row in &rows {
        server.submit_at(0, nid, row).unwrap();
    }
    server.drain().unwrap();
    assert!(server.take_dropped().is_empty());
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 2);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.output, reference.infer(&rows[i]).unwrap().output);
    }
    assert_eq!(server.alive_boards(), 1);
    let report = server.report();
    assert!(report.boards[0].evicted);
    assert_eq!(report.boards[0].batches, 0, "the killed dispatch never ran");
    assert_eq!(report.boards[1].batches, 1);
    assert_eq!(report.nets[0].retries, 0, "a death is not a strike against the batch");
}

#[test]
fn deadline_at_risk_requests_flush_early_onto_a_smaller_bucket() {
    // Graceful degradation: an SLO deadline pulls the flush forward, so
    // the partial batch rides a smaller (faster) ladder bucket instead
    // of waiting out max_wait for a fuller batch.
    let compiler = Compiler::new();
    let spec = mk_spec("slo", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 61);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 8,
        max_wait_cycles: 1000,
        queue_cap: 16,
        deadline_slack_cycles: 16,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    let rows = [vec![5i16, -5], vec![15i16, 25]];
    for row in &rows {
        server
            .submit_with(0, nid, row, SubmitOptions { priority: 1, deadline: Some(100) })
            .unwrap();
    }
    server.drain().unwrap();
    let mut comps = server.take_completions();
    comps.sort_by_key(|c| c.id);
    assert_eq!(comps.len(), 2);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(c.dispatched, 84, "flush at deadline − slack, not at max_wait");
        assert_eq!(c.bucket, 2, "2 urgent rows ride the 2-bucket, not the 8-bucket");
        assert_eq!(c.output, reference.infer(&rows[i]).unwrap().output);
    }
}

#[test]
fn expired_requests_drop_typed_not_silently() {
    let compiler = Compiler::new();
    let spec = mk_spec("expire", &[2, 4, 2]);
    let (w, b) = seeded_params(&spec, 71);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(1)).unwrap();
    let mut server = Server::open(ServeConfig {
        boards: 1,
        max_batch: 1,
        max_wait_cycles: 0,
        queue_cap: 16,
        ..ServeConfig::default()
    })
    .unwrap();
    let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    // request A dispatches immediately and occupies the only board well
    // past cycle 2; request B's deadline expires while it waits.
    let a = server.submit_at(0, nid, &[1, 2]).unwrap();
    let b_id = server
        .submit_with(1, nid, &[3, 4], SubmitOptions { priority: 2, deadline: Some(2) })
        .unwrap();
    server.drain().unwrap();
    let comps = server.take_completions();
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].id, a);
    let dropped = server.take_dropped();
    assert_eq!(dropped.len(), 1);
    assert_eq!(dropped[0].id, b_id);
    assert_eq!(dropped[0].reason, DropReason::DeadlineExceeded);
    assert_eq!(dropped[0].deadline, Some(2));
    assert_eq!(server.report().nets[0].expired, 1);

    // a deadline already in the past is refused at submit, typed
    let err = server
        .submit_with(1_000_000, nid, &[0, 0], SubmitOptions { priority: 0, deadline: Some(50) })
        .unwrap_err();
    assert!(
        matches!(err, ServeError::DeadlineExceeded { net: 0, deadline: 50, at: 1_000_000 }),
        "expected submit-time DeadlineExceeded, got {err}"
    );
}

#[test]
fn survivable_chaos_terminates_every_request_typed_and_bit_exact() {
    // The degraded-mode acceptance property end to end: a seeded
    // survivable fault plan against an SLO-annotated open-loop stream —
    // every admitted request completes or drops typed, completed
    // outputs match the batch-1 reference bit for bit, and the whole
    // outcome replays deterministically.
    let compiler = Compiler::new();
    let spec = mk_spec("chaos", &[3, 8, 2]);
    let (w, b) = seeded_params(&spec, 85);
    let (_, mut reference) = reference_session(&compiler, &spec, &w, &b);
    let artifact = compiler.compile_spec(&spec, &CompileOptions::serving(8)).unwrap();
    let workload = slo_open_loop(48, 5, 3, &[3], fixed());
    let want: Vec<Vec<i16>> =
        workload.iter().map(|q| reference.infer(&q.row).unwrap().output).collect();
    let boards = 3usize;
    let plan = ServeFaultPlan::survivable(0xC405, boards, 3);
    assert!(plan.is_survivable(boards, 3));
    let run = || {
        let mut server = Server::open(ServeConfig {
            boards,
            max_batch: 8,
            max_wait_cycles: 16,
            queue_cap: 64,
            faults: plan.clone(),
            max_retries: 3,
            ..ServeConfig::default()
        })
        .unwrap();
        let nid = server.register(Arc::clone(&artifact), &w, &b).unwrap();
        let mut admitted = Vec::new();
        for (i, q) in workload.iter().enumerate() {
            match server.submit_with(q.at, nid, &q.row, q.options()) {
                Ok(id) => admitted.push((id, i)),
                Err(ServeError::Shed { .. }) | Err(ServeError::DeadlineExceeded { .. }) => {}
                Err(e) => panic!("untyped submit failure: {e}"),
            }
        }
        server.drain().unwrap();
        (admitted, server.take_completions(), server.take_dropped(), server.report().to_json())
    };
    let (admitted, comps, dropped, json) = run();
    assert_eq!(
        comps.len() + dropped.len(),
        admitted.len(),
        "every admitted request must terminate exactly once"
    );
    assert!(
        dropped.iter().all(|d| d.reason != DropReason::RetryBudget),
        "a survivable plan never exhausts the hedged-retry budget"
    );
    let index: std::collections::BTreeMap<u64, usize> = admitted.iter().copied().collect();
    for c in &comps {
        assert_eq!(c.output, want[index[&c.id]], "fault-era output diverged from reference");
    }
    let (admitted2, comps2, dropped2, json2) = run();
    assert_eq!(admitted, admitted2);
    assert_eq!(dropped, dropped2);
    assert_eq!(json, json2, "chaos outcome must replay deterministically");
    assert_eq!(comps.len(), comps2.len());
    for (x, y) in comps.iter().zip(&comps2) {
        assert_eq!((x.id, &x.output, x.completed), (y.id, &y.output, y.completed));
    }
}

#[test]
fn ladder_report_and_clock_accessors_are_consistent() {
    let server = Server::open(ServeConfig { max_batch: 8, ..ServeConfig::default() }).unwrap();
    assert_eq!(server.ladder(), &[1, 2, 4, 8]);
    assert_eq!(server.now(), 0);
    assert_eq!(server.device().part.name, "XC7S75-2");
    let report = server.report();
    assert_eq!(report.total_submitted(), 0);
    assert_eq!(report.makespan_cycles, 0);
}
