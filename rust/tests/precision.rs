//! Golden tests for the per-tensor precision search and the planned
//! compile pipeline (DESIGN.md §Memory planner): searched formats stay
//! within the caller's error budget of the float64 oracle, never widen
//! past the uniform default, apply coherently through the compiler, and
//! memory-planned artifacts infer bit-identically to packed ones.

use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::precision;
use mfnn::nn::float_ref::FloatMlp;
use mfnn::session::{CompileOptions, Compiler, Session, Target};
use mfnn::util::Rng;
use std::sync::Arc;

/// Paper-style MLP specs the golden assertions sweep: the Q8.7 datapath
/// of the paper plus wider Q11/Q12 variants where a narrow budget has
/// real room to shrink.
fn specs() -> Vec<MlpSpec> {
    let mk = |name: &str, dims: &[usize], act, frac: u32| {
        let fixed = FixedSpec::q(frac).saturating();
        MlpSpec::from_dims(name, dims, act, ActKind::Identity, fixed, LutParams::training(fixed))
            .unwrap()
    };
    vec![
        mk("paper_q7", &[8, 16, 4], ActKind::Sigmoid, 7),
        mk("tanh_q11", &[6, 12, 12, 3], ActKind::Tanh, 11),
        mk("relu_q12", &[10, 20, 5], ActKind::Relu, 12),
    ]
}

#[test]
fn searched_plans_stay_within_budget_of_the_float_oracle() {
    // A budget the uniform default comfortably meets must be met by the
    // combined searched plan, and the reported error must reproduce
    // against the oracle on the exact probe construction.
    for spec in specs() {
        let budget = 0.08;
        let plan = precision::search_spec(&spec, budget, 0x90_1D);
        assert!(
            plan.max_err <= budget,
            "{}: combined error {} exceeds budget {budget}",
            spec.name,
            plan.max_err
        );
        // Reproduce the oracle comparison: same seeded init and probe
        // stream as search_spec.
        let mut rng = Rng::new(0x90_1D);
        let m = FloatMlp::init(&spec, &mut rng);
        let in_dim = spec.layers[0].inputs;
        let mut worst = 0.0f64;
        for _ in 0..32 {
            let x: Vec<f64> = (0..in_dim).map(|_| rng.gen_f64() * 2.0 - 1.0).collect();
            let want = m.forward(&x);
            let got = plan.forward(&m, &x);
            for (w, g) in want.iter().zip(&got) {
                worst = worst.max((w - g).abs());
            }
        }
        assert!(
            worst <= budget,
            "{}: oracle disagreement {worst} exceeds budget {budget}",
            spec.name
        );
        assert_eq!(worst, plan.max_err, "{}: reported error must be the probe error", spec.name);
    }
}

#[test]
fn searched_formats_never_widen_past_the_uniform_default() {
    for spec in specs() {
        for budget in [1e-6, 1e-3, 0.05, 0.5] {
            for seed in [1u64, 0xBEEF, 42] {
                let plan = precision::search_spec(&spec, budget, seed);
                assert!(
                    plan.unified().frac_bits <= spec.fixed.frac_bits,
                    "{}: budget {budget} seed {seed} widened Q{} to Q{}",
                    spec.name,
                    spec.fixed.frac_bits,
                    plan.unified().frac_bits
                );
                for c in &plan.per_layer {
                    assert!(c.spec.frac_bits <= spec.fixed.frac_bits);
                    assert!(c.spec.frac_bits >= 1);
                }
                assert_eq!(plan.unified().round, spec.fixed.round);
                // Deterministic: the same inputs always pick the same plan.
                assert_eq!(plan, precision::search_spec(&spec, budget, seed));
            }
        }
    }
}

#[test]
fn loose_budgets_narrow_wide_datapaths() {
    // On a Q12 datapath a 0.25 max-abs-error budget is orders of
    // magnitude above the quantisation floor: the search must find a
    // strictly narrower format, and monotonically — looser budgets never
    // pick wider formats than tighter ones.
    let spec = specs().remove(2);
    let mut prev = u32::MAX;
    for budget in [1e-5, 1e-3, 0.05, 0.25] {
        let plan = precision::search_spec(&spec, budget, 7);
        let frac = plan.unified().frac_bits;
        assert!(frac <= prev, "budget {budget} widened Q{prev} to Q{frac}");
        prev = frac;
    }
    assert!(prev < spec.fixed.frac_bits, "0.25 budget should narrow a Q12 datapath");
}

#[test]
fn compiler_applies_the_searched_format_coherently() {
    let compiler = Compiler::new();
    let spec = specs().remove(1); // tanh_q11
    let searched = precision::search_spec(&spec, 0.25, 0x9E3779B97F4A7C15);
    let a = compiler
        .compile_spec(&spec, &CompileOptions::inference(4).with_precision_search(0.25))
        .unwrap();
    // The artifact's datapath is the searched unified format, with the
    // training LUT re-derived from it.
    assert_eq!(a.fixed(), searched.unified());
    let got = a.spec().expect("MLP artifact");
    assert_eq!(got.lut, LutParams::training(searched.unified()));
    // Caching keys the options: a plain compile of the same spec is a
    // distinct artifact with the original format.
    let plain = compiler.compile_spec(&spec, &CompileOptions::inference(4)).unwrap();
    assert_eq!(plain.fixed(), spec.fixed);
    assert!(!Arc::ptr_eq(&a, &plain));
}

#[test]
fn graph_compiles_reject_precision_search_typed() {
    use mfnn::nn::graph::{GraphSpec, INPUT};
    use mfnn::session::Error;
    let fixed = FixedSpec::q(8).saturating();
    let mut g = GraphSpec::new("prec_graph", 4, fixed, LutParams::training(fixed));
    let l = g.linear(INPUT, 4);
    g.activation(l, ActKind::Relu);
    let compiler = Compiler::new();
    let err = compiler
        .compile_graph(&g, &CompileOptions::inference(2).with_precision_search(0.1))
        .expect_err("graphs have no float_ref oracle");
    assert!(matches!(err, Error::Unsupported { verb: "compile_graph", .. }), "{err}");
}

#[test]
fn memory_planned_artifacts_infer_bit_identically_to_packed() {
    // The compile-level twin of the memplan fuzz family: the same spec
    // compiled with and without `memory_plan` must produce bit-identical
    // inference through the Session front door.
    let device = FpgaDevice::selected();
    let compiler = Compiler::new();
    for spec in specs() {
        let fixed = spec.fixed;
        let batch = 3;
        let mut r = Rng::new(0x91A2);
        let params: Vec<(Vec<i16>, Vec<i16>)> = spec
            .layers
            .iter()
            .map(|l| {
                let scale = 1.0 / l.inputs as f64;
                let w = (0..l.inputs * l.outputs)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * scale))
                    .collect();
                let b = (0..l.outputs)
                    .map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * 0.25))
                    .collect();
                (w, b)
            })
            .collect();
        let x: Vec<i16> = (0..batch * spec.input_dim())
            .map(|_| fixed.from_f64(r.gen_f64() * 2.0 - 1.0))
            .collect();

        let mut outputs = Vec::new();
        for opts in [
            CompileOptions::inference(batch),
            CompileOptions::inference(batch).with_memory_plan(),
        ] {
            let a = compiler.compile_spec(&spec, &opts).unwrap();
            let mut s = Session::open(Arc::clone(&a), Target::Board(device)).unwrap();
            for (l, (w, b)) in params.iter().enumerate() {
                s.write(&a.tensor(&format!("w{l}")).unwrap(), w).unwrap();
                s.write(&a.tensor(&format!("b{l}")).unwrap(), b).unwrap();
            }
            outputs.push(s.infer(&x).unwrap().output);
        }
        assert_eq!(outputs[0], outputs[1], "{}: planned infer diverged from packed", spec.name);
    }
}
