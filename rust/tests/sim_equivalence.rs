//! Structural (cycle-accurate, microcode-interpreting) simulator vs the
//! fast functional simulator: identical numerics on randomized programs.
//! This is the promise that lets training runs use the fast path while
//! timing claims rest on the structural model.

use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
use mfnn::fixed::FixedSpec;
use mfnn::hw::{FpgaDevice, MatrixMachine};
use mfnn::isa::Opcode;
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::util::Rng;

/// Build a random but valid program over a handful of buffers.
fn random_program(seed: u64, fixed: FixedSpec) -> (Program, Vec<(usize, Vec<i16>)>) {
    let mut r = Rng::new(seed);
    let n = 8 + r.gen_range(60) as usize; // vector length
    let mut p = Program::new("rand", fixed);
    let n_bufs = 4 + r.gen_range(3) as usize;
    let mut binds = Vec::new();
    for i in 0..n_bufs {
        let id = p.buffer(&format!("buf{i}"), n, 1, if i == 0 { BufKind::Input } else { BufKind::Output });
        let data: Vec<i16> = (0..n).map(|_| r.gen_range_i64(-6000, 6000) as i16).collect();
        binds.push((id, data));
    }
    let scalar = p.buffer("scalar", n_bufs, 1, BufKind::Output);
    let lut_id = p.lut(
        ActLut::build(ActKind::Tanh, false, fixed, AddrMode::Clamp, fixed.frac_bits.saturating_sub(4))
            .with_interp(),
    );
    p.steps.push(Step::LoadLut(lut_id));
    let n_waves = 3 + r.gen_range(8) as usize;
    for wi in 0..n_waves {
        let op = *r.choose(&[
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
            Opcode::VectorDotProduct,
            Opcode::VectorSummation,
            Opcode::ActivationFunction,
        ]);
        let a = r.gen_range(n_bufs as u64) as usize;
        let b = r.gen_range(n_bufs as u64) as usize;
        let dst = 1 + r.gen_range((n_bufs - 1) as u64) as usize;
        let lanes = match op {
            Opcode::VectorDotProduct | Opcode::VectorSummation => vec![LaneOp {
                a: View::all(a, n),
                b: (op == Opcode::VectorDotProduct).then(|| View::all(b, n)),
                out: View::contiguous(scalar, wi % n_bufs, 1),
            }],
            Opcode::ActivationFunction => vec![LaneOp {
                a: View::all(a, n),
                b: None,
                out: View::all(dst, n),
            }],
            _ => vec![LaneOp {
                a: View::all(a, n),
                b: Some(View::all(b, n)),
                out: View::all(dst, n),
            }],
        };
        p.steps.push(Step::Wave(Wave {
            op,
            vec_len: n,
            lut: (op == Opcode::ActivationFunction).then_some(lut_id),
            lanes,
        }));
    }
    (p, binds)
}

#[test]
fn random_programs_agree_between_fast_and_structural() {
    for seed in 0..12u64 {
        let fixed = if seed % 2 == 0 { FixedSpec::PAPER } else { FixedSpec::q(10).saturating() };
        let (p, binds) = random_program(seed, fixed);
        p.check().expect("random program must validate");
        let device = FpgaDevice::selected();
        let mut fast = MatrixMachine::new(device, &p).unwrap();
        let mut slow = MatrixMachine::new(device, &p).unwrap();
        for (id, data) in &binds {
            fast.bind(&p, &p.buffers[*id].name.clone(), data).unwrap();
            slow.bind(&p, &p.buffers[*id].name.clone(), data).unwrap();
        }
        let sf = fast.run(&p).unwrap();
        let sv = slow.run_verified(&p).expect("structural verification must pass");
        assert_eq!(sf.cycles, sv.cycles, "seed {seed}: cycle accounting diverged");
        for (id, _) in &binds {
            assert_eq!(fast.read_id(*id), slow.read_id(*id), "seed {seed} buffer {id}");
        }
    }
}

#[test]
fn multi_lane_waves_verify_structurally() {
    // Wide waves exercise the group-batch split inside run_verified.
    let fixed = FixedSpec::q(10).saturating();
    let mut r = Rng::new(77);
    let n = 32usize;
    let lanes_count = 19; // not a multiple of 4: partial batch at the tail
    let mut p = Program::new("wide", fixed);
    let a = p.buffer("a", lanes_count, n, BufKind::Input);
    let o = p.buffer("o", lanes_count, n, BufKind::Output);
    let lanes: Vec<LaneOp> = (0..lanes_count)
        .map(|i| LaneOp {
            a: View::contiguous(a, i * n, n),
            b: Some(View::contiguous(a, ((i + 7) % lanes_count) * n, n)),
            out: View::contiguous(o, i * n, n),
        })
        .collect();
    p.steps.push(Step::Wave(Wave { op: Opcode::ElementMultiplication, vec_len: n, lut: None, lanes }));
    let data: Vec<i16> = (0..lanes_count * n).map(|_| r.gen_i16()).collect();
    let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
    m.bind(&p, "a", &data).unwrap();
    m.run_verified(&p).unwrap();
}
