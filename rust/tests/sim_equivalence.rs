//! Structural (cycle-accurate, microcode-interpreting) simulator vs the
//! fast functional simulator: identical numerics on randomized programs.
//! This is the promise that lets training runs use the fast path while
//! timing claims rest on the structural model.

use mfnn::assembler::program::{BufKind, LaneOp, Program, Step, View, Wave};
use mfnn::fixed::FixedSpec;
use mfnn::hw::{ExecPlan, FpgaDevice, MatrixMachine};
use mfnn::isa::Opcode;
use mfnn::nn::lut::{ActKind, ActLut, AddrMode};
use mfnn::util::Rng;

/// Run the same bindings through the fast (compiled-plan) path and the
/// structurally-verified path; assert identical cycle accounting and
/// identical contents of every buffer.
fn assert_fast_matches_structural(p: &Program, binds: &[(usize, Vec<i16>)], tag: &str) {
    let device = FpgaDevice::selected();
    let mut fast = MatrixMachine::new(device, p).unwrap();
    let mut slow = MatrixMachine::new(device, p).unwrap();
    for (id, data) in binds {
        let name = p.buffers[*id].name.clone();
        fast.bind_named(&name, data).unwrap();
        slow.bind_named(&name, data).unwrap();
    }
    let sf = fast.execute();
    let sv = slow.execute_verified().expect("structural verification must pass");
    assert_eq!(sf.cycles, sv.cycles, "{tag}: cycle accounting diverged");
    assert_eq!(sf, sv, "{tag}: run stats diverged");
    for id in 0..p.buffers.len() {
        assert_eq!(fast.read_id(id), slow.read_id(id), "{tag} buffer {id}");
    }
}

/// Build a random but valid program over a handful of buffers.
fn random_program(seed: u64, fixed: FixedSpec) -> (Program, Vec<(usize, Vec<i16>)>) {
    let mut r = Rng::new(seed);
    let n = 8 + r.gen_range(60) as usize; // vector length
    let mut p = Program::new("rand", fixed);
    let n_bufs = 4 + r.gen_range(3) as usize;
    let mut binds = Vec::new();
    for i in 0..n_bufs {
        let kind = if i == 0 { BufKind::Input } else { BufKind::Output };
        let id = p.buffer(&format!("buf{i}"), n, 1, kind);
        let data: Vec<i16> = (0..n).map(|_| r.gen_range_i64(-6000, 6000) as i16).collect();
        binds.push((id, data));
    }
    let scalar = p.buffer("scalar", n_bufs, 1, BufKind::Output);
    let shift = fixed.frac_bits.saturating_sub(4);
    let lut_id =
        p.lut(ActLut::build(ActKind::Tanh, false, fixed, AddrMode::Clamp, shift).with_interp());
    p.steps.push(Step::LoadLut(lut_id));
    let n_waves = 3 + r.gen_range(8) as usize;
    for wi in 0..n_waves {
        let op = *r.choose(&[
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
            Opcode::VectorDotProduct,
            Opcode::VectorSummation,
            Opcode::ActivationFunction,
        ]);
        let a = r.gen_range(n_bufs as u64) as usize;
        let b = r.gen_range(n_bufs as u64) as usize;
        let dst = 1 + r.gen_range((n_bufs - 1) as u64) as usize;
        let lanes = match op {
            Opcode::VectorDotProduct | Opcode::VectorSummation => vec![LaneOp {
                a: View::all(a, n),
                b: (op == Opcode::VectorDotProduct).then(|| View::all(b, n)),
                out: View::contiguous(scalar, wi % n_bufs, 1),
            }],
            Opcode::ActivationFunction => vec![LaneOp {
                a: View::all(a, n),
                b: None,
                out: View::all(dst, n),
            }],
            _ => vec![LaneOp {
                a: View::all(a, n),
                b: Some(View::all(b, n)),
                out: View::all(dst, n),
            }],
        };
        p.steps.push(Step::Wave(Wave {
            op,
            vec_len: n,
            lut: (op == Opcode::ActivationFunction).then_some(lut_id),
            lanes,
        }));
    }
    (p, binds)
}

#[test]
fn random_programs_agree_between_fast_and_structural() {
    for seed in 0..12u64 {
        let fixed = if seed % 2 == 0 { FixedSpec::PAPER } else { FixedSpec::q(10).saturating() };
        let (p, binds) = random_program(seed, fixed);
        p.check().expect("random program must validate");
        let device = FpgaDevice::selected();
        let mut fast = MatrixMachine::new(device, &p).unwrap();
        let mut slow = MatrixMachine::new(device, &p).unwrap();
        for (id, data) in &binds {
            fast.write_id(*id, data).unwrap();
            slow.write_id(*id, data).unwrap();
        }
        let sf = fast.execute();
        let sv = slow.execute_verified().expect("structural verification must pass");
        assert_eq!(sf.cycles, sv.cycles, "seed {seed}: cycle accounting diverged");
        for (id, _) in &binds {
            assert_eq!(fast.read_id(*id), slow.read_id(*id), "seed {seed} buffer {id}");
        }
    }
}

#[test]
fn multi_lane_waves_verify_structurally() {
    // Wide waves exercise the group-batch split inside run_verified.
    let fixed = FixedSpec::q(10).saturating();
    let mut r = Rng::new(77);
    let n = 32usize;
    let lanes_count = 19; // not a multiple of 4: partial batch at the tail
    let mut p = Program::new("wide", fixed);
    let a = p.buffer("a", lanes_count, n, BufKind::Input);
    let o = p.buffer("o", lanes_count, n, BufKind::Output);
    let lanes: Vec<LaneOp> = (0..lanes_count)
        .map(|i| LaneOp {
            a: View::contiguous(a, i * n, n),
            b: Some(View::contiguous(a, ((i + 7) % lanes_count) * n, n)),
            out: View::contiguous(o, i * n, n),
        })
        .collect();
    p.steps.push(Step::Wave(Wave {
        op: Opcode::ElementMultiplication,
        vec_len: n,
        lut: None,
        lanes,
    }));
    let data: Vec<i16> = (0..lanes_count * n).map(|_| r.gen_i16()).collect();
    let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
    m.bind_named("a", &data).unwrap();
    m.execute_verified().unwrap();
}

/// Build a random program whose waves walk *columns* of row-major
/// matrices (stride = cols), exercising the plan's strided views.
fn random_strided_program(seed: u64, fixed: FixedSpec) -> (Program, Vec<(usize, Vec<i16>)>) {
    let mut r = Rng::new(seed);
    let rows = 4 + r.gen_range(12) as usize;
    let cols = 2 + r.gen_range(5) as usize;
    let mut p = Program::new("strided", fixed);
    let n_bufs = 3 + r.gen_range(3) as usize;
    let mut binds = Vec::new();
    for i in 0..n_bufs {
        let kind = if i == 0 { BufKind::Input } else { BufKind::Output };
        let id = p.buffer(&format!("m{i}"), rows, cols, kind);
        let data: Vec<i16> =
            (0..rows * cols).map(|_| r.gen_range_i64(-5000, 5000) as i16).collect();
        binds.push((id, data));
    }
    let scalar = p.buffer("scalar", cols, 1, BufKind::Output);
    let lut_id = p.lut(ActLut::build(ActKind::Relu, false, fixed, AddrMode::Clamp, 7));
    p.steps.push(Step::LoadLut(lut_id));
    let column = |buf: usize, c: usize| View { buf, offset: c, len: rows, stride: cols };
    let n_waves = 4 + r.gen_range(6) as usize;
    for wi in 0..n_waves {
        let op = *r.choose(&[
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
            Opcode::VectorDotProduct,
            Opcode::VectorSummation,
            Opcode::ActivationFunction,
        ]);
        let ca = r.gen_range(cols as u64) as usize;
        let cb = r.gen_range(cols as u64) as usize;
        let a_buf = r.gen_range(n_bufs as u64) as usize;
        let b_buf = r.gen_range(n_bufs as u64) as usize;
        let dst = 1 + r.gen_range((n_bufs - 1) as u64) as usize;
        let cd = r.gen_range(cols as u64) as usize;
        let lanes = match op {
            Opcode::VectorDotProduct | Opcode::VectorSummation => vec![LaneOp {
                a: column(a_buf, ca),
                b: (op == Opcode::VectorDotProduct).then(|| column(b_buf, cb)),
                out: View::contiguous(scalar, wi % cols, 1),
            }],
            Opcode::ActivationFunction => vec![LaneOp {
                a: column(a_buf, ca),
                b: None,
                out: column(dst, cd),
            }],
            _ => vec![LaneOp {
                a: column(a_buf, ca),
                b: Some(column(b_buf, cb)),
                out: column(dst, cd),
            }],
        };
        p.steps.push(Step::Wave(Wave {
            op,
            vec_len: rows,
            lut: (op == Opcode::ActivationFunction).then_some(lut_id),
            lanes,
        }));
    }
    (p, binds)
}

#[test]
fn random_strided_programs_agree_between_fast_and_structural() {
    for seed in 100..112u64 {
        let fixed = if seed % 2 == 0 { FixedSpec::PAPER } else { FixedSpec::q(10).saturating() };
        let (p, binds) = random_strided_program(seed, fixed);
        p.check().expect("random strided program must validate");
        assert_fast_matches_structural(&p, &binds, &format!("strided seed {seed}"));
    }
}

/// dot wave → activation over exactly the dot outputs: the plan fuses
/// the pair; the structural oracle executes them as two waves. Both the
/// numerics and the cycle accounting must be unchanged by fusion.
fn fused_dot_act_program(
    seed: u64,
    fixed: FixedSpec,
) -> (Program, Vec<(usize, Vec<i16>)>) {
    let mut r = Rng::new(seed);
    let lanes_n = 4 + r.gen_range(36) as usize;
    let len = 4 + r.gen_range(28) as usize;
    let in_place = seed % 2 == 0;
    let strided_b = seed % 3 == 0;
    let mut p = Program::new("fused", fixed);
    let a = p.buffer("a", lanes_n, len, BufKind::Input);
    let w = p.buffer("w", len, lanes_n, BufKind::Weight); // column operands
    let z = p.buffer("z", lanes_n, 1, BufKind::Temp);
    let o = p.buffer("o", lanes_n, 1, BufKind::Output);
    let lut = p.lut(ActLut::build(ActKind::Tanh, false, fixed, AddrMode::Clamp, 7));
    let mut binds = Vec::new();
    for (id, n) in [(a, lanes_n * len), (w, len * lanes_n)] {
        let data: Vec<i16> = (0..n).map(|_| r.gen_range_i64(-4000, 4000) as i16).collect();
        binds.push((id, data));
    }
    let dots: Vec<LaneOp> = (0..lanes_n)
        .map(|i| LaneOp {
            a: View::contiguous(a, i * len, len),
            b: Some(if strided_b {
                View { buf: w, offset: i, len, stride: lanes_n } // column i of w
            } else {
                View::contiguous(a, ((i + 1) % lanes_n) * len, len)
            }),
            out: View::contiguous(z, i, 1),
        })
        .collect();
    p.steps.push(Step::Wave(Wave {
        op: Opcode::VectorDotProduct,
        vec_len: len,
        lut: None,
        lanes: dots,
    }));
    p.steps.push(Step::LoadLut(lut));
    p.steps.push(Step::Wave(Wave {
        op: Opcode::ActivationFunction,
        vec_len: lanes_n,
        lut: Some(lut),
        lanes: vec![LaneOp {
            a: View::all(z, lanes_n),
            b: None,
            out: if in_place { View::all(z, lanes_n) } else { View::all(o, lanes_n) },
        }],
    }));
    (p, binds)
}

#[test]
fn fused_dot_act_programs_agree_between_fast_and_structural() {
    let device = FpgaDevice::selected();
    for seed in 200..212u64 {
        let fixed = if seed % 2 == 0 { FixedSpec::PAPER } else { FixedSpec::q(10).saturating() };
        let (p, binds) = fused_dot_act_program(seed, fixed);
        p.check().expect("fused program must validate");
        // the optimisation actually fires
        let plan = ExecPlan::new(&p, &device);
        assert_eq!(plan.fused_waves(), 1, "seed {seed}: dot→act pair must fuse");
        assert_fast_matches_structural(&p, &binds, &format!("fused seed {seed}"));
    }
}
