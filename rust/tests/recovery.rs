//! Crash-tolerance acceptance tests (DESIGN.md §Recovery): chunk
//! rescheduling keeps results bit-identical to the fault-free run,
//! checkpoint→resume reproduces the uninterrupted run bit-exactly, the
//! serving pool survives board eviction, and no worker thread outlives
//! the leader.

use mfnn::cluster::{ClusterConfig, FaultPlan, RecoveryPolicy};
use mfnn::fixed::FixedSpec;
use mfnn::hw::FpgaDevice;
use mfnn::nn::dataset;
use mfnn::nn::lut::ActKind;
use mfnn::nn::mlp::{LutParams, MlpSpec};
use mfnn::nn::trainer::TrainConfig;
use mfnn::{
    CompileOptions, Compiler, Session, Target, TrainCheckpoint, TrainOptions,
};
use std::sync::Arc;

const LR: f64 = 1.0 / 128.0;

fn spec(name: &str) -> MlpSpec {
    let fixed = FixedSpec::q(10).saturating();
    MlpSpec::from_dims(
        name,
        &[2, 8, 2],
        ActKind::Relu,
        ActKind::Identity,
        fixed,
        LutParams::training(fixed),
    )
    .unwrap()
}

fn session(name: &str, target: Target) -> Session {
    let compiler = Compiler::new();
    let artifact = compiler
        .compile_spec(&spec(name), &CompileOptions::training(8, LR))
        .unwrap();
    Session::open(artifact, target).unwrap()
}

fn cfg(steps: usize, seed: u64) -> TrainConfig {
    TrainConfig { batch: 8, lr: LR, steps, seed, log_every: 5 }
}

#[test]
fn board_resume_from_every_checkpoint_reproduces_the_full_run() {
    // The acceptance property: resume(k) ≡ uninterrupted run, for every
    // captured k — weights, loss curve, and stats, bit for bit. Each
    // snapshot additionally round-trips through its byte serialisation.
    let ds = dataset::xor(64, 3);
    let c = cfg(40, 11);
    let mut full = session("ckpt_net", Target::Board(FpgaDevice::selected()));
    let (summary, ckpts) =
        full.train_with(&ds, &c, &TrainOptions::checkpoint_every(10)).unwrap();
    assert_eq!(ckpts.len(), 4, "40 steps / every 10");
    let want = full.weights().expect("trainable");
    for ck in &ckpts {
        let ck = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        let at = ck.steps_done;
        let mut resumed = session("ckpt_net", Target::Board(FpgaDevice::selected()));
        let opts = TrainOptions { checkpoint_every: 10, resume: Some(ck) };
        let (rsum, _) = resumed.train_with(&ds, &c, &opts).unwrap();
        assert_eq!(resumed.weights().unwrap(), want, "weights diverged resuming at {at}");
        assert_eq!(rsum.curve, summary.curve, "curve diverged resuming at {at}");
        assert_eq!(rsum.stats, summary.stats, "stats diverged resuming at {at}");
        assert_eq!(rsum.sim_seconds, summary.sim_seconds, "sim time diverged at {at}");
    }
}

#[test]
fn resume_against_the_wrong_run_is_a_typed_error() {
    let ds = dataset::xor(64, 3);
    let c = cfg(20, 11);
    let mut s = session("ckpt_net", Target::Board(FpgaDevice::selected()));
    let (_, ckpts) = s.train_with(&ds, &c, &TrainOptions::checkpoint_every(10)).unwrap();
    let ck = ckpts[0].clone();
    // wrong seed
    let mut other = session("ckpt_net", Target::Board(FpgaDevice::selected()));
    let bad = cfg(20, 12);
    let err = other.train_with(&ds, &bad, &TrainOptions::resume(ck.clone())).unwrap_err();
    assert!(matches!(err, mfnn::Error::Checkpoint(_)), "{err}");
    // fewer total steps than the snapshot has trained
    let short = cfg(5, 11);
    let err = other.train_with(&ds, &short, &TrainOptions::resume(ck)).unwrap_err();
    assert!(matches!(err, mfnn::Error::Checkpoint(_)), "{err}");
}

#[test]
fn cluster_session_checkpoints_and_resumes_bit_exactly() {
    // Divided 2-board cluster target: snapshots land on weight-sync
    // boundaries; a fresh session resumed from the mid-run snapshot
    // adopts exactly the uninterrupted run's final weights and curve.
    let ds = dataset::blobs(96, 2, 2, 5);
    let c = cfg(40, 21);
    let ccfg = ClusterConfig { boards: 2, sync_every: 10, ..Default::default() };
    let mut full = session("cluster_ck", Target::Cluster(ccfg.clone()));
    let (summary, ckpts) =
        full.train_with(&ds, &c, &TrainOptions::checkpoint_every(20)).unwrap();
    assert!(!ckpts.is_empty(), "no cluster checkpoints captured");
    let mid = &ckpts[0];
    assert_eq!(mid.steps_done % 10, 0, "snapshot off a sync boundary");
    assert!(mid.steps_done < 40);
    let mut resumed = session("cluster_ck", Target::Cluster(ccfg));
    let opts = TrainOptions { checkpoint_every: 20, resume: Some(mid.clone()) };
    let (rsum, _) = resumed.train_with(&ds, &c, &opts).unwrap();
    assert_eq!(resumed.weights().unwrap(), full.weights().unwrap());
    assert_eq!(rsum.curve, summary.curve);
    assert_eq!(rsum.stats, summary.stats);
}

#[test]
fn kill_one_board_then_resume_from_checkpoint_file() {
    // The CI recovery smoke scenario end-to-end: a 3-board divided job
    // loses board 1 mid-run but completes bit-identically to the clean
    // run; its mid-run snapshot, round-tripped through a file, resumes
    // a third run to the same final weights.
    let ds = dataset::blobs(96, 2, 2, 9);
    let c = cfg(40, 33);
    let base = ClusterConfig {
        boards: 3,
        sync_every: 10,
        recovery: RecoveryPolicy::checkpointed(10),
        ..Default::default()
    };
    let mut clean = session("smoke", Target::Cluster(base.clone()));
    let (clean_sum, clean_ckpts) =
        clean.train_with(&ds, &c, &TrainOptions::default()).unwrap();
    let faulty_cfg = ClusterConfig {
        faults: FaultPlan::none().kill(1, 4),
        ..base.clone()
    };
    let mut faulty = session("smoke", Target::Cluster(faulty_cfg));
    let (faulty_sum, faulty_ckpts) =
        faulty.train_with(&ds, &c, &TrainOptions::default()).unwrap();
    assert_eq!(faulty.weights().unwrap(), clean.weights().unwrap(), "recovery diverged");
    assert_eq!(faulty_sum.curve, clean_sum.curve);
    assert_eq!(faulty_ckpts.len(), clean_ckpts.len());
    // checkpoint file round-trip → resume → same end state
    let mid = clean_ckpts.iter().find(|ck| ck.steps_done == 20).expect("mid snapshot");
    let dir = std::env::temp_dir().join(format!("mfnn_recovery_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("smoke.mfck");
    mid.save(&path).unwrap();
    let loaded = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let mut resumed = session("smoke", Target::Cluster(base));
    let opts = TrainOptions { checkpoint_every: 0, resume: Some(loaded) };
    resumed.train_with(&ds, &c, &opts).unwrap();
    assert_eq!(resumed.weights().unwrap(), clean.weights().unwrap());
}

#[test]
fn serve_eviction_redistributes_the_backlog_without_errors() {
    use mfnn::serve::{seeded_params, ServeError};
    use mfnn::ServeConfig;
    let fixed = FixedSpec::q(10).saturating();
    let nspec = spec("served");
    let (w, b) = seeded_params(&nspec, 77);
    let compiler = Compiler::new();
    let artifact = compiler.compile_spec(&nspec, &CompileOptions::serving(4)).unwrap();
    let scfg = ServeConfig {
        boards: 2,
        max_batch: 4,
        max_wait_cycles: 16,
        queue_cap: 256,
        ..ServeConfig::default()
    };
    let rows: Vec<Vec<i16>> = (0..24)
        .map(|i| {
            vec![
                fixed.from_f64((i as f64 / 24.0) - 0.5),
                fixed.from_f64(0.5 - (i as f64 / 24.0)),
            ]
        })
        .collect();
    let run = |evict_at: Option<usize>| {
        let mut server = mfnn::Server::open(scfg.clone()).unwrap();
        let net = server.register(Arc::clone(&artifact), &w, &b).unwrap();
        for (i, row) in rows.iter().enumerate() {
            if evict_at == Some(i) {
                server.evict_board(1).unwrap();
                server.evict_board(1).unwrap(); // idempotent
            }
            server.submit_at(i as u64 * 3, net, row).unwrap();
        }
        server.drain().unwrap();
        let mut done = server.take_completions();
        done.sort_by_key(|r| r.id);
        (done, server.report())
    };
    let (healthy, _) = run(None);
    let (survived, report) = run(Some(8));
    assert_eq!(healthy.len(), 24);
    assert_eq!(survived.len(), 24, "eviction dropped requests");
    for (a, c) in healthy.iter().zip(&survived) {
        assert_eq!(a.output, c.output, "eviction changed request {} bitwise", a.id);
    }
    assert!(report.boards[1].evicted, "eviction not reported");
    assert!(!report.boards[0].evicted);
    // losing the whole pool is terminal and typed, never a hang
    let mut server = mfnn::Server::open(scfg.clone()).unwrap();
    let net = server.register(Arc::clone(&artifact), &w, &b).unwrap();
    server.submit_at(0, net, &rows[0]).unwrap();
    server.evict_board(0).unwrap();
    server.evict_board(1).unwrap();
    assert!(matches!(
        server.submit_at(1, net, &rows[1]),
        Err(ServeError::NoBoards { boards: 2 })
    ));
    match server.drain() {
        Ok(_) => {} // the backlog may already have dispatched pre-eviction
        Err(ServeError::NoBoards { .. }) => {}
        Err(e) => panic!("unexpected drain error: {e}"),
    }
    // out-of-range eviction is a typed config error
    assert!(server.evict_board(9).is_err());
}

/// Threads of this process whose name marks them as the 5-board pool of
/// [`no_worker_threads_survive_execute`] (board indices 0..=4; the
/// highest index is unique to that test within this test binary).
#[cfg(target_os = "linux")]
fn pool_marker_threads() -> usize {
    let Ok(dir) = std::fs::read_dir("/proc/self/task") else { return 0 };
    dir.filter_map(|e| e.ok())
        .filter(|e| {
            std::fs::read_to_string(e.path().join("comm"))
                .map(|c| c.trim() == "fpga-worker-4")
                .unwrap_or(false)
        })
        .count()
}

#[test]
fn no_worker_threads_survive_execute() {
    // Regression for the thread-leak bug: on abort AND on eviction the
    // leader must close command channels and join every surviving
    // worker before returning — no `fpga-worker-*` thread may outlive
    // `execute`. Uses a 5-board pool so its marker thread name is
    // unique within this test binary.
    use mfnn::cluster::leader::{execute, Job};
    let mk = |name: &str, seed: u64| Job {
        name: name.into(),
        spec: spec(name),
        cfg: cfg(8, seed),
        train_data: Arc::new(dataset::xor(64, seed)),
        test_data: Arc::new(dataset::xor(32, seed + 1)),
        initial: None,
        resume: None,
    };
    let jobs: Vec<Job> = (0..5).map(|i| mk(&format!("j{i}"), 40 + i as u64)).collect();
    // abort path: board 4 dies, recovery off → typed error
    let abort = ClusterConfig {
        boards: 5,
        faults: FaultPlan::none().kill(4, 0),
        recovery: RecoveryPolicy::abort(),
        ..Default::default()
    };
    assert!(execute(&abort, &jobs).is_err());
    #[cfg(target_os = "linux")]
    assert_eq!(pool_marker_threads(), 0, "worker thread leaked after abort");
    // eviction path: board 4 dies, recovery on → completes
    let recover = ClusterConfig {
        boards: 5,
        faults: FaultPlan::none().kill(4, 0),
        ..Default::default()
    };
    assert!(execute(&recover, &jobs).is_ok());
    #[cfg(target_os = "linux")]
    assert_eq!(pool_marker_threads(), 0, "worker thread leaked after eviction");
}
