//! Float64 reference MLP — the host-side oracle used to judge how well
//! the 16-bit fixed-point on-device training tracks ideal training
//! (EXPERIMENTS.md §E-E2E), and the "CPU baseline" role of §1.

use super::mlp::MlpSpec;
use crate::util::Rng;

/// Float weights for one MLP.
#[derive(Debug, Clone)]
pub struct FloatMlp {
    /// Layer dims mirrored from the spec.
    pub spec: MlpSpec,
    /// Per-layer `(inputs × outputs)` row-major weights.
    pub weights: Vec<Vec<f64>>,
    /// Per-layer biases.
    pub biases: Vec<Vec<f64>>,
}

impl FloatMlp {
    /// Initialise with scaled-uniform weights (He-like: ±sqrt(2/fan_in)),
    /// zero biases.
    pub fn init(spec: &MlpSpec, rng: &mut Rng) -> FloatMlp {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in &spec.layers {
            let scale = (2.0 / l.inputs as f64).sqrt();
            weights.push(
                (0..l.inputs * l.outputs).map(|_| (rng.gen_f64() * 2.0 - 1.0) * scale).collect(),
            );
            biases.push(vec![0.0; l.outputs]);
        }
        FloatMlp { spec: spec.clone(), weights, biases }
    }

    /// Forward one sample; returns all pre-activations and activations
    /// (`zs[l]`, `os[l]`), with `os.last()` the output.
    pub fn forward_trace(&self, x: &[f64]) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut zs = Vec::new();
        let mut os = Vec::new();
        let mut cur = x.to_vec();
        for (l, layer) in self.spec.layers.iter().enumerate() {
            let (n_in, n_out) = (layer.inputs, layer.outputs);
            let mut z = vec![0.0; n_out];
            for j in 0..n_out {
                let mut acc = self.biases[l][j];
                for i in 0..n_in {
                    acc += cur[i] * self.weights[l][i * n_out + j];
                }
                z[j] = acc;
            }
            let o: Vec<f64> = z.iter().map(|&v| layer.act.f(v)).collect();
            zs.push(z);
            os.push(o.clone());
            cur = o;
        }
        (zs, os)
    }

    /// Forward one sample → output vector.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.forward_trace(x).1.pop().unwrap()
    }

    /// One mini-batch SGD step with MSE loss; returns the batch's summed
    /// squared error (before the update).
    pub fn train_step(&mut self, xs: &[Vec<f64>], ys: &[Vec<f64>], lr: f64) -> f64 {
        let nl = self.spec.layers.len();
        let mut gw: Vec<Vec<f64>> = self.weights.iter().map(|w| vec![0.0; w.len()]).collect();
        let mut gb: Vec<Vec<f64>> = self.biases.iter().map(|b| vec![0.0; b.len()]).collect();
        let mut loss = 0.0;
        for (x, y) in xs.iter().zip(ys) {
            let (zs, os) = self.forward_trace(x);
            let out = &os[nl - 1];
            let mut delta: Vec<f64> = out
                .iter()
                .zip(y)
                .zip(&zs[nl - 1])
                .map(|((&o, &t), &z)| {
                    loss += (o - t) * (o - t);
                    (o - t) * self.spec.layers[nl - 1].act.df(z)
                })
                .collect();
            for l in (0..nl).rev() {
                let layer = self.spec.layers[l];
                let input: &[f64] = if l == 0 { x } else { &os[l - 1] };
                for i in 0..layer.inputs {
                    for j in 0..layer.outputs {
                        gw[l][i * layer.outputs + j] += input[i] * delta[j];
                    }
                }
                for j in 0..layer.outputs {
                    gb[l][j] += delta[j];
                }
                if l > 0 {
                    let prev = self.spec.layers[l - 1];
                    let mut nd = vec![0.0; layer.inputs];
                    for (i, nd_i) in nd.iter_mut().enumerate() {
                        let mut acc = 0.0;
                        for j in 0..layer.outputs {
                            acc += self.weights[l][i * layer.outputs + j] * delta[j];
                        }
                        *nd_i = acc * prev.act.df(zs[l - 1][i]);
                    }
                    delta = nd;
                }
            }
        }
        for l in 0..nl {
            for (w, g) in self.weights[l].iter_mut().zip(&gw[l]) {
                *w -= lr * g;
            }
            for (b, g) in self.biases[l].iter_mut().zip(&gb[l]) {
                *b -= lr * g;
            }
        }
        loss
    }

    /// Classification accuracy by argmax (one-hot targets).
    pub fn accuracy(&self, xs: &[Vec<f64>], ys: &[Vec<f64>]) -> f64 {
        let mut ok = 0usize;
        for (x, y) in xs.iter().zip(ys) {
            let o = self.forward(x);
            if argmax(&o) == argmax(y) {
                ok += 1;
            }
        }
        ok as f64 / xs.len().max(1) as f64
    }

    /// Quantise weights/biases into the spec's fixed-point format (the
    /// initial "flash" the trainer binds to the machine).
    pub fn quantized(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let f = self.spec.fixed;
        (
            self.weights.iter().map(|w| f.encode_vec(w)).collect(),
            self.biases.iter().map(|b| f.encode_vec(b)).collect(),
        )
    }
}

/// Index of the maximum element.
pub fn argmax(xs: &[f64]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;

    fn spec() -> MlpSpec {
        MlpSpec::from_dims(
            "f",
            &[2, 8, 1],
            ActKind::Tanh,
            ActKind::Identity,
            FixedSpec::q(10),
            LutParams::training(FixedSpec::q(10)),
        )
        .unwrap()
    }

    #[test]
    fn forward_identity_linear() {
        let s = MlpSpec::from_dims(
            "lin",
            &[2, 1],
            ActKind::Identity,
            ActKind::Identity,
            FixedSpec::q(10),
            LutParams::training(FixedSpec::q(10)),
        )
        .unwrap();
        let mut m = FloatMlp::init(&s, &mut Rng::new(1));
        m.weights[0] = vec![0.5, -0.25];
        m.biases[0] = vec![0.125];
        assert!((m.forward(&[1.0, 1.0])[0] - (0.5 - 0.25 + 0.125)).abs() < 1e-12);
    }

    #[test]
    fn learns_xor() {
        let s = spec();
        let mut m = FloatMlp::init(&s, &mut Rng::new(3));
        let xs: Vec<Vec<f64>> =
            vec![vec![0., 0.], vec![0., 1.], vec![1., 0.], vec![1., 1.]];
        let ys: Vec<Vec<f64>> = vec![vec![0.], vec![1.], vec![1.], vec![0.]];
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..2000 {
            let l = m.train_step(&xs, &ys, 0.1);
            if step == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first * 0.05, "first {first}, last {last}");
        for (x, y) in xs.iter().zip(&ys) {
            assert!((m.forward(x)[0] - y[0]).abs() < 0.25);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let s = spec();
        let mut m = FloatMlp::init(&s, &mut Rng::new(5));
        let x = vec![0.3, -0.7];
        let y = vec![0.4];
        // analytic gradient of 0.5 * dL/dw — our train_step applies
        // full (o-t)*df; replicate by measuring the loss decrease of a
        // small step against finite differences of the loss.
        let loss = |m: &FloatMlp| {
            let o = m.forward(&x)[0];
            (o - y[0]) * (o - y[0])
        };
        let eps = 1e-6;
        // pick one weight, compute numeric grad
        let base = loss(&m);
        m.weights[0][3] += eps;
        let up = loss(&m);
        m.weights[0][3] -= eps;
        let num_grad = (up - base) / eps;
        // one train step with tiny lr moves w by -lr*analytic_grad
        let w_before = m.weights[0][3];
        m.train_step(&[x.clone()], &[y.clone()], 1e-3);
        let analytic = (w_before - m.weights[0][3]) / 1e-3;
        // dL/dw of (o-t)^2 is 2(o-t)do/dw; our delta uses (o-t)do/dw → the
        // analytic step is half the numeric gradient.
        assert!(
            (2.0 * analytic - num_grad).abs() < 1e-3,
            "numeric {num_grad}, 2×analytic {}",
            2.0 * analytic
        );
    }

    #[test]
    fn argmax_and_accuracy() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        let s = spec();
        let m = FloatMlp::init(&s, &mut Rng::new(7));
        let xs = vec![vec![0.0, 0.0]];
        let ys = vec![vec![1.0]];
        let acc = m.accuracy(&xs, &ys);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn quantized_roundtrips_within_resolution() {
        let s = spec();
        let m = FloatMlp::init(&s, &mut Rng::new(9));
        let (qw, _) = m.quantized();
        let f = s.fixed;
        for (w, q) in m.weights[0].iter().zip(&qw[0]) {
            assert!((w - f.to_f64(*q)).abs() <= f.resolution());
        }
    }
}
