//! Lowering MLP inference **and training** onto the Matrix Machine's
//! seven vector opcodes + LUT activations — the paper's §2 functional
//! requirement ("the Matrix Machine must train and test MLPs... the loss
//! functions' gradients must be calculated using the back-propagation
//! algorithm").
//!
//! Data layout (batch `B`, layer `n_in → n_out`):
//!
//! * activations/targets are `(B, n)` row-major — a sample is a contiguous
//!   row; a feature column is a strided view;
//! * weights are `(n_in, n_out)` row-major — forward needs weight
//!   *columns* (strided), the backward delta needs weight *rows*
//!   (contiguous); both are single `View`s, no transposes materialised.
//!
//! Generated schedule per layer (forward): one `VECTOR_DOT_PRODUCT` wave
//! of `B·n_out` lanes (`z = Wᵀx`), one `VECTOR_ADDITION` wave of `B` lanes
//! (`+ bias`), one `ACTIVATION_FUNCTION` wave of `B` lanes. Backward:
//! `VECTOR_SUBTRACTION` (output error), derivative-LUT +
//! `ELEMENT_MULTIPLICATION` (δ), `VECTOR_DOT_PRODUCT` over *batch columns*
//! (∂W: lanes are (i,j) pairs, operands stride through the batch),
//! `VECTOR_SUMMATION` (∂b), `VECTOR_DOT_PRODUCT` over weight rows
//! (δ propagation), then `ELEMENT_MULTIPLICATION` by the learning-rate
//! constant vector + `VECTOR_SUBTRACTION` (SGD update, in place).
//!
//! The learning rate is a [`BufKind::Const`] vector (there is no scalar
//! path in the ISA). Loss is also computed on-device as Σ(o−y)² via
//! square + row sums + a final sum (diagnostic; the trainer reads it
//! back).

use super::lut::{ActKind, ActLut};
use super::mlp::{LutParams, MlpSpec};
use crate::assembler::program::{BufId, BufKind, LaneOp, LutId, Program, ProgramError, Step, View, Wave};
use crate::fixed::FixedSpec;
use crate::hw::COLUMN_LEN;
use crate::isa::Opcode;
use thiserror::Error;

/// Lowering errors.
#[derive(Debug, Error, PartialEq)]
pub enum LowerError {
    /// Spec invalid.
    #[error("bad MLP spec: {0}")]
    Spec(#[from] super::mlp::SpecError),
    /// Graph invalid.
    #[error("bad graph: {0}")]
    Graph(#[from] super::graph::GraphError),
    /// Batch exceeds a column.
    #[error("batch {0} out of range 1..={COLUMN_LEN}")]
    BadBatch(usize),
    /// Learning rate quantises to zero.
    #[error("learning rate {0} is below the fixed-point resolution")]
    LrUnderflow(f64),
    /// A lowering constant quantises to zero.
    #[error("{what} {value} is below the fixed-point resolution")]
    ConstUnderflow {
        /// Which constant.
        what: &'static str,
        /// The real value that underflowed.
        value: f64,
    },
    /// Training is not chunked: every layer dim must fit one column.
    #[error("training requires layer dims ≤ {COLUMN_LEN} (layer has {0})")]
    TrainingTooWide(usize),
    /// The op has no on-device backward recipe in this position.
    #[error("op {op}: training unsupported: {why}")]
    TrainUnsupported {
        /// Graph op index.
        op: usize,
        /// What is missing.
        why: &'static str,
    },
    /// A train step over a graph with nothing to update.
    #[error("graph has no trainable parameters")]
    NoParams,
    /// The emitted program failed validation — a lowering bug surfaced
    /// as a typed error instead of a panic.
    #[error("lowered program failed validation: {0}")]
    Invalid(#[from] ProgramError),
}

/// A lowered MLP program with its buffer handles.
#[derive(Debug, Clone)]
pub struct LoweredMlp {
    /// The vector program.
    pub program: Program,
    /// Batch size it was lowered for.
    pub batch: usize,
    /// Input buffer (`B × in_dim`).
    pub x: BufId,
    /// Target buffer (train programs only).
    pub y: Option<BufId>,
    /// Final activation buffer (`B × out_dim`).
    pub out: BufId,
    /// Per-layer weight buffers.
    pub weights: Vec<BufId>,
    /// Per-layer bias buffers.
    pub biases: Vec<BufId>,
    /// On-device Σ(o−y)² lane (train programs only).
    pub loss: Option<BufId>,
}

/// Shared emission context: the program under construction plus the
/// LUT dedup/swap state. Used by both the legacy MLP emission kept
/// below as the bit-identity reference and the operator-graph lowering
/// in [`super::graph::lower`].
pub(crate) struct Ctx {
    pub(crate) p: Program,
    pub(crate) act_luts: Vec<(ActKind, bool, LutId)>,
    pub(crate) current_lut: Option<LutId>,
}

impl Ctx {
    pub(crate) fn new(name: &str, fixed: FixedSpec) -> Ctx {
        Ctx { p: Program::new(name, fixed), act_luts: Vec::new(), current_lut: None }
    }

    pub(crate) fn lut_for(
        &mut self,
        fixed: FixedSpec,
        lp: LutParams,
        kind: ActKind,
        deriv: bool,
    ) -> LutId {
        if let Some(&(_, _, id)) =
            self.act_luts.iter().find(|(k, d, _)| *k == kind && *d == deriv)
        {
            return id;
        }
        let lut = if lp.interp {
            ActLut::build(kind, deriv, fixed, lp.mode, lp.shift).with_interp()
        } else {
            ActLut::build(kind, deriv, fixed, lp.mode, lp.shift)
        };
        let id = self.p.lut(lut);
        self.act_luts.push((kind, deriv, id));
        id
    }

    /// Emit an activation wave, swapping the ACTPRO table if needed.
    pub(crate) fn act_wave(&mut self, lut: LutId, lanes: Vec<LaneOp>, vec_len: usize) {
        if self.current_lut != Some(lut) {
            self.p.steps.push(Step::LoadLut(lut));
            self.current_lut = Some(lut);
        }
        self.p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len,
            lut: Some(lut),
            lanes,
        }));
    }

    pub(crate) fn wave(&mut self, op: Opcode, vec_len: usize, lanes: Vec<LaneOp>) {
        self.p.steps.push(Step::Wave(Wave { op, vec_len, lut: None, lanes }));
    }
}

/// Row view of a `(rows, cols)` row-major buffer.
pub(crate) fn row(buf: BufId, cols: usize, r: usize) -> View {
    View::contiguous(buf, r * cols, cols)
}

/// Column view of a `(rows, cols)` row-major buffer.
pub(crate) fn col(buf: BufId, rows: usize, cols: usize, c: usize) -> View {
    View { buf, offset: c, len: rows, stride: cols }
}

/// Single-lane view.
pub(crate) fn lane(buf: BufId, i: usize) -> View {
    View::contiguous(buf, i, 1)
}

fn declare_net(ctx: &mut Ctx, spec: &MlpSpec, batch: usize, train: bool) -> LoweredMlp {
    let p = &mut ctx.p;
    let in_dim = spec.input_dim();
    let out_dim = spec.output_dim();
    let x = p.buffer("x", batch, in_dim, BufKind::Input);
    let mut weights = Vec::new();
    let mut biases = Vec::new();
    for (l, layer) in spec.layers.iter().enumerate() {
        weights.push(p.buffer(&format!("w{l}"), layer.inputs, layer.outputs, BufKind::Weight));
        biases.push(p.buffer(&format!("b{l}"), layer.outputs, 1, BufKind::Bias));
    }
    // z/o per layer; the last o is the program output.
    let mut out = x;
    for (l, layer) in spec.layers.iter().enumerate() {
        p.buffer(&format!("z{l}"), batch, layer.outputs, BufKind::Temp);
        let kind =
            if l + 1 == spec.layers.len() { BufKind::Output } else { BufKind::Temp };
        out = p.buffer(&format!("o{l}"), batch, layer.outputs, kind);
    }
    let y = train.then(|| p.buffer("y", batch, out_dim, BufKind::Target));
    LoweredMlp {
        program: Program::new("placeholder", spec.fixed), // replaced by caller
        batch,
        x,
        y,
        out,
        weights,
        biases,
        loss: None,
    }
}

/// The canonical batch ladder for batch-parametric forward compilation:
/// powers of two `1, 2, 4, …` strictly below `max_batch`, then
/// `max_batch` itself as the top bucket. Every bucket is a valid
/// [`lower_forward`] batch; the serving runtime rounds each micro-batch
/// up to the smallest bucket that fits, so one net compiles a small
/// number of forward plans instead of one per observed batch size.
///
/// A `max_batch` outside `1..=COLUMN_LEN` is a typed
/// [`LowerError::BadBatch`] (this used to panic).
pub fn forward_buckets(max_batch: usize) -> Result<Vec<usize>, LowerError> {
    if max_batch == 0 || max_batch > COLUMN_LEN {
        return Err(LowerError::BadBatch(max_batch));
    }
    let mut out = Vec::new();
    let mut b = 1;
    while b < max_batch {
        out.push(b);
        b *= 2;
    }
    out.push(max_batch);
    Ok(out)
}

/// Split `0..n` into segments of at most [`COLUMN_LEN`] lanes.
pub(crate) fn segments(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    while off < n {
        let len = (n - off).min(COLUMN_LEN);
        out.push((off, len));
        off += len;
    }
    out
}

fn emit_forward(ctx: &mut Ctx, spec: &MlpSpec, h: &LoweredMlp) {
    let batch = h.batch;
    ctx.p.steps.push(Step::LoadDram(h.x));
    let mut input = h.x;
    let mut input_cols = spec.input_dim();
    for (l, layer) in spec.layers.iter().enumerate() {
        let (n_in, n_out) = (layer.inputs, layer.outputs);
        let w = h.weights[l];
        let b = h.biases[l];
        let z = ctx.p.buffer_named(&format!("z{l}")).unwrap();
        let o = ctx.p.buffer_named(&format!("o{l}")).unwrap();
        // z[b,j] = dot(x row b, w col j) — chunked over the fan-in when it
        // exceeds one 512-lane column (paper §2 "any size" requirement).
        // Chunk partials are truncated to Q.F before the cross-chunk adds;
        // this is the documented quantisation of chunked dots (each chunk
        // is one hardware VECTOR_DOT_PRODUCT).
        let in_chunks = segments(n_in);
        for (ci, &(c_off, c_len)) in in_chunks.iter().enumerate() {
            let dest = if ci == 0 {
                z
            } else {
                // partial accumulator for chunks past the first
                ctx.p
                    .buffer_named(&format!("zc{l}"))
                    .unwrap_or_else(|| ctx.p.buffer(&format!("zc{l}"), batch, n_out, BufKind::Temp))
            };
            let mut lanes = Vec::with_capacity(batch * n_out);
            for bi in 0..batch {
                for j in 0..n_out {
                    lanes.push(LaneOp {
                        a: View::contiguous(input, bi * input_cols + c_off, c_len),
                        b: Some(View {
                            buf: w,
                            offset: c_off * n_out + j,
                            len: c_len,
                            stride: n_out,
                        }),
                        out: lane(dest, bi * n_out + j),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, c_len, lanes);
            if ci > 0 {
                // z += partial, segment-wise
                for &(s_off, s_len) in &segments(n_out) {
                    let lanes = (0..batch)
                        .map(|bi| LaneOp {
                            a: View::contiguous(z, bi * n_out + s_off, s_len),
                            b: Some(View::contiguous(dest, bi * n_out + s_off, s_len)),
                            out: View::contiguous(z, bi * n_out + s_off, s_len),
                        })
                        .collect();
                    ctx.wave(Opcode::VectorAddition, s_len, lanes);
                }
            }
        }
        // z row += bias; o = A(z) — segment-wise over wide outputs.
        let lut = ctx.lut_for(spec.fixed, spec.lut, layer.act, false);
        for &(s_off, s_len) in &segments(n_out) {
            let lanes = (0..batch)
                .map(|bi| LaneOp {
                    a: View::contiguous(z, bi * n_out + s_off, s_len),
                    b: Some(View::contiguous(b, s_off, s_len)),
                    out: View::contiguous(z, bi * n_out + s_off, s_len),
                })
                .collect();
            ctx.wave(Opcode::VectorAddition, s_len, lanes);
        }
        for &(s_off, s_len) in &segments(n_out) {
            let lanes = (0..batch)
                .map(|bi| LaneOp {
                    a: View::contiguous(z, bi * n_out + s_off, s_len),
                    b: None,
                    out: View::contiguous(o, bi * n_out + s_off, s_len),
                })
                .collect();
            ctx.act_wave(lut, lanes, s_len);
        }
        input = o;
        input_cols = n_out;
    }
    ctx.p.steps.push(Step::StoreDram(h.out));
}

/// Lower inference: forward pass over a batch.
///
/// Deprecated shim: `MlpSpec` now lowers *through the operator-graph
/// IR* ([`super::graph::lower_mlp_forward`]), which emits bit-identical
/// programs (asserted by `rust/tests/graph.rs` against
/// [`legacy_lower_forward`], the frozen pre-graph emission).
#[deprecated(note = "use nn::graph::lower_mlp_forward — MlpSpec lowers through the graph IR")]
pub fn lower_forward(spec: &MlpSpec, batch: usize) -> Result<LoweredMlp, LowerError> {
    super::graph::lower_mlp_forward(spec, batch)
}

/// Lower one SGD training step: forward + backprop + in-place update,
/// with on-device loss.
///
/// Deprecated shim over [`super::graph::lower_mlp_train`]; see
/// [`lower_forward`].
#[deprecated(note = "use nn::graph::lower_mlp_train — MlpSpec lowers through the graph IR")]
pub fn lower_train_step(spec: &MlpSpec, batch: usize, lr: f64) -> Result<LoweredMlp, LowerError> {
    super::graph::lower_mlp_train(spec, batch, lr)
}

/// The frozen pre-graph forward emission, kept verbatim as the
/// bit-identity oracle for the graph path (`rust/tests/graph.rs`
/// asserts [`super::graph::lower_mlp_forward`] reproduces its programs
/// field-for-field). Not deprecated — it *is* the reference — but new
/// code should lower through the graph.
pub fn legacy_lower_forward(spec: &MlpSpec, batch: usize) -> Result<LoweredMlp, LowerError> {
    spec.check()?;
    if batch == 0 || batch > COLUMN_LEN {
        return Err(LowerError::BadBatch(batch));
    }
    let mut ctx = Ctx::new(&format!("{}_fwd_b{batch}", spec.name), spec.fixed);
    let mut h = declare_net(&mut ctx, spec, batch, false);
    emit_forward(&mut ctx, spec, &h);
    h.program = ctx.p;
    h.program.check().expect("lowered forward program must validate");
    Ok(h)
}

/// The frozen pre-graph train-step emission; see
/// [`legacy_lower_forward`].
pub fn legacy_lower_train_step(
    spec: &MlpSpec,
    batch: usize,
    lr: f64,
) -> Result<LoweredMlp, LowerError> {
    spec.check()?;
    if batch == 0 || batch > COLUMN_LEN {
        return Err(LowerError::BadBatch(batch));
    }
    // The backward pass is not chunked (gradient dots span whole rows).
    for l in &spec.layers {
        let wide = l.inputs.max(l.outputs);
        if wide > COLUMN_LEN {
            return Err(LowerError::TrainingTooWide(wide));
        }
    }
    let lr_q = spec.fixed.from_f64(lr);
    if lr_q == 0 {
        return Err(LowerError::LrUnderflow(lr));
    }
    let mut ctx = Ctx::new(&format!("{}_train_b{batch}", spec.name), spec.fixed);
    let mut h = declare_net(&mut ctx, spec, batch, true);
    let nl = spec.layers.len();
    let out_dim = spec.output_dim();

    // Extra training buffers.
    let max_out = spec.layers.iter().map(|l| l.outputs).max().unwrap();
    let lr_buf = ctx.p.const_buffer("lr", vec![lr_q; max_out]);
    let mut d_bufs = Vec::new(); // δ per layer (B × n_out)
    let mut g_bufs = Vec::new(); // A'(z) per layer
    let mut gw_bufs = Vec::new();
    let mut gb_bufs = Vec::new();
    for (l, layer) in spec.layers.iter().enumerate() {
        d_bufs.push(ctx.p.buffer(&format!("d{l}"), batch, layer.outputs, BufKind::Temp));
        g_bufs.push(ctx.p.buffer(&format!("g{l}"), batch, layer.outputs, BufKind::Temp));
        gw_bufs.push(ctx.p.buffer(
            &format!("gw{l}"),
            layer.inputs,
            layer.outputs,
            BufKind::Temp,
        ));
        gb_bufs.push(ctx.p.buffer(&format!("gb{l}"), layer.outputs, 1, BufKind::Temp));
    }
    let sq = ctx.p.buffer("sq", batch, out_dim, BufKind::Temp);
    let lsum = ctx.p.buffer("lsum", batch, 1, BufKind::Temp);
    let loss = ctx.p.buffer("loss", 1, 1, BufKind::Output);
    h.loss = Some(loss);

    // ---- forward ----
    emit_forward(&mut ctx, spec, &h);
    let y = h.y.unwrap();
    ctx.p.steps.push(Step::LoadDram(y));
    ctx.p.steps.push(Step::LoadDram(lr_buf));

    // ---- output error: d_L = o_L − y ----
    let d_last = d_bufs[nl - 1];
    let lanes = (0..batch)
        .map(|bi| LaneOp {
            a: row(h.out, out_dim, bi),
            b: Some(row(y, out_dim, bi)),
            out: row(d_last, out_dim, bi),
        })
        .collect();
    ctx.wave(Opcode::VectorSubtraction, out_dim, lanes);

    // ---- loss = Σ (o−y)² (diagnostic) ----
    let lanes = (0..batch)
        .map(|bi| LaneOp {
            a: row(d_last, out_dim, bi),
            b: Some(row(d_last, out_dim, bi)),
            out: row(sq, out_dim, bi),
        })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, out_dim, lanes);
    let lanes = (0..batch)
        .map(|bi| LaneOp { a: row(sq, out_dim, bi), b: None, out: lane(lsum, bi) })
        .collect();
    ctx.wave(Opcode::VectorSummation, out_dim, lanes);
    ctx.wave(
        Opcode::VectorSummation,
        batch,
        vec![LaneOp { a: View::all(lsum, batch), b: None, out: lane(loss, 0) }],
    );

    // ---- backward ----
    for l in (0..nl).rev() {
        let layer = spec.layers[l];
        let (n_in, n_out) = (layer.inputs, layer.outputs);
        let w = h.weights[l];
        let d = d_bufs[l];
        let g = g_bufs[l];
        let z = ctx.p.buffer_named(&format!("z{l}")).unwrap();
        let input =
            if l == 0 { h.x } else { ctx.p.buffer_named(&format!("o{}", l - 1)).unwrap() };

        // δ_l = d_l ⊙ A'(z_l)
        let dlut = ctx.lut_for(spec.fixed, spec.lut, layer.act, true);
        let lanes = (0..batch)
            .map(|bi| LaneOp { a: row(z, n_out, bi), b: None, out: row(g, n_out, bi) })
            .collect();
        ctx.act_wave(dlut, lanes, n_out);
        let lanes = (0..batch)
            .map(|bi| LaneOp {
                a: row(d, n_out, bi),
                b: Some(row(g, n_out, bi)),
                out: row(d, n_out, bi),
            })
            .collect();
        ctx.wave(Opcode::ElementMultiplication, n_out, lanes);

        // ∂W[i,j] = Σ_b input[b,i]·δ[b,j]  (dot over batch columns)
        let gw = gw_bufs[l];
        let mut lanes = Vec::with_capacity(n_in * n_out);
        for i in 0..n_in {
            for j in 0..n_out {
                lanes.push(LaneOp {
                    a: col(input, batch, n_in, i),
                    b: Some(col(d, batch, n_out, j)),
                    out: lane(gw, i * n_out + j),
                });
            }
        }
        ctx.wave(Opcode::VectorDotProduct, batch, lanes);

        // ∂b[j] = Σ_b δ[b,j]
        let gb = gb_bufs[l];
        let lanes = (0..n_out)
            .map(|j| LaneOp { a: col(d, batch, n_out, j), b: None, out: lane(gb, j) })
            .collect();
        ctx.wave(Opcode::VectorSummation, batch, lanes);

        // δ_{l-1}[b,i] = dot(w row i, δ_l row b)   (pre-update weights)
        if l > 0 {
            let d_prev = d_bufs[l - 1];
            let mut lanes = Vec::with_capacity(batch * n_in);
            for bi in 0..batch {
                for i in 0..n_in {
                    lanes.push(LaneOp {
                        a: View::contiguous(w, i * n_out, n_out),
                        b: Some(row(d, n_out, bi)),
                        out: lane(d_prev, bi * n_in + i),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, n_out, lanes);
        }

        // SGD: w −= lr ⊙ ∂W ; b −= lr ⊙ ∂b  (in place)
        let lanes = (0..n_in)
            .map(|i| LaneOp {
                a: row(gw, n_out, i),
                b: Some(View::contiguous(lr_buf, 0, n_out)),
                out: row(gw, n_out, i),
            })
            .collect();
        ctx.wave(Opcode::ElementMultiplication, n_out, lanes);
        let lanes = (0..n_in)
            .map(|i| LaneOp {
                a: row(w, n_out, i),
                b: Some(row(gw, n_out, i)),
                out: row(w, n_out, i),
            })
            .collect();
        ctx.wave(Opcode::VectorSubtraction, n_out, lanes);
        ctx.wave(
            Opcode::ElementMultiplication,
            n_out,
            vec![LaneOp {
                a: View::all(gb, n_out),
                b: Some(View::contiguous(lr_buf, 0, n_out)),
                out: View::all(gb, n_out),
            }],
        );
        ctx.wave(
            Opcode::VectorSubtraction,
            n_out,
            vec![LaneOp {
                a: View::all(h.biases[l], n_out),
                b: Some(View::all(gb, n_out)),
                out: View::all(h.biases[l], n_out),
            }],
        );
    }
    ctx.p.steps.push(Step::StoreDram(loss));

    h.program = ctx.p;
    h.program.check().expect("lowered train program must validate");
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    // These tests pin the *legacy* emission (the bit-identity oracle);
    // the graph path is exercised in nn::graph and rust/tests/graph.rs.
    use super::legacy_lower_forward as lower_forward;
    use super::legacy_lower_train_step as lower_train_step;
    use crate::fixed::FixedSpec;
    use crate::hw::{FpgaDevice, MatrixMachine};
    use crate::nn::lut::AddrMode;
    use crate::nn::mlp::LutParams;
    use crate::util::Rng;

    fn spec(dims: &[usize]) -> MlpSpec {
        MlpSpec::from_dims(
            "t",
            dims,
            ActKind::Relu,
            ActKind::Identity,
            FixedSpec::q(10),
            LutParams { shift: 5, mode: AddrMode::Clamp, interp: true },
        )
        .unwrap()
    }

    fn rand_q(r: &mut Rng, fixed: FixedSpec, n: usize, amp: f64) -> Vec<i16> {
        (0..n).map(|_| fixed.from_f64((r.gen_f64() * 2.0 - 1.0) * amp)).collect()
    }

    #[test]
    fn forward_program_shape() {
        let s = spec(&[4, 8, 2]);
        let h = lower_forward(&s, 3).unwrap();
        assert_eq!(h.program.waves().count(), 6); // 3 waves per layer
        assert_eq!(h.program.buffers[h.x].len(), 12);
        assert_eq!(h.program.buffers[h.out].len(), 6);
        assert!(h.y.is_none() && h.loss.is_none());
    }

    /// Independent host-side quantised forward pass (same semantics).
    fn host_forward(
        s: &MlpSpec,
        h: &LoweredMlp,
        x: &[i16],
        ws: &[Vec<i16>],
        bs: &[Vec<i16>],
        batch: usize,
    ) -> Vec<i16> {
        let f = s.fixed;
        let mut cur = x.to_vec();
        let mut cur_dim = s.input_dim();
        for (l, layer) in s.layers.iter().enumerate() {
            let (n_in, n_out) = (layer.inputs, layer.outputs);
            assert_eq!(cur_dim, n_in);
            let lut = h.program.luts.iter().find(|t| t.kind == layer.act && !t.deriv).unwrap();
            let mut next = vec![0i16; batch * n_out];
            for bi in 0..batch {
                for j in 0..n_out {
                    let xrow = &cur[bi * n_in..(bi + 1) * n_in];
                    let wcol: Vec<i16> =
                        (0..n_in).map(|i| ws[l][i * n_out + j]).collect();
                    let z = f.add(f.dot(xrow, &wcol), bs[l][j]);
                    next[bi * n_out + j] = z;
                }
                // bias add then act happen per full row in program order —
                // identical lane-wise, so per-element here is fine.
                for j in 0..n_out {
                    next[bi * n_out + j] = lut.apply_scalar(next[bi * n_out + j]);
                }
            }
            cur = next;
            cur_dim = n_out;
        }
        cur
    }

    #[test]
    fn forward_matches_host_reference() {
        let s = spec(&[4, 8, 2]);
        let batch = 5;
        let h = lower_forward(&s, batch).unwrap();
        let mut r = Rng::new(77);
        let f = s.fixed;
        let x = rand_q(&mut r, f, batch * 4, 1.0);
        let ws: Vec<Vec<i16>> = s
            .layers
            .iter()
            .map(|l| rand_q(&mut r, f, l.inputs * l.outputs, 0.5))
            .collect();
        let bs: Vec<Vec<i16>> =
            s.layers.iter().map(|l| rand_q(&mut r, f, l.outputs, 0.2)).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("x", &x).unwrap();
        for l in 0..s.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        m.execute();
        let got = m.read_named("o1").unwrap().to_vec();
        let want = host_forward(&s, &h, &x, &ws, &bs, batch);
        assert_eq!(got, want);
    }

    #[test]
    fn forward_verified_structurally() {
        // Small net through the microcode/structural path.
        let s = spec(&[3, 4, 2]);
        let h = lower_forward(&s, 2).unwrap();
        let mut r = Rng::new(78);
        let f = s.fixed;
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("x", &rand_q(&mut r, f, 6, 1.0)).unwrap();
        m.bind_named("w0", &rand_q(&mut r, f, 12, 0.5)).unwrap();
        m.bind_named("b0", &rand_q(&mut r, f, 4, 0.2)).unwrap();
        m.bind_named("w1", &rand_q(&mut r, f, 8, 0.5)).unwrap();
        m.bind_named("b1", &rand_q(&mut r, f, 2, 0.2)).unwrap();
        m.execute_verified().unwrap();
    }

    #[test]
    fn train_step_reduces_loss_on_linear_task() {
        // y = 0.5·x₀ − 0.25·x₁ learned by a 2→1 identity "MLP".
        let s = MlpSpec::from_dims(
            "lin",
            &[2, 1],
            ActKind::Identity,
            ActKind::Identity,
            FixedSpec::q(10),
            LutParams { shift: 5, mode: AddrMode::Clamp, interp: true },
        )
        .unwrap();
        let batch = 32;
        let h = lower_train_step(&s, batch, 0.03125).unwrap();
        let f = s.fixed;
        let mut r = Rng::new(79);
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("w0", &rand_q(&mut r, f, 2, 0.1)).unwrap();
        m.bind_named("b0", &[0i16; 1]).unwrap();
        let mut losses = Vec::new();
        for _ in 0..60 {
            let xs: Vec<f64> = (0..batch * 2).map(|_| r.gen_f64() * 2.0 - 1.0).collect();
            let ys: Vec<f64> =
                (0..batch).map(|bi| 0.5 * xs[bi * 2] - 0.25 * xs[bi * 2 + 1]).collect();
            m.bind_named("x", &f.encode_vec(&xs)).unwrap();
            m.bind_named("y", &f.encode_vec(&ys)).unwrap();
            m.execute();
            let loss_q = m.read_named("loss").unwrap()[0];
            losses.push(f.to_f64(loss_q));
        }
        let early: f64 = losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 = losses[50..].iter().sum::<f64>() / 10.0;
        assert!(
            late < early * 0.5,
            "training did not reduce loss: early {early:.4}, late {late:.4}, losses {losses:?}"
        );
        // learned weights should approach [0.5, -0.25]
        let w = m.read_named("w0").unwrap().to_vec();
        let w0 = f.to_f64(w[0]);
        let w1 = f.to_f64(w[1]);
        assert!((w0 - 0.5).abs() < 0.15, "w0={w0}");
        assert!((w1 + 0.25).abs() < 0.15, "w1={w1}");
    }

    #[test]
    fn train_program_validates_and_has_update_waves() {
        let s = spec(&[4, 8, 3]);
        let h = lower_train_step(&s, 16, 0.0078125).unwrap();
        h.program.check().unwrap();
        assert!(h.loss.is_some() && h.y.is_some());
        // per layer: fwd 3 waves + bwd (act' + mul + gw + gb [+ delta]) +
        // 4 update waves; plus 4 loss-ish waves.
        let n_waves = h.program.waves().count();
        assert!(n_waves >= 2 * 3 + 4 + 2 * 8 - 1, "only {n_waves} waves");
        // weight buffers are mutated in place: last-layer update writes w1.
        let has_w_update = h.program.waves().any(|w| {
            w.op == Opcode::VectorSubtraction
                && w.lanes.iter().any(|l| l.out.buf == h.weights[1])
        });
        assert!(has_w_update);
    }

    #[test]
    fn lr_underflow_rejected() {
        let s = spec(&[2, 1]);
        assert!(matches!(
            lower_train_step(&s, 4, 1e-6),
            Err(LowerError::LrUnderflow(x)) if x == 1e-6
        ));
    }

    #[test]
    fn wide_forward_layers_chunk_over_columns() {
        // 1100→700: fan-in needs 3 dot chunks, fan-out needs 2 segments.
        let s = spec(&[1100, 700, 4]);
        let batch = 2;
        let h = lower_forward(&s, batch).unwrap();
        h.program.check().unwrap();
        // chunked program still runs and matches a host-side reference
        // built from the same chunk semantics.
        let f = s.fixed;
        let mut r = Rng::new(404);
        let x = rand_q(&mut r, f, batch * 1100, 1.0);
        let ws: Vec<Vec<i16>> = s
            .layers
            .iter()
            .map(|l| rand_q(&mut r, f, l.inputs * l.outputs, 0.2))
            .collect();
        let bs: Vec<Vec<i16>> =
            s.layers.iter().map(|l| rand_q(&mut r, f, l.outputs, 0.1)).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named("x", &x).unwrap();
        for l in 0..s.layers.len() {
            m.bind_named(&format!("w{l}"), &ws[l]).unwrap();
            m.bind_named(&format!("b{l}"), &bs[l]).unwrap();
        }
        m.execute();
        // host reference with chunked-dot truncation semantics
        let lut0 = h.program.luts.iter().find(|t| t.kind == s.layers[0].act && !t.deriv).unwrap();
        let mut z0 = vec![0i16; batch * 700];
        for bi in 0..batch {
            for j in 0..700 {
                let mut acc_q: i16 = 0;
                for (ci, &(c_off, c_len)) in
                    [(0usize, 512usize), (512, 512), (1024, 76)].iter().enumerate()
                {
                    let xa = &x[bi * 1100 + c_off..bi * 1100 + c_off + c_len];
                    let wcol: Vec<i16> =
                        (0..c_len).map(|i| ws[0][(c_off + i) * 700 + j]).collect();
                    let part = f.dot(xa, &wcol);
                    acc_q = if ci == 0 { part } else { f.add(acc_q, part) };
                }
                z0[bi * 700 + j] = lut0.apply_scalar(f.add(acc_q, bs[0][j]));
            }
        }
        let got_h = m.read_named("o0").unwrap().to_vec();
        assert_eq!(got_h, z0, "chunked hidden layer mismatch");
    }

    #[test]
    fn training_rejects_wide_layers() {
        let s = spec(&[1100, 4]);
        assert!(matches!(
            lower_train_step(&s, 4, 0.01),
            Err(LowerError::TrainingTooWide(1100))
        ));
    }

    #[test]
    fn bad_batch_rejected() {
        let s = spec(&[2, 1]);
        assert!(matches!(lower_forward(&s, 0), Err(LowerError::BadBatch(0))));
        assert!(matches!(lower_forward(&s, 513), Err(LowerError::BadBatch(513))));
    }

    #[test]
    fn forward_buckets_cover_every_micro_batch_size() {
        assert_eq!(forward_buckets(1).unwrap(), vec![1]);
        assert_eq!(forward_buckets(8).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(forward_buckets(32).unwrap(), vec![1, 2, 4, 8, 16, 32]);
        // non-power-of-two tops keep the full power-of-two prefix
        assert_eq!(forward_buckets(12).unwrap(), vec![1, 2, 4, 8, 12]);
        // every rows ∈ 1..=max has a bucket ≥ rows, and buckets lower
        for max in [1usize, 3, 8, 17, 32] {
            let ladder = forward_buckets(max).unwrap();
            let s = spec(&[2, 3]);
            for &b in &ladder {
                lower_forward(&s, b).unwrap();
            }
            for rows in 1..=max {
                assert!(
                    ladder.iter().any(|&b| b >= rows),
                    "no bucket for {rows} rows in {ladder:?}"
                );
            }
        }
    }

    #[test]
    fn forward_buckets_rejects_malformed_max_batch_as_typed_errors() {
        // Both of these used to assert!-panic deep in the serving path;
        // they now surface as LowerError (and through mfnn::Error).
        assert_eq!(forward_buckets(0), Err(LowerError::BadBatch(0)));
        assert_eq!(forward_buckets(COLUMN_LEN + 88), Err(LowerError::BadBatch(COLUMN_LEN + 88)));
    }
}
