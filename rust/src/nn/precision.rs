//! Per-tensor fixed-point precision search against the float oracle
//! (DESIGN.md §Memory planner, "Precision search").
//!
//! The datapath runs one Q(16, F) format end-to-end, and picking `F` has
//! so far been manual (the paper's Q8.7, or per-experiment overrides).
//! [`search`] automates the choice: for each layer it sweeps fraction
//! widths against the [`FloatMlp`] float64 oracle and picks the
//! *narrowest* `FixedSpec` whose worst-case output error over a probe
//! batch stays within the caller's error budget — never picking a wider
//! format than the uniform default. The per-layer choices are reported
//! ([`PrecisionPlan::per_layer`]) and combined into one
//! [`PrecisionPlan::unified`] format (the widest per-layer requirement)
//! that the compiler applies when `CompileOptions::precision_search` is
//! set.
//!
//! ### Budget semantics
//!
//! The budget is a bound on the **max absolute output error** introduced
//! by quantization, measured against the float64 forward pass on the
//! probe inputs. It is best-effort bounded below by the uniform default's
//! own quantization error: if even the default format exceeds the
//! budget, the search returns the default (it never widens past it) and
//! reports the achieved error in [`PrecisionPlan::max_err`].

use crate::fixed::FixedSpec;
use crate::nn::float_ref::FloatMlp;
use crate::nn::mlp::{LutParams, MlpSpec};
use crate::util::Rng;

/// Probe rows used by [`search_spec`]'s derived sample batch.
const PROBE_ROWS: usize = 32;

/// The chosen format for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerChoice {
    /// Layer index.
    pub layer: usize,
    /// Chosen format (same rounding mode as the default).
    pub spec: FixedSpec,
    /// Max abs output error observed when this choice was made (the solo
    /// sweep, or the combined error after the widening pass).
    pub err: f64,
}

/// Result of a precision search: per-layer choices plus the unified
/// format the compiler applies.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionPlan {
    /// Net name the search ran against.
    pub net: String,
    /// The caller's error budget.
    pub budget: f64,
    /// The uniform default the search must never exceed.
    pub default_spec: FixedSpec,
    /// Narrowest per-layer formats within budget.
    pub per_layer: Vec<LayerChoice>,
    /// Max abs output error of the combined (all layers quantized at
    /// their chosen formats) forward pass over the probe batch.
    pub max_err: f64,
}

impl PrecisionPlan {
    /// The single datapath format implied by the per-layer choices: the
    /// widest per-layer requirement. Never wider than the default.
    pub fn unified(&self) -> FixedSpec {
        let frac = self
            .per_layer
            .iter()
            .map(|c| c.spec.frac_bits)
            .max()
            .unwrap_or(self.default_spec.frac_bits);
        FixedSpec { frac_bits: frac, ..self.default_spec }
    }

    /// Apply the unified format to a spec, keeping the LUT parameters
    /// coherent: a LUT derived from the old format via
    /// [`LutParams::training`] is re-derived from the new one; anything
    /// else is left untouched.
    pub fn apply(&self, spec: &MlpSpec) -> MlpSpec {
        let unified = self.unified();
        let mut out = spec.clone();
        if out.lut == LutParams::training(out.fixed) {
            out.lut = LutParams::training(unified);
        }
        out.fixed = unified;
        out
    }

    /// Forward `x` through `m` with every layer quantized at its chosen
    /// format (weights, biases, and the layer's output activations).
    pub fn forward(&self, m: &FloatMlp, x: &[f64]) -> Vec<f64> {
        let frac: Vec<u32> = self.per_layer.iter().map(|c| c.spec.frac_bits).collect();
        mixed_forward(m, &frac, self.default_spec, x)
    }
}

/// Quantization round-trip at `s`.
fn q(s: FixedSpec, v: f64) -> f64 {
    s.to_f64(s.from_f64(v))
}

/// Forward pass with layer `l` quantized at `frac[l]` fraction bits
/// (rounding mode taken from `default`): weights, biases, and the
/// layer's output activations all pass through the layer's format, the
/// way the fixed datapath would hold them.
fn mixed_forward(m: &FloatMlp, frac: &[u32], default: FixedSpec, x: &[f64]) -> Vec<f64> {
    let mut cur: Vec<f64> = x.to_vec();
    for (l, layer) in m.spec.layers.iter().enumerate() {
        let s = FixedSpec { frac_bits: frac[l], ..default };
        let (n_in, n_out) = (layer.inputs, layer.outputs);
        let mut out = vec![0.0; n_out];
        for (j, out_j) in out.iter_mut().enumerate() {
            let mut acc = q(s, m.biases[l][j]);
            for i in 0..n_in {
                acc += q(s, cur[i]) * q(s, m.weights[l][i * n_out + j]);
            }
            *out_j = q(s, layer.act.f(acc));
        }
        cur = out;
    }
    cur
}

/// Max abs error of the mixed-precision forward vs the float64 oracle
/// over the probe batch.
fn probe_err(m: &FloatMlp, frac: &[u32], default: FixedSpec, samples: &[Vec<f64>]) -> f64 {
    let mut worst = 0.0f64;
    for x in samples {
        let want = m.forward(x);
        let got = mixed_forward(m, frac, default, x);
        for (w, g) in want.iter().zip(&got) {
            worst = worst.max((w - g).abs());
        }
    }
    worst
}

/// Per-layer precision search against the float oracle `m`: for each
/// layer, the narrowest fraction width whose solo quantization error
/// stays within `budget`; then the combined plan is widened greedily
/// (narrowest layer first, never past the default) until the combined
/// error also fits — or every layer is back at the default.
pub fn search(m: &FloatMlp, budget: f64, samples: &[Vec<f64>]) -> PrecisionPlan {
    let default = m.spec.fixed;
    let d = default.frac_bits;
    let n_layers = m.spec.layers.len();
    let uniform: Vec<u32> = vec![d; n_layers];
    let mut per_layer = Vec::with_capacity(n_layers);
    let mut frac = uniform.clone();
    for l in 0..n_layers {
        let mut choice = (d, probe_err(m, &uniform, default, samples));
        for f in 1..d {
            let mut solo = uniform.clone();
            solo[l] = f;
            let err = probe_err(m, &solo, default, samples);
            if err <= budget {
                choice = (f, err);
                break;
            }
        }
        frac[l] = choice.0;
        per_layer.push(LayerChoice {
            layer: l,
            spec: FixedSpec { frac_bits: choice.0, ..default },
            err: choice.1,
        });
    }
    // Combined pass: per-layer errors compound; widen until within
    // budget or back at the uniform default.
    let mut max_err = probe_err(m, &frac, default, samples);
    while max_err > budget {
        let Some(narrowest) = (0..n_layers).filter(|&l| frac[l] < d).min_by_key(|&l| frac[l])
        else {
            break; // all layers at the default — budget unreachable
        };
        frac[narrowest] += 1;
        per_layer[narrowest].spec = FixedSpec { frac_bits: frac[narrowest], ..default };
        max_err = probe_err(m, &frac, default, samples);
        per_layer[narrowest].err = max_err;
    }
    PrecisionPlan { net: m.spec.name.clone(), budget, default_spec: default, per_layer, max_err }
}

/// [`search`] with a deterministic seeded oracle and probe batch derived
/// from the spec — the entry the compiler uses
/// (`CompileOptions::precision_search`).
pub fn search_spec(spec: &MlpSpec, budget: f64, seed: u64) -> PrecisionPlan {
    let mut rng = Rng::new(seed);
    let m = FloatMlp::init(spec, &mut rng);
    let in_dim = spec.layers[0].inputs;
    let samples: Vec<Vec<f64>> = (0..PROBE_ROWS)
        .map(|_| (0..in_dim).map(|_| rng.gen_f64() * 2.0 - 1.0).collect())
        .collect();
    search(&m, budget, &samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::lut::ActKind;

    fn spec(frac: u32) -> MlpSpec {
        let fixed = FixedSpec::q(frac).saturating();
        MlpSpec::from_dims(
            "prec",
            &[6, 12, 4],
            ActKind::Tanh,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    #[test]
    fn search_never_widens_past_the_default() {
        let plan = search_spec(&spec(12), 0.05, 11);
        for c in &plan.per_layer {
            assert!(c.spec.frac_bits <= plan.default_spec.frac_bits);
        }
        assert!(plan.unified().frac_bits <= plan.default_spec.frac_bits);
        assert_eq!(plan.unified().round, plan.default_spec.round);
    }

    #[test]
    fn combined_plan_meets_the_budget_when_the_default_does() {
        let s = spec(12);
        let plan = search_spec(&s, 0.05, 11);
        // Q12 resolution is ~2.4e-4; a 0.05 budget is generously
        // reachable, so the combined error must be within it.
        assert!(plan.max_err <= 0.05, "max_err {}", plan.max_err);
    }

    #[test]
    fn loose_budget_picks_narrower_formats() {
        let s = spec(12);
        let tight = search_spec(&s, 1e-4, 11);
        let loose = search_spec(&s, 0.25, 11);
        assert!(loose.unified().frac_bits <= tight.unified().frac_bits);
        assert!(loose.unified().frac_bits < s.fixed.frac_bits, "0.25 budget should narrow Q12");
    }

    #[test]
    fn apply_rewrites_fixed_and_training_lut_coherently() {
        let s = spec(12);
        let plan = search_spec(&s, 0.25, 11);
        let applied = plan.apply(&s);
        assert_eq!(applied.fixed, plan.unified());
        assert_eq!(applied.lut, LutParams::training(plan.unified()));
        // Deterministic: same seed, same plan.
        assert_eq!(plan, search_spec(&s, 0.25, 11));
    }
}
