//! Weight checkpointing: persist/restore a trained net's quantised
//! parameters — what the control server keeps in its model store between
//! "flash" operations (§2: the system buses move network data from the
//! control server to the boards).
//!
//! Format (little-endian, versioned):
//!
//! ```text
//! magic "MFNN"  u32 version  u32 frac_bits  u8 saturate  u32 n_layers
//! per layer: u32 rows  u32 cols  rows*cols*i16 weights  cols*i16 biases
//! ```

use crate::fixed::{FixedSpec, RoundMode};
use std::io::{Read, Write};
use std::path::Path;
use thiserror::Error;

/// Checkpoint format version.
pub const VERSION: u32 = 1;
const MAGIC: &[u8; 4] = b"MFNN";

/// Checkpoint errors.
#[derive(Debug, Error)]
pub enum CheckpointError {
    /// I/O failure.
    #[error("checkpoint io: {0}")]
    Io(#[from] std::io::Error),
    /// Not a checkpoint / wrong version.
    #[error("bad checkpoint: {0}")]
    Format(String),
}

/// A saved set of parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Fixed-point format the lanes are in.
    pub fixed: FixedSpec,
    /// Per-layer `(rows, cols, weights, biases)`.
    pub layers: Vec<(u32, u32, Vec<i16>, Vec<i16>)>,
}

impl Checkpoint {
    /// Capture from per-layer weight/bias lanes (`weights[l]` is
    /// `rows*cols` row-major; `biases[l]` has `cols` lanes).
    pub fn capture(
        fixed: FixedSpec,
        dims: &[(usize, usize)],
        weights: &[Vec<i16>],
        biases: &[Vec<i16>],
    ) -> Checkpoint {
        assert_eq!(dims.len(), weights.len());
        assert_eq!(dims.len(), biases.len());
        let layers = dims
            .iter()
            .zip(weights)
            .zip(biases)
            .map(|((&(r, c), w), b)| {
                assert_eq!(w.len(), r * c, "weight lanes mismatch");
                assert_eq!(b.len(), c, "bias lanes mismatch");
                (r as u32, c as u32, w.clone(), b.clone())
            })
            .collect();
        Checkpoint { fixed, layers }
    }

    /// Serialise to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.fixed.frac_bits.to_le_bytes());
        out.push(matches!(self.fixed.round, RoundMode::Saturate) as u8);
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for (r, c, w, b) in &self.layers {
            out.extend_from_slice(&r.to_le_bytes());
            out.extend_from_slice(&c.to_le_bytes());
            for v in w {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for v in b {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(mut data: &[u8]) -> Result<Checkpoint, CheckpointError> {
        fn take<'a>(data: &mut &'a [u8], n: usize) -> Result<&'a [u8], CheckpointError> {
            if data.len() < n {
                return Err(CheckpointError::Format("truncated".into()));
            }
            let (head, rest) = data.split_at(n);
            *data = rest;
            Ok(head)
        }
        fn take_u32(data: &mut &[u8]) -> Result<u32, CheckpointError> {
            Ok(u32::from_le_bytes(take(data, 4)?.try_into().unwrap()))
        }
        fn take_i16s(data: &mut &[u8], n: usize) -> Result<Vec<i16>, CheckpointError> {
            let raw = take(data, n * 2)?;
            Ok(raw.chunks_exact(2).map(|c| i16::from_le_bytes([c[0], c[1]])).collect())
        }
        let magic = take(&mut data, 4)?;
        if magic != MAGIC {
            return Err(CheckpointError::Format("bad magic".into()));
        }
        let version = take_u32(&mut data)?;
        if version != VERSION {
            return Err(CheckpointError::Format(format!("unsupported version {version}")));
        }
        let frac_bits = take_u32(&mut data)?;
        if frac_bits >= 16 {
            return Err(CheckpointError::Format(format!("bad frac_bits {frac_bits}")));
        }
        let saturate = take(&mut data, 1)?[0] != 0;
        let mut fixed = FixedSpec::q(frac_bits);
        if saturate {
            fixed = fixed.saturating();
        }
        let n_layers = take_u32(&mut data)? as usize;
        if n_layers > 1024 {
            return Err(CheckpointError::Format(format!("implausible layer count {n_layers}")));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let r = take_u32(&mut data)?;
            let c = take_u32(&mut data)?;
            if r as usize * c as usize > 1 << 24 {
                return Err(CheckpointError::Format("implausible layer size".into()));
            }
            let w = take_i16s(&mut data, r as usize * c as usize)?;
            let b = take_i16s(&mut data, c as usize)?;
            layers.push((r, c, w, b));
        }
        if !data.is_empty() {
            return Err(CheckpointError::Format("trailing bytes".into()));
        }
        Ok(Checkpoint { fixed, layers })
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        Checkpoint::from_bytes(&buf)
    }

    /// Split back into (weights, biases) lane vectors.
    pub fn into_params(self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let mut ws = Vec::with_capacity(self.layers.len());
        let mut bs = Vec::with_capacity(self.layers.len());
        for (_, _, w, b) in self.layers {
            ws.push(w);
            bs.push(b);
        }
        (ws, bs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample() -> Checkpoint {
        let mut r = Rng::new(3);
        let dims = [(4usize, 8usize), (8, 2)];
        let ws: Vec<Vec<i16>> =
            dims.iter().map(|&(a, b)| (0..a * b).map(|_| r.gen_i16()).collect()).collect();
        let bs: Vec<Vec<i16>> =
            dims.iter().map(|&(_, b)| (0..b).map(|_| r.gen_i16()).collect()).collect();
        Checkpoint::capture(FixedSpec::q(10).saturating(), &dims, &ws, &bs)
    }

    #[test]
    fn roundtrip_bytes() {
        let c = sample();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn roundtrip_file() {
        let c = sample();
        let dir = std::env::temp_dir().join(format!("mfnn_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("net.mfnn");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corruption() {
        let c = sample();
        let mut bytes = c.to_bytes();
        // bad magic
        let mut b2 = bytes.clone();
        b2[0] = b'X';
        assert!(matches!(Checkpoint::from_bytes(&b2), Err(CheckpointError::Format(_))));
        // truncation
        bytes.truncate(bytes.len() - 3);
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CheckpointError::Format(_))));
        // trailing garbage
        let mut b3 = c.to_bytes();
        b3.push(0);
        assert!(matches!(Checkpoint::from_bytes(&b3), Err(CheckpointError::Format(_))));
        // bad version
        let mut b4 = c.to_bytes();
        b4[4] = 99;
        assert!(matches!(Checkpoint::from_bytes(&b4), Err(CheckpointError::Format(_))));
    }

    #[test]
    fn into_params_matches_capture() {
        let c = sample();
        let (ws, bs) = c.clone().into_params();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0], c.layers[0].2);
        assert_eq!(bs[1], c.layers[1].3);
    }
}
