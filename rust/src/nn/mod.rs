//! Neural-network layer: MLP specifications, activation lookup tables,
//! quantisation, datasets, the lowering of training/inference onto the
//! Matrix Machine's vector ISA, and the trainer that drives the simulator.
//!
//! The paper's functional requirements (§2): "the Matrix Machine must train
//! and test MLPs. The Matrix Machine must calculate the forward passes...
//! the loss functions' gradients must be calculated using the
//! back-propagation algorithm. The gradients are then used to update the
//! weights." All of that is built here on top of the seven vector opcodes +
//! LUT activations (see [`lowering`]).

pub mod checkpoint;
pub mod dataset;
pub mod float_ref;
pub mod graph;
pub mod lowering;
pub mod lut;
pub mod mlp;
pub mod precision;
pub mod trainer;

pub use graph::{FloatGraph, GraphSpec, GraphTrainer};
pub use lut::{ActKind, ActLut, AddrMode};
pub use mlp::MlpSpec;
pub use precision::PrecisionPlan;
