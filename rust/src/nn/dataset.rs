//! Synthetic datasets for training/testing the MLPs.
//!
//! The paper reports no datasets; per the substitution rule (DESIGN.md §2)
//! we generate classic small classification tasks that exercise the same
//! code paths: Gaussian blobs, two moons, XOR, and "mini-digits" — noisy
//! 5×3 digit glyphs, a tiny synthetic stand-in for a real digits corpus.
//! All generators are deterministic from a seed.

use super::float_ref::argmax;
use crate::fixed::FixedSpec;
use crate::util::Rng;
use std::ops::Range;

/// In-order chunks of at most `batch` rows covering `0..len` — **the**
/// batch-chunking rule shared by every evaluation path
/// ([`crate::nn::trainer::Trainer::evaluate`] and
/// [`crate::session::Session::evaluate`] both iterate these ranges; the
/// final range is the partial remainder chunk when `len % batch != 0`).
pub fn chunk_ranges(len: usize, batch: usize) -> impl Iterator<Item = Range<usize>> {
    assert!(batch > 0, "batch must be positive");
    (0..len).step_by(batch).map(move |off| off..(off + batch).min(len))
}

/// Flatten request `rows` (each exactly `dim` lanes) into a row-major
/// `pad_to × dim` matrix, zero-padding the tail rows — the serving
/// micro-batcher's batch-assembly step, producing the same row-major
/// layout [`Dataset::encode_rows`] emits for evaluation chunks (one
/// assembly rule for every batched-forward path). Padding is safe:
/// forward lanes are per-row, so zero rows never perturb real rows.
pub fn flatten_rows(rows: &[&[i16]], dim: usize, pad_to: usize) -> Vec<i16> {
    assert!(rows.len() <= pad_to, "{} rows exceed bucket {pad_to}", rows.len());
    let mut q = Vec::with_capacity(pad_to * dim);
    for r in rows {
        assert_eq!(r.len(), dim, "row has {} lanes, expected {dim}", r.len());
        q.extend_from_slice(r);
    }
    q.resize(pad_to * dim, 0);
    q
}

/// A labelled dataset with one-hot targets.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Feature vectors.
    pub x: Vec<Vec<f64>>,
    /// One-hot target vectors.
    pub y: Vec<Vec<f64>>,
    /// Number of classes.
    pub classes: usize,
    /// Human-readable name.
    pub name: String,
}

impl Dataset {
    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.x.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.x.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Class label of sample `i`.
    pub fn label(&self, i: usize) -> usize {
        argmax(&self.y[i])
    }

    /// Shuffle and split into (train, test) at `train_frac`.
    pub fn split(mut self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let cut = ((self.len() as f64) * train_frac).round() as usize;
        let take = |ids: &[usize], s: &Dataset, name: String| Dataset {
            x: ids.iter().map(|&i| s.x[i].clone()).collect(),
            y: ids.iter().map(|&i| s.y[i].clone()).collect(),
            classes: s.classes,
            name,
        };
        let train = take(&idx[..cut], &self, format!("{}-train", self.name));
        let test = take(&idx[cut..], &self, format!("{}-test", self.name));
        self.x.clear();
        self.y.clear();
        (train, test)
    }

    /// Quantised row-major feature matrix of rows `r` (the encode step of
    /// every evaluation chunk loop — see [`chunk_ranges`]).
    pub fn encode_rows(&self, r: Range<usize>, fixed: FixedSpec) -> Vec<i16> {
        let mut q = Vec::with_capacity(r.len() * self.dim());
        for i in r {
            q.extend(self.x[i].iter().map(|&v| fixed.from_f64(v)));
        }
        q
    }

    /// Count rows of chunk `r` whose decoded argmax matches the label;
    /// `out` is the device's row-major `(r.len() × classes)` output for
    /// the chunk.
    pub fn count_correct(&self, r: Range<usize>, out: &[i16], fixed: FixedSpec) -> usize {
        let k = self.classes;
        let mut row: Vec<f64> = Vec::with_capacity(k);
        let mut correct = 0usize;
        for (j, i) in r.enumerate() {
            row.clear();
            row.extend(out[j * k..(j + 1) * k].iter().map(|&q| fixed.to_f64(q)));
            if argmax(&row) == self.label(i) {
                correct += 1;
            }
        }
        correct
    }

    /// A mini-batch as flattened row-major matrices `(B×dim, B×classes)`.
    pub fn batch(&self, ids: &[usize]) -> (Vec<f64>, Vec<f64>) {
        let mut bx = Vec::with_capacity(ids.len() * self.dim());
        let mut by = Vec::with_capacity(ids.len() * self.classes);
        for &i in ids {
            bx.extend_from_slice(&self.x[i]);
            by.extend_from_slice(&self.y[i]);
        }
        (bx, by)
    }
}

fn one_hot(classes: usize, c: usize) -> Vec<f64> {
    let mut v = vec![0.0; classes];
    v[c] = 1.0;
    v
}

/// Isotropic Gaussian blobs: `classes` clusters in `dim` dimensions.
pub fn blobs(n: usize, classes: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f64>> = (0..classes)
        .map(|_| (0..dim).map(|_| rng.gen_f64() * 4.0 - 2.0).collect())
        .collect();
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        x.push(centers[c].iter().map(|&m| m + rng.gen_normal() * 0.35).collect());
        y.push(one_hot(classes, c));
    }
    Dataset { x, y, classes, name: "blobs".into() }
}

/// Two interleaved half-moons (2 classes, 2-D).
pub fn two_moons(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let t = rng.gen_f64() * std::f64::consts::PI;
        let (noise_x, noise_y) = (rng.gen_normal() * 0.1, rng.gen_normal() * 0.1);
        if i % 2 == 0 {
            x.push(vec![t.cos() + noise_x, t.sin() + noise_y]);
            y.push(one_hot(2, 0));
        } else {
            x.push(vec![1.0 - t.cos() + noise_x, 0.5 - t.sin() + noise_y]);
            y.push(one_hot(2, 1));
        }
    }
    Dataset { x, y, classes: 2, name: "two_moons".into() }
}

/// The XOR problem with jitter (2 classes, 2-D).
pub fn xor(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.gen_bool(0.5);
        let b = rng.gen_bool(0.5);
        x.push(vec![
            a as u8 as f64 + rng.gen_normal() * 0.1,
            b as u8 as f64 + rng.gen_normal() * 0.1,
        ]);
        y.push(one_hot(2, (a ^ b) as usize));
    }
    Dataset { x, y, classes: 2, name: "xor".into() }
}

/// 5×3 glyphs of the digits 0–9.
const GLYPHS: [[u8; 15]; 10] = [
    [1, 1, 1, 1, 0, 1, 1, 0, 1, 1, 0, 1, 1, 1, 1], // 0
    [0, 1, 0, 1, 1, 0, 0, 1, 0, 0, 1, 0, 1, 1, 1], // 1
    [1, 1, 1, 0, 0, 1, 1, 1, 1, 1, 0, 0, 1, 1, 1], // 2
    [1, 1, 1, 0, 0, 1, 0, 1, 1, 0, 0, 1, 1, 1, 1], // 3
    [1, 0, 1, 1, 0, 1, 1, 1, 1, 0, 0, 1, 0, 0, 1], // 4
    [1, 1, 1, 1, 0, 0, 1, 1, 1, 0, 0, 1, 1, 1, 1], // 5
    [1, 1, 1, 1, 0, 0, 1, 1, 1, 1, 0, 1, 1, 1, 1], // 6
    [1, 1, 1, 0, 0, 1, 0, 1, 0, 0, 1, 0, 0, 1, 0], // 7
    [1, 1, 1, 1, 0, 1, 1, 1, 1, 1, 0, 1, 1, 1, 1], // 8
    [1, 1, 1, 1, 0, 1, 1, 1, 1, 0, 0, 1, 1, 1, 1], // 9
];

/// "Mini-digits": noisy 15-pixel digit glyphs, 10 classes — the synthetic
/// stand-in for a small real digits corpus (DESIGN.md §2).
pub fn mini_digits(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % 10;
        let v: Vec<f64> = GLYPHS[c]
            .iter()
            .map(|&p| {
                let mut val = p as f64;
                if rng.gen_bool(0.02) {
                    val = 1.0 - val; // pixel flip
                }
                val + rng.gen_normal() * 0.12
            })
            .collect();
        x.push(v);
        y.push(one_hot(10, c));
    }
    Dataset { x, y, classes: 10, name: "mini_digits".into() }
}

/// Look up a generator by name (launcher configs).
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    match name {
        "blobs" => Some(blobs(n, 4, 8, seed)),
        "two_moons" => Some(two_moons(n, seed)),
        "xor" => Some(xor(n, seed)),
        "mini_digits" => Some(mini_digits(n, seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        for name in ["blobs", "two_moons", "xor", "mini_digits"] {
            let a = by_name(name, 100, 42).unwrap();
            let b = by_name(name, 100, 42).unwrap();
            assert_eq!(a.len(), 100, "{name}");
            assert_eq!(a.x, b.x, "{name} not deterministic");
            assert!(a.y.iter().all(|y| y.len() == a.classes));
            assert!(a.x.iter().all(|x| x.len() == a.dim()));
        }
        assert!(by_name("nope", 10, 1).is_none());
    }

    #[test]
    fn labels_are_one_hot() {
        let d = mini_digits(50, 7);
        for i in 0..d.len() {
            assert_eq!(d.y[i].iter().sum::<f64>(), 1.0);
            assert_eq!(d.y[i][d.label(i)], 1.0);
            assert_eq!(d.label(i), i % 10);
        }
    }

    #[test]
    fn split_partitions() {
        let d = blobs(100, 3, 4, 9);
        let (tr, te) = d.split(0.8, &mut Rng::new(1));
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.classes, 3);
    }

    #[test]
    fn chunk_ranges_cover_in_order_with_partial_tail() {
        let rs: Vec<_> = chunk_ranges(10, 4).collect();
        assert_eq!(rs, vec![0..4, 4..8, 8..10]);
        let rs: Vec<_> = chunk_ranges(8, 4).collect();
        assert_eq!(rs, vec![0..4, 4..8]);
        assert_eq!(chunk_ranges(0, 4).count(), 0);
        assert_eq!(chunk_ranges(3, 16).collect::<Vec<_>>(), vec![0..3]);
    }

    #[test]
    fn encode_rows_matches_batch_encoding() {
        let d = xor(10, 3);
        let f = FixedSpec::q(10);
        let (bx, _) = d.batch(&[2, 3, 4]);
        let via_batch: Vec<i16> = bx.iter().map(|&v| f.from_f64(v)).collect();
        assert_eq!(d.encode_rows(2..5, f), via_batch);
    }

    #[test]
    fn flatten_rows_matches_encode_rows_and_pads_with_zeros() {
        let d = xor(6, 5);
        let f = FixedSpec::q(10);
        let r0 = d.encode_rows(0..1, f);
        let r1 = d.encode_rows(1..2, f);
        let r2 = d.encode_rows(2..3, f);
        // same layout as one encode_rows call over the contiguous range
        let flat = flatten_rows(&[&r0, &r1, &r2], 2, 3);
        assert_eq!(flat, d.encode_rows(0..3, f));
        // padding appends zero rows only
        let padded = flatten_rows(&[&r0, &r1, &r2], 2, 5);
        assert_eq!(padded[..6], flat[..]);
        assert!(padded[6..].iter().all(|&v| v == 0));
        assert_eq!(padded.len(), 10);
        assert_eq!(flatten_rows(&[], 2, 2), vec![0i16; 4]);
    }

    #[test]
    fn count_correct_scores_argmax_rows() {
        let d = xor(6, 1);
        let f = FixedSpec::q(10);
        // device output that one-hot matches every label exactly
        let mut out = Vec::new();
        for i in 2..5 {
            for c in 0..d.classes {
                out.push(if c == d.label(i) { f.from_f64(1.0) } else { 0 });
            }
        }
        assert_eq!(d.count_correct(2..5, &out, f), 3);
        // flip one row's scores → one miss
        let k = d.classes;
        out[..k].reverse();
        assert_eq!(d.count_correct(2..5, &out, f), 2);
    }

    #[test]
    fn batch_flattens_row_major() {
        let d = xor(10, 3);
        let (bx, by) = d.batch(&[0, 3, 5]);
        assert_eq!(bx.len(), 3 * 2);
        assert_eq!(by.len(), 3 * 2);
        assert_eq!(bx[2..4], d.x[3][..]);
    }

    #[test]
    fn blobs_are_separable_by_centroid_distance() {
        // Same-class points should on average be closer to their own
        // centroid than to others.
        let d = blobs(400, 4, 8, 11);
        let mut centroids = vec![vec![0.0; d.dim()]; 4];
        let mut counts = [0usize; 4];
        for i in 0..d.len() {
            let c = d.label(i);
            counts[c] += 1;
            for (k, v) in d.x[i].iter().enumerate() {
                centroids[c][k] += v;
            }
        }
        for (c, cen) in centroids.iter_mut().enumerate() {
            for v in cen.iter_mut() {
                *v /= counts[c] as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut correct = 0;
        for i in 0..d.len() {
            let best = (0..4)
                .min_by(|&a, &b| {
                    dist(&d.x[i], &centroids[a]).partial_cmp(&dist(&d.x[i], &centroids[b])).unwrap()
                })
                .unwrap();
            if best == d.label(i) {
                correct += 1;
            }
        }
        assert!(correct as f64 / d.len() as f64 > 0.95);
    }
}
