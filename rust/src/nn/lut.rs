//! Activation lookup tables (paper §4.3).
//!
//! "Each bit shifter applies a 7 bit shift to the right. After the dual bit
//! shifts, the values are used as addresses to look-up the results for the
//! activation functions... the look-up tables are able to store the
//! activation functions as well as the derivatives of the activation
//! functions."
//!
//! A table holds [`LUT_SIZE`] = 1024 entries of Q.F outputs (one RAMB18E1).
//! The address of input `x: i16` is `x >> shift`, interpreted per
//! [`AddrMode`]:
//!
//! * [`AddrMode::Wrap`] — paper-accurate: the shifted value is truncated to
//!   10 bits and used directly (two's-complement aliasing at the edges).
//! * [`AddrMode::Clamp`] — our default for training: the shifted value is
//!   offset by half the table and saturated into `[0, 1023]`, so
//!   out-of-range inputs hit the table's edge entries instead of aliasing
//!   (DESIGN.md §3 deviation note; ablated in `benches/bench_ablation.rs`).
//!
//! `shift` trades range for resolution: the table covers real inputs of
//! magnitude `2^(shift+9-F)` with resolution `2^(shift-F)`. The paper fixes
//! `shift = 7`; the training stack typically uses smaller shifts for
//! saturating activations. Linear interpolation on the residual low bits is
//! available as an extension (`interp`), giving exact piecewise-linear
//! ReLU between knots.

use crate::fixed::FixedSpec;

/// Entries in one activation table (one RAMB18E1 of 1024 × 16).
pub const LUT_SIZE: usize = 1024;

/// LUT addressing behaviour for out-of-range inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AddrMode {
    /// Truncate the shifted value to 10 bits (paper behaviour).
    Wrap,
    /// Offset by 512 and saturate into the table (default for training).
    Clamp,
}

/// Guard epsilon for the unbounded table kinds ([`ActKind::Recip`],
/// [`ActKind::Rsqrt`]): inputs below it are treated as ε so the knot
/// values stay inside the representable range. Baked into the table at
/// build time, so every fidelity level sees the same knots.
pub const LUT_EPS: f64 = 1.0 / 64.0;

/// Supported activation functions (and via `deriv` their derivatives).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    /// `max(0, x)` (paper Eqn 2).
    Relu,
    /// Logistic `1 / (1 + e^-x)`.
    Sigmoid,
    /// Hyperbolic tangent.
    Tanh,
    /// Pass-through (useful for output layers / testing).
    Identity,
    /// `e^x` — the softmax numerator table (operator-graph attention).
    Exp,
    /// `1 / max(x, ε)` — the softmax normaliser table (ε = [`LUT_EPS`]).
    Recip,
    /// `1 / sqrt(max(x, ε))` — the layernorm inverse-stddev table
    /// (ε = [`LUT_EPS`], playing the usual layernorm ε role).
    Rsqrt,
}

impl ActKind {
    /// Real-valued function.
    pub fn f(self, x: f64) -> f64 {
        match self {
            ActKind::Relu => x.max(0.0),
            ActKind::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            ActKind::Tanh => x.tanh(),
            ActKind::Identity => x,
            ActKind::Exp => x.exp(),
            ActKind::Recip => 1.0 / x.max(LUT_EPS),
            ActKind::Rsqrt => 1.0 / x.max(LUT_EPS).sqrt(),
        }
    }

    /// Real-valued derivative.
    pub fn df(self, x: f64) -> f64 {
        match self {
            ActKind::Relu => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            ActKind::Sigmoid => {
                let s = self.f(x);
                s * (1.0 - s)
            }
            ActKind::Tanh => 1.0 - x.tanh().powi(2),
            ActKind::Identity => 1.0,
            ActKind::Exp => x.exp(),
            ActKind::Recip => {
                let c = x.max(LUT_EPS);
                -1.0 / (c * c)
            }
            ActKind::Rsqrt => {
                let c = x.max(LUT_EPS);
                -0.5 / (c * c.sqrt())
            }
        }
    }

    /// Parse a config name.
    pub fn parse(name: &str) -> Option<ActKind> {
        match name {
            "relu" => Some(ActKind::Relu),
            "sigmoid" => Some(ActKind::Sigmoid),
            "tanh" => Some(ActKind::Tanh),
            "identity" | "linear" => Some(ActKind::Identity),
            "exp" => Some(ActKind::Exp),
            "recip" => Some(ActKind::Recip),
            "rsqrt" => Some(ActKind::Rsqrt),
            _ => None,
        }
    }

    /// Config name.
    pub fn name(self) -> &'static str {
        match self {
            ActKind::Relu => "relu",
            ActKind::Sigmoid => "sigmoid",
            ActKind::Tanh => "tanh",
            ActKind::Identity => "identity",
            ActKind::Exp => "exp",
            ActKind::Recip => "recip",
            ActKind::Rsqrt => "rsqrt",
        }
    }
}

/// A built activation table + its addressing parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ActLut {
    table: Vec<i16>,
    /// Right-shift applied to the input before addressing.
    pub shift: u32,
    /// Addressing behaviour.
    pub mode: AddrMode,
    /// Linear interpolation on the residual bits (extension).
    pub interp: bool,
    /// The function this table encodes.
    pub kind: ActKind,
    /// Is this the derivative table?
    pub deriv: bool,
    /// Fixed-point format of inputs and outputs.
    pub fixed: FixedSpec,
}

impl ActLut {
    /// Build a table for `kind` (or its derivative) under the given
    /// fixed-point format, addressing mode, and shift.
    pub fn build(
        kind: ActKind,
        deriv: bool,
        fixed: FixedSpec,
        mode: AddrMode,
        shift: u32,
    ) -> ActLut {
        assert!(shift <= 15, "shift {shift} out of range");
        let mut table = vec![0i16; LUT_SIZE];
        for (i, slot) in table.iter_mut().enumerate() {
            // Index → the 10-bit shifted-input value it corresponds to.
            let v10: i64 = match mode {
                // Wrap: index IS the low 10 bits of (x >> shift), so the
                // represented value is the sign-extended 10-bit pattern.
                AddrMode::Wrap => ((i as i64) << 54) >> 54,
                // Clamp: index = (x >> shift) + 512.
                AddrMode::Clamp => i as i64 - (LUT_SIZE as i64 / 2),
            };
            // Real input at this knot: (v10 << shift) / 2^F.
            let x_real = (v10 << shift) as f64 / fixed.scale();
            let y = if deriv { kind.df(x_real) } else { kind.f(x_real) };
            *slot = fixed.from_f64(y.clamp(-255.0, 255.0));
        }
        ActLut { table, shift, mode, interp: false, kind, deriv, fixed }
    }

    /// Enable linear interpolation on the residual low `shift` bits.
    pub fn with_interp(mut self) -> ActLut {
        self.interp = true;
        self
    }

    /// The raw 1024-entry table (what `ACTPRO_WRITE_ACT` loads).
    pub fn table(&self) -> &[i16] {
        &self.table
    }

    /// Table address for input `x` (the shift + mode datapath of Fig 9).
    #[inline]
    pub fn addr(&self, x: i16) -> usize {
        let shifted = (x as i32) >> self.shift;
        match self.mode {
            AddrMode::Wrap => (shifted as u32 as usize) & (LUT_SIZE - 1),
            AddrMode::Clamp => {
                (shifted + LUT_SIZE as i32 / 2).clamp(0, LUT_SIZE as i32 - 1) as usize
            }
        }
    }

    /// Apply the activation to one lane exactly as the ACTPRO datapath
    /// does (shift → address → BRAM read [→ optional interpolation]).
    #[inline]
    pub fn apply_scalar(&self, x: i16) -> i16 {
        let a = self.addr(x);
        let y0 = self.table[a] as i64;
        if !self.interp || self.shift == 0 {
            return y0 as i16;
        }
        // Residual low bits select the fraction between knot a and a+1.
        let frac = (x as i64) & ((1 << self.shift) - 1);
        let a1 = match self.mode {
            AddrMode::Wrap => (a + 1) & (LUT_SIZE - 1),
            AddrMode::Clamp => (a + 1).min(LUT_SIZE - 1),
        };
        let y1 = self.table[a1] as i64;
        self.fixed.narrow(y0 + (((y1 - y0) * frac) >> self.shift))
    }

    /// Apply to a vector.
    pub fn apply(&self, xs: &[i16]) -> Vec<i16> {
        xs.iter().map(|&x| self.apply_scalar(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    #[test]
    fn paper_mode_is_shift7_wrap() {
        // §4.3: "Each bit shifter applies a 7 bit shift to the right".
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Wrap, 7);
        // x = 1.0 (128 raw) → 128 >> 7 = 1 → knot value relu(1.0) = 1.0.
        assert_eq!(lut.apply_scalar(S.from_f64(1.0)), S.from_f64(1.0));
        // x = -1.0 → -128 >> 7 = -1 → relu(-1.0) = 0.
        assert_eq!(lut.apply_scalar(S.from_f64(-1.0)), 0);
    }

    #[test]
    fn wrap_mode_aliases_out_of_range() {
        // shift 2, wrap: x >> 2 covers ±512 of shifted units = ±2048 raw =
        // ±16.0 real. x = +16.0 (2048 raw) → 2048>>2 = 512 → wraps to
        // index 512 → v10 = -512 → relu(-512 * 4 / 128) = 0: aliased!
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Wrap, 2);
        assert_eq!(lut.apply_scalar(S.from_f64(16.0)), 0);
    }

    #[test]
    fn clamp_mode_saturates_out_of_range() {
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 2);
        // +16.0 clamps to the top knot: relu(511 * 4 / 128) = 15.97.
        let top = lut.apply_scalar(S.from_f64(16.0));
        assert_eq!(top, S.from_f64(511.0 * 4.0 / 128.0));
        // very negative input → bottom knot → 0
        assert_eq!(lut.apply_scalar(S.from_f64(-100.0)), 0);
    }

    #[test]
    fn relu_knots_are_exact() {
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7);
        for k in -3..=3i64 {
            let x = (k << 7) as i16; // exactly on knot k
            let want = S.from_f64(ActKind::Relu.f(k as f64));
            assert_eq!(lut.apply_scalar(x), want, "knot {k}");
        }
    }

    #[test]
    fn interp_makes_relu_exact_away_from_kink() {
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7).with_interp();
        let mut r = Rng::new(8);
        for _ in 0..2000 {
            let x = r.gen_range_i64(-20000, 20000) as i16;
            let y = lut.apply_scalar(x);
            if x >= 128 {
                // fully in the linear region: interp reconstructs x exactly
                assert_eq!(y, x, "x={x}");
            } else if x < -128 {
                assert_eq!(y, 0, "x={x}");
            }
        }
    }

    #[test]
    fn sigmoid_close_to_real_function_with_fine_shift() {
        // shift 2 → resolution 4/128 = 1/32 real units per knot.
        let lut =
            ActLut::build(ActKind::Sigmoid, false, S, AddrMode::Clamp, 2).with_interp();
        for i in -600..600 {
            let x_real = i as f64 / 50.0; // ±12
            let x = S.from_f64(x_real);
            let y = S.to_f64(lut.apply_scalar(x));
            let want = ActKind::Sigmoid.f(S.to_f64(x));
            assert!(
                (y - want).abs() < 0.02,
                "sigmoid({x_real}) = {y}, want {want}"
            );
        }
    }

    #[test]
    fn derivative_tables() {
        let dlut = ActLut::build(ActKind::Relu, true, S, AddrMode::Clamp, 7);
        assert_eq!(dlut.apply_scalar(S.from_f64(3.0)), S.from_f64(1.0));
        assert_eq!(dlut.apply_scalar(S.from_f64(-3.0)), 0);
        let dsig = ActLut::build(ActKind::Sigmoid, true, S, AddrMode::Clamp, 2);
        // sigmoid'(0) = 0.25
        assert_eq!(dsig.apply_scalar(0), S.from_f64(0.25));
    }

    #[test]
    fn table_size_is_one_bram() {
        let lut = ActLut::build(ActKind::Tanh, false, S, AddrMode::Clamp, 3);
        assert_eq!(lut.table().len(), LUT_SIZE);
    }

    #[test]
    fn all_kinds_parse_roundtrip() {
        for k in [
            ActKind::Relu,
            ActKind::Sigmoid,
            ActKind::Tanh,
            ActKind::Identity,
            ActKind::Exp,
            ActKind::Recip,
            ActKind::Rsqrt,
        ] {
            assert_eq!(ActKind::parse(k.name()), Some(k));
        }
        assert_eq!(ActKind::parse("swish"), None);
    }

    #[test]
    fn graph_tables_track_their_functions() {
        // shift 2 → knots every 4/128 = 1/32 real units; interp keeps the
        // residual error well under the tolerance oracle's band.
        for kind in [ActKind::Exp, ActKind::Recip, ActKind::Rsqrt] {
            let lut = ActLut::build(kind, false, S, AddrMode::Clamp, 2).with_interp();
            // Recip/Rsqrt are steep near the ε guard; the accuracy
            // contract is over the moderate range the lowering feeds
            // them (sums/variances well above ε).
            for i in 20..200 {
                let x_real = i as f64 / 40.0; // [0.5, 5]
                let x = S.from_f64(x_real);
                let y = S.to_f64(lut.apply_scalar(x));
                let want = kind.f(S.to_f64(x));
                assert!(
                    (y - want).abs() < 0.3,
                    "{}({x_real}) = {y}, want {want}",
                    kind.name()
                );
            }
        }
        // the ε guard keeps small/negative inputs finite and positive
        let recip = ActLut::build(ActKind::Recip, false, S, AddrMode::Clamp, 2);
        assert_eq!(recip.apply_scalar(0), S.from_f64(64.0));
        let rsqrt = ActLut::build(ActKind::Rsqrt, false, S, AddrMode::Clamp, 2);
        assert_eq!(rsqrt.apply_scalar(0), S.from_f64(8.0));
    }
}
