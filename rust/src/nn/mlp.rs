//! MLP specifications (paper §1.1).
//!
//! `O_i = A(W_iᵀ X_i + B_i)` per layer; weights are `(inputs × outputs)`
//! row-major so a weight column (one output neuron's fan-in) is a strided
//! view and a weight row (one input's fan-out) is contiguous — the two
//! access patterns forward and backward passes need (see
//! [`super::lowering`]).

use super::lut::{ActKind, AddrMode};
use crate::fixed::FixedSpec;
use crate::hw::COLUMN_LEN;
use thiserror::Error;

/// Maximum layer dimension the assembler supports by chunking vectors
/// over multiple 512-lane columns (paper §2: matrices "could be as big
/// as the user wants"; the chunked-dot quantisation note is in
/// [`super::lowering`]).
pub const MAX_DIM: usize = 8 * COLUMN_LEN;

/// One layer: `inputs → outputs` with an activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    /// Fan-in.
    pub inputs: usize,
    /// Fan-out.
    pub outputs: usize,
    /// Activation function.
    pub act: ActKind,
}

/// LUT generation parameters (VHDL generics of the ACTPRO groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LutParams {
    /// Input right-shift before addressing.
    pub shift: u32,
    /// Addressing mode.
    pub mode: AddrMode,
    /// Linear interpolation extension.
    pub interp: bool,
}

impl LutParams {
    /// The paper's configuration (§4.3): shift 7, wrap, no interpolation.
    pub const PAPER: LutParams = LutParams { shift: 7, mode: AddrMode::Wrap, interp: false };

    /// Default training configuration: finer shift, clamped addressing,
    /// interpolation on (DESIGN.md §3).
    pub fn training(fixed: FixedSpec) -> LutParams {
        LutParams {
            shift: fixed.frac_bits.saturating_sub(5),
            mode: AddrMode::Clamp,
            interp: true,
        }
    }
}

/// A full MLP specification.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpSpec {
    /// Network name.
    pub name: String,
    /// Layers, in forward order.
    pub layers: Vec<LayerSpec>,
    /// Datapath fixed-point format.
    pub fixed: FixedSpec,
    /// Activation-table parameters.
    pub lut: LutParams,
}

/// Spec validation errors.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum SpecError {
    /// No layers.
    #[error("MLP has no layers")]
    NoLayers,
    /// A dimension is zero or exceeds the assembler's chunking limit.
    #[error("layer {0}: dimension {1} out of range 1..={MAX_DIM}")]
    BadDim(usize, usize),
    /// Consecutive layers disagree on width.
    #[error("layer {0}: inputs {1} != previous outputs {2}")]
    Mismatch(usize, usize, usize),
}

impl MlpSpec {
    /// Build from a dimension list `[in, h1, ..., out]`, hidden activation
    /// `act`, and output activation `out_act`.
    pub fn from_dims(
        name: &str,
        dims: &[usize],
        act: ActKind,
        out_act: ActKind,
        fixed: FixedSpec,
        lut: LutParams,
    ) -> Result<MlpSpec, SpecError> {
        if dims.len() < 2 {
            return Err(SpecError::NoLayers);
        }
        let layers: Vec<LayerSpec> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| LayerSpec {
                inputs: w[0],
                outputs: w[1],
                act: if i + 2 == dims.len() { out_act } else { act },
            })
            .collect();
        let spec = MlpSpec { name: name.to_string(), layers, fixed, lut };
        spec.check()?;
        Ok(spec)
    }

    /// Validate dimensions.
    pub fn check(&self) -> Result<(), SpecError> {
        if self.layers.is_empty() {
            return Err(SpecError::NoLayers);
        }
        for (i, l) in self.layers.iter().enumerate() {
            for d in [l.inputs, l.outputs] {
                if d == 0 || d > MAX_DIM {
                    return Err(SpecError::BadDim(i, d));
                }
            }
            if i > 0 && l.inputs != self.layers[i - 1].outputs {
                return Err(SpecError::Mismatch(i, l.inputs, self.layers[i - 1].outputs));
            }
        }
        Ok(())
    }

    /// Input dimension.
    pub fn input_dim(&self) -> usize {
        self.layers[0].inputs
    }

    /// Output dimension.
    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().outputs
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.inputs * l.outputs + l.outputs).sum()
    }

    /// Parameter bytes at 16 bits/lane (what the cluster must ship to a
    /// board when placing this net).
    pub fn param_bytes(&self) -> u64 {
        self.param_count() as u64 * 2
    }

    /// The MLP as an operator graph: a `Linear` + `Activation` chain.
    /// Lowering this graph emits programs **bit-identical** to the
    /// frozen legacy MLP lowering (the pairs fuse back into dense
    /// layers) — `MlpSpec` is now a thin builder over
    /// [`crate::nn::graph::GraphSpec`].
    pub fn to_graph(&self) -> crate::nn::graph::GraphSpec {
        let mut g = crate::nn::graph::GraphSpec::new(
            &self.name,
            self.input_dim(),
            self.fixed,
            self.lut,
        );
        let mut v = crate::nn::graph::INPUT;
        for layer in &self.layers {
            v = g.linear(v, layer.outputs);
            v = g.activation(v, layer.act);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lp() -> LutParams {
        LutParams::training(FixedSpec::PAPER)
    }

    #[test]
    fn from_dims_builds_layers() {
        let m = MlpSpec::from_dims(
            "m",
            &[4, 16, 8, 3],
            ActKind::Relu,
            ActKind::Sigmoid,
            FixedSpec::PAPER,
            lp(),
        )
        .unwrap();
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.layers[0], LayerSpec { inputs: 4, outputs: 16, act: ActKind::Relu });
        assert_eq!(m.layers[2], LayerSpec { inputs: 8, outputs: 3, act: ActKind::Sigmoid });
        assert_eq!(m.input_dim(), 4);
        assert_eq!(m.output_dim(), 3);
        assert_eq!(m.param_count(), 4 * 16 + 16 + 16 * 8 + 8 + 8 * 3 + 3);
        assert_eq!(m.param_bytes(), 2 * m.param_count() as u64);
    }

    #[test]
    fn rejects_bad_specs() {
        assert_eq!(
            MlpSpec::from_dims("m", &[4], ActKind::Relu, ActKind::Relu, FixedSpec::PAPER, lp()),
            Err(SpecError::NoLayers)
        );
        assert_eq!(
            MlpSpec::from_dims(
                "m",
                &[4, MAX_DIM + 1],
                ActKind::Relu,
                ActKind::Relu,
                FixedSpec::PAPER,
                lp()
            ),
            Err(SpecError::BadDim(0, MAX_DIM + 1))
        );
        // dims beyond one column but within MAX_DIM are fine (chunked)
        assert!(MlpSpec::from_dims(
            "m",
            &[600, 513],
            ActKind::Relu,
            ActKind::Relu,
            FixedSpec::PAPER,
            lp()
        )
        .is_ok());
        let mut m = MlpSpec::from_dims(
            "m",
            &[4, 8, 2],
            ActKind::Relu,
            ActKind::Relu,
            FixedSpec::PAPER,
            lp(),
        )
        .unwrap();
        m.layers[1].inputs = 9;
        assert_eq!(m.check(), Err(SpecError::Mismatch(1, 9, 8)));
    }

    #[test]
    fn training_lut_params() {
        let p = LutParams::training(FixedSpec::q(10));
        assert_eq!(p.shift, 5);
        assert_eq!(p.mode, AddrMode::Clamp);
        assert!(p.interp);
        assert_eq!(LutParams::PAPER.shift, 7);
        assert_eq!(LutParams::PAPER.mode, AddrMode::Wrap);
    }
}
