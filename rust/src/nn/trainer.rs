//! The trainer: drives on-device MLP training on a simulated Matrix
//! Machine — the paper's "training phase" (§2), with loss tracking and
//! accuracy evaluation on the forward program ("testing phase").

use super::dataset::Dataset;
use super::float_ref::{argmax, FloatMlp};
use super::lowering::{lower_forward, lower_train_step, LowerError, LoweredMlp};
use super::mlp::MlpSpec;
use crate::hw::machine::MachineError;
use crate::hw::{FpgaDevice, MatrixMachine, RunStats};
use crate::util::Rng;
use thiserror::Error;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Mini-batch size (≤ 512).
    pub batch: usize,
    /// Learning rate (must be representable in the fixed format).
    pub lr: f64,
    /// Training steps.
    pub steps: usize,
    /// RNG seed (weights + batch sampling).
    pub seed: u64,
    /// Record loss every `log_every` steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 300, seed: 0xF00D, log_every: 10 }
    }
}

/// Trainer errors.
#[derive(Debug, Error)]
pub enum TrainError {
    /// Lowering failed.
    #[error("lowering failed: {0}")]
    Lower(#[from] LowerError),
    /// Machine failed.
    #[error("machine error: {0}")]
    Machine(#[from] MachineError),
    /// Dataset/spec dimension mismatch.
    #[error("dataset dim {0}/classes {1} do not match MLP {2}→{3}")]
    DimMismatch(usize, usize, usize, usize),
}

/// One logged training point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossPoint {
    /// Step index.
    pub step: usize,
    /// Mean squared error per sample·output (host-computed, float).
    pub loss: f64,
    /// On-device loss register (Σ(o−y)², quantised; may wrap for large
    /// batches — diagnostic only).
    pub device_loss: f64,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Loss curve.
    pub curve: Vec<LossPoint>,
    /// Aggregated machine statistics.
    pub stats: RunStats,
    /// Simulated wall-clock seconds on the device.
    pub sim_seconds: f64,
    /// Steps executed.
    pub steps: usize,
}

/// Drives one MLP's training + evaluation on one simulated board.
pub struct Trainer {
    /// Network spec.
    pub spec: MlpSpec,
    /// Board.
    pub device: FpgaDevice,
    /// Config.
    pub cfg: TrainConfig,
    train: LoweredMlp,
    fwd: LoweredMlp,
    train_machine: MatrixMachine,
    fwd_machine: MatrixMachine,
    /// Lazily-lowered forward program for the final partial evaluation
    /// chunk (`(rows, program, machine)`): instead of padding the last
    /// chunk up to `cfg.batch` and paying full-batch compute, a
    /// right-sized plan runs exactly the remaining rows (perf pass,
    /// DESIGN.md §Perf).
    fwd_rem: Option<(usize, LoweredMlp, MatrixMachine)>,
    rng: Rng,
}

impl Trainer {
    /// Lower programs and initialise weights (He-scaled, quantised).
    pub fn new(spec: MlpSpec, device: FpgaDevice, cfg: TrainConfig) -> Result<Trainer, TrainError> {
        let train = lower_train_step(&spec, cfg.batch, cfg.lr)?;
        let fwd = lower_forward(&spec, cfg.batch)?;
        let mut train_machine = MatrixMachine::new(device, &train.program)?;
        let fwd_machine = MatrixMachine::new(device, &fwd.program)?;
        let mut rng = Rng::new(cfg.seed);
        // Initial weights from the float reference's init, quantised.
        let init = FloatMlp::init(&spec, &mut rng);
        let (qw, qb) = init.quantized();
        for l in 0..spec.layers.len() {
            train_machine.bind(&train.program, &format!("w{l}"), &qw[l])?;
            train_machine.bind(&train.program, &format!("b{l}"), &qb[l])?;
        }
        Ok(Trainer { spec, device, cfg, train, fwd, train_machine, fwd_machine, fwd_rem: None, rng })
    }

    /// Bind explicit weights (e.g. to mirror a float run).
    pub fn set_weights(&mut self, qw: &[Vec<i16>], qb: &[Vec<i16>]) -> Result<(), TrainError> {
        for l in 0..self.spec.layers.len() {
            self.train_machine.bind(&self.train.program, &format!("w{l}"), &qw[l])?;
            self.train_machine.bind(&self.train.program, &format!("b{l}"), &qb[l])?;
        }
        Ok(())
    }

    /// Snapshot the on-device parameters as a [`Checkpoint`].
    pub fn checkpoint(&self) -> crate::nn::checkpoint::Checkpoint {
        let (w, b) = self.weights();
        let dims: Vec<(usize, usize)> =
            self.spec.layers.iter().map(|l| (l.inputs, l.outputs)).collect();
        crate::nn::checkpoint::Checkpoint::capture(self.spec.fixed, &dims, &w, &b)
    }

    /// Restore parameters from a [`Checkpoint`] (shapes must match).
    pub fn restore(
        &mut self,
        ckpt: crate::nn::checkpoint::Checkpoint,
    ) -> Result<(), TrainError> {
        let (w, b) = ckpt.into_params();
        self.set_weights(&w, &b)
    }

    /// Current on-device weights.
    pub fn weights(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let nl = self.spec.layers.len();
        let w = (0..nl)
            .map(|l| self.train_machine.read(&self.train.program, &format!("w{l}")).unwrap())
            .collect();
        let b = (0..nl)
            .map(|l| self.train_machine.read(&self.train.program, &format!("b{l}")).unwrap())
            .collect();
        (w, b)
    }

    fn check_dims(&self, ds: &Dataset) -> Result<(), TrainError> {
        if ds.dim() != self.spec.input_dim() || ds.classes != self.spec.output_dim() {
            return Err(TrainError::DimMismatch(
                ds.dim(),
                ds.classes,
                self.spec.input_dim(),
                self.spec.output_dim(),
            ));
        }
        Ok(())
    }

    /// Run `cfg.steps` SGD steps over random mini-batches of `ds`.
    pub fn train(&mut self, ds: &Dataset) -> Result<TrainReport, TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let out_dim = self.spec.output_dim();
        let mut stats = RunStats::default();
        let mut curve = Vec::new();
        let mut ids: Vec<usize> = Vec::with_capacity(batch);
        for step in 0..self.cfg.steps {
            ids.clear();
            for _ in 0..batch {
                ids.push(self.rng.gen_range(ds.len() as u64) as usize);
            }
            let (bx, by) = ds.batch(&ids);
            let qx = f.encode_vec(&bx);
            let qy = f.encode_vec(&by);
            self.train_machine.bind(&self.train.program, "x", &qx)?;
            self.train_machine.bind(&self.train.program, "y", &qy)?;
            let st = self.train_machine.run(&self.train.program)?;
            stats.add(&st);
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                // Host-side float loss from the device's output activations.
                let last = self.spec.layers.len() - 1;
                let o = self.train_machine.read(&self.train.program, &format!("o{last}"))?;
                let mut loss = 0.0;
                for (i, &q) in o.iter().enumerate() {
                    let d = f.to_f64(q) - by[i];
                    loss += d * d;
                }
                loss /= (batch * out_dim) as f64;
                let device_loss =
                    f.to_f64(self.train_machine.read(&self.train.program, "loss")?[0]);
                curve.push(LossPoint { step, loss, device_loss });
            }
        }
        Ok(TrainReport {
            curve,
            stats,
            sim_seconds: stats.seconds(&self.device),
            steps: self.cfg.steps,
        })
    }

    /// Classification accuracy of the current weights over `ds` (uses the
    /// forward program — the paper's "testing" phase).
    ///
    /// The final partial chunk (when `ds.len() % batch != 0`) runs on a
    /// right-sized forward plan instead of being padded to the full
    /// batch, so no compute (or cycle charge) is spent on padding rows.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<(f64, RunStats), TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let out_dim = self.spec.output_dim();
        // copy current weights into the forward machine(s)
        let (qw, qb) = self.weights();
        for l in 0..self.spec.layers.len() {
            self.fwd_machine.bind(&self.fwd.program, &format!("w{l}"), &qw[l])?;
            self.fwd_machine.bind(&self.fwd.program, &format!("b{l}"), &qb[l])?;
        }
        let rem = ds.len() % batch;
        if rem != 0 {
            if self.fwd_rem.as_ref().map_or(true, |(rows, _, _)| *rows != rem) {
                let lowered = lower_forward(&self.spec, rem)?;
                let machine = MatrixMachine::new(self.device, &lowered.program)?;
                self.fwd_rem = Some((rem, lowered, machine));
            }
            let (_, lowered, machine) = self.fwd_rem.as_mut().expect("just built");
            for l in 0..qw.len() {
                machine.bind(&lowered.program, &format!("w{l}"), &qw[l])?;
                machine.bind(&lowered.program, &format!("b{l}"), &qb[l])?;
            }
        }
        let mut stats = RunStats::default();
        let mut correct = 0usize;
        let mut seen = 0usize;
        let last = self.spec.layers.len() - 1;
        let out_name = format!("o{last}");
        let mut ids: Vec<usize> = Vec::with_capacity(batch);
        let mut row: Vec<f64> = Vec::with_capacity(out_dim);
        let mut off = 0usize;
        while off < ds.len() {
            let end = (off + batch).min(ds.len());
            ids.clear();
            ids.extend(off..end);
            let (bx, _) = ds.batch(&ids);
            let qx = f.encode_vec(&bx);
            let o = if end - off == batch {
                self.fwd_machine.bind(&self.fwd.program, "x", &qx)?;
                stats.add(&self.fwd_machine.run(&self.fwd.program)?);
                self.fwd_machine.read(&self.fwd.program, &out_name)?
            } else {
                let (_, lowered, machine) =
                    self.fwd_rem.as_mut().expect("partial-chunk machine built above");
                machine.bind(&lowered.program, "x", &qx)?;
                stats.add(&machine.run(&lowered.program)?);
                machine.read(&lowered.program, &out_name)?
            };
            for (k, i) in (off..end).enumerate() {
                row.clear();
                row.extend(o[k * out_dim..(k + 1) * out_dim].iter().map(|&q| f.to_f64(q)));
                if argmax(&row) == ds.label(i) {
                    correct += 1;
                }
                seen += 1;
            }
            off = end;
        }
        Ok((correct as f64 / seen.max(1) as f64, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;

    fn spec(dims: &[usize]) -> MlpSpec {
        // Training datapath: Q5.10 with SATURATING narrowing — summed
        // batch gradients exceed the Q range and must clamp, not wrap
        // (DESIGN.md §3; wrap is the paper-accurate ablation mode).
        let fixed = FixedSpec::q(10).saturating();
        MlpSpec::from_dims(
            "t",
            dims,
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    #[test]
    fn trains_blobs_to_high_accuracy() {
        let ds = dataset::blobs(256, 3, 4, 1234);
        let (train, test) = ds.split(0.8, &mut Rng::new(5));
        let s = spec(&[4, 16, 3]);
        let cfg = TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 150, seed: 42, log_every: 10 };
        let mut t = Trainer::new(s, FpgaDevice::selected(), cfg).unwrap();
        let (acc0, _) = t.evaluate(&test).unwrap();
        let report = t.train(&train).unwrap();
        let (acc1, _) = t.evaluate(&test).unwrap();
        assert!(
            acc1 > 0.85 && acc1 > acc0,
            "accuracy before {acc0}, after {acc1}, curve {:?}",
            report.curve
        );
        // loss decreased
        let first = report.curve.first().unwrap().loss;
        let last = report.curve.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} → {last}");
        assert!(report.stats.cycles > 0);
        assert!(report.sim_seconds > 0.0);
    }

    #[test]
    fn dim_mismatch_detected() {
        let ds = dataset::xor(32, 1);
        let s = spec(&[4, 8, 3]);
        let mut t = Trainer::new(s, FpgaDevice::selected(), TrainConfig::default()).unwrap();
        assert!(matches!(t.train(&ds), Err(TrainError::DimMismatch(2, 2, 4, 3))));
    }

    #[test]
    fn checkpoint_roundtrip_restores_training_state() {
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 5, seed: 13, log_every: 1 };
        let ds = dataset::xor(64, 4);
        let mut t = Trainer::new(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        t.train(&ds).unwrap();
        let ckpt = t.checkpoint();
        let bytes = ckpt.to_bytes();
        // a fresh trainer restored from the checkpoint evaluates identically
        let mut t2 = Trainer::new(s, FpgaDevice::selected(), cfg).unwrap();
        t2.restore(crate::nn::checkpoint::Checkpoint::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(t.weights(), t2.weights());
        let (a1, _) = t.evaluate(&ds).unwrap();
        let (a2, _) = t2.evaluate(&ds).unwrap();
        assert_eq!(a1, a2);
    }

    #[test]
    fn weights_persist_across_steps() {
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 32.0, steps: 3, seed: 7, log_every: 1 };
        let mut t = Trainer::new(s, FpgaDevice::selected(), cfg).unwrap();
        let (w0, _) = t.weights();
        let ds = dataset::xor(64, 3);
        t.train(&ds).unwrap();
        let (w1, _) = t.weights();
        assert_ne!(w0, w1, "training did not change weights");
    }
}
