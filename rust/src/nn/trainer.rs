//! The trainer: drives on-device MLP training on a simulated Matrix
//! Machine — the paper's "training phase" (§2), with loss tracking and
//! accuracy evaluation on the forward program ("testing phase").
//!
//! Since the session redesign this type is the **engine behind**
//! [`crate::session::Session`], not a front door of its own:
//! [`Trainer::build`] is what the session layer and the cluster workers
//! construct, [`Trainer::from_parts`] lets the session reuse an
//! artifact's pre-compiled plans, and the deprecated [`Trainer::new`]
//! remains as a thin shim for old callers. All tensor traffic goes
//! through pre-resolved buffer ids (no per-step name lookups).

use super::dataset::{self, Dataset};
use super::float_ref::FloatMlp;
use super::graph::{lower_mlp_forward, lower_mlp_train};
use super::lowering::{LowerError, LoweredMlp};
use super::mlp::MlpSpec;
use crate::hw::machine::MachineError;
use crate::hw::{FpgaDevice, MatrixMachine, RunStats};
use crate::util::Rng;
use std::collections::HashMap;
use thiserror::Error;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Mini-batch size (≤ 512).
    pub batch: usize,
    /// Learning rate (must be representable in the fixed format).
    pub lr: f64,
    /// Training steps.
    pub steps: usize,
    /// RNG seed (weights + batch sampling).
    pub seed: u64,
    /// Record loss every `log_every` steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 300, seed: 0xF00D, log_every: 10 }
    }
}

/// Trainer errors.
#[derive(Debug, Error)]
pub enum TrainError {
    /// Lowering failed.
    #[error("lowering failed: {0}")]
    Lower(#[from] LowerError),
    /// Machine failed.
    #[error("machine error: {0}")]
    Machine(#[from] MachineError),
    /// Dataset/spec dimension mismatch.
    #[error("dataset dim {0}/classes {1} do not match MLP {2}→{3}")]
    DimMismatch(usize, usize, usize, usize),
}

/// One logged training point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossPoint {
    /// Step index.
    pub step: usize,
    /// Mean squared error per sample·output (host-computed, float).
    pub loss: f64,
    /// On-device loss register (Σ(o−y)², quantised; may wrap for large
    /// batches — diagnostic only).
    pub device_loss: f64,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Loss curve.
    pub curve: Vec<LossPoint>,
    /// Aggregated machine statistics.
    pub stats: RunStats,
    /// Simulated wall-clock seconds on the device.
    pub sim_seconds: f64,
    /// Steps executed.
    pub steps: usize,
}

/// One right-sized forward instance of the trainer's batch ladder: the
/// forward program lowered at exactly `lowered.batch` rows plus the
/// machine executing it, with the parameter version it last synced.
struct FwdVariant {
    lowered: LoweredMlp,
    machine: MatrixMachine,
    synced: u64,
}

/// Drives one MLP's training + evaluation on one simulated board.
pub struct Trainer {
    /// Network spec.
    pub spec: MlpSpec,
    /// Board.
    pub device: FpgaDevice,
    /// Config.
    pub cfg: TrainConfig,
    train: LoweredMlp,
    fwd: LoweredMlp,
    train_machine: MatrixMachine,
    fwd_machine: MatrixMachine,
    /// Lazily-lowered forward ladder for row counts other than
    /// `cfg.batch` (the final partial evaluation chunk and the serving
    /// runtime's variable-size `InferChunk` micro-batches): instead of
    /// padding up to `cfg.batch` and paying full-batch compute, a
    /// right-sized plan runs exactly the requested rows (perf pass,
    /// DESIGN.md §Perf/§Serving).
    fwd_variants: HashMap<usize, FwdVariant>,
    /// Bumped whenever the on-device parameters change; forward machines
    /// record the version they last copied, so a steady-state serving
    /// loop of `infer`/`infer_rows` calls copies nothing.
    params_version: u64,
    /// Version the primary forward machine's parameter copies are at.
    fwd_synced: u64,
    rng: Rng,
}

impl Trainer {
    /// Lower programs, compile machines, and initialise weights
    /// (He-scaled, quantised) — the engine constructor used by the
    /// session layer's board target and by every cluster worker.
    pub fn build(
        spec: MlpSpec,
        device: FpgaDevice,
        cfg: TrainConfig,
    ) -> Result<Trainer, TrainError> {
        let train = lower_mlp_train(&spec, cfg.batch, cfg.lr)?;
        let fwd = lower_mlp_forward(&spec, cfg.batch)?;
        let train_machine = MatrixMachine::new(device, &train.program)?;
        let fwd_machine = MatrixMachine::new(device, &fwd.program)?;
        let seed = cfg.seed;
        let mut t =
            Trainer::from_parts(spec, device, cfg, train, fwd, train_machine, fwd_machine);
        t.init_weights(seed)?;
        Ok(t)
    }

    /// Assemble a trainer from pre-lowered programs and pre-built
    /// machines (the artifact plan-reuse path — see
    /// [`crate::session::Artifact`]). Weights are **not** initialised;
    /// call [`Trainer::init_weights`] or [`Trainer::set_weights`].
    pub fn from_parts(
        spec: MlpSpec,
        device: FpgaDevice,
        cfg: TrainConfig,
        train: LoweredMlp,
        fwd: LoweredMlp,
        train_machine: MatrixMachine,
        fwd_machine: MatrixMachine,
    ) -> Trainer {
        debug_assert_eq!(train.program.name, train_machine.program_name());
        debug_assert_eq!(fwd.program.name, fwd_machine.program_name());
        let seed = cfg.seed;
        Trainer {
            spec,
            device,
            cfg,
            train,
            fwd,
            train_machine,
            fwd_machine,
            fwd_variants: HashMap::new(),
            params_version: 1,
            fwd_synced: 0,
            rng: Rng::new(seed),
        }
    }

    /// Legacy front door.
    #[deprecated(note = "construct via `session::{Compiler, Session}` \
                         (or `Trainer::build` for the bare engine)")]
    pub fn new(spec: MlpSpec, device: FpgaDevice, cfg: TrainConfig) -> Result<Trainer, TrainError> {
        Trainer::build(spec, device, cfg)
    }

    /// (Re-)initialise on-device weights from `seed` (He-scaled float
    /// init, quantised) and reset the batch-sampling RNG to the same
    /// stream — bit-identical to what [`Trainer::build`] does.
    pub fn init_weights(&mut self, seed: u64) -> Result<(), TrainError> {
        self.rng = Rng::new(seed);
        let init = FloatMlp::init(&self.spec, &mut self.rng);
        let (qw, qb) = init.quantized();
        self.set_weights(&qw, &qb)
    }

    /// Reset the batch-sampling RNG without touching on-device weights
    /// (used by the session layer when training continues from preloaded
    /// parameters).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    /// Fast-forward the batch sampler past `steps` already-trained steps
    /// (each step draws `cfg.batch` sample indices) without running any
    /// compute — the deterministic checkpoint/resume cursor: a fresh
    /// trainer built from the same seed, restored to a snapshot's
    /// parameters and skipped to its step count, continues the exact
    /// sample stream of the uninterrupted run.
    pub fn skip_steps(&mut self, steps: usize) {
        self.rng.skip(steps as u64 * self.cfg.batch as u64);
    }

    /// Bind explicit weights (e.g. to mirror a float run).
    pub fn set_weights(&mut self, qw: &[Vec<i16>], qb: &[Vec<i16>]) -> Result<(), TrainError> {
        for l in 0..self.spec.layers.len() {
            self.train_machine.write_id(self.train.weights[l], &qw[l])?;
            self.train_machine.write_id(self.train.biases[l], &qb[l])?;
        }
        self.params_version += 1;
        Ok(())
    }

    /// Snapshot the on-device parameters as a [`Checkpoint`].
    ///
    /// [`Checkpoint`]: crate::nn::checkpoint::Checkpoint
    pub fn checkpoint(&self) -> crate::nn::checkpoint::Checkpoint {
        let (w, b) = self.weights();
        let dims: Vec<(usize, usize)> =
            self.spec.layers.iter().map(|l| (l.inputs, l.outputs)).collect();
        crate::nn::checkpoint::Checkpoint::capture(self.spec.fixed, &dims, &w, &b)
    }

    /// Restore parameters from a [`Checkpoint`] (shapes must match).
    ///
    /// [`Checkpoint`]: crate::nn::checkpoint::Checkpoint
    pub fn restore(
        &mut self,
        ckpt: crate::nn::checkpoint::Checkpoint,
    ) -> Result<(), TrainError> {
        let (w, b) = ckpt.into_params();
        self.set_weights(&w, &b)
    }

    /// Current on-device weights.
    pub fn weights(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        let nl = self.spec.layers.len();
        let w = (0..nl)
            .map(|l| self.train_machine.read_id(self.train.weights[l]).to_vec())
            .collect();
        let b = (0..nl)
            .map(|l| self.train_machine.read_id(self.train.biases[l]).to_vec())
            .collect();
        (w, b)
    }

    /// The machine executing the training program (the session layer's
    /// primary machine for typed-handle I/O).
    pub(crate) fn primary_machine(&self) -> &MatrixMachine {
        &self.train_machine
    }

    /// Mutable access to the training machine.
    pub(crate) fn primary_machine_mut(&mut self) -> &mut MatrixMachine {
        &mut self.train_machine
    }

    /// Mark the forward machines' parameter copies stale (the session
    /// layer calls this after writing a weight/bias tensor through a
    /// handle, which bypasses [`Trainer::set_weights`]).
    pub(crate) fn mark_params_dirty(&mut self) {
        self.params_version += 1;
    }

    /// Execute the training program once on the currently bound tensors
    /// (the session layer's raw `step`; parameters mutate on-device).
    pub(crate) fn step_primary(&mut self) -> RunStats {
        self.params_version += 1;
        self.train_machine.execute()
    }

    fn check_dims(&self, ds: &Dataset) -> Result<(), TrainError> {
        if ds.dim() != self.spec.input_dim() || ds.classes != self.spec.output_dim() {
            return Err(TrainError::DimMismatch(
                ds.dim(),
                ds.classes,
                self.spec.input_dim(),
                self.spec.output_dim(),
            ));
        }
        Ok(())
    }

    /// Run `cfg.steps` SGD steps over random mini-batches of `ds`.
    pub fn train(&mut self, ds: &Dataset) -> Result<TrainReport, TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let out_dim = self.spec.output_dim();
        let y_id = self.train.y.expect("training program declares targets");
        let loss_id = self.train.loss.expect("training program declares a loss lane");
        let mut stats = RunStats::default();
        let mut curve = Vec::new();
        let mut ids: Vec<usize> = Vec::with_capacity(batch);
        for step in 0..self.cfg.steps {
            ids.clear();
            for _ in 0..batch {
                ids.push(self.rng.gen_range(ds.len() as u64) as usize);
            }
            let (bx, by) = ds.batch(&ids);
            let qx = f.encode_vec(&bx);
            let qy = f.encode_vec(&by);
            self.train_machine.write_id(self.train.x, &qx)?;
            self.train_machine.write_id(y_id, &qy)?;
            let st = self.train_machine.execute();
            stats.add(&st);
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                // Host-side float loss from the device's output activations.
                let o = self.train_machine.read_id(self.train.out);
                let mut loss = 0.0;
                for (i, &q) in o.iter().enumerate() {
                    let d = f.to_f64(q) - by[i];
                    loss += d * d;
                }
                loss /= (batch * out_dim) as f64;
                let device_loss = f.to_f64(self.train_machine.read_id(loss_id)[0]);
                curve.push(LossPoint { step, loss, device_loss });
            }
        }
        if self.cfg.steps > 0 {
            self.params_version += 1;
        }
        Ok(TrainReport {
            curve,
            stats,
            sim_seconds: stats.seconds(&self.device),
            steps: self.cfg.steps,
        })
    }

    /// Refresh the forward machine's parameters from the training
    /// machine if they are stale.
    fn sync_fwd_params(&mut self) -> Result<(), TrainError> {
        if self.fwd_synced != self.params_version {
            let (qw, qb) = self.weights();
            for l in 0..self.spec.layers.len() {
                self.fwd_machine.write_id(self.fwd.weights[l], &qw[l])?;
                self.fwd_machine.write_id(self.fwd.biases[l], &qb[l])?;
            }
            self.fwd_synced = self.params_version;
        }
        Ok(())
    }

    /// One inference pass over a quantised `cfg.batch × input_dim` batch
    /// with the current on-device weights (used by
    /// [`crate::session::Session::infer`]). Parameters are copied to the
    /// forward machine only when they changed since the last pass.
    pub fn infer(&mut self, qx: &[i16]) -> Result<(Vec<i16>, RunStats), TrainError> {
        self.sync_fwd_params()?;
        self.fwd_machine.write_id(self.fwd.x, qx)?;
        let stats = self.fwd_machine.execute();
        Ok((self.fwd_machine.read_id(self.fwd.out).to_vec(), stats))
    }

    /// One forward pass over a quantised `rows × input_dim` micro-batch:
    /// `rows == cfg.batch` runs the primary forward machine; any other
    /// row count runs a lazily-lowered right-sized variant from the
    /// forward ladder (the serving runtime's `InferChunk` path and the
    /// partial evaluation chunk both land here). Variant parameters are
    /// refreshed only when they changed since the variant's last pass.
    pub fn infer_rows(
        &mut self,
        rows: usize,
        qx: &[i16],
    ) -> Result<(Vec<i16>, RunStats), TrainError> {
        if rows == self.cfg.batch {
            return self.infer(qx);
        }
        if let std::collections::hash_map::Entry::Vacant(slot) = self.fwd_variants.entry(rows) {
            let lowered = lower_mlp_forward(&self.spec, rows)?;
            let machine = MatrixMachine::new(self.device, &lowered.program)?;
            slot.insert(FwdVariant { lowered, machine, synced: 0 });
        }
        if self.fwd_variants[&rows].synced != self.params_version {
            let (qw, qb) = self.weights();
            let version = self.params_version;
            let v = self.fwd_variants.get_mut(&rows).expect("variant built above");
            for l in 0..qw.len() {
                v.machine.write_id(v.lowered.weights[l], &qw[l])?;
                v.machine.write_id(v.lowered.biases[l], &qb[l])?;
            }
            v.synced = version;
        }
        let v = self.fwd_variants.get_mut(&rows).expect("variant built above");
        v.machine.write_id(v.lowered.x, qx)?;
        let stats = v.machine.execute();
        Ok((v.machine.read_id(v.lowered.out).to_vec(), stats))
    }

    /// Classification accuracy of the current weights over `ds` (uses the
    /// forward program — the paper's "testing" phase).
    ///
    /// Chunking comes from [`dataset::chunk_ranges`] (shared with the
    /// session layer and the serving micro-batcher — one chunking rule
    /// for every batched-forward path); the final partial chunk (when
    /// `ds.len() % batch != 0`) runs on a right-sized forward-ladder
    /// variant instead of being padded to the full batch, so no compute
    /// (or cycle charge) is spent on padding rows.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<(f64, RunStats), TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let mut stats = RunStats::default();
        let mut correct = 0usize;
        for r in dataset::chunk_ranges(ds.len(), batch) {
            let qx = ds.encode_rows(r.clone(), f);
            let (out, st) = self.infer_rows(r.len(), &qx)?;
            stats.add(&st);
            correct += ds.count_correct(r, &out, f);
        }
        Ok((correct as f64 / ds.len().max(1) as f64, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;

    fn spec(dims: &[usize]) -> MlpSpec {
        // Training datapath: Q5.10 with SATURATING narrowing — summed
        // batch gradients exceed the Q range and must clamp, not wrap
        // (DESIGN.md §3; wrap is the paper-accurate ablation mode).
        let fixed = FixedSpec::q(10).saturating();
        MlpSpec::from_dims(
            "t",
            dims,
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    #[test]
    fn trains_blobs_to_high_accuracy() {
        let ds = dataset::blobs(256, 3, 4, 1234);
        let (train, test) = ds.split(0.8, &mut Rng::new(5));
        let s = spec(&[4, 16, 3]);
        let cfg = TrainConfig { batch: 16, lr: 1.0 / 256.0, steps: 150, seed: 42, log_every: 10 };
        let mut t = Trainer::build(s, FpgaDevice::selected(), cfg).unwrap();
        let (acc0, _) = t.evaluate(&test).unwrap();
        let report = t.train(&train).unwrap();
        let (acc1, _) = t.evaluate(&test).unwrap();
        assert!(
            acc1 > 0.85 && acc1 > acc0,
            "accuracy before {acc0}, after {acc1}, curve {:?}",
            report.curve
        );
        // loss decreased
        let first = report.curve.first().unwrap().loss;
        let last = report.curve.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} → {last}");
        assert!(report.stats.cycles > 0);
        assert!(report.sim_seconds > 0.0);
    }

    #[test]
    fn dim_mismatch_detected() {
        let ds = dataset::xor(32, 1);
        let s = spec(&[4, 8, 3]);
        let mut t = Trainer::build(s, FpgaDevice::selected(), TrainConfig::default()).unwrap();
        assert!(matches!(t.train(&ds), Err(TrainError::DimMismatch(2, 2, 4, 3))));
    }

    #[test]
    fn checkpoint_roundtrip_restores_training_state() {
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 5, seed: 13, log_every: 1 };
        let ds = dataset::xor(64, 4);
        let mut t = Trainer::build(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        t.train(&ds).unwrap();
        let ckpt = t.checkpoint();
        let bytes = ckpt.to_bytes();
        // a fresh trainer restored from the checkpoint evaluates identically
        let mut t2 = Trainer::build(s, FpgaDevice::selected(), cfg).unwrap();
        t2.restore(crate::nn::checkpoint::Checkpoint::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(t.weights(), t2.weights());
        let (a1, _) = t.evaluate(&ds).unwrap();
        let (a2, _) = t2.evaluate(&ds).unwrap();
        assert_eq!(a1, a2);
    }

    #[test]
    fn weights_persist_across_steps() {
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 32.0, steps: 3, seed: 7, log_every: 1 };
        let mut t = Trainer::build(s, FpgaDevice::selected(), cfg).unwrap();
        let (w0, _) = t.weights();
        let ds = dataset::xor(64, 3);
        t.train(&ds).unwrap();
        let (w1, _) = t.weights();
        assert_ne!(w0, w1, "training did not change weights");
    }

    #[test]
    fn infer_matches_evaluate_numerics() {
        // infer() on a batch of test rows must score exactly like
        // evaluate() does on the same rows.
        let s = spec(&[2, 8, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 30, seed: 3, log_every: 10 };
        let ds = dataset::xor(64, 9);
        let mut t = Trainer::build(s.clone(), FpgaDevice::selected(), cfg).unwrap();
        t.train(&ds).unwrap();
        let f = s.fixed;
        let qx = ds.encode_rows(0..8, f);
        let (out, stats) = t.infer(&qx).unwrap();
        assert_eq!(out.len(), 8 * s.output_dim());
        assert!(stats.cycles > 0);
        let correct = ds.count_correct(0..8, &out, f);
        let (acc, _) = t.evaluate(&ds).unwrap();
        // consistency: the full-dataset accuracy counts these same rows
        // the same way; spot-check infer's chunk is plausible.
        assert!(correct <= 8);
        assert!(acc >= 0.0);
    }

    #[test]
    fn infer_reflects_weight_updates() {
        // The params-dirty tracking must never serve stale parameters:
        // a set_weights between infers has to be visible immediately.
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 4, lr: 1.0 / 64.0, steps: 0, seed: 2, log_every: 1 };
        let mut t = Trainer::build(s.clone(), FpgaDevice::selected(), cfg).unwrap();
        let qx = vec![512i16; 4 * 2];
        let (o1, _) = t.infer(&qx).unwrap();
        let (o1b, _) = t.infer(&qx).unwrap();
        assert_eq!(o1, o1b, "steady-state infer must be deterministic");
        let zw: Vec<Vec<i16>> =
            s.layers.iter().map(|l| vec![0i16; l.inputs * l.outputs]).collect();
        let zb: Vec<Vec<i16>> = s.layers.iter().map(|l| vec![0i16; l.outputs]).collect();
        t.set_weights(&zw, &zb).unwrap();
        let (o2, _) = t.infer(&qx).unwrap();
        assert!(
            o2.iter().all(|&v| v == 0),
            "stale parameters served after set_weights: {o2:?}"
        );
    }

    #[test]
    fn infer_rows_matches_primary_batch_bit_exactly() {
        // A 4-row batch through the primary forward machine must equal
        // the same rows served as 3-row + 1-row ladder variants: forward
        // lanes are per-row, so micro-batch size never changes a bit.
        let s = spec(&[2, 6, 2]);
        let cfg = TrainConfig { batch: 4, lr: 1.0 / 128.0, steps: 10, seed: 21, log_every: 5 };
        let ds = dataset::xor(64, 6);
        let mut t = Trainer::build(s.clone(), FpgaDevice::selected(), cfg).unwrap();
        t.train(&ds).unwrap();
        let f = s.fixed;
        let qx = ds.encode_rows(0..4, f);
        let (full, _) = t.infer(&qx).unwrap();
        let (head, _) = t.infer_rows(3, &ds.encode_rows(0..3, f)).unwrap();
        let (tail, _) = t.infer_rows(1, &ds.encode_rows(3..4, f)).unwrap();
        assert_eq!([head, tail].concat(), full);
        // ladder variants must observe weight updates immediately
        let zw: Vec<Vec<i16>> =
            s.layers.iter().map(|l| vec![0i16; l.inputs * l.outputs]).collect();
        let zb: Vec<Vec<i16>> = s.layers.iter().map(|l| vec![0i16; l.outputs]).collect();
        t.set_weights(&zw, &zb).unwrap();
        let (o, _) = t.infer_rows(3, &ds.encode_rows(0..3, f)).unwrap();
        assert!(o.iter().all(|&v| v == 0), "stale ladder variant served: {o:?}");
    }

    #[test]
    fn skip_steps_fast_forwards_the_sample_stream_bit_exactly() {
        // Train 7 steps straight vs train 3, snapshot, restore into a
        // fresh trainer skipped to step 3, train 4 more: identical
        // weights — the resume primitive under cluster checkpointing.
        let s = spec(&[2, 6, 2]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 7, seed: 31, log_every: 2 };
        let ds = dataset::xor(64, 8);
        let mut straight = Trainer::build(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        straight.train(&ds).unwrap();

        let mut head = Trainer::build(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        head.cfg.steps = 3;
        head.train(&ds).unwrap();
        let (w3, b3) = head.weights();

        let mut resumed = Trainer::build(s, FpgaDevice::selected(), cfg).unwrap();
        resumed.set_weights(&w3, &b3).unwrap();
        resumed.skip_steps(3);
        resumed.cfg.steps = 4;
        resumed.train(&ds).unwrap();
        assert_eq!(resumed.weights(), straight.weights(), "resume diverged");
    }

    #[test]
    fn deprecated_new_shim_matches_build() {
        let s = spec(&[2, 4, 2]);
        let cfg = TrainConfig { batch: 4, lr: 1.0 / 64.0, steps: 2, seed: 11, log_every: 1 };
        #[allow(deprecated)]
        let t1 = Trainer::new(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        let t2 = Trainer::build(s, FpgaDevice::selected(), cfg).unwrap();
        assert_eq!(t1.weights(), t2.weights());
    }
}
