//! The graph trainer: drives on-device training and inference of an
//! operator-graph net ([`GraphSpec`]) on a simulated Matrix Machine —
//! the graph twin of [`crate::nn::trainer::Trainer`], sharing its
//! [`TrainConfig`]/[`TrainError`]/[`TrainReport`] surface so the
//! session layer can hold either engine behind one API.
//!
//! Parameters are `(weights, bias)` pairs in [`GraphSpec::param_decls`]
//! order (the only difference from the MLP trainer, whose parameters
//! are per-layer by construction). Everything else — the batch-ladder
//! forward variants, the params-version dirty tracking, the
//! deterministic batch-sampling RNG with `skip_steps` resume — is
//! behaviourally identical, and for an `MlpSpec::to_graph` net the
//! lowered programs are bit-identical too.

use super::float::FloatGraph;
use super::ir::GraphSpec;
use super::lower::{lower_graph_forward, lower_graph_train};
use crate::nn::dataset::{self, Dataset};
use crate::nn::lowering::LoweredMlp;
use crate::nn::trainer::{LossPoint, TrainConfig, TrainError, TrainReport};
use crate::hw::{FpgaDevice, MatrixMachine, RunStats};
use crate::util::Rng;
use std::collections::HashMap;

/// One right-sized forward instance of the graph trainer's batch
/// ladder (see [`crate::nn::trainer::Trainer::infer_rows`]).
struct FwdVariant {
    lowered: LoweredMlp,
    machine: MatrixMachine,
    synced: u64,
}

/// Drives one operator-graph net's training + evaluation on one
/// simulated board.
pub struct GraphTrainer {
    /// Network graph.
    pub spec: GraphSpec,
    /// Board.
    pub device: FpgaDevice,
    /// Config.
    pub cfg: TrainConfig,
    train: LoweredMlp,
    fwd: LoweredMlp,
    train_machine: MatrixMachine,
    fwd_machine: MatrixMachine,
    fwd_variants: HashMap<usize, FwdVariant>,
    params_version: u64,
    fwd_synced: u64,
    rng: Rng,
}

impl GraphTrainer {
    /// Lower programs, compile machines, and initialise parameters
    /// (He-scaled, quantised).
    pub fn build(
        spec: GraphSpec,
        device: FpgaDevice,
        cfg: TrainConfig,
    ) -> Result<GraphTrainer, TrainError> {
        let train = lower_graph_train(&spec, cfg.batch, cfg.lr)?;
        let fwd = lower_graph_forward(&spec, cfg.batch)?;
        let train_machine = MatrixMachine::new(device, &train.program)?;
        let fwd_machine = MatrixMachine::new(device, &fwd.program)?;
        let seed = cfg.seed;
        let mut t =
            GraphTrainer::from_parts(spec, device, cfg, train, fwd, train_machine, fwd_machine);
        t.init_params(seed)?;
        Ok(t)
    }

    /// Assemble from pre-lowered programs and pre-built machines (the
    /// artifact plan-reuse path). Parameters are **not** initialised;
    /// call [`GraphTrainer::init_params`] or [`GraphTrainer::set_params`].
    pub fn from_parts(
        spec: GraphSpec,
        device: FpgaDevice,
        cfg: TrainConfig,
        train: LoweredMlp,
        fwd: LoweredMlp,
        train_machine: MatrixMachine,
        fwd_machine: MatrixMachine,
    ) -> GraphTrainer {
        debug_assert_eq!(train.program.name, train_machine.program_name());
        debug_assert_eq!(fwd.program.name, fwd_machine.program_name());
        let seed = cfg.seed;
        GraphTrainer {
            spec,
            device,
            cfg,
            train,
            fwd,
            train_machine,
            fwd_machine,
            fwd_variants: HashMap::new(),
            params_version: 1,
            fwd_synced: 0,
            rng: Rng::new(seed),
        }
    }

    /// (Re-)initialise on-device parameters from `seed` (He-scaled
    /// float init, quantised) and reset the batch-sampling RNG to the
    /// same stream.
    pub fn init_params(&mut self, seed: u64) -> Result<(), TrainError> {
        self.rng = Rng::new(seed);
        let init = FloatGraph::init(&self.spec, &mut self.rng);
        self.set_params(&init.quantized())
    }

    /// Reset the batch-sampling RNG without touching on-device
    /// parameters.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    /// Fast-forward the batch sampler past `steps` already-trained
    /// steps (the deterministic resume cursor — see
    /// [`crate::nn::trainer::Trainer::skip_steps`]).
    pub fn skip_steps(&mut self, steps: usize) {
        self.rng.skip(steps as u64 * self.cfg.batch as u64);
    }

    /// Bind explicit parameters: one `(weights, biases)` pair per
    /// [`GraphSpec::param_decls`] entry.
    pub fn set_params(&mut self, params: &[(Vec<i16>, Vec<i16>)]) -> Result<(), TrainError> {
        for (i, (w, b)) in params.iter().enumerate() {
            self.train_machine.write_id(self.train.weights[i], w)?;
            self.train_machine.write_id(self.train.biases[i], b)?;
        }
        self.params_version += 1;
        Ok(())
    }

    /// Current on-device parameters, in decl order.
    pub fn params(&self) -> Vec<(Vec<i16>, Vec<i16>)> {
        self.train
            .weights
            .iter()
            .zip(&self.train.biases)
            .map(|(&w, &b)| {
                (self.train_machine.read_id(w).to_vec(), self.train_machine.read_id(b).to_vec())
            })
            .collect()
    }

    /// Current on-device parameters split into parallel weight/bias
    /// lists (the session layer's `weights()` shape).
    pub fn weights(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        self.params().into_iter().unzip()
    }

    /// The machine executing the training program (typed-handle I/O).
    pub(crate) fn primary_machine(&self) -> &MatrixMachine {
        &self.train_machine
    }

    /// Mutable access to the training machine.
    pub(crate) fn primary_machine_mut(&mut self) -> &mut MatrixMachine {
        &mut self.train_machine
    }

    /// Mark the forward machines' parameter copies stale (after a
    /// direct handle write bypassed [`GraphTrainer::set_params`]).
    pub(crate) fn mark_params_dirty(&mut self) {
        self.params_version += 1;
    }

    /// Execute the training program once on the currently bound
    /// tensors (parameters mutate on-device).
    pub(crate) fn step_primary(&mut self) -> RunStats {
        self.params_version += 1;
        self.train_machine.execute()
    }

    fn check_dims(&self, ds: &Dataset) -> Result<(), TrainError> {
        if ds.dim() != self.spec.input_dim() || ds.classes != self.spec.output_dim() {
            return Err(TrainError::DimMismatch(
                ds.dim(),
                ds.classes,
                self.spec.input_dim(),
                self.spec.output_dim(),
            ));
        }
        Ok(())
    }

    /// Run `cfg.steps` SGD steps over random mini-batches of `ds` —
    /// the same loop (and the same sample stream for the same seed) as
    /// the MLP trainer.
    pub fn train(&mut self, ds: &Dataset) -> Result<TrainReport, TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let out_dim = self.spec.output_dim();
        let y_id = self.train.y.expect("training program declares targets");
        let loss_id = self.train.loss.expect("training program declares a loss lane");
        let mut stats = RunStats::default();
        let mut curve = Vec::new();
        let mut ids: Vec<usize> = Vec::with_capacity(batch);
        for step in 0..self.cfg.steps {
            ids.clear();
            for _ in 0..batch {
                ids.push(self.rng.gen_range(ds.len() as u64) as usize);
            }
            let (bx, by) = ds.batch(&ids);
            let qx = f.encode_vec(&bx);
            let qy = f.encode_vec(&by);
            self.train_machine.write_id(self.train.x, &qx)?;
            self.train_machine.write_id(y_id, &qy)?;
            let st = self.train_machine.execute();
            stats.add(&st);
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                let o = self.train_machine.read_id(self.train.out);
                let mut loss = 0.0;
                for (i, &q) in o.iter().enumerate() {
                    let d = f.to_f64(q) - by[i];
                    loss += d * d;
                }
                loss /= (batch * out_dim) as f64;
                let device_loss = f.to_f64(self.train_machine.read_id(loss_id)[0]);
                curve.push(LossPoint { step, loss, device_loss });
            }
        }
        if self.cfg.steps > 0 {
            self.params_version += 1;
        }
        Ok(TrainReport {
            curve,
            stats,
            sim_seconds: stats.seconds(&self.device),
            steps: self.cfg.steps,
        })
    }

    /// Refresh the forward machine's parameters if stale.
    fn sync_fwd_params(&mut self) -> Result<(), TrainError> {
        if self.fwd_synced != self.params_version {
            for (i, (w, b)) in self.params().iter().enumerate() {
                self.fwd_machine.write_id(self.fwd.weights[i], w)?;
                self.fwd_machine.write_id(self.fwd.biases[i], b)?;
            }
            self.fwd_synced = self.params_version;
        }
        Ok(())
    }

    /// One inference pass over a quantised `cfg.batch × input_dim`
    /// batch with the current on-device parameters.
    pub fn infer(&mut self, qx: &[i16]) -> Result<(Vec<i16>, RunStats), TrainError> {
        self.sync_fwd_params()?;
        self.fwd_machine.write_id(self.fwd.x, qx)?;
        let stats = self.fwd_machine.execute();
        Ok((self.fwd_machine.read_id(self.fwd.out).to_vec(), stats))
    }

    /// One forward pass over a quantised `rows × input_dim`
    /// micro-batch via the lazily-lowered forward batch ladder (the
    /// serving runtime's variable-size micro-batch path — every graph
    /// op maps rows independently, so micro-batch size never changes a
    /// bit of any row's output).
    pub fn infer_rows(
        &mut self,
        rows: usize,
        qx: &[i16],
    ) -> Result<(Vec<i16>, RunStats), TrainError> {
        if rows == self.cfg.batch {
            return self.infer(qx);
        }
        if let std::collections::hash_map::Entry::Vacant(slot) = self.fwd_variants.entry(rows) {
            let lowered = lower_graph_forward(&self.spec, rows)?;
            let machine = MatrixMachine::new(self.device, &lowered.program)?;
            slot.insert(FwdVariant { lowered, machine, synced: 0 });
        }
        if self.fwd_variants[&rows].synced != self.params_version {
            let params = self.params();
            let version = self.params_version;
            let v = self.fwd_variants.get_mut(&rows).expect("variant built above");
            for (i, (w, b)) in params.iter().enumerate() {
                v.machine.write_id(v.lowered.weights[i], w)?;
                v.machine.write_id(v.lowered.biases[i], b)?;
            }
            v.synced = version;
        }
        let v = self.fwd_variants.get_mut(&rows).expect("variant built above");
        v.machine.write_id(v.lowered.x, qx)?;
        let stats = v.machine.execute();
        Ok((v.machine.read_id(v.lowered.out).to_vec(), stats))
    }

    /// Classification accuracy of the current parameters over `ds`
    /// (forward program only; chunking shared with every batched
    /// forward path via [`dataset::chunk_ranges`]).
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<(f64, RunStats), TrainError> {
        self.check_dims(ds)?;
        let f = self.spec.fixed;
        let batch = self.cfg.batch;
        let mut stats = RunStats::default();
        let mut correct = 0usize;
        for r in dataset::chunk_ranges(ds.len(), batch) {
            let qx = ds.encode_rows(r.clone(), f);
            let (out, st) = self.infer_rows(r.len(), &qx)?;
            stats.add(&st);
            correct += ds.count_correct(r, &out, f);
        }
        Ok((correct as f64 / ds.len().max(1) as f64, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::graph::ir::INPUT;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::{LutParams, MlpSpec};
    use crate::nn::trainer::Trainer;

    fn fixed() -> FixedSpec {
        FixedSpec::q(10).saturating()
    }

    fn mlp(dims: &[usize]) -> MlpSpec {
        MlpSpec::from_dims(
            "gt",
            dims,
            ActKind::Relu,
            ActKind::Identity,
            fixed(),
            LutParams::training(fixed()),
        )
        .unwrap()
    }

    #[test]
    fn graph_trainer_matches_mlp_trainer_bit_exactly() {
        // An MlpSpec trained through the legacy Trainer and its
        // to_graph() twin trained through GraphTrainer must produce
        // identical parameters, loss curves, and evaluations: the
        // graph path's programs are bit-identical, the sample streams
        // share one RNG recipe, and the init draws the same weights
        // in the same order.
        let ds = dataset::blobs(128, 3, 4, 77);
        let s = mlp(&[4, 12, 3]);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 256.0, steps: 40, seed: 9, log_every: 10 };
        let mut t = Trainer::build(s.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        let mut g = GraphTrainer::build(s.to_graph(), FpgaDevice::selected(), cfg).unwrap();
        let (tw, tb) = t.weights();
        let (gw, gb) = g.weights();
        assert_eq!((tw, tb), (gw, gb), "init diverged");
        let rt = t.train(&ds).unwrap();
        let rg = g.train(&ds).unwrap();
        assert_eq!(t.weights(), g.weights(), "training diverged");
        assert_eq!(rt.curve, rg.curve, "loss curves diverged");
        assert_eq!(rt.stats.cycles, rg.stats.cycles, "cycle counts diverged");
        let (at, _) = t.evaluate(&ds).unwrap();
        let (ag, _) = g.evaluate(&ds).unwrap();
        assert_eq!(at, ag, "evaluation diverged");
    }

    #[test]
    fn residual_net_trains_and_infers() {
        // linear → relu → linear, with a residual add around the
        // middle: trains to better-than-chance on blobs and infers
        // deterministically row-by-row.
        let mut spec = GraphSpec::new("res", 4, fixed(), LutParams::training(fixed()));
        let h = spec.linear(INPUT, 8);
        let a = spec.activation(h, ActKind::Relu);
        let r = spec.add(a, h);
        spec.linear(r, 3);
        let ds = dataset::blobs(192, 3, 4, 55);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 256.0, steps: 120, seed: 4, log_every: 20 };
        let mut g = GraphTrainer::build(spec, FpgaDevice::selected(), cfg).unwrap();
        let (acc0, _) = g.evaluate(&ds).unwrap();
        g.train(&ds).unwrap();
        let (acc1, _) = g.evaluate(&ds).unwrap();
        assert!(acc1 > 0.6 && acc1 >= acc0, "accuracy {acc0} → {acc1}");
        // infer_rows ladder equals the primary batch bit-exactly
        let f = g.spec.fixed;
        let qx = ds.encode_rows(0..8, f);
        let (full, _) = g.infer(&qx).unwrap();
        let (head, _) = g.infer_rows(5, &ds.encode_rows(0..5, f)).unwrap();
        let (tail, _) = g.infer_rows(3, &ds.encode_rows(5..8, f)).unwrap();
        assert_eq!([head, tail].concat(), full);
    }

    #[test]
    fn set_params_is_visible_immediately() {
        let mut spec = GraphSpec::new("z", 2, fixed(), LutParams::training(fixed()));
        let h = spec.linear(INPUT, 4);
        let a = spec.activation(h, ActKind::Relu);
        spec.linear(a, 2);
        let cfg = TrainConfig { batch: 4, lr: 1.0 / 64.0, steps: 0, seed: 2, log_every: 1 };
        let mut g = GraphTrainer::build(spec, FpgaDevice::selected(), cfg).unwrap();
        let qx = vec![512i16; 4 * 2];
        let (o1, _) = g.infer(&qx).unwrap();
        let (o1b, _) = g.infer(&qx).unwrap();
        assert_eq!(o1, o1b, "steady-state infer must be deterministic");
        let zero: Vec<(Vec<i16>, Vec<i16>)> = g
            .params()
            .into_iter()
            .map(|(w, b)| (vec![0; w.len()], vec![0; b.len()]))
            .collect();
        g.set_params(&zero).unwrap();
        let (o2, _) = g.infer(&qx).unwrap();
        assert!(o2.iter().all(|&v| v == 0), "stale parameters served: {o2:?}");
    }

    #[test]
    fn skip_steps_resumes_bit_exactly() {
        let mut spec = GraphSpec::new("rs", 2, fixed(), LutParams::training(fixed()));
        let h = spec.linear(INPUT, 6);
        let a = spec.activation(h, ActKind::Relu);
        spec.linear(a, 2);
        let ds = dataset::xor(64, 8);
        let cfg = TrainConfig { batch: 8, lr: 1.0 / 128.0, steps: 7, seed: 31, log_every: 2 };
        let mut straight =
            GraphTrainer::build(spec.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        straight.train(&ds).unwrap();

        let mut head =
            GraphTrainer::build(spec.clone(), FpgaDevice::selected(), cfg.clone()).unwrap();
        head.cfg.steps = 3;
        head.train(&ds).unwrap();
        let at3 = head.params();

        let mut resumed = GraphTrainer::build(spec, FpgaDevice::selected(), cfg).unwrap();
        resumed.set_params(&at3).unwrap();
        resumed.skip_steps(3);
        resumed.cfg.steps = 4;
        resumed.train(&ds).unwrap();
        assert_eq!(resumed.params(), straight.params(), "resume diverged");
    }
}
