//! Per-op lowering of a [`GraphSpec`] onto the MVM/ActPro vector ISA.
//!
//! The pass works over **units**: a `Linear` (or `Conv2d`) immediately
//! followed by its only consumer, an `Activation`, fuses into one dense
//! unit that is emitted exactly like a legacy `MlpSpec` layer — chunked
//! dots, segment-wise bias add, segment-wise activation. That fusion is
//! what makes [`lower_mlp_forward`]/[`lower_mlp_train`] emit programs
//! **bit-identical** to `nn::lowering`'s frozen legacy emission
//! (asserted in the tests here and in `rust/tests/graph.rs`): same
//! buffer names in the same declaration order, same LUT registration
//! order, same wave-for-wave schedule.
//!
//! Backward recipes (see DESIGN.md §Operator IR for the contract):
//!
//! * dense / conv-as-dense: the legacy backprop schedule (deriv LUT,
//!   gradient dots over batch columns, delta dots over weight rows,
//!   in-place SGD update);
//! * `ElemAdd` routes δ to both inputs, `ElemMul` cross-multiplies;
//! * `Normalization` is straight-through scaled by the saved `1/σ`
//!   (the Jacobian's mean/variance terms are dropped — documented
//!   approximation);
//! * `Attention` freezes the softmax scores: `Wv/Wo` (and biases) get
//!   exact gradients through `A = P·V`, `Wq/Wk` are not updated —
//!   documented approximation, keeps the whole step on-device;
//! * `Conv2d` trains only when it reads the graph input (there is no
//!   col2im delta path), surfaced as a typed error otherwise.
//!
//! Values consumed by more than one op get their deltas accumulated:
//! the first contribution overwrites the delta buffer (device state
//! persists across steps, so every buffer must be fully written before
//! being read), later contributions go through a scratch buffer and a
//! `VECTOR_ADDITION`.

use super::ir::{Conv2dGeom, GraphSpec, OpKind, ValueId, INPUT};
use crate::assembler::program::{BufId, BufKind, LaneOp, Program, Step, View};
use crate::fixed::FixedSpec;
use crate::hw::COLUMN_LEN;
use crate::isa::Opcode;
use crate::nn::lowering::{col, lane, row, segments, Ctx, LowerError, LoweredMlp};
use crate::nn::lut::ActKind;
use crate::nn::mlp::{LutParams, MlpSpec};

// ---------------------------------------------------------------------
// Units: ops after Linear/Conv2d + Activation fusion.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum UnitKind {
    /// `Linear` (+ optionally its fused activation and the activation's
    /// per-kind naming counter, so `o{j}` matches the legacy layout).
    Dense { n_out: usize, act: Option<(ActKind, usize)> },
    /// `Conv2d` (+ optionally its fused activation).
    Conv { geom: Conv2dGeom, act: Option<ActKind> },
    /// A standalone activation.
    Act { act: ActKind },
    Add,
    Mul,
    Norm { cols: usize },
    Attn { seq: usize, d: usize },
}

#[derive(Debug, Clone)]
struct Unit {
    kind: UnitKind,
    /// Index of the unit's first op (names errors, keys param decls).
    op: usize,
    /// Input values of the first op.
    ins: Vec<ValueId>,
    /// Output value (the fused activation's value when fused).
    out: ValueId,
    /// Per-kind naming counter (`z{tag}`, `cz{tag}`, `add{tag}`, …).
    tag: usize,
}

fn build_units(g: &GraphSpec) -> Vec<Unit> {
    let mut consumers = vec![0usize; g.ops.len() + 1];
    for op in &g.ops {
        for &v in &op.ins {
            consumers[v] += 1;
        }
    }
    let mut units = Vec::new();
    let (mut nl, mut na, mut nc, mut nat) = (0usize, 0usize, 0usize, 0usize);
    let (mut nadd, mut nmul, mut nnorm) = (0usize, 0usize, 0usize);
    let mut i = 0;
    while i < g.ops.len() {
        let op = &g.ops[i];
        // A Linear/Conv2d whose value is consumed only by the very next
        // op, an Activation, fuses into one dense unit — the legacy
        // layer shape.
        let fused = match g.ops.get(i + 1) {
            Some(next) if matches!(op.kind, OpKind::Linear { .. } | OpKind::Conv2d(_)) => {
                match next.kind {
                    OpKind::Activation { act }
                        if next.ins.len() == 1 && next.ins[0] == i + 1 && consumers[i + 1] == 1 =>
                    {
                        Some(act)
                    }
                    _ => None,
                }
            }
            _ => None,
        };
        match op.kind {
            OpKind::Linear { outputs } => {
                let act = fused.map(|a| {
                    let pair = (a, na);
                    na += 1;
                    pair
                });
                let span = if act.is_some() { 2 } else { 1 };
                units.push(Unit {
                    kind: UnitKind::Dense { n_out: outputs, act },
                    op: i,
                    ins: op.ins.clone(),
                    out: i + span,
                    tag: nl,
                });
                nl += 1;
                i += span;
            }
            OpKind::Conv2d(geom) => {
                if fused.is_some() {
                    na += 1;
                }
                let span = if fused.is_some() { 2 } else { 1 };
                units.push(Unit {
                    kind: UnitKind::Conv { geom, act: fused },
                    op: i,
                    ins: op.ins.clone(),
                    out: i + span,
                    tag: nc,
                });
                nc += 1;
                i += span;
            }
            OpKind::Activation { act } => {
                units.push(Unit {
                    kind: UnitKind::Act { act },
                    op: i,
                    ins: op.ins.clone(),
                    out: i + 1,
                    tag: na,
                });
                na += 1;
                i += 1;
            }
            OpKind::ElemAdd => {
                units.push(Unit {
                    kind: UnitKind::Add,
                    op: i,
                    ins: op.ins.clone(),
                    out: i + 1,
                    tag: nadd,
                });
                nadd += 1;
                i += 1;
            }
            OpKind::ElemMul => {
                units.push(Unit {
                    kind: UnitKind::Mul,
                    op: i,
                    ins: op.ins.clone(),
                    out: i + 1,
                    tag: nmul,
                });
                nmul += 1;
                i += 1;
            }
            OpKind::Normalization { cols } => {
                units.push(Unit {
                    kind: UnitKind::Norm { cols },
                    op: i,
                    ins: op.ins.clone(),
                    out: i + 1,
                    tag: nnorm,
                });
                nnorm += 1;
                i += 1;
            }
            OpKind::Attention { seq, d } => {
                units.push(Unit {
                    kind: UnitKind::Attn { seq, d },
                    op: i,
                    ins: op.ins.clone(),
                    out: i + 1,
                    tag: nat,
                });
                nat += 1;
                i += 1;
            }
        }
    }
    units
}

// ---------------------------------------------------------------------
// Declaration.
// ---------------------------------------------------------------------

struct Net {
    dims: Vec<usize>,
    units: Vec<Unit>,
    decls: Vec<super::ir::ParamDecl>,
    /// Param pairs aligned with `decls`.
    params: Vec<(BufId, BufId)>,
    /// Buffer per value id (value 0 is `x`).
    val_buf: Vec<BufId>,
    x: BufId,
    y: Option<BufId>,
    out: BufId,
}

fn params_for(net: &Net, op: usize) -> Vec<(BufId, BufId)> {
    net.decls
        .iter()
        .zip(&net.params)
        .filter(|(d, _)| d.op == op)
        .map(|(_, &p)| p)
        .collect()
}

/// Declare `x`, parameters, per-value buffers, and (for training) `y` —
/// in exactly the legacy order so MLP chains stay bit-identical.
fn declare_graph(ctx: &mut Ctx, g: &GraphSpec, batch: usize, train: bool) -> Result<Net, LowerError> {
    let dims = g.value_dims()?;
    let units = build_units(g);
    let decls = g.param_decls()?;
    let last = g.ops.len();
    let p = &mut ctx.p;
    let x = p.buffer("x", batch, dims[0], BufKind::Input);
    let mut params = Vec::with_capacity(decls.len());
    for d in &decls {
        let w = p.buffer(&d.wname, d.rows, d.cols, BufKind::Weight);
        let b = p.buffer(&d.bname, d.cols, 1, BufKind::Bias);
        params.push((w, b));
    }
    let out_kind = |v: ValueId| if v == last { BufKind::Output } else { BufKind::Temp };
    let mut val_buf = vec![x];
    for u in &units {
        match u.kind {
            UnitKind::Dense { n_out, act } => {
                let zk = if act.is_some() { BufKind::Temp } else { out_kind(u.out) };
                val_buf.push(p.buffer(&format!("z{}", u.tag), batch, n_out, zk));
                if let Some((_, atag)) = act {
                    val_buf.push(p.buffer(&format!("o{atag}"), batch, n_out, out_kind(u.out)));
                }
            }
            UnitKind::Conv { geom, act } => {
                let od = geom.out_dim();
                let zk = if act.is_some() { BufKind::Temp } else { out_kind(u.out) };
                val_buf.push(p.buffer(&format!("cz{}", u.tag), batch, od, zk));
                if act.is_some() {
                    val_buf.push(p.buffer(&format!("co{}", u.tag), batch, od, out_kind(u.out)));
                }
            }
            UnitKind::Act { .. } => {
                val_buf.push(p.buffer(&format!("o{}", u.tag), batch, dims[u.out], out_kind(u.out)));
            }
            UnitKind::Add => {
                val_buf.push(p.buffer(&format!("add{}", u.tag), batch, dims[u.out], out_kind(u.out)));
            }
            UnitKind::Mul => {
                val_buf.push(p.buffer(&format!("mul{}", u.tag), batch, dims[u.out], out_kind(u.out)));
            }
            UnitKind::Norm { .. } => {
                val_buf.push(p.buffer(&format!("nrm{}", u.tag), batch, dims[u.out], out_kind(u.out)));
            }
            UnitKind::Attn { .. } => {
                val_buf.push(p.buffer(&format!("att{}", u.tag), batch, dims[u.out], out_kind(u.out)));
            }
        }
    }
    let out = *val_buf.last().unwrap();
    let y = if train { Some(p.buffer("y", batch, dims[last], BufKind::Target)) } else { None };
    Ok(Net { dims, units, decls, params, val_buf, x, y, out })
}

// ---------------------------------------------------------------------
// Forward emission.
// ---------------------------------------------------------------------

/// The legacy dense-layer emission, parametrised so conv's im2col
/// matrix can ride it too: chunked dots over the fan-in, a lazy partial
/// accumulator, segment-wise bias add, optional segment-wise
/// activation. Wave order and views match `nn::lowering::emit_forward`
/// exactly (`rows` is the batch there).
#[allow(clippy::too_many_arguments)]
fn emit_dense_core(
    ctx: &mut Ctx,
    fixed: FixedSpec,
    lp: LutParams,
    input: BufId,
    n_in: usize,
    rows: usize,
    w: BufId,
    bias: BufId,
    z: BufId,
    n_out: usize,
    act: Option<(ActKind, BufId)>,
    partial: &str,
) {
    let in_chunks = segments(n_in);
    for (ci, &(c_off, c_len)) in in_chunks.iter().enumerate() {
        let dest = if ci == 0 {
            z
        } else {
            ctx.p
                .buffer_named(partial)
                .unwrap_or_else(|| ctx.p.buffer(partial, rows, n_out, BufKind::Temp))
        };
        let mut lanes = Vec::with_capacity(rows * n_out);
        for bi in 0..rows {
            for j in 0..n_out {
                lanes.push(LaneOp {
                    a: View::contiguous(input, bi * n_in + c_off, c_len),
                    b: Some(View { buf: w, offset: c_off * n_out + j, len: c_len, stride: n_out }),
                    out: lane(dest, bi * n_out + j),
                });
            }
        }
        ctx.wave(Opcode::VectorDotProduct, c_len, lanes);
        if ci > 0 {
            // z += partial, segment-wise
            for &(s_off, s_len) in &segments(n_out) {
                let lanes = (0..rows)
                    .map(|bi| LaneOp {
                        a: View::contiguous(z, bi * n_out + s_off, s_len),
                        b: Some(View::contiguous(dest, bi * n_out + s_off, s_len)),
                        out: View::contiguous(z, bi * n_out + s_off, s_len),
                    })
                    .collect();
                ctx.wave(Opcode::VectorAddition, s_len, lanes);
            }
        }
    }
    // z row += bias; o = A(z) — segment-wise over wide outputs. The LUT
    // is registered before the bias waves, matching the legacy order.
    let lut = act.map(|(kind, _)| ctx.lut_for(fixed, lp, kind, false));
    for &(s_off, s_len) in &segments(n_out) {
        let lanes = (0..rows)
            .map(|bi| LaneOp {
                a: View::contiguous(z, bi * n_out + s_off, s_len),
                b: Some(View::contiguous(bias, s_off, s_len)),
                out: View::contiguous(z, bi * n_out + s_off, s_len),
            })
            .collect();
        ctx.wave(Opcode::VectorAddition, s_len, lanes);
    }
    if let Some((_, o)) = act {
        let lut = lut.unwrap();
        for &(s_off, s_len) in &segments(n_out) {
            let lanes = (0..rows)
                .map(|bi| LaneOp {
                    a: View::contiguous(z, bi * n_out + s_off, s_len),
                    b: None,
                    out: View::contiguous(o, bi * n_out + s_off, s_len),
                })
                .collect();
            ctx.act_wave(lut, lanes, s_len);
        }
    }
}

fn emit_conv_forward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    u: &Unit,
    batch: usize,
    geom: Conv2dGeom,
    act: Option<ActKind>,
) {
    let (w, b) = params_for(net, u.op)[0];
    let input = net.val_buf[u.ins[0]];
    let in_dim = geom.in_dim();
    let (oh, ow) = (geom.out_h(), geom.out_w());
    let f = geom.patch();
    let p_rows = batch * oh * ow;
    let im = ctx.p.buffer(&format!("im{}", u.tag), p_rows, f, BufKind::Temp);
    let zeros = ctx.p.const_buffer(&format!("imz{}", u.tag), vec![0i16; geom.kw]);
    // im2col: one VECTOR_ADDITION wave copies every kw-pixel strip of
    // the input volume into its patch slot (x + 0 — the ISA has no
    // copy). Strips stay contiguous for any stride because the stride
    // only moves the strip *start*.
    let mut lanes = Vec::with_capacity(p_rows * geom.in_c * geom.kh);
    for bi in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let prow = (bi * oh + oy) * ow + ox;
                for c in 0..geom.in_c {
                    for ky in 0..geom.kh {
                        let src = bi * in_dim
                            + c * (geom.in_h * geom.in_w)
                            + (oy * geom.stride + ky) * geom.in_w
                            + ox * geom.stride;
                        let dst = prow * f + (c * geom.kh + ky) * geom.kw;
                        lanes.push(LaneOp {
                            a: View::contiguous(input, src, geom.kw),
                            b: Some(View::contiguous(zeros, 0, geom.kw)),
                            out: View::contiguous(im, dst, geom.kw),
                        });
                    }
                }
            }
        }
    }
    ctx.wave(Opcode::VectorAddition, geom.kw, lanes);
    // Then the convolution is a dense layer over the (P × patch) im2col
    // matrix; the (batch × oh·ow·oc) value buffer is the same flat
    // memory as the (P × oc) dense output.
    let z = net.val_buf[u.op + 1];
    let act_cfg = act.map(|k| (k, net.val_buf[u.out]));
    emit_dense_core(
        ctx,
        g.fixed,
        g.lut,
        im,
        f,
        p_rows,
        w,
        b,
        z,
        geom.out_c,
        act_cfg,
        &format!("czp{}", u.tag),
    );
}

fn emit_norm_forward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    u: &Unit,
    batch: usize,
    cols: usize,
) -> Result<(), LowerError> {
    let dim = net.dims[u.out];
    let rr = batch * (dim / cols); // normalisation rows
    let input = net.val_buf[u.ins[0]];
    let outb = net.val_buf[u.out];
    let inv = g.fixed.from_f64(1.0 / cols as f64);
    if inv == 0 {
        return Err(LowerError::ConstUnderflow {
            what: "normalization 1/n",
            value: 1.0 / cols as f64,
        });
    }
    let t = u.tag;
    let p = &mut ctx.p;
    let nm = p.buffer(&format!("nm{t}"), rr, 1, BufKind::Temp);
    let nv = p.buffer(&format!("nv{t}"), rr, 1, BufKind::Temp);
    let ni = p.buffer(&format!("ni{t}"), rr, 1, BufKind::Temp);
    let ncn = p.buffer(&format!("ncn{t}"), rr, cols, BufKind::Temp);
    let nsq = p.buffer(&format!("nsq{t}"), rr, cols, BufKind::Temp);
    let ninv = p.const_buffer(&format!("nin{t}"), vec![inv]);
    // mean per group: row-sum × (1/n)
    let lanes = (0..rr)
        .map(|r| LaneOp { a: View::contiguous(input, r * cols, cols), b: None, out: lane(nm, r) })
        .collect();
    ctx.wave(Opcode::VectorSummation, cols, lanes);
    let lanes = (0..rr)
        .map(|r| LaneOp { a: lane(nm, r), b: Some(lane(ninv, 0)), out: lane(nm, r) })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, 1, lanes);
    // centre: x − mean, mean broadcast lane-wise
    let mut lanes = Vec::with_capacity(rr * cols);
    for r in 0..rr {
        for i in 0..cols {
            lanes.push(LaneOp {
                a: lane(input, r * cols + i),
                b: Some(lane(nm, r)),
                out: lane(ncn, r * cols + i),
            });
        }
    }
    ctx.wave(Opcode::VectorSubtraction, 1, lanes);
    // variance = Σ centred² × (1/n)
    let lanes = (0..rr)
        .map(|r| LaneOp { a: row(ncn, cols, r), b: Some(row(ncn, cols, r)), out: row(nsq, cols, r) })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, cols, lanes);
    let lanes =
        (0..rr).map(|r| LaneOp { a: row(nsq, cols, r), b: None, out: lane(nv, r) }).collect();
    ctx.wave(Opcode::VectorSummation, cols, lanes);
    let lanes = (0..rr)
        .map(|r| LaneOp { a: lane(nv, r), b: Some(lane(ninv, 0)), out: lane(nv, r) })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, 1, lanes);
    // 1/√(var ∨ ε) via the Rsqrt table (ε is baked into the knots)
    let lut = ctx.lut_for(g.fixed, g.lut, ActKind::Rsqrt, false);
    for &(s_off, s_len) in &segments(rr) {
        ctx.act_wave(
            lut,
            vec![LaneOp {
                a: View::contiguous(nv, s_off, s_len),
                b: None,
                out: View::contiguous(ni, s_off, s_len),
            }],
            s_len,
        );
    }
    // y = centred ⊙ invstd (broadcast)
    let mut lanes = Vec::with_capacity(rr * cols);
    for r in 0..rr {
        for i in 0..cols {
            lanes.push(LaneOp {
                a: lane(ncn, r * cols + i),
                b: Some(lane(ni, r)),
                out: lane(outb, r * cols + i),
            });
        }
    }
    ctx.wave(Opcode::ElementMultiplication, 1, lanes);
    Ok(())
}

fn emit_attn_forward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    u: &Unit,
    batch: usize,
    s: usize,
    d: usize,
) -> Result<(), LowerError> {
    let pairs = params_for(net, u.op); // q, k, v, o
    let input = net.val_buf[u.ins[0]];
    let outb = net.val_buf[u.out];
    let sd = s * d;
    let t = u.tag;
    let scale = 1.0 / (d as f64).sqrt();
    let scale_q = g.fixed.from_f64(scale);
    if scale_q == 0 {
        return Err(LowerError::ConstUnderflow { what: "attention 1/√d", value: scale });
    }
    let p = &mut ctx.p;
    let aq = p.buffer(&format!("aq{t}"), batch, sd, BufKind::Temp);
    let ak = p.buffer(&format!("ak{t}"), batch, sd, BufKind::Temp);
    let av = p.buffer(&format!("av{t}"), batch, sd, BufKind::Temp);
    let asb = p.buffer(&format!("as{t}"), batch, s * s, BufKind::Temp);
    let ap = p.buffer(&format!("ap{t}"), batch, s * s, BufKind::Temp);
    let ar = p.buffer(&format!("ar{t}"), batch * s, 1, BufKind::Temp);
    let ai = p.buffer(&format!("ai{t}"), batch * s, 1, BufKind::Temp);
    let ao = p.buffer(&format!("ao{t}"), batch, sd, BufKind::Temp);
    let asc = p.const_buffer(&format!("asc{t}"), vec![scale_q; s]);
    // X·W + b per token *within each sample* — attention never crosses
    // the batch (row-independence invariant).
    let proj = |ctx: &mut Ctx, src: BufId, w: BufId, bias: BufId, dst: BufId| {
        let mut lanes = Vec::with_capacity(batch * sd);
        for bi in 0..batch {
            for tok in 0..s {
                for jd in 0..d {
                    lanes.push(LaneOp {
                        a: View::contiguous(src, bi * sd + tok * d, d),
                        b: Some(View { buf: w, offset: jd, len: d, stride: d }),
                        out: lane(dst, bi * sd + tok * d + jd),
                    });
                }
            }
        }
        ctx.wave(Opcode::VectorDotProduct, d, lanes);
        let lanes = (0..batch * s)
            .map(|r| LaneOp {
                a: View::contiguous(dst, r * d, d),
                b: Some(View::contiguous(bias, 0, d)),
                out: View::contiguous(dst, r * d, d),
            })
            .collect();
        ctx.wave(Opcode::VectorAddition, d, lanes);
    };
    proj(ctx, input, pairs[0].0, pairs[0].1, aq);
    proj(ctx, input, pairs[1].0, pairs[1].1, ak);
    proj(ctx, input, pairs[2].0, pairs[2].1, av);
    // S = QKᵀ / √d, per sample (K rows are contiguous, no transpose)
    let mut lanes = Vec::with_capacity(batch * s * s);
    for bi in 0..batch {
        for tq in 0..s {
            for tk in 0..s {
                lanes.push(LaneOp {
                    a: View::contiguous(aq, bi * sd + tq * d, d),
                    b: Some(View::contiguous(ak, bi * sd + tk * d, d)),
                    out: lane(asb, (bi * s + tq) * s + tk),
                });
            }
        }
    }
    ctx.wave(Opcode::VectorDotProduct, d, lanes);
    let lanes = (0..batch * s)
        .map(|r| LaneOp {
            a: View::contiguous(asb, r * s, s),
            b: Some(View::contiguous(asc, 0, s)),
            out: View::contiguous(asb, r * s, s),
        })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, s, lanes);
    // softmax rows: exp → row-sum → recip → broadcast multiply. No
    // max-subtraction: scaled scores live in the LUT's representable
    // range under the same fixed-point contract as every activation.
    let exp = ctx.lut_for(g.fixed, g.lut, ActKind::Exp, false);
    let lanes = (0..batch * s)
        .map(|r| LaneOp {
            a: View::contiguous(asb, r * s, s),
            b: None,
            out: View::contiguous(ap, r * s, s),
        })
        .collect();
    ctx.act_wave(exp, lanes, s);
    let lanes = (0..batch * s)
        .map(|r| LaneOp { a: View::contiguous(ap, r * s, s), b: None, out: lane(ar, r) })
        .collect();
    ctx.wave(Opcode::VectorSummation, s, lanes);
    let recip = ctx.lut_for(g.fixed, g.lut, ActKind::Recip, false);
    for &(s_off, s_len) in &segments(batch * s) {
        ctx.act_wave(
            recip,
            vec![LaneOp {
                a: View::contiguous(ar, s_off, s_len),
                b: None,
                out: View::contiguous(ai, s_off, s_len),
            }],
            s_len,
        );
    }
    let mut lanes = Vec::with_capacity(batch * s * s);
    for r in 0..batch * s {
        for tk in 0..s {
            lanes.push(LaneOp {
                a: lane(ap, r * s + tk),
                b: Some(lane(ai, r)),
                out: lane(ap, r * s + tk),
            });
        }
    }
    ctx.wave(Opcode::ElementMultiplication, 1, lanes);
    // A = P·V per sample; V columns are strided views within the sample
    let mut lanes = Vec::with_capacity(batch * sd);
    for bi in 0..batch {
        for tq in 0..s {
            for jd in 0..d {
                lanes.push(LaneOp {
                    a: View::contiguous(ap, (bi * s + tq) * s, s),
                    b: Some(View { buf: av, offset: bi * sd + jd, len: s, stride: d }),
                    out: lane(ao, bi * sd + tq * d + jd),
                });
            }
        }
    }
    ctx.wave(Opcode::VectorDotProduct, s, lanes);
    // out = A·Wo + bo
    proj(ctx, ao, pairs[3].0, pairs[3].1, outb);
    Ok(())
}

fn emit_unit_forward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    u: &Unit,
    batch: usize,
) -> Result<(), LowerError> {
    match u.kind {
        UnitKind::Dense { n_out, act } => {
            let (w, b) = params_for(net, u.op)[0];
            let input = net.val_buf[u.ins[0]];
            let n_in = net.dims[u.ins[0]];
            let z = net.val_buf[u.op + 1];
            let act_cfg = act.map(|(k, _)| (k, net.val_buf[u.out]));
            emit_dense_core(
                ctx,
                g.fixed,
                g.lut,
                input,
                n_in,
                batch,
                w,
                b,
                z,
                n_out,
                act_cfg,
                &format!("zc{}", u.tag),
            );
        }
        UnitKind::Conv { geom, act } => emit_conv_forward(ctx, g, net, u, batch, geom, act),
        UnitKind::Act { act } => {
            let lut = ctx.lut_for(g.fixed, g.lut, act, false);
            let dim = net.dims[u.out];
            let input = net.val_buf[u.ins[0]];
            let o = net.val_buf[u.out];
            for &(s_off, s_len) in &segments(dim) {
                let lanes = (0..batch)
                    .map(|bi| LaneOp {
                        a: View::contiguous(input, bi * dim + s_off, s_len),
                        b: None,
                        out: View::contiguous(o, bi * dim + s_off, s_len),
                    })
                    .collect();
                ctx.act_wave(lut, lanes, s_len);
            }
        }
        UnitKind::Add | UnitKind::Mul => {
            let opcode = if matches!(u.kind, UnitKind::Add) {
                Opcode::VectorAddition
            } else {
                Opcode::ElementMultiplication
            };
            let dim = net.dims[u.out];
            let (a, b) = (net.val_buf[u.ins[0]], net.val_buf[u.ins[1]]);
            let o = net.val_buf[u.out];
            for &(s_off, s_len) in &segments(dim) {
                let lanes = (0..batch)
                    .map(|bi| LaneOp {
                        a: View::contiguous(a, bi * dim + s_off, s_len),
                        b: Some(View::contiguous(b, bi * dim + s_off, s_len)),
                        out: View::contiguous(o, bi * dim + s_off, s_len),
                    })
                    .collect();
                ctx.wave(opcode, s_len, lanes);
            }
        }
        UnitKind::Norm { cols } => emit_norm_forward(ctx, g, net, u, batch, cols)?,
        UnitKind::Attn { seq, d } => emit_attn_forward(ctx, g, net, u, batch, seq, d)?,
    }
    Ok(())
}

fn emit_units_forward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    batch: usize,
) -> Result<(), LowerError> {
    ctx.p.steps.push(Step::LoadDram(net.x));
    for u in &net.units {
        emit_unit_forward(ctx, g, net, u, batch)?;
    }
    ctx.p.steps.push(Step::StoreDram(net.out));
    Ok(())
}

// ---------------------------------------------------------------------
// Entry points.
// ---------------------------------------------------------------------

fn handles(net: &Net, batch: usize, fixed: FixedSpec) -> LoweredMlp {
    LoweredMlp {
        program: Program::new("placeholder", fixed), // replaced by caller
        batch,
        x: net.x,
        y: net.y,
        out: net.out,
        weights: net.params.iter().map(|&(w, _)| w).collect(),
        biases: net.params.iter().map(|&(_, b)| b).collect(),
        loss: None,
    }
}

/// Lower a graph forward pass over a batch.
pub fn lower_graph_forward(g: &GraphSpec, batch: usize) -> Result<LoweredMlp, LowerError> {
    g.check()?;
    if batch == 0 || batch > COLUMN_LEN {
        return Err(LowerError::BadBatch(batch));
    }
    let mut ctx = Ctx::new(&format!("{}_fwd_b{batch}", g.name), g.fixed);
    let net = declare_graph(&mut ctx, g, batch, false)?;
    emit_units_forward(&mut ctx, g, &net, batch)?;
    let mut h = handles(&net, batch, g.fixed);
    h.program = ctx.p;
    h.program.check()?;
    Ok(h)
}

/// Lower an [`MlpSpec`] forward pass through the graph IR. Emits
/// programs bit-identical to the frozen legacy lowering.
pub fn lower_mlp_forward(spec: &MlpSpec, batch: usize) -> Result<LoweredMlp, LowerError> {
    spec.check()?;
    lower_graph_forward(&spec.to_graph(), batch)
}

/// Lower an [`MlpSpec`] SGD train step through the graph IR.
pub fn lower_mlp_train(spec: &MlpSpec, batch: usize, lr: f64) -> Result<LoweredMlp, LowerError> {
    spec.check()?;
    lower_graph_train(&spec.to_graph(), batch, lr)
}

// ---------------------------------------------------------------------
// Training.
// ---------------------------------------------------------------------

struct TrainBufs {
    /// Delta buffer per value id (None for the graph input and for
    /// fused intermediates, which no other op can consume).
    val_delta: Vec<Option<BufId>>,
    sq: BufId,
    lsum: BufId,
    loss: BufId,
}

/// Declare the per-unit gradient/delta buffers and the loss chain, in
/// the legacy order (per unit in forward order, then sq/lsum/loss).
fn declare_train_bufs(ctx: &mut Ctx, net: &Net, batch: usize) -> TrainBufs {
    let p = &mut ctx.p;
    let mut val_delta = vec![None; net.dims.len()];
    for u in &net.units {
        let t = u.tag;
        let dim = net.dims[u.out];
        let dbuf = match u.kind {
            UnitKind::Dense { n_out, .. } => {
                let d = p.buffer(&format!("d{t}"), batch, n_out, BufKind::Temp);
                p.buffer(&format!("g{t}"), batch, n_out, BufKind::Temp);
                p.buffer(&format!("gw{t}"), net.dims[u.ins[0]], n_out, BufKind::Temp);
                p.buffer(&format!("gb{t}"), n_out, 1, BufKind::Temp);
                d
            }
            UnitKind::Conv { geom, act } => {
                let d = p.buffer(&format!("dc{t}"), batch, dim, BufKind::Temp);
                if act.is_some() {
                    p.buffer(&format!("gc{t}"), batch, dim, BufKind::Temp);
                }
                p.buffer(&format!("gwc{t}"), geom.patch(), geom.out_c, BufKind::Temp);
                p.buffer(&format!("gbc{t}"), geom.out_c, 1, BufKind::Temp);
                d
            }
            UnitKind::Act { .. } => {
                let d = p.buffer(&format!("da{t}"), batch, dim, BufKind::Temp);
                p.buffer(&format!("ga{t}"), batch, dim, BufKind::Temp);
                d
            }
            UnitKind::Add => p.buffer(&format!("dadd{t}"), batch, dim, BufKind::Temp),
            UnitKind::Mul => p.buffer(&format!("dmul{t}"), batch, dim, BufKind::Temp),
            UnitKind::Norm { .. } => p.buffer(&format!("dnrm{t}"), batch, dim, BufKind::Temp),
            UnitKind::Attn { d, .. } => {
                let db = p.buffer(&format!("datt{t}"), batch, dim, BufKind::Temp);
                p.buffer(&format!("gwv{t}"), d, d, BufKind::Temp);
                p.buffer(&format!("gbv{t}"), d, 1, BufKind::Temp);
                p.buffer(&format!("gwo{t}"), d, d, BufKind::Temp);
                p.buffer(&format!("gbo{t}"), d, 1, BufKind::Temp);
                db
            }
        };
        val_delta[u.out] = Some(dbuf);
    }
    let out_dim = *net.dims.last().unwrap();
    let sq = p.buffer("sq", batch, out_dim, BufKind::Temp);
    let lsum = p.buffer("lsum", batch, 1, BufKind::Temp);
    let loss = p.buffer("loss", 1, 1, BufKind::Output);
    TrainBufs { val_delta, sq, lsum, loss }
}

/// Route a delta contribution into value `v`'s delta buffer. The first
/// contribution overwrites (buffers persist across steps, so they must
/// be fully written before read); later ones go through `scratch` and
/// a segment-wise accumulate.
fn deposit(
    ctx: &mut Ctx,
    net: &Net,
    tb: &TrainBufs,
    written: &mut [bool],
    batch: usize,
    v: ValueId,
    scratch: &str,
    emit: impl FnOnce(&mut Ctx, BufId),
) {
    let dest = tb.val_delta[v].expect("consumed value must have a delta buffer");
    if !written[v] {
        emit(ctx, dest);
        written[v] = true;
        return;
    }
    let dim = net.dims[v];
    let s = ctx
        .p
        .buffer_named(scratch)
        .unwrap_or_else(|| ctx.p.buffer(scratch, batch, dim, BufKind::Temp));
    emit(ctx, s);
    for &(s_off, s_len) in &segments(dim) {
        let lanes = (0..batch)
            .map(|bi| LaneOp {
                a: View::contiguous(dest, bi * dim + s_off, s_len),
                b: Some(View::contiguous(s, bi * dim + s_off, s_len)),
                out: View::contiguous(dest, bi * dim + s_off, s_len),
            })
            .collect();
        ctx.wave(Opcode::VectorAddition, s_len, lanes);
    }
}

/// The legacy in-place SGD update: `gw ⊙= lr` per row, `w −= gw`,
/// `gb ⊙= lr`, `b −= gb`.
#[allow(clippy::too_many_arguments)]
fn sgd_update(
    ctx: &mut Ctx,
    w: BufId,
    bias: BufId,
    gw: BufId,
    gb: BufId,
    n_in: usize,
    n_out: usize,
    lr_buf: BufId,
) {
    let lanes = (0..n_in)
        .map(|i| LaneOp {
            a: row(gw, n_out, i),
            b: Some(View::contiguous(lr_buf, 0, n_out)),
            out: row(gw, n_out, i),
        })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, n_out, lanes);
    let lanes = (0..n_in)
        .map(|i| LaneOp { a: row(w, n_out, i), b: Some(row(gw, n_out, i)), out: row(w, n_out, i) })
        .collect();
    ctx.wave(Opcode::VectorSubtraction, n_out, lanes);
    ctx.wave(
        Opcode::ElementMultiplication,
        n_out,
        vec![LaneOp {
            a: View::all(gb, n_out),
            b: Some(View::contiguous(lr_buf, 0, n_out)),
            out: View::all(gb, n_out),
        }],
    );
    ctx.wave(
        Opcode::VectorSubtraction,
        n_out,
        vec![LaneOp {
            a: View::all(bias, n_out),
            b: Some(View::all(gb, n_out)),
            out: View::all(bias, n_out),
        }],
    );
}

#[allow(clippy::too_many_arguments)]
fn emit_unit_backward(
    ctx: &mut Ctx,
    g: &GraphSpec,
    net: &Net,
    tb: &TrainBufs,
    u: &Unit,
    batch: usize,
    lr_buf: BufId,
    written: &mut [bool],
) {
    match u.kind {
        UnitKind::Dense { n_out, act } => {
            let (w, bias) = params_for(net, u.op)[0];
            let n_in = net.dims[u.ins[0]];
            let input = net.val_buf[u.ins[0]];
            let d = tb.val_delta[u.out].unwrap();
            let z = net.val_buf[u.op + 1];
            let gbuf = ctx.p.buffer_named(&format!("g{}", u.tag)).unwrap();
            let gw = ctx.p.buffer_named(&format!("gw{}", u.tag)).unwrap();
            let gb = ctx.p.buffer_named(&format!("gb{}", u.tag)).unwrap();
            // δ = d ⊙ A'(z) (fused activation only — a bare Linear's
            // delta is already the pre-activation delta)
            if let Some((akind, _)) = act {
                let dlut = ctx.lut_for(g.fixed, g.lut, akind, true);
                let lanes = (0..batch)
                    .map(|bi| LaneOp { a: row(z, n_out, bi), b: None, out: row(gbuf, n_out, bi) })
                    .collect();
                ctx.act_wave(dlut, lanes, n_out);
                let lanes = (0..batch)
                    .map(|bi| LaneOp {
                        a: row(d, n_out, bi),
                        b: Some(row(gbuf, n_out, bi)),
                        out: row(d, n_out, bi),
                    })
                    .collect();
                ctx.wave(Opcode::ElementMultiplication, n_out, lanes);
            }
            // ∂W[i,j] = Σ_b input[b,i]·δ[b,j]
            let mut lanes = Vec::with_capacity(n_in * n_out);
            for i in 0..n_in {
                for j in 0..n_out {
                    lanes.push(LaneOp {
                        a: col(input, batch, n_in, i),
                        b: Some(col(d, batch, n_out, j)),
                        out: lane(gw, i * n_out + j),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, batch, lanes);
            // ∂b[j] = Σ_b δ[b,j]
            let lanes = (0..n_out)
                .map(|j| LaneOp { a: col(d, batch, n_out, j), b: None, out: lane(gb, j) })
                .collect();
            ctx.wave(Opcode::VectorSummation, batch, lanes);
            // δ_prev[b,i] = dot(w row i, δ row b)  (pre-update weights)
            if u.ins[0] != INPUT {
                deposit(ctx, net, tb, written, batch, u.ins[0], &format!("ds{}", u.op), |ctx, dest| {
                    let mut lanes = Vec::with_capacity(batch * n_in);
                    for bi in 0..batch {
                        for i in 0..n_in {
                            lanes.push(LaneOp {
                                a: View::contiguous(w, i * n_out, n_out),
                                b: Some(row(d, n_out, bi)),
                                out: lane(dest, bi * n_in + i),
                            });
                        }
                    }
                    ctx.wave(Opcode::VectorDotProduct, n_out, lanes);
                });
            }
            sgd_update(ctx, w, bias, gw, gb, n_in, n_out, lr_buf);
        }
        UnitKind::Conv { geom, act } => {
            // Only lowered when the conv reads the graph input (checked
            // up front): param grads via the dense backward over the
            // im2col matrix; no col2im delta path.
            let (w, bias) = params_for(net, u.op)[0];
            let f = geom.patch();
            let oc = geom.out_c;
            let prows = batch * geom.out_h() * geom.out_w();
            let dc = tb.val_delta[u.out].unwrap();
            let im = ctx.p.buffer_named(&format!("im{}", u.tag)).unwrap();
            let gwc = ctx.p.buffer_named(&format!("gwc{}", u.tag)).unwrap();
            let gbc = ctx.p.buffer_named(&format!("gbc{}", u.tag)).unwrap();
            if let Some(akind) = act {
                let gc = ctx.p.buffer_named(&format!("gc{}", u.tag)).unwrap();
                let cz = net.val_buf[u.op + 1];
                let dlut = ctx.lut_for(g.fixed, g.lut, akind, true);
                let lanes = (0..prows)
                    .map(|r| LaneOp { a: row(cz, oc, r), b: None, out: row(gc, oc, r) })
                    .collect();
                ctx.act_wave(dlut, lanes, oc);
                let lanes = (0..prows)
                    .map(|r| LaneOp {
                        a: row(dc, oc, r),
                        b: Some(row(gc, oc, r)),
                        out: row(dc, oc, r),
                    })
                    .collect();
                ctx.wave(Opcode::ElementMultiplication, oc, lanes);
            }
            let mut lanes = Vec::with_capacity(f * oc);
            for i in 0..f {
                for j in 0..oc {
                    lanes.push(LaneOp {
                        a: col(im, prows, f, i),
                        b: Some(col(dc, prows, oc, j)),
                        out: lane(gwc, i * oc + j),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, prows, lanes);
            let lanes = (0..oc)
                .map(|j| LaneOp { a: col(dc, prows, oc, j), b: None, out: lane(gbc, j) })
                .collect();
            ctx.wave(Opcode::VectorSummation, prows, lanes);
            sgd_update(ctx, w, bias, gwc, gbc, f, oc, lr_buf);
        }
        UnitKind::Act { act } => {
            let dim = net.dims[u.out];
            let dout = tb.val_delta[u.out].unwrap();
            let input = net.val_buf[u.ins[0]];
            let ga = ctx.p.buffer_named(&format!("ga{}", u.tag)).unwrap();
            let dlut = ctx.lut_for(g.fixed, g.lut, act, true);
            for &(s_off, s_len) in &segments(dim) {
                let lanes = (0..batch)
                    .map(|bi| LaneOp {
                        a: View::contiguous(input, bi * dim + s_off, s_len),
                        b: None,
                        out: View::contiguous(ga, bi * dim + s_off, s_len),
                    })
                    .collect();
                ctx.act_wave(dlut, lanes, s_len);
            }
            if u.ins[0] != INPUT {
                deposit(ctx, net, tb, written, batch, u.ins[0], &format!("ds{}", u.op), |ctx, dest| {
                    for &(s_off, s_len) in &segments(dim) {
                        let lanes = (0..batch)
                            .map(|bi| LaneOp {
                                a: View::contiguous(dout, bi * dim + s_off, s_len),
                                b: Some(View::contiguous(ga, bi * dim + s_off, s_len)),
                                out: View::contiguous(dest, bi * dim + s_off, s_len),
                            })
                            .collect();
                        ctx.wave(Opcode::ElementMultiplication, s_len, lanes);
                    }
                });
            }
        }
        UnitKind::Add => {
            // δ flows unchanged to both inputs. First contribution is a
            // copy (x + 0 — full overwrite); a repeat contribution can
            // accumulate straight from dout.
            let dim = net.dims[u.out];
            let dout = tb.val_delta[u.out].unwrap();
            for &vin in &u.ins {
                if vin == INPUT {
                    continue;
                }
                let dest = tb.val_delta[vin].expect("consumed value must have a delta buffer");
                if !written[vin] {
                    let zeros = ctx
                        .p
                        .buffer_named("gz")
                        .unwrap_or_else(|| ctx.p.const_buffer("gz", vec![0i16; COLUMN_LEN]));
                    for &(s_off, s_len) in &segments(dim) {
                        let lanes = (0..batch)
                            .map(|bi| LaneOp {
                                a: View::contiguous(dout, bi * dim + s_off, s_len),
                                b: Some(View::contiguous(zeros, 0, s_len)),
                                out: View::contiguous(dest, bi * dim + s_off, s_len),
                            })
                            .collect();
                        ctx.wave(Opcode::VectorAddition, s_len, lanes);
                    }
                    written[vin] = true;
                } else {
                    for &(s_off, s_len) in &segments(dim) {
                        let lanes = (0..batch)
                            .map(|bi| LaneOp {
                                a: View::contiguous(dest, bi * dim + s_off, s_len),
                                b: Some(View::contiguous(dout, bi * dim + s_off, s_len)),
                                out: View::contiguous(dest, bi * dim + s_off, s_len),
                            })
                            .collect();
                        ctx.wave(Opcode::VectorAddition, s_len, lanes);
                    }
                }
            }
        }
        UnitKind::Mul => {
            // δ_a = δ ⊙ b, δ_b = δ ⊙ a
            let dim = net.dims[u.out];
            let dout = tb.val_delta[u.out].unwrap();
            for (slot, other) in [(0usize, u.ins[1]), (1usize, u.ins[0])] {
                let vin = u.ins[slot];
                if vin == INPUT {
                    continue;
                }
                let other_buf = net.val_buf[other];
                let scratch = format!("ds{}{}", u.op, ["a", "b"][slot]);
                deposit(ctx, net, tb, written, batch, vin, &scratch, |ctx, dest| {
                    for &(s_off, s_len) in &segments(dim) {
                        let lanes = (0..batch)
                            .map(|bi| LaneOp {
                                a: View::contiguous(dout, bi * dim + s_off, s_len),
                                b: Some(View::contiguous(other_buf, bi * dim + s_off, s_len)),
                                out: View::contiguous(dest, bi * dim + s_off, s_len),
                            })
                            .collect();
                        ctx.wave(Opcode::ElementMultiplication, s_len, lanes);
                    }
                });
            }
        }
        UnitKind::Norm { cols } => {
            // Straight-through scaled by the saved 1/σ (Jacobian
            // mean/variance terms dropped — documented approximation).
            if u.ins[0] == INPUT {
                return;
            }
            let dim = net.dims[u.out];
            let rr = batch * (dim / cols);
            let dout = tb.val_delta[u.out].unwrap();
            let ni = ctx.p.buffer_named(&format!("ni{}", u.tag)).unwrap();
            deposit(ctx, net, tb, written, batch, u.ins[0], &format!("ds{}", u.op), |ctx, dest| {
                let mut lanes = Vec::with_capacity(rr * cols);
                for r in 0..rr {
                    for i in 0..cols {
                        lanes.push(LaneOp {
                            a: lane(dout, r * cols + i),
                            b: Some(lane(ni, r)),
                            out: lane(dest, r * cols + i),
                        });
                    }
                }
                ctx.wave(Opcode::ElementMultiplication, 1, lanes);
            });
        }
        UnitKind::Attn { seq: s, d } => {
            // Frozen-scores backward: exact grads for Wv/bv/Wo/bo
            // through A = P·V; Wq/Wk/bq/bk are not updated (documented
            // approximation — keeps the whole step on-device).
            let sd = s * d;
            let rr = batch * s;
            let t = u.tag;
            let dout = tb.val_delta[u.out].unwrap();
            let pairs = params_for(net, u.op);
            let (wv, bv) = pairs[2];
            let (wo, bo) = pairs[3];
            let ap = ctx.p.buffer_named(&format!("ap{t}")).unwrap();
            let ao = ctx.p.buffer_named(&format!("ao{t}")).unwrap();
            let gwv = ctx.p.buffer_named(&format!("gwv{t}")).unwrap();
            let gbv = ctx.p.buffer_named(&format!("gbv{t}")).unwrap();
            let gwo = ctx.p.buffer_named(&format!("gwo{t}")).unwrap();
            let gbo = ctx.p.buffer_named(&format!("gbo{t}")).unwrap();
            let input = net.val_buf[u.ins[0]];
            let dao = ctx.p.buffer(&format!("dao{t}"), batch, sd, BufKind::Temp);
            let dav = ctx.p.buffer(&format!("dav{t}"), batch, sd, BufKind::Temp);
            // δA = δout · Woᵀ (Wo rows are contiguous)
            let mut lanes = Vec::with_capacity(batch * sd);
            for bi in 0..batch {
                for tok in 0..s {
                    for i in 0..d {
                        lanes.push(LaneOp {
                            a: View::contiguous(wo, i * d, d),
                            b: Some(View::contiguous(dout, bi * sd + tok * d, d)),
                            out: lane(dao, bi * sd + tok * d + i),
                        });
                    }
                }
            }
            ctx.wave(Opcode::VectorDotProduct, d, lanes);
            // ∂Wo[i,j] = Σ_r A[r,i]·δout[r,j] over all batch·seq rows
            let mut lanes = Vec::with_capacity(d * d);
            for i in 0..d {
                for j in 0..d {
                    lanes.push(LaneOp {
                        a: col(ao, rr, d, i),
                        b: Some(col(dout, rr, d, j)),
                        out: lane(gwo, i * d + j),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, rr, lanes);
            let lanes = (0..d)
                .map(|j| LaneOp { a: col(dout, rr, d, j), b: None, out: lane(gbo, j) })
                .collect();
            ctx.wave(Opcode::VectorSummation, rr, lanes);
            // δV[b,u,j] = Σ_t P[b,t,u]·δA[b,t,j] (per sample)
            let mut lanes = Vec::with_capacity(batch * sd);
            for bi in 0..batch {
                for uu in 0..s {
                    for j in 0..d {
                        lanes.push(LaneOp {
                            a: View { buf: ap, offset: bi * s * s + uu, len: s, stride: s },
                            b: Some(View { buf: dao, offset: bi * sd + j, len: s, stride: d }),
                            out: lane(dav, bi * sd + uu * d + j),
                        });
                    }
                }
            }
            ctx.wave(Opcode::VectorDotProduct, s, lanes);
            // ∂Wv[i,j] = Σ_r X[r,i]·δV[r,j]
            let mut lanes = Vec::with_capacity(d * d);
            for i in 0..d {
                for j in 0..d {
                    lanes.push(LaneOp {
                        a: col(input, rr, d, i),
                        b: Some(col(dav, rr, d, j)),
                        out: lane(gwv, i * d + j),
                    });
                }
            }
            ctx.wave(Opcode::VectorDotProduct, rr, lanes);
            let lanes = (0..d)
                .map(|j| LaneOp { a: col(dav, rr, d, j), b: None, out: lane(gbv, j) })
                .collect();
            ctx.wave(Opcode::VectorSummation, rr, lanes);
            // δX = δV · Wvᵀ (the only surviving input-delta term under
            // frozen scores)
            if u.ins[0] != INPUT {
                deposit(ctx, net, tb, written, batch, u.ins[0], &format!("ds{}", u.op), |ctx, dest| {
                    let mut lanes = Vec::with_capacity(batch * sd);
                    for bi in 0..batch {
                        for tok in 0..s {
                            for i in 0..d {
                                lanes.push(LaneOp {
                                    a: View::contiguous(wv, i * d, d),
                                    b: Some(View::contiguous(dav, bi * sd + tok * d, d)),
                                    out: lane(dest, bi * sd + tok * d + i),
                                });
                            }
                        }
                    }
                    ctx.wave(Opcode::VectorDotProduct, d, lanes);
                });
            }
            sgd_update(ctx, wv, bv, gwv, gbv, d, d, lr_buf);
            sgd_update(ctx, wo, bo, gwo, gbo, d, d, lr_buf);
        }
    }
}

/// Lower one SGD train step over a graph: forward + backward + in-place
/// update with on-device Σ(o−y)² loss, mirroring the legacy MLP train
/// schedule (and bit-identical to it for MLP chains).
pub fn lower_graph_train(g: &GraphSpec, batch: usize, lr: f64) -> Result<LoweredMlp, LowerError> {
    g.check()?;
    if batch == 0 || batch > COLUMN_LEN {
        return Err(LowerError::BadBatch(batch));
    }
    let dims = g.value_dims()?;
    let units = build_units(g);
    // Per-unit trainability checks, in op order (legacy precedence:
    // width errors before the learning-rate check).
    for u in &units {
        match u.kind {
            UnitKind::Dense { n_out, .. } => {
                let wide = dims[u.ins[0]].max(n_out);
                if wide > COLUMN_LEN {
                    return Err(LowerError::TrainingTooWide(wide));
                }
            }
            UnitKind::Conv { geom, .. } => {
                if u.ins[0] != INPUT {
                    return Err(LowerError::TrainUnsupported {
                        op: u.op,
                        why: "Conv2d gradients need the convolution first in the graph \
                              (no col2im delta path)",
                    });
                }
                let prows = batch * geom.out_h() * geom.out_w();
                if prows > COLUMN_LEN {
                    return Err(LowerError::TrainingTooWide(prows));
                }
                if geom.out_c > COLUMN_LEN {
                    return Err(LowerError::TrainingTooWide(geom.out_c));
                }
            }
            UnitKind::Attn { seq, .. } => {
                if batch * seq > COLUMN_LEN {
                    return Err(LowerError::TrainingTooWide(batch * seq));
                }
            }
            _ => {}
        }
    }
    let out_dim = *dims.last().unwrap();
    if out_dim > COLUMN_LEN {
        return Err(LowerError::TrainingTooWide(out_dim));
    }
    let lr_q = g.fixed.from_f64(lr);
    if lr_q == 0 {
        return Err(LowerError::LrUnderflow(lr));
    }
    let decls = g.param_decls()?;
    if decls.is_empty() {
        return Err(LowerError::NoParams);
    }
    let lr_len = decls.iter().map(|d| d.cols).max().unwrap();

    let mut ctx = Ctx::new(&format!("{}_train_b{batch}", g.name), g.fixed);
    let net = declare_graph(&mut ctx, g, batch, true)?;
    let lr_buf = ctx.p.const_buffer("lr", vec![lr_q; lr_len]);
    let tb = declare_train_bufs(&mut ctx, &net, batch);

    // ---- forward ----
    emit_units_forward(&mut ctx, g, &net, batch)?;
    let y = net.y.unwrap();
    ctx.p.steps.push(Step::LoadDram(y));
    ctx.p.steps.push(Step::LoadDram(lr_buf));

    // ---- output error: d_out = o − y ----
    let last = g.ops.len();
    let d_last = tb.val_delta[last].unwrap();
    let lanes = (0..batch)
        .map(|bi| LaneOp {
            a: row(net.out, out_dim, bi),
            b: Some(row(y, out_dim, bi)),
            out: row(d_last, out_dim, bi),
        })
        .collect();
    ctx.wave(Opcode::VectorSubtraction, out_dim, lanes);

    // ---- loss = Σ (o−y)² (diagnostic) ----
    let lanes = (0..batch)
        .map(|bi| LaneOp {
            a: row(d_last, out_dim, bi),
            b: Some(row(d_last, out_dim, bi)),
            out: row(tb.sq, out_dim, bi),
        })
        .collect();
    ctx.wave(Opcode::ElementMultiplication, out_dim, lanes);
    let lanes = (0..batch)
        .map(|bi| LaneOp { a: row(tb.sq, out_dim, bi), b: None, out: lane(tb.lsum, bi) })
        .collect();
    ctx.wave(Opcode::VectorSummation, out_dim, lanes);
    ctx.wave(
        Opcode::VectorSummation,
        batch,
        vec![LaneOp { a: View::all(tb.lsum, batch), b: None, out: lane(tb.loss, 0) }],
    );

    // ---- backward, reverse unit order ----
    let mut written = vec![false; net.dims.len()];
    written[last] = true;
    for ui in (0..net.units.len()).rev() {
        let u = net.units[ui].clone();
        if !written[u.out] {
            continue; // dead branch: nothing consumed it, no delta
        }
        emit_unit_backward(&mut ctx, g, &net, &tb, &u, batch, lr_buf, &mut written);
    }
    ctx.p.steps.push(Step::StoreDram(tb.loss));

    let mut h = handles(&net, batch, g.fixed);
    h.y = net.y;
    h.loss = Some(tb.loss);
    h.program = ctx.p;
    h.program.check()?;
    Ok(h)
}

// ---------------------------------------------------------------------
// Tests.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{FastSim, FpgaDevice, MatrixMachine};
    use crate::nn::graph::FloatGraph;
    use crate::nn::lowering::{legacy_lower_forward, legacy_lower_train_step};
    use crate::util::Rng;

    fn mlp(dims: &[usize]) -> MlpSpec {
        let fixed = FixedSpec::q(10).saturating();
        MlpSpec::from_dims(
            "m",
            dims,
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    /// Field-wise program equality ([`Program`] doesn't derive
    /// `PartialEq`); the step loop pinpoints the first divergent wave.
    fn assert_same_program(a: &Program, b: &Program) {
        assert_eq!(a.name, b.name, "program names");
        assert_eq!(a.fixed, b.fixed, "fixed-point specs");
        assert_eq!(a.buffers, b.buffers, "buffer declarations");
        assert_eq!(a.luts, b.luts, "LUT tables");
        for (i, (x, y)) in a.steps.iter().zip(&b.steps).enumerate() {
            assert_eq!(x, y, "step {i}");
        }
        assert_eq!(a.steps.len(), b.steps.len(), "step counts");
    }

    fn assert_same_handles(a: &LoweredMlp, b: &LoweredMlp) {
        assert_eq!(a.batch, b.batch, "batch");
        assert_eq!(a.x, b.x, "x handle");
        assert_eq!(a.y, b.y, "y handle");
        assert_eq!(a.out, b.out, "out handle");
        assert_eq!(a.weights, b.weights, "weight handles");
        assert_eq!(a.biases, b.biases, "bias handles");
        assert_eq!(a.loss, b.loss, "loss handle");
    }

    #[test]
    fn mlp_forward_through_graph_is_bit_identical_to_legacy() {
        let spec = mlp(&[5, 9, 3]);
        for batch in [1, 4] {
            let g = lower_mlp_forward(&spec, batch).unwrap();
            let l = legacy_lower_forward(&spec, batch).unwrap();
            assert_same_program(&g.program, &l.program);
            assert_same_handles(&g, &l);
        }
    }

    #[test]
    fn wide_mlp_forward_chunks_identically_to_legacy() {
        // Dims beyond COLUMN_LEN exercise the chunked-dot and segmented
        // bias/activation paths on both sides.
        let spec = mlp(&[1100, 700, 4]);
        let g = lower_mlp_forward(&spec, 2).unwrap();
        let l = legacy_lower_forward(&spec, 2).unwrap();
        assert_same_program(&g.program, &l.program);
        assert_same_handles(&g, &l);
    }

    #[test]
    fn mlp_train_through_graph_is_bit_identical_to_legacy() {
        let spec = mlp(&[5, 9, 3]);
        let g = lower_mlp_train(&spec, 6, 1.0 / 64.0).unwrap();
        let l = legacy_lower_train_step(&spec, 6, 1.0 / 64.0).unwrap();
        assert_same_program(&g.program, &l.program);
        assert_same_handles(&g, &l);
    }

    #[test]
    fn mlp_error_cases_match_legacy() {
        let spec = mlp(&[5, 9, 3]);
        assert_eq!(
            lower_mlp_forward(&spec, 0).unwrap_err(),
            legacy_lower_forward(&spec, 0).unwrap_err()
        );
        let wide = mlp(&[600, 10, 4]);
        assert_eq!(
            lower_mlp_train(&wide, 2, 1.0 / 64.0).unwrap_err(),
            legacy_lower_train_step(&wide, 2, 1.0 / 64.0).unwrap_err()
        );
    }

    // ---- golden per-op tests: lowered programs vs the float oracle ----

    /// Lower `spec`, run the forward program on [`FastSim`], return the
    /// output lanes.
    fn run_forward(
        spec: &GraphSpec,
        params: &[(Vec<i16>, Vec<i16>)],
        qx: &[i16],
        batch: usize,
    ) -> Vec<i16> {
        let h = lower_graph_forward(spec, batch).expect("lower forward");
        let mut sim = FastSim::new(&h.program);
        sim.set_buffer(h.x, qx);
        for (i, (w, b)) in params.iter().enumerate() {
            sim.set_buffer(h.weights[i], w);
            sim.set_buffer(h.biases[i], b);
        }
        for step in &h.program.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(&h.program, w);
            }
        }
        sim.buffer(h.out).to_vec()
    }

    /// Snap the float oracle's parameters onto the fixed-point grid so
    /// the only divergence left is datapath rounding, not param
    /// quantisation.
    fn dequantized(fg: &FloatGraph) -> FloatGraph {
        let f = fg.spec.fixed;
        let mut out = fg.clone();
        for p in &mut out.params {
            *p = (f.decode_vec(&f.encode_vec(&p.0)), f.decode_vec(&f.encode_vec(&p.1)));
        }
        out
    }

    fn rand_x(fixed: FixedSpec, rng: &mut Rng, n: usize) -> Vec<i16> {
        fixed.encode_vec(&(0..n).map(|_| rng.gen_f64() * 2.0 - 1.0).collect::<Vec<_>>())
    }

    fn assert_close(fixed: FixedSpec, got: &[i16], want: &[f64], tol: f64, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: lane counts");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            let g = fixed.to_f64(g);
            assert!((g - w).abs() < tol, "{what} lane {i}: {g} vs float {w}");
        }
    }

    #[test]
    fn conv_im2col_matches_float_reference() {
        let fixed = FixedSpec::q(9).saturating();
        let geom = Conv2dGeom { in_h: 4, in_w: 4, in_c: 1, out_c: 3, kh: 2, kw: 2, stride: 1 };
        let mut s = GraphSpec::new("conv", 16, fixed, LutParams::training(fixed));
        let c = s.conv2d(INPUT, geom);
        s.activation(c, ActKind::Relu);
        let mut rng = Rng::new(11);
        let fg = dequantized(&FloatGraph::init(&s, &mut rng));
        let qx = rand_x(fixed, &mut rng, 2 * 16);
        let got = run_forward(&s, &fg.quantized(), &qx, 2);
        let want = fg.forward_batch(&fixed.decode_vec(&qx), 2);
        assert_close(fixed, &got, &want, 0.05, "conv");
    }

    #[test]
    fn layernorm_matches_float_reference_and_centres_groups() {
        let fixed = FixedSpec::q(9).saturating();
        let mut s = GraphSpec::new("ln", 8, fixed, LutParams::training(fixed));
        let l = s.linear(INPUT, 8);
        s.normalization(l, 4);
        let mut rng = Rng::new(12);
        let fg = dequantized(&FloatGraph::init(&s, &mut rng));
        let qx = rand_x(fixed, &mut rng, 2 * 8);
        let got = run_forward(&s, &fg.quantized(), &qx, 2);
        let want = fg.forward_batch(&fixed.decode_vec(&qx), 2);
        // Rsqrt amplifies rounding near small variances — wider band.
        assert_close(fixed, &got, &want, 0.35, "layernorm");
        for (gi, group) in got.chunks(4).enumerate() {
            let sum: f64 = group.iter().map(|&v| fixed.to_f64(v)).sum();
            assert!(sum.abs() < 0.1, "group {gi} mean not removed: Σ = {sum}");
        }
    }

    #[test]
    fn residual_add_matches_float_reference() {
        let fixed = FixedSpec::q(9).saturating();
        let mut s = GraphSpec::new("res", 6, fixed, LutParams::training(fixed));
        let l = s.linear(INPUT, 6);
        let a = s.activation(l, ActKind::Tanh);
        s.add(a, INPUT);
        let mut rng = Rng::new(13);
        let fg = dequantized(&FloatGraph::init(&s, &mut rng));
        let qx = rand_x(fixed, &mut rng, 3 * 6);
        let got = run_forward(&s, &fg.quantized(), &qx, 3);
        let want = fg.forward_batch(&fixed.decode_vec(&qx), 3);
        assert_close(fixed, &got, &want, 0.1, "residual add");
    }

    #[test]
    fn gated_elementwise_mul_matches_float_reference() {
        let fixed = FixedSpec::q(9).saturating();
        let mut s = GraphSpec::new("gate", 5, fixed, LutParams::training(fixed));
        let g1 = s.linear(INPUT, 4);
        let a = s.activation(g1, ActKind::Sigmoid);
        let g2 = s.linear(INPUT, 4);
        s.mul(a, g2);
        let mut rng = Rng::new(14);
        let fg = dequantized(&FloatGraph::init(&s, &mut rng));
        let qx = rand_x(fixed, &mut rng, 2 * 5);
        let got = run_forward(&s, &fg.quantized(), &qx, 2);
        let want = fg.forward_batch(&fixed.decode_vec(&qx), 2);
        assert_close(fixed, &got, &want, 0.1, "gated mul");
    }

    #[test]
    fn attention_matches_float_reference_on_the_verified_machine() {
        // Q8 keeps the un-shifted softmax Exp inputs representable.
        let fixed = FixedSpec::q(8).saturating();
        let (seq, d) = (3, 2);
        let mut s = GraphSpec::new("attn", seq * d, fixed, LutParams::training(fixed));
        s.attention(INPUT, seq, d);
        let mut rng = Rng::new(15);
        let mut fg = FloatGraph::init(&s, &mut rng);
        // Halve the He-init weights: keeps the un-shifted softmax
        // scores small, where the nearest-knot Exp table is accurate.
        for (w, _) in &mut fg.params {
            w.iter_mut().for_each(|v| *v *= 0.5);
        }
        let fg = dequantized(&fg);
        let q = fg.quantized();
        let qx = rand_x(fixed, &mut rng, seq * d);

        // Through the full machine model with structural verification.
        let h = lower_graph_forward(&s, 1).unwrap();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &h.program).unwrap();
        m.bind_named(&h.program.buffers[h.x].name, &qx).unwrap();
        let decls = s.param_decls().unwrap();
        for (dcl, (w, b)) in decls.iter().zip(&q) {
            m.bind_named(&dcl.wname, w).unwrap();
            m.bind_named(&dcl.bname, b).unwrap();
        }
        m.execute_verified().expect("verified execution");
        let got = m.read_named(&h.program.buffers[h.out].name).unwrap().to_vec();
        let want = fg.forward(&fixed.decode_vec(&qx));
        // Exp → Recip → mixing chains three LUT approximations.
        assert_close(fixed, &got, &want, 0.5, "attention");
    }

    #[test]
    fn attention_batch_rows_are_independent() {
        // A batch-2 forward must be bit-identical to two batch-1
        // forwards concatenated: no cross-row leakage in the lowering.
        let fixed = FixedSpec::q(8).saturating();
        let (seq, d) = (3, 2);
        let mut s = GraphSpec::new("attn", seq * d, fixed, LutParams::training(fixed));
        s.attention(INPUT, seq, d);
        let mut rng = Rng::new(16);
        let fg = FloatGraph::init(&s, &mut rng);
        let q = fg.quantized();
        let qx = rand_x(fixed, &mut rng, 2 * seq * d);
        let both = run_forward(&s, &q, &qx, 2);
        let row0 = run_forward(&s, &q, &qx[..seq * d], 1);
        let row1 = run_forward(&s, &q, &qx[seq * d..], 1);
        assert_eq!(both[..seq * d], row0[..], "row 0 leaked");
        assert_eq!(both[seq * d..], row1[..], "row 1 leaked");
    }

    // ---- typed lowering errors ----

    #[test]
    fn conv_not_first_is_a_typed_training_error() {
        let fixed = FixedSpec::q(9).saturating();
        let geom = Conv2dGeom { in_h: 4, in_w: 4, in_c: 1, out_c: 2, kh: 2, kw: 2, stride: 1 };
        let mut s = GraphSpec::new("cv", 16, fixed, LutParams::training(fixed));
        let a = s.activation(INPUT, ActKind::Relu);
        s.conv2d(a, geom);
        match lower_graph_train(&s, 1, 1.0 / 64.0) {
            Err(LowerError::TrainUnsupported { op, .. }) => assert_eq!(op, 1),
            other => panic!("want TrainUnsupported, got {other:?}"),
        }
        // The same graph still lowers for inference.
        lower_graph_forward(&s, 1).unwrap();
    }

    #[test]
    fn attention_wider_than_a_column_is_a_typed_training_error() {
        let fixed = FixedSpec::q(8).saturating();
        let (seq, d) = (300, 2);
        let mut s = GraphSpec::new("wide_attn", seq * d, fixed, LutParams::training(fixed));
        s.attention(INPUT, seq, d);
        assert_eq!(
            lower_graph_train(&s, 2, 1.0 / 64.0).unwrap_err(),
            LowerError::TrainingTooWide(600)
        );
    }

    #[test]
    fn param_free_graph_is_a_typed_training_error() {
        let fixed = FixedSpec::q(9).saturating();
        let mut s = GraphSpec::new("np", 4, fixed, LutParams::training(fixed));
        s.activation(INPUT, ActKind::Tanh);
        assert_eq!(lower_graph_train(&s, 1, 1.0 / 64.0).unwrap_err(), LowerError::NoParams);
    }

    #[test]
    fn normalization_one_over_n_underflow_is_typed() {
        // At Q7 the constant 1/512 quantises to zero — surfaced as a
        // typed error instead of silently zeroing every group.
        let fixed = FixedSpec::q(7).saturating();
        let mut s = GraphSpec::new("uf", 512, fixed, LutParams::training(fixed));
        s.normalization(INPUT, 512);
        match lower_graph_forward(&s, 1) {
            Err(LowerError::ConstUnderflow { what, .. }) => {
                assert_eq!(what, "normalization 1/n");
            }
            other => panic!("want ConstUnderflow, got {other:?}"),
        }
    }
}
