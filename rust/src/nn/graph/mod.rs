//! The operator-graph IR: compile *any* network shape — not just the
//! fixed `MlpSpec` topology — onto the MVM/ActPro processor groups.
//!
//! The paper's pitch is one flexible structure that trains and tests
//! "any neural network" on the processor groups; this subsystem is the
//! compiler layer that makes good on it. A [`GraphSpec`] is a small
//! typed dataflow graph of per-sample tensor values (shape + the net's
//! `FixedSpec`) connected by operators:
//!
//! * [`OpKind::Linear`] — dense `x·W + b` (the MLP building block),
//! * [`OpKind::Activation`] — LUT activation over a value,
//! * [`OpKind::ElemAdd`] / [`OpKind::ElemMul`] — residual / gating
//!   elementwise combinators,
//! * [`OpKind::Normalization`] — layernorm-style row normalisation
//!   built from sums, elementwise ops, and an `Rsqrt` LUT (the ISA has
//!   no divide),
//! * [`OpKind::Conv2d`] — 2-D convolution lowered via im2col onto the
//!   existing chunked-dot machinery,
//! * [`OpKind::Attention`] — a single-head attention block assembled
//!   from linear projections, an `Exp`/`Recip` softmax LUT pair, and
//!   elementwise primitives.
//!
//! [`lower::lower_graph_forward`] / [`lower::lower_graph_train`] emit
//! the same kind of MVM/ActPro vector [`crate::assembler::program::Program`]s
//! `nn::lowering` produced for MLPs — and for a graph built by
//! [`crate::nn::MlpSpec::to_graph`] the emitted programs are
//! **bit-identical** to the legacy MLP lowering (asserted by
//! `rust/tests/graph.rs`), which is why the old entry points are now
//! thin `#[deprecated]` shims over this path.
//!
//! [`float::FloatGraph`] is the float64 forward oracle (the graph twin
//! of `nn::float_ref::FloatMlp`) used by the `graph` fuzz family, and
//! [`trainer::GraphTrainer`] is the board training engine behind
//! `Session` for graph artifacts (the graph twin of `nn::Trainer`).
//!
//! See DESIGN.md §Operator IR for the data model, the per-op lowering
//! contract, and the `MlpSpec` migration table.

pub mod float;
pub mod ir;
pub mod lower;
pub mod trainer;

pub use float::FloatGraph;
pub use ir::{Conv2dGeom, GraphError, GraphSpec, Op, OpKind, ParamDecl, ValueId, INPUT};
pub use lower::{lower_graph_forward, lower_graph_train, lower_mlp_forward, lower_mlp_train};
pub use trainer::GraphTrainer;
