//! Float64 reference evaluation of a [`GraphSpec`] — the graph twin of
//! [`crate::nn::float_ref::FloatMlp`], used by the `graph` fuzz family
//! and the golden per-op tests to judge the 16-bit fixed-point
//! lowering.
//!
//! The semantics mirror the lowering exactly, not textbook math: the
//! softmax has no max-subtraction and normalises via `1/max(Σ, ε)`
//! (the `Recip` table's guard), and normalisation scales by
//! `1/√max(var, ε)` (the `Rsqrt` table's guard) — see
//! [`crate::nn::lut::LUT_EPS`].

use super::ir::{Conv2dGeom, GraphSpec, OpKind};
use crate::nn::lut::ActKind;
use crate::util::Rng;

/// Float parameters for one graph net, aligned with
/// [`GraphSpec::param_decls`] (attention contributes q, k, v, o pairs
/// in that order).
#[derive(Debug, Clone)]
pub struct FloatGraph {
    /// The graph mirrored from the spec.
    pub spec: GraphSpec,
    /// `(weights, bias)` per parameter pair; weights are
    /// `(rows × cols)` row-major exactly like the lowered buffers.
    pub params: Vec<(Vec<f64>, Vec<f64>)>,
}

impl FloatGraph {
    /// Initialise with scaled-uniform weights (He-like:
    /// ±sqrt(2/fan_in)), zero biases — the same recipe as
    /// [`crate::nn::float_ref::FloatMlp::init`].
    pub fn init(spec: &GraphSpec, rng: &mut Rng) -> FloatGraph {
        let decls = spec.param_decls().expect("init on an invalid graph");
        let params = decls
            .iter()
            .map(|d| {
                let scale = (2.0 / d.rows as f64).sqrt();
                let w =
                    (0..d.rows * d.cols).map(|_| (rng.gen_f64() * 2.0 - 1.0) * scale).collect();
                (w, vec![0.0; d.cols])
            })
            .collect();
        FloatGraph { spec: spec.clone(), params }
    }

    /// Forward one sample, returning every value (`values[0]` is the
    /// input copy, `values.last()` the output).
    pub fn forward_trace(&self, x: &[f64]) -> Vec<Vec<f64>> {
        let dims = self.spec.value_dims().expect("forward on an invalid graph");
        assert_eq!(x.len(), dims[0], "input length");
        let mut values: Vec<Vec<f64>> = vec![x.to_vec()];
        let mut pi = 0usize; // param-pair cursor (decls are in op order)
        for op in &self.spec.ops {
            let a = &values[op.ins[0]];
            let out = match op.kind {
                OpKind::Linear { outputs } => {
                    let (w, b) = &self.params[pi];
                    pi += 1;
                    dense(a, w, b, outputs)
                }
                OpKind::Activation { act } => a.iter().map(|&v| act.f(v)).collect(),
                OpKind::ElemAdd => {
                    let bb = &values[op.ins[1]];
                    a.iter().zip(bb).map(|(&x, &y)| x + y).collect()
                }
                OpKind::ElemMul => {
                    let bb = &values[op.ins[1]];
                    a.iter().zip(bb).map(|(&x, &y)| x * y).collect()
                }
                OpKind::Normalization { cols } => normalize(a, cols),
                OpKind::Conv2d(g) => {
                    let (w, b) = &self.params[pi];
                    pi += 1;
                    conv2d(a, w, b, g)
                }
                OpKind::Attention { seq, d } => {
                    let p = &self.params[pi..pi + 4];
                    pi += 4;
                    attention(a, p, seq, d)
                }
            };
            values.push(out);
        }
        values
    }

    /// Forward one sample → output vector.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        self.forward_trace(x).pop().unwrap()
    }

    /// Forward a row-major batch → row-major outputs (rows are
    /// independent, mirroring the lowering's row invariant).
    pub fn forward_batch(&self, xs: &[f64], rows: usize) -> Vec<f64> {
        let in_dim = self.spec.input_dim();
        let mut out = Vec::new();
        for r in 0..rows {
            out.extend(self.forward(&xs[r * in_dim..(r + 1) * in_dim]));
        }
        out
    }

    /// Quantise parameters into the spec's fixed-point format, in
    /// lowered-buffer order (what [`super::GraphTrainer`] flashes).
    pub fn quantized(&self) -> Vec<(Vec<i16>, Vec<i16>)> {
        let f = self.spec.fixed;
        self.params.iter().map(|(w, b)| (f.encode_vec(w), f.encode_vec(b))).collect()
    }
}

fn dense(x: &[f64], w: &[f64], b: &[f64], n_out: usize) -> Vec<f64> {
    let n_in = x.len();
    (0..n_out)
        .map(|j| {
            let mut acc = b[j];
            for i in 0..n_in {
                acc += x[i] * w[i * n_out + j];
            }
            acc
        })
        .collect()
}

fn normalize(x: &[f64], cols: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.len());
    for group in x.chunks(cols) {
        let n = cols as f64;
        let mean = group.iter().sum::<f64>() / n;
        let var = group.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let inv = ActKind::Rsqrt.f(var); // 1/√max(var, ε)
        out.extend(group.iter().map(|&v| (v - mean) * inv));
    }
    out
}

fn conv2d(x: &[f64], w: &[f64], b: &[f64], g: Conv2dGeom) -> Vec<f64> {
    let (oh, ow) = (g.out_h(), g.out_w());
    let mut out = Vec::with_capacity(oh * ow * g.out_c);
    for oy in 0..oh {
        for ox in 0..ow {
            for oc in 0..g.out_c {
                let mut acc = b[oc];
                for c in 0..g.in_c {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            let iv = x[c * (g.in_h * g.in_w)
                                + (oy * g.stride + ky) * g.in_w
                                + (ox * g.stride + kx)];
                            // weight rows are im2col patch-major
                            let wv = w[((c * g.kh + ky) * g.kw + kx) * g.out_c + oc];
                            acc += iv * wv;
                        }
                    }
                }
                out.push(acc);
            }
        }
    }
    out
}

fn attention(x: &[f64], p: &[(Vec<f64>, Vec<f64>)], seq: usize, d: usize) -> Vec<f64> {
    let tok = |buf: &[f64], t: usize| buf[t * d..(t + 1) * d].to_vec();
    let project = |src: &[f64], (w, b): &(Vec<f64>, Vec<f64>)| -> Vec<f64> {
        let mut out = Vec::with_capacity(seq * d);
        for t in 0..seq {
            out.extend(dense(&tok(src, t), w, b, d));
        }
        out
    };
    let q = project(x, &p[0]);
    let k = project(x, &p[1]);
    let v = project(x, &p[2]);
    let scale = 1.0 / (d as f64).sqrt();
    let mut a = vec![0.0; seq * d];
    for tq in 0..seq {
        // scores → exp → normalise by 1/max(Σ, ε) (no max-subtraction,
        // matching the on-device Exp/Recip tables)
        let mut pr: Vec<f64> = (0..seq)
            .map(|tk| {
                let s: f64 = (0..d).map(|i| q[tq * d + i] * k[tk * d + i]).sum();
                ActKind::Exp.f(s * scale)
            })
            .collect();
        let inv = ActKind::Recip.f(pr.iter().sum());
        pr.iter_mut().for_each(|w| *w *= inv);
        for j in 0..d {
            a[tq * d + j] = (0..seq).map(|tk| pr[tk] * v[tk * d + j]).sum();
        }
    }
    project(&a, &p[3])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::graph::ir::INPUT;
    use crate::nn::mlp::LutParams;

    fn g(input: usize) -> GraphSpec {
        GraphSpec::new("fg", input, FixedSpec::PAPER, LutParams::training(FixedSpec::PAPER))
    }

    #[test]
    fn linear_matches_hand_math() {
        let mut s = g(2);
        s.linear(INPUT, 1);
        let mut fg = FloatGraph::init(&s, &mut Rng::new(1));
        fg.params[0] = (vec![0.5, -0.25], vec![0.125]);
        assert!((fg.forward(&[1.0, 1.0])[0] - (0.5 - 0.25 + 0.125)).abs() < 1e-12);
    }

    #[test]
    fn residual_add_and_mul() {
        let mut s = g(3);
        let v1 = s.activation(INPUT, ActKind::Identity);
        let v2 = s.add(v1, INPUT); // x + x
        s.mul(v2, INPUT); // 2x · x
        let fg = FloatGraph::init(&s, &mut Rng::new(2));
        let out = fg.forward(&[1.0, 2.0, -3.0]);
        assert_eq!(out, vec![2.0, 8.0, 18.0]);
    }

    #[test]
    fn normalization_centres_and_scales() {
        let mut s = g(4);
        s.normalization(INPUT, 4);
        let fg = FloatGraph::init(&s, &mut Rng::new(3));
        let out = fg.forward(&[1.0, 2.0, 3.0, 4.0]);
        let mean: f64 = out.iter().sum::<f64>() / 4.0;
        let var: f64 = out.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-9, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}"); // ε skews slightly
    }

    #[test]
    fn conv_matches_im2col_dense() {
        // 1×4×4 input, 1 output channel, 3×3 kernel → 2×2 output; the
        // direct convolution must equal an explicit im2col dot.
        let geom = Conv2dGeom { in_h: 4, in_w: 4, in_c: 1, out_c: 1, kh: 3, kw: 3, stride: 1 };
        let mut s = g(16);
        s.conv2d(INPUT, geom);
        let mut fg = FloatGraph::init(&s, &mut Rng::new(4));
        let w: Vec<f64> = (0..9).map(|i| (i as f64 - 4.0) / 8.0).collect();
        fg.params[0] = (w.clone(), vec![0.25]);
        let x: Vec<f64> = (0..16).map(|i| i as f64 / 16.0).collect();
        let out = fg.forward(&x);
        for (pos, &o) in out.iter().enumerate() {
            let (oy, ox) = (pos / 2, pos % 2);
            let mut acc = 0.25;
            for ky in 0..3 {
                for kx in 0..3 {
                    acc += x[(oy + ky) * 4 + (ox + kx)] * w[ky * 3 + kx];
                }
            }
            assert!((o - acc).abs() < 1e-12, "pos {pos}: {o} vs {acc}");
        }
    }

    #[test]
    fn attention_rows_are_a_distribution() {
        // With Wo = I, bo = 0 and V = x the output of each token is a
        // convex combination of value rows — bounded by their extremes.
        let (seq, d) = (3, 2);
        let mut s = g(seq * d);
        s.attention(INPUT, seq, d);
        let mut fg = FloatGraph::init(&s, &mut Rng::new(5));
        let eye: Vec<f64> =
            (0..d * d).map(|i| if i / d == i % d { 1.0 } else { 0.0 }).collect();
        fg.params[2] = (eye.clone(), vec![0.0; d]); // v
        fg.params[3] = (eye, vec![0.0; d]); // o
        let x = vec![0.5, -0.25, 0.75, 0.0, -0.5, 0.25];
        let out = fg.forward(&x);
        for j in 0..d {
            let col: Vec<f64> = (0..seq).map(|t| x[t * d + j]).collect();
            let (lo, hi) = (
                col.iter().cloned().fold(f64::INFINITY, f64::min),
                col.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            );
            for t in 0..seq {
                let o = out[t * d + j];
                assert!(o >= lo - 0.05 && o <= hi + 0.05, "token {t} col {j}: {o} ∉ [{lo},{hi}]");
            }
        }
    }
}
