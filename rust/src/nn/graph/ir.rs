//! The typed operator-graph data model.
//!
//! A [`GraphSpec`] is a straight-line dataflow graph over *per-sample*
//! tensor values: value `0` is the graph input, and the op at index `i`
//! produces value `i + 1`. Every value is a flat per-sample vector
//! (batches add a leading row dimension at lowering time, exactly like
//! `MlpSpec`); ops that carry 2-D structure ([`OpKind::Conv2d`]) or
//! sequence structure ([`OpKind::Attention`]) record their geometry in
//! the op itself and interpret the flat vector accordingly.
//!
//! **Row independence invariant:** every op maps sample rows to sample
//! rows independently — attention attends *within* one sample's
//! `seq × d` tokens, never across the batch. This is what lets graph
//! nets ride the forward batch ladder and serve through `serve/` with
//! micro-batching bit-exact against batch-1 execution.

use crate::fixed::FixedSpec;
use crate::hw::COLUMN_LEN;
use crate::nn::lut::ActKind;
use crate::nn::mlp::{LutParams, MAX_DIM};
use thiserror::Error;

/// Index of a value in a [`GraphSpec`]: `0` is the graph input, the op
/// at index `i` produces value `i + 1`.
pub type ValueId = usize;

/// Conv2d geometry: valid (no-padding) convolution over a per-sample
/// `(channels, height, width)` channel-major input volume, producing a
/// `(out_h, out_w, out_c)` *position-major* output vector — positions
/// outer, output channels inner, so the conv output doubles as the
/// `(batch·out_h·out_w) × out_c` matrix the im2col dot waves write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dGeom {
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Input channels.
    pub in_c: usize,
    /// Output channels.
    pub out_c: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride (both axes).
    pub stride: usize,
}

impl Conv2dGeom {
    /// Output height (valid padding, floor semantics).
    pub fn out_h(&self) -> usize {
        if self.in_h < self.kh || self.stride == 0 {
            return 0;
        }
        (self.in_h - self.kh) / self.stride + 1
    }

    /// Output width (valid padding, floor semantics).
    pub fn out_w(&self) -> usize {
        if self.in_w < self.kw || self.stride == 0 {
            return 0;
        }
        (self.in_w - self.kw) / self.stride + 1
    }

    /// im2col patch length (`in_c · kh · kw`) — the fan-in of the dense
    /// dot the convolution lowers to.
    pub fn patch(&self) -> usize {
        self.in_c * self.kh * self.kw
    }

    /// Per-sample input vector length (`in_c · in_h · in_w`).
    pub fn in_dim(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Per-sample output vector length (`out_h · out_w · out_c`).
    pub fn out_dim(&self) -> usize {
        self.out_h() * self.out_w() * self.out_c
    }
}

/// One operator kind. Arity (number of input values) is 1 for all
/// kinds except the elementwise combinators, which take 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Dense `x·W + b`: per-sample `n_in → outputs`. Weights are
    /// `(n_in, outputs)` row-major, exactly like an `MlpSpec` layer.
    Linear {
        /// Fan-out.
        outputs: usize,
    },
    /// LUT activation applied elementwise over the input value.
    Activation {
        /// Table function.
        act: ActKind,
    },
    /// Elementwise sum of two same-shaped values (residual connection).
    ElemAdd,
    /// Elementwise product of two same-shaped values (gating).
    ElemMul,
    /// Layernorm-style row normalisation: the per-sample vector is
    /// split into `dim / cols` groups of `cols` lanes; each group is
    /// centred and scaled by `1/√(var + ε)` via the `Rsqrt` table
    /// (no learned affine). `cols == dim` is classic layernorm.
    Normalization {
        /// Group width (must divide the input dimension).
        cols: usize,
    },
    /// 2-D convolution via im2col onto the chunked-dot machinery.
    Conv2d(Conv2dGeom),
    /// Single-head self-attention over a per-sample `seq × d` token
    /// matrix: `softmax(QKᵀ/√d)·V·Wo + bo` with `Q/K/V = x·W* + b*`.
    /// Softmax is `Exp` + row-sum + `Recip` LUTs (the ISA has no
    /// divide, and no max-subtraction — documented in DESIGN.md).
    Attention {
        /// Tokens per sample.
        seq: usize,
        /// Model width per token (`dim == seq · d`).
        d: usize,
    },
}

/// One operator instance: a kind plus its input value ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Op {
    /// What it computes.
    pub kind: OpKind,
    /// Input values (arity checked by [`GraphSpec::check`]).
    pub ins: Vec<ValueId>,
}

/// Graph validation errors.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum GraphError {
    /// No operators.
    #[error("graph has no ops")]
    Empty,
    /// Graph input dimension out of range.
    #[error("graph input dimension {0} out of range 1..={MAX_DIM}")]
    BadInput(usize),
    /// Wrong number of op inputs.
    #[error("op {op}: expects {want} inputs, got {got}")]
    Arity {
        /// Op index.
        op: usize,
        /// Required arity.
        want: usize,
        /// Provided arity.
        got: usize,
    },
    /// An op references a value that is not yet defined (ops may only
    /// consume the graph input or earlier ops' outputs).
    #[error("op {op}: input value {value} is not defined yet")]
    UnknownValue {
        /// Op index.
        op: usize,
        /// Offending value id.
        value: ValueId,
    },
    /// Elementwise inputs disagree on shape.
    #[error("op {op}: elementwise inputs disagree: {a} vs {b}")]
    DimMismatch {
        /// Op index.
        op: usize,
        /// First input dimension.
        a: usize,
        /// Second input dimension.
        b: usize,
    },
    /// A dimension is zero or exceeds the assembler's chunking limit.
    #[error("op {op}: dimension {dim} out of range 1..={MAX_DIM}")]
    BadDim {
        /// Op index.
        op: usize,
        /// Offending dimension.
        dim: usize,
    },
    /// A dimension this op cannot chunk exceeds one 512-lane column.
    #[error("op {op}: {what} {dim} exceeds one column ({COLUMN_LEN})")]
    TooWide {
        /// Op index.
        op: usize,
        /// Which dimension.
        what: &'static str,
        /// Offending dimension.
        dim: usize,
    },
    /// Normalization group width does not divide the input dimension.
    #[error("op {op}: group width {cols} does not divide dimension {dim}")]
    NotDivisible {
        /// Op index.
        op: usize,
        /// Group width.
        cols: usize,
        /// Input dimension.
        dim: usize,
    },
    /// An op's declared geometry disagrees with its input dimension.
    #[error("op {op}: geometry expects input dimension {want}, got {got}")]
    GeometryMismatch {
        /// Op index.
        op: usize,
        /// Dimension the geometry implies.
        want: usize,
        /// Actual input dimension.
        got: usize,
    },
}

/// A full operator-graph network specification.
///
/// The graph output is the **last op's value**. Build with
/// [`GraphSpec::new`] plus the builder methods, then [`check`]
/// (lowering checks for you).
///
/// [`check`]: GraphSpec::check
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    /// Network name.
    pub name: String,
    /// Per-sample input dimension (value 0).
    pub input: usize,
    /// Operators in definition order; op `i` produces value `i + 1`.
    pub ops: Vec<Op>,
    /// Datapath fixed-point format.
    pub fixed: FixedSpec,
    /// Activation-table parameters.
    pub lut: LutParams,
}

/// The graph input's [`ValueId`].
pub const INPUT: ValueId = 0;

/// One weight/bias parameter pair as it appears in the lowered
/// program: `w` is `(rows × cols)` row-major, the bias is `cols` lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamDecl {
    /// Index of the op owning this pair.
    pub op: usize,
    /// Weight buffer name in the lowered program.
    pub wname: String,
    /// Bias buffer name in the lowered program.
    pub bname: String,
    /// Weight rows (fan-in).
    pub rows: usize,
    /// Weight columns = bias length (fan-out).
    pub cols: usize,
}

impl GraphSpec {
    /// Start an empty graph with the given per-sample input dimension.
    pub fn new(name: &str, input: usize, fixed: FixedSpec, lut: LutParams) -> GraphSpec {
        GraphSpec { name: name.to_string(), input, ops: Vec::new(), fixed, lut }
    }

    fn push(&mut self, kind: OpKind, ins: Vec<ValueId>) -> ValueId {
        self.ops.push(Op { kind, ins });
        self.ops.len()
    }

    /// Append a dense layer on `input`, returning the new value.
    pub fn linear(&mut self, input: ValueId, outputs: usize) -> ValueId {
        self.push(OpKind::Linear { outputs }, vec![input])
    }

    /// Append a LUT activation on `input`.
    pub fn activation(&mut self, input: ValueId, act: ActKind) -> ValueId {
        self.push(OpKind::Activation { act }, vec![input])
    }

    /// Append an elementwise sum of `a` and `b` (residual connection).
    pub fn add(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::ElemAdd, vec![a, b])
    }

    /// Append an elementwise product of `a` and `b`.
    pub fn mul(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::ElemMul, vec![a, b])
    }

    /// Append a row normalisation with group width `cols`.
    pub fn normalization(&mut self, input: ValueId, cols: usize) -> ValueId {
        self.push(OpKind::Normalization { cols }, vec![input])
    }

    /// Append a 2-D convolution with the given geometry.
    pub fn conv2d(&mut self, input: ValueId, geom: Conv2dGeom) -> ValueId {
        self.push(OpKind::Conv2d(geom), vec![input])
    }

    /// Append a single-head self-attention block over `seq` tokens of
    /// width `d`.
    pub fn attention(&mut self, input: ValueId, seq: usize, d: usize) -> ValueId {
        self.push(OpKind::Attention { seq, d }, vec![input])
    }

    /// Per-value dimensions (`dims[0]` is the input), validating the
    /// whole graph along the way. [`check`](GraphSpec::check) is this
    /// with the dimensions thrown away.
    pub fn value_dims(&self) -> Result<Vec<usize>, GraphError> {
        if self.ops.is_empty() {
            return Err(GraphError::Empty);
        }
        if self.input == 0 || self.input > MAX_DIM {
            return Err(GraphError::BadInput(self.input));
        }
        let mut dims = Vec::with_capacity(self.ops.len() + 1);
        dims.push(self.input);
        for (i, op) in self.ops.iter().enumerate() {
            let want = match op.kind {
                OpKind::ElemAdd | OpKind::ElemMul => 2,
                _ => 1,
            };
            if op.ins.len() != want {
                return Err(GraphError::Arity { op: i, want, got: op.ins.len() });
            }
            for &v in &op.ins {
                if v >= dims.len() {
                    return Err(GraphError::UnknownValue { op: i, value: v });
                }
            }
            let a = dims[op.ins[0]];
            let out = match op.kind {
                OpKind::Linear { outputs } => {
                    if outputs == 0 || outputs > MAX_DIM {
                        return Err(GraphError::BadDim { op: i, dim: outputs });
                    }
                    outputs
                }
                OpKind::Activation { .. } => a,
                OpKind::ElemAdd | OpKind::ElemMul => {
                    let b = dims[op.ins[1]];
                    if a != b {
                        return Err(GraphError::DimMismatch { op: i, a, b });
                    }
                    a
                }
                OpKind::Normalization { cols } => {
                    if cols == 0 {
                        return Err(GraphError::BadDim { op: i, dim: cols });
                    }
                    if cols > COLUMN_LEN {
                        // group sums/variances are single VECTOR_SUMMATION
                        // lanes and cannot chunk
                        return Err(GraphError::TooWide { op: i, what: "group width", dim: cols });
                    }
                    if a % cols != 0 {
                        return Err(GraphError::NotDivisible { op: i, cols, dim: a });
                    }
                    a
                }
                OpKind::Conv2d(g) => {
                    for d in [g.in_h, g.in_w, g.in_c, g.out_c, g.kh, g.kw, g.stride] {
                        if d == 0 {
                            return Err(GraphError::BadDim { op: i, dim: d });
                        }
                    }
                    if g.kw > COLUMN_LEN {
                        // im2col copies one kw-pixel strip per lane and
                        // cannot chunk
                        return Err(GraphError::TooWide { op: i, what: "kernel width", dim: g.kw });
                    }
                    if g.kh > g.in_h || g.kw > g.in_w {
                        return Err(GraphError::GeometryMismatch {
                            op: i,
                            want: g.kh.max(g.kw),
                            got: g.in_h.min(g.in_w),
                        });
                    }
                    if g.in_dim() != a {
                        return Err(GraphError::GeometryMismatch { op: i, want: g.in_dim(), got: a });
                    }
                    let out = g.out_dim();
                    if out == 0 || out > MAX_DIM {
                        return Err(GraphError::BadDim { op: i, dim: out });
                    }
                    if g.patch() > MAX_DIM {
                        return Err(GraphError::BadDim { op: i, dim: g.patch() });
                    }
                    out
                }
                OpKind::Attention { seq, d } => {
                    if seq == 0 || d == 0 {
                        return Err(GraphError::BadDim { op: i, dim: seq.min(d) });
                    }
                    // per-token dots (vec_len d) and per-row softmax
                    // lanes (vec_len seq) cannot chunk
                    if d > COLUMN_LEN {
                        return Err(GraphError::TooWide { op: i, what: "head width", dim: d });
                    }
                    if seq > COLUMN_LEN {
                        return Err(GraphError::TooWide { op: i, what: "sequence", dim: seq });
                    }
                    if seq * d != a {
                        return Err(GraphError::GeometryMismatch { op: i, want: seq * d, got: a });
                    }
                    a
                }
            };
            if out == 0 || out > MAX_DIM {
                return Err(GraphError::BadDim { op: i, dim: out });
            }
            dims.push(out);
        }
        Ok(dims)
    }

    /// Validate the graph (typing, arity, dimension ranges).
    pub fn check(&self) -> Result<(), GraphError> {
        self.value_dims().map(|_| ())
    }

    /// Per-sample input dimension.
    pub fn input_dim(&self) -> usize {
        self.input
    }

    /// Per-sample output dimension (last op's value). Call only on a
    /// graph that passes [`check`](GraphSpec::check).
    pub fn output_dim(&self) -> usize {
        *self.value_dims().expect("output_dim on an invalid graph").last().unwrap()
    }

    /// Weight/bias parameter pairs in lowered-program order (op order;
    /// attention contributes four pairs q, k, v, o). Buffer names here
    /// are exactly the names the lowered programs declare, so trainers
    /// and the serving runtime can address parameters generically.
    pub fn param_decls(&self) -> Result<Vec<ParamDecl>, GraphError> {
        let dims = self.value_dims()?;
        let mut out = Vec::new();
        let mut n_linear = 0usize;
        let mut n_conv = 0usize;
        let mut n_attn = 0usize;
        for (i, op) in self.ops.iter().enumerate() {
            match op.kind {
                OpKind::Linear { outputs } => {
                    out.push(ParamDecl {
                        op: i,
                        wname: format!("w{n_linear}"),
                        bname: format!("b{n_linear}"),
                        rows: dims[op.ins[0]],
                        cols: outputs,
                    });
                    n_linear += 1;
                }
                OpKind::Conv2d(g) => {
                    out.push(ParamDecl {
                        op: i,
                        wname: format!("wc{n_conv}"),
                        bname: format!("bc{n_conv}"),
                        rows: g.patch(),
                        cols: g.out_c,
                    });
                    n_conv += 1;
                }
                OpKind::Attention { d, .. } => {
                    for proj in ["q", "k", "v", "o"] {
                        out.push(ParamDecl {
                            op: i,
                            wname: format!("w{proj}{n_attn}"),
                            bname: format!("b{proj}{n_attn}"),
                            rows: d,
                            cols: d,
                        });
                    }
                    n_attn += 1;
                }
                _ => {}
            }
        }
        Ok(out)
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.param_decls()
            .map(|ds| ds.iter().map(|d| d.rows * d.cols + d.cols).sum())
            .unwrap_or(0)
    }

    /// Parameter bytes at 16 bits/lane (what the cluster must ship to
    /// a board when placing this net).
    pub fn param_bytes(&self) -> u64 {
        self.param_count() as u64 * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(input: usize) -> GraphSpec {
        GraphSpec::new("g", input, FixedSpec::PAPER, LutParams::training(FixedSpec::PAPER))
    }

    #[test]
    fn mlp_chain_dims_and_params() {
        let mut s = g(4);
        let v1 = s.linear(INPUT, 16);
        let v2 = s.activation(v1, ActKind::Relu);
        let v3 = s.linear(v2, 3);
        let v4 = s.activation(v3, ActKind::Identity);
        assert_eq!(v4, 4);
        assert_eq!(s.value_dims().unwrap(), vec![4, 16, 16, 3, 3]);
        assert_eq!(s.output_dim(), 3);
        let ps = s.param_decls().unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!((ps[0].wname.as_str(), ps[0].rows, ps[0].cols), ("w0", 4, 16));
        assert_eq!((ps[1].wname.as_str(), ps[1].rows, ps[1].cols), ("w1", 16, 3));
        assert_eq!(s.param_count(), 4 * 16 + 16 + 16 * 3 + 3);
        assert_eq!(s.param_bytes(), 2 * s.param_count() as u64);
    }

    #[test]
    fn conv_geometry() {
        let geom = Conv2dGeom { in_h: 6, in_w: 6, in_c: 2, out_c: 3, kh: 3, kw: 3, stride: 1 };
        assert_eq!((geom.out_h(), geom.out_w()), (4, 4));
        assert_eq!(geom.patch(), 18);
        assert_eq!(geom.in_dim(), 72);
        assert_eq!(geom.out_dim(), 48);
        // stride 2 floors
        let s2 = Conv2dGeom { stride: 2, ..geom };
        assert_eq!((s2.out_h(), s2.out_w()), (2, 2));
        assert_eq!(s2.out_dim(), 12);
        let mut s = g(72);
        s.conv2d(INPUT, geom);
        assert_eq!(s.value_dims().unwrap(), vec![72, 48]);
        let ps = s.param_decls().unwrap();
        assert_eq!((ps[0].wname.as_str(), ps[0].rows, ps[0].cols), ("wc0", 18, 3));
    }

    #[test]
    fn attention_and_residual_dims() {
        let mut s = g(12); // 4 tokens × width 3
        let a = s.attention(INPUT, 4, 3);
        let r = s.add(a, INPUT);
        let n = s.normalization(r, 3);
        assert_eq!(s.value_dims().unwrap(), vec![12, 12, 12, 12]);
        assert_eq!(n, 3);
        let ps = s.param_decls().unwrap();
        assert_eq!(ps.len(), 4);
        assert_eq!(
            ps.iter().map(|p| p.wname.as_str()).collect::<Vec<_>>(),
            vec!["wq0", "wk0", "wv0", "wo0"]
        );
        assert!(ps.iter().all(|p| (p.rows, p.cols) == (3, 3)));
    }

    #[test]
    fn rejects_malformed_graphs() {
        assert_eq!(g(4).check(), Err(GraphError::Empty));
        let mut s = g(0);
        s.linear(INPUT, 2);
        assert_eq!(s.check(), Err(GraphError::BadInput(0)));

        // forward reference
        let mut s = g(4);
        s.ops.push(Op { kind: OpKind::ElemAdd, ins: vec![INPUT, 3] });
        assert_eq!(s.check(), Err(GraphError::UnknownValue { op: 0, value: 3 }));

        // arity
        let mut s = g(4);
        s.ops.push(Op { kind: OpKind::ElemAdd, ins: vec![INPUT] });
        assert_eq!(s.check(), Err(GraphError::Arity { op: 0, want: 2, got: 1 }));

        // elementwise shape mismatch
        let mut s = g(4);
        let v1 = s.linear(INPUT, 5);
        s.add(v1, INPUT);
        assert_eq!(s.check(), Err(GraphError::DimMismatch { op: 1, a: 5, b: 4 }));

        // normalization divisibility and width
        let mut s = g(10);
        s.normalization(INPUT, 3);
        assert_eq!(s.check(), Err(GraphError::NotDivisible { op: 0, cols: 3, dim: 10 }));
        let mut s = g(MAX_DIM);
        s.normalization(INPUT, COLUMN_LEN + 1);
        assert_eq!(
            s.check(),
            Err(GraphError::TooWide { op: 0, what: "group width", dim: COLUMN_LEN + 1 })
        );

        // conv geometry vs input dim
        let mut s = g(50);
        s.conv2d(
            INPUT,
            Conv2dGeom { in_h: 6, in_w: 6, in_c: 2, out_c: 3, kh: 3, kw: 3, stride: 1 },
        );
        assert_eq!(s.check(), Err(GraphError::GeometryMismatch { op: 0, want: 72, got: 50 }));

        // attention geometry
        let mut s = g(13);
        s.attention(INPUT, 4, 3);
        assert_eq!(s.check(), Err(GraphError::GeometryMismatch { op: 0, want: 12, got: 13 }));
    }
}
