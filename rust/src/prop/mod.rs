//! Mini property-based testing framework (`proptest` is not in the
//! sandbox's vendored crate set; see DESIGN.md §2).
//!
//! Provides seeded generators, a configurable case count, and greedy
//! shrinking: on failure the framework repeatedly asks the generator's
//! paired `shrink` function for smaller candidates and reports the smallest
//! failing input it can find.
//!
//! ```no_run
//! // (no_run: doctest executables lack the xla_extension rpath)
//! use mfnn::prop::{check, Gen};
//! check("add_commutes", Gen::pair(Gen::i16s(), Gen::i16s()), |&(a, b)| {
//!     (a as i32 + b as i32) == (b as i32 + a as i32)
//! });
//! ```

use crate::util::Rng;
use std::fmt::Debug;
use std::rc::Rc;

/// Number of cases per property (override with `MFNN_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("MFNN_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(256)
}

type GenFn<T> = Rc<dyn Fn(&mut Rng) -> T>;
type ShrinkFn<T> = Rc<dyn Fn(&T) -> Vec<T>>;

/// A generator: a sampling function plus a shrinker.
#[derive(Clone)]
pub struct Gen<T> {
    sample: GenFn<T>,
    shrink: ShrinkFn<T>,
}

impl<T: Clone + 'static> Gen<T> {
    /// Build from closures.
    pub fn new(
        sample: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Gen<T> {
        Gen { sample: Rc::new(sample), shrink: Rc::new(shrink) }
    }

    /// Sample one value.
    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.sample)(rng)
    }

    /// Shrink candidates (smaller-first preferred).
    pub fn shrink(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value. There is no inverse of `f` to pull
    /// mapped-domain candidates back through, so the result does **not**
    /// shrink — prefer [`Gen::map_with_shrink`] whenever a shrinker can
    /// be stated in the mapped domain.
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        self.map_with_shrink(f, |_| Vec::new())
    }

    /// Map the generated value while supplying a shrinker in the
    /// *mapped* domain, so mapped generators keep shrinking end to end
    /// instead of silently losing their shrinker like [`Gen::map`]
    /// does. (Generators with richly structured cases — e.g. the
    /// testkit's — may instead pair a custom sampler and shrinker via
    /// [`Gen::new`] directly; this combinator is for the quick-map
    /// case.)
    pub fn map_with_shrink<U: Clone + 'static>(
        self,
        f: impl Fn(T) -> U + 'static,
        shrink: impl Fn(&U) -> Vec<U> + 'static,
    ) -> Gen<U> {
        let sample = self.sample.clone();
        Gen::new(move |rng| f(sample(rng)), shrink)
    }
}

impl Gen<i64> {
    /// Integers in `[lo, hi]`, shrinking toward 0 (or the bound nearest 0).
    pub fn int_range(lo: i64, hi: i64) -> Gen<i64> {
        assert!(lo <= hi);
        let target = 0i64.clamp(lo, hi);
        Gen::new(
            move |rng| rng.gen_range_i64(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v != target {
                    out.push(target);
                    let mid = target + (v - target) / 2;
                    if mid != v && mid != target {
                        out.push(mid);
                    }
                    if (v - target).abs() > 1 {
                        out.push(v - (v - target).signum());
                    }
                }
                out
            },
        )
    }
}

impl Gen<i16> {
    /// Full-range `i16`, shrinking toward 0.
    pub fn i16s() -> Gen<i16> {
        Gen::new(
            |rng| rng.gen_i16(),
            |&v| {
                let mut out = Vec::new();
                if v != 0 {
                    out.push(0);
                    out.push(v / 2);
                    if v.abs() > 1 {
                        out.push(v - v.signum());
                    }
                }
                out.dedup();
                out
            },
        )
    }
}

impl<T: Clone + Debug + 'static> Gen<Vec<T>> {
    /// Vectors of `elem` with length in `[min_len, max_len]`; shrinks by
    /// halving length, dropping elements, and shrinking elements.
    pub fn vec(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
        assert!(min_len <= max_len);
        let e2 = elem.clone();
        Gen::new(
            move |rng| {
                let len = min_len + rng.gen_range((max_len - min_len + 1) as u64) as usize;
                (0..len).map(|_| elem.sample(rng)).collect()
            },
            move |v: &Vec<T>| {
                let mut out: Vec<Vec<T>> = Vec::new();
                if v.len() > min_len {
                    // halve toward min_len
                    let half = (v.len() / 2).max(min_len);
                    out.push(v[..half].to_vec());
                    // drop last element
                    out.push(v[..v.len() - 1].to_vec());
                }
                // shrink the first shrinkable element
                for (i, x) in v.iter().enumerate() {
                    let cands = e2.shrink(x);
                    if let Some(c) = cands.first() {
                        let mut w = v.clone();
                        w[i] = c.clone();
                        out.push(w);
                        break;
                    }
                }
                out
            },
        )
    }
}

impl<A: Clone + 'static, B: Clone + 'static> Gen<(A, B)> {
    /// Pair generator; shrinks each component independently.
    pub fn pair(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
        let (a2, b2) = (a.clone(), b.clone());
        Gen::new(
            move |rng| (a.sample(rng), b.sample(rng)),
            move |(x, y)| {
                let mut out = Vec::new();
                for xs in a2.shrink(x) {
                    out.push((xs, y.clone()));
                }
                for ys in b2.shrink(y) {
                    out.push((x.clone(), ys));
                }
                out
            },
        )
    }
}

/// Result of a failed property with the shrunk counterexample rendered.
#[derive(Debug)]
pub struct PropFailure {
    /// Property name.
    pub name: String,
    /// Seed that reproduces the failure.
    pub seed: u64,
    /// Debug rendering of the (shrunk) counterexample.
    pub counterexample: String,
    /// Number of shrink steps applied.
    pub shrink_steps: usize,
}

/// Run a property over `default_cases()` random cases; panics with the
/// shrunk counterexample on failure. Seed is derived from the name so runs
/// are deterministic but properties are decorrelated.
pub fn check<T: Clone + Debug + 'static>(name: &str, gen: Gen<T>, prop: impl Fn(&T) -> bool) {
    if let Err(f) = check_quiet(name, gen, prop) {
        panic!(
            "property {:?} failed (seed {}): counterexample after {} shrinks: {}",
            f.name, f.seed, f.shrink_steps, f.counterexample
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking.
pub fn check_quiet<T: Clone + Debug + 'static>(
    name: &str,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) -> Result<(), PropFailure> {
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Rng::new(seed);
    for _case in 0..default_cases() {
        let v = gen.sample(&mut rng);
        if !prop(&v) {
            // Greedy shrink.
            let mut best = v;
            let mut steps = 0usize;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    if !prop(&cand) {
                        best = cand;
                        steps += 1;
                        if steps > 10_000 {
                            break 'outer;
                        }
                        continue 'outer;
                    }
                }
                break;
            }
            return Err(PropFailure {
                name: name.to_string(),
                seed,
                counterexample: format!("{best:?}"),
                shrink_steps: steps,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("i16_double_negate", Gen::i16s(), |&v| v.wrapping_neg().wrapping_neg() == v);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // "all values < 100" fails; minimal counterexample is 100.
        let f = check_quiet("lt_100", Gen::int_range(0, 10_000), |&v| v < 100).unwrap_err();
        assert_eq!(f.counterexample, "100");
    }

    #[test]
    fn vec_generator_respects_bounds_and_shrinks() {
        let g = Gen::vec(Gen::i16s(), 1, 16);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let v = g.sample(&mut rng);
            assert!((1..=16).contains(&v.len()));
        }
        // property: no vector contains a nonzero element → fails; shrinks to
        // a single-element vector.
        let f =
            check_quiet("all_zero", g, |v: &Vec<i16>| v.iter().all(|&x| x == 0)).unwrap_err();
        let shrunk: Vec<i16> = {
            // parse "[x]" debug form loosely: just check it's length 1
            let inner = f.counterexample.trim_start_matches('[').trim_end_matches(']');
            inner.split(',').map(|s| s.trim().parse().unwrap()).collect()
        };
        assert_eq!(shrunk.len(), 1, "expected single-element shrink, got {f:?}");
    }

    #[test]
    fn pair_generator_shrinks_components() {
        let g = Gen::pair(Gen::int_range(0, 1000), Gen::int_range(0, 1000));
        let f = check_quiet("sum_lt_500", g, |&(a, b)| a + b < 500).unwrap_err();
        // minimal failing sum is 500 with one side 0 or both shrunk
        assert!(f.counterexample.contains("500") || f.shrink_steps > 0);
    }

    #[test]
    fn map_with_shrink_threads_shrinking_through_the_map() {
        // Doubled integers with a mapped-domain shrinker: the minimal
        // even failing value of "v < 100" is 100.
        let g = Gen::int_range(0, 500).map_with_shrink(
            |v| v * 2,
            |&v| if v == 0 { Vec::new() } else { vec![0, v - 2] },
        );
        let f = check_quiet("even_lt_100", g, |&v| v < 100).unwrap_err();
        assert_eq!(f.counterexample, "100");
        assert!(f.shrink_steps > 0 || f.counterexample == "100");
    }

    #[test]
    fn plain_map_samples_but_does_not_shrink() {
        let g = Gen::int_range(0, 500).map(|v| v * 2);
        let f = check_quiet("map_lt_100", g, |&v| v < 100).unwrap_err();
        assert_eq!(f.shrink_steps, 0, "map has no inverse; it must not shrink");
    }

    #[test]
    fn deterministic_by_name() {
        let f1 = check_quiet("det", Gen::int_range(0, 1 << 30), |&v| v < 5).unwrap_err();
        let f2 = check_quiet("det", Gen::int_range(0, 1 << 30), |&v| v < 5).unwrap_err();
        assert_eq!(f1.counterexample, f2.counterexample);
    }
}
