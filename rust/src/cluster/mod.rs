//! Multi-FPGA cluster runtime — the paper's "§2 scaling requirement:
//! "the Matrix Machine must scale to any number of FPGAs":
//!
//! * M > F MLPs: "processed sequentially" — per-board job queues.
//! * M < F: "the MLPs are divided and are processed in parallel" — each
//!   MLP gets a group of boards running synchronous data-parallel
//!   training with periodic fixed-point weight averaging (our
//!   concretisation of "divided", documented in DESIGN.md §2).
//! * M = F: "maps 1 MLP to 1 FPGA".
//!
//! Boards are dual-workload: alongside the training protocol
//! (`NewTrainer`/`SetWeights`/`TrainChunk`/`Evaluate`), workers accept
//! `InferChunk` — serve one inference micro-batch of any size on a
//! job's current parameters ([`infer_on`] is the leader-side entry) —
//! so the same boards train and serve (DESIGN.md §Serving).
//!
//! Architecture (tokio is unavailable — std threads + bounded channels
//! provide the same backpressure semantics):
//!
//! ```text
//!   leader (one orchestrator thread per board-group)
//!     │  sync_channel(1) per board  — bounded ⇒ backpressure
//!     ▼
//!   worker thread per FPGA board — owns the board's Trainers
//!     │  mpsc replies (chunk results, weights, evaluations)
//!     ▼
//!   leader aggregates: weight averaging, bus-time accounting, metrics
//! ```
//!
//! Time is **simulated**: compute time comes from the Matrix Machine's
//! cycle model, transfer time from the [`bus`] model; the makespan of a
//! schedule is the max over boards of accumulated simulated time. Wall
//! clock is also reported (it measures the simulator, not the modelled
//! hardware).
//!
//! The runtime is **crash-tolerant** (DESIGN.md §Recovery): under the
//! default [`RecoveryPolicy`] the leader retries checksum-failed chunks
//! over the bus, evicts dead/persistently-failing boards, reschedules
//! their outstanding chunks onto survivors **bit-identically** to the
//! fault-free run, and captures deterministic [`TrainCheckpoint`]s that
//! resume a job bit-exactly (`Session::train_with`,
//! `mfnn train --checkpoint-every/--resume`).

pub mod bus;
pub mod checkpoint;
pub mod cost;
pub mod fault;
pub mod leader;
pub mod metrics;
pub mod recovery;
pub mod scheduler;
pub mod worker;

pub use bus::{params_checksum, SystemBus};
pub use checkpoint::{RunIdentity, TrainCheckpoint};
pub use cost::{ring_sync_cost, star_sync_cost, SyncCost, SyncPolicy, BUS_CLOCK_HZ};
pub use fault::{FaultPlan, FaultSite};
pub use leader::{
    execute, infer_on, ClusterConfig, ClusterError, ClusterReport, Job, JobResult, JobResume,
    Params,
};
#[allow(deprecated)]
pub use leader::run_cluster;
pub use metrics::{Metrics, MetricsSnapshot};
pub use recovery::RecoveryPolicy;
pub use scheduler::{schedule, Placement, PlacementMode};
