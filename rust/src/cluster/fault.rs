//! Deterministic fault injection for the cluster runtime.
//!
//! The testkit's fault differential (`crate::testkit`) must be able to
//! kill workers, corrupt parameter chunks in transit, and delay or
//! reorder replies — and replay *exactly* the same faults from a seed.
//! A [`FaultPlan`] is therefore a pure schedule: every fault is addressed
//! by an explicit `(board, event-index)` site, with no randomness at
//! injection time. The hooks live in [`super::worker`] (death, delay,
//! reorder, corruption) and [`super::leader`] (corrupt-chunk rejection
//! via the [`super::bus::params_checksum`] integrity word).
//!
//! The contract the leader must uphold under any plan: **never hang** —
//! finish with correct results (benign faults) or surface a typed
//! [`super::leader::ClusterError`] (lethal faults).

/// One injected fault site, addressed by board + a per-board event index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSite {
    /// Target board.
    pub board: usize,
    /// Per-board event index: the command index for deaths, the
    /// successful chunk-reply index for the chunk faults.
    pub at: usize,
}

/// A deterministic fault schedule for one cluster run. Empty by default
/// (no faults); [`super::ClusterConfig`] carries one per run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Worker death: the board's thread exits without replying, on
    /// receipt of its `at`-th command. The leader must surface
    /// [`super::leader::ClusterError::WorkerDied`].
    pub kills: Vec<FaultSite>,
    /// Corrupt the `at`-th chunk reply's parameters *after* the board
    /// checksummed them (simulated bus corruption); the leader must
    /// reject the chunk ([`super::leader::ClusterError::CorruptChunk`]).
    pub corruptions: Vec<FaultSite>,
    /// Delay the `at`-th chunk reply by ~1 ms of wall clock. The
    /// protocol is synchronous per board, so results must be unchanged.
    pub delays: Vec<FaultSite>,
    /// Send a stray out-of-order reply before the `at`-th chunk reply;
    /// the leader must surface a typed protocol error, not hang.
    pub reorders: Vec<FaultSite>,
}

impl FaultPlan {
    /// The empty plan (no faults) — what [`Default`] gives.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.corruptions.is_empty()
            && self.delays.is_empty()
            && self.reorders.is_empty()
    }

    /// True when every injected fault is result-preserving (delays only):
    /// the run must complete with results bit-identical to a clean run.
    pub fn is_benign(&self) -> bool {
        self.kills.is_empty() && self.corruptions.is_empty() && self.reorders.is_empty()
    }

    /// Schedule a worker death on `board` at command index `at`.
    pub fn kill(mut self, board: usize, at: usize) -> FaultPlan {
        self.kills.push(FaultSite { board, at });
        self
    }

    /// Schedule a parameter corruption on `board`'s `at`-th chunk reply.
    pub fn corrupt(mut self, board: usize, at: usize) -> FaultPlan {
        self.corruptions.push(FaultSite { board, at });
        self
    }

    /// Schedule a delay on `board`'s `at`-th chunk reply.
    pub fn delay(mut self, board: usize, at: usize) -> FaultPlan {
        self.delays.push(FaultSite { board, at });
        self
    }

    /// Schedule a stray out-of-order reply before `board`'s `at`-th
    /// chunk reply.
    pub fn reorder(mut self, board: usize, at: usize) -> FaultPlan {
        self.reorders.push(FaultSite { board, at });
        self
    }

    fn hits(sites: &[FaultSite], board: usize, at: usize) -> bool {
        sites.iter().any(|s| s.board == board && s.at == at)
    }

    /// Does `board`'s worker die on receipt of command `cmd`?
    pub(crate) fn dies_at(&self, board: usize, cmd: usize) -> bool {
        Self::hits(&self.kills, board, cmd)
    }

    /// Is `board`'s `chunk`-th chunk reply corrupted in transit?
    pub(crate) fn corrupts_chunk(&self, board: usize, chunk: usize) -> bool {
        Self::hits(&self.corruptions, board, chunk)
    }

    /// Is `board`'s `chunk`-th chunk reply delayed?
    pub(crate) fn delays_chunk(&self, board: usize, chunk: usize) -> bool {
        Self::hits(&self.delays, board, chunk)
    }

    /// Is a stray reply injected before `board`'s `chunk`-th chunk reply?
    pub(crate) fn reorders_chunk(&self, board: usize, chunk: usize) -> bool {
        Self::hits(&self.reorders, board, chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_injects_nothing() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(p.is_benign());
        assert!(!p.dies_at(0, 0));
        assert!(!p.corrupts_chunk(0, 0));
    }

    #[test]
    fn sites_address_board_and_index_exactly() {
        let p = FaultPlan::none().kill(1, 2).corrupt(0, 0).delay(2, 1).reorder(1, 0);
        assert!(p.dies_at(1, 2));
        assert!(!p.dies_at(1, 1));
        assert!(!p.dies_at(2, 2));
        assert!(p.corrupts_chunk(0, 0));
        assert!(p.delays_chunk(2, 1));
        assert!(p.reorders_chunk(1, 0));
        assert!(!p.is_empty());
        assert!(!p.is_benign());
    }

    #[test]
    fn delay_only_plans_are_benign() {
        let p = FaultPlan::none().delay(0, 0).delay(1, 3);
        assert!(p.is_benign());
        assert!(!p.is_empty());
    }
}
