//! System-bus model: host (control server) ↔ board transfers.
//!
//! "The system buses transfer the neural network data and microcode from
//! the control server to the onboard RAM" (§2). We model a shared
//! full-duplex link per board with fixed per-message latency + bandwidth,
//! defaulting to a gigabit-class link — the class of board-management
//! links the paper's Spartan-7 boards would carry.

/// Host↔board link model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemBus {
    /// Sustained bandwidth in bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency in seconds.
    pub latency_s: f64,
}

impl Default for SystemBus {
    fn default() -> Self {
        // 1 GbE-class: 125 MB/s, 50 µs per message.
        SystemBus { bandwidth_bps: 125e6, latency_s: 50e-6 }
    }
}

impl SystemBus {
    /// Seconds to move `bytes` in one message.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Seconds for a round trip moving `up` bytes out and `down` back.
    pub fn round_trip_s(&self, up: u64, down: u64) -> f64 {
        self.transfer_s(up) + self.transfer_s(down)
    }

    /// Modelled bus-controller cycles to move `bytes` in one message,
    /// at [`super::cost::BUS_CLOCK_HZ`] — the unit
    /// [`super::Metrics::sync_cycles`] accumulates.
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        super::cost::cycles_of(self.transfer_s(bytes))
    }
}

/// FNV-1a integrity word over a per-layer parameter set — the checksum a
/// board attaches to the parameters it returns over the bus, and the
/// leader re-derives to reject chunks corrupted in transit (the
/// fault-injection differential plants exactly such corruption; see
/// [`super::fault::FaultPlan::corruptions`]). Layer lengths are folded in
/// so differently-shaped layouts cannot collide by concatenation.
pub fn params_checksum(w: &[Vec<i16>], b: &[Vec<i16>]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for group in [w, b] {
        h = (h ^ group.len() as u64).wrapping_mul(PRIME);
        for layer in group {
            h = (h ^ layer.len() as u64).wrapping_mul(PRIME);
            for lane in layer {
                for byte in lane.to_le_bytes() {
                    h = (h ^ byte as u64).wrapping_mul(PRIME);
                }
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_dominates_small_messages() {
        let b = SystemBus::default();
        let t = b.transfer_s(64);
        assert!(t > b.latency_s && t < b.latency_s * 1.1);
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let b = SystemBus::default();
        // 125 MB at 125 MB/s ≈ 1 s
        let t = b.transfer_s(125_000_000);
        assert!((t - 1.0).abs() < 0.01);
    }

    #[test]
    fn transfer_cycles_scale_with_the_bus_clock() {
        let b = SystemBus { bandwidth_bps: 1e6, latency_s: 0.0 };
        // 1000 bytes at 1 MB/s = 1 ms = 100_000 cycles at 100 MHz.
        assert_eq!(b.transfer_cycles(1000), 100_000);
    }

    #[test]
    fn round_trip_sums() {
        let b = SystemBus { bandwidth_bps: 1e6, latency_s: 1e-3 };
        let t = b.round_trip_s(1000, 2000);
        assert!((t - (2e-3 + 0.003)).abs() < 1e-9);
    }

    #[test]
    fn checksum_detects_single_lane_flips_and_layout_shuffles() {
        let w = vec![vec![1i16, -2, 3], vec![4]];
        let b = vec![vec![5i16], vec![6]];
        let base = params_checksum(&w, &b);
        assert_eq!(base, params_checksum(&w.clone(), &b.clone()), "not deterministic");
        let mut flipped = w.clone();
        flipped[0][1] ^= 0x0400;
        assert_ne!(base, params_checksum(&flipped, &b));
        // moving a lane across the layer boundary must not collide
        let w2 = vec![vec![1i16, -2], vec![3, 4]];
        assert_ne!(base, params_checksum(&w2, &b));
        // swapping the weight/bias roles must not collide
        assert_ne!(base, params_checksum(&b, &w));
    }
}
