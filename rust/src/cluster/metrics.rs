//! Cluster-wide metrics registry (lock-free counters shared between the
//! leader and worker threads).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared atomic counters.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs completed.
    pub jobs_completed: AtomicU64,
    /// Total training steps executed (across boards).
    pub steps_total: AtomicU64,
    /// Total simulated machine cycles.
    pub sim_cycles: AtomicU64,
    /// Bytes moved over the system bus.
    pub bus_bytes: AtomicU64,
    /// Weight-synchronisation rounds performed.
    pub sync_rounds: AtomicU64,
    /// Modelled bus-controller cycles spent inside weight-sync
    /// collectives, under the [`super::cost`] contention model (star
    /// serializes on the leader's link; ring overlaps neighbours).
    pub sync_cycles: AtomicU64,
    /// Worker errors observed.
    pub errors: AtomicU64,
    /// Faults injected by the run's [`super::fault::FaultPlan`].
    pub faults_injected: AtomicU64,
    /// Inference micro-batches served (`Cmd::InferChunk` — the serving
    /// workload coexisting with training on the same boards).
    pub infer_chunks: AtomicU64,
    /// Corrupt parameter chunks re-read over the bus (`Cmd::ReadParams`
    /// retries under the run's [`super::recovery::RecoveryPolicy`]).
    pub chunk_retries: AtomicU64,
    /// Chunks recomputed on a surviving board after a death/eviction
    /// (divided-replica adoptions and single-job redispatches).
    pub chunks_rescheduled: AtomicU64,
    /// Boards evicted from the pool (dead or persistently failing).
    pub boards_evicted: AtomicU64,
    /// Deterministic checkpoints captured at chunk/sync boundaries.
    pub checkpoints_captured: AtomicU64,
}

impl Metrics {
    /// Fresh shared registry.
    pub fn shared() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    /// Add to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Read a consistent-enough snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            steps_total: self.steps_total.load(Ordering::Relaxed),
            sim_cycles: self.sim_cycles.load(Ordering::Relaxed),
            bus_bytes: self.bus_bytes.load(Ordering::Relaxed),
            sync_rounds: self.sync_rounds.load(Ordering::Relaxed),
            sync_cycles: self.sync_cycles.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            infer_chunks: self.infer_chunks.load(Ordering::Relaxed),
            chunk_retries: self.chunk_retries.load(Ordering::Relaxed),
            chunks_rescheduled: self.chunks_rescheduled.load(Ordering::Relaxed),
            boards_evicted: self.boards_evicted.load(Ordering::Relaxed),
            checkpoints_captured: self.checkpoints_captured.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Jobs completed.
    pub jobs_completed: u64,
    /// Total training steps executed.
    pub steps_total: u64,
    /// Total simulated machine cycles.
    pub sim_cycles: u64,
    /// Bytes moved over the system bus.
    pub bus_bytes: u64,
    /// Weight-sync rounds.
    pub sync_rounds: u64,
    /// Modelled bus cycles spent in weight-sync collectives.
    pub sync_cycles: u64,
    /// Worker errors.
    pub errors: u64,
    /// Injected faults that fired.
    pub faults_injected: u64,
    /// Inference micro-batches served.
    pub infer_chunks: u64,
    /// Corrupt chunks re-read over the bus (recovery retries).
    pub chunk_retries: u64,
    /// Chunks recomputed on a surviving board after death/eviction.
    pub chunks_rescheduled: u64,
    /// Boards evicted from the pool.
    pub boards_evicted: u64,
    /// Deterministic checkpoints captured.
    pub checkpoints_captured: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn concurrent_counting() {
        let m = Metrics::shared();
        thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        Metrics::add(&m.steps_total, 1);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().steps_total, 8000);
    }

    #[test]
    fn snapshot_reads_all_fields() {
        let m = Metrics::default();
        Metrics::add(&m.jobs_completed, 2);
        Metrics::add(&m.bus_bytes, 1024);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 2);
        assert_eq!(s.bus_bytes, 1024);
        assert_eq!(s.errors, 0);
    }
}
