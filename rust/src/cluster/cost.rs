//! Weight-sync policies and the discrete-event bus cost model.
//!
//! The paper stitches processor groups together with an on-chip ring
//! buffer; this module extends that ring to the *cluster*: instead of
//! the leader's star-shaped gather/average/broadcast (an O(k·P)
//! serialized hot spot on the leader's link), a group's k replicas can
//! run a simulated **ring all-reduce** — reduce-scatter then all-gather
//! over ⌈P/k⌉-sized chunks — moving O(P) bytes per board with every
//! link busy in parallel. A third policy, bounded-stale averaging,
//! trades bit-exact synchrony for fewer collectives.
//!
//! Three layers live here:
//!
//! * [`SyncPolicy`] — the pluggable policy carried by
//!   [`super::ClusterConfig`]: `Star` (the bit-exact default and
//!   oracle), `Ring` (bit-identical averages, ring-shaped cost), and
//!   `BoundedStale { max_lag }` (skip up to `max_lag` consecutive sync
//!   boundaries; validated by convergence oracles, not bit-exactness).
//! * [`ring_average`] — the simulated ring all-reduce itself. It
//!   produces **bit-identical** output to
//!   [`super::leader::average_weights`]: fixed-point addition is
//!   associative-commutative here because averaging already sums each
//!   lane in a wide `i32` accumulator before one truncating divide, so
//!   chunk-by-chunk summation in ring order cannot differ. That claim
//!   is **asserted** on every call in debug builds (and exhaustively by
//!   `tests/sync_policy.rs`), not assumed.
//! * [`BusModel`] — a small discrete-event simulator of per-endpoint
//!   link occupancy: each endpoint has full-duplex tx/rx frontiers, a
//!   message occupies both ends for its transfer time, and contention
//!   is what makes the star's leader link the bottleneck. The derived
//!   [`SyncCost`] charges (cycles at [`BUS_CLOCK_HZ`], bytes, seconds)
//!   feed [`super::Metrics::sync_cycles`] / `bus_bytes` and the
//!   `bench_cluster` scaling curves.
//!
//! Cost shape (asserted by the unit tests below): for k replicas of a
//! P-byte parameter set, star sync serializes 2k messages of P bytes on
//! the leader's link → makespan ~O(k·P); ring sync runs k parallel
//! transfers per round for 2(k−1) rounds of ⌈P/k⌉ bytes → makespan
//! ~O(P) per board (plus 2(k−1) latencies). Star's *byte* and *second*
//! charges are kept exactly equal to the pre-policy implementation so
//! every existing makespan and metric stays bit-identical.

use super::bus::SystemBus;

/// Modelled bus controller clock: cycle charges are
/// `seconds × BUS_CLOCK_HZ`, rounded. 100 MHz matches the DDR bus-clock
/// class of the paper's Table 8 boards.
pub const BUS_CLOCK_HZ: f64 = 100e6;

/// How a divided group's replicas synchronise weights at `sync_every`
/// boundaries. Carried by [`super::ClusterConfig::sync`]; recorded in
/// every [`super::RunIdentity`] so checkpoints refuse to resume under a
/// different policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncPolicy {
    /// Leader-centric gather / average / broadcast — the bit-exact
    /// default and the oracle every other policy is tested against.
    #[default]
    Star,
    /// Simulated ring all-reduce (reduce-scatter + all-gather over
    /// ⌈P/k⌉-sized chunks). Bit-identical averaged parameters to
    /// [`SyncPolicy::Star`] — asserted, not assumed — with ~O(P)
    /// per-board cost instead of O(k·P) at the leader.
    Ring,
    /// Bounded staleness: replicas proceed past up to `max_lag`
    /// consecutive sync boundaries on their own weights (derived from
    /// the last completed average), then a full collective is forced.
    /// `max_lag: 0` degenerates bit-exactly to [`SyncPolicy::Star`].
    /// The final boundary always syncs, so a job's result weights are
    /// a proper average. Validated by statistical-convergence oracles
    /// (the run completes, replays deterministically, and the loss
    /// does not diverge), not by bit-exactness.
    BoundedStale {
        /// Consecutive sync boundaries a replica may skip.
        max_lag: usize,
    },
}

impl SyncPolicy {
    /// Stable serialization tag (checkpoint format v2; CLI parsing).
    pub fn tag(&self) -> u32 {
        match self {
            SyncPolicy::Star => 0,
            SyncPolicy::Ring => 1,
            SyncPolicy::BoundedStale { .. } => 2,
        }
    }

    /// The policy's `max_lag` payload (0 for the deterministic ones).
    pub fn lag(&self) -> u32 {
        match self {
            SyncPolicy::BoundedStale { max_lag } => *max_lag as u32,
            _ => 0,
        }
    }

    /// Inverse of [`SyncPolicy::tag`]/[`SyncPolicy::lag`].
    pub fn from_tag(tag: u32, lag: u32) -> Option<SyncPolicy> {
        match tag {
            0 => Some(SyncPolicy::Star),
            1 => Some(SyncPolicy::Ring),
            2 => Some(SyncPolicy::BoundedStale { max_lag: lag as usize }),
            _ => None,
        }
    }

    /// Stable human name (CLI / corpus / bench note keys).
    pub fn name(&self) -> &'static str {
        match self {
            SyncPolicy::Star => "star",
            SyncPolicy::Ring => "ring",
            SyncPolicy::BoundedStale { .. } => "bounded-stale",
        }
    }

    /// Parse a CLI spelling (`star`, `ring`, `bounded-stale[:LAG]`;
    /// `stale` is accepted as shorthand, lag defaulting to 1).
    pub fn parse(s: &str) -> Option<SyncPolicy> {
        match s {
            "star" => return Some(SyncPolicy::Star),
            "ring" => return Some(SyncPolicy::Ring),
            "stale" | "bounded-stale" => {
                return Some(SyncPolicy::BoundedStale { max_lag: 1 })
            }
            _ => {}
        }
        let rest = s.strip_prefix("bounded-stale:").or_else(|| s.strip_prefix("stale:"))?;
        let max_lag: usize = rest.parse().ok()?;
        Some(SyncPolicy::BoundedStale { max_lag })
    }

    /// True when the policy guarantees bit-exact replay against
    /// [`SyncPolicy::Star`] (so the bit-exact differential oracles
    /// apply; `BoundedStale` uses the convergence oracle instead —
    /// except at `max_lag: 0`, which never skips a boundary).
    pub fn deterministic_vs_star(&self) -> bool {
        match self {
            SyncPolicy::Star | SyncPolicy::Ring => true,
            SyncPolicy::BoundedStale { max_lag } => *max_lag == 0,
        }
    }
}

impl std::fmt::Display for SyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SyncPolicy::BoundedStale { max_lag } => write!(f, "bounded-stale:{max_lag}"),
            other => f.write_str(other.name()),
        }
    }
}

// ------------------------------------------------------- ring all-reduce

/// Simulated ring all-reduce over per-layer parameter sets, producing
/// the **average** of the k replicas — bit-identical to
/// [`super::leader::average_weights`].
///
/// The schedule is the textbook one, run lane-exactly: the flattened
/// parameter vector is cut into k contiguous chunks; in reduce-scatter
/// round r, replica i adds its accumulator for chunk
/// `(i − r) mod k` into its successor's, so after k−1 rounds replica i
/// holds the full `i32` sum of chunk `(i + 1) mod k`; each owner then
/// divides by k once (the same truncating `i32 / k` as the star path)
/// and k−1 all-gather rounds circulate the finished chunks. Because
/// every lane is summed completely in `i32` before its single divide,
/// the ring's order of additions cannot change a bit — integer addition
/// is associative and commutative and k·|i16| fits `i32` — which is
/// exactly why the result equals the star average. `debug_assert`
/// enforces that equality on every call.
pub fn ring_average(replicas: &[Vec<Vec<i16>>]) -> Vec<Vec<i16>> {
    let k = replicas.len();
    assert!(k > 0);
    // Flatten layer boundaries away: chunking is over the whole P-lane
    // vector, as the wire schedule would see it.
    let layer_lens: Vec<usize> = replicas[0].iter().map(|l| l.len()).collect();
    let p: usize = layer_lens.iter().sum();
    let flat: Vec<Vec<i32>> = replicas
        .iter()
        .map(|r| r.iter().flat_map(|l| l.iter().map(|&v| v as i32)).collect())
        .collect();
    // Chunk c covers lanes chunk_start[c]..chunk_start[c+1].
    let chunk = p.div_ceil(k.max(1)).max(1);
    let bounds: Vec<(usize, usize)> =
        (0..k).map(|c| ((c * chunk).min(p), ((c + 1) * chunk).min(p))).collect();
    // Per-replica i32 accumulators (what each board's partial holds).
    let mut acc = flat.clone();
    // Reduce-scatter: k−1 rounds; in round r, replica i sends chunk
    // (i − r) mod k to replica (i+1) mod k, which adds it in.
    for r in 0..k.saturating_sub(1) {
        // Snapshot the chunks in flight this round so the simulated
        // transfers are simultaneous (no intra-round ordering effects).
        let outgoing: Vec<Vec<i32>> = (0..k)
            .map(|i| {
                let c = (i + k - r % k.max(1)) % k;
                let (s, e) = bounds[c];
                acc[i][s..e].to_vec()
            })
            .collect();
        for i in 0..k {
            let c = (i + k - r % k.max(1)) % k;
            let (s, e) = bounds[c];
            let dst = (i + 1) % k;
            for (j, v) in outgoing[i].iter().enumerate() {
                acc[dst][s + j] += v;
            }
        }
    }
    // After k−1 rounds replica i owns the fully-reduced chunk
    // (i+1) mod k; one truncating divide finishes the average.
    let mut out_flat = vec![0i16; p];
    for i in 0..k {
        let c = (i + 1) % k;
        let (s, e) = bounds[c];
        for j in s..e {
            out_flat[j] = (acc[i][j] / k as i32) as i16;
        }
    }
    // All-gather (k−1 more rounds) only moves the finished chunks — a
    // cost-model event, not a numeric one; `out_flat` is already the
    // complete vector every replica ends up holding.
    let mut out = Vec::with_capacity(layer_lens.len());
    let mut at = 0usize;
    for len in layer_lens {
        out.push(out_flat[at..at + len].to_vec());
        at += len;
    }
    debug_assert_eq!(
        out,
        super::leader::average_weights(replicas),
        "ring all-reduce must be bit-identical to the star average \
         (wide-accumulator associativity violated)"
    );
    out
}

// ------------------------------------------------- discrete-event model

/// One bus endpoint's full-duplex occupancy frontiers (seconds).
#[derive(Debug, Clone, Copy, Default)]
struct Endpoint {
    tx_free_s: f64,
    rx_free_s: f64,
}

/// Discrete-event model of per-message link contention. Endpoint 0 is
/// the leader/host; endpoints 1..=n are boards. A message from `src` to
/// `dst` starts when both `src`'s transmitter and `dst`'s receiver are
/// free, occupies them for [`SystemBus::transfer_s`], and advances both
/// frontiers — so serialized traffic through one endpoint (the star's
/// leader) queues, while disjoint pairs (the ring's neighbours)
/// overlap. Deterministic: same message sequence, same timeline.
#[derive(Debug, Clone)]
pub struct BusModel {
    bus: SystemBus,
    endpoints: Vec<Endpoint>,
    bytes: u64,
}

impl BusModel {
    /// A fresh timeline over `endpoints` endpoints (leader + boards).
    pub fn new(bus: SystemBus, endpoints: usize) -> BusModel {
        BusModel { bus, endpoints: vec![Endpoint::default(); endpoints], bytes: 0 }
    }

    /// Schedule one `bytes`-byte message `src → dst`; returns its
    /// completion time on the model clock.
    pub fn send(&mut self, src: usize, dst: usize, bytes: u64) -> f64 {
        let start = self.endpoints[src].tx_free_s.max(self.endpoints[dst].rx_free_s);
        let done = start + self.bus.transfer_s(bytes);
        self.endpoints[src].tx_free_s = done;
        self.endpoints[dst].rx_free_s = done;
        self.bytes += bytes;
        done
    }

    /// Total bytes scheduled so far.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The timeline's makespan: when the last endpoint goes idle.
    pub fn makespan_s(&self) -> f64 {
        self.endpoints
            .iter()
            .map(|e| e.tx_free_s.max(e.rx_free_s))
            .fold(0.0, f64::max)
    }
}

/// The charges of one weight-sync collective, derived from a
/// [`BusModel`] timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncCost {
    /// Modelled bus-controller cycles ([`BUS_CLOCK_HZ`] × seconds) —
    /// what [`super::Metrics::sync_cycles`] accumulates.
    pub cycles: u64,
    /// Bytes moved over the bus.
    pub bytes: u64,
    /// Wall time of the collective on the modelled bus (its makespan).
    pub seconds: f64,
}

/// Cycle charge for a span of modelled seconds.
pub fn cycles_of(seconds: f64) -> u64 {
    (seconds * BUS_CLOCK_HZ).round() as u64
}

/// Star collective: the leader serially receives k P-byte uploads, then
/// serially sends k+1 P-byte downloads (k replicas + its own retained
/// copy's bookkeeping transfer — matching the pre-policy charge of
/// `(k+1) · transfer_s(P)` exactly, so existing makespans and
/// `bus_bytes` stay bit-identical). Everything queues on endpoint 0.
pub fn star_sync_cost(k: usize, param_bytes: u64, bus: &SystemBus) -> SyncCost {
    // Keep the legacy closed form for seconds/bytes (bit-compat with
    // the pre-policy leader); the discrete-event model reproduces it
    // because every message shares the leader endpoint.
    let seconds = bus.transfer_s(param_bytes) * (k as f64 + 1.0);
    let bytes = param_bytes * (k as u64 + 1);
    let mut model = BusModel::new(*bus, k + 1);
    for b in 1..=k {
        model.send(b, 0, param_bytes);
    }
    model.send(0, 0, param_bytes); // leader-side average bookkeeping
    debug_assert!((model.makespan_s() - seconds).abs() < 1e-12 * (k as f64 + 1.0).max(1.0));
    SyncCost { cycles: cycles_of(seconds), bytes, seconds }
}

/// Ring collective among `live` boards holding a `param_bytes`-byte
/// parameter set: 2(live−1) rounds of `live` simultaneous
/// neighbour-to-neighbour messages of ⌈P/live⌉ bytes. With one board
/// (or zero) there is nothing to move. `live` may be smaller than the
/// group's original size after an eviction — survivors re-form the
/// smaller ring deterministically.
pub fn ring_sync_cost(live: usize, param_bytes: u64, bus: &SystemBus) -> SyncCost {
    if live <= 1 {
        return SyncCost { cycles: 0, bytes: 0, seconds: 0.0 };
    }
    let chunk = param_bytes.div_ceil(live as u64);
    let mut model = BusModel::new(*bus, live + 1);
    for _round in 0..2 * (live - 1) {
        for i in 0..live {
            // Board endpoints are 1..=live; neighbour (i+1) mod live.
            model.send(1 + i, 1 + (i + 1) % live, chunk);
        }
    }
    let seconds = model.makespan_s();
    SyncCost { cycles: cycles_of(seconds), bytes: model.bytes(), seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_replicas(k: usize, shape: &[usize], seed: u64) -> Vec<Vec<Vec<i16>>> {
        let mut r = Rng::new(seed);
        (0..k)
            .map(|_| {
                shape
                    .iter()
                    .map(|&n| (0..n).map(|_| r.gen_range_i64(-30000, 30000) as i16).collect())
                    .collect()
            })
            .collect()
    }

    #[test]
    fn policy_tags_round_trip() {
        for p in [
            SyncPolicy::Star,
            SyncPolicy::Ring,
            SyncPolicy::BoundedStale { max_lag: 0 },
            SyncPolicy::BoundedStale { max_lag: 7 },
        ] {
            assert_eq!(SyncPolicy::from_tag(p.tag(), p.lag()), Some(p));
        }
        assert_eq!(SyncPolicy::from_tag(99, 0), None);
    }

    #[test]
    fn policy_parses_cli_spellings() {
        assert_eq!(SyncPolicy::parse("star"), Some(SyncPolicy::Star));
        assert_eq!(SyncPolicy::parse("ring"), Some(SyncPolicy::Ring));
        assert_eq!(
            SyncPolicy::parse("bounded-stale"),
            Some(SyncPolicy::BoundedStale { max_lag: 1 })
        );
        assert_eq!(
            SyncPolicy::parse("stale:3"),
            Some(SyncPolicy::BoundedStale { max_lag: 3 })
        );
        assert_eq!(SyncPolicy::parse("bounded-stale:0"), Some(SyncPolicy::BoundedStale { max_lag: 0 }));
        assert_eq!(SyncPolicy::parse("mesh"), None);
        assert_eq!(SyncPolicy::parse("stale:x"), None);
        assert_eq!(SyncPolicy::BoundedStale { max_lag: 3 }.to_string(), "bounded-stale:3");
    }

    #[test]
    fn ring_average_is_bit_identical_to_star_for_many_shapes() {
        // The debug_assert inside ring_average already enforces this;
        // assert it explicitly too so release builds cover it.
        for (k, shape, seed) in [
            (1usize, vec![7usize], 1u64),
            (2, vec![4, 9], 2),
            (3, vec![5], 3),
            (3, vec![16, 3, 4], 4),
            (5, vec![2, 2, 2], 5),
            (8, vec![64, 10], 6),
            (7, vec![1], 7),
            (4, vec![3, 1, 1, 3], 8),
        ] {
            let reps = random_replicas(k, &shape, seed);
            assert_eq!(
                ring_average(&reps),
                crate::cluster::leader::average_weights(&reps),
                "k={k} shape={shape:?}"
            );
        }
    }

    #[test]
    fn ring_average_handles_more_replicas_than_lanes() {
        // P < k: some chunks are empty; every lane still averages.
        let reps = random_replicas(6, &[2], 99);
        assert_eq!(ring_average(&reps), crate::cluster::leader::average_weights(&reps));
    }

    #[test]
    fn star_cost_matches_the_legacy_closed_form() {
        let bus = SystemBus::default();
        for k in [1usize, 2, 4, 8] {
            let c = star_sync_cost(k, 4096, &bus);
            let want_s = bus.transfer_s(4096) * (k as f64 + 1.0);
            assert!((c.seconds - want_s).abs() < 1e-12, "k={k}");
            assert_eq!(c.bytes, 4096 * (k as u64 + 1));
            assert_eq!(c.cycles, cycles_of(want_s));
        }
    }

    #[test]
    fn ring_cost_is_flat_per_board_while_star_grows_linearly() {
        // The acceptance shape: star makespan ~O(k·P) at the leader,
        // ring ~O(P) per board. Compare k=4 vs k=16 at fixed P: star
        // grows ~4×, ring stays within the latency-added band (the
        // 2(k−1) per-message latencies grow, but the bandwidth term —
        // dominant at this P — shrinks per chunk).
        let bus = SystemBus::default();
        let p = 1_000_000u64; // 1 MB of params: bandwidth-dominated
        let star4 = star_sync_cost(4, p, &bus);
        let star16 = star_sync_cost(16, p, &bus);
        let ring4 = ring_sync_cost(4, p, &bus);
        let ring16 = ring_sync_cost(16, p, &bus);
        let star_growth = star16.seconds / star4.seconds;
        let ring_growth = ring16.seconds / ring4.seconds;
        assert!(star_growth > 3.0, "star grew only {star_growth:.2}×");
        assert!(ring_growth < 1.5, "ring grew {ring_growth:.2}× — not O(P)");
        // And at equal k the ring's makespan beats the star's.
        assert!(ring16.seconds < star16.seconds);
        assert!(ring16.cycles < star16.cycles);
    }

    #[test]
    fn ring_cost_degenerates_for_singleton_groups() {
        let c = ring_sync_cost(1, 4096, &SystemBus::default());
        assert_eq!(c, SyncCost { cycles: 0, bytes: 0, seconds: 0.0 });
        assert_eq!(ring_sync_cost(0, 4096, &SystemBus::default()).bytes, 0);
    }

    #[test]
    fn bus_model_serializes_shared_endpoints_and_overlaps_disjoint_ones() {
        let bus = SystemBus { bandwidth_bps: 1e6, latency_s: 0.0 };
        let t = bus.transfer_s(1000); // 1 ms
        // Two messages into the same receiver queue...
        let mut m = BusModel::new(bus, 3);
        m.send(1, 0, 1000);
        m.send(2, 0, 1000);
        assert!((m.makespan_s() - 2.0 * t).abs() < 1e-12);
        // ...but disjoint pairs overlap fully.
        let mut m = BusModel::new(bus, 5);
        m.send(1, 2, 1000);
        m.send(3, 4, 1000);
        assert!((m.makespan_s() - t).abs() < 1e-12);
        assert_eq!(m.bytes(), 2000);
    }
}
