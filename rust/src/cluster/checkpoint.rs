//! Deterministic training checkpoints — the versioned snapshot a run
//! can be resumed from **bit-exactly**.
//!
//! A [`TrainCheckpoint`] captures everything the deterministic training
//! pipeline needs to continue as if it had never stopped:
//!
//! * the quantised **parameters** (as a [`crate::nn::checkpoint`]
//!   payload — weights, biases, fixed-point format, layer shapes);
//! * the **sampler cursor** (`steps_done`): the batch sampler draws
//!   exactly `batch` indices per step from a seed-determined stream, so
//!   a fresh trainer built from the same seed and fast-forwarded by
//!   `steps_done` steps ([`crate::nn::trainer::Trainer::skip_steps`])
//!   continues the exact stream;
//! * the **chunk cursor** and run identity (`seed`, `batch`,
//!   `total_steps`, net name) so a resume against the wrong run is a
//!   typed error instead of a silent divergence;
//! * the **metrics so far**: loss-curve prefix, aggregated
//!   [`RunStats`], and simulated compute seconds — so the resumed run's
//!   final curve and stats equal the uninterrupted run's, bit for bit
//!   (f64 additions replay in the same order).
//!
//! Format (little-endian, versioned, self-checking):
//!
//! ```text
//! magic "MFCK"  u32 version  u32 name_len  name  u64 seed  u32 batch
//! f64 lr  u32 replicas  u32 sync_every  u32 boards  u32 sync_tag  u32 sync_lag
//! u64 total_steps  u64 steps_done  u64 params_checksum  f64 sim_compute_s
//! RunStats (8 × u64)  u32 curve_len  curve_len × (u64 step, f64, f64)
//! u32 params_len  params (nn::checkpoint bytes)
//! ```
//!
//! Version 2 added `boards` (the cluster's total board count F — a
//! snapshot cut on 4 boards must not silently resume on 8, where the
//! divided-mode schedule differs) and the run's [`SyncPolicy`]
//! (`sync_tag`/`sync_lag`, see [`SyncPolicy::tag`]) — resuming under a
//! different policy is a typed error too.
//!
//! `params_checksum` is [`super::bus::params_checksum`] over the decoded
//! parameters — a truncated or bit-flipped snapshot fails closed.

use super::bus::params_checksum;
use super::cost::SyncPolicy;
use crate::hw::RunStats;
use crate::nn::checkpoint::{Checkpoint, CheckpointError};
use crate::nn::trainer::LossPoint;
use crate::nn::MlpSpec;
use std::io::{Read, Write};
use std::path::Path;

/// Cluster checkpoint format version.
pub const VERSION: u32 = 2;
const MAGIC: &[u8; 4] = b"MFCK";

/// A deterministic, resumable snapshot of one training job at a chunk
/// boundary. See the module docs for the exact resume contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainCheckpoint {
    /// Net / job name the snapshot belongs to.
    pub net: String,
    /// Training seed of the run (weights init + sample stream).
    pub seed: u64,
    /// Mini-batch size (sampler draws per step).
    pub batch: usize,
    /// Learning rate of the run — resuming under a different lr would
    /// silently change the gradient scale, so it is validated.
    pub lr: f64,
    /// Data-parallel replicas of the run this snapshot was cut from
    /// (1 for board targets and single-board cluster jobs, the group
    /// size for divided jobs). A divided resume must match it exactly.
    pub replicas: usize,
    /// Weight-sync cadence of a divided run (0 for single-board /
    /// board-target snapshots). A divided resume must match it.
    pub sync_every: usize,
    /// Total board count F of the cluster the snapshot was cut on
    /// (1 for board targets). Resuming on a different board count is a
    /// typed wrong-topology error — the divided-mode schedule depends
    /// on F, so a 4-board snapshot must not silently continue on 8.
    pub boards: usize,
    /// Weight-sync policy of the run. Resuming under a different
    /// policy is a typed error (`BoundedStale` trajectories are not
    /// interchangeable with `Star`/`Ring` ones).
    pub sync: SyncPolicy,
    /// Total steps of the run this snapshot was cut from.
    pub total_steps: usize,
    /// Steps completed at capture time — the sampler cursor.
    pub steps_done: usize,
    /// Loss-curve prefix up to `steps_done`.
    pub curve: Vec<LossPoint>,
    /// Machine stats aggregated up to `steps_done`.
    pub stats: RunStats,
    /// Simulated compute seconds accumulated up to `steps_done`.
    pub sim_compute_s: f64,
    /// The parameters at `steps_done` (weights/biases + format).
    pub params: Checkpoint,
}

impl TrainCheckpoint {
    /// Capture a snapshot from leader-held state.
    #[allow(clippy::too_many_arguments)]
    pub fn capture(
        spec: &MlpSpec,
        run: &RunIdentity,
        steps_done: usize,
        curve: &[LossPoint],
        stats: RunStats,
        sim_compute_s: f64,
        w: &[Vec<i16>],
        b: &[Vec<i16>],
    ) -> TrainCheckpoint {
        let dims: Vec<(usize, usize)> =
            spec.layers.iter().map(|l| (l.inputs, l.outputs)).collect();
        TrainCheckpoint {
            net: spec.name.clone(),
            seed: run.seed,
            batch: run.batch,
            lr: run.lr,
            replicas: run.replicas,
            sync_every: run.sync_every,
            boards: run.boards,
            sync: run.sync,
            total_steps: run.total_steps,
            steps_done,
            curve: curve.to_vec(),
            stats,
            sim_compute_s,
            params: Checkpoint::capture(spec.fixed, &dims, w, b),
        }
    }

    /// The snapshot's parameters as per-layer `(weights, biases)`.
    pub fn weights(&self) -> (Vec<Vec<i16>>, Vec<Vec<i16>>) {
        self.params.clone().into_params()
    }

    /// Serialise to bytes (see the module docs for the layout).
    pub fn to_bytes(&self) -> Vec<u8> {
        let (w, b) = self.weights();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.net.len() as u32).to_le_bytes());
        out.extend_from_slice(self.net.as_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.batch as u32).to_le_bytes());
        out.extend_from_slice(&self.lr.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.replicas as u32).to_le_bytes());
        out.extend_from_slice(&(self.sync_every as u32).to_le_bytes());
        out.extend_from_slice(&(self.boards as u32).to_le_bytes());
        out.extend_from_slice(&self.sync.tag().to_le_bytes());
        out.extend_from_slice(&self.sync.lag().to_le_bytes());
        out.extend_from_slice(&(self.total_steps as u64).to_le_bytes());
        out.extend_from_slice(&(self.steps_done as u64).to_le_bytes());
        out.extend_from_slice(&params_checksum(&w, &b).to_le_bytes());
        out.extend_from_slice(&self.sim_compute_s.to_bits().to_le_bytes());
        for v in [
            self.stats.cycles,
            self.stats.dma_cycles,
            self.stats.compute_cycles,
            self.stats.lut_cycles,
            self.stats.ring_cycles,
            self.stats.waves,
            self.stats.lane_ops,
            self.stats.dma_bytes,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.curve.len() as u32).to_le_bytes());
        for p in &self.curve {
            out.extend_from_slice(&(p.step as u64).to_le_bytes());
            out.extend_from_slice(&p.loss.to_bits().to_le_bytes());
            out.extend_from_slice(&p.device_loss.to_bits().to_le_bytes());
        }
        let params = self.params.to_bytes();
        out.extend_from_slice(&(params.len() as u32).to_le_bytes());
        out.extend_from_slice(&params);
        out
    }

    /// Parse from bytes; rejects bad magic/version, truncation,
    /// trailing bytes, and parameter-checksum mismatches.
    pub fn from_bytes(mut data: &[u8]) -> Result<TrainCheckpoint, CheckpointError> {
        fn take<'a>(data: &mut &'a [u8], n: usize) -> Result<&'a [u8], CheckpointError> {
            if data.len() < n {
                return Err(CheckpointError::Format("truncated".into()));
            }
            let (head, rest) = data.split_at(n);
            *data = rest;
            Ok(head)
        }
        fn take_u32(data: &mut &[u8]) -> Result<u32, CheckpointError> {
            Ok(u32::from_le_bytes(take(data, 4)?.try_into().unwrap()))
        }
        fn take_u64(data: &mut &[u8]) -> Result<u64, CheckpointError> {
            Ok(u64::from_le_bytes(take(data, 8)?.try_into().unwrap()))
        }
        fn take_f64(data: &mut &[u8]) -> Result<f64, CheckpointError> {
            Ok(f64::from_bits(take_u64(data)?))
        }
        if take(&mut data, 4)? != MAGIC {
            return Err(CheckpointError::Format("bad magic (not a cluster checkpoint)".into()));
        }
        let version = take_u32(&mut data)?;
        if version != VERSION {
            return Err(CheckpointError::Format(format!("unsupported version {version}")));
        }
        let name_len = take_u32(&mut data)? as usize;
        if name_len > 4096 {
            return Err(CheckpointError::Format("implausible name length".into()));
        }
        let net = String::from_utf8(take(&mut data, name_len)?.to_vec())
            .map_err(|_| CheckpointError::Format("name is not utf-8".into()))?;
        let seed = take_u64(&mut data)?;
        let batch = take_u32(&mut data)? as usize;
        let lr = take_f64(&mut data)?;
        let replicas = take_u32(&mut data)? as usize;
        let sync_every = take_u32(&mut data)? as usize;
        let boards = take_u32(&mut data)? as usize;
        let sync_tag = take_u32(&mut data)?;
        let sync_lag = take_u32(&mut data)?;
        let sync = SyncPolicy::from_tag(sync_tag, sync_lag).ok_or_else(|| {
            CheckpointError::Format(format!("unknown sync-policy tag {sync_tag}"))
        })?;
        let total_steps = take_u64(&mut data)? as usize;
        let steps_done = take_u64(&mut data)? as usize;
        let checksum = take_u64(&mut data)?;
        let sim_compute_s = take_f64(&mut data)?;
        let stats = RunStats {
            cycles: take_u64(&mut data)?,
            dma_cycles: take_u64(&mut data)?,
            compute_cycles: take_u64(&mut data)?,
            lut_cycles: take_u64(&mut data)?,
            ring_cycles: take_u64(&mut data)?,
            waves: take_u64(&mut data)?,
            lane_ops: take_u64(&mut data)?,
            dma_bytes: take_u64(&mut data)?,
        };
        let curve_len = take_u32(&mut data)? as usize;
        if curve_len > 1 << 24 {
            return Err(CheckpointError::Format("implausible curve length".into()));
        }
        let mut curve = Vec::with_capacity(curve_len);
        for _ in 0..curve_len {
            curve.push(LossPoint {
                step: take_u64(&mut data)? as usize,
                loss: take_f64(&mut data)?,
                device_loss: take_f64(&mut data)?,
            });
        }
        let params_len = take_u32(&mut data)? as usize;
        let params = Checkpoint::from_bytes(take(&mut data, params_len)?)?;
        if !data.is_empty() {
            return Err(CheckpointError::Format("trailing bytes".into()));
        }
        let ck = TrainCheckpoint {
            net,
            seed,
            batch,
            lr,
            replicas,
            sync_every,
            boards,
            sync,
            total_steps,
            steps_done,
            curve,
            stats,
            sim_compute_s,
            params,
        };
        let (w, b) = ck.weights();
        if params_checksum(&w, &b) != checksum {
            return Err(CheckpointError::Format(
                "parameter checksum mismatch (corrupt snapshot)".into(),
            ));
        }
        Ok(ck)
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<TrainCheckpoint, CheckpointError> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        TrainCheckpoint::from_bytes(&buf)
    }

    /// Validate this snapshot against the run it is being resumed into.
    /// `run.replicas`/`run.sync_every` describe the resuming topology:
    /// a divided resume must match the snapshot's exactly (a different
    /// group size or sync cadence would silently diverge from the
    /// uninterrupted run instead of reproducing it).
    pub fn check_resume(&self, net: &str, run: &RunIdentity) -> Result<(), CheckpointError> {
        if self.net != net {
            return Err(CheckpointError::Format(format!(
                "checkpoint is for net {:?}, resuming {net:?}",
                self.net
            )));
        }
        if self.seed != run.seed || self.batch != run.batch || self.lr != run.lr {
            return Err(CheckpointError::Format(format!(
                "checkpoint run identity (seed {}, batch {}, lr {}) does not \
                 match the resume config (seed {}, batch {}, lr {})",
                self.seed, self.batch, self.lr, run.seed, run.batch, run.lr
            )));
        }
        if self.replicas != run.replicas
            || self.sync_every != run.sync_every
            || self.boards != run.boards
        {
            return Err(CheckpointError::Format(format!(
                "checkpoint topology ({} board(s), {} replica(s), sync_every {}) \
                 does not match the resuming target ({} board(s), {} replica(s), \
                 sync_every {})",
                self.boards,
                self.replicas,
                self.sync_every,
                run.boards,
                run.replicas,
                run.sync_every
            )));
        }
        if self.sync != run.sync {
            return Err(CheckpointError::Format(format!(
                "checkpoint was cut under sync policy {} but the resuming run \
                 uses {}",
                self.sync, run.sync
            )));
        }
        if self.steps_done > run.total_steps {
            return Err(CheckpointError::Format(format!(
                "checkpoint is at step {} but the run has only {} steps",
                self.steps_done, run.total_steps
            )));
        }
        Ok(())
    }
}

/// The identity of a training run a snapshot belongs to (or is resumed
/// into): everything that shapes the deterministic trajectory besides
/// the dataset and the net itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunIdentity {
    /// Training seed (weights init + sample stream).
    pub seed: u64,
    /// Mini-batch size.
    pub batch: usize,
    /// Learning rate.
    pub lr: f64,
    /// Data-parallel replicas (1 = single board / board target).
    pub replicas: usize,
    /// Weight-sync cadence (0 = not divided).
    pub sync_every: usize,
    /// Total board count F of the cluster (1 = board target).
    pub boards: usize,
    /// Weight-sync policy of the run.
    pub sync: SyncPolicy,
    /// Total steps of the run.
    pub total_steps: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;
    use crate::util::Rng;

    fn sample() -> TrainCheckpoint {
        let fixed = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            "ck",
            &[3, 5, 2],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let mut r = Rng::new(9);
        let w: Vec<Vec<i16>> = spec
            .layers
            .iter()
            .map(|l| (0..l.inputs * l.outputs).map(|_| r.gen_i16()).collect())
            .collect();
        let b: Vec<Vec<i16>> =
            spec.layers.iter().map(|l| (0..l.outputs).map(|_| r.gen_i16()).collect()).collect();
        let curve = vec![
            LossPoint { step: 0, loss: 1.25, device_loss: 1.5 },
            LossPoint { step: 10, loss: 0.5, device_loss: 0.75 },
        ];
        let stats = RunStats { cycles: 123, waves: 4, lane_ops: 99, ..RunStats::default() };
        let run = RunIdentity {
            seed: 42,
            batch: 16,
            lr: 1.0 / 128.0,
            replicas: 1,
            sync_every: 0,
            boards: 1,
            sync: SyncPolicy::Star,
            total_steps: 100,
        };
        TrainCheckpoint::capture(&spec, &run, 20, &curve, stats, 0.125, &w, &b)
    }

    #[test]
    fn roundtrip_bytes_and_file() {
        let ck = sample();
        assert_eq!(TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap(), ck);
        let dir = std::env::temp_dir().join(format!("mfnn_tck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.mfck");
        ck.save(&path).unwrap();
        assert_eq!(TrainCheckpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corruption_and_truncation() {
        let ck = sample();
        let bytes = ck.to_bytes();
        // bad magic
        let mut b2 = bytes.clone();
        b2[0] = b'X';
        assert!(TrainCheckpoint::from_bytes(&b2).is_err());
        // truncation
        let mut b3 = bytes.clone();
        b3.truncate(b3.len() - 5);
        assert!(TrainCheckpoint::from_bytes(&b3).is_err());
        // a flipped parameter lane fails the integrity checksum
        let mut b4 = bytes.clone();
        let n = b4.len();
        b4[n - 3] ^= 0x40;
        assert!(TrainCheckpoint::from_bytes(&b4).is_err());
        // trailing garbage
        let mut b5 = bytes;
        b5.push(0);
        assert!(TrainCheckpoint::from_bytes(&b5).is_err());
    }

    #[test]
    fn resume_identity_is_validated() {
        let ck = sample();
        let run = RunIdentity {
            seed: 42,
            batch: 16,
            lr: 1.0 / 128.0,
            replicas: 1,
            sync_every: 0,
            boards: 1,
            sync: SyncPolicy::Star,
            total_steps: 100,
        };
        ck.check_resume("ck", &run).unwrap();
        // exactly at the end is fine
        ck.check_resume("ck", &RunIdentity { total_steps: 20, ..run }).unwrap();
        assert!(ck.check_resume("other", &run).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { seed: 43, ..run }).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { batch: 8, ..run }).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { lr: 1.0 / 64.0, ..run }).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { replicas: 2, ..run }).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { sync_every: 10, ..run }).is_err());
        assert!(ck.check_resume("ck", &RunIdentity { boards: 2, ..run }).is_err());
        assert!(ck
            .check_resume("ck", &RunIdentity { sync: SyncPolicy::Ring, ..run })
            .is_err());
        assert!(ck.check_resume("ck", &RunIdentity { total_steps: 19, ..run }).is_err());
    }

    #[test]
    fn wrong_board_count_is_a_typed_topology_error() {
        // Regression: a snapshot cut on a 4-board cluster used to resume
        // silently on 8 boards (RunIdentity did not capture F), where
        // the divided-mode schedule differs. It must be a typed error.
        let mut ck = sample();
        ck.boards = 4;
        let run = RunIdentity {
            seed: 42,
            batch: 16,
            lr: 1.0 / 128.0,
            replicas: 1,
            sync_every: 0,
            boards: 8,
            sync: SyncPolicy::Star,
            total_steps: 100,
        };
        let err = ck.check_resume("ck", &run).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("4 board(s)") && msg.contains("8 board(s)"), "{msg}");
        // and the board count round-trips through the byte format
        let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.boards, 4);
    }

    #[test]
    fn sync_policy_round_trips_and_mismatches_are_typed() {
        let mut ck = sample();
        ck.sync = SyncPolicy::BoundedStale { max_lag: 3 };
        let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.sync, SyncPolicy::BoundedStale { max_lag: 3 });
        let run = RunIdentity {
            seed: 42,
            batch: 16,
            lr: 1.0 / 128.0,
            replicas: 1,
            sync_every: 0,
            boards: 1,
            sync: SyncPolicy::Ring,
            total_steps: 100,
        };
        let err = back.check_resume("ck", &run).unwrap_err();
        assert!(err.to_string().contains("bounded-stale:3"), "{err}");
    }
}
