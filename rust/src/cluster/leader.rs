//! The cluster leader: schedules jobs onto boards (per §2's three cases),
//! orchestrates data-parallel weight averaging for divided jobs, accounts
//! simulated bus + compute time, and aggregates results.
//!
//! Since the recovery pass the leader also **survives board loss**: under
//! the run's [`RecoveryPolicy`] (on by default) a dead or
//! persistently-corrupting board is evicted and its outstanding chunks
//! are rescheduled onto surviving boards — single-board jobs restart
//! from their last leader-held checkpoint on the lowest-indexed
//! surviving board, divided replicas are **adopted** by a surviving
//! group member that rebuilds the replica's trainer from the last
//! broadcast average and fast-forwards its sampler. Gradients still
//! accumulate in chunk-index (replica) order, so recovered results are
//! **bit-identical** to the fault-free run (DESIGN.md §Recovery).
//! Checksum-failed chunks are re-read over the bus
//! (`Cmd::ReadParams`) within a bounded retry budget before eviction.
//! Worker-reported job errors and protocol violations still abort with
//! the pre-recovery typed errors. On every exit path — success, abort,
//! eviction — the leader closes each worker's command channel and joins
//! its thread before returning (no leaked `fpga-worker-*` threads).

use super::bus::{params_checksum, SystemBus};
use super::checkpoint::{RunIdentity, TrainCheckpoint};
use super::cost::{ring_average, ring_sync_cost, star_sync_cost, SyncPolicy};
use super::fault::FaultPlan;
use super::metrics::{Metrics, MetricsSnapshot};
use super::recovery::RecoveryPolicy;
use super::scheduler::{schedule, Placement, PlacementMode};
use super::worker::{Cmd, Reply, Worker, WorkerGone};
use crate::hw::{FpgaDevice, RunStats};
use crate::nn::dataset::Dataset;
use crate::nn::trainer::{LossPoint, TrainConfig};
use crate::nn::MlpSpec;
use std::sync::Arc;
use thiserror::Error;

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of FPGA boards.
    pub boards: usize,
    /// Board part name (Table 8 catalog).
    pub device: String,
    /// Host↔board link model.
    pub bus: SystemBus,
    /// Steps between weight syncs for divided jobs.
    pub sync_every: usize,
    /// How divided groups synchronise weights at `sync_every`
    /// boundaries: star gather/broadcast (the bit-exact default), ring
    /// all-reduce (bit-identical averages, ring-shaped cost), or
    /// bounded-stale averaging (see [`SyncPolicy`]). Recorded in every
    /// checkpoint's [`RunIdentity`]; resuming under a different policy
    /// is a typed error.
    pub sync: SyncPolicy,
    /// Deterministic fault schedule (empty = no faults) — the testkit's
    /// fault differential injects worker death, chunk corruption, and
    /// delayed/reordered replies through this.
    pub faults: FaultPlan,
    /// What the leader does when a board fails (retry / evict /
    /// reschedule / checkpoint); defaults to recovery **on**.
    pub recovery: RecoveryPolicy,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            boards: 2,
            device: "XC7S75-2".into(),
            bus: SystemBus::default(),
            sync_every: 20,
            sync: SyncPolicy::Star,
            faults: FaultPlan::none(),
            recovery: RecoveryPolicy::default(),
        }
    }
}

/// Per-layer quantised parameters `(weights, biases)` as shipped over
/// the bus.
pub type Params = (Vec<Vec<i16>>, Vec<Vec<i16>>);

/// A resume cursor for a job whose [`Job::initial`] parameters were
/// captured at a checkpoint: the leader fast-forwards each trainer's
/// batch sampler past `steps_done` steps and seeds the result's curve
/// and stats with the snapshot's prefix, so the continued run is
/// bit-identical to the uninterrupted one.
#[derive(Debug, Clone, Default)]
pub struct JobResume {
    /// Steps already trained into [`Job::initial`].
    pub steps_done: usize,
    /// Loss-curve prefix up to `steps_done`.
    pub curve: Vec<LossPoint>,
    /// Machine stats aggregated up to `steps_done`.
    pub stats: RunStats,
    /// Simulated compute seconds up to `steps_done`.
    pub sim_compute_s: f64,
}

impl JobResume {
    /// Build the resume cursor encoded by a [`TrainCheckpoint`] (pair it
    /// with `Job::initial = Some(ckpt.weights())`).
    pub fn from_checkpoint(ck: &TrainCheckpoint) -> JobResume {
        JobResume {
            steps_done: ck.steps_done,
            curve: ck.curve.clone(),
            stats: ck.stats,
            sim_compute_s: ck.sim_compute_s,
        }
    }
}

/// One training job.
#[derive(Debug, Clone)]
pub struct Job {
    /// Job name (reporting).
    pub name: String,
    /// Network.
    pub spec: MlpSpec,
    /// Trainer configuration (total steps live here).
    pub cfg: TrainConfig,
    /// Training split.
    pub train_data: Arc<Dataset>,
    /// Test split.
    pub test_data: Arc<Dataset>,
    /// Optional starting parameters (checkpoint restore / session
    /// weights); `None` ⇒ each board initialises from `cfg.seed` (divided
    /// jobs then broadcast replica 0's init).
    pub initial: Option<Params>,
    /// Resume cursor when `initial` came from a checkpoint (requires
    /// `initial`; divided jobs additionally require the cursor to sit on
    /// a weight-sync boundary).
    pub resume: Option<JobResume>,
}

/// Result of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Job name.
    pub name: String,
    /// Boards it ran on: the placement's group for divided jobs (even
    /// when a member was evicted mid-run — its replica's chunks were
    /// recomputed by the survivors), the final board for single-board
    /// jobs (which differs from the placement when the job was
    /// rescheduled).
    pub boards: Vec<usize>,
    /// Final test accuracy.
    pub accuracy: f64,
    /// Loss curve (replica 0's view for divided jobs).
    pub curve: Vec<LossPoint>,
    /// Aggregated machine stats — the successful chunk lineage only, so
    /// a recovered run reports bit-identical stats to a fault-free one
    /// (wasted work shows in board time and the recovery metrics).
    pub stats: RunStats,
    /// Simulated compute seconds (critical path over replicas).
    pub sim_compute_s: f64,
    /// Simulated bus seconds attributed to this job.
    pub sim_bus_s: f64,
    /// Steps executed (per replica).
    pub steps: usize,
    /// Final per-layer weights (post-averaging for divided jobs) — what a
    /// [`crate::session::Session`] adopts after a cluster train.
    pub weights: Vec<Vec<i16>>,
    /// Final per-layer biases.
    pub biases: Vec<Vec<i16>>,
    /// Deterministic snapshots captured at chunk / sync boundaries when
    /// [`RecoveryPolicy::checkpoint_every`] is non-zero (chronological).
    pub checkpoints: Vec<TrainCheckpoint>,
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Placement used.
    pub placement: Placement,
    /// Per-job results (job order preserved).
    pub results: Vec<JobResult>,
    /// Simulated makespan: max over boards of accumulated sim time.
    pub makespan_s: f64,
    /// Per-board simulated busy time.
    pub board_time_s: Vec<f64>,
    /// Metrics snapshot.
    pub metrics: MetricsSnapshot,
    /// Wall-clock seconds spent simulating.
    pub wall_s: f64,
}

/// Cluster errors.
#[derive(Debug, Error)]
pub enum ClusterError {
    /// Unknown device name.
    #[error("unknown FPGA part {0:?}")]
    UnknownDevice(String),
    /// A worker reported an error.
    #[error("job {0} on board {1}: {2}")]
    Worker(String, usize, String),
    /// A worker thread died (channel closed) while serving a job — the
    /// typed surface of injected (or real) worker death. With recovery
    /// off (or no surviving board left) the leader aborts the job with
    /// this instead of hanging on the dead channel; with recovery on it
    /// first evicts the board and reschedules the outstanding chunks.
    #[error("job {0}: board {1} worker died (channel closed)")]
    WorkerDied(String, usize),
    /// A returned parameter chunk failed its bus integrity check
    /// ([`params_checksum`]) and every retry in the
    /// [`RecoveryPolicy::max_chunk_retries`] budget failed too; the
    /// leader rejects it rather than adopting or averaging corrupted
    /// parameters.
    #[error("job {0}: board {1} returned a corrupt parameter chunk (checksum mismatch)")]
    CorruptChunk(String, usize),
    /// A checkpoint/resume request is inconsistent with the job.
    #[error("bad checkpoint/resume: {0}")]
    Checkpoint(String),
    /// No jobs given.
    #[error("no jobs")]
    NoJobs,
}

/// Map a closed worker channel into the typed error for `job`.
fn died(job_name: &str) -> impl '_ + Fn(WorkerGone) -> ClusterError {
    move |g| ClusterError::WorkerDied(job_name.to_string(), g.board)
}

/// Average quantised weights across replicas (element-wise i32 mean,
/// round-to-nearest-even-free: plain round toward zero like the DSP
/// truncation).
pub fn average_weights(replicas: &[Vec<Vec<i16>>]) -> Vec<Vec<i16>> {
    let k = replicas.len() as i32;
    assert!(k > 0);
    let mut out = replicas[0].clone();
    for (l, layer) in out.iter_mut().enumerate() {
        for (i, v) in layer.iter_mut().enumerate() {
            let sum: i32 = replicas.iter().map(|r| r[l][i] as i32).sum();
            *v = (sum / k) as i16;
        }
    }
    out
}

/// Run a set of jobs on the cluster; blocks until completion.
#[deprecated(note = "use `session::Session` (Target::Cluster) or \
                     `session::Session::train_many`; `cluster::execute` \
                     is the bare engine entry")]
pub fn run_cluster(cfg: &ClusterConfig, jobs: &[Job]) -> Result<ClusterReport, ClusterError> {
    execute(cfg, jobs)
}

/// Engine entry point: run a set of jobs on the cluster; blocks until
/// completion. Front doors ([`crate::session::Session::train_many`], the
/// deprecated [`run_cluster`]) delegate here.
pub fn execute(cfg: &ClusterConfig, jobs: &[Job]) -> Result<ClusterReport, ClusterError> {
    if jobs.is_empty() {
        return Err(ClusterError::NoJobs);
    }
    let device = FpgaDevice::by_name(&cfg.device)
        .ok_or_else(|| ClusterError::UnknownDevice(cfg.device.clone()))?;
    let wall0 = std::time::Instant::now();
    let metrics = Metrics::shared();
    let placement = schedule(jobs.len(), cfg.boards);
    // Workers are moved into the orchestrator threads that exclusively
    // drive them (board queues / board groups are disjoint), because the
    // reply receiver is single-consumer. Every worker comes back to this
    // frame — via the thread result or `worker_slots` — so the explicit
    // shutdown pass below joins all of them on every exit path.
    let mut worker_slots: Vec<Option<Worker>> = (0..cfg.boards)
        .map(|b| Some(Worker::spawn(b, device, Arc::clone(&metrics), cfg.faults.clone())))
        .collect();

    let mut board_time = vec![0.0f64; cfg.boards];
    let mut results: Vec<Option<JobResult>> = (0..jobs.len()).map(|_| None).collect();

    let outcome = match placement.mode {
        PlacementMode::Sequential | PlacementMode::OneToOne => run_queues(
            cfg,
            jobs,
            &placement,
            &mut worker_slots,
            &mut board_time,
            &mut results,
            &metrics,
        ),
        PlacementMode::Divided => run_groups(
            cfg,
            jobs,
            &placement,
            &mut worker_slots,
            &mut board_time,
            &mut results,
            &metrics,
        ),
    };

    // Leak-proof teardown (also on the error path): close every
    // remaining command channel and join every surviving worker thread
    // before returning. Evicted workers were already shut down at
    // eviction time.
    for w in worker_slots.iter_mut().filter_map(Option::take) {
        w.shutdown();
    }
    outcome?;

    let results: Vec<JobResult> = results.into_iter().map(Option::unwrap).collect();
    let makespan_s = board_time.iter().cloned().fold(0.0, f64::max);
    Ok(ClusterReport {
        placement,
        results,
        makespan_s,
        board_time_s: board_time,
        metrics: metrics.snapshot(),
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

/// Dataset bytes shipped to a board (quantised lanes).
fn dataset_bytes(ds: &Dataset) -> u64 {
    (ds.len() * (ds.dim() + ds.classes)) as u64 * 2
}

// ------------------------------------------------------------------
// Sequential / OneToOne orchestration with recovery passes
// ------------------------------------------------------------------

/// One job awaiting redispatch after a board failure.
struct PendingJob {
    job: usize,
    /// Progress to resume from (`None` = from scratch / its own resume
    /// point).
    ckpt: Option<LeaderCkpt>,
    /// Whether the job had actually started on the failed board — only
    /// then does a redispatch recompute lost work
    /// (`metrics.chunks_rescheduled`); queued-behind jobs just run
    /// normally elsewhere.
    started: bool,
}

/// A board's queue stopped early: the typed error, whether the board
/// fault is recoverable (death / persistent corruption ⇒ evict +
/// reschedule) and the jobs left outstanding with their progress.
struct QueueFailure {
    err: ClusterError,
    retryable: bool,
    pending: Vec<PendingJob>,
}

/// Phase 1: every board runs its static queue concurrently. Phase 2
/// (serial, deterministic): outstanding jobs of failed boards are
/// redispatched in job order onto the lowest-indexed surviving board,
/// resuming from their last leader-held checkpoint.
fn run_queues(
    cfg: &ClusterConfig,
    jobs: &[Job],
    placement: &Placement,
    worker_slots: &mut [Option<Worker>],
    board_time: &mut [f64],
    results: &mut [Option<JobResult>],
    metrics: &Arc<Metrics>,
) -> Result<(), ClusterError> {
    let policy = &cfg.recovery;
    let bus = cfg.bus;
    let topo = (cfg.boards, cfg.sync);
    type QueueOut = (Worker, f64, Vec<(usize, JobResult)>, Option<QueueFailure>);
    let outs: Vec<(usize, QueueOut)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (b, queue) in placement.queues.iter().enumerate() {
            let worker = worker_slots[b].take().expect("board used once");
            let metrics = Arc::clone(metrics);
            let queue = queue.clone();
            handles.push((
                b,
                s.spawn(move || -> QueueOut {
                    let mut time = 0.0f64;
                    let mut done = Vec::new();
                    for (idx, &j) in queue.iter().enumerate() {
                        match run_single_on(
                            &worker, b, &jobs[j], j, &bus, &metrics, policy, topo, None,
                        ) {
                            Ok((r, dt)) => {
                                time += dt;
                                done.push((j, r));
                            }
                            Err(f) => {
                                time += f.time_spent;
                                let mut pending =
                                    vec![PendingJob { job: j, ckpt: f.ckpt, started: true }];
                                pending.extend(queue[idx + 1..].iter().map(|&j2| {
                                    PendingJob { job: j2, ckpt: None, started: false }
                                }));
                                let failure = QueueFailure {
                                    err: f.err,
                                    retryable: f.retryable,
                                    pending,
                                };
                                return (worker, time, done, Some(failure));
                            }
                        }
                    }
                    (worker, time, done, None)
                }),
            ));
        }
        handles
            .into_iter()
            .map(|(b, h)| (b, h.join().expect("leader thread panicked")))
            .collect()
    });

    // Merge phase-1 outcomes; failed boards are evicted (shut down now).
    let mut pending: Vec<PendingJob> = Vec::new();
    let mut last_err: Option<ClusterError> = None;
    let mut fatal: Option<ClusterError> = None;
    for (b, (worker, time, done, failure)) in outs {
        board_time[b] += time;
        for (j, r) in done {
            results[j] = Some(r);
        }
        match failure {
            None => worker_slots[b] = Some(worker),
            Some(f) => {
                // Evicted: close + join its thread immediately.
                worker.shutdown();
                if f.retryable && policy.reschedule {
                    Metrics::add(&metrics.boards_evicted, 1);
                    pending.extend(f.pending);
                    last_err = Some(f.err);
                } else if fatal.is_none() {
                    fatal = Some(f.err);
                }
            }
        }
    }
    if let Some(e) = fatal {
        return Err(e);
    }
    pending.sort_by_key(|p| p.job);

    // Phase 2: serial recovery passes (deterministic board choice).
    while !pending.is_empty() {
        let p = pending.remove(0);
        let Some(b) = worker_slots.iter().position(Option::is_some) else {
            return Err(last_err.expect("pending work implies a recorded failure"));
        };
        if p.started {
            // The failed board's in-flight chunk recomputes here.
            Metrics::add(&metrics.chunks_rescheduled, 1);
        }
        let worker = worker_slots[b].as_ref().expect("chosen alive");
        match run_single_on(worker, b, &jobs[p.job], p.job, &bus, metrics, policy, topo, p.ckpt)
        {
            Ok((r, dt)) => {
                board_time[b] += dt;
                results[p.job] = Some(r);
            }
            Err(f) => {
                board_time[b] += f.time_spent;
                // Evict this board too and keep the job's progress.
                worker_slots[b].take().expect("chosen alive").shutdown();
                if !(f.retryable && policy.reschedule) {
                    return Err(f.err);
                }
                Metrics::add(&metrics.boards_evicted, 1);
                last_err = Some(f.err);
                pending.insert(0, PendingJob { job: p.job, ckpt: f.ckpt, started: true });
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------------------
// One job on one board, chunked, with retry/eviction classification
// ------------------------------------------------------------------

/// Leader-held progress of a single-board job — everything needed to
/// resume it bit-exactly on another board.
struct LeaderCkpt {
    steps_done: usize,
    w: Vec<Vec<i16>>,
    b: Vec<Vec<i16>>,
    curve: Vec<LossPoint>,
    stats: RunStats,
    compute_s: f64,
    /// Durable snapshots captured so far (threaded through failures so
    /// a redispatched job's `JobResult.checkpoints` stays complete; the
    /// live list is kept in [`SingleRun`] and only moved here — never
    /// cloned per chunk).
    checkpoints: Vec<TrainCheckpoint>,
}

/// Why (and how recoverably) a single-board job stopped.
struct SingleFailure {
    err: ClusterError,
    /// Death / persistent corruption — evict the board and reschedule.
    /// Worker-reported job errors and protocol violations are not.
    retryable: bool,
    /// Progress to resume from (falls back to the job's own resume
    /// point, or scratch).
    ckpt: Option<LeaderCkpt>,
    /// Simulated board time consumed before the failure.
    time_spent: f64,
}

/// One received chunk (curve/stats are always trustworthy — only the
/// parameter lanes are subject to in-transit corruption and retries).
struct ChunkData {
    curve: Vec<LossPoint>,
    stats: RunStats,
    sim_s: f64,
    w: Vec<Vec<i16>>,
    b: Vec<Vec<i16>>,
}

/// Run one job on one board (OneToOne / Sequential path, and the
/// recovery redispatch), chunked at the policy's checkpoint cadence,
/// optionally starting from a leader checkpoint or the job's own
/// resume point.
#[allow(clippy::too_many_arguments)]
fn run_single_on(
    worker: &Worker,
    board: usize,
    job: &Job,
    job_id: usize,
    bus: &SystemBus,
    metrics: &Metrics,
    policy: &RecoveryPolicy,
    topo: (usize, SyncPolicy),
    start: Option<LeaderCkpt>,
) -> Result<(JobResult, f64), SingleFailure> {
    let mut run = SingleRun {
        worker,
        board,
        job,
        job_id,
        bus,
        metrics,
        policy,
        topo,
        ckpt: None,
        checkpoints: Vec::new(),
        time: 0.0,
    };
    let mut start = match start {
        Some(c) => Some(c),
        None => match start_ckpt(job) {
            Ok(c) => c,
            Err(e) => {
                return Err(SingleFailure { err: e, retryable: false, ckpt: None, time_spent: 0.0 })
            }
        },
    };
    if let Some(c) = &mut start {
        run.checkpoints = std::mem::take(&mut c.checkpoints);
    }
    run.ckpt = start;
    match run.drive() {
        Ok(out) => Ok(out),
        Err((err, retryable)) => Err(SingleFailure {
            err,
            retryable,
            ckpt: run.ckpt.take().map(|mut c| {
                c.checkpoints = std::mem::take(&mut run.checkpoints);
                c
            }),
            time_spent: run.time,
        }),
    }
}

/// Validate a job's resume point (shared by the single-board and
/// divided paths; the divided path adds its sync-boundary check on
/// top).
fn validate_resume(job: &Job) -> Result<(), ClusterError> {
    let Some(r) = &job.resume else { return Ok(()) };
    if job.initial.is_none() {
        return Err(ClusterError::Checkpoint(format!(
            "job {:?} resumes at step {} but carries no initial parameters",
            job.name, r.steps_done
        )));
    }
    if r.steps_done > job.cfg.steps {
        return Err(ClusterError::Checkpoint(format!(
            "job {:?} resumes at step {} of a {}-step run",
            job.name, r.steps_done, job.cfg.steps
        )));
    }
    Ok(())
}

/// Convert a job's own resume point into the leader checkpoint shape
/// (validated).
fn start_ckpt(job: &Job) -> Result<Option<LeaderCkpt>, ClusterError> {
    validate_resume(job)?;
    let Some(r) = &job.resume else { return Ok(None) };
    let (w, b) = job.initial.clone().expect("validated above");
    Ok(Some(LeaderCkpt {
        steps_done: r.steps_done,
        w,
        b,
        curve: r.curve.clone(),
        stats: r.stats,
        compute_s: r.sim_compute_s,
        checkpoints: Vec::new(),
    }))
}

struct SingleRun<'a> {
    worker: &'a Worker,
    board: usize,
    job: &'a Job,
    job_id: usize,
    bus: &'a SystemBus,
    metrics: &'a Metrics,
    policy: &'a RecoveryPolicy,
    /// The run's `(total boards, sync policy)` — checkpoint identity
    /// only (a single-board job never syncs, but its checkpoints must
    /// refuse a different topology on resume).
    topo: (usize, SyncPolicy),
    /// Live progress, read back by [`run_single_on`] on failure.
    ckpt: Option<LeaderCkpt>,
    /// Durable snapshots captured so far (moved, not cloned, into the
    /// failure checkpoint / the final [`JobResult`]).
    checkpoints: Vec<TrainCheckpoint>,
    /// Simulated board time consumed so far.
    time: f64,
}

impl SingleRun<'_> {
    fn gone(&self) -> (ClusterError, bool) {
        (ClusterError::WorkerDied(self.job.name.clone(), self.board), true)
    }

    fn fatal(&self, message: String) -> (ClusterError, bool) {
        (ClusterError::Worker(self.job.name.clone(), self.board, message), false)
    }

    fn send(&self, cmd: Cmd) -> Result<(), (ClusterError, bool)> {
        self.worker.send(cmd).map_err(|_| self.gone())
    }

    fn ready(&self) -> Result<(), (ClusterError, bool)> {
        match self.worker.recv().map_err(|_| self.gone())? {
            Reply::Ready { .. } => Ok(()),
            Reply::Error { message, .. } => Err(self.fatal(message)),
            other => Err(self.fatal(format!("unexpected reply {other:?}"))),
        }
    }

    /// Receive a chunk reply; on checksum failure re-read the parameters
    /// within the retry budget, then classify the board as
    /// persistently-failing.
    fn recv_chunk(&self) -> Result<ChunkData, (ClusterError, bool)> {
        match self.worker.recv().map_err(|_| self.gone())? {
            Reply::ChunkDone { curve, stats, sim_seconds, w, b, checksum, .. } => {
                if params_checksum(&w, &b) == checksum {
                    return Ok(ChunkData { curve, stats, sim_s: sim_seconds, w, b });
                }
                for _ in 0..self.policy.max_chunk_retries {
                    Metrics::add(&self.metrics.chunk_retries, 1);
                    self.send(Cmd::ReadParams { job: self.job_id })?;
                    match self.worker.recv().map_err(|_| self.gone())? {
                        Reply::Params { w: rw, b: rb, checksum: rc, .. } => {
                            if params_checksum(&rw, &rb) == rc {
                                return Ok(ChunkData {
                                    curve,
                                    stats,
                                    sim_s: sim_seconds,
                                    w: rw,
                                    b: rb,
                                });
                            }
                        }
                        Reply::Error { message, .. } => return Err(self.fatal(message)),
                        other => {
                            return Err(self.fatal(format!("unexpected reply {other:?}")))
                        }
                    }
                }
                Err((
                    ClusterError::CorruptChunk(self.job.name.clone(), self.board),
                    true,
                ))
            }
            Reply::Error { message, .. } => Err(self.fatal(message)),
            other => Err(self.fatal(format!("unexpected reply {other:?}"))),
        }
    }

    fn drive(&mut self) -> Result<(JobResult, f64), (ClusterError, bool)> {
        let job = self.job;
        // Ship program + params + dataset.
        let up_bytes = job.spec.param_bytes() + dataset_bytes(&job.train_data);
        let mut bus_s = self.bus.transfer_s(up_bytes);
        Metrics::add(&self.metrics.bus_bytes, up_bytes);
        self.time += bus_s;

        self.send(Cmd::NewTrainer {
            job: self.job_id,
            spec: job.spec.clone(),
            cfg: job.cfg.clone(),
        })?;
        self.ready()?;
        if let Some(ck) = &self.ckpt {
            self.send(Cmd::SetWeights { job: self.job_id, w: ck.w.clone(), b: ck.b.clone() })?;
            self.ready()?;
            if ck.steps_done > 0 {
                self.send(Cmd::SkipSamples { job: self.job_id, steps: ck.steps_done })?;
                self.ready()?;
            }
        } else if let Some((w0, b0)) = &job.initial {
            self.send(Cmd::SetWeights { job: self.job_id, w: w0.clone(), b: b0.clone() })?;
            self.ready()?;
        }

        let total = job.cfg.steps;
        let mut done = self.ckpt.as_ref().map_or(0, |c| c.steps_done);
        let every = self.policy.checkpoint_every;

        // `self.ckpt` is the single live accumulator (curve/stats grow
        // in place — never re-cloned per chunk); it stays `None` until
        // real progress exists, so a pre-first-chunk failure restarts
        // from scratch / the job's own resume point. When no chunk runs
        // at all (steps-0 jobs) a zero-step probe chunk fetches the
        // parameters (the pre-recovery trace).
        if done >= total && self.ckpt.is_none() {
            self.send(Cmd::TrainChunk {
                job: self.job_id,
                data: Arc::clone(&job.train_data),
                steps: 0,
            })?;
            let chunk = self.recv_chunk()?;
            self.time += chunk.sim_s;
            self.absorb(chunk, done, done);
        }
        while done < total {
            let steps = if every > 0 { every.min(total - done) } else { total - done };
            self.send(Cmd::TrainChunk {
                job: self.job_id,
                data: Arc::clone(&job.train_data),
                steps,
            })?;
            let chunk = self.recv_chunk()?;
            self.time += chunk.sim_s;
            self.absorb(chunk, done, done + steps);
            done += steps;
            if every > 0 {
                let run = RunIdentity {
                    seed: job.cfg.seed,
                    batch: job.cfg.batch,
                    lr: job.cfg.lr,
                    replicas: 1,
                    sync_every: 0,
                    boards: self.topo.0,
                    sync: self.topo.1,
                    total_steps: total,
                };
                let ck = self.ckpt.as_ref().expect("absorbed above");
                let snap = TrainCheckpoint::capture(
                    &job.spec, &run, done, &ck.curve, ck.stats, ck.compute_s, &ck.w, &ck.b,
                );
                self.checkpoints.push(snap);
                Metrics::add(&self.metrics.checkpoints_captured, 1);
            }
        }

        self.send(Cmd::Evaluate { job: self.job_id, data: Arc::clone(&job.test_data) })?;
        let (accuracy, eval_stats, eval_s) = match self.worker.recv().map_err(|_| self.gone())? {
            Reply::EvalDone { accuracy, stats, sim_seconds, .. } => {
                (accuracy, stats, sim_seconds)
            }
            Reply::Error { message, .. } => return Err(self.fatal(message)),
            other => return Err(self.fatal(format!("unexpected reply {other:?}"))),
        };
        self.time += eval_s;

        // Results readback.
        let down = job.spec.param_bytes();
        let down_s = self.bus.transfer_s(down);
        bus_s += down_s;
        self.time += down_s;
        Metrics::add(&self.metrics.bus_bytes, down);
        Metrics::add(&self.metrics.jobs_completed, 1);

        // Evaluation succeeded — no failure can follow, so the live
        // accumulator moves (not clones) into the result.
        let mut ck = self.ckpt.take().expect("progress exists after training");
        ck.stats.add(&eval_stats);
        Ok((
            JobResult {
                name: job.name.clone(),
                boards: vec![self.board],
                accuracy,
                curve: ck.curve,
                stats: ck.stats,
                sim_compute_s: ck.compute_s + eval_s,
                sim_bus_s: bus_s,
                steps: total,
                weights: ck.w,
                biases: ck.b,
                checkpoints: std::mem::take(&mut self.checkpoints),
            },
            self.time,
        ))
    }

    /// Fold a received chunk into the live progress accumulator:
    /// curve points shift by `from` (the chunk's absolute start step),
    /// stats/compute accumulate, the cursor moves to `to`, and the
    /// chunk's parameters become the current ones.
    fn absorb(&mut self, chunk: ChunkData, from: usize, to: usize) {
        let ck = self.ckpt.get_or_insert_with(|| LeaderCkpt {
            steps_done: 0,
            w: Vec::new(),
            b: Vec::new(),
            curve: Vec::new(),
            stats: RunStats::default(),
            compute_s: 0.0,
            checkpoints: Vec::new(),
        });
        ck.curve.extend(chunk.curve.into_iter().map(|mut p| {
            p.step += from;
            p
        }));
        ck.stats.add(&chunk.stats);
        ck.compute_s += chunk.sim_s;
        ck.steps_done = to;
        ck.w = chunk.w;
        ck.b = chunk.b;
    }
}

// ------------------------------------------------------------------
// Inference serving entry (unchanged protocol)
// ------------------------------------------------------------------

#[cfg(test)]
fn expect_chunk(
    worker: &Worker,
    job_name: &str,
    board: usize,
) -> Result<(Vec<LossPoint>, RunStats, f64, Vec<Vec<i16>>, Vec<Vec<i16>>), ClusterError> {
    match worker.recv().map_err(died(job_name))? {
        Reply::ChunkDone { curve, stats, sim_seconds, w, b, checksum, .. } => {
            if params_checksum(&w, &b) != checksum {
                return Err(ClusterError::CorruptChunk(job_name.to_string(), board));
            }
            Ok((curve, stats, sim_seconds, w, b))
        }
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

/// Serve one inference micro-batch on a board's job, synchronously —
/// the leader-side entry of the dual-workload protocol (`InferChunk`
/// alongside training): send the rows, wait for the outputs, surface
/// worker death/errors as typed [`ClusterError`]s (the same never-hangs
/// contract as the training path). `qx` is a quantised
/// `rows × input_dim` batch; the reply is the `rows × output_dim`
/// outputs with the pass's stats and simulated seconds.
pub fn infer_on(
    worker: &Worker,
    job_name: &str,
    board: usize,
    job_id: usize,
    rows: usize,
    qx: Vec<i16>,
) -> Result<(Vec<i16>, RunStats, f64), ClusterError> {
    worker.send(Cmd::InferChunk { job: job_id, rows, qx }).map_err(died(job_name))?;
    match worker.recv().map_err(died(job_name))? {
        Reply::InferDone { out, stats, sim_seconds, .. } => Ok((out, stats, sim_seconds)),
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

#[cfg(test)]
fn expect_ready(worker: &Worker, job_name: &str, board: usize) -> Result<(), ClusterError> {
    match worker.recv().map_err(died(job_name))? {
        Reply::Ready { .. } => Ok(()),
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

// ------------------------------------------------------------------
// Divided orchestration with replica adoption
// ------------------------------------------------------------------

/// Each job owns a group of boards; groups run concurrently and fail
/// independently (there is no cross-group rescheduling — a group that
/// loses all its boards aborts the run with [`ClusterError::WorkerDied`]).
fn run_groups(
    cfg: &ClusterConfig,
    jobs: &[Job],
    placement: &Placement,
    worker_slots: &mut [Option<Worker>],
    board_time: &mut [f64],
    results: &mut [Option<JobResult>],
    metrics: &Arc<Metrics>,
) -> Result<(), ClusterError> {
    let policy = &cfg.recovery;
    let bus = cfg.bus;
    let sync_every = cfg.sync_every;
    let topo = (cfg.boards, cfg.sync);
    type GroupOut = (Vec<Worker>, Vec<f64>, Result<JobResult, ClusterError>);
    let outs: Vec<(usize, GroupOut)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (j, group) in placement.groups.iter().enumerate() {
            let group_workers: Vec<Worker> = group
                .iter()
                .map(|&b| worker_slots[b].take().expect("board used once"))
                .collect();
            let metrics = Arc::clone(metrics);
            let job = &jobs[j];
            let group = group.clone();
            handles.push((
                j,
                s.spawn(move || -> GroupOut {
                    let mut run = DividedRun::new(
                        job, j, &group_workers, &group, &bus, sync_every, topo, policy,
                        &metrics,
                    );
                    let result = run.drive();
                    let times = run.times.clone();
                    drop(run);
                    (group_workers, times, result)
                }),
            ));
        }
        handles
            .into_iter()
            .map(|(j, h)| (j, h.join().expect("leader thread panicked")))
            .collect()
    });
    let mut first_err: Option<ClusterError> = None;
    for (j, (group_workers, times, result)) in outs {
        for (k, &b) in placement.groups[j].iter().enumerate() {
            board_time[b] += times[k];
        }
        for w in group_workers {
            w.shutdown();
        }
        match result {
            Ok(r) => results[j] = Some(r),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// The per-replica state machine driving one divided job over its board
/// group, including adoption of replicas whose board died.
struct DividedRun<'a> {
    job: &'a Job,
    job_id: usize,
    workers: &'a [Worker],
    boards: &'a [usize],
    bus: &'a SystemBus,
    sync_every: usize,
    /// The run's sync policy (how the collective below is priced and,
    /// for [`SyncPolicy::BoundedStale`], whether it runs at all).
    sync: SyncPolicy,
    /// Total board count F of the whole run (checkpoint identity; can
    /// exceed this group's `k` when several groups share the cluster).
    total_boards: usize,
    /// Consecutive sync boundaries skipped since the last completed
    /// collective (always 0 for the deterministic policies).
    lag: usize,
    policy: &'a RecoveryPolicy,
    metrics: &'a Metrics,
    /// Per-slot liveness (a slot is a position in `workers`).
    alive: Vec<bool>,
    /// Replica → slot currently hosting its trainer.
    owner: Vec<usize>,
    /// Replica → worker-side trainer key.
    key: Vec<usize>,
    /// Replica → sampler steps its current trainer has consumed
    /// (`None` = no live trainer; must be re-established).
    cursor: Vec<Option<usize>>,
    /// Last broadcast parameters (what re-establishment binds).
    cur_w: Vec<Vec<i16>>,
    cur_b: Vec<Vec<i16>>,
    /// Steps completed by every replica.
    done: usize,
    /// Fresh trainer keys for adopted replicas (counts down from
    /// `usize::MAX`; never collides with job ids).
    next_key: usize,
    /// Per-slot simulated time.
    times: Vec<f64>,
    last_dead_slot: usize,
}

impl<'a> DividedRun<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        job: &'a Job,
        job_id: usize,
        workers: &'a [Worker],
        boards: &'a [usize],
        bus: &'a SystemBus,
        sync_every: usize,
        topo: (usize, SyncPolicy),
        policy: &'a RecoveryPolicy,
        metrics: &'a Metrics,
    ) -> DividedRun<'a> {
        let k = workers.len();
        DividedRun {
            job,
            job_id,
            workers,
            boards,
            bus,
            sync_every,
            sync: topo.1,
            total_boards: topo.0,
            lag: 0,
            policy,
            metrics,
            alive: vec![true; k],
            owner: (0..k).collect(),
            key: vec![job_id; k],
            cursor: vec![None; k],
            cur_w: Vec::new(),
            cur_b: Vec::new(),
            done: 0,
            next_key: usize::MAX,
            times: vec![0.0f64; k],
            last_dead_slot: 0,
        }
    }

    fn k(&self) -> usize {
        self.workers.len()
    }

    fn replica_cfg(&self, i: usize) -> TrainConfig {
        let mut cfg = self.job.cfg.clone();
        cfg.seed = cfg.seed.wrapping_add(i as u64 * 0x9E37);
        cfg
    }

    /// Evict a slot: mark dead, invalidate every replica it hosted, and
    /// return the typed death error (callers abort with it when the
    /// policy forbids rescheduling).
    fn kill_slot(&mut self, slot: usize) -> ClusterError {
        if self.alive[slot] {
            self.alive[slot] = false;
            if self.policy.reschedule {
                // Only an actual eviction (abort policy kills the whole
                // run instead — nothing is evicted from a pool).
                Metrics::add(&self.metrics.boards_evicted, 1);
            }
            for r in 0..self.k() {
                if self.owner[r] == slot {
                    self.cursor[r] = None;
                }
            }
        }
        self.last_dead_slot = slot;
        ClusterError::WorkerDied(self.job.name.clone(), self.boards[slot])
    }

    fn no_survivors(&self) -> ClusterError {
        ClusterError::WorkerDied(self.job.name.clone(), self.boards[self.last_dead_slot])
    }

    fn fatal(&self, slot: usize, message: String) -> ClusterError {
        ClusterError::Worker(self.job.name.clone(), self.boards[slot], message)
    }

    /// Wait for a `Ready` from `slot`. `Ok(false)` = slot died.
    fn ready(&mut self, slot: usize) -> Result<bool, ClusterError> {
        match self.workers[slot].recv() {
            Err(_) => {
                let e = self.kill_slot(slot);
                if !self.policy.reschedule {
                    return Err(e);
                }
                Ok(false)
            }
            Ok(Reply::Ready { .. }) => Ok(true),
            Ok(Reply::Error { message, .. }) => Err(self.fatal(slot, message)),
            Ok(other) => Err(self.fatal(slot, format!("unexpected reply {other:?}"))),
        }
    }

    /// Send `cmd` to `slot`. `Ok(false)` = slot died.
    fn send(&mut self, slot: usize, cmd: Cmd) -> Result<bool, ClusterError> {
        if self.workers[slot].send(cmd).is_err() {
            let e = self.kill_slot(slot);
            if !self.policy.reschedule {
                return Err(e);
            }
            return Ok(false);
        }
        Ok(true)
    }

    /// Make sure replica `r` has a live trainer positioned at the
    /// current `(cur_w, cur_b, done)` state, adopting it onto the
    /// lowest-indexed surviving slot if its board died.
    fn ensure(&mut self, r: usize) -> Result<(), ClusterError> {
        loop {
            if self.alive[self.owner[r]] && self.cursor[r] == Some(self.done) {
                return Ok(());
            }
            let Some(slot) = (0..self.k()).find(|&s| self.alive[s]) else {
                return Err(self.no_survivors());
            };
            let key = self.next_key;
            self.next_key -= 1;
            // Re-ship params (+ the shard the new host trains on).
            let up = self.job.spec.param_bytes()
                + dataset_bytes(&self.job.train_data) / self.k() as u64;
            self.times[slot] += self.bus.transfer_s(up);
            Metrics::add(&self.metrics.bus_bytes, up);
            let cfg = self.replica_cfg(r);
            let spec = self.job.spec.clone();
            if !self.send(slot, Cmd::NewTrainer { job: key, spec, cfg })? {
                continue;
            }
            if !self.ready(slot)? {
                continue;
            }
            let (w, b) = (self.cur_w.clone(), self.cur_b.clone());
            if !self.send(slot, Cmd::SetWeights { job: key, w, b })? {
                continue;
            }
            if !self.ready(slot)? {
                continue;
            }
            if self.done > 0 {
                if !self.send(slot, Cmd::SkipSamples { job: key, steps: self.done })? {
                    continue;
                }
                if !self.ready(slot)? {
                    continue;
                }
            }
            self.owner[r] = slot;
            self.key[r] = key;
            self.cursor[r] = Some(self.done);
            // The replica's outstanding chunk now recomputes here.
            Metrics::add(&self.metrics.chunks_rescheduled, 1);
            return Ok(());
        }
    }

    /// Receive one chunk reply from `slot`; `Ok(None)` = slot died.
    /// Checksum failures are recorded in the returned flag — retries run
    /// after the sweep so they never interleave with queued replies.
    #[allow(clippy::type_complexity)]
    fn recv_chunk(&mut self, slot: usize) -> Result<Option<(ChunkData, bool)>, ClusterError> {
        match self.workers[slot].recv() {
            Err(_) => {
                let e = self.kill_slot(slot);
                if !self.policy.reschedule {
                    return Err(e);
                }
                Ok(None)
            }
            Ok(Reply::ChunkDone { curve, stats, sim_seconds, w, b, checksum, .. }) => {
                let ok = params_checksum(&w, &b) == checksum;
                if !ok && self.policy.max_chunk_retries == 0 && !self.policy.reschedule {
                    // Pre-recovery trace: corrupt chunks abort on the spot.
                    return Err(ClusterError::CorruptChunk(
                        self.job.name.clone(),
                        self.boards[slot],
                    ));
                }
                Ok(Some((ChunkData { curve, stats, sim_s: sim_seconds, w, b }, ok)))
            }
            Ok(Reply::Error { message, .. }) => Err(self.fatal(slot, message)),
            Ok(other) => Err(self.fatal(slot, format!("unexpected reply {other:?}"))),
        }
    }

    /// Post-sweep retry of a checksum-failed chunk: re-read the params
    /// from the (idle) owner within the budget. `Ok(None)` = the board
    /// kept corrupting (or died) and was evicted.
    fn retry_params(&mut self, r: usize) -> Result<Option<Params>, ClusterError> {
        let slot = self.owner[r];
        for _ in 0..self.policy.max_chunk_retries {
            Metrics::add(&self.metrics.chunk_retries, 1);
            if !self.send(slot, Cmd::ReadParams { job: self.key[r] })? {
                return Ok(None);
            }
            match self.workers[slot].recv() {
                Err(_) => {
                    let e = self.kill_slot(slot);
                    if !self.policy.reschedule {
                        return Err(e);
                    }
                    return Ok(None);
                }
                Ok(Reply::Params { w, b, checksum, .. }) => {
                    if params_checksum(&w, &b) == checksum {
                        return Ok(Some((w, b)));
                    }
                }
                Ok(Reply::Error { message, .. }) => return Err(self.fatal(slot, message)),
                Ok(other) => {
                    return Err(self.fatal(slot, format!("unexpected reply {other:?}")))
                }
            }
        }
        // Persistently failing: evict.
        let _ = self.kill_slot(slot);
        if !self.policy.reschedule {
            return Err(ClusterError::CorruptChunk(
                self.job.name.clone(),
                self.boards[slot],
            ));
        }
        Ok(None)
    }

    /// Initial setup: spawn every replica's trainer on its own board
    /// (the pre-recovery command trace), derive the shared starting
    /// parameters, broadcast them, and fast-forward samplers on resume.
    fn setup(&mut self) -> Result<(), ClusterError> {
        validate_resume(self.job)?;
        if let Some(r) = &self.job.resume {
            if r.steps_done % self.sync_every != 0 && r.steps_done != self.job.cfg.steps {
                return Err(ClusterError::Checkpoint(format!(
                    "divided job {:?} can only resume on a weight-sync boundary \
                     (step {} is not a multiple of sync_every = {})",
                    self.job.name, r.steps_done, self.sync_every
                )));
            }
        }
        for slot in 0..self.k() {
            let up = self.job.spec.param_bytes()
                + dataset_bytes(&self.job.train_data) / self.k() as u64;
            self.times[slot] += self.bus.transfer_s(up);
            Metrics::add(&self.metrics.bus_bytes, up);
            let cfg = self.replica_cfg(slot);
            let spec = self.job.spec.clone();
            self.send(slot, Cmd::NewTrainer { job: self.job_id, spec, cfg })?;
        }
        for slot in 0..self.k() {
            if self.alive[slot] && self.ready(slot)? {
                self.cursor[slot] = Some(0);
            }
        }
        // Replicas start from identical weights: the job's explicit
        // initial parameters when given, else replica 0's seed init is
        // broadcast (derived via a zero-step probe chunk).
        let (w0, b0) = match &self.job.initial {
            Some((w0, b0)) => (w0.clone(), b0.clone()),
            None => self.derive_init()?,
        };
        self.cur_w = w0;
        self.cur_b = b0;
        for r in 0..self.k() {
            // Broadcast to live trainers; dead/unestablished replicas are
            // rebuilt (with these parameters) on first use.
            if !self.alive[self.owner[r]] || self.cursor[r].is_none() {
                continue;
            }
            let (w, b) = (self.cur_w.clone(), self.cur_b.clone());
            let key = self.key[r];
            self.send(self.owner[r], Cmd::SetWeights { job: key, w, b })?;
        }
        for r in 0..self.k() {
            if self.alive[self.owner[r]] && self.cursor[r].is_some() {
                self.ready(self.owner[r])?;
            }
        }
        if let Some(res) = &self.job.resume {
            self.done = res.steps_done;
            if self.done > 0 {
                for r in 0..self.k() {
                    if !self.alive[self.owner[r]] || self.cursor[r] != Some(0) {
                        self.cursor[r] = None; // rebuild at `done` on first use
                        continue;
                    }
                    let key = self.key[r];
                    let steps = self.done;
                    if self.send(self.owner[r], Cmd::SkipSamples { job: key, steps })?
                        && self.ready(self.owner[r])?
                    {
                        self.cursor[r] = Some(self.done);
                    }
                }
            }
        }
        Ok(())
    }

    /// Replica 0's seed-initialised parameters via a zero-step probe
    /// chunk (re-hosted if its board is gone).
    fn derive_init(&mut self) -> Result<Params, ClusterError> {
        loop {
            if !self.alive[self.owner[0]] || self.cursor[0].is_none() {
                // Fresh trainer for replica 0 on a surviving slot; at
                // done == 0 the seed init *is* the state — no
                // SetWeights / SkipSamples needed.
                let Some(slot) = (0..self.k()).find(|&s| self.alive[s]) else {
                    return Err(self.no_survivors());
                };
                let key = self.next_key;
                self.next_key -= 1;
                let cfg = self.replica_cfg(0);
                let spec = self.job.spec.clone();
                if !self.send(slot, Cmd::NewTrainer { job: key, spec, cfg })? {
                    continue;
                }
                if !self.ready(slot)? {
                    continue;
                }
                self.owner[0] = slot;
                self.key[0] = key;
                self.cursor[0] = Some(0);
            }
            let slot = self.owner[0];
            let key = self.key[0];
            let data = Arc::clone(&self.job.train_data);
            if !self.send(slot, Cmd::TrainChunk { job: key, data, steps: 0 })? {
                continue;
            }
            match self.recv_chunk(slot)? {
                None => continue,
                Some((chunk, true)) => return Ok((chunk.w, chunk.b)),
                Some((_, false)) => match self.retry_params(0)? {
                    Some(params) => return Ok(params),
                    None => continue,
                },
            }
        }
    }

    /// The synchronous data-parallel rounds, with per-round recovery.
    /// Returns `(curve, stats, compute_critical, bus_total, checkpoints)`.
    #[allow(clippy::type_complexity)]
    fn rounds(
        &mut self,
    ) -> Result<
        (Vec<LossPoint>, RunStats, f64, f64, Vec<TrainCheckpoint>),
        ClusterError,
    > {
        let total = self.job.cfg.steps;
        let k = self.k();
        let mut curve: Vec<LossPoint> =
            self.job.resume.as_ref().map_or_else(Vec::new, |r| r.curve.clone());
        let mut stats =
            self.job.resume.as_ref().map_or_else(RunStats::default, |r| r.stats);
        let mut compute_critical =
            self.job.resume.as_ref().map_or(0.0, |r| r.sim_compute_s);
        let mut bus_total = 0.0f64;
        let mut checkpoints: Vec<TrainCheckpoint> = Vec::new();
        let every = self.policy.checkpoint_every;

        while self.done < total {
            let steps = self.sync_every.min(total - self.done);
            let mut collected: Vec<Option<ChunkData>> = (0..k).map(|_| None).collect();
            loop {
                let missing: Vec<usize> =
                    (0..k).filter(|&r| collected[r].is_none()).collect();
                if missing.is_empty() {
                    break;
                }
                for &r in &missing {
                    self.ensure(r)?;
                }
                // Send sweep (replica order — chunk-index order is the
                // total order the averaging accumulates in).
                let mut sent = vec![false; k];
                for &r in &missing {
                    let slot = self.owner[r];
                    if !self.alive[slot] {
                        continue;
                    }
                    let key = self.key[r];
                    let data = Arc::clone(&self.job.train_data);
                    sent[r] = self.send(slot, Cmd::TrainChunk { job: key, data, steps })?;
                }
                // Receive sweep; corrupt params are retried afterwards.
                let mut corrupt: Vec<usize> = Vec::new();
                for &r in &missing {
                    if !sent[r] || !self.alive[self.owner[r]] {
                        continue;
                    }
                    match self.recv_chunk(self.owner[r])? {
                        None => {}
                        Some((chunk, true)) => {
                            self.times[self.owner[r]] += chunk.sim_s;
                            self.cursor[r] = Some(self.done + steps);
                            collected[r] = Some(chunk);
                        }
                        Some((chunk, false)) => {
                            self.times[self.owner[r]] += chunk.sim_s;
                            self.cursor[r] = Some(self.done + steps);
                            collected[r] = Some(chunk);
                            corrupt.push(r);
                        }
                    }
                }
                for r in corrupt {
                    if !self.alive[self.owner[r]] {
                        // Owner died after replying; recompute instead.
                        collected[r] = None;
                        continue;
                    }
                    match self.retry_params(r)? {
                        Some((w, b)) => {
                            let c = collected[r].as_mut().expect("collected above");
                            c.w = w;
                            c.b = b;
                        }
                        None => collected[r] = None, // evicted: recompute
                    }
                }
                if !self.policy.reschedule && collected.iter().any(Option::is_none) {
                    return Err(self.no_survivors());
                }
            }
            // Merge in replica order; replica 0 carries curve + stats.
            let mut ws = Vec::with_capacity(k);
            let mut bs = Vec::with_capacity(k);
            let mut round_max = 0.0f64;
            for (r, c) in collected.into_iter().enumerate() {
                let chunk = c.expect("loop above collected every replica");
                if r == 0 {
                    let done = self.done;
                    curve.extend(chunk.curve.into_iter().map(|mut p| {
                        p.step += done;
                        p
                    }));
                    stats.add(&chunk.stats);
                }
                round_max = round_max.max(chunk.sim_s);
                ws.push(chunk.w);
                bs.push(chunk.b);
            }
            compute_critical += round_max;
            // Weight sync under the run's [`SyncPolicy`] (charges from
            // the [`super::cost`] contention model). BoundedStale may
            // skip the collective while within its lag budget — the
            // replicas then continue on their own weights, diverged
            // from the last completed average — but the final boundary
            // always syncs so the reported parameters are a true
            // average of all replicas.
            let last_round = self.done + steps == total;
            let collective = match self.sync {
                SyncPolicy::BoundedStale { max_lag } if !last_round && self.lag < max_lag => {
                    self.lag += 1;
                    false
                }
                _ => true,
            };
            if collective {
                self.lag = 0;
                let p_bytes = self.job.spec.param_bytes();
                let (sync_s, sync_bytes, sync_cycles, per_slot_s);
                match self.sync {
                    SyncPolicy::Ring => {
                        // Survivors re-form the ring after an eviction:
                        // the collective is sized to the *live* board
                        // count, while the average still folds in all k
                        // replica parameter sets (adopted replicas run
                        // on surviving boards).
                        let live = self.alive.iter().filter(|&&a| a).count();
                        let c = ring_sync_cost(live, p_bytes, self.bus);
                        sync_s = c.seconds;
                        sync_bytes = c.bytes;
                        sync_cycles = c.cycles;
                        // Every ring member's link is busy for the
                        // whole collective.
                        per_slot_s = c.seconds;
                        self.cur_w = ring_average(&ws);
                        self.cur_b = ring_average(&bs);
                    }
                    SyncPolicy::Star | SyncPolicy::BoundedStale { .. } => {
                        // Star: gather k × params up, broadcast the
                        // average — charges identical to the pre-policy
                        // leader (asserted in cost.rs), so existing
                        // makespans and metrics stay bit-identical.
                        // BoundedStale's performed collectives are
                        // star-shaped too.
                        let c = star_sync_cost(k, p_bytes, self.bus);
                        sync_s = c.seconds;
                        sync_bytes = c.bytes;
                        sync_cycles = c.cycles;
                        per_slot_s = sync_s / k as f64;
                        self.cur_w = average_weights(&ws);
                        self.cur_b = average_weights(&bs);
                    }
                }
                Metrics::add(&self.metrics.bus_bytes, sync_bytes);
                Metrics::add(&self.metrics.sync_rounds, 1);
                Metrics::add(&self.metrics.sync_cycles, sync_cycles);
                bus_total += sync_s;
                let mut acked = vec![false; k];
                for r in 0..k {
                    let slot = self.owner[r];
                    if !self.alive[slot] {
                        self.cursor[r] = None;
                        continue;
                    }
                    let (w, b) = (self.cur_w.clone(), self.cur_b.clone());
                    let key = self.key[r];
                    acked[r] = self.send(slot, Cmd::SetWeights { job: key, w, b })?;
                    self.times[slot] += per_slot_s;
                }
                for r in 0..k {
                    if acked[r] && self.alive[self.owner[r]] && !self.ready(self.owner[r])? {
                        self.cursor[r] = None;
                    }
                }
            }
            let before = self.done;
            self.done += steps;
            // Divided checkpoints are only valid at completed-sync
            // boundaries (resume re-broadcasts the snapshot weights to
            // every replica), so a skipped boundary captures nothing.
            if collective && every > 0 && (self.done / every > before / every || self.done == total)
            {
                let run = RunIdentity {
                    seed: self.job.cfg.seed,
                    batch: self.job.cfg.batch,
                    lr: self.job.cfg.lr,
                    replicas: k,
                    sync_every: self.sync_every,
                    boards: self.total_boards,
                    sync: self.sync,
                    total_steps: total,
                };
                checkpoints.push(TrainCheckpoint::capture(
                    &self.job.spec,
                    &run,
                    self.done,
                    &curve,
                    stats,
                    compute_critical,
                    &self.cur_w,
                    &self.cur_b,
                ));
                Metrics::add(&self.metrics.checkpoints_captured, 1);
            }
        }
        Ok((curve, stats, compute_critical, bus_total, checkpoints))
    }

    /// Evaluate on replica 0 (re-hosting it first if its board died).
    fn evaluate_r0(&mut self) -> Result<(f64, RunStats, f64), ClusterError> {
        loop {
            self.ensure(0)?;
            let slot = self.owner[0];
            let key = self.key[0];
            let data = Arc::clone(&self.job.test_data);
            if !self.send(slot, Cmd::Evaluate { job: key, data })? {
                continue;
            }
            match self.workers[slot].recv() {
                Err(_) => {
                    let e = self.kill_slot(slot);
                    if !self.policy.reschedule {
                        return Err(e);
                    }
                }
                Ok(Reply::EvalDone { accuracy, stats, sim_seconds, .. }) => {
                    return Ok((accuracy, stats, sim_seconds))
                }
                Ok(Reply::Error { message, .. }) => return Err(self.fatal(slot, message)),
                Ok(other) => {
                    return Err(self.fatal(slot, format!("unexpected reply {other:?}")))
                }
            }
        }
    }

    fn drive(&mut self) -> Result<JobResult, ClusterError> {
        assert!(self.k() >= 1);
        self.setup()?;
        let (curve, mut stats, compute_critical, bus_total, checkpoints) = self.rounds()?;
        let (accuracy, eval_stats, eval_s) = self.evaluate_r0()?;
        self.times[self.owner[0]] += eval_s;
        stats.add(&eval_stats);
        Metrics::add(&self.metrics.jobs_completed, 1);
        Ok(JobResult {
            name: self.job.name.clone(),
            boards: self.boards.to_vec(),
            accuracy,
            curve,
            stats,
            sim_compute_s: compute_critical + eval_s,
            sim_bus_s: bus_total,
            steps: self.job.cfg.steps,
            weights: self.cur_w.clone(),
            biases: self.cur_b.clone(),
            checkpoints,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;
    use crate::util::Rng;

    fn mk_job(name: &str, seed: u64, steps: usize) -> Job {
        let fixed = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            name,
            &[4, 16, 3],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let ds = dataset::blobs(192, 3, 4, seed);
        let (train, test) = ds.split(0.75, &mut Rng::new(seed));
        Job {
            name: name.to_string(),
            spec,
            cfg: TrainConfig { batch: 16, lr: 1.0 / 256.0, steps, seed, log_every: 10 },
            train_data: Arc::new(train),
            test_data: Arc::new(test),
            initial: None,
            resume: None,
        }
    }

    #[test]
    fn one_to_one_two_jobs_two_boards() {
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let jobs = vec![mk_job("a", 1, 60), mk_job("b", 2, 60)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::OneToOne);
        assert_eq!(r.results.len(), 2);
        for jr in &r.results {
            assert!(jr.accuracy > 0.7, "{} acc {}", jr.name, jr.accuracy);
            assert!(jr.sim_compute_s > 0.0 && jr.sim_bus_s > 0.0);
        }
        assert_eq!(r.metrics.jobs_completed, 2);
        assert!(r.makespan_s > 0.0);
        // both boards did work
        assert!(r.board_time_s.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn sequential_more_jobs_than_boards() {
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let jobs =
            vec![mk_job("a", 1, 25), mk_job("b", 2, 25), mk_job("c", 3, 25), mk_job("d", 4, 25)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Sequential);
        assert_eq!(r.metrics.jobs_completed, 4);
        // a board running two jobs should take about twice one job's time
        let t = &r.board_time_s;
        assert!(t[0] > 0.0 && t[1] > 0.0);
    }

    #[test]
    fn divided_one_job_three_boards_syncs_weights() {
        let cfg = ClusterConfig { boards: 3, sync_every: 15, ..Default::default() };
        let jobs = vec![mk_job("dp", 5, 60)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Divided);
        assert_eq!(r.results[0].boards, vec![0, 1, 2]);
        assert_eq!(r.metrics.sync_rounds, 4); // 60/15
        assert!(r.results[0].accuracy > 0.7, "acc {}", r.results[0].accuracy);
        assert!(r.metrics.bus_bytes > 0);
    }

    #[test]
    fn initial_weights_respected_and_final_weights_reported() {
        // steps = 0 ⇒ the job's explicit initial parameters come back
        // untouched as the final parameters, on both scheduling paths.
        let shape_job = mk_job("shape", 6, 1);
        let w0: Vec<Vec<i16>> = shape_job
            .spec
            .layers
            .iter()
            .map(|l| vec![7i16; l.inputs * l.outputs])
            .collect();
        let b0: Vec<Vec<i16>> =
            shape_job.spec.layers.iter().map(|l| vec![3i16; l.outputs]).collect();
        let mut single = mk_job("single", 6, 0);
        single.initial = Some((w0.clone(), b0.clone()));
        let r = execute(&ClusterConfig { boards: 1, ..Default::default() }, &[single]).unwrap();
        assert_eq!(r.results[0].weights, w0);
        assert_eq!(r.results[0].biases, b0);
        let mut divided = mk_job("divided", 6, 0);
        divided.initial = Some((w0.clone(), b0.clone()));
        let r = execute(&ClusterConfig { boards: 2, ..Default::default() }, &[divided]).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Divided);
        assert_eq!(r.results[0].weights, w0);
        assert_eq!(r.results[0].biases, b0);
    }

    #[test]
    #[allow(deprecated)]
    fn run_cluster_shim_delegates_to_execute() {
        let cfg = ClusterConfig { boards: 1, ..Default::default() };
        let r = run_cluster(&cfg, &[mk_job("shim", 4, 10)]).unwrap();
        assert_eq!(r.results.len(), 1);
        assert!(matches!(run_cluster(&cfg, &[]), Err(ClusterError::NoJobs)));
    }

    #[test]
    fn infer_on_serves_between_train_chunks() {
        // A board mid-training-session answers inference micro-batches
        // through the same command channel — both workloads on one
        // board, with typed errors instead of hangs.
        let metrics = Metrics::shared();
        let device = FpgaDevice::by_name("XC7S75-2").unwrap();
        let job = mk_job("mix", 11, 6);
        let w = Worker::spawn(0, device, Arc::clone(&metrics), FaultPlan::none());
        w.send(Cmd::NewTrainer { job: 0, spec: job.spec.clone(), cfg: job.cfg.clone() })
            .unwrap();
        expect_ready(&w, "mix", 0).unwrap();
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&job.train_data), steps: 3 })
            .unwrap();
        expect_chunk(&w, "mix", 0).unwrap();
        // serve a 2-row micro-batch (not the training batch size) on the
        // current parameters
        let qx = job.train_data.encode_rows(0..2, job.spec.fixed);
        let (out, stats, sim_s) = infer_on(&w, "mix", 0, 0, 2, qx).unwrap();
        assert_eq!(out.len(), 2 * job.spec.output_dim());
        assert!(stats.cycles > 0 && sim_s > 0.0);
        // training resumes unperturbed on the same board
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&job.train_data), steps: 3 })
            .unwrap();
        expect_chunk(&w, "mix", 0).unwrap();
        assert_eq!(metrics.snapshot().infer_chunks, 1);
        // wrong-size rows surface as a typed worker error, not a hang
        let err = infer_on(&w, "mix", 0, 0, 3, vec![0i16; 5]).unwrap_err();
        assert!(matches!(err, ClusterError::Worker(ref n, 0, _) if n == "mix"), "{err}");
    }

    #[test]
    fn average_weights_elementwise_mean() {
        let a = vec![vec![10i16, -10], vec![4]];
        let b = vec![vec![20i16, -20], vec![6]];
        assert_eq!(average_weights(&[a, b]), vec![vec![15, -15], vec![5]]);
    }

    #[test]
    fn failure_injection_bad_job_does_not_hang_cluster() {
        // Job "bad" has a dataset whose dimensionality mismatches its
        // spec: a *logic* error, not a board fault — recovery must NOT
        // mask it; the leader surfaces it instead of deadlocking (or
        // endlessly rescheduling it around) the other board.
        let mut bad = mk_job("bad", 9, 30);
        bad.train_data = Arc::new(dataset::xor(32, 1)); // dim 2 != 4
        let jobs = vec![mk_job("good", 8, 30), bad];
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let t0 = std::time::Instant::now();
        let err = execute(&cfg, &jobs).unwrap_err();
        assert!(matches!(err, ClusterError::Worker(ref name, _, _) if name == "bad"), "{err}");
        assert!(t0.elapsed().as_secs() < 30, "cluster hung on worker failure");
    }

    #[test]
    fn errors_propagate() {
        assert!(matches!(
            execute(&ClusterConfig::default(), &[]),
            Err(ClusterError::NoJobs)
        ));
        let cfg = ClusterConfig { device: "nope".into(), ..Default::default() };
        assert!(matches!(
            execute(&cfg, &[mk_job("a", 1, 5)]),
            Err(ClusterError::UnknownDevice(_))
        ));
    }

    #[test]
    fn abort_policy_worker_death_surfaces_typed_error_without_hanging() {
        // The pre-recovery contract, pinned under RecoveryPolicy::abort:
        // board 1's worker dies on its very first command; the leader
        // must abort job "b" with WorkerDied while board 0 completes.
        let cfg = ClusterConfig {
            boards: 2,
            faults: FaultPlan::none().kill(1, 0),
            recovery: RecoveryPolicy::abort(),
            ..Default::default()
        };
        let jobs = vec![mk_job("a", 1, 10), mk_job("b", 2, 10)];
        let t0 = std::time::Instant::now();
        let err = execute(&cfg, &jobs).unwrap_err();
        assert!(
            matches!(err, ClusterError::WorkerDied(ref name, 1) if name == "b"),
            "{err}"
        );
        assert!(t0.elapsed().as_secs() < 30, "leader hung on worker death");
    }

    #[test]
    fn abort_policy_chunk_corruption_is_rejected() {
        // Single-board run under the abort policy: the one TrainChunk
        // reply is corrupted after checksumming; the leader must reject
        // it, not adopt it.
        let cfg = ClusterConfig {
            boards: 1,
            faults: FaultPlan::none().corrupt(0, 0),
            recovery: RecoveryPolicy::abort(),
            ..Default::default()
        };
        let err = execute(&cfg, &[mk_job("c", 3, 5)]).unwrap_err();
        assert!(
            matches!(err, ClusterError::CorruptChunk(ref name, 0) if name == "c"),
            "{err}"
        );
    }

    #[test]
    fn injected_reorder_surfaces_typed_protocol_error() {
        // Protocol violations are not board faults: recovery leaves them
        // as typed aborts even with rescheduling on (the default).
        let cfg = ClusterConfig {
            boards: 1,
            faults: FaultPlan::none().reorder(0, 0),
            ..Default::default()
        };
        let err = execute(&cfg, &[mk_job("r", 4, 5)]).unwrap_err();
        assert!(
            matches!(err, ClusterError::Worker(ref name, 0, ref m)
                if name == "r" && m.contains("unexpected reply")),
            "{err}"
        );
    }

    #[test]
    fn delay_only_faults_leave_results_bit_identical() {
        // Delays exercise wall-clock timing without touching the
        // synchronous protocol: results must match the clean run exactly,
        // on both the divided and the single-board path.
        for boards in [1usize, 2] {
            let clean = ClusterConfig { boards, ..Default::default() };
            let slow = ClusterConfig {
                boards,
                faults: FaultPlan::none().delay(0, 0).delay(0, 1),
                ..Default::default()
            };
            let jobs = vec![mk_job("d", 6, 25)];
            let r1 = execute(&clean, &jobs).unwrap();
            let r2 = execute(&slow, &jobs).unwrap();
            assert_eq!(r1.results[0].weights, r2.results[0].weights, "boards {boards}");
            assert_eq!(r1.results[0].biases, r2.results[0].biases, "boards {boards}");
            assert_eq!(r1.results[0].accuracy, r2.results[0].accuracy, "boards {boards}");
            assert!(r2.metrics.faults_injected > 0, "delays did not fire");
        }
    }

    #[test]
    fn recovery_reschedules_a_dead_boards_job_bit_identically() {
        // Sequential pool, board 1 dies on its first command. With the
        // default recovery policy job "b" restarts on board 0 and the
        // whole run completes with results bit-identical to a clean run.
        let jobs = vec![mk_job("a", 1, 12), mk_job("b", 2, 12)];
        let clean = execute(&ClusterConfig { boards: 2, ..Default::default() }, &jobs).unwrap();
        let cfg = ClusterConfig {
            boards: 2,
            faults: FaultPlan::none().kill(1, 0),
            ..Default::default()
        };
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.metrics.jobs_completed, 2);
        assert!(r.metrics.boards_evicted >= 1);
        assert!(r.metrics.chunks_rescheduled >= 1);
        for (jr, cl) in r.results.iter().zip(&clean.results) {
            assert_eq!(jr.weights, cl.weights, "{}", jr.name);
            assert_eq!(jr.biases, cl.biases, "{}", jr.name);
            assert_eq!(jr.accuracy, cl.accuracy, "{}", jr.name);
            assert_eq!(jr.curve, cl.curve, "{}", jr.name);
            assert_eq!(jr.stats, cl.stats, "{}", jr.name);
        }
        // the rescheduled job ran on the surviving board
        assert_eq!(r.results[1].boards, vec![0]);
    }

    #[test]
    fn recovery_retries_a_corrupt_chunk_over_the_bus() {
        // One corruption site: the chunk reply fails its checksum, the
        // retry (ReadParams) is clean — the run completes bit-identical
        // to a fault-free one, with no eviction.
        let jobs = vec![mk_job("c", 3, 8)];
        let clean = execute(&ClusterConfig { boards: 1, ..Default::default() }, &jobs).unwrap();
        let cfg = ClusterConfig {
            boards: 1,
            faults: FaultPlan::none().corrupt(0, 0),
            ..Default::default()
        };
        let r = execute(&cfg, &jobs).unwrap();
        assert!(r.metrics.chunk_retries >= 1);
        assert_eq!(r.metrics.boards_evicted, 0);
        assert_eq!(r.results[0].weights, clean.results[0].weights);
        assert_eq!(r.results[0].curve, clean.results[0].curve);
    }

    #[test]
    fn persistent_corruption_evicts_and_errors_only_without_survivors() {
        // Corruption at chunk indices 0..=3 outlasts the 2-retry budget.
        // With one board there is nowhere left to go: typed CorruptChunk.
        let plan = FaultPlan::none().corrupt(0, 0).corrupt(0, 1).corrupt(0, 2).corrupt(0, 3);
        let cfg = ClusterConfig { boards: 1, faults: plan, ..Default::default() };
        let err = execute(&cfg, &[mk_job("p", 5, 6)]).unwrap_err();
        assert!(
            matches!(err, ClusterError::WorkerDied(..) | ClusterError::CorruptChunk(..)),
            "{err}"
        );
    }

    #[test]
    fn divided_replica_adoption_keeps_weights_bit_identical() {
        // One job over three boards; board 2 dies mid-run. Its replica
        // is adopted by a survivor and recomputed from the last average,
        // so the final averaged weights equal the clean run's exactly.
        let jobs = vec![mk_job("dp", 7, 30)];
        let base = ClusterConfig { boards: 3, sync_every: 10, ..Default::default() };
        let clean = execute(&base, &jobs).unwrap();
        // kill board 2 on its 3rd command (mid-round TrainChunk)
        let cfg = ClusterConfig {
            faults: FaultPlan::none().kill(2, 3),
            ..base.clone()
        };
        let r = execute(&cfg, &jobs).unwrap();
        assert!(r.metrics.boards_evicted >= 1, "no eviction recorded");
        assert!(r.metrics.chunks_rescheduled >= 1, "no adoption recorded");
        assert_eq!(r.results[0].weights, clean.results[0].weights);
        assert_eq!(r.results[0].biases, clean.results[0].biases);
        assert_eq!(r.results[0].curve, clean.results[0].curve);
        assert_eq!(r.results[0].accuracy, clean.results[0].accuracy);
        assert_eq!(r.results[0].boards, clean.results[0].boards, "group identity kept");
    }

    #[test]
    fn checkpoints_are_captured_and_resume_bit_exactly() {
        // checkpoint_every chunks the job; resuming a fresh run from the
        // mid-run snapshot reproduces the uninterrupted run's weights,
        // curve, and stats bit-exactly.
        let job = mk_job("ck", 8, 40);
        let cfg = ClusterConfig {
            boards: 1,
            recovery: RecoveryPolicy::checkpointed(10),
            ..Default::default()
        };
        let full = execute(&cfg, std::slice::from_ref(&job)).unwrap();
        let jr = &full.results[0];
        assert_eq!(jr.checkpoints.len(), 4, "40 steps / every 10");
        assert_eq!(full.metrics.checkpoints_captured, 4);
        let mid = &jr.checkpoints[1]; // step 20
        assert_eq!(mid.steps_done, 20);
        // serialise → parse → resume
        let mid = TrainCheckpoint::from_bytes(&mid.to_bytes()).unwrap();
        let mut resumed_job = job.clone();
        resumed_job.initial = Some(mid.weights());
        resumed_job.resume = Some(JobResume::from_checkpoint(&mid));
        let resumed = execute(&cfg, &[resumed_job]).unwrap();
        let rr = &resumed.results[0];
        assert_eq!(rr.weights, jr.weights);
        assert_eq!(rr.biases, jr.biases);
        assert_eq!(rr.curve, jr.curve);
        assert_eq!(rr.stats, jr.stats);
        assert_eq!(rr.accuracy, jr.accuracy);
        assert_eq!(rr.sim_compute_s, jr.sim_compute_s);
    }

    #[test]
    fn divided_checkpoint_resume_is_bit_exact_on_sync_boundaries() {
        let job = mk_job("dpc", 12, 40);
        let cfg = ClusterConfig {
            boards: 2,
            sync_every: 10,
            recovery: RecoveryPolicy::checkpointed(20),
            ..Default::default()
        };
        let full = execute(&cfg, std::slice::from_ref(&job)).unwrap();
        let jr = &full.results[0];
        assert!(!jr.checkpoints.is_empty());
        let mid = jr.checkpoints[0].clone(); // first boundary ≥ 20
        assert_eq!(mid.steps_done % 10, 0, "divided snapshots sit on sync boundaries");
        let mut resumed_job = job.clone();
        resumed_job.initial = Some(mid.weights());
        resumed_job.resume = Some(JobResume::from_checkpoint(&mid));
        let resumed = execute(&cfg, &[resumed_job]).unwrap();
        assert_eq!(resumed.results[0].weights, jr.weights);
        assert_eq!(resumed.results[0].biases, jr.biases);
        assert_eq!(resumed.results[0].curve, jr.curve);
        // off-boundary resume is a typed error, not silent divergence
        let mut bad = job.clone();
        let mut off = JobResume::from_checkpoint(&mid);
        off.steps_done = 7;
        bad.initial = Some(mid.weights());
        bad.resume = Some(off);
        assert!(matches!(
            execute(&cfg, &[bad]),
            Err(ClusterError::Checkpoint(_))
        ));
    }

    #[test]
    fn resume_without_initial_parameters_is_rejected() {
        let mut job = mk_job("bad", 2, 10);
        job.resume = Some(JobResume { steps_done: 5, ..JobResume::default() });
        let cfg = ClusterConfig { boards: 1, ..Default::default() };
        assert!(matches!(execute(&cfg, &[job]), Err(ClusterError::Checkpoint(_))));
    }

    #[test]
    fn ring_sync_is_bit_identical_to_star_and_cheaper_on_the_bus() {
        let jobs = vec![mk_job("rs", 5, 60)];
        let base = ClusterConfig { boards: 3, sync_every: 15, ..Default::default() };
        let star = execute(&base, &jobs).unwrap();
        let ring_cfg = ClusterConfig { sync: SyncPolicy::Ring, ..base };
        let ring = execute(&ring_cfg, &jobs).unwrap();
        assert_eq!(ring.results[0].weights, star.results[0].weights);
        assert_eq!(ring.results[0].biases, star.results[0].biases);
        assert_eq!(ring.results[0].curve, star.results[0].curve);
        assert_eq!(ring.results[0].accuracy, star.results[0].accuracy);
        assert_eq!(ring.results[0].stats, star.results[0].stats);
        assert_eq!(ring.metrics.sync_rounds, star.metrics.sync_rounds);
        // Same averages, different cost shape: the ring avoids the
        // leader's serialized link.
        assert!(ring.metrics.sync_cycles > 0);
        assert!(
            ring.metrics.sync_cycles < star.metrics.sync_cycles,
            "ring {} !< star {}",
            ring.metrics.sync_cycles,
            star.metrics.sync_cycles
        );
    }

    #[test]
    fn bounded_stale_zero_lag_degenerates_to_star() {
        let jobs = vec![mk_job("bz", 9, 60)];
        let base = ClusterConfig { boards: 3, sync_every: 15, ..Default::default() };
        let star = execute(&base, &jobs).unwrap();
        let stale_cfg =
            ClusterConfig { sync: SyncPolicy::BoundedStale { max_lag: 0 }, ..base };
        let stale = execute(&stale_cfg, &jobs).unwrap();
        assert_eq!(stale.results[0].weights, star.results[0].weights);
        assert_eq!(stale.results[0].biases, star.results[0].biases);
        assert_eq!(stale.results[0].curve, star.results[0].curve);
        assert_eq!(stale.results[0].accuracy, star.results[0].accuracy);
        assert_eq!(stale.metrics.sync_rounds, star.metrics.sync_rounds);
        assert_eq!(stale.metrics.sync_cycles, star.metrics.sync_cycles);
        assert_eq!(stale.metrics.bus_bytes, star.metrics.bus_bytes);
    }

    #[test]
    fn bounded_stale_skips_collectives_within_the_lag_budget() {
        // Boundaries at 15/30/45/60 with max_lag 1: skip, sync, skip,
        // forced final sync — exactly 2 collectives, and the run still
        // trains (deterministically: same config, same result).
        let cfg = ClusterConfig {
            boards: 3,
            sync_every: 15,
            sync: SyncPolicy::BoundedStale { max_lag: 1 },
            ..Default::default()
        };
        let jobs = vec![mk_job("bs", 5, 60)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.metrics.sync_rounds, 2, "skip/sync/skip/forced-final");
        assert!(r.results[0].accuracy > 0.5, "acc {}", r.results[0].accuracy);
        let again = execute(&cfg, &jobs).unwrap();
        assert_eq!(again.results[0].weights, r.results[0].weights);
        assert_eq!(again.results[0].curve, r.results[0].curve);
    }

    #[test]
    fn ring_heals_after_eviction_and_stays_bit_identical() {
        // Board 2 dies mid-run: its replica is adopted, the survivors
        // re-form a 2-board ring (cheaper collectives), and the final
        // weights still equal the fault-free ring run's exactly.
        let jobs = vec![mk_job("rh", 7, 30)];
        let base = ClusterConfig {
            boards: 3,
            sync_every: 10,
            sync: SyncPolicy::Ring,
            ..Default::default()
        };
        let clean = execute(&base, &jobs).unwrap();
        let cfg = ClusterConfig { faults: FaultPlan::none().kill(2, 3), ..base };
        let r = execute(&cfg, &jobs).unwrap();
        assert!(r.metrics.boards_evicted >= 1, "no eviction recorded");
        assert_eq!(r.results[0].weights, clean.results[0].weights);
        assert_eq!(r.results[0].biases, clean.results[0].biases);
        assert_eq!(r.results[0].curve, clean.results[0].curve);
        assert_eq!(r.results[0].accuracy, clean.results[0].accuracy);
        assert!(
            r.metrics.sync_cycles < clean.metrics.sync_cycles,
            "the healed 2-board ring should be cheaper than the 3-board one"
        );
    }
}
