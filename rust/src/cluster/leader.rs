//! The cluster leader: schedules jobs onto boards (per §2's three cases),
//! orchestrates data-parallel weight averaging for divided jobs, accounts
//! simulated bus + compute time, and aggregates results.

use super::bus::{params_checksum, SystemBus};
use super::fault::FaultPlan;
use super::metrics::{Metrics, MetricsSnapshot};
use super::scheduler::{schedule, Placement, PlacementMode};
use super::worker::{Cmd, Reply, Worker, WorkerGone};
use crate::hw::{FpgaDevice, RunStats};
use crate::nn::dataset::Dataset;
use crate::nn::trainer::{LossPoint, TrainConfig};
use crate::nn::MlpSpec;
use std::sync::Arc;
use thiserror::Error;

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of FPGA boards.
    pub boards: usize,
    /// Board part name (Table 8 catalog).
    pub device: String,
    /// Host↔board link model.
    pub bus: SystemBus,
    /// Steps between weight syncs for divided jobs.
    pub sync_every: usize,
    /// Deterministic fault schedule (empty = no faults) — the testkit's
    /// fault differential injects worker death, chunk corruption, and
    /// delayed/reordered replies through this.
    pub faults: FaultPlan,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            boards: 2,
            device: "XC7S75-2".into(),
            bus: SystemBus::default(),
            sync_every: 20,
            faults: FaultPlan::none(),
        }
    }
}

/// Per-layer quantised parameters `(weights, biases)` as shipped over
/// the bus.
pub type Params = (Vec<Vec<i16>>, Vec<Vec<i16>>);

/// One training job.
#[derive(Debug, Clone)]
pub struct Job {
    /// Job name (reporting).
    pub name: String,
    /// Network.
    pub spec: MlpSpec,
    /// Trainer configuration (total steps live here).
    pub cfg: TrainConfig,
    /// Training split.
    pub train_data: Arc<Dataset>,
    /// Test split.
    pub test_data: Arc<Dataset>,
    /// Optional starting parameters (checkpoint restore / session
    /// weights); `None` ⇒ each board initialises from `cfg.seed` (divided
    /// jobs then broadcast replica 0's init).
    pub initial: Option<Params>,
}

/// Result of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Job name.
    pub name: String,
    /// Boards it ran on.
    pub boards: Vec<usize>,
    /// Final test accuracy.
    pub accuracy: f64,
    /// Loss curve (replica 0's view for divided jobs).
    pub curve: Vec<LossPoint>,
    /// Aggregated machine stats.
    pub stats: RunStats,
    /// Simulated compute seconds (critical path over replicas).
    pub sim_compute_s: f64,
    /// Simulated bus seconds attributed to this job.
    pub sim_bus_s: f64,
    /// Steps executed (per replica).
    pub steps: usize,
    /// Final per-layer weights (post-averaging for divided jobs) — what a
    /// [`crate::session::Session`] adopts after a cluster train.
    pub weights: Vec<Vec<i16>>,
    /// Final per-layer biases.
    pub biases: Vec<Vec<i16>>,
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Placement used.
    pub placement: Placement,
    /// Per-job results (job order preserved).
    pub results: Vec<JobResult>,
    /// Simulated makespan: max over boards of accumulated sim time.
    pub makespan_s: f64,
    /// Per-board simulated busy time.
    pub board_time_s: Vec<f64>,
    /// Metrics snapshot.
    pub metrics: MetricsSnapshot,
    /// Wall-clock seconds spent simulating.
    pub wall_s: f64,
}

/// Cluster errors.
#[derive(Debug, Error)]
pub enum ClusterError {
    /// Unknown device name.
    #[error("unknown FPGA part {0:?}")]
    UnknownDevice(String),
    /// A worker reported an error.
    #[error("job {0} on board {1}: {2}")]
    Worker(String, usize, String),
    /// A worker thread died (channel closed) while serving a job — the
    /// typed surface of injected (or real) worker death; the leader
    /// aborts the job instead of hanging on the dead channel.
    #[error("job {0}: board {1} worker died (channel closed)")]
    WorkerDied(String, usize),
    /// A returned parameter chunk failed its bus integrity check
    /// ([`params_checksum`]); the leader rejects it rather than adopting
    /// or averaging corrupted parameters.
    #[error("job {0}: board {1} returned a corrupt parameter chunk (checksum mismatch)")]
    CorruptChunk(String, usize),
    /// No jobs given.
    #[error("no jobs")]
    NoJobs,
}

/// Map a closed worker channel into the typed error for `job`.
fn died(job_name: &str) -> impl '_ + Fn(WorkerGone) -> ClusterError {
    move |g| ClusterError::WorkerDied(job_name.to_string(), g.board)
}

/// Average quantised weights across replicas (element-wise i32 mean,
/// round-to-nearest-even-free: plain round toward zero like the DSP
/// truncation).
pub fn average_weights(replicas: &[Vec<Vec<i16>>]) -> Vec<Vec<i16>> {
    let k = replicas.len() as i32;
    assert!(k > 0);
    let mut out = replicas[0].clone();
    for (l, layer) in out.iter_mut().enumerate() {
        for (i, v) in layer.iter_mut().enumerate() {
            let sum: i32 = replicas.iter().map(|r| r[l][i] as i32).sum();
            *v = (sum / k) as i16;
        }
    }
    out
}

/// Run a set of jobs on the cluster; blocks until completion.
#[deprecated(note = "use `session::Session` (Target::Cluster) or \
                     `session::Session::train_many`; `cluster::execute` \
                     is the bare engine entry")]
pub fn run_cluster(cfg: &ClusterConfig, jobs: &[Job]) -> Result<ClusterReport, ClusterError> {
    execute(cfg, jobs)
}

/// Engine entry point: run a set of jobs on the cluster; blocks until
/// completion. Front doors ([`crate::session::Session::train_many`], the
/// deprecated [`run_cluster`]) delegate here.
pub fn execute(cfg: &ClusterConfig, jobs: &[Job]) -> Result<ClusterReport, ClusterError> {
    if jobs.is_empty() {
        return Err(ClusterError::NoJobs);
    }
    let device = FpgaDevice::by_name(&cfg.device)
        .ok_or_else(|| ClusterError::UnknownDevice(cfg.device.clone()))?;
    let wall0 = std::time::Instant::now();
    let metrics = Metrics::shared();
    let placement = schedule(jobs.len(), cfg.boards);
    // Workers are moved into the orchestrator thread that exclusively
    // drives them (board queues / board groups are disjoint), because the
    // reply receiver is single-consumer.
    let mut worker_slots: Vec<Option<Worker>> = (0..cfg.boards)
        .map(|b| Some(Worker::spawn(b, device, Arc::clone(&metrics), cfg.faults.clone())))
        .collect();

    let mut board_time = vec![0.0f64; cfg.boards];
    let mut results: Vec<Option<JobResult>> = (0..jobs.len()).map(|_| None).collect();

    match placement.mode {
        PlacementMode::Sequential | PlacementMode::OneToOne => {
            // Per-board queues run concurrently; jobs within a queue run
            // in order. Orchestrate each board from its own leader thread.
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for (b, queue) in placement.queues.iter().enumerate() {
                    let worker = worker_slots[b].take().expect("board used once");
                    let metrics = Arc::clone(&metrics);
                    let bus = cfg.bus;
                    let jobs_ref = jobs;
                    let queue = queue.clone();
                    type QueueOut = Result<(f64, Vec<(usize, JobResult)>), ClusterError>;
                    handles.push(s.spawn(move || -> QueueOut {
                        let mut t = 0.0f64;
                        let mut out = Vec::new();
                        for j in queue {
                            let (r, dt) =
                                run_single(&worker, b, &jobs_ref[j], j, &bus, &metrics)?;
                            t += dt;
                            out.push((j, r));
                        }
                        Ok((t, out))
                    }));
                }
                for (b, h) in handles.into_iter().enumerate() {
                    let (t, rs) = h.join().expect("leader thread panicked")?;
                    board_time[b] += t;
                    for (j, r) in rs {
                        results[j] = Some(r);
                    }
                }
                Ok::<(), ClusterError>(())
            })?;
        }
        PlacementMode::Divided => {
            // Each job owns a group of boards; groups run concurrently.
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for (j, group) in placement.groups.iter().enumerate() {
                    let group_workers: Vec<Worker> =
                        group
                            .iter()
                            .map(|&b| worker_slots[b].take().expect("board used once"))
                            .collect();
                    let metrics = Arc::clone(&metrics);
                    let bus = cfg.bus;
                    let job = &jobs[j];
                    let sync_every = cfg.sync_every;
                    let group = group.clone();
                    handles.push(s.spawn(
                        move || -> Result<(Vec<f64>, JobResult), ClusterError> {
                            let refs: Vec<&Worker> = group_workers.iter().collect();
                            run_divided(&refs, &group, job, j, &bus, sync_every, &metrics)
                        },
                    ));
                }
                for (j, h) in handles.into_iter().enumerate() {
                    let (times, r) = h.join().expect("leader thread panicked")?;
                    for (k, &b) in placement.groups[j].iter().enumerate() {
                        board_time[b] += times[k];
                    }
                    results[j] = Some(r);
                }
                Ok::<(), ClusterError>(())
            })?;
        }
    }

    drop(worker_slots);
    let results: Vec<JobResult> = results.into_iter().map(Option::unwrap).collect();
    let makespan_s = board_time.iter().cloned().fold(0.0, f64::max);
    Ok(ClusterReport {
        placement,
        results,
        makespan_s,
        board_time_s: board_time,
        metrics: metrics.snapshot(),
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

/// Dataset bytes shipped to a board (quantised lanes).
fn dataset_bytes(ds: &Dataset) -> u64 {
    (ds.len() * (ds.dim() + ds.classes)) as u64 * 2
}

fn expect_chunk(
    worker: &Worker,
    job_name: &str,
    board: usize,
) -> Result<(Vec<LossPoint>, RunStats, f64, Vec<Vec<i16>>, Vec<Vec<i16>>), ClusterError> {
    match worker.recv().map_err(died(job_name))? {
        Reply::ChunkDone { curve, stats, sim_seconds, w, b, checksum, .. } => {
            if params_checksum(&w, &b) != checksum {
                return Err(ClusterError::CorruptChunk(job_name.to_string(), board));
            }
            Ok((curve, stats, sim_seconds, w, b))
        }
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

/// Serve one inference micro-batch on a board's job, synchronously —
/// the leader-side entry of the dual-workload protocol (`InferChunk`
/// alongside training): send the rows, wait for the outputs, surface
/// worker death/errors as typed [`ClusterError`]s (the same never-hangs
/// contract as the training path). `qx` is a quantised
/// `rows × input_dim` batch; the reply is the `rows × output_dim`
/// outputs with the pass's stats and simulated seconds.
pub fn infer_on(
    worker: &Worker,
    job_name: &str,
    board: usize,
    job_id: usize,
    rows: usize,
    qx: Vec<i16>,
) -> Result<(Vec<i16>, RunStats, f64), ClusterError> {
    worker.send(Cmd::InferChunk { job: job_id, rows, qx }).map_err(died(job_name))?;
    match worker.recv().map_err(died(job_name))? {
        Reply::InferDone { out, stats, sim_seconds, .. } => Ok((out, stats, sim_seconds)),
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

fn expect_ready(worker: &Worker, job_name: &str, board: usize) -> Result<(), ClusterError> {
    match worker.recv().map_err(died(job_name))? {
        Reply::Ready { .. } => Ok(()),
        Reply::Error { message, .. } => {
            Err(ClusterError::Worker(job_name.to_string(), board, message))
        }
        other => Err(ClusterError::Worker(
            job_name.to_string(),
            board,
            format!("unexpected reply {other:?}"),
        )),
    }
}

/// Run one job on one board (OneToOne / Sequential path).
fn run_single(
    worker: &Worker,
    board: usize,
    job: &Job,
    job_id: usize,
    bus: &SystemBus,
    metrics: &Metrics,
) -> Result<(JobResult, f64), ClusterError> {
    // Ship program + params + dataset.
    let up_bytes = job.spec.param_bytes() + dataset_bytes(&job.train_data);
    let mut bus_s = bus.transfer_s(up_bytes);
    Metrics::add(&metrics.bus_bytes, up_bytes);

    worker
        .send(Cmd::NewTrainer { job: job_id, spec: job.spec.clone(), cfg: job.cfg.clone() })
        .map_err(died(&job.name))?;
    expect_ready(worker, &job.name, board)?;
    if let Some((w0, b0)) = &job.initial {
        worker
            .send(Cmd::SetWeights { job: job_id, w: w0.clone(), b: b0.clone() })
            .map_err(died(&job.name))?;
        expect_ready(worker, &job.name, board)?;
    }
    worker
        .send(Cmd::TrainChunk {
            job: job_id,
            data: Arc::clone(&job.train_data),
            steps: job.cfg.steps,
        })
        .map_err(died(&job.name))?;
    let (curve, stats, sim_s, final_w, final_b) = expect_chunk(worker, &job.name, board)?;

    worker
        .send(Cmd::Evaluate { job: job_id, data: Arc::clone(&job.test_data) })
        .map_err(died(&job.name))?;
    let (accuracy, eval_stats, eval_s) = match worker.recv().map_err(died(&job.name))? {
        Reply::EvalDone { accuracy, stats, sim_seconds, .. } => (accuracy, stats, sim_seconds),
        Reply::Error { message, .. } => {
            return Err(ClusterError::Worker(job.name.clone(), board, message))
        }
        other => {
            return Err(ClusterError::Worker(
                job.name.clone(),
                board,
                format!("unexpected reply {other:?}"),
            ))
        }
    };
    // Results readback.
    let down = job.spec.param_bytes();
    bus_s += bus.transfer_s(down);
    Metrics::add(&metrics.bus_bytes, down);
    Metrics::add(&metrics.jobs_completed, 1);

    let mut total_stats = stats;
    total_stats.add(&eval_stats);
    let total = sim_s + eval_s + bus_s;
    Ok((
        JobResult {
            name: job.name.clone(),
            boards: vec![board],
            accuracy,
            curve,
            stats: total_stats,
            sim_compute_s: sim_s + eval_s,
            sim_bus_s: bus_s,
            steps: job.cfg.steps,
            weights: final_w,
            biases: final_b,
        },
        total,
    ))
}

/// Run one job data-parallel over a board group with periodic weight
/// averaging (Divided path).
fn run_divided(
    group_workers: &[&Worker],
    boards: &[usize],
    job: &Job,
    job_id: usize,
    bus: &SystemBus,
    sync_every: usize,
    metrics: &Metrics,
) -> Result<(Vec<f64>, JobResult), ClusterError> {
    let k = group_workers.len();
    assert!(k >= 1);
    let mut times = vec![0.0f64; k];

    // Ship params + a dataset shard to every board.
    for (i, w) in group_workers.iter().enumerate() {
        let up = job.spec.param_bytes() + dataset_bytes(&job.train_data) / k as u64;
        times[i] += bus.transfer_s(up);
        Metrics::add(&metrics.bus_bytes, up);
        let mut cfg = job.cfg.clone();
        cfg.seed = cfg.seed.wrapping_add(i as u64 * 0x9E37);
        w.send(Cmd::NewTrainer { job: job_id, spec: job.spec.clone(), cfg })
            .map_err(died(&job.name))?;
    }
    for (i, w) in group_workers.iter().enumerate() {
        expect_ready(w, &job.name, boards[i])?;
    }
    // Replicas start from identical weights: the job's explicit initial
    // parameters when given, else replica 0's seed init is broadcast.
    let (w0, b0) = match &job.initial {
        Some((w0, b0)) => (w0.clone(), b0.clone()),
        None => {
            group_workers[0]
                .send(Cmd::TrainChunk {
                    job: job_id,
                    data: Arc::clone(&job.train_data),
                    steps: 0,
                })
                .map_err(died(&job.name))?;
            let (_, _, _, w0, b0) = expect_chunk(group_workers[0], &job.name, boards[0])?;
            (w0, b0)
        }
    };
    for (i, w) in group_workers.iter().enumerate() {
        w.send(Cmd::SetWeights { job: job_id, w: w0.clone(), b: b0.clone() })
            .map_err(died(&job.name))?;
        expect_ready(w, &job.name, boards[i])?;
    }

    let total_steps = job.cfg.steps;
    let mut done = 0usize;
    let mut curve = Vec::new();
    let mut stats = RunStats::default();
    let mut compute_critical = 0.0f64;
    let mut bus_total = 0.0f64;
    // Final synced parameters (what the last averaging round broadcast).
    let mut cur_w = w0;
    let mut cur_b = b0;
    while done < total_steps {
        let steps = sync_every.min(total_steps - done);
        for w in group_workers {
            w.send(Cmd::TrainChunk {
                job: job_id,
                data: Arc::clone(&job.train_data),
                steps,
            })
            .map_err(died(&job.name))?;
        }
        let mut ws = Vec::with_capacity(k);
        let mut bs = Vec::with_capacity(k);
        let mut round_max = 0.0f64;
        for (i, w) in group_workers.iter().enumerate() {
            let (c, st, sim_s, wi, bi) = expect_chunk(w, &job.name, boards[i])?;
            if i == 0 {
                curve.extend(c.into_iter().map(|mut p| {
                    p.step += done;
                    p
                }));
                stats.add(&st);
            }
            round_max = round_max.max(sim_s);
            times[i] += sim_s;
            ws.push(wi);
            bs.push(bi);
        }
        compute_critical += round_max;
        // Weight sync: gather k × params up, broadcast averaged params.
        let sync_bytes = job.spec.param_bytes() * (k as u64 + 1);
        let sync_s = bus.transfer_s(job.spec.param_bytes()) * (k as f64 + 1.0);
        Metrics::add(&metrics.bus_bytes, sync_bytes);
        Metrics::add(&metrics.sync_rounds, 1);
        bus_total += sync_s;
        let avg_w = average_weights(&ws);
        let avg_b = average_weights(&bs);
        for (i, w) in group_workers.iter().enumerate() {
            w.send(Cmd::SetWeights { job: job_id, w: avg_w.clone(), b: avg_b.clone() })
                .map_err(died(&job.name))?;
            times[i] += sync_s / k as f64;
        }
        cur_w = avg_w;
        cur_b = avg_b;
        for (i, w) in group_workers.iter().enumerate() {
            expect_ready(w, &job.name, boards[i])?;
        }
        done += steps;
    }

    // Evaluate on replica 0.
    group_workers[0]
        .send(Cmd::Evaluate { job: job_id, data: Arc::clone(&job.test_data) })
        .map_err(died(&job.name))?;
    let (accuracy, eval_stats, eval_s) = match group_workers[0].recv().map_err(died(&job.name))? {
        Reply::EvalDone { accuracy, stats, sim_seconds, .. } => (accuracy, stats, sim_seconds),
        Reply::Error { message, .. } => {
            return Err(ClusterError::Worker(job.name.clone(), boards[0], message))
        }
        other => {
            return Err(ClusterError::Worker(
                job.name.clone(),
                boards[0],
                format!("unexpected reply {other:?}"),
            ))
        }
    };
    times[0] += eval_s;
    stats.add(&eval_stats);
    Metrics::add(&metrics.jobs_completed, 1);

    Ok((
        times,
        JobResult {
            name: job.name.clone(),
            boards: boards.to_vec(),
            accuracy,
            curve,
            stats,
            sim_compute_s: compute_critical + eval_s,
            sim_bus_s: bus_total,
            steps: total_steps,
            weights: cur_w,
            biases: cur_b,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;
    use crate::util::Rng;

    fn mk_job(name: &str, seed: u64, steps: usize) -> Job {
        let fixed = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            name,
            &[4, 16, 3],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let ds = dataset::blobs(192, 3, 4, seed);
        let (train, test) = ds.split(0.75, &mut Rng::new(seed));
        Job {
            name: name.to_string(),
            spec,
            cfg: TrainConfig { batch: 16, lr: 1.0 / 256.0, steps, seed, log_every: 10 },
            train_data: Arc::new(train),
            test_data: Arc::new(test),
            initial: None,
        }
    }

    #[test]
    fn one_to_one_two_jobs_two_boards() {
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let jobs = vec![mk_job("a", 1, 60), mk_job("b", 2, 60)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::OneToOne);
        assert_eq!(r.results.len(), 2);
        for jr in &r.results {
            assert!(jr.accuracy > 0.7, "{} acc {}", jr.name, jr.accuracy);
            assert!(jr.sim_compute_s > 0.0 && jr.sim_bus_s > 0.0);
        }
        assert_eq!(r.metrics.jobs_completed, 2);
        assert!(r.makespan_s > 0.0);
        // both boards did work
        assert!(r.board_time_s.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn sequential_more_jobs_than_boards() {
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let jobs =
            vec![mk_job("a", 1, 25), mk_job("b", 2, 25), mk_job("c", 3, 25), mk_job("d", 4, 25)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Sequential);
        assert_eq!(r.metrics.jobs_completed, 4);
        // a board running two jobs should take about twice one job's time
        let t = &r.board_time_s;
        assert!(t[0] > 0.0 && t[1] > 0.0);
    }

    #[test]
    fn divided_one_job_three_boards_syncs_weights() {
        let cfg =
            ClusterConfig { boards: 3, sync_every: 15, ..Default::default() };
        let jobs = vec![mk_job("dp", 5, 60)];
        let r = execute(&cfg, &jobs).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Divided);
        assert_eq!(r.results[0].boards, vec![0, 1, 2]);
        assert_eq!(r.metrics.sync_rounds, 4); // 60/15
        assert!(r.results[0].accuracy > 0.7, "acc {}", r.results[0].accuracy);
        assert!(r.metrics.bus_bytes > 0);
    }

    #[test]
    fn initial_weights_respected_and_final_weights_reported() {
        // steps = 0 ⇒ the job's explicit initial parameters come back
        // untouched as the final parameters, on both scheduling paths.
        let shape_job = mk_job("shape", 6, 1);
        let w0: Vec<Vec<i16>> = shape_job
            .spec
            .layers
            .iter()
            .map(|l| vec![7i16; l.inputs * l.outputs])
            .collect();
        let b0: Vec<Vec<i16>> =
            shape_job.spec.layers.iter().map(|l| vec![3i16; l.outputs]).collect();
        let mut single = mk_job("single", 6, 0);
        single.initial = Some((w0.clone(), b0.clone()));
        let r = execute(&ClusterConfig { boards: 1, ..Default::default() }, &[single]).unwrap();
        assert_eq!(r.results[0].weights, w0);
        assert_eq!(r.results[0].biases, b0);
        let mut divided = mk_job("divided", 6, 0);
        divided.initial = Some((w0.clone(), b0.clone()));
        let r = execute(&ClusterConfig { boards: 2, ..Default::default() }, &[divided]).unwrap();
        assert_eq!(r.placement.mode, PlacementMode::Divided);
        assert_eq!(r.results[0].weights, w0);
        assert_eq!(r.results[0].biases, b0);
    }

    #[test]
    #[allow(deprecated)]
    fn run_cluster_shim_delegates_to_execute() {
        let cfg = ClusterConfig { boards: 1, ..Default::default() };
        let r = run_cluster(&cfg, &[mk_job("shim", 4, 10)]).unwrap();
        assert_eq!(r.results.len(), 1);
        assert!(matches!(run_cluster(&cfg, &[]), Err(ClusterError::NoJobs)));
    }

    #[test]
    fn infer_on_serves_between_train_chunks() {
        // A board mid-training-session answers inference micro-batches
        // through the same command channel — both workloads on one
        // board, with typed errors instead of hangs.
        let metrics = Metrics::shared();
        let device = FpgaDevice::by_name("XC7S75-2").unwrap();
        let job = mk_job("mix", 11, 6);
        let w = Worker::spawn(0, device, Arc::clone(&metrics), FaultPlan::none());
        w.send(Cmd::NewTrainer { job: 0, spec: job.spec.clone(), cfg: job.cfg.clone() })
            .unwrap();
        expect_ready(&w, "mix", 0).unwrap();
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&job.train_data), steps: 3 })
            .unwrap();
        expect_chunk(&w, "mix", 0).unwrap();
        // serve a 2-row micro-batch (not the training batch size) on the
        // current parameters
        let qx = job.train_data.encode_rows(0..2, job.spec.fixed);
        let (out, stats, sim_s) = infer_on(&w, "mix", 0, 0, 2, qx).unwrap();
        assert_eq!(out.len(), 2 * job.spec.output_dim());
        assert!(stats.cycles > 0 && sim_s > 0.0);
        // training resumes unperturbed on the same board
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&job.train_data), steps: 3 })
            .unwrap();
        expect_chunk(&w, "mix", 0).unwrap();
        assert_eq!(metrics.snapshot().infer_chunks, 1);
        // wrong-size rows surface as a typed worker error, not a hang
        let err = infer_on(&w, "mix", 0, 0, 3, vec![0i16; 5]).unwrap_err();
        assert!(matches!(err, ClusterError::Worker(ref n, 0, _) if n == "mix"), "{err}");
    }

    #[test]
    fn average_weights_elementwise_mean() {
        let a = vec![vec![10i16, -10], vec![4]];
        let b = vec![vec![20i16, -20], vec![8]];
        assert_eq!(average_weights(&[a, b]), vec![vec![15, -15], vec![6]]);
    }

    #[test]
    fn failure_injection_bad_job_does_not_hang_cluster() {
        // Job "bad" has a dataset whose dimensionality mismatches its
        // spec: the worker reports the error and the leader surfaces it
        // instead of deadlocking the other board.
        let mut bad = mk_job("bad", 9, 30);
        bad.train_data = Arc::new(dataset::xor(32, 1)); // dim 2 != 4
        let jobs = vec![mk_job("good", 8, 30), bad];
        let cfg = ClusterConfig { boards: 2, ..Default::default() };
        let t0 = std::time::Instant::now();
        let err = execute(&cfg, &jobs).unwrap_err();
        assert!(matches!(err, ClusterError::Worker(ref name, _, _) if name == "bad"), "{err}");
        assert!(t0.elapsed().as_secs() < 30, "cluster hung on worker failure");
    }

    #[test]
    fn errors_propagate() {
        assert!(matches!(
            execute(&ClusterConfig::default(), &[]),
            Err(ClusterError::NoJobs)
        ));
        let cfg = ClusterConfig { device: "nope".into(), ..Default::default() };
        assert!(matches!(
            execute(&cfg, &[mk_job("a", 1, 5)]),
            Err(ClusterError::UnknownDevice(_))
        ));
    }

    #[test]
    fn injected_worker_death_surfaces_typed_error_without_hanging() {
        // Board 1's worker dies on its very first command; the leader
        // must abort job "b" with WorkerDied while board 0 completes.
        let cfg = ClusterConfig {
            boards: 2,
            faults: FaultPlan::none().kill(1, 0),
            ..Default::default()
        };
        let jobs = vec![mk_job("a", 1, 10), mk_job("b", 2, 10)];
        let t0 = std::time::Instant::now();
        let err = execute(&cfg, &jobs).unwrap_err();
        assert!(
            matches!(err, ClusterError::WorkerDied(ref name, 1) if name == "b"),
            "{err}"
        );
        assert!(t0.elapsed().as_secs() < 30, "leader hung on worker death");
    }

    #[test]
    fn injected_chunk_corruption_is_rejected() {
        // Single-board run: the one TrainChunk reply is corrupted after
        // checksumming; the leader must reject it, not adopt it.
        let cfg = ClusterConfig {
            boards: 1,
            faults: FaultPlan::none().corrupt(0, 0),
            ..Default::default()
        };
        let err = execute(&cfg, &[mk_job("c", 3, 5)]).unwrap_err();
        assert!(
            matches!(err, ClusterError::CorruptChunk(ref name, 0) if name == "c"),
            "{err}"
        );
    }

    #[test]
    fn injected_reorder_surfaces_typed_protocol_error() {
        let cfg = ClusterConfig {
            boards: 1,
            faults: FaultPlan::none().reorder(0, 0),
            ..Default::default()
        };
        let err = execute(&cfg, &[mk_job("r", 4, 5)]).unwrap_err();
        assert!(
            matches!(err, ClusterError::Worker(ref name, 0, ref m)
                if name == "r" && m.contains("unexpected reply")),
            "{err}"
        );
    }

    #[test]
    fn delay_only_faults_leave_results_bit_identical() {
        // Delays exercise wall-clock timing without touching the
        // synchronous protocol: results must match the clean run exactly,
        // on both the divided and the single-board path.
        for boards in [1usize, 2] {
            let clean = ClusterConfig { boards, ..Default::default() };
            let slow = ClusterConfig {
                boards,
                faults: FaultPlan::none().delay(0, 0).delay(0, 1),
                ..Default::default()
            };
            let jobs = vec![mk_job("d", 6, 25)];
            let r1 = execute(&clean, &jobs).unwrap();
            let r2 = execute(&slow, &jobs).unwrap();
            assert_eq!(r1.results[0].weights, r2.results[0].weights, "boards {boards}");
            assert_eq!(r1.results[0].biases, r2.results[0].biases, "boards {boards}");
            assert_eq!(r1.results[0].accuracy, r2.results[0].accuracy, "boards {boards}");
            assert!(r2.metrics.faults_injected > 0, "delays did not fire");
        }
    }
}
