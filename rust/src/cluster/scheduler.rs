//! Job → board placement, implementing §2's three cases verbatim.

/// How the schedule was derived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// M = F: "maps 1 MLP to 1 FPGA".
    OneToOne,
    /// M > F: "the MLPs are processed sequentially" (per-board queues).
    Sequential,
    /// M < F: "the MLPs are divided and are processed in parallel"
    /// (board groups per MLP, data-parallel with weight averaging).
    Divided,
}

/// A placement: per job, the boards assigned to it, plus the execution
/// order on shared boards.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Mode chosen from the M/F relation.
    pub mode: PlacementMode,
    /// `groups[j]` = boards assigned to job `j`.
    pub groups: Vec<Vec<usize>>,
    /// `queues[b]` = jobs queued on board `b`, in order.
    pub queues: Vec<Vec<usize>>,
}

/// Compute the placement of `jobs` jobs onto `boards` boards.
pub fn schedule(jobs: usize, boards: usize) -> Placement {
    assert!(jobs > 0, "no jobs");
    assert!(boards > 0, "no boards");
    let mut groups = vec![Vec::new(); jobs];
    let mut queues = vec![Vec::new(); boards];
    let mode = if jobs == boards {
        for j in 0..jobs {
            groups[j].push(j);
            queues[j].push(j);
        }
        PlacementMode::OneToOne
    } else if jobs > boards {
        // Round-robin queues: board b runs jobs b, b+F, b+2F... in order.
        for j in 0..jobs {
            let b = j % boards;
            groups[j].push(b);
            queues[b].push(j);
        }
        PlacementMode::Sequential
    } else {
        // Divide boards among jobs: first (boards % jobs) jobs get one
        // extra board.
        let base = boards / jobs;
        let extra = boards % jobs;
        let mut next = 0usize;
        for (j, group) in groups.iter_mut().enumerate() {
            let take = base + usize::from(j < extra);
            for _ in 0..take {
                group.push(next);
                queues[next].push(j);
                next += 1;
            }
        }
        PlacementMode::Divided
    };
    Placement { mode, groups, queues }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Gen};

    #[test]
    fn one_to_one() {
        let p = schedule(4, 4);
        assert_eq!(p.mode, PlacementMode::OneToOne);
        for j in 0..4 {
            assert_eq!(p.groups[j], vec![j]);
            assert_eq!(p.queues[j], vec![j]);
        }
    }

    #[test]
    fn sequential_round_robin() {
        let p = schedule(7, 3);
        assert_eq!(p.mode, PlacementMode::Sequential);
        assert_eq!(p.queues[0], vec![0, 3, 6]);
        assert_eq!(p.queues[1], vec![1, 4]);
        assert_eq!(p.queues[2], vec![2, 5]);
        assert!(p.groups.iter().all(|g| g.len() == 1));
    }

    #[test]
    fn divided_spreads_boards() {
        let p = schedule(2, 5);
        assert_eq!(p.mode, PlacementMode::Divided);
        assert_eq!(p.groups[0], vec![0, 1, 2]); // first job gets the extra
        assert_eq!(p.groups[1], vec![3, 4]);
        // every board runs exactly one job
        assert!(p.queues.iter().all(|q| q.len() == 1));
    }

    #[test]
    fn full_mf_grid_schedules_correctly() {
        // Exhaustive properties over the 1..=8 × 1..=8 M×F grid: the mode
        // matches the M/F relation, every job gets ≥ 1 board, and the
        // queues partition the jobs consistently with the groups.
        for jobs in 1..=8usize {
            for boards in 1..=8usize {
                let p = schedule(jobs, boards);
                let want = if jobs == boards {
                    PlacementMode::OneToOne
                } else if jobs > boards {
                    PlacementMode::Sequential
                } else {
                    PlacementMode::Divided
                };
                assert_eq!(p.mode, want, "M={jobs} F={boards}");
                assert_eq!(p.groups.len(), jobs);
                assert_eq!(p.queues.len(), boards);
                // every job gets at least one board
                assert!(
                    p.groups.iter().all(|g| !g.is_empty()),
                    "M={jobs} F={boards}: job without a board"
                );
                // queues partition the jobs: each job appears in exactly
                // the queues of its group's boards, once per board
                let mut seen = vec![0usize; jobs];
                for (b, q) in p.queues.iter().enumerate() {
                    for &j in q {
                        seen[j] += 1;
                        assert!(
                            p.groups[j].contains(&b),
                            "M={jobs} F={boards}: queue {b} lists job {j} outside its group"
                        );
                    }
                }
                for (j, &n) in seen.iter().enumerate() {
                    assert_eq!(
                        n,
                        p.groups[j].len(),
                        "M={jobs} F={boards}: job {j} queued {n}× for {} board(s)",
                        p.groups[j].len()
                    );
                }
                if jobs <= boards {
                    // no board is double-booked, and groups cover all
                    // boards disjointly
                    assert!(p.queues.iter().all(|q| q.len() == 1));
                    let total: usize = p.groups.iter().map(Vec::len).sum();
                    assert_eq!(total, boards, "M={jobs} F={boards}: boards not covered");
                }
            }
        }
    }

    #[test]
    fn divided_remainder_distribution_is_balanced() {
        // When boards % jobs != 0 the remainder boards must spread one
        // per job from the front: no job sits at `base` boards while
        // another holds `base + 2` (i.e. one idle board's worth of
        // chunks piled two deep on a neighbour).
        for jobs in 1..=8usize {
            for boards in jobs..=24usize {
                let p = schedule(jobs, boards);
                let sizes: Vec<usize> = p.groups.iter().map(Vec::len).collect();
                let max = *sizes.iter().max().unwrap();
                let min = *sizes.iter().min().unwrap();
                assert!(
                    max - min <= 1,
                    "M={jobs} F={boards}: group sizes {sizes:?} differ by more than 1"
                );
                if boards % jobs != 0 {
                    // exactly (boards % jobs) jobs carry the extra board,
                    // and they are the lowest-indexed ones
                    let extras: Vec<usize> = sizes
                        .iter()
                        .enumerate()
                        .filter(|&(_, &s)| s == max)
                        .map(|(j, _)| j)
                        .collect();
                    assert_eq!(extras.len(), boards % jobs, "M={jobs} F={boards}");
                    assert_eq!(extras, (0..boards % jobs).collect::<Vec<_>>());
                }
            }
        }
    }

    #[test]
    fn divided_chunk_index_order_is_a_total_order() {
        // The leader accumulates a divided job's chunks in
        // (job, replica-slot) order; that enumeration must be a strict
        // total order over distinct boards with no repeats or gaps —
        // what makes the recovery path's "accumulate in chunk-index
        // order" rule well-defined.
        for jobs in 1..=6usize {
            for boards in jobs..=18usize {
                let p = schedule(jobs, boards);
                let mut seen = vec![false; boards];
                let mut chunk_index = Vec::new();
                for (j, group) in p.groups.iter().enumerate() {
                    for (slot, &b) in group.iter().enumerate() {
                        assert!(!seen[b], "M={jobs} F={boards}: board {b} assigned twice");
                        seen[b] = true;
                        chunk_index.push((j, slot));
                    }
                }
                assert!(seen.iter().all(|&s| s), "M={jobs} F={boards}: idle board");
                // strictly increasing lexicographic (job, slot) order
                assert!(
                    chunk_index.windows(2).all(|w| w[0] < w[1]),
                    "M={jobs} F={boards}: chunk order {chunk_index:?} not total"
                );
            }
        }
    }

    #[test]
    fn placement_invariants_hold_for_all_shapes() {
        // Property: every job appears in ≥1 group; every board queue entry
        // is consistent with groups; no board is double-booked in Divided
        // mode; all boards used when M ≤ F.
        check(
            "placement_invariants",
            Gen::pair(Gen::int_range(1, 24), Gen::int_range(1, 24)),
            |&(jobs, boards)| {
                let (jobs, boards) = (jobs as usize, boards as usize);
                let p = schedule(jobs, boards);
                let groups_ok = p.groups.iter().all(|g| !g.is_empty())
                    && p.groups.len() == jobs
                    && p.queues.len() == boards;
                let consistent = p.queues.iter().enumerate().all(|(b, q)| {
                    q.iter().all(|&j| p.groups[j].contains(&b))
                });
                let all_used = jobs >= boards
                    || p.queues.iter().all(|q| q.len() == 1);
                groups_ok && consistent && all_used
            },
        );
    }
}
