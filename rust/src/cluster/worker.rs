//! Per-board worker threads.
//!
//! One OS thread per simulated FPGA board. Commands arrive on a **bounded**
//! channel (`sync_channel(1)`) — a busy board exerts backpressure on the
//! leader exactly like a full board-side command queue would. Each worker
//! owns the [`Trainer`]s of the jobs placed on its board.
//!
//! Since the perf pass every trainer's machines run on compiled
//! [`crate::hw::ExecPlan`]s: the per-job train/forward programs are
//! compiled once at `Cmd::NewTrainer` time, and every `TrainChunk` /
//! `Evaluate` step executes the arena-backed plan (fused waves, pooled
//! lanes) instead of re-interpreting the program, so cluster training
//! inherits the single-board speedup without protocol changes.
//!
//! Fault injection (testkit): the worker honours the run's
//! [`FaultPlan`] — seeded death (exit without replying), delayed and
//! reordered chunk replies, and post-checksum parameter corruption. Every
//! chunk reply carries a [`super::bus::params_checksum`] integrity word
//! so the leader can reject corrupted parameters instead of averaging
//! them in.
//!
//! Sync policies (DESIGN.md §Cluster): `Cmd::SetWeights` stays the one
//! transport-level primitive for weight sync regardless of the run's
//! [`super::cost::SyncPolicy`] — the leader computes the average (star
//! gather or simulated ring all-reduce, bit-identical by construction)
//! and broadcasts it here, while the *modelled* bus traffic of the
//! chosen collective is charged by [`super::cost`], not by counting
//! these commands.

use super::bus::params_checksum;
use super::fault::FaultPlan;
use super::metrics::Metrics;
use crate::hw::{FpgaDevice, RunStats};
use crate::nn::dataset::Dataset;
use crate::nn::trainer::{LossPoint, TrainConfig, Trainer};
use crate::nn::MlpSpec;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Commands the leader sends to a board.
pub enum Cmd {
    /// Create a trainer for a job (weights initialised from `seed`).
    NewTrainer {
        /// Job index.
        job: usize,
        /// Network spec.
        spec: MlpSpec,
        /// Training configuration (seed included).
        cfg: TrainConfig,
    },
    /// Overwrite a job's on-device weights (weight-sync).
    SetWeights {
        /// Job index.
        job: usize,
        /// Per-layer weights.
        w: Vec<Vec<i16>>,
        /// Per-layer biases.
        b: Vec<Vec<i16>>,
    },
    /// Train `steps` mini-batch steps on `data`.
    TrainChunk {
        /// Job index.
        job: usize,
        /// Training data.
        data: Arc<Dataset>,
        /// Steps to run.
        steps: usize,
    },
    /// Evaluate accuracy on `data`.
    Evaluate {
        /// Job index.
        job: usize,
        /// Test data.
        data: Arc<Dataset>,
    },
    /// Serve one inference micro-batch on the job's current parameters —
    /// the serving workload kind, accepted alongside training so the
    /// same board serves both. `rows` may be any size `1..=512`; the
    /// worker rounds it up to the power-of-two forward bucket
    /// (zero-padded, same ladder policy as the serving runtime) and
    /// runs it through [`Trainer::infer_rows`] without touching
    /// training state.
    InferChunk {
        /// Job index.
        job: usize,
        /// Rows in the micro-batch.
        rows: usize,
        /// Quantised `rows × input_dim` input.
        qx: Vec<i16>,
    },
    /// Re-read a job's current on-device parameters (the recovery
    /// retry path for a checksum-failed chunk reply: the board's state
    /// is fine, the corruption was in transit — see
    /// [`super::recovery::RecoveryPolicy::max_chunk_retries`]).
    ReadParams {
        /// Job index.
        job: usize,
    },
    /// Fast-forward a job's batch sampler past `steps` already-trained
    /// steps without running compute ([`Trainer::skip_steps`]) — how a
    /// rescheduled replica or a checkpoint resume lands on the exact
    /// sample stream of the uninterrupted run.
    SkipSamples {
        /// Job index.
        job: usize,
        /// Steps to skip (each consumes `cfg.batch` sampler draws).
        steps: usize,
    },
    /// Terminate the worker.
    Shutdown,
}

/// Worker → leader replies.
#[derive(Debug)]
pub enum Reply {
    /// Trainer created.
    Ready {
        /// Job index.
        job: usize,
    },
    /// A chunk finished.
    ChunkDone {
        /// Job index.
        job: usize,
        /// Loss curve of the chunk.
        curve: Vec<LossPoint>,
        /// Machine stats of the chunk.
        stats: RunStats,
        /// Simulated seconds of the chunk.
        sim_seconds: f64,
        /// Current weights (for averaging).
        w: Vec<Vec<i16>>,
        /// Current biases.
        b: Vec<Vec<i16>>,
        /// [`params_checksum`] of `(w, b)` as the board computed them —
        /// the leader re-derives it to reject in-transit corruption.
        checksum: u64,
    },
    /// An evaluation finished.
    EvalDone {
        /// Job index.
        job: usize,
        /// Accuracy in [0,1].
        accuracy: f64,
        /// Machine stats.
        stats: RunStats,
        /// Simulated seconds.
        sim_seconds: f64,
    },
    /// A parameter re-read finished (`Cmd::ReadParams`).
    Params {
        /// Job index.
        job: usize,
        /// Current per-layer weights.
        w: Vec<Vec<i16>>,
        /// Current per-layer biases.
        b: Vec<Vec<i16>>,
        /// [`params_checksum`] of `(w, b)` as the board computed them.
        checksum: u64,
    },
    /// An inference micro-batch finished.
    InferDone {
        /// Job index.
        job: usize,
        /// Quantised `rows × output_dim` outputs.
        out: Vec<i16>,
        /// Machine stats of the pass.
        stats: RunStats,
        /// Simulated seconds.
        sim_seconds: f64,
    },
    /// Something failed.
    Error {
        /// Job index.
        job: usize,
        /// Message.
        message: String,
    },
}

/// A worker whose thread is gone: a channel to it is closed because the
/// thread exited (injected death, shutdown, or panic). The leader maps
/// this into [`super::leader::ClusterError::WorkerDied`] — the typed
/// surface of the "leader never hangs" contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerGone {
    /// Board whose worker vanished.
    pub board: usize,
}

/// Handle to a running worker.
pub struct Worker {
    /// Board index.
    pub board: usize,
    cmd_tx: SyncSender<Cmd>,
    reply_rx: Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn a worker for `board` simulating `device`, honouring the
    /// run's fault plan.
    pub fn spawn(
        board: usize,
        device: FpgaDevice,
        metrics: Arc<Metrics>,
        faults: FaultPlan,
    ) -> Worker {
        // Bounded depth 1: leader blocks while the board is busy.
        let (cmd_tx, cmd_rx) = sync_channel::<Cmd>(1);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();
        let handle = std::thread::Builder::new()
            .name(format!("fpga-worker-{board}"))
            .spawn(move || worker_main(board, device, cmd_rx, reply_tx, metrics, faults))
            .expect("spawn worker thread");
        Worker { board, cmd_tx, reply_rx, handle: Some(handle) }
    }

    /// Send a command (blocks when the board's queue is full —
    /// backpressure). `Err` when the worker thread is gone.
    pub fn send(&self, cmd: Cmd) -> Result<(), WorkerGone> {
        self.cmd_tx.send(cmd).map_err(|_| WorkerGone { board: self.board })
    }

    /// Wait for the next reply. `Err` when the worker thread died
    /// without replying.
    pub fn recv(&self) -> Result<Reply, WorkerGone> {
        self.reply_rx.recv().map_err(|_| WorkerGone { board: self.board })
    }

    /// Explicit teardown: send `Shutdown` down the command channel and
    /// **join** the worker thread before returning. The leader calls
    /// this on every exit path — abort, eviction, and normal completion
    /// — so no `fpga-worker-*` thread outlives
    /// [`super::leader::execute`] (asserted by
    /// `tests/recovery.rs::no_worker_threads_survive_execute`). `Drop`
    /// performs the same teardown as a safety net.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        let _ = self.cmd_tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.close();
    }
}

fn worker_main(
    board: usize,
    device: FpgaDevice,
    cmd_rx: Receiver<Cmd>,
    reply_tx: Sender<Reply>,
    metrics: Arc<Metrics>,
    faults: FaultPlan,
) {
    let mut trainers: HashMap<usize, Trainer> = HashMap::new();
    // Deterministic fault addressing: cmd_idx counts received commands,
    // chunk_idx counts successful ChunkDone replies.
    let mut cmd_idx = 0usize;
    let mut chunk_idx = 0usize;
    while let Ok(cmd) = cmd_rx.recv() {
        if faults.dies_at(board, cmd_idx) {
            // Injected worker death: exit without replying. The dropped
            // reply channel surfaces at the leader as WorkerDied.
            metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        cmd_idx += 1;
        match cmd {
            Cmd::Shutdown => break,
            Cmd::NewTrainer { job, spec, cfg } => {
                match Trainer::build(spec, device, cfg) {
                    Ok(t) => {
                        trainers.insert(job, t);
                        let _ = reply_tx.send(Reply::Ready { job });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
            Cmd::SetWeights { job, w, b } => {
                if let Some(t) = trainers.get_mut(&job) {
                    if let Err(e) = t.set_weights(&w, &b) {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                        continue;
                    }
                }
                let _ = reply_tx.send(Reply::Ready { job });
            }
            Cmd::TrainChunk { job, data, steps } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                let saved_steps = t.cfg.steps;
                t.cfg.steps = steps;
                let res = t.train(&data);
                t.cfg.steps = saved_steps;
                match res {
                    Ok(report) => {
                        metrics.steps_total.fetch_add(steps as u64, Ordering::Relaxed);
                        metrics.sim_cycles.fetch_add(report.stats.cycles, Ordering::Relaxed);
                        let (mut w, b) = t.weights();
                        // Checksum what the board actually holds, then
                        // apply any injected in-transit corruption.
                        let checksum = params_checksum(&w, &b);
                        if faults.corrupts_chunk(board, chunk_idx) {
                            metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
                            if let Some(lane) =
                                w.iter_mut().find_map(|layer| layer.first_mut())
                            {
                                *lane ^= 0x0400;
                            }
                        }
                        if faults.delays_chunk(board, chunk_idx) {
                            metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        if faults.reorders_chunk(board, chunk_idx) {
                            metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
                            let _ = reply_tx.send(Reply::Ready { job });
                        }
                        chunk_idx += 1;
                        let _ = reply_tx.send(Reply::ChunkDone {
                            job,
                            curve: report.curve,
                            stats: report.stats,
                            sim_seconds: report.sim_seconds,
                            w,
                            b,
                            checksum,
                        });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
            Cmd::ReadParams { job } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                let (mut w, b) = t.weights();
                // Same in-transit fault surface as a chunk reply: the
                // retry path must be corruptible too, so persistent
                // corruption (consecutive sites) is expressible.
                let checksum = params_checksum(&w, &b);
                if faults.corrupts_chunk(board, chunk_idx) {
                    metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
                    if let Some(lane) = w.iter_mut().find_map(|layer| layer.first_mut()) {
                        *lane ^= 0x0400;
                    }
                }
                if faults.delays_chunk(board, chunk_idx) {
                    metrics.faults_injected.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                chunk_idx += 1;
                let _ = reply_tx.send(Reply::Params { job, w, b, checksum });
            }
            Cmd::SkipSamples { job, steps } => {
                if let Some(t) = trainers.get_mut(&job) {
                    t.skip_steps(steps);
                    let _ = reply_tx.send(Reply::Ready { job });
                } else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                }
            }
            Cmd::Evaluate { job, data } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                match t.evaluate(&data) {
                    Ok((accuracy, stats)) => {
                        metrics.sim_cycles.fetch_add(stats.cycles, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::EvalDone {
                            job,
                            accuracy,
                            stats,
                            sim_seconds: stats.seconds(&t.device),
                        });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
            Cmd::InferChunk { job, rows, mut qx } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                let in_dim = t.spec.input_dim();
                if rows == 0 || qx.len() != rows * in_dim {
                    let _ = reply_tx.send(Reply::Error {
                        job,
                        message: format!(
                            "inference batch has {} lanes, expected {rows} × {in_dim}",
                            qx.len()
                        ),
                    });
                    continue;
                }
                // Round up to the power-of-two forward bucket and
                // zero-pad, mirroring the serving runtime's ladder: at
                // most log2(COLUMN_LEN) lazily-compiled variants per
                // trainer instead of one per observed micro-batch size.
                // Forward lanes are per-row, so padding never perturbs
                // real rows.
                let bucket = rows.next_power_of_two();
                qx.resize(bucket * in_dim, 0);
                match t.infer_rows(bucket, &qx) {
                    Ok((mut out, stats)) => {
                        out.truncate(rows * t.spec.output_dim());
                        metrics.infer_chunks.fetch_add(1, Ordering::Relaxed);
                        metrics.sim_cycles.fetch_add(stats.cycles, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::InferDone {
                            job,
                            out,
                            stats,
                            sim_seconds: stats.seconds(&t.device),
                        });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;

    fn spec() -> MlpSpec {
        let fixed = FixedSpec::q(10).saturating();
        MlpSpec::from_dims(
            "w",
            &[2, 8, 2],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    #[test]
    fn worker_lifecycle() {
        let m = Metrics::shared();
        let w = Worker::spawn(0, FpgaDevice::selected(), Arc::clone(&m), FaultPlan::none());
        let cfg = TrainConfig { batch: 8, steps: 5, lr: 1.0 / 256.0, seed: 1, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        let ds = Arc::new(dataset::xor(64, 2));
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&ds), steps: 5 }).unwrap();
        match w.recv().unwrap() {
            Reply::ChunkDone { job, sim_seconds, w: wts, b: bts, checksum, .. } => {
                assert_eq!(job, 0);
                assert!(sim_seconds > 0.0);
                assert_eq!(wts.len(), 2);
                assert_eq!(checksum, params_checksum(&wts, &bts));
            }
            other => panic!("unexpected {other:?}"),
        }
        w.send(Cmd::Evaluate { job: 0, data: ds }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::EvalDone { job: 0, .. })));
        assert_eq!(m.snapshot().steps_total, 5);
        drop(w); // clean shutdown
    }

    #[test]
    fn infer_chunks_serve_alongside_training_without_perturbing_it() {
        use crate::nn::trainer::Trainer;
        let m = Metrics::shared();
        let device = FpgaDevice::selected();
        let w = Worker::spawn(0, device, Arc::clone(&m), FaultPlan::none());
        let cfg = TrainConfig { batch: 8, steps: 3, lr: 1.0 / 256.0, seed: 5, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg: cfg.clone() }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        let ds = Arc::new(dataset::xor(64, 3));
        let fixed = spec().fixed;
        // train → serve → train on the same board
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&ds), steps: 3 }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::ChunkDone { .. })));
        let qx = ds.encode_rows(0..3, fixed);
        w.send(Cmd::InferChunk { job: 0, rows: 3, qx: qx.clone() }).unwrap();
        let served = match w.recv().unwrap() {
            Reply::InferDone { job, out, stats, sim_seconds } => {
                assert_eq!(job, 0);
                assert_eq!(out.len(), 3 * 2);
                assert!(stats.cycles > 0 && sim_seconds > 0.0);
                out
            }
            other => panic!("unexpected {other:?}"),
        };
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&ds), steps: 3 }).unwrap();
        let final_w = match w.recv().unwrap() {
            Reply::ChunkDone { w, .. } => w,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(m.snapshot().infer_chunks, 1);
        // reference: the identical training run with no serve interleave
        // — inference must not perturb training state (weights or RNG)
        let mut reference = Trainer::build(spec(), device, cfg).unwrap();
        reference.train(&ds).unwrap();
        let (ref_out, _) = reference.infer_rows(3, &qx).unwrap();
        assert_eq!(served, ref_out, "served outputs diverge from the engine");
        reference.train(&ds).unwrap();
        assert_eq!(final_w, reference.weights().0, "serving perturbed training");
    }

    #[test]
    fn infer_chunk_for_unknown_job_errors() {
        let m = Metrics::shared();
        let w = Worker::spawn(2, FpgaDevice::selected(), m, FaultPlan::none());
        w.send(Cmd::InferChunk { job: 4, rows: 1, qx: vec![0, 0] }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Error { job: 4, .. })));
    }

    #[test]
    fn unknown_job_errors() {
        let m = Metrics::shared();
        let w = Worker::spawn(1, FpgaDevice::selected(), m, FaultPlan::none());
        w.send(Cmd::TrainChunk { job: 9, data: Arc::new(dataset::xor(8, 1)), steps: 1 })
            .unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Error { job: 9, .. })));
    }

    #[test]
    fn read_params_returns_the_boards_current_state() {
        let m = Metrics::shared();
        let w = Worker::spawn(0, FpgaDevice::selected(), Arc::clone(&m), FaultPlan::none());
        let cfg = TrainConfig { batch: 8, steps: 2, lr: 1.0 / 256.0, seed: 4, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        let ds = Arc::new(dataset::xor(32, 5));
        w.send(Cmd::TrainChunk { job: 0, data: ds, steps: 2 }).unwrap();
        let (cw, cb) = match w.recv().unwrap() {
            Reply::ChunkDone { w, b, .. } => (w, b),
            other => panic!("unexpected {other:?}"),
        };
        w.send(Cmd::ReadParams { job: 0 }).unwrap();
        match w.recv().unwrap() {
            Reply::Params { job, w: pw, b: pb, checksum } => {
                assert_eq!(job, 0);
                assert_eq!((pw.clone(), pb.clone()), (cw, cb));
                assert_eq!(checksum, params_checksum(&pw, &pb));
            }
            other => panic!("unexpected {other:?}"),
        }
        // unknown job is a typed error, not a hang
        w.send(Cmd::ReadParams { job: 7 }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Error { job: 7, .. })));
    }

    #[test]
    fn read_params_retry_escapes_a_single_corruption_site() {
        // Corrupt chunk reply 0; the retry (chunk index 1) is clean —
        // exactly the in-transit corruption the recovery retry fixes.
        let m = Metrics::shared();
        let plan = FaultPlan::none().corrupt(0, 0);
        let w = Worker::spawn(0, FpgaDevice::selected(), Arc::clone(&m), plan);
        let cfg = TrainConfig { batch: 8, steps: 1, lr: 1.0 / 256.0, seed: 1, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        w.send(Cmd::TrainChunk { job: 0, data: Arc::new(dataset::xor(32, 2)), steps: 1 })
            .unwrap();
        match w.recv().unwrap() {
            Reply::ChunkDone { w: cw, b: cb, checksum, .. } => {
                assert_ne!(checksum, params_checksum(&cw, &cb), "corruption not applied");
            }
            other => panic!("unexpected {other:?}"),
        }
        w.send(Cmd::ReadParams { job: 0 }).unwrap();
        match w.recv().unwrap() {
            Reply::Params { w: pw, b: pb, checksum, .. } => {
                assert_eq!(checksum, params_checksum(&pw, &pb), "retry also corrupt");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn skip_samples_matches_trained_stream() {
        use crate::nn::trainer::Trainer;
        // A worker trainer that skips k steps then trains the tail must
        // land on the same weights as one that trained straight through.
        let m = Metrics::shared();
        let device = FpgaDevice::selected();
        let cfg = TrainConfig { batch: 8, steps: 6, lr: 1.0 / 128.0, seed: 9, log_every: 2 };
        let ds = Arc::new(dataset::xor(64, 6));
        let mut straight = Trainer::build(spec(), device, cfg.clone()).unwrap();
        straight.train(&ds).unwrap();
        let mut head = Trainer::build(spec(), device, cfg.clone()).unwrap();
        head.cfg.steps = 2;
        head.train(&ds).unwrap();
        let (w2, b2) = head.weights();

        let w = Worker::spawn(0, device, Arc::clone(&m), FaultPlan::none());
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        w.send(Cmd::SetWeights { job: 0, w: w2, b: b2 }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        w.send(Cmd::SkipSamples { job: 0, steps: 2 }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        w.send(Cmd::TrainChunk { job: 0, data: ds, steps: 4 }).unwrap();
        let tail_w = match w.recv().unwrap() {
            Reply::ChunkDone { w, .. } => w,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(tail_w, straight.weights().0, "skip+tail diverged from straight run");
    }

    #[test]
    fn injected_death_closes_the_reply_channel() {
        let m = Metrics::shared();
        let plan = FaultPlan::none().kill(3, 0);
        let w = Worker::spawn(3, FpgaDevice::selected(), Arc::clone(&m), plan);
        let cfg = TrainConfig { batch: 8, steps: 1, lr: 1.0 / 256.0, seed: 1, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Err(WorkerGone { board: 3 })));
        assert_eq!(m.snapshot().faults_injected, 1);
    }

    #[test]
    fn corrupted_chunk_fails_its_own_checksum() {
        let m = Metrics::shared();
        let plan = FaultPlan::none().corrupt(0, 0);
        let w = Worker::spawn(0, FpgaDevice::selected(), Arc::clone(&m), plan);
        let cfg = TrainConfig { batch: 8, steps: 1, lr: 1.0 / 256.0, seed: 1, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg }).unwrap();
        assert!(matches!(w.recv(), Ok(Reply::Ready { job: 0 })));
        let ds = Arc::new(dataset::xor(32, 2));
        w.send(Cmd::TrainChunk { job: 0, data: ds, steps: 1 }).unwrap();
        match w.recv().unwrap() {
            Reply::ChunkDone { w: wts, b: bts, checksum, .. } => {
                assert_ne!(checksum, params_checksum(&wts, &bts), "corruption not applied");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(m.snapshot().faults_injected, 1);
    }
}
