//! Per-board worker threads.
//!
//! One OS thread per simulated FPGA board. Commands arrive on a **bounded**
//! channel (`sync_channel(1)`) — a busy board exerts backpressure on the
//! leader exactly like a full board-side command queue would. Each worker
//! owns the [`Trainer`]s of the jobs placed on its board.
//!
//! Since the perf pass every trainer's machines run on compiled
//! [`crate::hw::ExecPlan`]s: the per-job train/forward programs are
//! compiled once at `Cmd::NewTrainer` time, and every `TrainChunk` /
//! `Evaluate` step executes the arena-backed plan (fused waves, pooled
//! lanes) instead of re-interpreting the program, so cluster training
//! inherits the single-board speedup without protocol changes.

use super::metrics::Metrics;
use crate::hw::{FpgaDevice, RunStats};
use crate::nn::dataset::Dataset;
use crate::nn::trainer::{LossPoint, TrainConfig, Trainer};
use crate::nn::MlpSpec;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Commands the leader sends to a board.
pub enum Cmd {
    /// Create a trainer for a job (weights initialised from `seed`).
    NewTrainer {
        /// Job index.
        job: usize,
        /// Network spec.
        spec: MlpSpec,
        /// Training configuration (seed included).
        cfg: TrainConfig,
    },
    /// Overwrite a job's on-device weights (weight-sync).
    SetWeights {
        /// Job index.
        job: usize,
        /// Per-layer weights.
        w: Vec<Vec<i16>>,
        /// Per-layer biases.
        b: Vec<Vec<i16>>,
    },
    /// Train `steps` mini-batch steps on `data`.
    TrainChunk {
        /// Job index.
        job: usize,
        /// Training data.
        data: Arc<Dataset>,
        /// Steps to run.
        steps: usize,
    },
    /// Evaluate accuracy on `data`.
    Evaluate {
        /// Job index.
        job: usize,
        /// Test data.
        data: Arc<Dataset>,
    },
    /// Terminate the worker.
    Shutdown,
}

/// Worker → leader replies.
#[derive(Debug)]
pub enum Reply {
    /// Trainer created.
    Ready {
        /// Job index.
        job: usize,
    },
    /// A chunk finished.
    ChunkDone {
        /// Job index.
        job: usize,
        /// Loss curve of the chunk.
        curve: Vec<LossPoint>,
        /// Machine stats of the chunk.
        stats: RunStats,
        /// Simulated seconds of the chunk.
        sim_seconds: f64,
        /// Current weights (for averaging).
        w: Vec<Vec<i16>>,
        /// Current biases.
        b: Vec<Vec<i16>>,
    },
    /// An evaluation finished.
    EvalDone {
        /// Job index.
        job: usize,
        /// Accuracy in [0,1].
        accuracy: f64,
        /// Machine stats.
        stats: RunStats,
        /// Simulated seconds.
        sim_seconds: f64,
    },
    /// Something failed.
    Error {
        /// Job index.
        job: usize,
        /// Message.
        message: String,
    },
}

/// Handle to a running worker.
pub struct Worker {
    /// Board index.
    pub board: usize,
    cmd_tx: SyncSender<Cmd>,
    reply_rx: Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn a worker for `board` simulating `device`.
    pub fn spawn(board: usize, device: FpgaDevice, metrics: Arc<Metrics>) -> Worker {
        // Bounded depth 1: leader blocks while the board is busy.
        let (cmd_tx, cmd_rx) = sync_channel::<Cmd>(1);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();
        let handle = std::thread::Builder::new()
            .name(format!("fpga-worker-{board}"))
            .spawn(move || worker_main(device, cmd_rx, reply_tx, metrics))
            .expect("spawn worker thread");
        Worker { board, cmd_tx, reply_rx, handle: Some(handle) }
    }

    /// Send a command (blocks when the board's queue is full —
    /// backpressure).
    pub fn send(&self, cmd: Cmd) {
        self.cmd_tx.send(cmd).expect("worker hung up");
    }

    /// Wait for the next reply.
    pub fn recv(&self) -> Reply {
        self.reply_rx.recv().expect("worker hung up")
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.cmd_tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker_main(
    device: FpgaDevice,
    cmd_rx: Receiver<Cmd>,
    reply_tx: Sender<Reply>,
    metrics: Arc<Metrics>,
) {
    let mut trainers: HashMap<usize, Trainer> = HashMap::new();
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            Cmd::Shutdown => break,
            Cmd::NewTrainer { job, spec, cfg } => {
                match Trainer::build(spec, device, cfg) {
                    Ok(t) => {
                        trainers.insert(job, t);
                        let _ = reply_tx.send(Reply::Ready { job });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
            Cmd::SetWeights { job, w, b } => {
                if let Some(t) = trainers.get_mut(&job) {
                    if let Err(e) = t.set_weights(&w, &b) {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                        continue;
                    }
                }
                let _ = reply_tx.send(Reply::Ready { job });
            }
            Cmd::TrainChunk { job, data, steps } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                let saved_steps = t.cfg.steps;
                t.cfg.steps = steps;
                let res = t.train(&data);
                t.cfg.steps = saved_steps;
                match res {
                    Ok(report) => {
                        metrics.steps_total.fetch_add(steps as u64, Ordering::Relaxed);
                        metrics.sim_cycles.fetch_add(report.stats.cycles, Ordering::Relaxed);
                        let (w, b) = t.weights();
                        let _ = reply_tx.send(Reply::ChunkDone {
                            job,
                            curve: report.curve,
                            stats: report.stats,
                            sim_seconds: report.sim_seconds,
                            w,
                            b,
                        });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
            Cmd::Evaluate { job, data } => {
                let Some(t) = trainers.get_mut(&job) else {
                    let _ = reply_tx
                        .send(Reply::Error { job, message: "no trainer for job".into() });
                    continue;
                };
                match t.evaluate(&data) {
                    Ok((accuracy, stats)) => {
                        metrics.sim_cycles.fetch_add(stats.cycles, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::EvalDone {
                            job,
                            accuracy,
                            stats,
                            sim_seconds: stats.seconds(&t.device),
                        });
                    }
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = reply_tx.send(Reply::Error { job, message: e.to_string() });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::dataset;
    use crate::nn::lut::ActKind;
    use crate::nn::mlp::LutParams;

    fn spec() -> MlpSpec {
        let fixed = FixedSpec::q(10).saturating();
        MlpSpec::from_dims(
            "w",
            &[2, 8, 2],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap()
    }

    #[test]
    fn worker_lifecycle() {
        let m = Metrics::shared();
        let w = Worker::spawn(0, FpgaDevice::selected(), Arc::clone(&m));
        let cfg = TrainConfig { batch: 8, steps: 5, lr: 1.0 / 256.0, seed: 1, log_every: 1 };
        w.send(Cmd::NewTrainer { job: 0, spec: spec(), cfg });
        assert!(matches!(w.recv(), Reply::Ready { job: 0 }));
        let ds = Arc::new(dataset::xor(64, 2));
        w.send(Cmd::TrainChunk { job: 0, data: Arc::clone(&ds), steps: 5 });
        match w.recv() {
            Reply::ChunkDone { job, sim_seconds, w: wts, .. } => {
                assert_eq!(job, 0);
                assert!(sim_seconds > 0.0);
                assert_eq!(wts.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        w.send(Cmd::Evaluate { job: 0, data: ds });
        assert!(matches!(w.recv(), Reply::EvalDone { job: 0, .. }));
        assert_eq!(m.snapshot().steps_total, 5);
        drop(w); // clean shutdown
    }

    #[test]
    fn unknown_job_errors() {
        let m = Metrics::shared();
        let w = Worker::spawn(1, FpgaDevice::selected(), m);
        w.send(Cmd::TrainChunk { job: 9, data: Arc::new(dataset::xor(8, 1)), steps: 1 });
        assert!(matches!(w.recv(), Reply::Error { job: 9, .. }));
    }
}
