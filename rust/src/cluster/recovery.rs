//! Recovery policy for the cluster runtime — what the leader does when a
//! board fails *instead of* aborting the whole job.
//!
//! PR 3's fault hooks proved the leader never hangs: injected worker
//! death and chunk corruption surfaced as typed
//! [`super::leader::ClusterError`]s. But a typed abort still wastes every
//! surviving board's work. With a [`RecoveryPolicy`] (on by default) the
//! leader instead:
//!
//! * **retries** a corrupt parameter chunk over the bus (the board's
//!   on-device state is fine — the [`super::bus::params_checksum`]
//!   mismatch was in transit) via `Cmd::ReadParams`, up to
//!   [`RecoveryPolicy::max_chunk_retries`] times;
//! * **evicts** a dead or persistently-corrupting board from the pool
//!   and **reschedules** its outstanding chunks onto surviving boards:
//!   single-board jobs restart from their last leader-held checkpoint
//!   (or from scratch) on the lowest-indexed surviving board; divided
//!   replicas are adopted by a surviving group member, which rebuilds
//!   the replica's trainer from the last broadcast average and
//!   fast-forwards its sampler — so the recomputed chunk, and therefore
//!   the chunk-index-ordered gradient accumulation, is **bit-identical**
//!   to the fault-free run (DESIGN.md §Recovery);
//! * **checkpoints** at a configurable step cadence, giving both the
//!   in-run restart granularity and the durable
//!   [`super::checkpoint::TrainCheckpoint`] snapshots that
//!   `Session::train_with` / `mfnn train --checkpoint-every` expose.
//!
//! Recovery never masks *logic* errors: a worker-reported job error
//! (bad dataset, shape mismatch) or a protocol violation still aborts
//! with the old typed error — rescheduling those would fail everywhere.
//!
//! Eviction also heals the ring (DESIGN.md §Cluster): under
//! [`super::cost::SyncPolicy::Ring`] the adopting replica re-collects
//! every replica's chunk, so the averaging input — and therefore the
//! trained state — stays bit-identical to the fault-free run; only the
//! modelled collective shrinks to the surviving ring
//! ([`super::cost::ring_sync_cost`] over the live count).

/// How the leader responds to board failures. Carried per run by
/// [`super::ClusterConfig`]; the default is recovery **on**.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Master switch: reschedule work off dead/evicted boards. `false`
    /// restores the pre-recovery behaviour (first fault aborts the job
    /// with a typed error — what the never-hangs fault tests pin down).
    pub reschedule: bool,
    /// How many times a checksum-failed parameter chunk is re-read
    /// (`Cmd::ReadParams`) before the board is declared
    /// persistently-failing and evicted.
    pub max_chunk_retries: usize,
    /// Capture a [`super::checkpoint::TrainCheckpoint`] every this many
    /// steps (0 = off). Single-board jobs are chunked at exactly this
    /// cadence; divided jobs capture at the first weight-sync boundary
    /// at or past each multiple. Also the restart granularity: a
    /// rescheduled single-board job resumes from its last checkpoint
    /// instead of step 0.
    pub checkpoint_every: usize,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { reschedule: true, max_chunk_retries: 2, checkpoint_every: 0 }
    }
}

impl RecoveryPolicy {
    /// The pre-recovery behaviour: any board fault aborts the job with
    /// a typed error (no retries, no rescheduling, no checkpoints).
    pub fn abort() -> RecoveryPolicy {
        RecoveryPolicy { reschedule: false, max_chunk_retries: 0, checkpoint_every: 0 }
    }

    /// Recovery with checkpoints every `steps` steps.
    pub fn checkpointed(steps: usize) -> RecoveryPolicy {
        RecoveryPolicy { checkpoint_every: steps, ..RecoveryPolicy::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_reschedules_with_a_bounded_retry_budget() {
        let p = RecoveryPolicy::default();
        assert!(p.reschedule);
        assert!(p.max_chunk_retries > 0);
        assert_eq!(p.checkpoint_every, 0);
    }

    #[test]
    fn abort_policy_disables_everything() {
        let p = RecoveryPolicy::abort();
        assert!(!p.reschedule);
        assert_eq!(p.max_chunk_retries, 0);
        assert_eq!(RecoveryPolicy::checkpointed(25).checkpoint_every, 25);
    }
}
