//! Pass 4: hazard oracle — certify the plan's optimisation claims.
//!
//! [`ExecPlan`] claims two things per compiled wave
//! ([`crate::hw::WaveClaim`]): that a fused dot→act pair is
//! semantics-preserving, and that a wave's lanes are independent
//! (worker-pool eligible). The executor trusts those claims; this pass
//! recomputes both from scratch over *exact* per-lane address sets
//! (packed prefix-sum layout, the same arithmetic as the unplanned
//! `ExecPlan::new` resolver) rather than the plan's interval sweeps:
//!
//! - **Fusion** — re-derives the fused-output mapping independently:
//!   single-lane distinct dot outputs, no dot chains, every activation
//!   element consuming a distinct dot output exactly once, and no
//!   activation write clobbering a dot input, another dot output, or
//!   another activation input. Any violated condition on a wave the
//!   plan *did* fuse is a [`Diagnostic::FusionUnsound`] miscompile.
//! - **Parallelism** — the exact independence condition: for lanes
//!   `i ≠ j`, `W_i ∩ (R_j ∪ W_j) = ∅` (fused writes included, own-lane
//!   aliasing exempt). A claimed-parallel wave violating it is a
//!   [`Diagnostic::ParallelUnsound`] miscompile. The plan's own checks
//!   are conservative under-approximations of this condition, so a
//!   correct plan can never be flagged — the oracle only fires on real
//!   unsoundness.
//! - **Order dependence** — any cross-lane RAW/WAR/WAW conflict on a
//!   wave executed sequentially is legal but fragile (the result
//!   depends on lane order); reported as
//!   [`Diagnostic::OrderDependent`] warnings for `Strict` runs.
//!
//! Waves whose exact address sets exceed [`ADDR_BUDGET`] are skipped
//! and counted in [`super::CheckReport::hazard_skipped`] so a bounded
//! check never silently claims full coverage.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::assembler::program::{Program, Step, View, Wave};
use crate::hw::{ExecPlan, FpgaDevice};
use crate::isa::Opcode;

use super::Diagnostic;

/// Exact-address budget per certified wave (dot + fused act).
const ADDR_BUDGET: usize = 1 << 20;

/// Run the pass; returns the number of skipped (over-budget) waves.
pub(super) fn run(
    program: &Program,
    device: &FpgaDevice,
    diags: &mut Vec<Diagnostic>,
) -> usize {
    // Packed arena layout: prefix sums of buffer lengths.
    let mut base = Vec::with_capacity(program.buffers.len());
    let mut acc = 0usize;
    for b in &program.buffers {
        base.push(acc);
        acc += b.len();
    }

    let plan = ExecPlan::new(program, device);
    let mut skipped = 0usize;
    for claim in plan.wave_claims() {
        let Step::Wave(w) = &program.steps[claim.src_step] else { continue };
        if w.op == Opcode::Nop {
            continue;
        }

        // Locate the fused activation wave the same way the plan did:
        // optionally one LoadLut, then the act wave.
        let fused_act: Option<(usize, &Wave)> = if claim.fused {
            let next = claim.src_step + 1;
            let act_idx = match program.steps.get(next) {
                Some(Step::LoadLut(_)) => next + 1,
                _ => next,
            };
            match program.steps.get(act_idx) {
                Some(Step::Wave(a)) if a.op == Opcode::ActivationFunction => {
                    Some((act_idx, a))
                }
                _ => {
                    diags.push(Diagnostic::FusionUnsound {
                        dot_step: claim.src_step,
                        act_step: act_idx,
                        reason: "no activation wave follows the fused dot",
                    });
                    continue;
                }
            }
        } else {
            None
        };

        let mut total = wave_addr_count(w);
        if let Some((_, act)) = fused_act {
            total += wave_addr_count(act);
        }
        if total > ADDR_BUDGET {
            skipped += 1;
            continue;
        }

        // Per-lane exact write sets; fused writes attach to the dot lane
        // producing the consumed output.
        let mut writes: Vec<Vec<usize>> =
            w.lanes.iter().map(|l| view_addrs(&base, &l.out)).collect();
        if let Some((act_step, act)) = fused_act {
            match fusion_map(&base, w, act) {
                Ok(fused_out) => {
                    for (lane, fo) in fused_out.into_iter().enumerate() {
                        if let Some(addr) = fo {
                            writes[lane].push(addr);
                        }
                    }
                }
                Err(reason) => {
                    diags.push(Diagnostic::FusionUnsound {
                        dot_step: claim.src_step,
                        act_step,
                        reason,
                    });
                    continue;
                }
            }
        }
        let reads: Vec<Vec<usize>> = w
            .lanes
            .iter()
            .map(|l| {
                let mut r = view_addrs(&base, &l.a);
                if let Some(b) = &l.b {
                    r.extend(view_addrs(&base, b));
                }
                r
            })
            .collect();

        if let Some((lanes, addr, hazard)) = first_conflict(&reads, &writes) {
            if claim.parallel {
                diags.push(Diagnostic::ParallelUnsound {
                    step: claim.src_step,
                    lanes,
                    addr,
                });
            } else {
                diags.push(Diagnostic::OrderDependent {
                    step: claim.src_step,
                    lanes,
                    addr,
                    hazard,
                });
            }
        }
    }
    skipped
}

fn view_addrs(base: &[usize], v: &View) -> Vec<usize> {
    (0..v.len).map(|i| base[v.buf] + v.offset + i * v.stride).collect()
}

fn wave_addr_count(w: &Wave) -> usize {
    w.lanes
        .iter()
        .map(|l| l.a.len + l.b.as_ref().map_or(0, |b| b.len) + l.out.len)
        .sum()
}

/// Independent re-derivation of the fused-output mapping: `Ok(map)`
/// gives each dot lane its activation write address (or `None` when its
/// output is unconsumed); `Err` names the violated soundness condition.
fn fusion_map(
    base: &[usize],
    dot: &Wave,
    act: &Wave,
) -> Result<Vec<Option<usize>>, &'static str> {
    let mut out_lane: HashMap<usize, usize> = HashMap::with_capacity(dot.lanes.len());
    for (i, l) in dot.lanes.iter().enumerate() {
        if l.out.len != 1 {
            return Err("dot output is not a single lane");
        }
        let o = base[l.out.buf] + l.out.offset;
        if out_lane.insert(o, i).is_some() {
            return Err("two dot lanes share an output lane");
        }
    }
    let mut dot_in: HashSet<usize> = HashSet::new();
    for l in &dot.lanes {
        dot_in.extend(view_addrs(base, &l.a));
        if let Some(b) = &l.b {
            dot_in.extend(view_addrs(base, b));
        }
    }
    if out_lane.keys().any(|a| dot_in.contains(a)) {
        return Err("dot chain: one lane reads another's output");
    }
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    let mut act_in: HashSet<usize> = HashSet::new();
    for l in &act.lanes {
        if l.a.len != l.out.len {
            return Err("activation element count mismatch");
        }
        let ia = view_addrs(base, &l.a);
        let oa = view_addrs(base, &l.out);
        act_in.extend(ia.iter().copied());
        pairs.extend(ia.into_iter().zip(oa));
    }
    let mut fused: Vec<Option<usize>> = vec![None; dot.lanes.len()];
    let mut seen_out: HashSet<usize> = HashSet::with_capacity(pairs.len());
    for (ia, oa) in pairs {
        let Some(&lane) = out_lane.get(&ia) else {
            return Err("activation reads a lane that is not a dot output");
        };
        if fused[lane].is_some() {
            return Err("dot output consumed by two activation elements");
        }
        if oa != ia && (out_lane.contains_key(&oa) || act_in.contains(&oa)) {
            return Err("activation write clobbers a dot output or activation input");
        }
        if dot_in.contains(&oa) {
            return Err("activation write clobbers a dot input");
        }
        if !seen_out.insert(oa) {
            return Err("two activation elements write the same lane");
        }
        fused[lane] = Some(oa);
    }
    Ok(fused)
}

/// First (lowest-address) cross-lane conflict, classified RAW/WAR/WAW.
/// Returns `((earlier lane, later lane), addr, hazard)`.
fn first_conflict(
    reads: &[Vec<usize>],
    writes: &[Vec<usize>],
) -> Option<((usize, usize), usize, &'static str)> {
    // addr → (first writer, second distinct writer)
    let mut writer: BTreeMap<usize, (usize, Option<usize>)> = BTreeMap::new();
    for (i, ws) in writes.iter().enumerate() {
        for &a in ws {
            match writer.get_mut(&a) {
                None => {
                    writer.insert(a, (i, None));
                }
                Some((w1, w2)) => {
                    if *w1 != i && w2.is_none() {
                        *w2 = Some(i);
                    }
                }
            }
        }
    }
    // addr → first two distinct reader lanes
    let mut reader: BTreeMap<usize, (usize, Option<usize>)> = BTreeMap::new();
    for (i, rs) in reads.iter().enumerate() {
        for &a in rs {
            match reader.get_mut(&a) {
                None => {
                    reader.insert(a, (i, None));
                }
                Some((r1, r2)) => {
                    if *r1 != i && r2.is_none() {
                        *r2 = Some(i);
                    }
                }
            }
        }
    }
    for (&addr, &(w1, w2)) in &writer {
        if let Some(w2) = w2 {
            return Some(((w1.min(w2), w1.max(w2)), addr, "WAW"));
        }
        if let Some(&(r1, r2)) = reader.get(&addr) {
            // Pick a reader that is not the writing lane itself.
            let r = if r1 != w1 { Some(r1) } else { r2 };
            if let Some(r) = r {
                return Some(if w1 < r {
                    ((w1, r), addr, "RAW")
                } else {
                    ((r, w1), addr, "WAR")
                });
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp};
    use crate::fixed::FixedSpec;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};

    fn device() -> FpgaDevice {
        FpgaDevice::selected()
    }

    /// Parallel dot lanes feeding a fused activation: the plan's claims
    /// must certify clean.
    #[test]
    fn certifies_correct_fused_parallel_claims() {
        let mut p = Program::new("hz", FixedSpec::PAPER);
        let x = p.buffer("x", 4, 4, BufKind::Input);
        let w = p.buffer("w", 4, 4, BufKind::Weight);
        let z = p.buffer("z", 4, 1, BufKind::Temp);
        let o = p.buffer("o", 4, 1, BufKind::Output);
        let lut = p.lut(ActLut::build(
            ActKind::Relu,
            false,
            FixedSpec::PAPER,
            AddrMode::Clamp,
            3,
        ));
        p.steps.push(Step::LoadLut(lut));
        let dots = (0..4)
            .map(|r| LaneOp {
                a: View::contiguous(x, 4 * r, 4),
                b: Some(View::contiguous(w, 4 * r, 4)),
                out: View::contiguous(z, r, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 4,
            lut: None,
            lanes: dots,
        }));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: 4,
            lut: Some(lut),
            lanes: vec![LaneOp { a: View::all(z, 4), b: None, out: View::all(o, 4) }],
        }));
        p.check().expect("valid program");
        let mut diags = Vec::new();
        let skipped = run(&p, &device(), &mut diags);
        assert_eq!(skipped, 0);
        assert!(diags.is_empty(), "{diags:?}");
    }

    /// A lane reading another lane's output is order-dependent — warned,
    /// never claimed parallel by the plan.
    #[test]
    fn cross_lane_raw_is_flagged_order_dependent() {
        let mut p = Program::new("hz", FixedSpec::PAPER);
        let x = p.buffer("x", 2, 1, BufKind::Input);
        let o = p.buffer("o", 2, 1, BufKind::Output);
        let lane0 = LaneOp {
            a: View::contiguous(x, 0, 1),
            b: Some(View::contiguous(x, 1, 1)),
            out: View::contiguous(o, 0, 1),
        };
        let lane1 = LaneOp {
            a: View::contiguous(o, 0, 1), // reads lane 0's output
            b: Some(View::contiguous(x, 0, 1)),
            out: View::contiguous(o, 1, 1),
        };
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 1,
            lut: None,
            lanes: vec![lane0, lane1],
        }));
        p.check().expect("valid program");
        let mut diags = Vec::new();
        run(&p, &device(), &mut diags);
        assert_eq!(diags.len(), 1, "{diags:?}");
        match &diags[0] {
            Diagnostic::OrderDependent { step, lanes, hazard, .. } => {
                assert_eq!((*step, *lanes, *hazard), (0, (0, 1), "RAW"));
            }
            other => panic!("wrong diagnostic: {other:?}"),
        }
    }

    /// The fusion oracle rejects each unsound shape with a precise
    /// reason (these shapes are unreachable through ExecPlan, which
    /// refuses to fuse them — exercised directly).
    #[test]
    fn fusion_oracle_rejects_unsound_shapes() {
        let mut p = Program::new("hz", FixedSpec::PAPER);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let z = p.buffer("z", 2, 1, BufKind::Temp);
        let o = p.buffer("o", 2, 1, BufKind::Output);
        let base = vec![0usize, 4, 6];
        let dot = |out_lane: usize| Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 2,
            lut: None,
            lanes: vec![LaneOp {
                a: View::contiguous(x, 0, 2),
                b: Some(View::contiguous(x, 2, 2)),
                out: View::contiguous(z, out_lane, 1),
            }],
        };
        let act = |src: View, dst: View| Wave {
            op: Opcode::ActivationFunction,
            vec_len: src.len,
            lut: Some(0),
            lanes: vec![LaneOp { a: src, b: None, out: dst }],
        };
        // Activation reading a non-dot-output lane.
        let err = fusion_map(
            &base,
            &dot(0),
            &act(View::contiguous(z, 1, 1), View::contiguous(o, 0, 1)),
        )
        .unwrap_err();
        assert!(err.contains("not a dot output"), "{err}");

        // Activation write clobbering a dot input.
        let err = fusion_map(
            &base,
            &dot(0),
            &act(View::contiguous(z, 0, 1), View::contiguous(x, 0, 1)),
        )
        .unwrap_err();
        assert!(err.contains("dot input"), "{err}");

        // Two activation elements consuming the same dot output.
        let strided_same = View { buf: z, offset: 0, len: 2, stride: 0 };
        let err = fusion_map(&base, &dot(0), &act(strided_same, View::all(o, 2)))
            .unwrap_err();
        assert!(err.contains("consumed by two"), "{err}");
    }

    /// The exact parallel-independence condition on synthetic lane sets.
    #[test]
    fn first_conflict_classifies_hazards() {
        // WAW: lanes 0 and 2 write addr 7.
        let conflict = first_conflict(
            &[vec![], vec![], vec![]],
            &[vec![7], vec![8], vec![7]],
        );
        assert_eq!(conflict, Some(((0, 2), 7, "WAW")));

        // WAR: lane 0 reads addr 5, lane 1 writes it.
        let conflict = first_conflict(&[vec![5], vec![]], &[vec![6], vec![5]]);
        assert_eq!(conflict, Some(((0, 1), 5, "WAR")));

        // Own-lane aliasing is exempt.
        let conflict = first_conflict(&[vec![3], vec![4]], &[vec![3], vec![4]]);
        assert_eq!(conflict, None);
    }
}
