//! Pass 1: lane-granular use-before-def dataflow.
//!
//! Tracks a defined bit per buffer lane through the step schedule.
//! Host-bindable buffers (every kind except `Temp`) and const-inited
//! buffers start defined; scratch lanes become defined only when a
//! `LoadDram` or an earlier wave writes them. A wave that reads an
//! undefined scratch lane observes arena zero-init — legal on the
//! simulators, garbage on real BRAM — and is reported as a hard
//! [`Diagnostic::UndefinedRead`].
//!
//! Soundness: lanes are walked in program order and, within a wave, in
//! lane order — exactly the sequential semantics `FastSim::exec_wave`
//! implements — so a lane defined by an earlier lane op of the same
//! wave is correctly visible to later lane ops. The pass never clears a
//! defined bit (writes only add definitions), so "defined here" is
//! path-insensitive and exact for this straight-line IR: a flagged read
//! is undefined on *the* execution path, not just some path.

use crate::assembler::program::{BufKind, Program, Step, View};
use crate::isa::Opcode;

use super::Diagnostic;

/// Run the pass, appending at most one [`Diagnostic::UndefinedRead`]
/// per wave (the first undefined read encountered).
pub(super) fn run(program: &Program, diags: &mut Vec<Diagnostic>) {
    let mut defined: Vec<Vec<bool>> = program
        .buffers
        .iter()
        .map(|b| vec![b.kind != BufKind::Temp || b.init.is_some(); b.len()])
        .collect();

    for (si, step) in program.steps.iter().enumerate() {
        match step {
            Step::LoadDram(b) => defined[*b].iter_mut().for_each(|d| *d = true),
            // A store reads whatever is there; stale lanes surface at the
            // wave that computed (or failed to compute) them, not here.
            Step::StoreDram(_) | Step::LoadLut(_) => {}
            Step::Wave(w) => {
                if w.op == Opcode::Nop {
                    continue;
                }
                let mut flagged = false;
                for (li, lane) in w.lanes.iter().enumerate() {
                    if !flagged {
                        let reads = [Some(&lane.a), lane.b.as_ref()];
                        'scan: for v in reads.into_iter().flatten() {
                            if let Some(bad) = first_undefined(v, &defined) {
                                diags.push(Diagnostic::UndefinedRead {
                                    step: si,
                                    op: w.op,
                                    lane_idx: li,
                                    buf: program.buffers[v.buf].name.clone(),
                                    lane: bad,
                                });
                                flagged = true;
                                break 'scan;
                            }
                        }
                    }
                    for i in 0..lane.out.len {
                        defined[lane.out.buf][lane.out.offset + i * lane.out.stride] = true;
                    }
                }
            }
        }
    }
}

fn first_undefined(v: &View, defined: &[Vec<bool>]) -> Option<usize> {
    (0..v.len).map(|i| v.offset + i * v.stride).find(|&lane| !defined[v.buf][lane])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::Wave;
    use crate::fixed::FixedSpec;

    fn two_buf_program() -> (Program, usize, usize) {
        let mut p = Program::new("df", FixedSpec::PAPER);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let t = p.buffer("t", 4, 1, BufKind::Temp);
        (p, x, t)
    }

    fn add_wave(a: View, b: View, out: View, vec_len: usize) -> Step {
        Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len,
            lut: None,
            lanes: vec![crate::assembler::program::LaneOp { a, b: Some(b), out }],
        })
    }

    #[test]
    fn read_of_unwritten_scratch_is_flagged_with_exact_lane() {
        let (mut p, x, t) = two_buf_program();
        p.steps.push(add_wave(View::all(t, 4), View::all(x, 4), View::all(x, 4), 4));
        let mut diags = Vec::new();
        run(&p, &mut diags);
        assert_eq!(
            diags,
            vec![Diagnostic::UndefinedRead {
                step: 0,
                op: Opcode::VectorAddition,
                lane_idx: 0,
                buf: "t".into(),
                lane: 0,
            }]
        );
    }

    #[test]
    fn write_then_read_is_clean_and_load_dram_defines() {
        let (mut p, x, t) = two_buf_program();
        // Write t, then read it back: clean.
        p.steps.push(add_wave(View::all(x, 4), View::all(x, 4), View::all(t, 4), 4));
        p.steps.push(add_wave(View::all(t, 4), View::all(x, 4), View::all(x, 4), 4));
        let mut diags = Vec::new();
        run(&p, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");

        // LoadDram alone also defines.
        let (mut p, x, t) = two_buf_program();
        p.steps.push(Step::LoadDram(t));
        p.steps.push(add_wave(View::all(t, 4), View::all(x, 4), View::all(x, 4), 4));
        let mut diags = Vec::new();
        run(&p, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn strided_write_leaves_gap_lanes_undefined() {
        let (mut p, x, t) = two_buf_program();
        // Write only even lanes of t (stride 2), then read all four.
        let strided = View { buf: t, offset: 0, len: 2, stride: 2 };
        p.steps.push(add_wave(View::contiguous(x, 0, 2), View::contiguous(x, 0, 2), strided, 2));
        p.steps.push(add_wave(View::all(t, 4), View::all(x, 4), View::all(x, 4), 4));
        let mut diags = Vec::new();
        run(&p, &mut diags);
        assert_eq!(diags.len(), 1);
        match &diags[0] {
            Diagnostic::UndefinedRead { step, lane, buf, .. } => {
                assert_eq!((*step, *lane, buf.as_str()), (1, 1, "t"));
            }
            other => panic!("wrong diagnostic: {other:?}"),
        }
    }

    #[test]
    fn earlier_lane_defines_for_later_lane_in_same_wave() {
        let (mut p, x, t) = two_buf_program();
        let lane0 = crate::assembler::program::LaneOp {
            a: View::contiguous(x, 0, 2),
            b: Some(View::contiguous(x, 2, 2)),
            out: View::contiguous(t, 0, 2),
        };
        let lane1 = crate::assembler::program::LaneOp {
            a: View::contiguous(t, 0, 2),
            b: Some(View::contiguous(x, 0, 2)),
            out: View::contiguous(t, 2, 2),
        };
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 2,
            lut: None,
            lanes: vec![lane0, lane1],
        }));
        let mut diags = Vec::new();
        run(&p, &mut diags);
        assert!(diags.is_empty(), "sequential lane semantics: {diags:?}");
    }
}
