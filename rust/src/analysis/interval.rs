//! Pass 2: fixed-point interval analysis.
//!
//! Propagates a per-lane value range `[lo, hi]` (raw `i16` units)
//! through the step schedule under the program's [`FixedSpec`], using
//! the exact transfer functions of the datapath:
//!
//! - add/sub: corner sums, narrowed without shift (`FixedSpec::add`);
//! - mul: 4-corner product range, floor-shifted by `frac_bits`
//!   (arithmetic `>>` = floor division, which is monotone, so shifting
//!   the corners bounds the shift of every interior value), then
//!   narrowed (`FixedSpec::mul` = `rescale`);
//! - dot: per-element corner-product ranges summed into a full-width
//!   accumulator bound, then floor-shifted and narrowed
//!   (`FixedSpec::dot`);
//! - sum: corner sums narrowed without shift (`FixedSpec::sum`);
//! - activation: the reachable table window under the LUT's shift and
//!   address mode — shifting is monotone so the reachable shifted
//!   addresses form one interval, and interpolated outputs are proven
//!   bounded by the two neighbouring table entries (`ActLut`), so the
//!   min/max over the reachable window (plus interpolation neighbours)
//!   bounds every output.
//!
//! Narrowing is where diagnostics fire. A pre-narrow range entirely
//! outside `i16` under `RoundMode::Wrap` wraps on *every* execution
//! within the host envelope — [`Diagnostic::GuaranteedOverflow`], a
//! hard error. A straddling range is [`Diagnostic::PossibleWrap`]; any
//! out-of-range bound under `RoundMode::Saturate` is
//! [`Diagnostic::PossibleSaturation`]; a `AddrMode::Wrap` LUT reachable
//! outside its `[-512, 511]` shifted domain is
//! [`Diagnostic::LutDomainExceeded`] (all warnings). Per wave, at most
//! one diagnostic per kind is emitted, carrying the worst-magnitude
//! bound and the lane op achieving it.
//!
//! Soundness: ranges only ever widen past the true value set (corner
//! arithmetic over monotone ops, full-`i16` fallback after a wrap), so
//! the final per-lane ranges returned to [`super::CheckReport::ranges`]
//! contain every value any execution within the host envelope can leave
//! in that lane — the property fuzzed in `tests/properties.rs`.

use crate::assembler::program::{BufKind, Program, Step};
use crate::fixed::RoundMode;
use crate::isa::Opcode;
use crate::nn::lut::{ActLut, AddrMode, LUT_SIZE};

use super::{CheckOptions, Diagnostic};

const I16_MIN: i64 = i16::MIN as i64;
const I16_MAX: i64 = i16::MAX as i64;

type Range = (i64, i64);

/// Run the pass; returns the final per-buffer per-lane ranges.
pub(super) fn run(
    program: &Program,
    opts: &CheckOptions,
    diags: &mut Vec<Diagnostic>,
) -> Vec<Vec<Range>> {
    let bound = opts.host_bound.map_or(I16_MAX, |b| b.unsigned_abs() as i64);
    let envelope = (-bound, bound);

    // Initial state: const data is exact, host-bindable buffers get the
    // envelope, scratch is arena zero-init.
    let init: Vec<Vec<Range>> = program
        .buffers
        .iter()
        .map(|b| match &b.init {
            Some(data) => data.iter().map(|&v| (v as i64, v as i64)).collect(),
            None if b.kind == BufKind::Temp => vec![(0, 0); b.len()],
            None => vec![envelope; b.len()],
        })
        .collect();
    let mut ranges = init.clone();
    // DDR shadow: what a LoadDram would bring back. Starts at the same
    // state (host-bound or zero) and is refreshed by StoreDram.
    let mut dram = init;

    for (si, step) in program.steps.iter().enumerate() {
        match step {
            Step::LoadDram(b) => ranges[*b] = dram[*b].clone(),
            Step::StoreDram(b) => dram[*b] = ranges[*b].clone(),
            Step::LoadLut(_) => {}
            Step::Wave(w) => {
                let mut agg = WaveAgg::default();
                for (li, lane) in w.lanes.iter().enumerate() {
                    let a: Vec<Range> = read(&ranges, &lane.a);
                    let b: Vec<Range> = match &lane.b {
                        Some(v) => read(&ranges, v),
                        None => Vec::new(),
                    };
                    let out: Vec<Range> = match w.op {
                        Opcode::Nop => continue,
                        Opcode::VectorAddition => (0..a.len())
                            .map(|i| {
                                narrow(add(a[i], b[i]), program.fixed.round, li, &mut agg)
                            })
                            .collect(),
                        Opcode::VectorSubtraction => (0..a.len())
                            .map(|i| {
                                narrow(sub(a[i], b[i]), program.fixed.round, li, &mut agg)
                            })
                            .collect(),
                        Opcode::ElementMultiplication => (0..a.len())
                            .map(|i| {
                                let p = shift(mul(a[i], b[i]), program.fixed.frac_bits);
                                narrow(p, program.fixed.round, li, &mut agg)
                            })
                            .collect(),
                        Opcode::VectorDotProduct => {
                            let mut acc = (0i64, 0i64);
                            for i in 0..a.len() {
                                acc = add(acc, mul(a[i], b[i]));
                            }
                            vec![narrow(
                                shift(acc, program.fixed.frac_bits),
                                program.fixed.round,
                                li,
                                &mut agg,
                            )]
                        }
                        Opcode::VectorSummation => {
                            let mut acc = (0i64, 0i64);
                            for &r in &a {
                                acc = add(acc, r);
                            }
                            vec![narrow(acc, program.fixed.round, li, &mut agg)]
                        }
                        Opcode::ActivationFunction => {
                            let lut = &program.luts[w.lut.expect("checked LUT")];
                            a.iter().map(|&r| lut_range(lut, r, &mut agg)).collect()
                        }
                    };
                    for (i, r) in out.iter().enumerate() {
                        ranges[lane.out.buf][lane.out.offset + i * lane.out.stride] = *r;
                    }
                }
                agg.flush(si, w.op, w.lut.unwrap_or(0), diags);
            }
        }
    }
    ranges
}

fn read(ranges: &[Vec<Range>], v: &crate::assembler::program::View) -> Vec<Range> {
    (0..v.len).map(|i| ranges[v.buf][v.offset + i * v.stride]).collect()
}

fn add(a: Range, b: Range) -> Range {
    (a.0 + b.0, a.1 + b.1)
}

fn sub(a: Range, b: Range) -> Range {
    (a.0 - b.1, a.1 - b.0)
}

fn mul(a: Range, b: Range) -> Range {
    let c = [a.0 * b.0, a.0 * b.1, a.1 * b.0, a.1 * b.1];
    (*c.iter().min().unwrap(), *c.iter().max().unwrap())
}

/// Floor shift (arithmetic `>>`) — monotone, so shifting the corners
/// is exact on the range.
fn shift(r: Range, frac_bits: u32) -> Range {
    (r.0 >> frac_bits, r.1 >> frac_bits)
}

/// Narrow a pre-narrow range into `i16`, recording the worst offender
/// per diagnostic kind in `agg`.
fn narrow(r: Range, round: RoundMode, lane_idx: usize, agg: &mut WaveAgg) -> Range {
    if r.0 >= I16_MIN && r.1 <= I16_MAX {
        return r;
    }
    match round {
        RoundMode::Saturate => {
            agg.record(NarrowKind::Sat, lane_idx, r);
            (r.0.clamp(I16_MIN, I16_MAX), r.1.clamp(I16_MIN, I16_MAX))
        }
        RoundMode::Wrap => {
            if r.0 > I16_MAX || r.1 < I16_MIN {
                agg.record(NarrowKind::Guaranteed, lane_idx, r);
            } else {
                agg.record(NarrowKind::Wrap, lane_idx, r);
            }
            // Wrapped values can land anywhere; the full range is the
            // only sound post-state.
            (I16_MIN, I16_MAX)
        }
    }
}

/// Output range of one LUT application over input range `r` (which is
/// always `i16`-bounded post-narrow).
fn lut_range(lut: &ActLut, r: Range, agg: &mut WaveAgg) -> Range {
    let slo = ((r.0 as i32) >> lut.shift) as i64;
    let shi = ((r.1 as i32) >> lut.shift) as i64;
    let table = lut.table();
    let interp = lut.interp && lut.shift > 0;
    let mut lo = i64::MAX;
    let mut hi = i64::MIN;
    let mut cover = |a: usize| {
        lo = lo.min(table[a] as i64);
        hi = hi.max(table[a] as i64);
    };
    match lut.mode {
        AddrMode::Clamp => {
            let alo = (slo + 512).clamp(0, LUT_SIZE as i64 - 1) as usize;
            let mut ahi = (shi + 512).clamp(0, LUT_SIZE as i64 - 1) as usize;
            if interp {
                ahi = (ahi + 1).min(LUT_SIZE - 1);
            }
            (alo..=ahi).for_each(&mut cover);
        }
        AddrMode::Wrap => {
            if slo < -512 || shi > 511 {
                // Addresses alias through the 10-bit truncation: two
                // distinct inputs share a table entry.
                let slot = &mut agg.lut_domain;
                *slot = Some(match *slot {
                    None => (slo, shi),
                    Some(prev) => (prev.0.min(slo), prev.1.max(shi)),
                });
            }
            if shi - slo >= LUT_SIZE as i64 - 1 {
                (0..LUT_SIZE).for_each(&mut cover);
            } else {
                for s in slo..=shi {
                    let a = (s as i32 as u32 as usize) & (LUT_SIZE - 1);
                    cover(a);
                    if interp {
                        cover((a + 1) & (LUT_SIZE - 1));
                    }
                }
            }
        }
    }
    // Interpolated outputs lie between neighbouring entries, both of
    // which the windows above cover, so (lo, hi) bounds them too.
    (lo, hi)
}

/// Per-wave aggregation: at most one diagnostic per kind, keeping the
/// worst-magnitude bound and the lane op achieving it.
#[derive(Default)]
struct WaveAgg {
    guaranteed: Option<(usize, Range)>,
    wrap: Option<(usize, Range)>,
    sat: Option<(usize, Range)>,
    lut_domain: Option<Range>,
}

/// Which narrow-time diagnostic a recorded bound belongs to.
enum NarrowKind {
    Guaranteed,
    Wrap,
    Sat,
}

impl WaveAgg {
    fn record(&mut self, kind: NarrowKind, lane_idx: usize, r: Range) {
        let slot = match kind {
            NarrowKind::Guaranteed => &mut self.guaranteed,
            NarrowKind::Wrap => &mut self.wrap,
            NarrowKind::Sat => &mut self.sat,
        };
        let mag = r.0.abs().max(r.1.abs());
        let keep = match *slot {
            None => true,
            Some((_, prev)) => mag > prev.0.abs().max(prev.1.abs()),
        };
        if keep {
            *slot = Some((lane_idx, r));
        }
    }

    fn flush(
        self,
        step: usize,
        op: Opcode,
        lut: usize,
        diags: &mut Vec<Diagnostic>,
    ) {
        if let Some((lane_idx, bound)) = self.guaranteed {
            diags.push(Diagnostic::GuaranteedOverflow { step, op, lane_idx, bound });
        }
        if let Some((lane_idx, bound)) = self.wrap {
            diags.push(Diagnostic::PossibleWrap { step, op, lane_idx, bound });
        }
        if let Some((lane_idx, bound)) = self.sat {
            diags.push(Diagnostic::PossibleSaturation { step, op, lane_idx, bound });
        }
        if let Some(shifted) = self.lut_domain {
            diags.push(Diagnostic::LutDomainExceeded { step, lut, shifted });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{LaneOp, View, Wave};
    use crate::fixed::FixedSpec;
    use crate::nn::lut::ActKind;

    fn wave(op: Opcode, a: View, b: Option<View>, out: View, vec_len: usize) -> Step {
        Step::Wave(Wave { op, vec_len, lut: None, lanes: vec![LaneOp { a, b, out }] })
    }

    #[test]
    fn const_add_chain_is_exact_and_guaranteed_overflow_fires() {
        // big + big = 60000: outside i16 on every execution under Wrap.
        let mut p = Program::new("iv", FixedSpec::PAPER);
        let big = p.const_buffer("big", vec![30000; 2]);
        let out = p.buffer("o", 2, 1, BufKind::Output);
        p.steps.push(wave(
            Opcode::VectorAddition,
            View::all(big, 2),
            Some(View::all(big, 2)),
            View::all(out, 2),
            2,
        ));
        let mut diags = Vec::new();
        let opts = CheckOptions::new(super::super::CheckLevel::Strict);
        let ranges = run(&p, &opts, &mut diags);
        assert_eq!(
            diags,
            vec![Diagnostic::GuaranteedOverflow {
                step: 0,
                op: Opcode::VectorAddition,
                lane_idx: 0,
                bound: (60000, 60000),
            }]
        );
        // Post-wrap state is the sound full range.
        assert_eq!(ranges[out], vec![(I16_MIN, I16_MAX); 2]);
    }

    #[test]
    fn saturating_format_downgrades_to_warning_and_clamps_range() {
        let mut p = Program::new("iv", FixedSpec::PAPER.saturating());
        let big = p.const_buffer("big", vec![30000]);
        let out = p.buffer("o", 1, 1, BufKind::Output);
        p.steps.push(wave(
            Opcode::VectorAddition,
            View::all(big, 1),
            Some(View::all(big, 1)),
            View::all(out, 1),
            1,
        ));
        let mut diags = Vec::new();
        let opts = CheckOptions::new(super::super::CheckLevel::Strict);
        let ranges = run(&p, &opts, &mut diags);
        assert!(matches!(diags[0], Diagnostic::PossibleSaturation { .. }), "{diags:?}");
        assert_eq!(ranges[out], vec![(I16_MAX, I16_MAX)]);
    }

    #[test]
    fn host_envelope_tightens_ranges_to_clean() {
        // envelope 100 + 100 = 200: in range, no diagnostics.
        let mut p = Program::new("iv", FixedSpec::PAPER);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let out = p.buffer("o", 4, 1, BufKind::Output);
        p.steps.push(wave(
            Opcode::VectorAddition,
            View::all(x, 4),
            Some(View::all(x, 4)),
            View::all(out, 4),
            4,
        ));
        let mut diags = Vec::new();
        let opts =
            CheckOptions::new(super::super::CheckLevel::Strict).with_host_bound(100);
        let ranges = run(&p, &opts, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(ranges[out], vec![(-200, 200); 4]);
    }

    #[test]
    fn lut_window_bounds_every_observable_output() {
        // Exhaustively compare the static LUT range against apply_scalar
        // over a concrete input interval.
        let fixed = FixedSpec::PAPER;
        let lut = ActLut::build(ActKind::Tanh, false, fixed, AddrMode::Clamp, 3).with_interp();
        let (lo_in, hi_in) = (-900i16, 1300i16);
        let mut agg = WaveAgg::default();
        let (lo, hi) = lut_range(&lut, (lo_in as i64, hi_in as i64), &mut agg);
        for x in lo_in..=hi_in {
            let y = lut.apply_scalar(x) as i64;
            assert!(y >= lo && y <= hi, "x={x} y={y} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn wrap_mode_lut_out_of_domain_is_flagged() {
        let fixed = FixedSpec::PAPER;
        // shift 0: shifted range == input range, way outside [-512, 511].
        let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Wrap, 0);
        let mut agg = WaveAgg::default();
        let _ = lut_range(&lut, (-4000, 4000), &mut agg);
        assert_eq!(agg.lut_domain, Some((-4000, 4000)));
    }

    #[test]
    fn store_then_load_round_trips_ranges_through_dram() {
        let mut p = Program::new("iv", FixedSpec::PAPER);
        let c = p.const_buffer("c", vec![7]);
        let t = p.buffer("t", 1, 1, BufKind::Output);
        p.steps.push(wave(
            Opcode::VectorAddition,
            View::all(c, 1),
            Some(View::all(c, 1)),
            View::all(t, 1),
            1,
        ));
        p.steps.push(Step::StoreDram(t));
        p.steps.push(Step::LoadDram(t));
        let mut diags = Vec::new();
        let opts = CheckOptions::new(super::super::CheckLevel::Strict);
        let ranges = run(&p, &opts, &mut diags);
        assert!(diags.is_empty());
        assert_eq!(ranges[t], vec![(14, 14)]);
    }
}
