//! Pass 3: ring-FIFO safety.
//!
//! The paper's circular FIFO (§4, Fig 4) distributes microcodes to the
//! processor groups and collects their results; a schedule whose
//! simultaneous result injections exceed the FIFO's bounded capacity
//! overruns it on hardware. This pass replays the program's
//! result-return schedule on the actual [`RingFifo`] model.
//!
//! The schedule is wavefront-synchronous: a wave over `L` lane ops
//! activates `used = min(groups, ceil(L / PROCS_PER_GROUP))` groups
//! (exactly [`Program::encode`]'s group assignment — MVM groups for
//! MVM opcodes, ACTPRO groups for activations), and every wavefront
//! ends with each active group injecting one result token towards the
//! global controller (station 0), which drains the ring completely
//! before the next wavefront issues. All wavefronts of a wave are
//! identical, so replaying one per wave covers the whole schedule.
//!
//! Proof obligations:
//! - **No overrun** ([`Diagnostic::RingOverrun`], error): every
//!   wavefront's `used` simultaneous injections fit the capacity. The
//!   replay detects this as actual [`RingFifo::push`] backpressure.
//! - **No deadlock** ([`Diagnostic::RingDeadlock`], error): each
//!   wavefront's tokens all reach station 0 within `worst_latency()`
//!   clocks — completion of the replay is the proof; the diagnostic is
//!   defensive (unreachable while the controller always pops).
//! - **Headroom** ([`Diagnostic::RingAtCapacity`], warning): the peak
//!   in-flight count never *equals* the capacity, so one straggling
//!   token cannot tip the schedule into backpressure.

use crate::assembler::program::{Program, Step};
use crate::hw::fifo::RingFifo;
use crate::hw::PROCS_PER_GROUP;
use crate::isa::Opcode;

use super::{CheckOptions, Diagnostic};

/// Replay the schedule; returns the peak in-flight token count.
pub(super) fn run(
    program: &Program,
    opts: &CheckOptions,
    capacity: usize,
    diags: &mut Vec<Diagnostic>,
) -> usize {
    let mvm = opts.device.mvm_groups as usize;
    let actpro = opts.device.actpro_groups as usize;
    let stations = 1 + (mvm + actpro).max(1);
    let mut fifo: RingFifo<usize> = RingFifo::new(stations, capacity);
    let mut peak = 0usize;
    let mut at_capacity_step: Option<usize> = None;

    for (si, step) in program.steps.iter().enumerate() {
        let Step::Wave(w) = step else { continue };
        if w.op == Opcode::Nop {
            continue;
        }
        // Group assignment mirrors Program::encode exactly.
        let (groups, first_station) = if w.op.is_mvm() {
            (mvm, 1)
        } else {
            (actpro, 1 + mvm)
        };
        let used = groups.min(w.lanes.len().div_ceil(PROCS_PER_GROUP)).max(1);

        // One representative wavefront: every active group injects its
        // result token towards the controller.
        let mut overran = false;
        for g in 0..used {
            let station = (first_station + g).min(stations - 1);
            if fifo.push(station, 0, si).is_err() {
                diags.push(Diagnostic::RingOverrun { step: si, demand: used, capacity });
                overran = true;
                break;
            }
            peak = peak.max(fifo.in_flight_len());
        }
        if peak >= capacity && at_capacity_step.is_none() && !overran {
            at_capacity_step = Some(si);
        }

        // Controller drains before the next wavefront. Every clock moves
        // every token one hop, so this terminates within worst_latency().
        let mut clocks = 0usize;
        loop {
            while fifo.pop(0).is_some() {}
            if fifo.in_flight_len() == 0 {
                break;
            }
            if clocks > fifo.worst_latency() {
                diags.push(Diagnostic::RingDeadlock { step: si, pending: fifo.in_flight_len() });
                return peak;
            }
            fifo.clock();
            clocks += 1;
        }
        while fifo.pop(0).is_some() {}
    }

    if let Some(step) = at_capacity_step {
        diags.push(Diagnostic::RingAtCapacity { step, peak, capacity });
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{CheckLevel, CheckOptions};
    use crate::assembler::program::{BufKind, LaneOp, View, Wave};
    use crate::fixed::FixedSpec;

    /// A single-wave program with `lanes` parallel one-lane additions.
    fn wide_program(lanes: usize) -> Program {
        let mut p = Program::new("ring", FixedSpec::PAPER);
        let x = p.buffer("x", lanes, 1, BufKind::Input);
        let o = p.buffer("o", lanes, 1, BufKind::Output);
        let lane_ops = (0..lanes)
            .map(|i| LaneOp {
                a: View::contiguous(x, i, 1),
                b: Some(View::contiguous(x, i, 1)),
                out: View::contiguous(o, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: crate::isa::Opcode::VectorAddition,
            vec_len: 1,
            lut: None,
            lanes: lane_ops,
        }));
        p
    }

    #[test]
    fn natural_capacity_is_always_safe() {
        // used ≤ max groups < stations = natural capacity, so the widest
        // possible wave still fits with headroom.
        let p = wide_program(64 * PROCS_PER_GROUP);
        let opts = CheckOptions::new(CheckLevel::Strict);
        let stations =
            1 + (opts.device.mvm_groups + opts.device.actpro_groups) as usize;
        let mut diags = Vec::new();
        let peak = run(&p, &opts, stations, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(peak, opts.device.mvm_groups as usize);
    }

    #[test]
    fn undersized_fifo_is_a_proven_overrun() {
        let p = wide_program(4 * PROCS_PER_GROUP); // 4 active MVM groups
        let opts = CheckOptions::new(CheckLevel::Strict).with_ring_capacity(2);
        let mut diags = Vec::new();
        run(&p, &opts, 2, &mut diags);
        assert_eq!(
            diags,
            vec![Diagnostic::RingOverrun { step: 0, demand: 4, capacity: 2 }]
        );
    }

    #[test]
    fn exact_fit_warns_about_zero_headroom() {
        let p = wide_program(3 * PROCS_PER_GROUP); // 3 active MVM groups
        let opts = CheckOptions::new(CheckLevel::Strict);
        let mut diags = Vec::new();
        let peak = run(&p, &opts, 3, &mut diags);
        assert_eq!(peak, 3);
        assert_eq!(
            diags,
            vec![Diagnostic::RingAtCapacity { step: 0, peak: 3, capacity: 3 }]
        );
    }
}
