//! Static program checker: compile-time verification of lowered
//! [`Program`]s before they touch a machine (DESIGN.md §Static
//! analysis).
//!
//! The FPGA-accelerator survey (arxiv 1712.08934) identifies fixed-point
//! overflow and buffer sizing as the dominant correctness hazards of the
//! paper's design class; this module turns both — plus stale-lane reads
//! and unsound plan optimisations — into compile-time diagnostics. Four
//! passes run over the wave/DMA step schedule:
//!
//! 1. **Lane-granular dataflow** ([`dataflow`]) — per-lane
//!    use-before-def through strided views: a wave that reads a scratch
//!    (`BufKind::Temp`) lane no `LoadDram` or earlier wave ever defined
//!    silently observes arena zero-init; that read is a hard
//!    [`Diagnostic::UndefinedRead`].
//! 2. **Fixed-point interval analysis** ([`interval`]) — value ranges
//!    propagated through dot/mul/add/rescale and the LUT tables under
//!    the program's [`FixedSpec`]. *Guaranteed* overflow (every
//!    execution within the host envelope wraps) is a hard error;
//!    *possible* wrap/saturation and LUT-domain aliasing are
//!    [`CheckLevel::Strict`] warnings carrying the offending wave, op,
//!    and worst-case bound. The static twin of `nn::precision`'s
//!    dynamic search.
//! 3. **Ring-FIFO safety** ([`ring`]) — the per-wavefront result-return
//!    schedule of every wave is replayed through an
//!    [`crate::hw::fifo::RingFifo`] sized to the device; a wavefront whose
//!    simultaneous group injections exceed the FIFO capacity is a
//!    provable overrun, and completion of the replay is a
//!    deadlock-freedom proof for the static schedule.
//! 4. **Hazard oracle** ([`hazard`]) — an independent exact-address
//!    RAW/WAR/WAW recomputation that certifies [`ExecPlan`]'s fusion
//!    and lane-parallel independence claims instead of trusting them
//!    ([`crate::hw::ExecPlan::wave_claims`]).
//!
//! Entry point: [`check_program`]. Severity collection is gated by
//! [`CheckLevel`]: `Standard` keeps hard errors only (zero on every
//! compiler-emitted golden program — asserted in
//! `rust/tests/analysis.rs`), `Strict` adds the advisory warnings.
//! Session wiring: `CompileOptions::with_checks` runs the checker at
//! compile time, attaches the [`CheckReport`]s to the `Artifact`, and
//! surfaces hard errors as typed `Error::Check` ([`CheckError`]).

use std::fmt;

use crate::assembler::program::Program;
use crate::hw::FpgaDevice;
use crate::isa::Opcode;

mod dataflow;
mod hazard;
mod interval;
mod ring;

/// How much the static checker reports (DESIGN.md §Static analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CheckLevel {
    /// Checker skipped entirely.
    #[default]
    Off,
    /// Hard errors only: defects every execution (within the host
    /// envelope) exhibits — undefined-lane reads, guaranteed overflow,
    /// ring overrun/deadlock, unsound plan claims. Zero on sane
    /// programs; safe as a compile gate.
    Standard,
    /// `Standard` plus advisory warnings: *possible* wrap/saturation,
    /// LUT-domain aliasing, order-dependent waves, a headroom-free
    /// ring. Input-envelope dependent; expect warnings on real nets.
    Strict,
}

impl CheckLevel {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Option<CheckLevel> {
        match name {
            "off" => Some(CheckLevel::Off),
            "standard" => Some(CheckLevel::Standard),
            "strict" => Some(CheckLevel::Strict),
            _ => None,
        }
    }

    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            CheckLevel::Off => "off",
            CheckLevel::Standard => "standard",
            CheckLevel::Strict => "strict",
        }
    }
}

/// Checker configuration: level + the modelled hardware and host-data
/// assumptions every soundness claim is relative to.
#[derive(Debug, Clone, Copy)]
pub struct CheckOptions {
    /// Reporting level.
    pub level: CheckLevel,
    /// Device the ring/hazard passes model.
    pub device: FpgaDevice,
    /// Assumed maximum `|raw i16|` of host-bound data (everything the
    /// host may write to a non-`Temp` buffer or DDR region). `None` =
    /// the full `i16` range. Interval soundness holds for any host data
    /// within this envelope.
    pub host_bound: Option<i16>,
    /// Ring-FIFO in-flight capacity override. `None` models the
    /// paper's circular buffer at its natural depth: one slot per ring
    /// station (global controller + every processor group).
    pub ring_capacity: Option<usize>,
}

impl CheckOptions {
    /// Options at `level` on the selected device, full host envelope.
    pub fn new(level: CheckLevel) -> CheckOptions {
        CheckOptions {
            level,
            device: FpgaDevice::selected(),
            host_bound: None,
            ring_capacity: None,
        }
    }

    /// Model a specific device.
    pub fn with_device(mut self, device: FpgaDevice) -> CheckOptions {
        self.device = device;
        self
    }

    /// Assume host data stays within `|x| ≤ bound` (raw).
    pub fn with_host_bound(mut self, bound: i16) -> CheckOptions {
        self.host_bound = Some(bound);
        self
    }

    /// Override the modelled ring-FIFO capacity.
    pub fn with_ring_capacity(mut self, capacity: usize) -> CheckOptions {
        self.ring_capacity = Some(capacity);
        self
    }
}

impl Default for CheckOptions {
    fn default() -> CheckOptions {
        CheckOptions::new(CheckLevel::Standard)
    }
}

/// Diagnostic severity. `Error`s are defects proven for *every*
/// execution within the host envelope; `Warning`s flag possibility.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Severity {
    /// Proven defect — surfaces as `Error::Check` when compiled with
    /// checks on.
    Error,
    /// Advisory — collected at [`CheckLevel::Strict`] only.
    Warning,
}

/// One typed finding, carrying the offending step, op, and worst-case
/// bound (asserted field-exact by the golden tests in
/// `rust/tests/analysis.rs`).
#[derive(Debug, Clone, PartialEq)]
pub enum Diagnostic {
    /// Dataflow (error): a wave reads a scratch lane no `LoadDram` or
    /// earlier wave defined — it observes arena zero-init.
    UndefinedRead {
        /// Source step of the reading wave.
        step: usize,
        /// Opcode of the reading wave.
        op: Opcode,
        /// Index of the reading lane op within the wave.
        lane_idx: usize,
        /// Name of the buffer holding the undefined lane.
        buf: String,
        /// First undefined buffer lane read.
        lane: usize,
    },
    /// Interval (error): under `RoundMode::Wrap` the narrowed value
    /// range lies entirely outside `i16` — every execution within the
    /// envelope wraps (catastrophic sign flip).
    GuaranteedOverflow {
        /// Source step of the wave.
        step: usize,
        /// Opcode.
        op: Opcode,
        /// Worst offending lane op.
        lane_idx: usize,
        /// Pre-narrow value bound `[lo, hi]`.
        bound: (i64, i64),
    },
    /// Interval (warning): under `RoundMode::Wrap` the range straddles
    /// the `i16` edge — some host data within the envelope wraps.
    PossibleWrap {
        /// Source step of the wave.
        step: usize,
        /// Opcode.
        op: Opcode,
        /// Worst offending lane op.
        lane_idx: usize,
        /// Pre-narrow value bound `[lo, hi]`.
        bound: (i64, i64),
    },
    /// Interval (warning): under `RoundMode::Saturate` the range
    /// exceeds `i16` — some host data within the envelope clamps.
    PossibleSaturation {
        /// Source step of the wave.
        step: usize,
        /// Opcode.
        op: Opcode,
        /// Worst offending lane op.
        lane_idx: usize,
        /// Pre-narrow value bound `[lo, hi]`.
        bound: (i64, i64),
    },
    /// Interval (warning): a `AddrMode::Wrap` LUT is reachable with
    /// shifted addresses outside `[-512, 511]` — the table aliases
    /// (two's-complement wraparound of the address).
    LutDomainExceeded {
        /// Source step of the ACT wave.
        step: usize,
        /// LUT index in `Program::luts`.
        lut: usize,
        /// Reachable shifted-address bound `[lo, hi]`.
        shifted: (i64, i64),
    },
    /// Ring (error): a wavefront injects `demand` simultaneous result
    /// tokens but the ring FIFO holds only `capacity` — the hardware
    /// overruns (drops data) before the controller can drain.
    RingOverrun {
        /// Source step of the wave.
        step: usize,
        /// Simultaneous per-wavefront injections (active groups).
        demand: usize,
        /// Modelled FIFO capacity.
        capacity: usize,
    },
    /// Ring (error): the static replay stopped making progress — the
    /// schedule cannot drain (defensive; unreachable while the
    /// controller always pops).
    RingDeadlock {
        /// Source step of the wave.
        step: usize,
        /// Tokens still in flight when progress stopped.
        pending: usize,
    },
    /// Ring (warning): the replay reached the FIFO's exact capacity —
    /// zero headroom; any extra in-flight token would overrun.
    RingAtCapacity {
        /// Source step of the wave.
        step: usize,
        /// Peak in-flight tokens observed.
        peak: usize,
        /// Modelled FIFO capacity.
        capacity: usize,
    },
    /// Hazard (error): the plan claims the wave's lanes independent,
    /// but lane `lanes.0`'s write set intersects lane `lanes.1`'s
    /// read-or-write set at `addr` — a parallel miscompile.
    ParallelUnsound {
        /// Source step of the wave.
        step: usize,
        /// (writer lane, conflicting lane).
        lanes: (usize, usize),
        /// Conflicting packed arena address.
        addr: usize,
    },
    /// Hazard (error): the plan fused a dot→act pair whose fusion is
    /// not semantics-preserving — a fusion miscompile.
    FusionUnsound {
        /// Source step of the dot wave.
        dot_step: usize,
        /// Source step of the act wave.
        act_step: usize,
        /// Why the fusion is unsound.
        reason: &'static str,
    },
    /// Hazard (warning): lanes conflict, so the wave's result depends
    /// on lane order (legal sequentially, but fragile).
    OrderDependent {
        /// Source step of the wave.
        step: usize,
        /// (earlier lane, later lane) in program order.
        lanes: (usize, usize),
        /// Conflicting packed arena address.
        addr: usize,
        /// Hazard class: `"RAW"`, `"WAR"`, or `"WAW"`.
        hazard: &'static str,
    },
}

impl Diagnostic {
    /// Severity of this finding.
    pub fn severity(&self) -> Severity {
        match self {
            Diagnostic::UndefinedRead { .. }
            | Diagnostic::GuaranteedOverflow { .. }
            | Diagnostic::RingOverrun { .. }
            | Diagnostic::RingDeadlock { .. }
            | Diagnostic::ParallelUnsound { .. }
            | Diagnostic::FusionUnsound { .. } => Severity::Error,
            Diagnostic::PossibleWrap { .. }
            | Diagnostic::PossibleSaturation { .. }
            | Diagnostic::LutDomainExceeded { .. }
            | Diagnostic::RingAtCapacity { .. }
            | Diagnostic::OrderDependent { .. } => Severity::Warning,
        }
    }

    /// Short machine-readable kind tag (JSON / table output).
    pub fn kind(&self) -> &'static str {
        match self {
            Diagnostic::UndefinedRead { .. } => "undefined-read",
            Diagnostic::GuaranteedOverflow { .. } => "guaranteed-overflow",
            Diagnostic::PossibleWrap { .. } => "possible-wrap",
            Diagnostic::PossibleSaturation { .. } => "possible-saturation",
            Diagnostic::LutDomainExceeded { .. } => "lut-domain-exceeded",
            Diagnostic::RingOverrun { .. } => "ring-overrun",
            Diagnostic::RingDeadlock { .. } => "ring-deadlock",
            Diagnostic::RingAtCapacity { .. } => "ring-at-capacity",
            Diagnostic::ParallelUnsound { .. } => "parallel-unsound",
            Diagnostic::FusionUnsound { .. } => "fusion-unsound",
            Diagnostic::OrderDependent { .. } => "order-dependent",
        }
    }

    /// Source step the finding anchors to.
    pub fn step(&self) -> usize {
        match *self {
            Diagnostic::UndefinedRead { step, .. }
            | Diagnostic::GuaranteedOverflow { step, .. }
            | Diagnostic::PossibleWrap { step, .. }
            | Diagnostic::PossibleSaturation { step, .. }
            | Diagnostic::LutDomainExceeded { step, .. }
            | Diagnostic::RingOverrun { step, .. }
            | Diagnostic::RingDeadlock { step, .. }
            | Diagnostic::RingAtCapacity { step, .. }
            | Diagnostic::ParallelUnsound { step, .. }
            | Diagnostic::OrderDependent { step, .. } => step,
            Diagnostic::FusionUnsound { dot_step, .. } => dot_step,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Diagnostic::UndefinedRead { step, op, lane_idx, buf, lane } => write!(
                f,
                "step {step}: {op} lane {lane_idx} reads `{buf}`[{lane}] which no \
                 LoadDram or wave ever defined (observes arena zero-init)"
            ),
            Diagnostic::GuaranteedOverflow { step, op, lane_idx, bound } => write!(
                f,
                "step {step}: {op} lane {lane_idx} wraps for every input in the \
                 envelope — value bound [{}, {}] lies outside i16",
                bound.0, bound.1
            ),
            Diagnostic::PossibleWrap { step, op, lane_idx, bound } => write!(
                f,
                "step {step}: {op} lane {lane_idx} may wrap — value bound [{}, {}] \
                 exceeds i16 under RoundMode::Wrap",
                bound.0, bound.1
            ),
            Diagnostic::PossibleSaturation { step, op, lane_idx, bound } => write!(
                f,
                "step {step}: {op} lane {lane_idx} may saturate — value bound \
                 [{}, {}] exceeds i16 under RoundMode::Saturate",
                bound.0, bound.1
            ),
            Diagnostic::LutDomainExceeded { step, lut, shifted } => write!(
                f,
                "step {step}: LUT {lut} (AddrMode::Wrap) reachable with shifted \
                 addresses [{}, {}] outside [-512, 511] — the table aliases",
                shifted.0, shifted.1
            ),
            Diagnostic::RingOverrun { step, demand, capacity } => write!(
                f,
                "step {step}: wavefront injects {demand} simultaneous ring tokens \
                 but the FIFO holds {capacity} — provable overrun"
            ),
            Diagnostic::RingDeadlock { step, pending } => write!(
                f,
                "step {step}: ring replay stopped draining with {pending} tokens \
                 in flight — schedule cannot complete"
            ),
            Diagnostic::RingAtCapacity { step, peak, capacity } => write!(
                f,
                "step {step}: ring reaches its exact capacity ({peak}/{capacity} \
                 in flight) — zero headroom"
            ),
            Diagnostic::ParallelUnsound { step, lanes, addr } => write!(
                f,
                "step {step}: plan claims lanes independent but lane {} writes \
                 arena address {addr} that lane {} reads or writes — parallel \
                 miscompile",
                lanes.0, lanes.1
            ),
            Diagnostic::FusionUnsound { dot_step, act_step, reason } => write!(
                f,
                "steps {dot_step}+{act_step}: plan fused dot→act but fusion is \
                 not semantics-preserving: {reason}"
            ),
            Diagnostic::OrderDependent { step, lanes, addr, hazard } => write!(
                f,
                "step {step}: {hazard} hazard between lanes {} and {} at arena \
                 address {addr} — result depends on lane order",
                lanes.0, lanes.1
            ),
        }
    }
}

/// The checker's output for one program: diagnostics at the requested
/// level plus the facts each proof rests on.
#[derive(Debug, Clone)]
pub struct CheckReport {
    /// Program name.
    pub program: String,
    /// Level the check ran at.
    pub level: CheckLevel,
    /// Findings, filtered to the level (errors only at `Standard`).
    pub diagnostics: Vec<Diagnostic>,
    /// Wave steps analysed.
    pub waves: usize,
    /// Lane ops analysed across all waves.
    pub lane_ops: usize,
    /// Peak simultaneous in-flight ring tokens over the whole schedule.
    pub ring_peak: usize,
    /// Modelled ring-FIFO capacity the proof holds against.
    pub ring_capacity: usize,
    /// Plan waves whose hazard certification was skipped (address-set
    /// budget exceeded); 0 means every claim was certified.
    pub hazard_skipped: usize,
    /// Final per-lane value ranges per buffer (post-schedule): sound
    /// bounds on what any execution within the host envelope leaves in
    /// each lane. Indexed `[buf][lane] = (lo, hi)` of raw `i16` values.
    pub ranges: Vec<Vec<(i64, i64)>>,
}

impl CheckReport {
    /// Hard-error findings.
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Error)
    }

    /// Number of hard errors.
    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    /// Number of warnings.
    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// No findings at all (at the level the check ran at).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Promote hard errors to a typed [`CheckError`], keeping a clean
    /// (or warnings-only) report as `Ok`.
    pub fn into_result(self) -> Result<CheckReport, CheckError> {
        if self.error_count() > 0 {
            let errors = self
                .diagnostics
                .iter()
                .filter(|d| d.severity() == Severity::Error)
                .cloned()
                .collect();
            Err(CheckError { program: self.program, errors })
        } else {
            Ok(self)
        }
    }

    /// JSON rendering of the report (diagnostics + proof facts) for
    /// `mfnn lint --json`.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{{\"program\":\"{}\",\"level\":\"{}\",\"waves\":{},\"lane_ops\":{},\
             \"ring_peak\":{},\"ring_capacity\":{},\"hazard_skipped\":{},\
             \"errors\":{},\"warnings\":{},\"diagnostics\":[",
            json_escape(&self.program),
            self.level.name(),
            self.waves,
            self.lane_ops,
            self.ring_peak,
            self.ring_capacity,
            self.hazard_skipped,
            self.error_count(),
            self.warning_count(),
        ));
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"kind\":\"{}\",\"severity\":\"{}\",\"step\":{},\"message\":\"{}\"}}",
                d.kind(),
                match d.severity() {
                    Severity::Error => "error",
                    Severity::Warning => "warning",
                },
                d.step(),
                json_escape(&d.to_string()),
            ));
        }
        s.push_str("]}");
        s
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Hard checker failure: the program has at least one proven defect.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("static check of `{program}` found {} hard error(s): {}", errors.len(),
        errors.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; "))]
pub struct CheckError {
    /// Program that failed.
    pub program: String,
    /// The proven defects (severity `Error` only).
    pub errors: Vec<Diagnostic>,
}

/// Run every pass over `program` and report at `opts.level`.
///
/// The program must already pass [`Program::check`] (structural
/// validity); the checker assumes in-bounds views. At
/// [`CheckLevel::Off`] no pass runs and the report is empty.
pub fn check_program(program: &Program, opts: &CheckOptions) -> CheckReport {
    let stations = 1 + (opts.device.mvm_groups + opts.device.actpro_groups).max(1) as usize;
    let ring_capacity = opts.ring_capacity.unwrap_or(stations).max(1);
    let mut report = CheckReport {
        program: program.name.clone(),
        level: opts.level,
        diagnostics: Vec::new(),
        waves: program.waves().count(),
        lane_ops: program.total_lane_ops() as usize,
        ring_peak: 0,
        ring_capacity,
        hazard_skipped: 0,
        ranges: Vec::new(),
    };
    if opts.level == CheckLevel::Off {
        return report;
    }
    let mut diags = Vec::new();
    dataflow::run(program, &mut diags);
    report.ranges = interval::run(program, opts, &mut diags);
    report.ring_peak = ring::run(program, opts, ring_capacity, &mut diags);
    report.hazard_skipped = hazard::run(program, &opts.device, &mut diags);
    diags.retain(|d| opts.level == CheckLevel::Strict || d.severity() == Severity::Error);
    diags.sort_by_key(|d| d.step());
    report.diagnostics = diags;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parses_round_trip() {
        for level in [CheckLevel::Off, CheckLevel::Standard, CheckLevel::Strict] {
            assert_eq!(CheckLevel::parse(level.name()), Some(level));
        }
        assert_eq!(CheckLevel::parse("pedantic"), None);
    }

    #[test]
    fn empty_program_is_clean_at_every_level() {
        let p = Program::new("empty", crate::fixed::FixedSpec::PAPER);
        for level in [CheckLevel::Off, CheckLevel::Standard, CheckLevel::Strict] {
            let r = check_program(&p, &CheckOptions::new(level));
            assert!(r.is_clean(), "{level:?}: {:?}", r.diagnostics);
            assert!(r.clone().into_result().is_ok());
        }
    }

    #[test]
    fn check_error_lists_every_hard_error() {
        let report = CheckReport {
            program: "p".into(),
            level: CheckLevel::Standard,
            diagnostics: vec![
                Diagnostic::RingOverrun { step: 3, demand: 4, capacity: 2 },
                Diagnostic::PossibleWrap {
                    step: 1,
                    op: Opcode::VectorAddition,
                    lane_idx: 0,
                    bound: (-40000, 1),
                },
            ],
            waves: 2,
            lane_ops: 2,
            ring_peak: 4,
            ring_capacity: 2,
            hazard_skipped: 0,
            ranges: Vec::new(),
        };
        assert_eq!(report.error_count(), 1);
        assert_eq!(report.warning_count(), 1);
        let err = report.into_result().unwrap_err();
        assert_eq!(err.errors.len(), 1);
        assert!(err.to_string().contains("step 3"));
    }

    #[test]
    fn json_escapes_quotes_and_lists_diagnostics() {
        let report = CheckReport {
            program: "a\"b".into(),
            level: CheckLevel::Strict,
            diagnostics: vec![Diagnostic::RingAtCapacity { step: 0, peak: 2, capacity: 2 }],
            waves: 1,
            lane_ops: 1,
            ring_peak: 2,
            ring_capacity: 2,
            hazard_skipped: 0,
            ranges: Vec::new(),
        };
        let j = report.to_json();
        assert!(j.contains("a\\\"b"));
        assert!(j.contains("\"kind\":\"ring-at-capacity\""));
        assert!(j.contains("\"severity\":\"warning\""));
    }
}
