//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is **HLO text** (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 serialises protos with 64-bit instruction ids that the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md and DESIGN.md §2).

use crate::config::Config;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use thiserror::Error;

/// Runtime errors.
#[derive(Debug, Error)]
pub enum RuntimeError {
    /// XLA/PJRT failure.
    #[error("xla: {0}")]
    Xla(String),
    /// Missing artifact file.
    #[error("artifact {0:?} not found under {1:?} — run `make artifacts`")]
    MissingArtifact(String, PathBuf),
    /// Manifest problems.
    #[error("manifest: {0}")]
    Manifest(String),
    /// Executable not loaded.
    #[error("executable {0:?} not loaded")]
    NotLoaded(String),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A PJRT CPU runtime bound to an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Config,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifacts directory (reads `manifest.toml`, creates the
    /// CPU client; compiles nothing yet).
    pub fn open(dir: &Path) -> Result<Runtime, RuntimeError> {
        let manifest_path = dir.join("manifest.toml");
        if !manifest_path.exists() {
            return Err(RuntimeError::MissingArtifact(
                "manifest.toml".into(),
                dir.to_path_buf(),
            ));
        }
        let manifest = Config::from_file(&manifest_path)
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, execs: HashMap::new() })
    }

    /// The default artifacts directory (`$MFNN_ARTIFACTS` or
    /// `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var("MFNN_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // tests run from the crate root; binaries may run elsewhere
            let cwd = PathBuf::from("artifacts");
            if cwd.exists() {
                cwd
            } else {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            }
        })
    }

    /// Parsed `manifest.toml`.
    pub fn manifest(&self) -> &Config {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest key (e.g. `"mlp_fwd"`).
    pub fn load(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let file = self
            .manifest
            .get_str(&format!("artifacts.{name}"))
            .ok_or_else(|| RuntimeError::Manifest(format!("no artifact key {name:?}")))?
            .to_string();
        let path = self.dir.join(&file);
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(file, self.dir.clone()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path is valid UTF-8"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. Inputs/outputs are i16 tensors
    /// (value, dims) — the artifacts are lowered with `return_tuple=True`
    /// so the single result literal decomposes into the output list.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[(&[i16], Vec<i64>)],
    ) -> Result<Vec<Vec<i16>>, RuntimeError> {
        let exe =
            self.execs.get(name).ok_or_else(|| RuntimeError::NotLoaded(name.to_string()))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            // i16 is not a `NativeType` in the crate (no `vec1::<i16>`),
            // but untyped creation with an S16 shape works.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 2)
            };
            let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
            literals.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S16,
                &dims_usize,
                bytes,
            )?);
        }
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.into_iter().map(|l| l.to_vec::<i16>().map_err(Into::into)).collect()
    }

    /// Names of loaded executables.
    pub fn loaded(&self) -> Vec<&str> {
        self.execs.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Runtime::default_dir().join("manifest.toml").exists()
    }

    #[test]
    fn open_and_read_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::open(&Runtime::default_dir()).unwrap();
        assert_eq!(rt.manifest().get_int("model.frac_bits"), Some(10));
        assert_eq!(rt.manifest().get_int_array("model.dims"), Some(vec![15, 16, 10]));
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn vec_ops_artifact_matches_fixed_semantics() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        use crate::fixed::FixedSpec;
        use crate::nn::lut::{ActKind, ActLut, AddrMode};
        use crate::util::Rng;
        let mut rt = Runtime::open(&Runtime::default_dir()).unwrap();
        rt.load("vec_ops").unwrap();
        let n = rt.manifest().get_int("vec_ops.len").unwrap() as usize;
        let fixed = FixedSpec::q(10).saturating();
        let lut = ActLut::build(ActKind::Relu, false, fixed, AddrMode::Clamp, 5).with_interp();
        let mut r = Rng::new(40);
        let a: Vec<i16> = (0..n).map(|_| r.gen_i16()).collect();
        let b: Vec<i16> = (0..n).map(|_| r.gen_i16()).collect();
        let outs = rt
            .execute(
                "vec_ops",
                &[
                    (&a, vec![n as i64]),
                    (&b, vec![n as i64]),
                    (lut.table(), vec![1024]),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 6);
        assert_eq!(outs[0], vec![fixed.dot(&a, &b)], "dot");
        assert_eq!(outs[1], vec![fixed.sum(&a)], "sum");
        assert_eq!(outs[2], fixed.vadd(&a, &b), "add");
        assert_eq!(outs[3], fixed.vsub(&a, &b), "sub");
        assert_eq!(outs[4], fixed.vmul(&a, &b), "mul");
        assert_eq!(outs[5], lut.apply(&a), "act");
    }

    #[test]
    fn missing_artifact_errors() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::open(&Runtime::default_dir()).unwrap();
        assert!(matches!(rt.load("nope"), Err(RuntimeError::Manifest(_))));
        assert!(matches!(
            rt.execute("mlp_fwd", &[]),
            Err(RuntimeError::NotLoaded(_))
        ));
    }
}
