//! PJRT runtime: load the AOT-compiled JAX/Pallas **golden model**
//! artifacts (HLO text, produced by `python/compile/aot.py`) and execute
//! them from Rust — Python never runs on this path.
//!
//! Roles of the golden model (DESIGN.md §3):
//!
//! * a **bit-exact oracle** for the simulator: `rust/tests/golden.rs`
//!   asserts the simulated Matrix Machine, the pure-jnp reference and the
//!   Pallas kernel produce identical int16 results for forward passes and
//!   full training steps;
//! * the **host/CPU baseline** of the paper's §1 comparison, used by
//!   `benches/bench_golden.rs`.

pub mod golden;
pub mod rt;

pub use golden::GoldenModel;
pub use rt::{Runtime, RuntimeError};
