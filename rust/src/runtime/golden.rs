//! Typed wrapper over the MLP golden-model artifacts.
//!
//! Binds the `manifest.toml` configuration (network dims, batch,
//! fixed-point format, LUT parameters) to the [`super::Runtime`] and
//! offers `forward` / `train_step` calls mirroring the machine's
//! buffer protocol.

use super::rt::{Runtime, RuntimeError};
use crate::fixed::FixedSpec;
use crate::nn::lut::{ActKind, ActLut, AddrMode};
use crate::nn::mlp::{LutParams, MlpSpec};
use std::path::Path;

/// The golden MLP model (shape fixed by the artifacts).
pub struct GoldenModel {
    rt: Runtime,
    /// Spec reconstructed from the manifest.
    pub spec: MlpSpec,
    /// Batch the artifacts were lowered for.
    pub batch: usize,
    /// Learning rate encoded in the train artifact's lr vector protocol.
    pub lr: f64,
    act_tables: Vec<ActLut>,
    dact_tables: Vec<ActLut>,
}

/// Output of one golden training step.
#[derive(Debug, Clone)]
pub struct GoldenStep {
    /// Final-layer activations (batch × out_dim).
    pub out: Vec<i16>,
    /// On-device-style loss lane.
    pub loss: i16,
    /// Updated weights.
    pub weights: Vec<Vec<i16>>,
    /// Updated biases.
    pub biases: Vec<Vec<i16>>,
}

impl GoldenModel {
    /// Open the artifacts and compile both MLP executables.
    pub fn open(dir: &Path) -> Result<GoldenModel, RuntimeError> {
        let mut rt = Runtime::open(dir)?;
        rt.load("mlp_fwd")?;
        rt.load("mlp_train")?;
        let m = rt.manifest();
        let dims: Vec<usize> = m
            .get_int_array("model.dims")
            .ok_or_else(|| RuntimeError::Manifest("model.dims missing".into()))?
            .into_iter()
            .map(|d| d as usize)
            .collect();
        let batch = m.get_int("model.batch").unwrap_or(0) as usize;
        let frac = m.get_int("model.frac_bits").unwrap_or(7) as u32;
        let saturate = m.bool_or("model.saturate", false);
        let shift = m.get_int("model.shift").unwrap_or(7) as u32;
        let clamp = m.bool_or("model.clamp", false);
        let interp = m.bool_or("model.interp", false);
        let lr = m.float_or("model.lr", 1.0 / 256.0);
        let act_names = m
            .get_str_array("model.acts")
            .ok_or_else(|| RuntimeError::Manifest("model.acts missing".into()))?;
        let mut fixed = FixedSpec::q(frac);
        if saturate {
            fixed = fixed.saturating();
        }
        let mode = if clamp { AddrMode::Clamp } else { AddrMode::Wrap };
        let lut = LutParams { shift, mode, interp };
        let acts: Vec<ActKind> = act_names
            .iter()
            .map(|n| {
                ActKind::parse(n)
                    .ok_or_else(|| RuntimeError::Manifest(format!("bad activation {n:?}")))
            })
            .collect::<Result<_, _>>()?;
        let spec = MlpSpec::from_dims(
            "golden",
            &dims,
            *acts.first().unwrap_or(&ActKind::Relu),
            *acts.last().unwrap_or(&ActKind::Identity),
            fixed,
            lut,
        )
        .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let build = |kind: ActKind, deriv: bool| {
            let t = ActLut::build(kind, deriv, fixed, mode, shift);
            if interp {
                t.with_interp()
            } else {
                t
            }
        };
        let act_tables = spec.layers.iter().map(|l| build(l.act, false)).collect();
        let dact_tables = spec.layers.iter().map(|l| build(l.act, true)).collect();
        Ok(GoldenModel { rt, spec, batch, lr, act_tables, dact_tables })
    }

    /// Activation tables the artifacts expect (built identically to the
    /// machine's).
    pub fn act_tables(&self) -> &[ActLut] {
        &self.act_tables
    }

    /// The learning-rate constant vector for the train artifact.
    pub fn lr_vec(&self) -> Vec<i16> {
        let max_out = self.spec.layers.iter().map(|l| l.outputs).max().unwrap();
        vec![self.spec.fixed.from_f64(self.lr); max_out]
    }

    fn mlp_inputs<'a>(
        &'a self,
        x: &'a [i16],
        y: Option<&'a [i16]>,
        weights: &'a [Vec<i16>],
        biases: &'a [Vec<i16>],
        lr_vec: Option<&'a [i16]>,
    ) -> Vec<(&'a [i16], Vec<i64>)> {
        let dims: Vec<usize> = std::iter::once(self.spec.input_dim())
            .chain(self.spec.layers.iter().map(|l| l.outputs))
            .collect();
        let mut inputs: Vec<(&[i16], Vec<i64>)> =
            vec![(x, vec![self.batch as i64, dims[0] as i64])];
        if let Some(y) = y {
            inputs.push((y, vec![self.batch as i64, *dims.last().unwrap() as i64]));
        }
        for (l, (w, b)) in weights.iter().zip(biases).enumerate() {
            inputs.push((w, vec![dims[l] as i64, dims[l + 1] as i64]));
            inputs.push((b, vec![dims[l + 1] as i64]));
        }
        for t in &self.act_tables {
            inputs.push((t.table(), vec![1024]));
        }
        if let Some(lr) = lr_vec {
            for t in &self.dact_tables {
                inputs.push((t.table(), vec![1024]));
            }
            inputs.push((lr, vec![lr.len() as i64]));
        }
        inputs
    }

    /// Run the forward artifact.
    pub fn forward(
        &self,
        x: &[i16],
        weights: &[Vec<i16>],
        biases: &[Vec<i16>],
    ) -> Result<Vec<i16>, RuntimeError> {
        let inputs = self.mlp_inputs(x, None, weights, biases, None);
        let mut outs = self.rt.execute("mlp_fwd", &inputs)?;
        Ok(outs.remove(0))
    }

    /// Run the train-step artifact.
    pub fn train_step(
        &self,
        x: &[i16],
        y: &[i16],
        weights: &[Vec<i16>],
        biases: &[Vec<i16>],
    ) -> Result<GoldenStep, RuntimeError> {
        let lr = self.lr_vec();
        let inputs = self.mlp_inputs(x, Some(y), weights, biases, Some(&lr));
        let mut outs = self.rt.execute("mlp_train", &inputs)?;
        // layout: out, loss, (w, b) per layer
        let out = outs.remove(0);
        let loss = outs.remove(0)[0];
        let mut weights_new = Vec::new();
        let mut biases_new = Vec::new();
        for _ in 0..self.spec.layers.len() {
            weights_new.push(outs.remove(0));
            biases_new.push(outs.remove(0));
        }
        Ok(GoldenStep { out, loss, weights: weights_new, biases: biases_new })
    }
}
