//! The **Matrix Assembler** — the paper's software contribution (§3).
//!
//! "The Matrix Assembler takes in neural network assembly codes and
//! produces instructions and VHDL codes. At runtime, the instructions are
//! decoded into microcodes... the Matrix Assembler controls the number of
//! processor groups and the types of processors using the VHDL codes."
//!
//! Pipeline implemented here:
//!
//! ```text
//! .nnasm text ──asm::parse──▶ asm::Ast ──lower──▶ Program (vector waves)
//!                                      │
//!                                      ├─ encode ─▶ Table-2 instructions (32/48-bit)
//!                                      ├─ microcode_gen ─▶ Fig-3 microcode words
//!                                      ├─ resource ─▶ processor-group counts (Eqns 3–4)
//!                                      └─ vhdl ─▶ generated Matrix Machine VHDL
//! ```

pub mod lower;
pub mod microcode_gen;
pub mod optimizer;
pub mod program;
pub mod resource;
pub mod vhdl;


pub use program::{BufId, BufKind, BufferDecl, LaneOp, Program, Step, SymbolTable, View, Wave};
pub use resource::{Allocation, ResourceModel};
