//! Microcode generation — the "instructions are decoded into microcodes"
//! step of §3.3, implemented as the deterministic expansion the global
//! controller performs at runtime.
//!
//! The conventions these sequences follow (word kinds, cycle budgets) are
//! documented at [`crate::hw::group`], which interprets them. One batch —
//! operands in, one compute pass, results out — always fits the 16-entry
//! microcode cache of §4.1 (asserted by tests).

use crate::hw::COLUMN_LEN;
use crate::isa::microcode::{
    Microcode, ProcCtrl, MAX_CYCLES, MICROCODE_CACHE_DEPTH, PROCS_PER_GROUP,
};
use crate::isa::{ActproOp, MvmOp, Opcode};
use thiserror::Error;

/// Microcode generation failures.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum GenError {
    /// Vector longer than an operand column.
    #[error("vector length {0} exceeds the {COLUMN_LEN}-lane column")]
    TooLong(usize),
    /// More processors than the group has.
    #[error("{0} processors requested; a group has {PROCS_PER_GROUP}")]
    TooManyProcs(usize),
    /// Opcode not executable on this group type.
    #[error("opcode {0} is not an MVM operation")]
    NotMvmOp(Opcode),
    /// Zero-length vector.
    #[error("zero-length vector")]
    Empty,
    /// A generated word exceeded the 10-bit cycle field.
    #[error("cycle budget {0} exceeds the 10-bit microcode field")]
    CycleOverflow(usize),
}

fn check_cycles(c: usize) -> Result<u16, GenError> {
    if c > MAX_CYCLES as usize {
        Err(GenError::CycleOverflow(c))
    } else {
        Ok(c as u16)
    }
}

/// All-idle nibbles for MVM words (`MVM_READ` is the halted state).
fn mvm_idle() -> [ProcCtrl; PROCS_PER_GROUP] {
    [ProcCtrl::mvm(MvmOp::Read, false); PROCS_PER_GROUP]
}

/// A write word streaming `pairs` input beats into processor `p`.
fn mvm_write_word(p: usize, pairs: usize, col: bool) -> Result<Microcode, GenError> {
    let mut w = Microcode {
        cycles: check_cycles(pairs + 1)?, // +1 setup (Fig 7)
        input_col: col,
        input_ctr_en: true,
        ..Default::default()
    };
    w.proc_ctrl = mvm_idle();
    w.proc_ctrl[p] = ProcCtrl::mvm(MvmOp::Write, false);
    Ok(w)
}

/// Generate the batch program for one MVM group executing `op` on
/// `nprocs` processors, each over `len`-lane vectors:
/// per-proc operand loads, one lockstep compute word, per-proc drains.
pub fn mvm_batch(op: Opcode, len: usize, nprocs: usize) -> Result<Vec<Microcode>, GenError> {
    let mvm_op = MvmOp::from_opcode(op).ok_or(GenError::NotMvmOp(op))?;
    if len == 0 {
        return Err(GenError::Empty);
    }
    if len > COLUMN_LEN {
        return Err(GenError::TooLong(len));
    }
    if nprocs == 0 || nprocs > PROCS_PER_GROUP {
        return Err(GenError::TooManyProcs(nprocs));
    }
    let pairs = len.div_ceil(2);
    let needs_b = !matches!(op, Opcode::VectorSummation);
    let mut words = Vec::new();
    // 1) operand loads
    for p in 0..nprocs {
        words.push(mvm_write_word(p, pairs, false)?);
        if needs_b {
            words.push(mvm_write_word(p, pairs, true)?);
        }
    }
    // 2) lockstep compute
    let mut compute = Microcode {
        cycles: check_cycles(len + 8)?, // setup + Fig 8 pipeline
        ..Default::default()
    };
    compute.proc_ctrl = mvm_idle();
    for pc in compute.proc_ctrl.iter_mut().take(nprocs) {
        *pc = ProcCtrl::mvm(mvm_op, false);
    }
    words.push(compute);
    // 3) drains (dot/sum produce a single lane)
    let out_len = match op {
        Opcode::VectorDotProduct | Opcode::VectorSummation => 1,
        _ => len,
    };
    for p in 0..nprocs {
        let mut d = Microcode {
            cycles: check_cycles(out_len)?,
            output_ctr_en: true,
            out_mux_sel: p as u8,
            ..Default::default()
        };
        d.proc_ctrl = mvm_idle();
        words.push(d);
    }
    debug_assert!(words.len() <= MICROCODE_CACHE_DEPTH);
    Ok(words)
}

/// Generate the batch program for one ACTPRO group applying its loaded
/// table to `nprocs` × `len`-element vectors.
pub fn actpro_batch(len: usize, nprocs: usize) -> Result<Vec<Microcode>, GenError> {
    if len == 0 {
        return Err(GenError::Empty);
    }
    if len > crate::hw::BRAM_DEPTH {
        return Err(GenError::TooLong(len));
    }
    if nprocs == 0 || nprocs > PROCS_PER_GROUP {
        return Err(GenError::TooManyProcs(nprocs));
    }
    let run_len = len + (len & 1); // pad to even
    let pairs = run_len / 2;
    let idle = [ProcCtrl::actpro(ActproOp::Read); PROCS_PER_GROUP];
    let mut words = Vec::new();
    for p in 0..nprocs {
        let mut w = Microcode {
            cycles: check_cycles(pairs + 1)?,
            input_ctr_en: true,
            ..Default::default()
        };
        w.proc_ctrl = idle;
        w.proc_ctrl[p] = ProcCtrl::actpro(ActproOp::WriteData);
        words.push(w);
    }
    let mut run = Microcode { cycles: check_cycles(pairs + 6)?, ..Default::default() };
    run.proc_ctrl = idle;
    for pc in run.proc_ctrl.iter_mut().take(nprocs) {
        *pc = ProcCtrl::actpro(ActproOp::Run);
    }
    words.push(run);
    for p in 0..nprocs {
        let mut d = Microcode {
            cycles: check_cycles(pairs)?,
            output_ctr_en: true,
            out_mux_sel: p as u8,
            ..Default::default()
        };
        d.proc_ctrl = idle;
        words.push(d);
    }
    debug_assert!(words.len() <= MICROCODE_CACHE_DEPTH);
    Ok(words)
}

/// Total cycle budget of a generated program.
pub fn program_cycles(words: &[Microcode]) -> u64 {
    words.iter().map(|w| w.cycles as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_batch_shape() {
        let w = mvm_batch(Opcode::VectorAddition, 512, 4).unwrap();
        assert_eq!(w.len(), 13); // 8 loads + 1 compute + 4 drains
        assert_eq!(w[0].cycles, 257); // 256 pairs + setup
        assert!(!w[0].input_col);
        assert!(w[1].input_col);
        assert_eq!(w[8].cycles, 520); // 512 + 8
        assert_eq!(w[9].cycles, 512);
        assert_eq!(w[9].out_mux_sel, 0);
        assert_eq!(w[12].out_mux_sel, 3);
    }

    #[test]
    fn sum_skips_operand_b() {
        let w = mvm_batch(Opcode::VectorSummation, 100, 4).unwrap();
        assert_eq!(w.len(), 4 + 1 + 4);
        assert!(w[..4].iter().all(|x| !x.input_col));
        // single-lane drains
        assert_eq!(w[5].cycles, 1);
    }

    #[test]
    fn dot_drain_is_single_lane() {
        let w = mvm_batch(Opcode::VectorDotProduct, 512, 2).unwrap();
        let drains: Vec<_> = w.iter().filter(|x| x.output_ctr_en).collect();
        assert_eq!(drains.len(), 2);
        assert!(drains.iter().all(|d| d.cycles == 1));
    }

    #[test]
    fn all_batches_fit_cache_and_cycle_fields() {
        for op in [
            Opcode::VectorDotProduct,
            Opcode::VectorSummation,
            Opcode::VectorAddition,
            Opcode::VectorSubtraction,
            Opcode::ElementMultiplication,
        ] {
            for len in [1, 2, 3, 100, 511, 512] {
                for n in 1..=4 {
                    let w = mvm_batch(op, len, n).unwrap();
                    assert!(w.len() <= MICROCODE_CACHE_DEPTH, "{op} len={len} n={n}");
                    assert!(w.iter().all(|x| x.cycles <= MAX_CYCLES));
                }
            }
        }
        for len in [1, 2, 999, 1024] {
            for n in 1..=4 {
                let w = actpro_batch(len, n).unwrap();
                assert!(w.len() <= MICROCODE_CACHE_DEPTH);
                assert!(w.iter().all(|x| x.cycles <= MAX_CYCLES));
            }
        }
    }

    #[test]
    fn errors() {
        assert_eq!(
            mvm_batch(Opcode::VectorAddition, 513, 4),
            Err(GenError::TooLong(513))
        );
        assert_eq!(mvm_batch(Opcode::VectorAddition, 5, 5), Err(GenError::TooManyProcs(5)));
        assert_eq!(
            mvm_batch(Opcode::ActivationFunction, 5, 4),
            Err(GenError::NotMvmOp(Opcode::ActivationFunction))
        );
        assert_eq!(mvm_batch(Opcode::VectorAddition, 0, 1), Err(GenError::Empty));
        assert_eq!(actpro_batch(1025, 4), Err(GenError::TooLong(1025)));
    }

    #[test]
    fn words_roundtrip_through_encoding() {
        for w in mvm_batch(Opcode::ElementMultiplication, 77, 3).unwrap() {
            assert_eq!(Microcode::decode(w.encode()), w);
        }
        for w in actpro_batch(200, 4).unwrap() {
            assert_eq!(Microcode::decode(w.encode()), w);
        }
    }

    #[test]
    fn cycle_budget_helper() {
        let w = mvm_batch(Opcode::VectorAddition, 2, 1).unwrap();
        // load A: 2 (1 pair+setup), load B: 2, compute: 10, drain: 2 = 16
        assert_eq!(program_cycles(&w), 16);
    }
}
