//! Resource allocation — Eqns 3–4 and Table 3 (paper §3.4).
//!
//! "The Matrix Assembler determines the optimal number of processor groups
//! in order to fully utilize the FPGA's resources."
//!
//! * Eqn 3: `N_MVM_PG = N_DDR · CLK_DDR / CLK_FPGA` — MVM group count is
//!   sized to saturate the DDR channels.
//! * Eqn 4: `N_ACTPRO_PG = min(LUT/LUT_pg, FF/FF_pg, BRAM/BRAM_pg)` over
//!   the *leftover* fabric after the MVM groups are placed.

use crate::perf::catalog::FpgaPart;

/// Per-group resource usage (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupUsage {
    /// 6-input LUTs.
    pub luts: u32,
    /// Flip-flops.
    pub ffs: u32,
    /// RAMB18K blocks.
    pub bram18: u32,
    /// DSP48E1 slices.
    pub dsps: u32,
}

/// Table 3 row `MVM_PG`.
pub const MVM_PG_USAGE: GroupUsage = GroupUsage { luts: 495, ffs: 1642, bram18: 8, dsps: 4 };
/// Table 3 row `ACTPRO_PG`.
pub const ACTPRO_PG_USAGE: GroupUsage = GroupUsage { luts: 447, ffs: 1406, bram18: 12, dsps: 0 };

/// The resource model for one target part.
#[derive(Debug, Clone, Copy)]
pub struct ResourceModel {
    /// Target device.
    pub part: &'static FpgaPart,
}

/// A computed allocation for one FPGA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    /// Eqn 3: MVM processor groups.
    pub mvm_groups: u32,
    /// Eqn 4: activation processor groups.
    pub actpro_groups: u32,
    /// Fabric left after both allocations.
    pub leftover: GroupUsage,
}

impl ResourceModel {
    /// Model for a catalog part.
    pub fn new(part: &'static FpgaPart) -> ResourceModel {
        ResourceModel { part }
    }

    /// Eqn 3 (floored to an integer group count, capped by DSP supply —
    /// the paper's §2 scaling requirement: "if the FPGA has a low number
    /// of DSPs, then the Matrix Assembler reduces the number of Mini
    /// Vector Machines").
    pub fn mvm_groups(&self) -> u32 {
        let eqn3 = (self.part.ddr_channels as f64 * self.part.ddr_clock_mhz
            / self.part.fpga_clock_mhz)
            .floor() as u32;
        let dsp_cap = self.part.dsps / MVM_PG_USAGE.dsps;
        let lut_cap = self.part.luts / MVM_PG_USAGE.luts;
        let ff_cap = self.part.ffs / MVM_PG_USAGE.ffs;
        let bram_cap = self.part.bram18 / MVM_PG_USAGE.bram18;
        eqn3.min(dsp_cap).min(lut_cap).min(ff_cap).min(bram_cap)
    }

    /// Eqn 4 over leftover fabric.
    pub fn actpro_groups(&self) -> u32 {
        let n = self.mvm_groups();
        let lut_left = self.part.luts - n * MVM_PG_USAGE.luts;
        let ff_left = self.part.ffs - n * MVM_PG_USAGE.ffs;
        let bram_left = self.part.bram18 - n * MVM_PG_USAGE.bram18;
        (lut_left / ACTPRO_PG_USAGE.luts)
            .min(ff_left / ACTPRO_PG_USAGE.ffs)
            .min(bram_left / ACTPRO_PG_USAGE.bram18)
    }

    /// Full allocation with leftovers.
    pub fn allocate(&self) -> Allocation {
        let m = self.mvm_groups();
        let a = self.actpro_groups();
        let leftover = GroupUsage {
            luts: self.part.luts - m * MVM_PG_USAGE.luts - a * ACTPRO_PG_USAGE.luts,
            ffs: self.part.ffs - m * MVM_PG_USAGE.ffs - a * ACTPRO_PG_USAGE.ffs,
            bram18: self.part.bram18 - m * MVM_PG_USAGE.bram18 - a * ACTPRO_PG_USAGE.bram18,
            dsps: self.part.dsps - m * MVM_PG_USAGE.dsps,
        };
        Allocation { mvm_groups: m, actpro_groups: a, leftover }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::catalog::{FpgaPart, CATALOG};

    #[test]
    fn table3_constants() {
        assert_eq!(MVM_PG_USAGE, GroupUsage { luts: 495, ffs: 1642, bram18: 8, dsps: 4 });
        assert_eq!(ACTPRO_PG_USAGE, GroupUsage { luts: 447, ffs: 1406, bram18: 12, dsps: 0 });
    }

    #[test]
    fn eqn3_on_selected_part() {
        // XC7S75-2: 4 channels × 400 MHz / 100 MHz = 16 MVM groups.
        let m = ResourceModel::new(FpgaPart::selected());
        assert_eq!(m.mvm_groups(), 16);
    }

    #[test]
    fn eqn4_on_selected_part() {
        // Leftover after 16 MVM_PG on XC7S75-2:
        //   LUT 48000−16·495=40080 → /447 = 89
        //   FF  96000−16·1642=69728 → /1406 = 49
        //   BRAM 180−16·8=52 → /12 = 4   ← binding
        let m = ResourceModel::new(FpgaPart::selected());
        assert_eq!(m.actpro_groups(), 4);
        let a = m.allocate();
        assert_eq!(a.mvm_groups, 16);
        assert_eq!(a.actpro_groups, 4);
        assert_eq!(a.leftover.bram18, 52 - 48);
        assert_eq!(a.leftover.dsps, 140 - 64);
    }

    #[test]
    fn dsp_supply_caps_small_parts() {
        // XC7S50-2: Eqn 3 gives 2·400/100 = 8 groups; DSP cap is
        // 120/4 = 30 → Eqn 3 binds. Sanity: every part ends with
        // non-negative leftovers and nonzero groups.
        for p in &CATALOG {
            let a = ResourceModel::new(p).allocate();
            assert!(a.mvm_groups > 0, "{}", p.name);
            assert!(a.actpro_groups > 0, "{}", p.name);
        }
    }

    #[test]
    fn allocation_never_oversubscribes() {
        for p in &CATALOG {
            let a = ResourceModel::new(p).allocate();
            let lut = a.mvm_groups * MVM_PG_USAGE.luts + a.actpro_groups * ACTPRO_PG_USAGE.luts;
            let ff = a.mvm_groups * MVM_PG_USAGE.ffs + a.actpro_groups * ACTPRO_PG_USAGE.ffs;
            let bram =
                a.mvm_groups * MVM_PG_USAGE.bram18 + a.actpro_groups * ACTPRO_PG_USAGE.bram18;
            let dsp = a.mvm_groups * MVM_PG_USAGE.dsps;
            assert!(
                lut <= p.luts && ff <= p.ffs && bram <= p.bram18 && dsp <= p.dsps,
                "{}",
                p.name
            );
        }
    }
}
