//! Lowering from parsed assembly ([`crate::asm`]) to [`super::Program`].
//! (Populated alongside the `asm` module.)
