//! The assembler's object code: a **vector program** for one Matrix
//! Machine.
//!
//! Table-2 instructions carry an opcode, a processor-group range and an
//! iteration count — operand *placement* is implied by the microcode
//! counters and the global controller's data movement. The executable IR
//! therefore carries both: each [`Wave`] is one Table-2 instruction's worth
//! of work (the same opcode across a group range, one vector op per
//! processor per iteration) *plus* symbolic operand bindings ([`LaneOp`])
//! that the functional simulator uses to move the right data. The encoded
//! instruction stream for the hardware is recovered with
//! [`Program::encode`], and per-wave microcode with
//! [`super::microcode_gen`].

use crate::fixed::FixedSpec;
use crate::hw::COLUMN_LEN;
use crate::isa::{Instruction, InstructionError, Opcode, Width};
use crate::nn::lut::ActLut;
use thiserror::Error;

/// Index of a buffer in a [`Program`].
pub type BufId = usize;
/// Index of a LUT in a [`Program`].
pub type LutId = usize;

/// What role a buffer plays (drives DMA direction and launcher binding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufKind {
    /// Loaded from DDR before execution (`INPUT` code).
    Input,
    /// Loaded from DDR; mutated in place by training (`WEIGHT` code).
    Weight,
    /// Loaded from DDR (`BIAS` code).
    Bias,
    /// Loaded from DDR; training target (`TARGET` extension).
    Target,
    /// Stored back to DDR after execution (`OUTPUT` code).
    Output,
    /// Scratch, never leaves the machine.
    Temp,
    /// Host-provided constant (e.g. the learning-rate vector), loaded once.
    Const,
}

/// One declared buffer: a row-major `rows × cols` matrix of Q.F lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BufferDecl {
    /// Assembly-level name.
    pub name: String,
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Role.
    pub kind: BufKind,
    /// Initial contents (constants); `None` ⇒ zeroed / host-bound.
    pub init: Option<Vec<i16>>,
}

impl BufferDecl {
    /// Total lanes.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// True when the matrix is empty (never valid in checked programs).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A strided view over a buffer: lanes `offset + i*stride`, `i < len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct View {
    /// Buffer index.
    pub buf: BufId,
    /// First lane.
    pub offset: usize,
    /// Number of lanes.
    pub len: usize,
    /// Lane stride (1 = contiguous; `cols` walks a column of a row-major
    /// matrix).
    pub stride: usize,
}

impl View {
    /// Contiguous view.
    pub fn contiguous(buf: BufId, offset: usize, len: usize) -> View {
        View { buf, offset, len, stride: 1 }
    }

    /// Whole-buffer view.
    pub fn all(buf: BufId, len: usize) -> View {
        View::contiguous(buf, 0, len)
    }

    /// Index of the last lane touched.
    pub fn max_lane(&self) -> usize {
        self.offset + (self.len - 1) * self.stride
    }
}

/// One vector operation bound to operands (one processor × one iteration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneOp {
    /// First operand (A column).
    pub a: View,
    /// Second operand (B column); `None` for unary ops (SUM, ACT).
    pub b: Option<View>,
    /// Destination.
    pub out: View,
}

/// A wave = one Table-2 instruction's worth of parallel vector ops.
#[derive(Debug, Clone, PartialEq)]
pub struct Wave {
    /// Instruction opcode.
    pub op: Opcode,
    /// Operand vector length (lanes per [`LaneOp`] input).
    pub vec_len: usize,
    /// For `ACTIVATION_FUNCTION` waves: which LUT to have loaded.
    pub lut: Option<LutId>,
    /// Independent vector ops, distributed over processors.
    pub lanes: Vec<LaneOp>,
}

impl Wave {
    /// Iteration count when spread over `procs` processors (the Table-2
    /// iteration field: each processor loops `ceil(lanes/procs)` times).
    pub fn iterations(&self, procs: usize) -> u32 {
        (self.lanes.len().div_ceil(procs.max(1))) as u32
    }
}

/// One step of the machine-level schedule.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// DMA a buffer DDR → machine (charged by the DDR model).
    LoadDram(BufId),
    /// DMA a buffer machine → DDR.
    StoreDram(BufId),
    /// Stream a LUT into the ACTPRO groups (`ACTPRO_WRITE_ACT`).
    LoadLut(LutId),
    /// Execute a wave of vector ops.
    Wave(Wave),
}

/// A complete vector program for one Matrix Machine.
#[derive(Debug, Clone)]
pub struct Program {
    /// Program name (assembly `NET` name).
    pub name: String,
    /// Declared buffers; indices are [`BufId`]s.
    pub buffers: Vec<BufferDecl>,
    /// Activation tables; indices are [`LutId`]s.
    pub luts: Vec<ActLut>,
    /// Schedule.
    pub steps: Vec<Step>,
    /// Datapath fixed-point format.
    pub fixed: FixedSpec,
}

/// Program validation errors.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum ProgramError {
    /// A view refers to a missing buffer.
    #[error("step {0}: view references undeclared buffer {1}")]
    UnknownBuffer(usize, BufId),
    /// A view reads/writes beyond its buffer.
    #[error("step {0}: view out of bounds (buffer {1} has {2} lanes, view touches lane {3})")]
    OutOfBounds(usize, BufId, usize, usize),
    /// Operand lengths disagree.
    #[error("step {0}: operand length mismatch")]
    LengthMismatch(usize),
    /// Vector longer than a column.
    #[error("step {0}: vector length {1} exceeds the {COLUMN_LEN}-lane column")]
    TooLong(usize, usize),
    /// Binary op missing B, or unary op with B.
    #[error("step {0}: operand arity wrong for {1}")]
    Arity(usize, Opcode),
    /// Activation wave without a LUT, or unknown LUT id.
    #[error("step {0}: bad LUT reference")]
    BadLut(usize),
    /// Zero-length vector or empty wave.
    #[error("step {0}: empty wave or zero-length vector")]
    Empty(usize),
}

impl Program {
    /// New empty program.
    pub fn new(name: &str, fixed: FixedSpec) -> Program {
        Program {
            name: name.to_string(),
            buffers: Vec::new(),
            luts: Vec::new(),
            steps: Vec::new(),
            fixed,
        }
    }

    /// Declare a buffer, returning its id.
    pub fn buffer(&mut self, name: &str, rows: usize, cols: usize, kind: BufKind) -> BufId {
        self.buffers.push(BufferDecl { name: name.to_string(), rows, cols, kind, init: None });
        self.buffers.len() - 1
    }

    /// Declare a constant buffer with initial contents.
    pub fn const_buffer(&mut self, name: &str, data: Vec<i16>) -> BufId {
        let rows = data.len();
        self.buffers.push(BufferDecl {
            name: name.to_string(),
            rows,
            cols: 1,
            kind: BufKind::Const,
            init: Some(data),
        });
        self.buffers.len() - 1
    }

    /// Register a LUT, returning its id.
    pub fn lut(&mut self, lut: ActLut) -> LutId {
        self.luts.push(lut);
        self.luts.len() - 1
    }

    /// Find a buffer by name.
    pub fn buffer_named(&self, name: &str) -> Option<BufId> {
        self.buffers.iter().position(|b| b.name == name)
    }

    /// Resolve every buffer name once into a [`SymbolTable`].
    pub fn symbols(&self) -> SymbolTable {
        SymbolTable::new(self)
    }

    /// All waves in schedule order.
    pub fn waves(&self) -> impl Iterator<Item = &Wave> {
        self.steps.iter().filter_map(|s| match s {
            Step::Wave(w) => Some(w),
            _ => None,
        })
    }

    /// Total lane-operations (vector-op count × vector length) — the
    /// work metric used by benches.
    pub fn total_lane_ops(&self) -> u64 {
        self.waves().map(|w| (w.lanes.len() * w.vec_len) as u64).sum()
    }

    /// Validate every step (bounds, arity, lengths, LUT references).
    pub fn check(&self) -> Result<(), ProgramError> {
        for (si, step) in self.steps.iter().enumerate() {
            match step {
                Step::LoadDram(b) | Step::StoreDram(b) => {
                    if *b >= self.buffers.len() {
                        return Err(ProgramError::UnknownBuffer(si, *b));
                    }
                }
                Step::LoadLut(l) => {
                    if *l >= self.luts.len() {
                        return Err(ProgramError::BadLut(si));
                    }
                }
                Step::Wave(w) => self.check_wave(si, w)?,
            }
        }
        Ok(())
    }

    fn check_view(&self, si: usize, v: &View) -> Result<(), ProgramError> {
        let decl = self.buffers.get(v.buf).ok_or(ProgramError::UnknownBuffer(si, v.buf))?;
        if v.len == 0 {
            return Err(ProgramError::Empty(si));
        }
        if v.max_lane() >= decl.len() {
            return Err(ProgramError::OutOfBounds(si, v.buf, decl.len(), v.max_lane()));
        }
        Ok(())
    }

    fn check_wave(&self, si: usize, w: &Wave) -> Result<(), ProgramError> {
        if w.lanes.is_empty() || w.vec_len == 0 {
            return Err(ProgramError::Empty(si));
        }
        if w.vec_len > COLUMN_LEN {
            return Err(ProgramError::TooLong(si, w.vec_len));
        }
        let binary = matches!(
            w.op,
            Opcode::VectorDotProduct
                | Opcode::VectorAddition
                | Opcode::VectorSubtraction
                | Opcode::ElementMultiplication
        );
        if w.op == Opcode::ActivationFunction {
            match w.lut {
                Some(l) if l < self.luts.len() => {}
                _ => return Err(ProgramError::BadLut(si)),
            }
        }
        for lane in &w.lanes {
            if lane.a.len != w.vec_len {
                return Err(ProgramError::LengthMismatch(si));
            }
            self.check_view(si, &lane.a)?;
            match (&lane.b, binary) {
                (Some(b), true) => {
                    if b.len != w.vec_len {
                        return Err(ProgramError::LengthMismatch(si));
                    }
                    self.check_view(si, b)?;
                }
                (None, false) => {}
                _ => return Err(ProgramError::Arity(si, w.op)),
            }
            let out_len = match w.op {
                Opcode::VectorDotProduct | Opcode::VectorSummation => 1,
                _ => w.vec_len,
            };
            if lane.out.len != out_len {
                return Err(ProgramError::LengthMismatch(si));
            }
            self.check_view(si, &lane.out)?;
        }
        Ok(())
    }

    /// Encode the wave schedule as Table-2 instruction words for a machine
    /// with `mvm_groups`/`actpro_groups` processor groups (MVM waves spread
    /// over the MVM groups, activation waves over the ACTPRO groups).
    pub fn encode(
        &self,
        width: Width,
        mvm_groups: usize,
        actpro_groups: usize,
    ) -> Result<Vec<Instruction>, InstructionError> {
        let mut out = Vec::new();
        for w in self.waves() {
            let groups = if w.op == Opcode::ActivationFunction { actpro_groups } else { mvm_groups }
                .max(1);
            let groups = groups.min(width.max_groups() as usize);
            // Use as many groups as there are lanes to fill.
            let used = groups.min(w.lanes.len().div_ceil(crate::hw::PROCS_PER_GROUP)).max(1);
            let procs = used * crate::hw::PROCS_PER_GROUP;
            out.push(Instruction::new(
                w.op,
                0,
                (used - 1) as u16,
                w.iterations(procs),
            ));
        }
        // Terminating NOP (global controller's end-of-program marker).
        out.push(Instruction::nop());
        for i in &out {
            i.encode(width)?;
        }
        Ok(out)
    }
}

/// A program's tensor names resolved once into [`BufId`]s.
///
/// Every front door used to re-scan `Program::buffers` on each
/// stringly-typed `bind`/`read`; the table does the name → id resolution
/// once (binary search afterwards) and answers near-miss queries for
/// "unknown tensor, did you mean …" diagnostics. Built by
/// [`crate::hw::MatrixMachine`] at construction and by the session
/// compiler for [`crate::session::TensorHandle`]s.
#[derive(Debug, Clone, Default)]
pub struct SymbolTable {
    /// `(name, id)` pairs sorted by name (lowest id wins on duplicates,
    /// matching [`Program::buffer_named`]).
    entries: Vec<(String, BufId)>,
}

impl SymbolTable {
    /// Build the table for `program`.
    pub fn new(program: &Program) -> SymbolTable {
        let mut entries: Vec<(String, BufId)> = program
            .buffers
            .iter()
            .enumerate()
            .map(|(id, b)| (b.name.clone(), id))
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        entries.dedup_by(|later, earlier| later.0 == earlier.0);
        SymbolTable { entries }
    }

    /// Number of distinct tensor names.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the program declares no buffers.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resolve a name to its buffer id.
    pub fn resolve(&self, name: &str) -> Option<BufId> {
        self.entries
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| self.entries[i].1)
    }

    /// All `(name, id)` pairs in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, BufId)> {
        self.entries.iter().map(|(n, id)| (n.as_str(), *id))
    }

    /// Closest declared name to a miss (edit distance ≤ max(2, len/3)),
    /// for "did you mean …" diagnostics.
    pub fn suggest(&self, name: &str) -> Option<&str> {
        let mut best: Option<(usize, &str)> = None;
        for (n, _) in &self.entries {
            let d = levenshtein(name, n);
            if best.is_none_or(|(bd, _)| d < bd) {
                best = Some((d, n.as_str()));
            }
        }
        let (d, n) = best?;
        let limit = (name.chars().count().max(n.chars().count()) / 3).max(2);
        (d <= limit).then_some(n)
    }

    /// The ", did you mean …?" suffix for an unknown name (empty when no
    /// declared name is close enough).
    pub fn hint(&self, name: &str) -> String {
        self.suggest(name).map(|s| format!(", did you mean {s:?}?")).unwrap_or_default()
    }
}

/// Classic two-row Levenshtein distance (tensor names are short).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};

    fn sample_program() -> Program {
        let mut p = Program::new("t", FixedSpec::PAPER);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let y = p.buffer("y", 4, 1, BufKind::Output);
        let lut = p.lut(ActLut::build(ActKind::Relu, false, FixedSpec::PAPER, AddrMode::Clamp, 7));
        p.steps.push(Step::LoadDram(x));
        p.steps.push(Step::LoadLut(lut));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 4,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(x, 4),
                b: Some(View::all(x, 4)),
                out: View::all(y, 4),
            }],
        }));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: 4,
            lut: Some(lut),
            lanes: vec![LaneOp { a: View::all(y, 4), b: None, out: View::all(y, 4) }],
        }));
        p.steps.push(Step::StoreDram(y));
        p
    }

    #[test]
    fn valid_program_checks() {
        sample_program().check().unwrap();
    }

    #[test]
    fn detects_out_of_bounds() {
        let mut p = sample_program();
        if let Step::Wave(w) = &mut p.steps[2] {
            w.lanes[0].a.len = 5;
            w.vec_len = 5;
        }
        assert!(matches!(p.check(), Err(ProgramError::OutOfBounds(2, _, 4, 4))));
    }

    #[test]
    fn detects_arity_errors() {
        let mut p = sample_program();
        if let Step::Wave(w) = &mut p.steps[2] {
            w.lanes[0].b = None;
        }
        assert!(matches!(p.check(), Err(ProgramError::Arity(2, Opcode::VectorAddition))));
    }

    #[test]
    fn detects_missing_lut() {
        let mut p = sample_program();
        if let Step::Wave(w) = &mut p.steps[3] {
            w.lut = None;
        }
        assert!(matches!(p.check(), Err(ProgramError::BadLut(3))));
    }

    #[test]
    fn dot_output_must_be_single_lane() {
        let mut p = Program::new("d", FixedSpec::PAPER);
        let a = p.buffer("a", 8, 1, BufKind::Input);
        let o = p.buffer("o", 8, 1, BufKind::Output);
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 8,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(a, 8),
                b: Some(View::all(a, 8)),
                out: View::all(o, 8), // wrong: dot yields 1 lane
            }],
        }));
        assert_eq!(p.check(), Err(ProgramError::LengthMismatch(0)));
    }

    #[test]
    fn strided_views_bounds() {
        // column of a 4x3 row-major matrix: offset=2, stride=3, len=4 → max
        // lane 2+3*3=11 < 12 OK
        let v = View { buf: 0, offset: 2, len: 4, stride: 3 };
        assert_eq!(v.max_lane(), 11);
    }

    #[test]
    fn encoding_produces_instruction_per_wave_plus_nop() {
        let p = sample_program();
        let instrs = p.encode(Width::W32, 4, 2).unwrap();
        assert_eq!(instrs.len(), 3); // 2 waves + NOP
        assert_eq!(instrs[0].op, Opcode::VectorAddition);
        assert_eq!(instrs[0].iterations, 1);
        assert_eq!(instrs[2].op, Opcode::Nop);
    }

    #[test]
    fn w48_encoding_covers_group_counts_beyond_128() {
        // A hypothetical 200-group machine exceeds the 32-bit format's
        // 128-group limit (sec 3.2) but fits the 48-bit one.
        let mut p = Program::new("wide", FixedSpec::PAPER);
        let a = p.buffer("a", 4096, 4, BufKind::Input);
        let o = p.buffer("o", 4096, 1, BufKind::Output);
        let lanes: Vec<LaneOp> = (0..4096)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * 4, 4),
                b: Some(View::contiguous(a, i * 4, 4)),
                out: View::contiguous(o, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 4,
            lut: None,
            lanes,
        }));
        let instrs = p.encode(Width::W48, 200, 4).unwrap();
        assert!(instrs[0].proc_end >= 128, "should use >128 groups: {}", instrs[0]);
        assert!(instrs[0].encode(Width::W48).is_ok());
        assert!(instrs[0].encode(Width::W32).is_err(), "W32 cannot hold the range");
        // the 32-bit encoding clamps the machine to its 128-group limit
        let instrs32 = p.encode(Width::W32, 200, 4).unwrap();
        assert!(instrs32[0].proc_end < 128, "{}", instrs32[0]);
    }

    #[test]
    fn iteration_counts_split_over_processors() {
        let w = Wave {
            op: Opcode::VectorAddition,
            vec_len: 4,
            lut: None,
            lanes: vec![
                LaneOp { a: View::all(0, 4), b: Some(View::all(0, 4)), out: View::all(1, 4) };
                33
            ],
        };
        assert_eq!(w.iterations(16), 3); // ceil(33/16)
        assert_eq!(w.iterations(64), 1);
    }

    #[test]
    fn total_lane_ops_counts_work() {
        let p = sample_program();
        assert_eq!(p.total_lane_ops(), 8); // two 4-lane waves
    }

    #[test]
    fn symbol_table_resolves_and_suggests() {
        let mut p = Program::new("s", FixedSpec::PAPER);
        let w0 = p.buffer("weights0", 4, 4, BufKind::Weight);
        let b0 = p.buffer("bias0", 4, 1, BufKind::Bias);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let t = p.symbols();
        assert_eq!(t.len(), 3);
        assert_eq!(t.resolve("weights0"), Some(w0));
        assert_eq!(t.resolve("bias0"), Some(b0));
        assert_eq!(t.resolve("x"), Some(x));
        assert_eq!(t.resolve("nope_at_all"), None);
        // close miss suggests, far miss does not
        assert_eq!(t.suggest("weighs0"), Some("weights0"));
        assert!(t.hint("weigths0").contains("did you mean"));
        assert_eq!(t.suggest("completely_unrelated"), None);
        assert_eq!(t.hint("completely_unrelated"), "");
    }

    #[test]
    fn symbol_table_duplicate_names_keep_first_id() {
        let mut p = Program::new("d", FixedSpec::PAPER);
        let first = p.buffer("t", 2, 1, BufKind::Temp);
        p.buffer("t", 4, 1, BufKind::Temp);
        assert_eq!(p.symbols().resolve("t"), Some(first));
        assert_eq!(p.symbols().resolve("t"), p.buffer_named("t"));
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("w0", "w1"), 1);
    }
}
