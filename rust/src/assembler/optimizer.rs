//! Optimisation passes over vector programs — the "high level optimizing
//! assembler" aspect of §3: the Matrix Assembler "optimizes the assembly
//! codes and neural network processors".
//!
//! Passes (all semantics-preserving; each returns what it changed):
//!
//! 1. [`dedup_lut_loads`] — drop `LoadLut` steps that are redundant
//!    (already-loaded table, or superseded before any activation wave).
//! 2. [`fuse_waves`] — merge adjacent waves with identical opcode /
//!    vector length / LUT when no data dependency separates them; fewer,
//!    wider waves fill more processor groups per instruction.
//! 3. [`eliminate_dead_waves`] — remove waves whose results are never
//!    observed (not read later, not persistent state, not stored).
//!
//! `optimize` runs all passes to a fixed point.

use super::program::{BufKind, Program, Step, View, Wave};
use std::collections::HashSet;

/// What the optimiser did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Redundant LUT loads removed.
    pub lut_loads_removed: usize,
    /// Wave pairs merged.
    pub waves_fused: usize,
    /// Dead waves removed.
    pub waves_removed: usize,
}

impl OptReport {
    /// Total changes.
    pub fn total(&self) -> usize {
        self.lut_loads_removed + self.waves_fused + self.waves_removed
    }
}

fn wave_reads(w: &Wave) -> impl Iterator<Item = &View> {
    w.lanes.iter().flat_map(|l| std::iter::once(&l.a).chain(l.b.as_ref()))
}

fn wave_writes(w: &Wave) -> impl Iterator<Item = &View> {
    w.lanes.iter().map(|l| &l.out)
}

/// Remove `LoadLut` steps that re-load the current table or are
/// superseded before any activation wave uses them. Runs its two passes
/// to a fixed point (removing a superseded load can make a later load
/// redundant).
pub fn dedup_lut_loads(p: &mut Program) -> usize {
    let mut total = 0;
    loop {
        let n = dedup_lut_loads_once(p);
        total += n;
        if n == 0 {
            return total;
        }
    }
}

fn dedup_lut_loads_once(p: &mut Program) -> usize {
    let mut removed = 0;
    // Pass A: drop re-loads of the already-current LUT.
    let mut current: Option<usize> = None;
    let mut keep = Vec::with_capacity(p.steps.len());
    for step in p.steps.drain(..) {
        match step {
            Step::LoadLut(l) if current == Some(l) => removed += 1,
            Step::LoadLut(l) => {
                current = Some(l);
                keep.push(Step::LoadLut(l));
            }
            other => keep.push(other),
        }
    }
    // Pass B (backwards): drop loads with no ACT wave before the next load.
    let mut used_since_next_load = false;
    let mut keep_flags = vec![true; keep.len()];
    for (i, step) in keep.iter().enumerate().rev() {
        match step {
            Step::Wave(w) if w.lut.is_some() => used_since_next_load = true,
            Step::LoadLut(_) => {
                if !used_since_next_load {
                    keep_flags[i] = false;
                    removed += 1;
                }
                used_since_next_load = false;
            }
            _ => {}
        }
    }
    p.steps = keep
        .into_iter()
        .zip(keep_flags)
        .filter_map(|(s, k)| k.then_some(s))
        .collect();
    removed
}

/// Merge adjacent compatible waves (same op, vec_len, lut) when the
/// second reads nothing the first writes and writes nothing the first
/// touches.
pub fn fuse_waves(p: &mut Program) -> usize {
    let mut fused = 0;
    let mut out: Vec<Step> = Vec::with_capacity(p.steps.len());
    for step in p.steps.drain(..) {
        if let (Some(Step::Wave(prev)), Step::Wave(cur)) = (out.last_mut(), &step) {
            let compatible =
                prev.op == cur.op && prev.vec_len == cur.vec_len && prev.lut == cur.lut;
            if compatible && independent(prev, cur) {
                prev.lanes.extend(cur.lanes.iter().copied());
                fused += 1;
                continue;
            }
        }
        out.push(step);
    }
    p.steps = out;
    fused
}

/// Conservative independence: no buffer written by `a` is touched by `b`,
/// and no buffer written by `b` is read by `a`.
fn independent(a: &Wave, b: &Wave) -> bool {
    let a_writes: HashSet<usize> = wave_writes(a).map(|v| v.buf).collect();
    let b_writes: HashSet<usize> = wave_writes(b).map(|v| v.buf).collect();
    let b_touches: HashSet<usize> =
        wave_reads(b).map(|v| v.buf).chain(b_writes.iter().copied()).collect();
    if a_writes.intersection(&b_touches).next().is_some() {
        return false;
    }
    wave_reads(a).all(|v| !b_writes.contains(&v.buf))
}

/// Remove waves whose outputs are never observed: not persistent
/// (Weight/Bias/Output), not stored to DRAM, and not read by any later
/// step.
pub fn eliminate_dead_waves(p: &mut Program) -> usize {
    let persistent: HashSet<usize> = p
        .buffers
        .iter()
        .enumerate()
        .filter(|(_, b)| matches!(b.kind, BufKind::Weight | BufKind::Bias | BufKind::Output))
        .map(|(i, _)| i)
        .collect();
    let mut live: HashSet<usize> = persistent;
    let mut removed = 0;
    let mut kept_rev: Vec<Step> = Vec::with_capacity(p.steps.len());
    for step in p.steps.drain(..).rev() {
        match &step {
            Step::StoreDram(b) => {
                live.insert(*b);
                kept_rev.push(step);
            }
            Step::Wave(w) => {
                let observed = wave_writes(w).any(|v| live.contains(&v.buf));
                if observed {
                    for v in wave_reads(w) {
                        live.insert(v.buf);
                    }
                    kept_rev.push(step);
                } else {
                    removed += 1;
                }
            }
            _ => kept_rev.push(step),
        }
    }
    kept_rev.reverse();
    p.steps = kept_rev;
    removed
}

/// Run all passes to a fixed point.
pub fn optimize(p: &mut Program) -> OptReport {
    let mut report = OptReport::default();
    loop {
        let mut changed = 0;
        let r = dedup_lut_loads(p);
        report.lut_loads_removed += r;
        changed += r;
        let r = fuse_waves(p);
        report.waves_fused += r;
        changed += r;
        let r = eliminate_dead_waves(p);
        report.waves_removed += r;
        changed += r;
        if changed == 0 {
            return report;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp, Program};
    use crate::fixed::FixedSpec;
    use crate::hw::{FpgaDevice, MatrixMachine};
    use crate::isa::Opcode;
    use crate::nn::graph::lower_mlp_train as lower_train_step;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};
    use crate::nn::mlp::{LutParams, MlpSpec};
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    fn add_wave(a: usize, b: usize, o: usize, n: usize) -> Step {
        Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: n,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(a, n),
                b: Some(View::all(b, n)),
                out: View::all(o, n),
            }],
        })
    }

    #[test]
    fn dedups_redundant_lut_loads() {
        let mut p = Program::new("t", S);
        let x = p.buffer("x", 4, 1, BufKind::Output);
        let l0 = p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        let l1 = p.lut(ActLut::build(ActKind::Relu, true, S, AddrMode::Clamp, 7));
        let act = |l: usize| {
            Step::Wave(Wave {
                op: Opcode::ActivationFunction,
                vec_len: 4,
                lut: Some(l),
                lanes: vec![LaneOp { a: View::all(x, 4), b: None, out: View::all(x, 4) }],
            })
        };
        p.steps = vec![
            Step::LoadLut(l0),
            Step::LoadLut(l0), // duplicate
            act(l0),
            Step::LoadLut(l1), // superseded with no use
            Step::LoadLut(l0),
            act(l0),
        ];
        let n = dedup_lut_loads(&mut p);
        assert_eq!(n, 3); // dup + superseded + (l0 reload is current again)
        let loads: Vec<_> =
            p.steps.iter().filter(|s| matches!(s, Step::LoadLut(_))).collect();
        assert_eq!(loads.len(), 1);
        p.check().unwrap();
    }

    #[test]
    fn fuses_independent_adjacent_waves() {
        let mut p = Program::new("t", S);
        let a = p.buffer("a", 8, 1, BufKind::Input);
        let o1 = p.buffer("o1", 8, 1, BufKind::Output);
        let o2 = p.buffer("o2", 8, 1, BufKind::Output);
        p.steps = vec![add_wave(a, a, o1, 8), add_wave(a, a, o2, 8)];
        assert_eq!(fuse_waves(&mut p), 1);
        assert_eq!(p.waves().count(), 1);
        assert_eq!(p.waves().next().unwrap().lanes.len(), 2);
        p.check().unwrap();
    }

    #[test]
    fn does_not_fuse_dependent_waves() {
        let mut p = Program::new("t", S);
        let a = p.buffer("a", 8, 1, BufKind::Input);
        let o1 = p.buffer("o1", 8, 1, BufKind::Output);
        let o2 = p.buffer("o2", 8, 1, BufKind::Output);
        // second wave reads o1 written by the first
        p.steps = vec![add_wave(a, a, o1, 8), add_wave(o1, a, o2, 8)];
        assert_eq!(fuse_waves(&mut p), 0);
        assert_eq!(p.waves().count(), 2);
    }

    #[test]
    fn removes_dead_waves() {
        let mut p = Program::new("t", S);
        let a = p.buffer("a", 8, 1, BufKind::Input);
        let t1 = p.buffer("t1", 8, 1, BufKind::Temp);
        let t2 = p.buffer("t2", 8, 1, BufKind::Temp);
        let o = p.buffer("o", 8, 1, BufKind::Output);
        p.steps = vec![
            add_wave(a, a, t1, 8), // live: read below
            add_wave(a, a, t2, 8), // dead: t2 never read
            add_wave(t1, a, o, 8),
        ];
        assert_eq!(eliminate_dead_waves(&mut p), 1);
        assert_eq!(p.waves().count(), 2);
        p.check().unwrap();
    }

    #[test]
    fn optimize_preserves_training_semantics() {
        // Optimised and unoptimised train programs must produce identical
        // weights and outputs.
        let fixed = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            "opt",
            &[4, 8, 2],
            ActKind::Relu,
            ActKind::Identity,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let h = lower_train_step(&spec, 8, 1.0 / 256.0).unwrap();
        let mut opt_prog = h.program.clone();
        // The emitted train program is already fairly tight; whatever the
        // optimiser does (possibly nothing) must preserve semantics.
        let _report = optimize(&mut opt_prog);
        opt_prog.check().unwrap();

        let mut r = Rng::new(3);
        let q = |n: usize, r: &mut Rng| -> Vec<i16> {
            (0..n).map(|_| fixed.from_f64(r.gen_f64() - 0.5)).collect()
        };
        let binds: Vec<(&str, Vec<i16>)> = vec![
            ("x", q(8 * 4, &mut r)),
            ("y", q(8 * 2, &mut r)),
            ("w0", q(4 * 8, &mut r)),
            ("b0", q(8, &mut r)),
            ("w1", q(8 * 2, &mut r)),
            ("b1", q(2, &mut r)),
        ];
        let run = |prog: &Program| -> (Vec<i16>, Vec<i16>) {
            let mut m = MatrixMachine::new(FpgaDevice::selected(), prog).unwrap();
            for (n, d) in &binds {
                m.bind_named(n, d).unwrap();
            }
            m.execute();
            (m.read_named("w0").unwrap().to_vec(), m.read_named("o1").unwrap().to_vec())
        };
        assert_eq!(run(&h.program), run(&opt_prog));
    }

    #[test]
    fn optimize_reduces_cycles() {
        let fixed = FixedSpec::q(10).saturating();
        let spec = MlpSpec::from_dims(
            "opt2",
            &[8, 16, 4],
            ActKind::Relu,
            ActKind::Relu,
            fixed,
            LutParams::training(fixed),
        )
        .unwrap();
        let h = lower_train_step(&spec, 16, 1.0 / 256.0).unwrap();
        let mut opt_prog = h.program.clone();
        optimize(&mut opt_prog);
        let cycles = |prog: &Program| {
            let mut m = MatrixMachine::new(FpgaDevice::selected(), prog).unwrap();
            m.execute().cycles
        };
        assert!(cycles(&opt_prog) <= cycles(&h.program));
    }
}
