//! Signal tracing + ASCII waveform rendering.
//!
//! The structural simulators record named signals per cycle; the renderer
//! produces the textual equivalents of the paper's timing diagrams (Fig 7
//! MVM write, Fig 8 MVM vector addition, Fig 10 ACTPRO ReLU), regenerated
//! by `examples/timing_traces.rs`.

use std::collections::BTreeMap;

/// A recorded trace: signal name → (cycle → value).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    signals: Vec<String>,
    data: BTreeMap<String, BTreeMap<u64, String>>,
    max_cycle: u64,
}

impl Trace {
    /// Empty trace.
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Record `signal = value` at `cycle`. First-recorded order of signals
    /// is preserved in the rendering.
    pub fn record<V: ToString>(&mut self, cycle: u64, signal: &str, value: V) {
        if !self.data.contains_key(signal) {
            self.signals.push(signal.to_string());
        }
        self.data.entry(signal.to_string()).or_default().insert(cycle, value.to_string());
        self.max_cycle = self.max_cycle.max(cycle);
    }

    /// Last recorded cycle.
    pub fn max_cycle(&self) -> u64 {
        self.max_cycle
    }

    /// Value of a signal at a cycle, if recorded.
    pub fn get(&self, cycle: u64, signal: &str) -> Option<&str> {
        self.data.get(signal)?.get(&cycle).map(|s| s.as_str())
    }

    /// The cycle at which `signal` first took value `value`, if ever.
    pub fn first_cycle_of(&self, signal: &str, value: &str) -> Option<u64> {
        self.data.get(signal)?.iter().find(|(_, v)| v.as_str() == value).map(|(c, _)| *c)
    }

    /// Render cycles `[from, to]` as an ASCII waveform table. Values repeat
    /// until changed; unchanged cycles show `.` to keep rows readable.
    pub fn render(&self, from: u64, to: u64) -> String {
        let width = self
            .signals
            .iter()
            .flat_map(|s| {
                self.data[s]
                    .iter()
                    .filter(|(c, _)| **c >= from && **c <= to)
                    .map(|(_, v)| v.len())
            })
            .max()
            .unwrap_or(1)
            .max((to.to_string()).len())
            .max(3);
        let name_w = self.signals.iter().map(|s| s.len()).max().unwrap_or(5).max(5);
        let mut out = String::new();
        out.push_str(&format!("{:<name_w$} |", "cycle"));
        for c in from..=to {
            out.push_str(&format!(" {c:>width$}"));
        }
        out.push('\n');
        out.push_str(&format!(
            "{:-<name_w$}-+{}\n",
            "",
            "-".repeat(((width + 1) * (to - from + 1) as usize).max(1))
        ));
        for sig in &self.signals {
            out.push_str(&format!("{sig:<name_w$} |"));
            let series = &self.data[sig];
            let mut last: Option<&str> = None;
            for c in from..=to {
                let cell: &str = match series.get(&c) {
                    Some(v) if last != Some(v.as_str()) => {
                        last = Some(v);
                        v
                    }
                    Some(_) => ".",
                    None => {
                        if last.is_some() {
                            "."
                        } else {
                            " "
                        }
                    }
                };
                out.push_str(&format!(" {cell:>width$}"));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_queries() {
        let mut t = Trace::new();
        t.record(1, "state", "SETUP");
        t.record(2, "state", "RUN");
        t.record(2, "addr", 0);
        t.record(3, "addr", 1);
        assert_eq!(t.get(2, "state"), Some("RUN"));
        assert_eq!(t.first_cycle_of("state", "RUN"), Some(2));
        assert_eq!(t.max_cycle(), 3);
    }

    #[test]
    fn render_dedupes_repeats() {
        let mut t = Trace::new();
        t.record(1, "s", "A");
        t.record(2, "s", "A");
        t.record(3, "s", "B");
        let r = t.render(1, 3);
        assert!(r.contains('A'), "{r}");
        // second A collapsed into '.'
        let line = r.lines().find(|l| l.starts_with("s")).unwrap();
        assert_eq!(line.matches('A').count(), 1, "{r}");
        assert!(line.contains('.'), "{r}");
        assert!(line.contains('B'), "{r}");
    }

    #[test]
    fn signal_order_is_first_recorded() {
        let mut t = Trace::new();
        t.record(1, "zzz", 1);
        t.record(1, "aaa", 2);
        let r = t.render(1, 1);
        let zi = r.find("zzz").unwrap();
        let ai = r.find("aaa").unwrap();
        assert!(zi < ai);
    }
}
