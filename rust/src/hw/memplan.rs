//! Static lane-arena memory planner (DESIGN.md §Memory planner).
//!
//! [`super::plan::ExecPlan`]'s default layout packs every declared buffer
//! back-to-back and keeps it alive for the whole program — simple, but it
//! means a deep net's temporaries all coexist even though most are dead
//! for most of the schedule. This module computes each buffer's live
//! interval over the step schedule and lays temporaries out so buffers
//! whose intervals do not overlap **share arena lanes**, shrinking peak
//! BRAM demand per net. It also turns "does this net fit the board?" into
//! a typed answer: [`MemPlan::fit`] returns [`PlanError::ExceedsBoard`]
//! (with a suggested schedule split point) instead of silently allocating
//! past the part's BRAM budget.
//!
//! ### Liveness model
//!
//! One interval per buffer over the program's step indices, conservative
//! in both directions:
//!
//! * `Wave` lane views: `a`/`b` are uses; `out` is a definition **and** a
//!   use (a strided write may touch only part of the buffer).
//! * `LoadDram` fully defines its buffer; `StoreDram` uses it.
//! * The interval is `[first reference, last reference]` — any reference,
//!   def or use, extends it.
//!
//! ### Lane-reuse rule
//!
//! Only `BufKind::Temp` buffers are reuse candidates, and only when their
//! *first* reference is a **full definition** (a `LoadDram`, or a wave
//! step whose `out` views cover every lane without reading the buffer in
//! the same step). Everything else is *pinned* — laid out packed, alive
//! for the whole program:
//!
//! * Host-visible kinds (`Input`/`Weight`/`Bias`/`Target`/`Output`/
//!   `Const`) may be written or read by the host before/after execution,
//!   so their lanes must survive end-to-end.
//! * A temporary first referenced by a *read* (or a partial write)
//!   observes the arena's zero-init; reusing its lanes would leak another
//!   buffer's stale values into that read and break bit-exactness.
//!
//! Eligible temporaries are placed first-fit above the pinned region,
//! ordered by interval start; two temporaries may overlap in lanes only
//! if their live intervals are disjoint. Because a shared-lane pair is
//! never live at the same step, no wave can read one while the other
//! holds the lanes — so planned execution is bit-identical to packed
//! execution, and every cycle charge (`wave_cycles`, DMA bytes, LUT
//! streams) is address-independent, so `RunStats` is too. The `memplan`
//! fuzz family ([`crate::testkit::diff::Differ::run_memplan`]) enforces
//! both properties on generated nets.

use super::BRAM_DEPTH;
use crate::assembler::program::{BufKind, Program, Step};
use crate::perf::catalog::FpgaPart;
use std::collections::{HashMap, HashSet};
use thiserror::Error;

/// Typed board-fit failure from [`MemPlan::fit`] / [`MemPlan::require_fit`].
#[derive(Debug, Clone, PartialEq, Eq, Error)]
pub enum PlanError {
    /// Even with lane reuse, the net's peak lane demand exceeds the
    /// board's BRAM capacity. `split_step` is the first step index whose
    /// live demand exceeds the capacity (or the peak-demand step when
    /// only fragmentation pushed the layout over) — splitting the
    /// schedule into separate programs before that step is the smallest
    /// cut that can help.
    #[error(
        "net `{net}`: peak lane demand {demand} exceeds board {board} \
         capacity of {capacity} lanes; suggest splitting the schedule \
         before step {split_step}"
    )]
    ExceedsBoard {
        /// Program name.
        net: String,
        /// Board part name.
        board: String,
        /// Planned peak lanes (after reuse).
        demand: usize,
        /// Board capacity in lanes (`bram18 × BRAM_DEPTH`).
        capacity: usize,
        /// Suggested schedule split point (step index).
        split_step: usize,
    },
}

/// Live interval of one buffer over the program's step schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// First step index referencing the buffer (def or use).
    pub first: usize,
    /// Last step index referencing the buffer.
    pub last: usize,
    /// Pinned buffers keep a packed, whole-program placement (see the
    /// module docs for which buffers pin).
    pub pinned: bool,
}

impl Interval {
    /// Do two intervals overlap in time? Pinned intervals span the whole
    /// program, so they overlap everything.
    pub fn overlaps(&self, other: &Interval) -> bool {
        self.pinned || other.pinned || (self.first <= other.last && other.first <= self.last)
    }

    /// Does the interval cover step `s`?
    pub fn covers(&self, s: usize) -> bool {
        self.pinned || (self.first <= s && s <= self.last)
    }
}

/// A computed arena layout for one [`Program`]: per-buffer live
/// intervals, a lane placement that reuses lanes across disjoint
/// intervals, and the resulting peak-lane / peak-BRAM report.
#[derive(Debug, Clone)]
pub struct MemPlan {
    name: String,
    /// `(arena base, lane count)` per program buffer — the same shape
    /// [`super::plan::ExecPlan`] uses for its arena layout.
    bufs: Vec<(usize, usize)>,
    intervals: Vec<Interval>,
    pinned_len: usize,
    packed_len: usize,
    arena_len: usize,
    n_steps: usize,
}

impl MemPlan {
    /// Analyze liveness and lay the program's buffers out with lane
    /// reuse. Infallible — board-fit is a separate, explicit check
    /// ([`MemPlan::require_fit`] / [`MemPlan::fit`]).
    pub fn build(program: &Program) -> MemPlan {
        let n = program.buffers.len();
        let n_steps = program.steps.len();
        let mut first = vec![usize::MAX; n];
        let mut last = vec![0usize; n];
        // Was the buffer's first reference a full definition?
        let mut full_def = vec![false; n];
        for (s, step) in program.steps.iter().enumerate() {
            match step {
                Step::LoadDram(b) => {
                    if first[*b] == usize::MAX {
                        first[*b] = s;
                        full_def[*b] = true;
                    }
                    last[*b] = s;
                }
                Step::StoreDram(b) => {
                    if first[*b] == usize::MAX {
                        first[*b] = s;
                    }
                    last[*b] = s;
                }
                Step::LoadLut(_) => {}
                Step::Wave(w) => {
                    let mut reads: HashSet<usize> = HashSet::new();
                    let mut writes: HashMap<usize, Vec<bool>> = HashMap::new();
                    for l in &w.lanes {
                        reads.insert(l.a.buf);
                        if let Some(b) = &l.b {
                            reads.insert(b.buf);
                        }
                        let cov = writes
                            .entry(l.out.buf)
                            .or_insert_with(|| vec![false; program.buffers[l.out.buf].len()]);
                        for i in 0..l.out.len {
                            let lane = l.out.offset + i * l.out.stride;
                            if lane < cov.len() {
                                cov[lane] = true;
                            }
                        }
                    }
                    for (&b, cov) in &writes {
                        if first[b] == usize::MAX {
                            first[b] = s;
                            full_def[b] = !reads.contains(&b) && cov.iter().all(|&c| c);
                        }
                        last[b] = s;
                    }
                    for &b in &reads {
                        if first[b] == usize::MAX {
                            first[b] = s; // read-before-def: stays pinned
                        }
                        last[b] = s;
                    }
                }
            }
        }
        // Classify: reusable temporaries vs pinned everything-else.
        let last_step = n_steps.saturating_sub(1);
        let mut reusable = vec![false; n];
        let mut intervals = Vec::with_capacity(n);
        for (i, decl) in program.buffers.iter().enumerate() {
            let referenced = first[i] != usize::MAX;
            reusable[i] = decl.kind == BufKind::Temp && referenced && full_def[i];
            intervals.push(if reusable[i] {
                Interval { first: first[i], last: last[i], pinned: false }
            } else {
                Interval { first: 0, last: last_step, pinned: true }
            });
        }
        // Pinned region: packed in declaration order, exactly like the
        // default ExecPlan layout restricted to the pinned set.
        let mut bufs = vec![(0usize, 0usize); n];
        let mut pinned_len = 0usize;
        for (i, decl) in program.buffers.iter().enumerate() {
            bufs[i].1 = decl.len();
            if !reusable[i] {
                bufs[i].0 = pinned_len;
                pinned_len += decl.len();
            }
        }
        // Reusable temporaries: first-fit above the pinned region,
        // ordered by interval start; a placed temp only blocks lanes for
        // candidates whose intervals overlap it.
        let mut order: Vec<usize> = (0..n).filter(|&i| reusable[i]).collect();
        order.sort_by_key(|&i| (intervals[i].first, i));
        let mut placed: Vec<usize> = Vec::with_capacity(order.len());
        for &t in &order {
            let len = bufs[t].1;
            let mut conflicts: Vec<(usize, usize)> = placed
                .iter()
                .filter(|&&o| bufs[o].1 > 0 && intervals[o].overlaps(&intervals[t]))
                .map(|&o| bufs[o])
                .collect();
            conflicts.sort_unstable();
            let mut base = pinned_len;
            for (cb, cl) in conflicts {
                if len == 0 || base + len <= cb {
                    break; // fits in the hole before this conflict
                }
                base = base.max(cb + cl);
            }
            bufs[t].0 = base;
            placed.push(t);
        }
        let arena_len = bufs.iter().map(|&(b, l)| b + l).max().unwrap_or(0);
        let packed_len = program.buffers.iter().map(|b| b.len()).sum();
        MemPlan {
            name: program.name.clone(),
            bufs,
            intervals,
            pinned_len,
            packed_len,
            arena_len,
            n_steps,
        }
    }

    /// Build and check against a catalog part in one call.
    pub fn fit(program: &Program, part: &FpgaPart) -> Result<MemPlan, PlanError> {
        let plan = MemPlan::build(program);
        plan.require_fit(part.name, MemPlan::board_lanes(part))?;
        Ok(plan)
    }

    /// Lane capacity of a board: every RAMB18 holds [`BRAM_DEPTH`]
    /// 16-bit lanes.
    pub fn board_lanes(part: &FpgaPart) -> usize {
        part.bram18 as usize * BRAM_DEPTH
    }

    /// Board-fit contract: `Err(ExceedsBoard)` **iff** the planned peak
    /// lane demand exceeds `capacity_lanes`.
    pub fn require_fit(&self, board: &str, capacity_lanes: usize) -> Result<(), PlanError> {
        if self.arena_len > capacity_lanes {
            return Err(PlanError::ExceedsBoard {
                net: self.name.clone(),
                board: board.to_string(),
                demand: self.arena_len,
                capacity: capacity_lanes,
                split_step: self.split_point(capacity_lanes),
            });
        }
        Ok(())
    }

    /// Program name the plan was built from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// `(arena base, lane count)` per buffer — feed to the planned
    /// [`super::plan::ExecPlan`] constructors.
    pub fn layout(&self) -> &[(usize, usize)] {
        &self.bufs
    }

    /// Per-buffer live intervals (index = buffer id).
    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    /// Peak lanes of the planned layout (= its arena length).
    pub fn peak_lanes(&self) -> usize {
        self.arena_len
    }

    /// Lanes of the default packed layout (sum of all buffer lengths).
    pub fn packed_lanes(&self) -> usize {
        self.packed_len
    }

    /// Lanes held by pinned buffers alone.
    pub fn pinned_lanes(&self) -> usize {
        self.pinned_len
    }

    /// Lanes saved by reuse versus the packed layout.
    pub fn saved_lanes(&self) -> usize {
        self.packed_len - self.arena_len
    }

    /// RAMB18 blocks the planned layout occupies.
    pub fn peak_bram(&self) -> usize {
        self.arena_len.div_ceil(BRAM_DEPTH)
    }

    /// RAMB18 blocks the packed layout occupies.
    pub fn packed_bram(&self) -> usize {
        self.packed_len.div_ceil(BRAM_DEPTH)
    }

    /// Number of schedule steps analyzed.
    pub fn steps(&self) -> usize {
        self.n_steps
    }

    /// Live lane demand at step `s`: pinned lanes plus every reusable
    /// temporary whose interval covers `s`. A lower bound on
    /// [`MemPlan::peak_lanes`] (first-fit may fragment).
    pub fn demand_at(&self, s: usize) -> usize {
        let mut d = self.pinned_len;
        for (iv, &(_, len)) in self.intervals.iter().zip(&self.bufs) {
            if !iv.pinned && iv.covers(s) {
                d += len;
            }
        }
        d
    }

    /// Suggested schedule split point for a board of `capacity` lanes:
    /// the first step whose live demand exceeds the capacity, or the
    /// peak-demand step when only layout fragmentation overflows.
    pub fn split_point(&self, capacity: usize) -> usize {
        let mut worst = (0usize, 0usize); // (demand, step)
        for s in 0..self.n_steps {
            let d = self.demand_at(s);
            if d > capacity {
                return s;
            }
            if d > worst.0 {
                worst = (d, s);
            }
        }
        worst.1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{LaneOp, View, Wave};
    use crate::fixed::FixedSpec;
    use crate::isa::Opcode;

    const S: FixedSpec = FixedSpec::PAPER;

    fn wave(op: Opcode, a: View, b: Option<View>, out: View) -> Step {
        Step::Wave(Wave { op, vec_len: a.len, lut: None, lanes: vec![LaneOp { a, b, out }] })
    }

    /// x → t1 → o, then x → t2 → o: t1 and t2 have disjoint intervals.
    fn reuse_program() -> (Program, usize, usize) {
        let mut p = Program::new("reuse", S);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let t1 = p.buffer("t1", 4, 1, BufKind::Temp);
        let t2 = p.buffer("t2", 4, 1, BufKind::Temp);
        let o = p.buffer("o", 4, 1, BufKind::Output);
        let add = Opcode::VectorAddition;
        p.steps.push(wave(add, View::all(x, 4), Some(View::all(x, 4)), View::all(t1, 4)));
        p.steps.push(wave(add, View::all(t1, 4), Some(View::all(t1, 4)), View::all(o, 4)));
        p.steps.push(wave(add, View::all(x, 4), Some(View::all(x, 4)), View::all(t2, 4)));
        p.steps.push(wave(add, View::all(t2, 4), Some(View::all(o, 4)), View::all(o, 4)));
        p.check().unwrap();
        (p, t1, t2)
    }

    #[test]
    fn disjoint_temps_share_lanes() {
        let (p, t1, t2) = reuse_program();
        let mp = MemPlan::build(&p);
        assert_eq!(mp.packed_lanes(), 16);
        assert_eq!(mp.peak_lanes(), 12, "t2 reuses t1's lanes");
        assert_eq!(mp.layout()[t1], mp.layout()[t2]);
        assert_eq!(mp.saved_lanes(), 4);
        assert!(!mp.intervals()[t1].overlaps(&mp.intervals()[t2]));
    }

    #[test]
    fn overlapping_temps_keep_disjoint_lanes() {
        // t1 live [0,3] and t2 live [1,2] overlap → no sharing.
        let mut p = Program::new("overlap", S);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        let t1 = p.buffer("t1", 4, 1, BufKind::Temp);
        let t2 = p.buffer("t2", 4, 1, BufKind::Temp);
        let o = p.buffer("o", 4, 1, BufKind::Output);
        let add = Opcode::VectorAddition;
        p.steps.push(wave(add, View::all(x, 4), Some(View::all(x, 4)), View::all(t1, 4)));
        p.steps.push(wave(add, View::all(x, 4), Some(View::all(x, 4)), View::all(t2, 4)));
        p.steps.push(wave(add, View::all(t2, 4), Some(View::all(t2, 4)), View::all(o, 4)));
        p.steps.push(wave(add, View::all(t1, 4), Some(View::all(o, 4)), View::all(o, 4)));
        p.check().unwrap();
        let mp = MemPlan::build(&p);
        assert_eq!(mp.peak_lanes(), mp.packed_lanes());
        let (b1, l1) = mp.layout()[t1];
        let (b2, _) = mp.layout()[t2];
        assert!(b1 + l1 <= b2 || b2 + 4 <= b1, "overlapping temps must not alias");
    }

    #[test]
    fn read_before_def_temp_stays_pinned() {
        // t's first reference is a read → its zero-init is observable.
        let mut p = Program::new("rbd", S);
        let t = p.buffer("t", 4, 1, BufKind::Temp);
        let o = p.buffer("o", 4, 1, BufKind::Output);
        p.steps.push(wave(
            Opcode::VectorAddition,
            View::all(t, 4),
            Some(View::all(t, 4)),
            View::all(o, 4),
        ));
        p.check().unwrap();
        let mp = MemPlan::build(&p);
        assert!(mp.intervals()[t].pinned);
        assert_eq!(mp.peak_lanes(), mp.packed_lanes());
    }

    #[test]
    fn partial_first_def_temp_stays_pinned() {
        // First write covers only 2 of t's 4 lanes → pinned.
        let mut p = Program::new("partial", S);
        let x = p.buffer("x", 2, 1, BufKind::Input);
        let t = p.buffer("t", 4, 1, BufKind::Temp);
        let o = p.buffer("o", 4, 1, BufKind::Output);
        let add = Opcode::VectorAddition;
        p.steps.push(wave(add, View::all(x, 2), Some(View::all(x, 2)), View::contiguous(t, 0, 2)));
        p.steps.push(wave(add, View::all(t, 4), Some(View::all(t, 4)), View::all(o, 4)));
        p.check().unwrap();
        let mp = MemPlan::build(&p);
        assert!(mp.intervals()[t].pinned);
    }

    #[test]
    fn full_def_temp_interval_covers_its_references() {
        let (p, t1, _) = reuse_program();
        let mp = MemPlan::build(&p);
        let iv = mp.intervals()[t1];
        assert!(!iv.pinned);
        assert_eq!((iv.first, iv.last), (0, 1));
    }

    #[test]
    fn exceeds_board_fires_iff_demand_exceeds_capacity() {
        let (p, _, _) = reuse_program();
        let mp = MemPlan::build(&p);
        for cap in 0..=mp.packed_lanes() + 1 {
            let r = mp.require_fit("test-board", cap);
            if cap >= mp.peak_lanes() {
                assert!(r.is_ok(), "cap {cap}");
            } else {
                let Err(PlanError::ExceedsBoard { demand, capacity, split_step, .. }) = r else {
                    panic!("cap {cap}: expected ExceedsBoard");
                };
                assert_eq!(demand, mp.peak_lanes());
                assert_eq!(capacity, cap);
                assert!(split_step < mp.steps());
            }
        }
    }

    #[test]
    fn fit_accepts_small_nets_on_the_selected_part() {
        let (p, _, _) = reuse_program();
        let part = FpgaPart::selected();
        let mp = MemPlan::fit(&p, part).unwrap();
        assert!(mp.peak_lanes() <= MemPlan::board_lanes(part));
        assert_eq!(mp.peak_bram(), 1);
    }

    #[test]
    fn demand_profile_lower_bounds_the_layout() {
        let (p, _, _) = reuse_program();
        let mp = MemPlan::build(&p);
        for s in 0..mp.steps() {
            assert!(mp.demand_at(s) <= mp.peak_lanes());
        }
        // Peak demand here equals the layout (no fragmentation): 12.
        assert_eq!((0..mp.steps()).map(|s| mp.demand_at(s)).max(), Some(12));
    }
}
