//! Processor groups: 4 processors + local controller + microcode cache +
//! 4:1 output multiplexer + input/output counters (paper §4.1, Fig 5,
//! Tables 3–4).
//!
//! The **local controller** here is a real microcode interpreter: a group
//! executes a sequence of up-to-16 [`Microcode`] words (the cache depth of
//! §4.1), driving its four processors cycle by cycle exactly as the word
//! fields dictate. The word kinds are distinguished by their
//! processor-control nibbles, with the following conventions (fixed by the
//! Matrix Assembler's `microcode_gen`, asserted here):
//!
//! * **write word** (MVM: one proc's nibble = `MVM_WRITE`; ACTPRO:
//!   `ACTPRO_WRITE_DATA`/`ACTPRO_WRITE_ACT`): streams 2 lanes/cycle from
//!   the group's input ports into the selected processor, addresses from
//!   the input counter, column from `input_col`. `cycles = pairs + 1`
//!   (setup cycle, Fig 7).
//! * **compute word** (MVM: compute nibbles; ACTPRO: `ACTPRO_RUN`): all
//!   flagged processors run in lockstep. MVM: `cycles = len + 8`
//!   (setup + Fig 8 pipeline); ACTPRO: `cycles = len/2 + 6` (Fig 10).
//! * **drain word** (all nibbles `*_READ`, output counter enabled): the
//!   4:1 mux selects one processor (`out_mux_sel`); its result column
//!   streams out at 1 lane/cycle (MVM right-BRAM port 1) or 2 lanes/cycle
//!   (ACTPRO). `cycles = lanes` (resp. `lanes/2`).
//!
//! Counters reset at word boundaries (our convention; the paper's enable
//! bits gate counting within a word).

use super::actpro::ActPro;
use super::counter::Counter;
use super::mvm::Mvm;
use super::Cycle;
use crate::fixed::FixedSpec;
use crate::isa::microcode::{Microcode, MICROCODE_CACHE_DEPTH, PROCS_PER_GROUP};
use crate::isa::{ActproOp, MvmOp};
use crate::nn::lut::ActLut;
use std::collections::VecDeque;

/// Streamed I/O of one group execution: input beats (2 lanes each) in,
/// output lanes out.
#[derive(Debug, Default)]
pub struct GroupIo {
    /// Input stream, consumed 2 lanes per write cycle.
    pub input: VecDeque<(i16, i16)>,
    /// Output stream, produced by drain words.
    pub output: Vec<i16>,
}

impl GroupIo {
    /// Queue a vector as input beats (padded to an even length).
    pub fn feed(&mut self, data: &[i16]) {
        let mut it = data.chunks(2);
        for c in &mut it {
            self.input.push_back((c[0], if c.len() > 1 { c[1] } else { 0 }));
        }
    }
}

/// MVM processor group (Fig 5; resources in Table 3 row `MVM_PG`).
#[derive(Debug, Clone)]
pub struct MvmGroup {
    mvms: Vec<Mvm>,
    input_ctr: Counter,
    output_ctr: Counter,
    /// Cycles consumed over the group's lifetime.
    pub cycles: Cycle,
}

impl MvmGroup {
    /// New group of 4 MVMs.
    pub fn new(fixed: FixedSpec) -> MvmGroup {
        MvmGroup {
            mvms: (0..PROCS_PER_GROUP).map(|_| Mvm::new(fixed)).collect(),
            input_ctr: Counter::bit8(),
            output_ctr: Counter::new(10),
            cycles: 0,
        }
    }

    /// Access a member MVM (testbench).
    pub fn mvm(&self, i: usize) -> &Mvm {
        &self.mvms[i]
    }

    /// Mutable access (testbench backdoors).
    pub fn mvm_mut(&mut self, i: usize) -> &mut Mvm {
        &mut self.mvms[i]
    }

    /// Execute a cached microcode program. Panics on malformed programs
    /// (the assembler's generator upholds the conventions). Returns cycles
    /// consumed.
    pub fn execute(&mut self, program: &[Microcode], io: &mut GroupIo) -> Cycle {
        assert!(
            program.len() <= MICROCODE_CACHE_DEPTH,
            "program of {} words exceeds the {MICROCODE_CACHE_DEPTH}-entry microcode cache",
            program.len()
        );
        let mut total: Cycle = 0;
        for (wi, w) in program.iter().enumerate() {
            total += w.cycles as Cycle;
            self.input_ctr.reset();
            self.output_ctr.reset();
            // Classify the word by its nibbles.
            let mvm_ops: Vec<(MvmOp, bool)> = w.proc_ctrl.iter().map(|pc| pc.as_mvm()).collect();
            let writers: Vec<usize> = (0..PROCS_PER_GROUP)
                .filter(|&p| mvm_ops[p].0 == MvmOp::Write)
                .collect();
            let computes: Vec<usize> = (0..PROCS_PER_GROUP)
                .filter(|&p| mvm_ops[p].0.is_compute())
                .collect();
            assert!(
                writers.len() <= 1,
                "word {wi}: {} writers but the group has one input port pair",
                writers.len()
            );
            if let Some(&p) = writers.first() {
                assert!(computes.is_empty(), "word {wi}: mixed write/compute");
                let col = w.input_col;
                self.mvms[p].begin_write();
                for cyc in 0..w.cycles {
                    if cyc == 0 {
                        // setup cycle (Fig 7 cycle 1)
                        self.mvms[p].write_pair(0, 0, 0, 0, col);
                        continue;
                    }
                    let (d0, d1) = io.input.pop_front().unwrap_or((0, 0));
                    let a = self.input_ctr.value() * 2;
                    self.mvms[p].write_pair(a, d0, a + 1, d1, col);
                    self.input_ctr.clock(w.input_ctr_en);
                }
                self.mvms[p].end_write();
            } else if !computes.is_empty() {
                assert!(w.cycles > 8, "word {wi}: compute word needs len+8 cycles");
                let len = w.cycles - 8;
                for &p in &computes {
                    let (op, msb) = mvm_ops[p];
                    self.mvms[p].begin_compute(op, len, msb);
                }
                for _cyc in 0..w.cycles {
                    for &p in &computes {
                        if !self.mvms[p].idle() {
                            self.mvms[p].step_compute(None);
                        }
                    }
                }
                for &p in &computes {
                    assert!(self.mvms[p].idle(), "word {wi}: compute did not retire in budget");
                }
            } else if w.output_ctr_en {
                // drain word: mux-selected processor, 1 lane/cycle.
                let p = w.out_mux_sel as usize;
                for _cyc in 0..w.cycles {
                    let v = self.mvms[p].drain(w.output_col, self.output_ctr.value());
                    io.output.push(v);
                    self.output_ctr.clock(true);
                }
            } else {
                // NOP / stall word.
            }
        }
        self.cycles += total;
        total
    }
}

/// Activation processor group (resources in Table 3 row `ACTPRO_PG`).
///
/// The LUT addressing parameters (`shift`, mode, interpolation) are VHDL
/// generics chosen by the Matrix Assembler at generation time; the table
/// *contents* are streamed at runtime via `ACTPRO_WRITE_ACT` words.
#[derive(Debug, Clone)]
pub struct ActproGroup {
    procs: Vec<ActPro>,
    input_ctr: Counter,
    output_ctr: Counter,
    /// Cycles consumed over the group's lifetime.
    pub cycles: Cycle,
}

impl ActproGroup {
    /// New group of 4 ACTPROs, all initialised with `lut`.
    pub fn new(lut: ActLut) -> ActproGroup {
        ActproGroup {
            procs: (0..PROCS_PER_GROUP).map(|_| ActPro::new(lut.clone())).collect(),
            input_ctr: Counter::bit8(),
            output_ctr: Counter::new(10),
            cycles: 0,
        }
    }

    /// Access a member processor (testbench).
    pub fn proc(&self, i: usize) -> &ActPro {
        &self.procs[i]
    }

    /// Swap the activation table on all processors (`ACTPRO_WRITE_ACT`
    /// broadcast), charging the dual-port streaming cost once per proc.
    pub fn write_act_all(&mut self, lut: &ActLut) -> Cycle {
        let cost = (lut.table().len() as Cycle / 2 + 1) * self.procs.len() as Cycle;
        for p in &mut self.procs {
            p.write_act(lut.clone());
        }
        self.cycles += cost;
        cost
    }

    /// Execute a cached microcode program (same conventions as
    /// [`MvmGroup::execute`], with Table 7 nibbles).
    pub fn execute(&mut self, program: &[Microcode], io: &mut GroupIo) -> Cycle {
        assert!(program.len() <= MICROCODE_CACHE_DEPTH);
        let mut total: Cycle = 0;
        for (wi, w) in program.iter().enumerate() {
            total += w.cycles as Cycle;
            self.input_ctr.reset();
            self.output_ctr.reset();
            let ops: Vec<ActproOp> = w.proc_ctrl.iter().map(|pc| pc.as_actpro()).collect();
            let writers: Vec<usize> = (0..PROCS_PER_GROUP)
                .filter(|&p| ops[p] == ActproOp::WriteData)
                .collect();
            let runners: Vec<usize> =
                (0..PROCS_PER_GROUP).filter(|&p| ops[p] == ActproOp::Run).collect();
            assert!(writers.len() <= 1, "word {wi}: multiple ACTPRO writers");
            if let Some(&p) = writers.first() {
                assert!(w.cycles >= 1);
                let pairs = (w.cycles - 1) as usize;
                let mut data = Vec::with_capacity(pairs * 2);
                for _ in 0..pairs {
                    let (d0, d1) = io.input.pop_front().unwrap_or((0, 0));
                    data.push(d0);
                    data.push(d1);
                }
                self.procs[p].load_input(&data);
            } else if !runners.is_empty() {
                assert!(w.cycles > 6, "word {wi}: run word needs len/2+6 cycles");
                let len = (w.cycles - 6) * 2;
                for &p in &runners {
                    self.procs[p].begin_run(len);
                    for _ in 0..w.cycles {
                        self.procs[p].step_run(None);
                    }
                }
            } else if w.output_ctr_en {
                // drain: 2 lanes/cycle from the mux-selected processor.
                let p = w.out_mux_sel as usize;
                for _ in 0..w.cycles {
                    let base = self.output_ctr.value() as usize * 2;
                    let pair = self.procs[p].dump_result(base + 2);
                    io.output.push(pair[base]);
                    io.output.push(pair[base + 1]);
                    self.output_ctr.clock(true);
                }
            }
        }
        self.cycles += total;
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::microcode_gen;
    use crate::fixed::FixedSpec;
    use crate::isa::Opcode;
    use crate::nn::lut::{ActKind, AddrMode};
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<i16> {
        (0..n).map(|_| r.gen_range_i64(-2000, 2000) as i16).collect()
    }

    #[test]
    fn group_runs_four_vector_adds_from_microcode() {
        let mut r = Rng::new(21);
        let n = 64usize;
        let inputs: Vec<(Vec<i16>, Vec<i16>)> =
            (0..4).map(|_| (rand_vec(&mut r, n), rand_vec(&mut r, n))).collect();
        let program = microcode_gen::mvm_batch(Opcode::VectorAddition, n, 4).unwrap();
        let mut io = GroupIo::default();
        for (a, b) in &inputs {
            io.feed(a);
            io.feed(b);
        }
        let mut g = MvmGroup::new(S);
        let cycles = g.execute(&program, &mut io);
        assert!(cycles > 0);
        // outputs: 4 drains of n lanes each, in proc order
        assert_eq!(io.output.len(), 4 * n);
        for (p, (a, b)) in inputs.iter().enumerate() {
            assert_eq!(&io.output[p * n..(p + 1) * n], S.vadd(a, b).as_slice(), "proc {p}");
        }
    }

    #[test]
    fn group_dot_products_from_microcode() {
        let mut r = Rng::new(22);
        let n = 100usize;
        let inputs: Vec<(Vec<i16>, Vec<i16>)> =
            (0..4).map(|_| (rand_vec(&mut r, n), rand_vec(&mut r, n))).collect();
        let program = microcode_gen::mvm_batch(Opcode::VectorDotProduct, n, 4).unwrap();
        let mut io = GroupIo::default();
        for (a, b) in &inputs {
            io.feed(a);
            io.feed(b);
        }
        let mut g = MvmGroup::new(S);
        g.execute(&program, &mut io);
        // dot drains are single-lane
        assert_eq!(io.output.len(), 4);
        for (p, (a, b)) in inputs.iter().enumerate() {
            assert_eq!(io.output[p], S.dot(a, b), "proc {p}");
        }
    }

    #[test]
    fn microcode_program_fits_cache() {
        // 4-proc batch: 8 write words + 1 compute + 4 drains = 13 ≤ 16.
        let program = microcode_gen::mvm_batch(Opcode::VectorAddition, 512, 4).unwrap();
        assert!(program.len() <= MICROCODE_CACHE_DEPTH);
        assert_eq!(program.len(), 13);
    }

    #[test]
    fn actpro_group_applies_relu_from_microcode() {
        let lut = ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7);
        let mut r = Rng::new(23);
        let n = 50usize; // odd pair count exercises padding
        let xs: Vec<Vec<i16>> = (0..4).map(|_| rand_vec(&mut r, n)).collect();
        let program = microcode_gen::actpro_batch(n, 4).unwrap();
        let mut io = GroupIo::default();
        for x in &xs {
            io.feed(x);
        }
        let mut g = ActproGroup::new(lut.clone());
        g.execute(&program, &mut io);
        // drains come back padded to even length
        let per = io.output.len() / 4;
        for (p, x) in xs.iter().enumerate() {
            let got = &io.output[p * per..p * per + n];
            let want = lut.apply(x);
            assert_eq!(got, want.as_slice(), "proc {p}");
        }
    }

    #[test]
    fn group_cycle_count_matches_word_budget() {
        let n = 32usize;
        let program = microcode_gen::mvm_batch(Opcode::VectorSubtraction, n, 2).unwrap();
        let budget: Cycle = program.iter().map(|w| w.cycles as Cycle).sum();
        let mut io = GroupIo::default();
        for _ in 0..2 {
            io.feed(&vec![1; n]);
            io.feed(&vec![2; n]);
        }
        let mut g = MvmGroup::new(S);
        let cycles = g.execute(&program, &mut io);
        assert_eq!(cycles, budget);
    }

    #[test]
    #[should_panic(expected = "exceeds the 16-entry microcode cache")]
    fn oversized_program_rejected() {
        let words = vec![Microcode::default(); 17];
        let mut g = MvmGroup::new(S);
        g.execute(&words, &mut GroupIo::default());
    }
}
