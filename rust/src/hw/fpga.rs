//! FPGA device model: one board of the paper's cluster (§2, §5).
//!
//! Combines a catalog part ([`crate::perf::catalog::FpgaPart`]) with the
//! assembler's resource allocation (Eqns 3–4) into the machine shape the
//! simulator executes against: how many MVM / ACTPRO groups exist and what
//! the DDR can move per cycle.

use crate::assembler::resource::ResourceModel;
use crate::perf::catalog::FpgaPart;

/// One FPGA board: part + derived Matrix Machine shape.
#[derive(Debug, Clone, Copy)]
pub struct FpgaDevice {
    /// Catalog entry.
    pub part: &'static FpgaPart,
    /// MVM processor groups (Eqn 3).
    pub mvm_groups: u32,
    /// Activation processor groups (Eqn 4).
    pub actpro_groups: u32,
}

impl FpgaDevice {
    /// Build from a catalog part via the resource model.
    pub fn new(part: &'static FpgaPart) -> FpgaDevice {
        let alloc = ResourceModel::new(part).allocate();
        FpgaDevice { part, mvm_groups: alloc.mvm_groups, actpro_groups: alloc.actpro_groups }
    }

    /// The paper's selected board (XC7S75-2).
    pub fn selected() -> FpgaDevice {
        FpgaDevice::new(FpgaPart::selected())
    }

    /// By part name.
    pub fn by_name(name: &str) -> Option<FpgaDevice> {
        FpgaPart::by_name(name).map(FpgaDevice::new)
    }

    /// Total MVM processors.
    pub fn mvm_procs(&self) -> u32 {
        self.mvm_groups * super::PROCS_PER_GROUP as u32
    }

    /// Cycles to move `bytes` over the board's DDR channels.
    pub fn dma_cycles(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.part.ddr_bytes_per_cycle()).ceil() as u64
    }

    /// Wall-clock seconds for a cycle count at the fabric clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.part.t_cycle_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selected_shape() {
        let d = FpgaDevice::selected();
        assert_eq!(d.mvm_groups, 16);
        assert_eq!(d.actpro_groups, 4);
        assert_eq!(d.mvm_procs(), 64);
    }

    #[test]
    fn dma_cycles_at_128_bytes_per_cycle() {
        let d = FpgaDevice::selected();
        assert_eq!(d.dma_cycles(128), 1);
        assert_eq!(d.dma_cycles(129), 2);
        assert_eq!(d.dma_cycles(0), 0);
        // a 512×512 i16 matrix = 512 KiB → 4096 cycles
        assert_eq!(d.dma_cycles(512 * 512 * 2), 4096);
    }

    #[test]
    fn seconds_at_100mhz() {
        let d = FpgaDevice::selected();
        assert!((d.seconds(100_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn by_name() {
        assert!(FpgaDevice::by_name("XC7S50-1").is_some());
        assert!(FpgaDevice::by_name("nope").is_none());
    }
}
