//! DSP48E1 slice model (paper §4.2; Xilinx UG479).
//!
//! "The left BRAM's dual outputs are feed to the dual inputs of the
//! DSP48E1... The DSP48E1 is configured as a 6 stage pipeline. At the 8th
//! cycle, the DSP48E1's P port outputs the result." — Fig 8.
//!
//! We model the slice as an opaque 6-stage pipeline: an operand pair issued
//! in cycle *t* affects the 48-bit `P` register at the clock edge of cycle
//! *t + 6*. Accumulating modes (`MultAcc` for dot products, `AddAcc` for
//! summation) add into `P` at the exit stage — 1 op/cycle throughput, as in
//! silicon where the post-adder closes the accumulate loop locally.
//! `P` is wrapped to 48 bits like the real register.

use super::DSP_PIPELINE_STAGES;

/// DSP operating mode for one issued operand pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DspOp {
    /// `P = A * B` (element-wise multiplication).
    Mult,
    /// `P = A + B` (vector addition).
    Add,
    /// `P = A - B` (vector subtraction).
    Sub,
    /// `P += A * B` (dot product).
    MultAcc,
    /// `P += A` (vector summation; B ignored).
    AddAcc,
}

/// Sign-wrap an i64 into the 48-bit P register domain.
#[inline]
pub fn wrap48(x: i64) -> i64 {
    (x << 16) >> 16
}

#[derive(Debug, Clone, Copy)]
struct Stage {
    value: i64,
    accumulate: bool,
}

/// One DSP48E1 slice as a 6-stage pipeline with a 48-bit `P` register.
#[derive(Debug, Clone)]
pub struct Dsp48 {
    stages: [Option<Stage>; DSP_PIPELINE_STAGES],
    p: i64,
    p_updated: bool,
}

impl Default for Dsp48 {
    fn default() -> Self {
        Self::new()
    }
}

impl Dsp48 {
    /// Fresh slice, empty pipeline, `P = 0`.
    pub fn new() -> Dsp48 {
        Dsp48 { stages: [None; DSP_PIPELINE_STAGES], p: 0, p_updated: false }
    }

    /// Issue an operand pair for this cycle (call before [`Dsp48::clock`]).
    pub fn issue(&mut self, a: i16, b: i16, op: DspOp) {
        debug_assert!(self.stages[0].is_none(), "double issue in one cycle");
        let (value, accumulate) = match op {
            DspOp::Mult => (a as i64 * b as i64, false),
            DspOp::Add => (a as i64 + b as i64, false),
            DspOp::Sub => (a as i64 - b as i64, false),
            DspOp::MultAcc => (a as i64 * b as i64, true),
            DspOp::AddAcc => (a as i64, true),
        };
        self.stages[0] = Some(Stage { value: wrap48(value), accumulate });
    }

    /// Clock edge: shift the pipeline; a stage exiting updates `P`.
    pub fn clock(&mut self) {
        self.p_updated = false;
        if let Some(out) = self.stages[DSP_PIPELINE_STAGES - 1] {
            self.p = if out.accumulate { wrap48(self.p + out.value) } else { out.value };
            self.p_updated = true;
        }
        for i in (1..DSP_PIPELINE_STAGES).rev() {
            self.stages[i] = self.stages[i - 1];
        }
        self.stages[0] = None;
    }

    /// The 48-bit `P` output register (sign-extended into i64).
    pub fn p(&self) -> i64 {
        self.p
    }

    /// Did the last clock edge update `P`? (The MVM uses this as the
    /// write-enable for the right BRAM / write counter, Fig 8 cycle 8.)
    pub fn p_valid(&self) -> bool {
        self.p_updated
    }

    /// Synchronous clear of the accumulator (issued between dot products).
    pub fn clear_p(&mut self) {
        self.p = 0;
    }

    /// True when no operations are in flight.
    pub fn pipeline_empty(&self) -> bool {
        self.stages.iter().all(Option::is_none)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_cycle_latency() {
        // Fig 8: operands fed in cycle 3 appear on P at cycle 8 → 6 edges.
        let mut d = Dsp48::new();
        d.issue(2, 3, DspOp::Add);
        for edge in 1..=DSP_PIPELINE_STAGES {
            d.clock();
            if edge < DSP_PIPELINE_STAGES {
                assert!(!d.p_valid(), "P updated early at edge {edge}");
            }
        }
        assert!(d.p_valid());
        assert_eq!(d.p(), 5);
    }

    #[test]
    fn pipelined_throughput_one_per_cycle() {
        let mut d = Dsp48::new();
        let mut outputs = Vec::new();
        for i in 0..10i16 {
            d.issue(i, 1, DspOp::Mult);
            d.clock();
            if d.p_valid() {
                outputs.push(d.p());
            }
        }
        // drain
        for _ in 0..DSP_PIPELINE_STAGES {
            d.clock();
            if d.p_valid() {
                outputs.push(d.p());
            }
        }
        assert_eq!(outputs, (0..10).map(|i| i as i64).collect::<Vec<_>>());
        assert!(d.pipeline_empty());
    }

    #[test]
    fn mult_accumulate_sums_products() {
        let mut d = Dsp48::new();
        let a = [1i16, 2, 3, 4];
        let b = [10i16, 20, 30, 40];
        for i in 0..4 {
            d.issue(a[i], b[i], DspOp::MultAcc);
            d.clock();
        }
        for _ in 0..DSP_PIPELINE_STAGES {
            d.clock();
        }
        assert_eq!(d.p(), 10 + 40 + 90 + 160);
    }

    #[test]
    fn add_accumulate_ignores_b() {
        let mut d = Dsp48::new();
        for i in 1..=5i16 {
            d.issue(i, 99, DspOp::AddAcc);
            d.clock();
        }
        for _ in 0..DSP_PIPELINE_STAGES {
            d.clock();
        }
        assert_eq!(d.p(), 15);
    }

    #[test]
    fn p_wraps_at_48_bits() {
        assert_eq!(wrap48((1i64 << 47) - 1) , (1i64 << 47) - 1);
        assert_eq!(wrap48(1i64 << 47), -(1i64 << 47));
        let mut d = Dsp48::new();
        // accumulate i16::MIN * i16::MIN (=2^30) repeatedly: needs 2^17
        // accumulations to overflow 48 bits — spot-check the wrap helper
        // drives P through the pipeline instead.
        d.issue(i16::MIN, i16::MIN, DspOp::Mult);
        for _ in 0..DSP_PIPELINE_STAGES {
            d.clock();
        }
        assert_eq!(d.p(), 1i64 << 30);
    }

    #[test]
    fn clear_p_between_dots() {
        let mut d = Dsp48::new();
        d.issue(2, 2, DspOp::MultAcc);
        for _ in 0..DSP_PIPELINE_STAGES {
            d.clock();
        }
        assert_eq!(d.p(), 4);
        d.clear_p();
        assert_eq!(d.p(), 0);
    }
}
