//! RAMB18E1 block RAM model (paper §4.2; Xilinx UG473).
//!
//! "Each BRAM (RAMB18E1) stores 1024 x 16 bit signed value. Furthermore,
//! each BRAM has two read/write ports."
//!
//! The model is synchronous like the silicon: a read issued on a port in
//! cycle *t* presents its data on that port's output register in cycle
//! *t + 1*; writes are committed at the end of the cycle (write-first is
//! irrelevant here because the simulator never reads and writes the same
//! address in the same cycle from different ports — the assembler's
//! schedules keep operand and result columns disjoint).

use super::BRAM_DEPTH;

/// Per-port latched command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PortCmd {
    Idle,
    Read { addr: u16 },
    Write { addr: u16, data: i16 },
}

/// One dual-port 1024 × 16-bit block RAM.
#[derive(Debug, Clone)]
pub struct Bram {
    mem: Vec<i16>,
    cmd: [PortCmd; 2],
    dout: [i16; 2],
}

impl Default for Bram {
    fn default() -> Self {
        Self::new()
    }
}

impl Bram {
    /// Zero-initialised BRAM.
    pub fn new() -> Bram {
        Bram { mem: vec![0; BRAM_DEPTH], cmd: [PortCmd::Idle; 2], dout: [0; 2] }
    }

    /// Issue a read on `port` (0/1) for this cycle; data visible on
    /// [`Bram::dout`] after the next [`Bram::clock`].
    pub fn read(&mut self, port: usize, addr: u16) {
        debug_assert!((addr as usize) < BRAM_DEPTH, "BRAM address {addr} out of range");
        self.cmd[port] = PortCmd::Read { addr: addr % BRAM_DEPTH as u16 };
    }

    /// Issue a write on `port` for this cycle (committed at `clock`).
    pub fn write(&mut self, port: usize, addr: u16, data: i16) {
        debug_assert!((addr as usize) < BRAM_DEPTH, "BRAM address {addr} out of range");
        self.cmd[port] = PortCmd::Write { addr: addr % BRAM_DEPTH as u16, data };
    }

    /// Advance one clock edge: commit writes, latch read data.
    pub fn clock(&mut self) {
        for p in 0..2 {
            match self.cmd[p] {
                PortCmd::Idle => {}
                PortCmd::Read { addr } => {
                    self.dout[p] = self.mem[addr as usize];
                }
                PortCmd::Write { addr, data } => {
                    self.mem[addr as usize] = data;
                }
            }
            self.cmd[p] = PortCmd::Idle;
        }
    }

    /// Registered read-data output of `port` (value latched by the last
    /// `clock` that serviced a read).
    pub fn dout(&self, port: usize) -> i16 {
        self.dout[port]
    }

    /// Debug/testbench backdoor: read memory combinationally.
    pub fn peek(&self, addr: usize) -> i16 {
        self.mem[addr]
    }

    /// Debug/testbench backdoor: load contents directly (used by the
    /// functional machine to skip cycle-accurate DMA when configured).
    pub fn load(&mut self, base: usize, data: &[i16]) {
        assert!(base + data.len() <= BRAM_DEPTH, "BRAM load overflow");
        self.mem[base..base + data.len()].copy_from_slice(data);
    }

    /// Debug/testbench backdoor: dump a range.
    pub fn dump(&self, base: usize, len: usize) -> Vec<i16> {
        self.mem[base..base + len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_is_synchronous() {
        let mut b = Bram::new();
        b.load(0, &[5, 6, 7]);
        b.read(0, 1);
        // before the clock edge, dout still holds the old value (0)
        assert_eq!(b.dout(0), 0);
        b.clock();
        assert_eq!(b.dout(0), 6);
    }

    #[test]
    fn dual_port_parallel_write() {
        // Fig 7: "the left BRAM writes input_data0 and input_data1 in
        // parallel using the addresses given by input_addr0 and input_addr1"
        let mut b = Bram::new();
        b.write(0, 10, 111);
        b.write(1, 11, 222);
        b.clock();
        assert_eq!(b.peek(10), 111);
        assert_eq!(b.peek(11), 222);
    }

    #[test]
    fn write_then_read_same_port() {
        let mut b = Bram::new();
        b.write(0, 3, -9);
        b.clock();
        b.read(0, 3);
        b.clock();
        assert_eq!(b.dout(0), -9);
    }

    #[test]
    fn dout_holds_between_reads() {
        let mut b = Bram::new();
        b.load(0, &[42]);
        b.read(1, 0);
        b.clock();
        assert_eq!(b.dout(1), 42);
        b.clock(); // idle cycle: output register holds
        assert_eq!(b.dout(1), 42);
    }

    #[test]
    fn capacity_is_1024() {
        let mut b = Bram::new();
        b.write(0, (BRAM_DEPTH - 1) as u16, 1);
        b.clock();
        assert_eq!(b.peek(BRAM_DEPTH - 1), 1);
    }

    #[test]
    #[should_panic(expected = "BRAM load overflow")]
    fn load_overflow_panics() {
        let mut b = Bram::new();
        b.load(BRAM_DEPTH - 1, &[1, 2]);
    }
}
