//! Mini Vector Machine — the paper's unit processor (§4.2, Tables 5–6,
//! Figs 6–8).
//!
//! Structure (Fig 6): 1 × DSP48E1, 2 × RAMB18E1 (left = operands, right =
//! results), read/write counters, and control logic (50 LUTs / 210 FFs in
//! the paper's Table; those constants live in the resource model).
//!
//! Operand layout (see [`crate::hw`] module docs): the left BRAM holds
//! operand `A` in column 0 (`0..512`) and operand `B` in column 1
//! (`512..1024`). During a binary vector op both ports read lane `i` of each
//! column in the same cycle and feed the DSP's `A`/`B` inputs. The right
//! BRAM's column for results is chosen by `processor_control(3)`
//! ("Right BRAM MSB select", Table 5).
//!
//! Timing reproduced from the paper:
//! * **Write** (Fig 7): after a 1-cycle setup, each cycle commits
//!   `input_data0/1` through both ports — 2 elements/cycle.
//! * **Vector op** (Fig 8): setup at cycle 1; first BRAM read issued at
//!   cycle 2; the DSP's 6-stage pipeline updates `P` at cycle 8; the write
//!   counter increments at cycle 8 and the right BRAM commits at cycle 9.
//!   A length-`L` elementwise op spans `L + 7` run cycles (`519` for
//!   `L = 512`, the paper's `C_RUN`).

use super::bram::Bram;
use super::counter::Counter;
use super::dsp48::{Dsp48, DspOp};
use super::trace::Trace;
use super::COLUMN_LEN;
use crate::fixed::FixedSpec;
use crate::isa::MvmOp;

/// MVM execution state (Table 6 states; compute ops carry progress).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// `MVM_READ` — halted / drain reads.
    Idle,
    /// `MVM_WRITE` — loading operand columns.
    Write { setup_done: bool },
    /// One of the compute ops is running.
    Compute { op: MvmOp, len: u16, cycle_in_op: u64 },
}

/// One Mini Vector Machine.
#[derive(Debug, Clone)]
pub struct Mvm {
    left: Bram,
    right: Bram,
    dsp: Dsp48,
    read_ctr: Counter,
    write_ctr: Counter,
    state: State,
    fixed: FixedSpec,
    out_col: bool,
    /// Reads issued but whose BRAM data has not yet been forwarded to the
    /// DSP (models the 1-cycle BRAM read latency).
    pending_read: Option<(DspOp, bool)>, // (op, is_last_element)
    /// Result registered at the DSP output, committed to the right BRAM on
    /// the following cycle (Fig 8: P at cycle 8, BRAM write at cycle 9).
    pending_write: Option<(u16, i16)>,
    /// For accumulating ops: the element count that has entered the DSP.
    issued: u16,
    /// Results committed for the current op.
    writes_done: u16,
    /// Total cycles spent in the current/last op (excludes setup).
    run_cycles: u64,
    last_op_total_cycles: u64,
}

impl Mvm {
    /// New MVM with the given fixed-point datapath spec.
    pub fn new(fixed: FixedSpec) -> Mvm {
        Mvm {
            left: Bram::new(),
            right: Bram::new(),
            dsp: Dsp48::new(),
            // The paper says 8-bit counters, which cannot address the
            // 512-lane columns its own C_RUN=519 implies; our VHDL and
            // model widen them to 10 bits (noted in DESIGN.md).
            read_ctr: Counter::new(10),
            write_ctr: Counter::new(10),
            state: State::Idle,
            fixed,
            out_col: false,
            pending_read: None,
            pending_write: None,
            issued: 0,
            writes_done: 0,
            run_cycles: 0,
            last_op_total_cycles: 0,
        }
    }

    /// Datapath spec in use.
    pub fn fixed(&self) -> FixedSpec {
        self.fixed
    }

    /// Is the MVM in the halted `MVM_READ` state?
    pub fn idle(&self) -> bool {
        self.state == State::Idle
    }

    /// Run cycles consumed by the most recently completed op (the measured
    /// analogue of the paper's `C_RUN`).
    pub fn last_op_cycles(&self) -> u64 {
        self.last_op_total_cycles
    }

    // ---------------------------------------------------------- write phase

    /// Enter `MVM_WRITE`. The next [`Mvm::write_pair`] cycle is the setup
    /// cycle of Fig 7 (no data committed).
    pub fn begin_write(&mut self) {
        self.state = State::Write { setup_done: false };
    }

    /// One `MVM_WRITE` cycle: commit a pair through both ports (Fig 7).
    /// `col` selects the operand column (microcode input-column bit).
    /// Returns `true` if data was committed (false for the setup cycle).
    pub fn write_pair(&mut self, addr0: u16, d0: i16, addr1: u16, d1: i16, col: bool) -> bool {
        match self.state {
            State::Write { setup_done: false } => {
                // Fig 7 cycle 1: "executes the setup phase of the left BRAM".
                self.state = State::Write { setup_done: true };
                self.left.clock();
                false
            }
            State::Write { setup_done: true } => {
                let base = if col { COLUMN_LEN as u16 } else { 0 };
                self.left.write(0, base + addr0, d0);
                self.left.write(1, base + addr1, d1);
                self.left.clock();
                true
            }
            _ => panic!("write_pair outside MVM_WRITE (state {:?})", self.state),
        }
    }

    /// Leave the write state.
    pub fn end_write(&mut self) {
        self.state = State::Idle;
    }

    // -------------------------------------------------------- compute phase

    /// Latch a compute op. `len` is the number of lanes (≤ [`COLUMN_LEN`]);
    /// `out_col` is the right-BRAM MSB select (Table 5 bit 3).
    pub fn begin_compute(&mut self, op: MvmOp, len: u16, out_col: bool) {
        assert!(op.is_compute(), "begin_compute with non-compute op {op}");
        assert!(len as usize <= COLUMN_LEN, "vector length {len} exceeds column");
        assert!(len > 0, "zero-length vector op");
        self.state = State::Compute { op, len, cycle_in_op: 0 };
        self.out_col = out_col;
        self.pending_read = None;
        self.pending_write = None;
        self.issued = 0;
        self.writes_done = 0;
        self.run_cycles = 0;
    }

    /// Advance one clock cycle of the running compute op. Returns `true`
    /// when the op has fully retired (last result committed).
    ///
    /// With `trace`, records the Fig 8 signal set: `state`, `rd_addr`,
    /// `dsp_p`, `wr_en`, `wr_addr` keyed by the cycle number within the op
    /// (setup = cycle 1, matching the paper's numbering).
    pub fn step_compute(&mut self, mut trace: Option<&mut Trace>) -> bool {
        let (op, len, cycle_in_op) = match self.state {
            State::Compute { op, len, cycle_in_op } => (op, len, cycle_in_op),
            _ => panic!("step_compute outside compute state"),
        };
        let cyc = cycle_in_op + 1; // 1-based, paper numbering
        let dsp_op = match op {
            MvmOp::VecDot => DspOp::MultAcc,
            MvmOp::VecSum => DspOp::AddAcc,
            MvmOp::VecAdd => DspOp::Add,
            MvmOp::VecSub => DspOp::Sub,
            MvmOp::ElemMult => DspOp::Mult,
            _ => unreachable!(),
        };
        let accumulating = matches!(dsp_op, DspOp::MultAcc | DspOp::AddAcc);

        if let Some(t) = trace.as_deref_mut() {
            t.record(cyc, "state", op.mnemonic());
        }

        if cyc == 1 {
            // Setup: reset counters + accumulator (Fig 8 cycle 1).
            self.read_ctr.reset();
            self.write_ctr.reset();
            self.dsp.clear_p();
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "phase", "setup");
            }
            self.state = State::Compute { op, len, cycle_in_op: cycle_in_op + 1 };
            return false;
        }
        self.run_cycles += 1;

        // 1) Commit the result registered last cycle (Fig 8: the right BRAM
        //    writes at cycle 9, one cycle after P updates at cycle 8).
        if let Some((addr, v)) = self.pending_write.take() {
            self.right.write(0, addr, v);
            self.writes_done += 1;
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "wr_en", 1);
                t.record(cyc, "wr_addr", addr);
            }
        }

        // 2) Forward last cycle's BRAM read data into the DSP.
        if let Some((pending_op, is_last)) = self.pending_read.take() {
            let a = self.left.dout(0);
            let b = self.left.dout(1);
            self.dsp.issue(a, b, pending_op);
            if is_last {
                self.issued = len; // all elements now in flight
            }
        }

        // 3) Issue the next BRAM read if elements remain.
        let reads_done = self.read_ctr.value() >= len;
        if !reads_done {
            let i = self.read_ctr.value();
            self.left.read(0, i);
            self.left.read(1, COLUMN_LEN as u16 + i);
            self.pending_read = Some((dsp_op, i + 1 == len));
            self.read_ctr.clock(true);
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "rd_addr", i);
            }
        }

        // 4) Clock the datapath.
        self.left.clock();
        self.dsp.clock();

        // 5) Register the next write when P updates ("also in the 8th
        //    cycle, the write counter increments").
        let out_base = if self.out_col { COLUMN_LEN as u16 } else { 0 };
        if self.dsp.p_valid() {
            if let Some(t) = trace.as_deref_mut() {
                t.record(cyc, "dsp_p", self.dsp.p());
            }
            let result = if !accumulating {
                // Elementwise: every P update is a result.
                Some(if matches!(dsp_op, DspOp::Mult) {
                    self.fixed.rescale(self.dsp.p())
                } else {
                    self.fixed.narrow(self.dsp.p())
                })
            } else if self.issued == len && self.dsp.pipeline_empty() {
                // Accumulating: single result once the pipeline drained.
                Some(match op {
                    MvmOp::VecDot => self.fixed.rescale(self.dsp.p()),
                    MvmOp::VecSum => self.fixed.narrow(self.dsp.p()),
                    _ => unreachable!(),
                })
            } else {
                None
            };
            if let Some(v) = result {
                let addr = out_base + self.write_ctr.value();
                self.pending_write = Some((addr, v));
                self.write_ctr.clock(true);
            }
        }
        self.right.clock();

        // 6) Completion: elementwise after `len` committed writes;
        //    accumulating after its single write.
        let expected_writes = if accumulating { 1 } else { len };
        let done = self.writes_done >= expected_writes;
        if done {
            self.last_op_total_cycles = self.run_cycles;
            self.state = State::Idle;
        } else {
            self.state = State::Compute { op, len, cycle_in_op: cycle_in_op + 1 };
        }
        done
    }

    // ---------------------------------------------------------- drain phase

    /// `MVM_READ` drain: combinational testbench read of the right BRAM
    /// (port 1 is "always set to read", §4.2). One element per cycle in
    /// hardware; the group charges those cycles.
    pub fn drain(&self, col: bool, idx: u16) -> i16 {
        let base = if col { COLUMN_LEN } else { 0 };
        self.right.peek(base + idx as usize)
    }

    /// Testbench backdoor: load an operand column directly.
    pub fn load_column(&mut self, col: bool, data: &[i16]) {
        assert!(data.len() <= COLUMN_LEN);
        let base = if col { COLUMN_LEN } else { 0 };
        self.left.load(base, data);
    }

    /// Testbench backdoor: dump the result column.
    pub fn dump_result(&self, col: bool, len: usize) -> Vec<i16> {
        let base = if col { COLUMN_LEN } else { 0 };
        self.right.dump(base, len)
    }

    /// Run a whole compute op to completion, returning the cycle count
    /// (including the setup cycle).
    pub fn run_op(&mut self, op: MvmOp, len: u16, out_col: bool) -> u64 {
        self.begin_compute(op, len, out_col);
        let mut cycles = 1; // setup
        assert!(!self.step_compute(None));
        loop {
            cycles += 1;
            if self.step_compute(None) {
                return cycles;
            }
            assert!(cycles < 10_000, "runaway op");
        }
    }

    /// Full reset (`MVM_RESET`).
    pub fn reset(&mut self) {
        *self = Mvm::new(self.fixed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::DSP_PIPELINE_STAGES;
    use crate::util::Rng;

    fn spec() -> FixedSpec {
        FixedSpec::PAPER
    }

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<i16> {
        (0..n).map(|_| (r.gen_range_i64(-4000, 4000)) as i16).collect()
    }

    #[test]
    fn vec_add_matches_fixed_reference() {
        let mut r = Rng::new(2);
        let (a, b) = (rand_vec(&mut r, 512), rand_vec(&mut r, 512));
        let mut m = Mvm::new(spec());
        m.load_column(false, &a);
        m.load_column(true, &b);
        m.run_op(MvmOp::VecAdd, 512, false);
        assert_eq!(m.dump_result(false, 512), spec().vadd(&a, &b));
    }

    #[test]
    fn vec_sub_and_mult_match_reference() {
        let mut r = Rng::new(3);
        let (a, b) = (rand_vec(&mut r, 100), rand_vec(&mut r, 100));
        let mut m = Mvm::new(spec());
        m.load_column(false, &a);
        m.load_column(true, &b);
        m.run_op(MvmOp::VecSub, 100, false);
        assert_eq!(m.dump_result(false, 100), spec().vsub(&a, &b));
        m.run_op(MvmOp::ElemMult, 100, true);
        assert_eq!(m.dump_result(true, 100), spec().vmul(&a, &b));
    }

    #[test]
    fn dot_and_sum_match_reference() {
        let mut r = Rng::new(4);
        let (a, b) = (rand_vec(&mut r, 256), rand_vec(&mut r, 256));
        let mut m = Mvm::new(spec());
        m.load_column(false, &a);
        m.load_column(true, &b);
        m.run_op(MvmOp::VecDot, 256, false);
        assert_eq!(m.dump_result(false, 1)[0], spec().dot(&a, &b));
        m.run_op(MvmOp::VecSum, 256, false);
        assert_eq!(m.dump_result(false, 1)[0], spec().sum(&a));
    }

    #[test]
    fn elementwise_run_cycles_match_paper_c_run() {
        // C_RUN = L + 7 → 519 at L = 512 (§4.1 worked example).
        let mut m = Mvm::new(spec());
        m.load_column(false, &vec![1; 512]);
        m.load_column(true, &vec![2; 512]);
        let total = m.run_op(MvmOp::VecAdd, 512, false);
        // total includes the setup cycle; C_RUN excludes it.
        assert_eq!(m.last_op_cycles(), 519);
        assert_eq!(total, 520);
    }

    #[test]
    fn fig8_timing_first_result_at_cycles_8_and_9() {
        let mut m = Mvm::new(spec());
        m.load_column(false, &[5, 6, 7, 8]);
        m.load_column(true, &[1, 1, 1, 1]);
        m.begin_compute(MvmOp::VecAdd, 4, false);
        let mut tr = Trace::new();
        while !m.step_compute(Some(&mut tr)) {}
        // Fig 8: read issued at cycle 2, P output at cycle 8, write at 9.
        assert_eq!(tr.first_cycle_of("rd_addr", "0"), Some(2));
        assert_eq!(tr.first_cycle_of("dsp_p", "6"), Some(8));
        assert_eq!(tr.first_cycle_of("wr_en", "1"), Some(9));
    }

    #[test]
    fn dsp_pipeline_depth_visible_in_latency() {
        // 1-lane op: setup(1) + read(1) + forward(1) + 6 stages + write = 9.
        let mut m = Mvm::new(spec());
        m.load_column(false, &[3]);
        m.load_column(true, &[4]);
        let total = m.run_op(MvmOp::VecAdd, 1, false);
        assert_eq!(total, 3 + DSP_PIPELINE_STAGES as u64); // 9 cycles
        assert_eq!(m.dump_result(false, 1)[0], 7);
    }

    #[test]
    fn write_phase_commits_two_per_cycle_after_setup() {
        let mut m = Mvm::new(spec());
        m.begin_write();
        assert!(!m.write_pair(0, 10, 1, 20, false)); // setup cycle
        assert!(m.write_pair(0, 10, 1, 20, false));
        assert!(m.write_pair(2, 30, 3, 40, false));
        m.end_write();
        m.run_op(MvmOp::VecSum, 4, false);
        assert_eq!(m.dump_result(false, 1)[0], 100);
    }

    #[test]
    fn write_to_column1_is_operand_b() {
        let mut m = Mvm::new(spec());
        m.begin_write();
        m.write_pair(0, 0, 0, 0, false); // setup
        m.write_pair(0, 7, 1, 7, false); // A = [7,7]
        m.write_pair(0, 3, 1, 3, true); // B = [3,3]
        m.end_write();
        m.run_op(MvmOp::VecSub, 2, false);
        assert_eq!(m.dump_result(false, 2), vec![4, 4]);
    }

    #[test]
    fn output_column_select_respected() {
        let mut m = Mvm::new(spec());
        m.load_column(false, &[1, 2]);
        m.load_column(true, &[1, 1]);
        m.run_op(MvmOp::VecAdd, 2, true);
        assert_eq!(m.dump_result(true, 2), vec![2, 3]);
        assert_eq!(m.dump_result(false, 2), vec![0, 0]); // col 0 untouched
        assert_eq!(m.drain(true, 1), 3);
    }

    #[test]
    fn back_to_back_ops_reset_state() {
        let mut m = Mvm::new(spec());
        m.load_column(false, &[10, 20, 30]);
        m.load_column(true, &[1, 2, 3]);
        m.run_op(MvmOp::VecDot, 3, false);
        assert_eq!(m.dump_result(false, 1)[0], spec().dot(&[10, 20, 30], &[1, 2, 3]));
        m.run_op(MvmOp::VecAdd, 3, false);
        assert_eq!(m.dump_result(false, 3), vec![11, 22, 33]);
    }

    #[test]
    #[should_panic(expected = "exceeds column")]
    fn rejects_oversize_vectors() {
        let mut m = Mvm::new(spec());
        m.begin_compute(MvmOp::VecAdd, 513, false);
    }
}
