//! Compiled execution plans: the Matrix Machine hot path (perf pass,
//! DESIGN.md §Perf).
//!
//! [`ExecPlan`] is built **once** per [`Program`] and amortises everything
//! the old per-step interpreter recomputed on every training step:
//!
//! * **Arena layout** — all declared buffers are flattened into one
//!   contiguous lane arena; every strided [`View`] is pre-resolved to an
//!   [`ArenaView`] (absolute base + stride), with contiguous fast paths
//!   detected at plan time, not per step.
//! * **Cycle tables** — each wave's DMA/compute/ring cycle charges are
//!   precomputed into the plan (the old `MatrixMachine::wave_cycles`
//!   allocated a `Box<dyn Fn>` per wave per run; the plan allocates
//!   nothing on the hot path).
//! * **Dot→activation fusion** — an `ACTIVATION_FUNCTION` wave that
//!   consumes exactly the outputs of the immediately preceding
//!   `VECTOR_DOT_PRODUCT` wave is folded into it: the LUT is applied to
//!   each dot result while it is still in a register, saving a full pass
//!   over the lane arena. Cycle charges of **both** waves are kept, so
//!   the cycle model is unchanged (asserted by `sim_equivalence`).
//! * **Parallel wave execution** — lanes of a wave whose operand/output
//!   address sets are proven disjoint at plan time are executed across a
//!   persistent worker pool sized to `min(host cores, processor groups)`,
//!   mirroring how the hardware spreads a wave over its MVM/ACTPRO
//!   groups. Disjointness is decided conservatively (interval overlap),
//!   so the parallel path is bit-exact with the sequential one.
//!
//! The structural simulator remains the equivalence oracle:
//! [`ExecPlan::execute_verified`] replays every wave on the microcode
//! interpreters ([`super::group`]) and compares lane-for-lane.

use super::fpga::FpgaDevice;
use super::group::{ActproGroup, GroupIo, MvmGroup};
use super::machine::RunStats;
use super::{Cycle, PROCS_PER_GROUP};
use crate::assembler::microcode_gen;
use crate::assembler::program::{Program, Step, View, Wave};
use crate::fixed::FixedSpec;
use crate::isa::Opcode;
use crate::nn::lut::ActLut;
use crate::perf::group::{structural_actpro_batch_cycles, structural_mvm_batch_cycles};
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Mutex, OnceLock};
use std::thread::JoinHandle;

/// Minimum lane-ops (`lanes × vec_len`) before a wave is worth spreading
/// over the worker pool; below this the dispatch overhead dominates.
pub const PAR_MIN_LANE_OPS: usize = 8192;

/// Minimum independent lanes before parallel dispatch.
const PAR_MIN_LANES: usize = 8;

/// Pairwise independence checking is O(lanes²); above this lane count
/// only the cheap strict check is attempted.
const PAIRWISE_MAX_LANES: usize = 2048;

/// Address-set budget for fusion analysis (one-time, at plan build).
const FUSE_MAX_ADDRS: usize = 1 << 20;

/// A [`View`] resolved against the plan's lane arena: lanes
/// `base + i*stride`, `i < len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaView {
    /// First arena lane.
    pub base: usize,
    /// Number of lanes.
    pub len: usize,
    /// Lane stride (1 = contiguous).
    pub stride: usize,
}

impl ArenaView {
    /// Sentinel for "no operand" (unary ops).
    const EMPTY: ArenaView = ArenaView { base: 0, len: 0, stride: 0 };

    /// First arena address touched.
    #[inline]
    fn first(&self) -> usize {
        self.base
    }

    /// Last arena address touched.
    #[inline]
    fn last(&self) -> usize {
        self.base + (self.len.max(1) - 1) * self.stride
    }

    /// Every arena address, in lane order.
    fn addrs(self) -> impl Iterator<Item = usize> {
        (0..self.len).map(move |i| self.base + i * self.stride)
    }

    /// Gather the view's lanes out of the arena.
    fn gather(&self, arena: &[i16]) -> Vec<i16> {
        (0..self.len).map(|i| arena[self.base + i * self.stride]).collect()
    }
}

/// Conservative overlap test on the views' bounding address intervals.
#[inline]
fn overlaps(x: &ArenaView, y: &ArenaView) -> bool {
    x.len > 0 && y.len > 0 && x.first() <= y.last() && y.first() <= x.last()
}

/// One pre-resolved lane of a wave.
#[derive(Debug, Clone, Copy)]
struct PlanLane {
    a: ArenaView,
    /// `EMPTY` (len 0) for unary ops.
    b: ArenaView,
    out: ArenaView,
    /// Fused dot→act destination address, or `usize::MAX` when unfused.
    fused_out: usize,
    /// Elementwise/ACT lanes whose output aliases an input in a
    /// non-identical way must stage results before scatter (preserves the
    /// read-all-then-write semantics of the pre-plan simulator).
    staged: bool,
}

/// One compiled wave: resolved lanes + precomputed cycle charges.
#[derive(Debug, Clone)]
struct PlanWave {
    op: Opcode,
    vec_len: usize,
    /// LUT of an `ACTIVATION_FUNCTION` wave.
    lut: Option<usize>,
    /// LUT of a fused dot→act wave.
    fused_lut: Option<usize>,
    lanes: Vec<PlanLane>,
    compute_cycles: Cycle,
    ring_cycles: Cycle,
    /// Waves accounted for (2 when a dot→act pair was fused).
    waves: u64,
    lane_ops: u64,
    /// Lanes proven independent — eligible for the worker pool.
    parallel: bool,
    /// Index of the originating step in the source [`Program`].
    src_step: usize,
}

/// One compiled schedule step.
#[derive(Debug, Clone)]
enum PlanStep {
    /// DDR DMA with the precomputed cycle/byte charge.
    Dma { cycles: Cycle, bytes: u64 },
    /// LUT stream; charged per the residency rules at run time.
    LoadLut { lut: usize, cycles: Cycle },
    /// A compiled wave.
    Wave(PlanWave),
}

/// One optimisation claim the plan makes about a source wave — what the
/// static checker's hazard oracle certifies independently
/// (`analysis::hazard`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaveClaim {
    /// Index of the originating step in the source [`Program`].
    pub src_step: usize,
    /// The plan fused this dot with the following activation wave.
    pub fused: bool,
    /// The plan claims the lanes independent (worker-pool eligible).
    pub parallel: bool,
}

/// Mutable run state of a plan: the lane arena + LUT residency.
///
/// Cheap to clone; several states may execute against one shared plan.
#[derive(Debug, Clone)]
pub struct PlanState {
    arena: Vec<i16>,
    lut_resident: Vec<bool>,
}

/// A compiled, arena-backed execution plan for one [`Program`] on one
/// [`FpgaDevice`]. Built once; executed many times against a
/// [`PlanState`].
pub struct ExecPlan {
    name: String,
    fixed: FixedSpec,
    /// `(arena base, lane count)` per program buffer.
    bufs: Vec<(usize, usize)>,
    arena_init: Vec<i16>,
    luts: Vec<ActLut>,
    /// Tables fit the ACTPRO groups → each streams at most once.
    lut_static: bool,
    steps: Vec<PlanStep>,
    /// This plan's parallelism cap including the calling thread
    /// (`min(host cores, processor groups)`); the threads themselves
    /// live in the process-wide [`lane_pool`].
    pool_threads: usize,
    fused_waves: usize,
    parallel_waves: usize,
}

impl fmt::Debug for ExecPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExecPlan")
            .field("name", &self.name)
            .field("steps", &self.steps.len())
            .field("arena_lanes", &self.arena_init.len())
            .field("fused_waves", &self.fused_waves)
            .field("parallel_waves", &self.parallel_waves)
            .field("pool_threads", &self.pool_threads)
            .finish()
    }
}

// ---------------------------------------------------------------- building

/// Resolve a program view against the arena layout.
fn resolve(bufs: &[(usize, usize)], v: &View) -> ArenaView {
    ArenaView { base: bufs[v.buf].0 + v.offset, len: v.len, stride: v.stride }
}

/// Cycle cost of one wave — the exact arithmetic of the pre-plan
/// `MatrixMachine::wave_cycles`, evaluated once at plan time.
fn wave_cycles(
    device: &FpgaDevice,
    lut_static: bool,
    lut_groups: &[u64],
    w: &Wave,
) -> (Cycle, Cycle) {
    let act = w.op == Opcode::ActivationFunction;
    let groups_raw: u64 = if act {
        if lut_static {
            lut_groups[w.lut.expect("checked: ACT wave has LUT")]
        } else {
            device.actpro_groups.max(1) as u64
        }
    } else {
        device.mvm_groups.max(1) as u64
    };
    let groups = groups_raw.max(1);
    let batch_cost = |procs: usize| -> u64 {
        if act {
            structural_actpro_batch_cycles(w.vec_len, procs)
        } else {
            structural_mvm_batch_cycles(w.op, w.vec_len, procs)
        }
    };
    let lanes = w.lanes.len() as u64;
    let procs_total = groups * PROCS_PER_GROUP as u64;
    let full_waves = lanes / procs_total;
    let rem_lanes = lanes % procs_total;
    let mut compute = full_waves * batch_cost(PROCS_PER_GROUP);
    if rem_lanes > 0 {
        let procs = (rem_lanes as usize).div_ceil(groups as usize).min(PROCS_PER_GROUP);
        compute += batch_cost(procs);
    }
    let wavefronts = full_waves + (rem_lanes > 0) as u64;
    let ring = wavefronts * (groups + 1);
    (compute, ring)
}

/// Does this lane need read-all-then-write staging to match the
/// sequential simulator bit-for-bit?
fn needs_staging(op: Opcode, a: &ArenaView, b: &ArenaView, out: &ArenaView) -> bool {
    match op {
        Opcode::VectorAddition
        | Opcode::VectorSubtraction
        | Opcode::ElementMultiplication
        | Opcode::ActivationFunction => {
            // Identical views (pure in-place) or disjoint intervals are
            // safe elementwise; anything else stages.
            let ok_a = out == a || !overlaps(out, a);
            let ok_b = b.len == 0 || out == b || !overlaps(out, b);
            !(ok_a && ok_b)
        }
        // Reductions read everything before their single write.
        _ => false,
    }
}

/// Sorted-interval sweep: does any interval of `a` overlap one of `b`?
/// Both slices sorted by start.
fn any_overlap(a: &[(usize, usize, usize)], b: &[(usize, usize, usize)]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (a_s, a_e, _) = a[i];
        let (b_s, b_e, _) = b[j];
        if a_s <= b_e && b_s <= a_e {
            return true;
        }
        if a_e < b_e {
            i += 1;
        } else {
            j += 1;
        }
    }
    false
}

/// Prove (conservatively) that the lanes of a wave are mutually
/// independent: no lane's outputs touch another lane's inputs or outputs.
fn lanes_independent(lanes: &[PlanLane]) -> bool {
    let n = lanes.len();
    if n < 2 {
        return false;
    }
    // Output intervals (fused single-lane writes included, except the
    // pure in-place case where the fused write lands on the lane's own
    // dot output).
    let mut outs: Vec<(usize, usize, usize)> = Vec::with_capacity(2 * n);
    for (i, l) in lanes.iter().enumerate() {
        outs.push((l.out.first(), l.out.last(), i));
        if l.fused_out != usize::MAX && l.fused_out != l.out.base {
            outs.push((l.fused_out, l.fused_out, i));
        }
    }
    outs.sort_unstable();
    // Cross-lane output overlap kills parallelism outright (and would
    // make results order-dependent even sequentially — keep order).
    let mut max_end = outs[0].1;
    for w in outs.windows(2) {
        if w[1].0 <= max_end {
            return false;
        }
        max_end = max_end.max(w[1].1);
    }
    // Strict check: outputs disjoint from every input interval.
    let mut ins: Vec<(usize, usize, usize)> = Vec::with_capacity(2 * n);
    for (i, l) in lanes.iter().enumerate() {
        ins.push((l.a.first(), l.a.last(), i));
        if l.b.len > 0 {
            ins.push((l.b.first(), l.b.last(), i));
        }
    }
    ins.sort_unstable();
    if !any_overlap(&outs, &ins) {
        return true;
    }
    // In-place waves (out == own input) fail the strict check; fall back
    // to pairwise with the own-lane exemption.
    if n > PAIRWISE_MAX_LANES {
        return false;
    }
    for (i, li) in lanes.iter().enumerate() {
        let mut own: [(usize, usize); 2] = [(0, 0); 2];
        let mut n_own = 0usize;
        if li.out.len > 0 {
            own[n_own] = (li.out.first(), li.out.last());
            n_own += 1;
        }
        if li.fused_out != usize::MAX {
            own[n_own] = (li.fused_out, li.fused_out);
            n_own += 1;
        }
        for (j, lj) in lanes.iter().enumerate() {
            if i == j {
                continue;
            }
            for &(s, e) in &own[..n_own] {
                if s <= lj.a.last() && lj.a.first() <= e {
                    return false;
                }
                if lj.b.len > 0 && s <= lj.b.last() && lj.b.first() <= e {
                    return false;
                }
            }
        }
    }
    true
}

/// Try to fuse an adjacent dot→activation pair. Returns the fused act
/// destination address per dot lane (`usize::MAX` = dot lane's output is
/// not consumed by the act wave) when the act wave reads **exactly** the
/// dot outputs and no write of either wave can corrupt a later read.
fn try_fuse(bufs: &[(usize, usize)], dot: &Wave, act: &Wave) -> Option<Vec<usize>> {
    if dot.op != Opcode::VectorDotProduct || act.op != Opcode::ActivationFunction {
        return None;
    }
    let dot_addrs = dot.lanes.len() * (2 * dot.vec_len + 1);
    let act_addrs = act.lanes.len() * 2 * act.vec_len;
    if dot_addrs + act_addrs > FUSE_MAX_ADDRS {
        return None;
    }
    // Dot outputs: single lanes, all distinct.
    let mut out_lane: HashMap<usize, usize> = HashMap::with_capacity(dot.lanes.len());
    for (i, l) in dot.lanes.iter().enumerate() {
        let o = resolve(bufs, &l.out);
        if o.len != 1 || out_lane.insert(o.base, i).is_some() {
            return None;
        }
    }
    // Dot inputs; a dot chain (one lane reading another's output) cannot
    // fuse because the act write would land before the dependent read.
    let mut dot_in: HashSet<usize> = HashSet::with_capacity(dot_addrs);
    for l in &dot.lanes {
        for addr in resolve(bufs, &l.a).addrs() {
            dot_in.insert(addr);
        }
        if let Some(b) = &l.b {
            for addr in resolve(bufs, b).addrs() {
                dot_in.insert(addr);
            }
        }
    }
    if out_lane.keys().any(|a| dot_in.contains(a)) {
        return None;
    }
    // Act elements: every input must be a distinct dot output; act writes
    // must not clobber dot inputs, other dot outputs, or other act
    // inputs (in-place `out == in` is allowed).
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(act_addrs / 2);
    let mut act_in: HashSet<usize> = HashSet::with_capacity(act_addrs / 2);
    for l in &act.lanes {
        let av = resolve(bufs, &l.a);
        let ov = resolve(bufs, &l.out);
        if av.len != ov.len {
            return None;
        }
        for (ia, oa) in av.addrs().zip(ov.addrs()) {
            act_in.insert(ia);
            pairs.push((ia, oa));
        }
    }
    let mut fused_out = vec![usize::MAX; dot.lanes.len()];
    let mut act_out_seen: HashSet<usize> = HashSet::with_capacity(pairs.len());
    for &(ia, oa) in &pairs {
        let &lane = out_lane.get(&ia)?;
        if fused_out[lane] != usize::MAX {
            return None; // dot output consumed twice
        }
        if oa != ia && (out_lane.contains_key(&oa) || act_in.contains(&oa)) {
            return None;
        }
        if dot_in.contains(&oa) || !act_out_seen.insert(oa) {
            return None;
        }
        fused_out[lane] = oa;
    }
    Some(fused_out)
}

impl ExecPlan {
    /// Compile `program` for `device` with all optimisations on.
    /// The program must already have passed [`Program::check`].
    pub fn new(program: &Program, device: &FpgaDevice) -> ExecPlan {
        ExecPlan::build(program, device, true, false)
    }

    /// Compile without dot→act fusion — one [`PlanWave`] per program
    /// wave, as required by [`ExecPlan::execute_verified`].
    pub fn new_unfused(program: &Program, device: &FpgaDevice) -> ExecPlan {
        ExecPlan::build(program, device, false, false)
    }

    /// Compile with the static memory planner's lane-reuse layout
    /// ([`super::memplan::MemPlan`]): temporaries with disjoint live
    /// intervals share arena lanes, shrinking [`ExecPlan::arena_len`] to
    /// the planner's peak demand. Outputs and `RunStats` stay
    /// bit-identical to [`ExecPlan::new`] (every cycle charge is
    /// address-independent; the `memplan` fuzz family enforces this).
    pub fn new_planned(program: &Program, device: &FpgaDevice) -> ExecPlan {
        ExecPlan::build(program, device, true, true)
    }

    /// Planned layout without fusion (see [`ExecPlan::new_planned`]).
    pub fn new_unfused_planned(program: &Program, device: &FpgaDevice) -> ExecPlan {
        ExecPlan::build(program, device, false, true)
    }

    fn build(program: &Program, device: &FpgaDevice, fuse: bool, planned: bool) -> ExecPlan {
        // Arena layout: buffers packed back to back, or the memory
        // planner's lane-reuse layout (DESIGN.md §Memory planner).
        let (bufs, arena_len) = if planned {
            let mp = super::memplan::MemPlan::build(program);
            (mp.layout().to_vec(), mp.peak_lanes())
        } else {
            let mut bufs = Vec::with_capacity(program.buffers.len());
            let mut arena_len = 0usize;
            for b in &program.buffers {
                bufs.push((arena_len, b.len()));
                arena_len += b.len();
            }
            (bufs, arena_len)
        };
        let mut arena_init = vec![0i16; arena_len];
        for (decl, &(base, len)) in program.buffers.iter().zip(&bufs) {
            if let Some(d) = &decl.init {
                assert_eq!(d.len(), len, "const init length mismatch");
                arena_init[base..base + len].copy_from_slice(d);
            }
        }
        // LUT → ACTPRO-group residency partition (identical to the
        // pre-plan machine).
        let n_luts = program.luts.len();
        let agroups = device.actpro_groups.max(1) as u64;
        let lut_static = (n_luts as u64) <= agroups;
        let lut_groups: Vec<u64> = if n_luts == 0 {
            Vec::new()
        } else if lut_static {
            let base = agroups / n_luts as u64;
            let extra = agroups % n_luts as u64;
            (0..n_luts as u64).map(|i| base + u64::from(i < extra)).collect()
        } else {
            vec![agroups; n_luts]
        };
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let groups = device.mvm_groups.max(device.actpro_groups).max(1) as usize;
        let mut plan = ExecPlan {
            name: program.name.clone(),
            fixed: program.fixed,
            bufs,
            arena_init,
            luts: program.luts.clone(),
            lut_static,
            steps: Vec::with_capacity(program.steps.len()),
            pool_threads: host.min(groups).max(1),
            fused_waves: 0,
            parallel_waves: 0,
        };
        let lut_stream_cycles = |l: usize| -> Cycle {
            (program.luts[l].table().len() as u64 / 2 + 1) * PROCS_PER_GROUP as u64
        };
        let src = &program.steps;
        let mut i = 0usize;
        while i < src.len() {
            match &src[i] {
                Step::LoadDram(b) | Step::StoreDram(b) => {
                    let bytes = program.buffers[*b].len() as u64 * 2;
                    plan.steps.push(PlanStep::Dma { cycles: device.dma_cycles(bytes), bytes });
                    i += 1;
                }
                Step::LoadLut(l) => {
                    plan.steps.push(PlanStep::LoadLut { lut: *l, cycles: lut_stream_cycles(*l) });
                    i += 1;
                }
                Step::Wave(w) => {
                    // Fusion lookahead: dot at i, optionally `LoadLut` of
                    // the act wave's own table at i+1, act at i+1 / i+2.
                    if fuse && w.op == Opcode::VectorDotProduct {
                        let (lut_step, act_idx) = match src.get(i + 1) {
                            Some(Step::LoadLut(l)) => (Some(*l), i + 2),
                            _ => (None, i + 1),
                        };
                        if let Some(Step::Wave(act)) = src.get(act_idx) {
                            if act.op == Opcode::ActivationFunction
                                && lut_step.is_none_or(|l| Some(l) == act.lut)
                            {
                                if let Some(fused_out) = try_fuse(&plan.bufs, w, act) {
                                    if let Some(l) = lut_step {
                                        plan.steps.push(PlanStep::LoadLut {
                                            lut: l,
                                            cycles: lut_stream_cycles(l),
                                        });
                                    }
                                    let (c1, r1) = wave_cycles(device, lut_static, &lut_groups, w);
                                    let (c2, r2) =
                                        wave_cycles(device, lut_static, &lut_groups, act);
                                    let mut pw =
                                        plan.compile_wave(w, i, (c1 + c2, r1 + r2), arena_len);
                                    pw.fused_lut = act.lut;
                                    for (lane, &fo) in pw.lanes.iter_mut().zip(&fused_out) {
                                        lane.fused_out = fo;
                                    }
                                    pw.waves = 2;
                                    pw.lane_ops += (act.lanes.len() * act.vec_len) as u64;
                                    pw.parallel = lanes_independent(&pw.lanes);
                                    plan.fused_waves += 1;
                                    if pw.parallel {
                                        plan.parallel_waves += 1;
                                    }
                                    plan.steps.push(PlanStep::Wave(pw));
                                    i = act_idx + 1;
                                    continue;
                                }
                            }
                        }
                    }
                    let charges = wave_cycles(device, lut_static, &lut_groups, w);
                    let pw = plan.compile_wave(w, i, charges, arena_len);
                    if pw.parallel {
                        plan.parallel_waves += 1;
                    }
                    plan.steps.push(PlanStep::Wave(pw));
                    i += 1;
                }
            }
        }
        plan
    }

    fn compile_wave(
        &self,
        w: &Wave,
        src_step: usize,
        (compute_cycles, ring_cycles): (Cycle, Cycle),
        arena_len: usize,
    ) -> PlanWave {
        let lanes: Vec<PlanLane> = w
            .lanes
            .iter()
            .map(|l| {
                let a = resolve(&self.bufs, &l.a);
                let b = l.b.as_ref().map_or(ArenaView::EMPTY, |b| resolve(&self.bufs, b));
                let out = resolve(&self.bufs, &l.out);
                // The raw-pointer executor relies on these bounds.
                assert!(a.last() < arena_len && out.last() < arena_len);
                assert!(b.len == 0 || b.last() < arena_len);
                let staged = needs_staging(w.op, &a, &b, &out);
                PlanLane { a, b, out, fused_out: usize::MAX, staged }
            })
            .collect();
        let parallel = lanes_independent(&lanes);
        PlanWave {
            op: w.op,
            vec_len: w.vec_len,
            lut: w.lut,
            fused_lut: None,
            lanes,
            compute_cycles,
            ring_cycles,
            waves: 1,
            lane_ops: (w.lanes.len() * w.vec_len) as u64,
            parallel,
            src_step,
        }
    }

    // ----------------------------------------------------------- accessors

    /// Program name the plan was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Fixed-point format of the datapath.
    pub fn fixed(&self) -> FixedSpec {
        self.fixed
    }

    /// Total lanes in the arena.
    pub fn arena_len(&self) -> usize {
        self.arena_init.len()
    }

    /// Number of dot→act pairs folded into single passes.
    pub fn fused_waves(&self) -> usize {
        self.fused_waves
    }

    /// Number of waves whose lanes were proven independent.
    pub fn parallel_waves(&self) -> usize {
        self.parallel_waves
    }

    /// The fusion/parallelism claims made per compiled wave, keyed by
    /// source step — consumed by the static hazard oracle.
    pub fn wave_claims(&self) -> Vec<WaveClaim> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                PlanStep::Wave(w) => Some(WaveClaim {
                    src_step: w.src_step,
                    fused: w.waves == 2,
                    parallel: w.parallel,
                }),
                _ => None,
            })
            .collect()
    }

    /// Worker-pool width (including the calling thread).
    pub fn pool_threads(&self) -> usize {
        self.pool_threads
    }

    /// Lane count of buffer `id`.
    pub fn buffer_len(&self, id: usize) -> usize {
        self.bufs[id].1
    }

    /// Fresh run state (buffers zeroed / constants applied).
    pub fn state(&self) -> PlanState {
        PlanState {
            arena: self.arena_init.clone(),
            lut_resident: vec![false; self.luts.len()],
        }
    }

    /// Overwrite buffer `id` (length must match the declaration).
    pub fn write_buffer(&self, st: &mut PlanState, id: usize, data: &[i16]) {
        let (base, len) = self.bufs[id];
        assert_eq!(len, data.len(), "buffer {id} length mismatch");
        st.arena[base..base + len].copy_from_slice(data);
    }

    /// Read buffer `id`.
    pub fn read_buffer<'a>(&self, st: &'a PlanState, id: usize) -> &'a [i16] {
        let (base, len) = self.bufs[id];
        &st.arena[base..base + len]
    }

    /// Batched-forward entry (the serving hot path): bind a micro-batch
    /// to input buffer `x`, execute the plan once, and return the `out`
    /// buffer's lanes — one plan invocation for the whole B-row bucket
    /// instead of B single-row runs. `qx` must be exactly the input
    /// buffer's declared lane count; callers pad partial buckets with
    /// zero rows (forward lanes are per-row, so padding never perturbs
    /// real rows).
    pub fn run_forward(
        &self,
        st: &mut PlanState,
        x: usize,
        qx: &[i16],
        out: usize,
    ) -> (Vec<i16>, RunStats) {
        self.write_buffer(st, x, qx);
        let stats = self.execute(st);
        (self.read_buffer(st, out).to_vec(), stats)
    }

    // ----------------------------------------------------------- execution

    /// Execute the plan against `st`, returning the run's cycle/work
    /// statistics. Bit-exact with the structural simulator; cycle charges
    /// identical to the pre-plan interpreter.
    pub fn execute(&self, st: &mut PlanState) -> RunStats {
        let mut stats = RunStats::default();
        for step in &self.steps {
            match step {
                PlanStep::Dma { cycles, bytes } => {
                    stats.dma_cycles += cycles;
                    stats.cycles += cycles;
                    stats.dma_bytes += bytes;
                }
                PlanStep::LoadLut { lut, cycles } => {
                    if !self.lut_static || !st.lut_resident[*lut] {
                        stats.lut_cycles += cycles;
                        stats.cycles += cycles;
                        st.lut_resident[*lut] = true;
                    }
                }
                PlanStep::Wave(w) => {
                    self.exec_wave(w, st);
                    stats.compute_cycles += w.compute_cycles;
                    stats.ring_cycles += w.ring_cycles;
                    stats.cycles += w.compute_cycles + w.ring_cycles;
                    stats.waves += w.waves;
                    stats.lane_ops += w.lane_ops;
                }
            }
        }
        stats
    }

    /// Execute with per-wave structural verification (slow; tests/CLI).
    /// Requires an unfused plan; returns the offending source step index
    /// on divergence.
    pub fn execute_verified(
        &self,
        st: &mut PlanState,
        _program: &Program,
    ) -> Result<RunStats, usize> {
        assert_eq!(self.fused_waves, 0, "verified execution requires an unfused plan");
        let mut stats = RunStats::default();
        for step in &self.steps {
            match step {
                PlanStep::Dma { cycles, bytes } => {
                    stats.dma_cycles += cycles;
                    stats.cycles += cycles;
                    stats.dma_bytes += bytes;
                }
                PlanStep::LoadLut { lut, cycles } => {
                    if !self.lut_static || !st.lut_resident[*lut] {
                        stats.lut_cycles += cycles;
                        stats.cycles += cycles;
                        st.lut_resident[*lut] = true;
                    }
                }
                PlanStep::Wave(w) => {
                    self.verify_wave(st, w)?;
                    stats.compute_cycles += w.compute_cycles;
                    stats.ring_cycles += w.ring_cycles;
                    stats.cycles += w.compute_cycles + w.ring_cycles;
                    stats.waves += w.waves;
                    stats.lane_ops += w.lane_ops;
                }
            }
        }
        Ok(stats)
    }

    /// Run one wave on the structural microcode interpreters from the
    /// pre-wave state, execute it on the plan path, and compare outputs
    /// lane-for-lane.
    fn verify_wave(&self, st: &mut PlanState, w: &PlanWave) -> Result<(), usize> {
        let procs = PROCS_PER_GROUP;
        let mut expected: Vec<(ArenaView, Vec<i16>)> = Vec::with_capacity(w.lanes.len());
        for chunk in w.lanes.chunks(procs) {
            let mut io = GroupIo::default();
            for lane in chunk {
                io.feed(&lane.a.gather(&st.arena));
                if w.op != Opcode::ActivationFunction
                    && w.op != Opcode::VectorSummation
                    && lane.b.len > 0
                {
                    io.feed(&lane.b.gather(&st.arena));
                }
            }
            let out_per_lane: usize;
            match w.op {
                Opcode::ActivationFunction => {
                    let lut = &self.luts[w.lut.expect("checked: ACT wave has LUT")];
                    let words = microcode_gen::actpro_batch(w.vec_len, chunk.len())
                        .expect("checked wave dims");
                    let mut g = ActproGroup::new(lut.clone());
                    g.execute(&words, &mut io);
                    out_per_lane = w.vec_len + (w.vec_len & 1);
                }
                op => {
                    let words = microcode_gen::mvm_batch(op, w.vec_len, chunk.len())
                        .expect("checked wave dims");
                    let mut g = MvmGroup::new(self.fixed);
                    g.execute(&words, &mut io);
                    out_per_lane = match op {
                        Opcode::VectorDotProduct | Opcode::VectorSummation => 1,
                        _ => w.vec_len,
                    };
                }
            }
            for (li, lane) in chunk.iter().enumerate() {
                let got = io.output[li * out_per_lane..li * out_per_lane + lane.out.len].to_vec();
                expected.push((lane.out, got));
            }
        }
        let arena_len = st.arena.len();
        let ptr = st.arena.as_mut_ptr();
        unsafe { self.exec_lane_range(w, ptr, arena_len, 0, w.lanes.len()) };
        for (view, want) in &expected {
            if view.gather(&st.arena) != *want {
                return Err(w.src_step);
            }
        }
        Ok(())
    }

    /// Execute one wave: parallel across the pool when proven safe and
    /// big enough, sequential otherwise.
    fn exec_wave(&self, w: &PlanWave, st: &mut PlanState) {
        let n = w.lanes.len();
        if w.parallel
            && self.pool_threads > 1
            && n >= PAR_MIN_LANES
            && n * w.vec_len >= PAR_MIN_LANE_OPS
        {
            self.exec_wave_parallel(w, st);
        } else {
            let arena_len = st.arena.len();
            let ptr = st.arena.as_mut_ptr();
            unsafe { self.exec_lane_range(w, ptr, arena_len, 0, n) };
        }
    }

    fn exec_wave_parallel(&self, w: &PlanWave, st: &mut PlanState) {
        // The lock makes pool use exclusive: machines dispatching from
        // different threads serialise their (short) wave hand-offs while
        // each wave's lanes still run across all workers.
        let pool = lane_pool().lock().expect("lane pool poisoned");
        let n = w.lanes.len();
        // Cap at this plan's device-derived width.
        let parts = (pool.workers() + 1).min(self.pool_threads);
        let per = n.div_ceil(parts);
        let arena_len = st.arena.len();
        let arena = st.arena.as_mut_ptr();
        let task = RawTask {
            plan: self as *const ExecPlan,
            wave: w as *const PlanWave,
            arena,
            arena_len,
        };
        let mut sent = 0usize;
        let mut lo = per.min(n);
        let mut worker = 0usize;
        while lo < n {
            let hi = (lo + per).min(n);
            pool.submit(worker, Job { task, lo, hi });
            worker += 1;
            sent += 1;
            lo = hi;
        }
        // Drain guard: if the inline execution below unwinds, block until
        // every dispatched job has finished before the arena (owned up
        // the stack) can be dropped.
        struct Drain<'a>(&'a PoolCore, usize);
        impl Drop for Drain<'_> {
            fn drop(&mut self) {
                for _ in 0..self.1 {
                    let _ = self.0.done_rx.recv();
                }
            }
        }
        let mut drain = Drain(&*pool, sent);
        // The calling thread is lane executor 0.
        unsafe { self.exec_lane_range(w, arena, arena_len, 0, per.min(n)) };
        drain.1 = 0; // disarm; the checked wait below consumes the dones
        drop(drain);
        pool.wait(sent);
    }

    /// Execute lanes `lo..hi` of `w` through the raw arena pointer.
    ///
    /// # Safety
    /// `arena` must point to `arena_len` lanes matching this plan's
    /// layout, and concurrent callers must cover disjoint lane ranges of
    /// a wave whose lanes were proven independent (`w.parallel`).
    unsafe fn exec_lane_range(
        &self,
        w: &PlanWave,
        arena: *mut i16,
        arena_len: usize,
        lo: usize,
        hi: usize,
    ) {
        debug_assert!(hi <= w.lanes.len() && self.arena_init.len() == arena_len);
        let s = self.fixed;
        let lanes = &w.lanes[lo..hi];
        match w.op {
            Opcode::Nop => {}
            Opcode::VectorDotProduct => {
                let flut = w.fused_lut.map(|l| &self.luts[l]);
                for lane in lanes {
                    let acc = if lane.a.stride == 1 && lane.b.stride == 1 {
                        let av = std::slice::from_raw_parts(
                            arena.add(lane.a.base) as *const i16,
                            lane.a.len,
                        );
                        let bv = std::slice::from_raw_parts(
                            arena.add(lane.b.base) as *const i16,
                            lane.a.len,
                        );
                        s.dot_acc(av, bv)
                    } else {
                        let mut acc = 0i64;
                        let (mut ia, mut ib) = (lane.a.base, lane.b.base);
                        for _ in 0..lane.a.len {
                            acc += *arena.add(ia) as i64 * *arena.add(ib) as i64;
                            ia += lane.a.stride;
                            ib += lane.b.stride;
                        }
                        acc
                    };
                    let v = s.rescale(acc);
                    *arena.add(lane.out.base) = v;
                    if lane.fused_out != usize::MAX {
                        *arena.add(lane.fused_out) =
                            flut.expect("fused lane has LUT").apply_scalar(v);
                    }
                }
            }
            Opcode::VectorSummation => {
                for lane in lanes {
                    let acc = if lane.a.stride == 1 {
                        let av = std::slice::from_raw_parts(
                            arena.add(lane.a.base) as *const i16,
                            lane.a.len,
                        );
                        av.iter().map(|&x| x as i64).sum::<i64>()
                    } else {
                        let mut acc = 0i64;
                        let mut ia = lane.a.base;
                        for _ in 0..lane.a.len {
                            acc += *arena.add(ia) as i64;
                            ia += lane.a.stride;
                        }
                        acc
                    };
                    *arena.add(lane.out.base) = s.narrow(acc);
                }
            }
            Opcode::ActivationFunction => {
                let lut = &self.luts[w.lut.expect("checked: ACT wave has LUT")];
                let mut scratch: Vec<i16> = Vec::new();
                for lane in lanes {
                    if lane.staged {
                        scratch.clear();
                        let mut ia = lane.a.base;
                        for _ in 0..lane.a.len {
                            scratch.push(lut.apply_scalar(*arena.add(ia)));
                            ia += lane.a.stride;
                        }
                        let mut io = lane.out.base;
                        for &v in &scratch {
                            *arena.add(io) = v;
                            io += lane.out.stride;
                        }
                    } else {
                        let (mut ia, mut io) = (lane.a.base, lane.out.base);
                        for _ in 0..lane.a.len {
                            *arena.add(io) = lut.apply_scalar(*arena.add(ia));
                            ia += lane.a.stride;
                            io += lane.out.stride;
                        }
                    }
                }
            }
            op => {
                let mut scratch: Vec<i16> = Vec::new();
                macro_rules! elementwise {
                    ($f:expr) => {
                        for lane in lanes {
                            if lane.staged {
                                scratch.clear();
                                let (mut ia, mut ib) = (lane.a.base, lane.b.base);
                                for _ in 0..lane.a.len {
                                    scratch.push($f(*arena.add(ia), *arena.add(ib)));
                                    ia += lane.a.stride;
                                    ib += lane.b.stride;
                                }
                                let mut io = lane.out.base;
                                for &v in &scratch {
                                    *arena.add(io) = v;
                                    io += lane.out.stride;
                                }
                            } else {
                                let (mut ia, mut ib, mut io) =
                                    (lane.a.base, lane.b.base, lane.out.base);
                                for _ in 0..lane.a.len {
                                    *arena.add(io) = $f(*arena.add(ia), *arena.add(ib));
                                    ia += lane.a.stride;
                                    ib += lane.b.stride;
                                    io += lane.out.stride;
                                }
                            }
                        }
                    };
                }
                match op {
                    Opcode::VectorAddition => elementwise!(|x, y| s.add(x, y)),
                    Opcode::VectorSubtraction => elementwise!(|x, y| s.sub(x, y)),
                    Opcode::ElementMultiplication => elementwise!(|x, y| s.mul(x, y)),
                    _ => unreachable!("non-wave opcode {op} in plan"),
                }
            }
        }
    }
}

// ------------------------------------------------------------- worker pool

/// A dispatched lane range. The raw pointers stay valid because the
/// dispatcher blocks on completion before returning.
#[derive(Clone, Copy)]
struct RawTask {
    plan: *const ExecPlan,
    wave: *const PlanWave,
    arena: *mut i16,
    arena_len: usize,
}

struct Job {
    task: RawTask,
    lo: usize,
    hi: usize,
}

// SAFETY: the dispatcher keeps plan/wave/arena alive and lane ranges
// disjoint for the whole job lifetime (it blocks in `PoolCore::wait`).
unsafe impl Send for Job {}

fn worker_loop(rx: Receiver<Job>, done: Sender<bool>) {
    while let Ok(job) = rx.recv() {
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let plan = unsafe { &*job.task.plan };
            let wave = unsafe { &*job.task.wave };
            unsafe {
                plan.exec_lane_range(wave, job.task.arena, job.task.arena_len, job.lo, job.hi)
            };
        }))
        .is_ok();
        if done.send(ok).is_err() {
            break;
        }
    }
}

/// Process-wide lane worker pool shared by every plan: one set of
/// threads no matter how many machines/trainers exist. Workers idle on
/// their job channels between waves.
static LANE_POOL: OnceLock<Mutex<PoolCore>> = OnceLock::new();

/// Workers spawned on first use: `host cores − 1` (the dispatching
/// thread is always lane executor 0), capped at 15 so a wave never
/// spreads wider than the largest board's 16 processor groups.
fn lane_pool() -> &'static Mutex<PoolCore> {
    LANE_POOL.get_or_init(|| {
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Mutex::new(PoolCore::new(host.saturating_sub(1).min(15)))
    })
}

/// Persistent lane workers. Threads exit when the job senders are
/// dropped (never, for the process-wide [`LANE_POOL`]).
struct PoolCore {
    txs: Vec<Sender<Job>>,
    done_rx: Receiver<bool>,
    handles: Vec<JoinHandle<()>>,
}

impl PoolCore {
    fn new(workers: usize) -> PoolCore {
        let (done_tx, done_rx) = channel();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            let dt = done_tx.clone();
            match std::thread::Builder::new()
                .name(format!("mfnn-lane-{i}"))
                .spawn(move || worker_loop(rx, dt))
            {
                Ok(h) => {
                    txs.push(tx);
                    handles.push(h);
                }
                Err(_) => break, // run with fewer workers
            }
        }
        PoolCore { txs, done_rx, handles }
    }

    fn workers(&self) -> usize {
        self.txs.len()
    }

    fn submit(&self, worker: usize, job: Job) {
        self.txs[worker].send(job).expect("lane worker hung up");
    }

    fn wait(&self, n: usize) {
        for _ in 0..n {
            let ok = self.done_rx.recv().expect("lane worker hung up");
            assert!(ok, "lane worker panicked during wave execution");
        }
    }
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        self.txs.clear(); // disconnect → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp};
    use crate::hw::fast::FastSim;
    use crate::nn::lut::{ActKind, AddrMode};
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    fn device() -> FpgaDevice {
        FpgaDevice::selected()
    }

    /// dot → act over the dot outputs, fusable.
    fn fused_program(lanes: usize, len: usize, in_place: bool) -> Program {
        let mut p = Program::new("fuse", S);
        let a = p.buffer("a", lanes, len, BufKind::Input);
        let z = p.buffer("z", lanes, 1, BufKind::Temp);
        let o = p.buffer("o", lanes, 1, BufKind::Output);
        let lut = p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        let dots: Vec<LaneOp> = (0..lanes)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * len, len),
                b: Some(View::contiguous(a, ((i + 1) % lanes) * len, len)),
                out: View::contiguous(z, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: len,
            lut: None,
            lanes: dots,
        }));
        p.steps.push(Step::LoadLut(lut));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: lanes,
            lut: Some(lut),
            lanes: vec![LaneOp {
                a: View::all(z, lanes),
                b: None,
                out: if in_place { View::all(z, lanes) } else { View::all(o, lanes) },
            }],
        }));
        p
    }

    fn run_fast_reference(p: &Program, binds: &[(usize, Vec<i16>)]) -> FastSim {
        let mut sim = FastSim::new(p);
        for (id, data) in binds {
            sim.set_buffer(*id, data);
        }
        for step in &p.steps {
            if let Step::Wave(w) = step {
                sim.exec_wave(p, w);
            }
        }
        sim
    }

    #[test]
    fn arena_layout_packs_buffers() {
        let mut p = Program::new("t", S);
        let a = p.buffer("a", 4, 2, BufKind::Input);
        let b = p.const_buffer("b", vec![1, 2, 3]);
        let plan = ExecPlan::new(&p, &device());
        assert_eq!(plan.arena_len(), 11);
        assert_eq!(plan.buffer_len(a), 8);
        let st = plan.state();
        assert_eq!(plan.read_buffer(&st, b), &[1, 2, 3]);
        assert_eq!(plan.read_buffer(&st, a), &[0; 8]);
    }

    #[test]
    fn planned_layout_is_bit_exact_and_smaller() {
        // Two disjoint-lifetime temps: the planner overlays them, and
        // execution plus cycle accounting must not change.
        let mut p = Program::new("planned", S);
        let x = p.buffer("x", 8, 1, BufKind::Input);
        let t1 = p.buffer("t1", 8, 1, BufKind::Temp);
        let t2 = p.buffer("t2", 8, 1, BufKind::Temp);
        let o = p.buffer("o", 8, 1, BufKind::Output);
        let mk = |a: View, b: View, out: View| {
            Step::Wave(Wave {
                op: Opcode::VectorAddition,
                vec_len: 8,
                lut: None,
                lanes: vec![LaneOp { a, b: Some(b), out }],
            })
        };
        p.steps.push(mk(View::all(x, 8), View::all(x, 8), View::all(t1, 8)));
        p.steps.push(mk(View::all(t1, 8), View::all(x, 8), View::all(o, 8)));
        p.steps.push(mk(View::all(o, 8), View::all(o, 8), View::all(t2, 8)));
        p.steps.push(mk(View::all(t2, 8), View::all(x, 8), View::all(o, 8)));
        p.check().unwrap();
        let packed = ExecPlan::new(&p, &device());
        let planned = ExecPlan::new_planned(&p, &device());
        assert!(planned.arena_len() < packed.arena_len());
        let data: Vec<i16> = (0..8).map(|i| (i * 3 - 9) as i16).collect();
        let mut s1 = packed.state();
        let mut s2 = planned.state();
        packed.write_buffer(&mut s1, x, &data);
        planned.write_buffer(&mut s2, x, &data);
        let st1 = packed.execute(&mut s1);
        let st2 = planned.execute(&mut s2);
        assert_eq!(st1, st2, "cycle accounting must not change under planning");
        assert_eq!(packed.read_buffer(&s1, o), planned.read_buffer(&s2, o));
        assert_eq!(packed.read_buffer(&s1, x), planned.read_buffer(&s2, x));
    }

    #[test]
    fn run_forward_is_write_execute_read_in_one_call() {
        let p = fused_program(16, 8, false);
        let plan = ExecPlan::new(&p, &device());
        let mut r = Rng::new(9);
        let data: Vec<i16> = (0..16 * 8).map(|_| r.gen_range_i64(-4000, 4000) as i16).collect();
        // reference: the three separate calls
        let mut st_ref = plan.state();
        plan.write_buffer(&mut st_ref, 0, &data);
        let stats_ref = plan.execute(&mut st_ref);
        let out_ref = plan.read_buffer(&st_ref, 2).to_vec();
        // batched entry on a fresh state
        let mut st = plan.state();
        let (out, stats) = plan.run_forward(&mut st, 0, &data, 2);
        assert_eq!(out, out_ref);
        assert_eq!(stats, stats_ref);
        // steady state: a second batch on the same state re-uses the
        // resident LUT, exactly like repeated execute() calls
        plan.write_buffer(&mut st_ref, 0, &data);
        let stats2_ref = plan.execute(&mut st_ref);
        let (_, stats2) = plan.run_forward(&mut st, 0, &data, 2);
        assert_eq!(stats2, stats2_ref);
    }

    #[test]
    fn dot_act_pair_fuses_and_matches_reference() {
        for in_place in [false, true] {
            let p = fused_program(16, 8, in_place);
            p.check().unwrap();
            let plan = ExecPlan::new(&p, &device());
            assert_eq!(plan.fused_waves(), 1, "in_place={in_place}");
            let mut r = Rng::new(7);
            let data: Vec<i16> = (0..16 * 8).map(|_| r.gen_range_i64(-4000, 4000) as i16).collect();
            let mut st = plan.state();
            plan.write_buffer(&mut st, 0, &data);
            let stats = plan.execute(&mut st);
            assert_eq!(stats.waves, 2, "fused wave still accounts for both");
            let reference = run_fast_reference(&p, &[(0, data)]);
            for id in 0..p.buffers.len() {
                assert_eq!(plan.read_buffer(&st, id), reference.buffer(id), "buffer {id}");
            }
        }
    }

    #[test]
    fn fusion_refused_when_act_reads_extra_lanes() {
        // Act wave reads one more lane than the dot wave produced.
        let mut p = Program::new("nofuse", S);
        let a = p.buffer("a", 4, 8, BufKind::Input);
        let z = p.buffer("z", 5, 1, BufKind::Temp);
        let lut = p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        let dots: Vec<LaneOp> = (0..4)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * 8, 8),
                b: Some(View::contiguous(a, i * 8, 8)),
                out: View::contiguous(z, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 8,
            lut: None,
            lanes: dots,
        }));
        p.steps.push(Step::LoadLut(lut));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: 5,
            lut: Some(lut),
            lanes: vec![LaneOp { a: View::all(z, 5), b: None, out: View::all(z, 5) }],
        }));
        p.check().unwrap();
        let plan = ExecPlan::new(&p, &device());
        assert_eq!(plan.fused_waves(), 0);
    }

    #[test]
    fn fused_and_unfused_charge_identical_cycles() {
        let p = fused_program(16, 8, false);
        let fused = ExecPlan::new(&p, &device());
        let unfused = ExecPlan::new_unfused(&p, &device());
        assert_eq!(fused.fused_waves(), 1);
        assert_eq!(unfused.fused_waves(), 0);
        let mut r = Rng::new(8);
        let data: Vec<i16> = (0..16 * 8).map(|_| r.gen_i16()).collect();
        let mut s1 = fused.state();
        let mut s2 = unfused.state();
        fused.write_buffer(&mut s1, 0, &data);
        unfused.write_buffer(&mut s2, 0, &data);
        let st1 = fused.execute(&mut s1);
        let st2 = unfused.execute(&mut s2);
        assert_eq!(st1, st2, "cycle accounting must not change under fusion");
        for id in 0..p.buffers.len() {
            assert_eq!(fused.read_buffer(&s1, id), unfused.read_buffer(&s2, id));
        }
    }

    #[test]
    fn wide_independent_wave_runs_parallel_and_bit_exact() {
        // 1024 lanes × 32 els = 32768 lane-ops ≥ PAR_MIN_LANE_OPS.
        let lanes_n = 1024usize;
        let len = 32usize;
        let mut p = Program::new("wide", S);
        let a = p.buffer("a", lanes_n, len, BufKind::Input);
        let o = p.buffer("o", lanes_n, len, BufKind::Output);
        let lanes: Vec<LaneOp> = (0..lanes_n)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * len, len),
                b: Some(View::contiguous(a, ((i + 13) % lanes_n) * len, len)),
                out: View::contiguous(o, i * len, len),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ElementMultiplication,
            vec_len: len,
            lut: None,
            lanes,
        }));
        p.check().unwrap();
        let plan = ExecPlan::new(&p, &device());
        assert_eq!(plan.parallel_waves(), 1, "lanes are provably independent");
        let mut r = Rng::new(9);
        let data: Vec<i16> = (0..lanes_n * len).map(|_| r.gen_i16()).collect();
        let mut st = plan.state();
        plan.write_buffer(&mut st, a, &data);
        plan.execute(&mut st);
        let reference = run_fast_reference(&p, &[(a, data)]);
        assert_eq!(plan.read_buffer(&st, o), reference.buffer(o));
    }

    #[test]
    fn overlapping_lanes_fall_back_to_sequential() {
        // Lane 1 reads lane 0's output: order matters, must not go
        // parallel.
        let mut p = Program::new("dep", S);
        let x = p.buffer("x", 3, 4, BufKind::Input);
        let lanes = vec![
            LaneOp {
                a: View::contiguous(x, 0, 4),
                b: Some(View::contiguous(x, 0, 4)),
                out: View::contiguous(x, 4, 4),
            },
            LaneOp {
                a: View::contiguous(x, 4, 4),
                b: Some(View::contiguous(x, 4, 4)),
                out: View::contiguous(x, 8, 4),
            },
        ];
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 4,
            lut: None,
            lanes,
        }));
        p.check().unwrap();
        let plan = ExecPlan::new(&p, &device());
        assert_eq!(plan.parallel_waves(), 0);
        let data: Vec<i16> = (1..=12).collect();
        let mut st = plan.state();
        plan.write_buffer(&mut st, x, &data);
        plan.execute(&mut st);
        let reference = run_fast_reference(&p, &[(x, data)]);
        assert_eq!(plan.read_buffer(&st, x), reference.buffer(x));
    }

    #[test]
    fn in_place_bias_adds_are_recognised_independent() {
        // out == a (in-place), shared read-only b: the pairwise check
        // with own-lane exemption must accept this.
        let rows = 16usize;
        let cols = 8usize;
        let mut p = Program::new("bias", S);
        let z = p.buffer("z", rows, cols, BufKind::Temp);
        let b = p.buffer("b", cols, 1, BufKind::Bias);
        let lanes: Vec<LaneOp> = (0..rows)
            .map(|i| LaneOp {
                a: View::contiguous(z, i * cols, cols),
                b: Some(View::all(b, cols)),
                out: View::contiguous(z, i * cols, cols),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: cols,
            lut: None,
            lanes,
        }));
        p.check().unwrap();
        let plan = ExecPlan::new(&p, &device());
        assert_eq!(plan.parallel_waves(), 1);
    }

    #[test]
    fn staged_lane_matches_read_all_then_write_semantics() {
        // out overlaps a shifted by one: the staged path must reproduce
        // FastSim's gather-then-scatter result exactly.
        let mut p = Program::new("shift", S);
        let x = p.buffer("x", 8, 1, BufKind::Input);
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 4,
            lut: None,
            lanes: vec![LaneOp {
                a: View::contiguous(x, 0, 4),
                b: Some(View::contiguous(x, 0, 4)),
                out: View::contiguous(x, 1, 4),
            }],
        }));
        p.check().unwrap();
        let plan = ExecPlan::new(&p, &device());
        let data: Vec<i16> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let mut st = plan.state();
        plan.write_buffer(&mut st, x, &data);
        plan.execute(&mut st);
        let reference = run_fast_reference(&p, &[(x, data)]);
        assert_eq!(plan.read_buffer(&st, x), reference.buffer(x));
    }
}
