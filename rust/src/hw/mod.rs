//! The **Matrix Machine** hardware, simulated (paper §4, Figs 4–10).
//!
//! The paper evaluates on Xilinx 7-series FPGAs we do not have; per the
//! substitution rule (DESIGN.md §2) this module is a from-scratch simulator
//! of the proposed design, at two fidelity levels:
//!
//! * **Structural / cycle-accurate** ([`bram`], [`dsp48`], [`counter`],
//!   [`mvm`], [`actpro`], [`group`], [`fifo`]): each component is a clocked
//!   state machine stepped one cycle at a time, with the port widths, BRAM
//!   geometry (RAMB18E1 = 1024 × 16-bit, dual-port), DSP48E1 6-stage
//!   pipeline, and FSM encodings from Tables 4–7. This level reproduces the
//!   paper's timing diagrams (Fig 7 write, Fig 8 vector addition, Fig 10
//!   ReLU) — rendered by [`trace`] — and provides measured per-op cycle
//!   counts that EXPERIMENTS.md compares against the analytic model
//!   (Eqns 5–9, implemented in [`crate::perf`]).
//! * **Functional / fast** ([`fast`], [`machine`]): executes whole tensor
//!   programs (what the Matrix Assembler emits) with bit-identical numerics
//!   but charges cycles from the per-op model instead of stepping every
//!   flip-flop. This is the engine used for end-to-end MLP training and the
//!   cluster experiments. Equivalence between the two levels is asserted by
//!   tests in `rust/tests/sim_equivalence.rs`.
//!
//! ### Reconstructed micro-architecture
//!
//! The paper's figures are images; the written description leaves the
//! column/addressing scheme implicit. We reconstruct it as follows (used
//! consistently by the structural sim, the assembler and the VHDL backend):
//!
//! * Each MVM's **left BRAM** holds the two operand vectors as *columns*:
//!   column 0 = addresses `0..512`, column 1 = `512..1024`. The microcode's
//!   input-column select is the address MSB for input writes; dual ports
//!   read `A[i]` (port 0, column 0) and `B[i]` (port 1, column 1)
//!   simultaneously during compute, so a vector op sees both operands each
//!   cycle. A vector therefore has at most [`COLUMN_LEN`] = 512 lanes.
//! * The **right BRAM**'s MSB select (`processor_control(3)`, Table 5)
//!   picks the output column; port 0 writes DSP results, port 1 drains.
//! * The DSP48E1 runs as a 6-stage pipeline (Fig 8): operands sampled at
//!   cycle *t* appear on `P` at cycle *t+6*; with the BRAM read at cycle 2
//!   and write-back at cycle 9, a length-`L` elementwise op occupies
//!   `L + 7` cycles after setup — matching the paper's `C_RUN = 519` for
//!   `L = 512`.
//! * The ACTPRO pipeline (Fig 10) is read → dual 7-bit shift → LUT BRAM
//!   lookup → write, 7 cycles of latency, matching `C_RUN = 517`.

pub mod actpro;
pub mod bram;
pub mod counter;
pub mod dsp48;
pub mod fast;
pub mod fifo;
pub mod fpga;
pub mod group;
pub mod machine;
pub mod memplan;
pub mod mvm;
pub mod plan;
pub mod trace;
pub mod trace_figures;

pub use fast::FastSim;
pub use fpga::FpgaDevice;
pub use machine::{MatrixMachine, RunStats};
pub use memplan::{Interval, MemPlan, PlanError};
pub use plan::{ExecPlan, PlanState, WaveClaim};

/// Simulated clock cycle count.
pub type Cycle = u64;

/// Depth of one BRAM (RAMB18E1 stores 1024 × 16-bit, paper §4.2).
pub const BRAM_DEPTH: usize = 1024;

/// Lanes per column (two operand columns per left BRAM).
pub const COLUMN_LEN: usize = BRAM_DEPTH / 2;

/// DSP48E1 pipeline depth ("configured as a 6 stage pipeline", §4.2).
pub const DSP_PIPELINE_STAGES: usize = 6;

/// Processors per group (4, behind a 4:1 mux — §3.3, §4.1).
pub const PROCS_PER_GROUP: usize = crate::isa::microcode::PROCS_PER_GROUP;
