//! The **Matrix Machine**: global controller + ring FIFO + processor
//! groups, executing assembled vector programs on one FPGA (paper §4,
//! Fig 4).
//!
//! Two execution paths share the same numerics:
//!
//! * [`MatrixMachine::execute`] — the fast path: a compiled, arena-backed
//!   [`super::plan::ExecPlan`] built once at machine construction (or
//!   shared across machines via [`MatrixMachine::with_plan`] — the
//!   session layer compiles a net once and opens many machines on the
//!   same plan). Views are pre-resolved, per-wave cycle charges are
//!   precomputed from the structural per-batch model
//!   ([`crate::perf::group`]) + the DDR/DMA model + ring distribution
//!   overhead, adjacent dot→activation waves are fused, and independent
//!   lanes execute across a persistent worker pool. Groups execute
//!   batches in parallel; a wave's cost is the per-group batch schedule's
//!   makespan.
//! * [`MatrixMachine::execute_verified`] — the checked path: every wave
//!   is additionally lowered to microcode
//!   ([`crate::assembler::microcode_gen`]) and executed on the structural
//!   [`super::group::MvmGroup`] / [`super::group::ActproGroup`]
//!   interpreters; outputs are asserted bit-identical to the fast path.
//!   Used by integration tests and available from the CLI (`--verify`).
//!
//! Tensor I/O is resolved through the program's
//! [`crate::assembler::program::SymbolTable`] built once at construction:
//! [`MatrixMachine::bind_named`] / [`MatrixMachine::read_named`] look a
//! name up in the table (misses come back with a "did you mean …" hint),
//! and [`MatrixMachine::write_id`] / [`MatrixMachine::read_id`] skip
//! names entirely for pre-resolved ids (what
//! [`crate::session::TensorHandle`] and the trainer's hot loops use).
//!
//! Ring overhead model: each batch's microcode + operands are distributed
//! over the circular FIFO (Fig 4); we charge the worst-case hop count
//! (`groups` stations) once per batch wavefront, which is what the paper's
//! "the FIFO reduces the propagation delay" buys relative to a flat bus.

use super::fpga::FpgaDevice;
use super::plan::{ExecPlan, PlanState};
use super::Cycle;
use crate::assembler::program::{Program, ProgramError, SymbolTable};
use std::sync::Arc;
use thiserror::Error;

/// Machine execution errors.
#[derive(Debug, Error)]
pub enum MachineError {
    /// Program failed validation.
    #[error("invalid program: {0}")]
    Invalid(#[from] ProgramError),
    /// A named tensor is missing (the second field is the pre-rendered
    /// ", did you mean …?" hint, empty when no declared name is close).
    #[error("unknown tensor {0:?}{1}")]
    UnknownBuffer(String, String),
    /// Bound data has the wrong length.
    #[error("buffer {0:?} expects {1} lanes, got {2}")]
    LengthMismatch(String, usize, usize),
    /// Structural verification diverged from the fast path.
    #[error("verification mismatch in step {0}: structural != functional")]
    VerifyMismatch(usize),
}

/// Cycle/work statistics of one program run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunStats {
    /// Total simulated cycles.
    pub cycles: Cycle,
    /// Cycles spent in DDR DMA.
    pub dma_cycles: Cycle,
    /// Cycles spent in compute batches (group makespan).
    pub compute_cycles: Cycle,
    /// Cycles spent streaming LUTs.
    pub lut_cycles: Cycle,
    /// Ring-distribution overhead cycles.
    pub ring_cycles: Cycle,
    /// Waves executed.
    pub waves: u64,
    /// Lane-operations executed (work metric).
    pub lane_ops: u64,
    /// Bytes moved over DDR.
    pub dma_bytes: u64,
}

impl RunStats {
    /// Merge another run's stats.
    pub fn add(&mut self, o: &RunStats) {
        self.cycles += o.cycles;
        self.dma_cycles += o.dma_cycles;
        self.compute_cycles += o.compute_cycles;
        self.lut_cycles += o.lut_cycles;
        self.ring_cycles += o.ring_cycles;
        self.waves += o.waves;
        self.lane_ops += o.lane_ops;
        self.dma_bytes += o.dma_bytes;
    }

    /// Wall-clock seconds on `device`.
    pub fn seconds(&self, device: &FpgaDevice) -> f64 {
        device.seconds(self.cycles)
    }

    /// Lane-ops per second on `device`.
    pub fn lane_ops_per_sec(&self, device: &FpgaDevice) -> f64 {
        self.lane_ops as f64 / self.seconds(device).max(1e-30)
    }
}

/// One simulated Matrix Machine: a shared compiled plan + this machine's
/// private run state (lane arena, LUT residency) + the program's symbol
/// table resolved once.
#[derive(Debug, Clone)]
pub struct MatrixMachine {
    /// The board this machine is generated for.
    pub device: FpgaDevice,
    plan: Arc<ExecPlan>,
    state: PlanState,
    program: Arc<Program>,
    symbols: SymbolTable,
}

impl MatrixMachine {
    /// Build a machine for `device` loaded with `program` (validates it,
    /// then compiles the execution plan once).
    pub fn new(device: FpgaDevice, program: &Program) -> Result<MatrixMachine, MachineError> {
        program.check()?;
        let plan = Arc::new(ExecPlan::new(program, &device));
        MatrixMachine::with_plan(device, program, plan)
    }

    /// Build a machine around an already-compiled plan (validates the
    /// program; the plan must have been compiled from it for `device`).
    ///
    /// This is the plan-reuse path: the session layer caches one
    /// [`ExecPlan`] per `(net, device)` and every
    /// [`crate::session::Session`] opened on that pair shares it. Each
    /// machine still owns a copy of the (small) program for verification
    /// and symbol resolution plus its private [`PlanState`]; the
    /// expensive part — plan compilation (view resolution, fusion, cycle
    /// precomputation) — happens once.
    pub fn with_plan(
        device: FpgaDevice,
        program: &Program,
        plan: Arc<ExecPlan>,
    ) -> Result<MatrixMachine, MachineError> {
        program.check()?;
        debug_assert_eq!(plan.name(), program.name, "plan compiled from a different program");
        let state = plan.state();
        let symbols = program.symbols();
        Ok(MatrixMachine {
            device,
            plan,
            state,
            program: Arc::new(program.clone()),
            symbols,
        })
    }

    /// Program name this machine was built for.
    pub fn program_name(&self) -> &str {
        &self.program.name
    }

    /// The program this machine executes.
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// The program's symbol table (names resolved once at construction).
    pub fn symbols(&self) -> &SymbolTable {
        &self.symbols
    }

    /// The compiled execution plan (diagnostics/benches).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    fn resolve(&self, name: &str) -> Result<usize, MachineError> {
        self.symbols
            .resolve(name)
            .ok_or_else(|| MachineError::UnknownBuffer(name.to_string(), self.symbols.hint(name)))
    }

    /// Bind data to a tensor by name (resolved through the symbol table;
    /// misses come back with a "did you mean …" hint).
    pub fn bind_named(&mut self, name: &str, data: &[i16]) -> Result<(), MachineError> {
        let id = self.resolve(name)?;
        self.write_id(id, data)
    }

    /// Read a tensor by name after a run.
    pub fn read_named(&self, name: &str) -> Result<&[i16], MachineError> {
        let id = self.resolve(name)?;
        Ok(self.plan.read_buffer(&self.state, id))
    }

    /// Bind data to a tensor by pre-resolved buffer id (the typed-handle
    /// hot path: no name lookup, just a length check).
    pub fn write_id(&mut self, id: usize, data: &[i16]) -> Result<(), MachineError> {
        let want = self.plan.buffer_len(id);
        if want != data.len() {
            return Err(MachineError::LengthMismatch(
                self.program.buffers[id].name.clone(),
                want,
                data.len(),
            ));
        }
        self.plan.write_buffer(&mut self.state, id, data);
        Ok(())
    }

    /// Read a tensor by pre-resolved buffer id.
    pub fn read_id(&self, id: usize) -> &[i16] {
        self.plan.read_buffer(&self.state, id)
    }

    /// Execute the compiled plan once on the fast path.
    pub fn execute(&mut self) -> RunStats {
        self.plan.execute(&mut self.state)
    }

    /// Execute once with per-wave structural verification (slow;
    /// tests/CLI).
    ///
    /// Verification replays an **unfused** plan — one wave per source
    /// step — so each wave can be checked against the microcode
    /// interpreters individually; its cycle charges are identical to the
    /// fused fast path (asserted by `sim_equivalence`).
    pub fn execute_verified(&mut self) -> Result<RunStats, MachineError> {
        let plan = ExecPlan::new_unfused(&self.program, &self.device);
        plan.execute_verified(&mut self.state, &self.program)
            .map_err(MachineError::VerifyMismatch)
    }

    /// Bind data to a named buffer.
    #[deprecated(note = "use `bind_named` (or a `session::TensorHandle`); \
                         the program is stored in the machine")]
    pub fn bind(
        &mut self,
        program: &Program,
        name: &str,
        data: &[i16],
    ) -> Result<(), MachineError> {
        debug_assert_eq!(program.name, self.program.name);
        self.bind_named(name, data)
    }

    /// Read a named buffer after a run.
    #[deprecated(note = "use `read_named` (or a `session::TensorHandle`); \
                         the program is stored in the machine")]
    pub fn read(&self, program: &Program, name: &str) -> Result<Vec<i16>, MachineError> {
        debug_assert_eq!(program.name, self.program.name);
        self.read_named(name).map(<[i16]>::to_vec)
    }

    /// Execute the program on the fast (compiled-plan) path.
    #[deprecated(note = "use `execute`; the program is stored in the machine")]
    pub fn run(&mut self, program: &Program) -> Result<RunStats, MachineError> {
        debug_assert_eq!(
            program.name, self.program.name,
            "machine was compiled for a different program"
        );
        Ok(self.execute())
    }

    /// Execute with per-wave structural verification.
    #[deprecated(note = "use `execute_verified`; the program is stored in the machine")]
    pub fn run_verified(&mut self, program: &Program) -> Result<RunStats, MachineError> {
        debug_assert_eq!(
            program.name, self.program.name,
            "machine was compiled for a different program"
        );
        self.execute_verified()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assembler::program::{BufKind, LaneOp, Step, View, Wave};
    use crate::fixed::FixedSpec;
    use crate::isa::Opcode;
    use crate::nn::lut::{ActKind, ActLut, AddrMode};
    use crate::perf::group::structural_mvm_batch_cycles;
    use crate::util::Rng;

    const S: FixedSpec = FixedSpec::PAPER;

    /// x (+) x → act → out, with DMA steps.
    fn small_program() -> (Program, usize, usize) {
        let mut p = Program::new("t", S);
        let x = p.buffer("x", 64, 1, BufKind::Input);
        let o = p.buffer("o", 64, 1, BufKind::Output);
        let lut = p.lut(ActLut::build(ActKind::Relu, false, S, AddrMode::Clamp, 7));
        p.steps.push(Step::LoadDram(x));
        p.steps.push(Step::LoadLut(lut));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 64,
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(x, 64),
                b: Some(View::all(x, 64)),
                out: View::all(o, 64),
            }],
        }));
        p.steps.push(Step::Wave(Wave {
            op: Opcode::ActivationFunction,
            vec_len: 64,
            lut: Some(lut),
            lanes: vec![LaneOp { a: View::all(o, 64), b: None, out: View::all(o, 64) }],
        }));
        p.steps.push(Step::StoreDram(o));
        (p, x, o)
    }

    #[test]
    fn run_produces_expected_numerics_and_stats() {
        let (p, _, _) = small_program();
        let mut r = Rng::new(31);
        let xs: Vec<i16> = (0..64).map(|_| r.gen_range_i64(-3000, 3000) as i16).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        m.bind_named("x", &xs).unwrap();
        let st = m.execute();
        let lut = &p.luts[0];
        let want = lut.apply(&S.vadd(&xs, &xs));
        assert_eq!(m.read_named("o").unwrap(), &want[..]);
        assert_eq!(st.waves, 2);
        assert_eq!(st.lane_ops, 128);
        assert!(st.dma_cycles > 0 && st.compute_cycles > 0 && st.lut_cycles > 0);
        assert_eq!(
            st.cycles,
            st.dma_cycles + st.compute_cycles + st.lut_cycles + st.ring_cycles
        );
    }

    #[test]
    fn verified_run_matches_fast_run() {
        let (p, _, _) = small_program();
        let mut r = Rng::new(32);
        let xs: Vec<i16> = (0..64).map(|_| r.gen_range_i64(-3000, 3000) as i16).collect();
        let mut fast = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        let mut slow = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        fast.bind_named("x", &xs).unwrap();
        slow.bind_named("x", &xs).unwrap();
        let sf = fast.execute();
        let sv = slow.execute_verified().unwrap();
        assert_eq!(fast.read_named("o").unwrap(), slow.read_named("o").unwrap());
        assert_eq!(sf.cycles, sv.cycles);
    }

    #[test]
    fn shared_plan_machines_are_independent() {
        // Two machines on ONE compiled plan (the session reuse path):
        // same cycles, private state.
        let (p, x, _) = small_program();
        let device = FpgaDevice::selected();
        let plan = Arc::new(ExecPlan::new(&p, &device));
        let mut a = MatrixMachine::with_plan(device, &p, Arc::clone(&plan)).unwrap();
        let mut b = MatrixMachine::with_plan(device, &p, Arc::clone(&plan)).unwrap();
        let xa: Vec<i16> = (0..64).collect();
        let xb: Vec<i16> = (0..64).map(|v| -v).collect();
        a.write_id(x, &xa).unwrap();
        b.write_id(x, &xb).unwrap();
        let sa = a.execute();
        let sb = b.execute();
        assert_eq!(sa.cycles, sb.cycles);
        assert_ne!(a.read_named("o").unwrap(), b.read_named("o").unwrap());
    }

    #[test]
    fn multi_lane_wave_distributes_over_groups() {
        // 128 dot products on a 16-group machine: 2 wavefronts of 64.
        let mut p = Program::new("dots", S);
        let a = p.buffer("a", 128, 32, BufKind::Input);
        let o = p.buffer("o", 128, 1, BufKind::Output);
        let lanes: Vec<LaneOp> = (0..128)
            .map(|i| LaneOp {
                a: View::contiguous(a, i * 32, 32),
                b: Some(View::contiguous(a, ((i + 1) % 128) * 32, 32)),
                out: View::contiguous(o, i, 1),
            })
            .collect();
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorDotProduct,
            vec_len: 32,
            lut: None,
            lanes,
        }));
        let mut r = Rng::new(33);
        let data: Vec<i16> = (0..128 * 32).map(|_| r.gen_i16()).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        m.bind_named("a", &data).unwrap();
        let st = m.execute();
        // expected: each lane dot(a[i], a[i+1])
        for i in 0..128 {
            let x = &data[i * 32..(i + 1) * 32];
            let y = &data[((i + 1) % 128) * 32..((i + 1) % 128) * 32 + 32];
            assert_eq!(m.read_named("o").unwrap()[i], S.dot(x, y), "lane {i}");
        }
        // 2 full wavefronts (128 lanes / 64 procs), each costing one
        // 4-proc batch.
        let batch = structural_mvm_batch_cycles(Opcode::VectorDotProduct, 32, 4);
        assert_eq!(st.compute_cycles, 2 * batch);
        assert_eq!(st.ring_cycles, 2 * 17);
    }

    #[test]
    fn errors_on_bad_bindings_with_suggestions() {
        let (p, _, _) = small_program();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        // total miss: no hint
        match m.bind_named("nope", &[0]) {
            Err(MachineError::UnknownBuffer(name, hint)) => {
                assert_eq!(name, "nope");
                assert_eq!(hint, "");
            }
            other => panic!("expected UnknownBuffer, got {other:?}"),
        }
        // near miss: did-you-mean hint names the declared tensor
        match m.read_named("0") {
            Err(MachineError::UnknownBuffer(_, hint)) => {
                assert!(hint.contains("did you mean \"o\""), "hint {hint:?}");
            }
            other => panic!("expected UnknownBuffer, got {other:?}"),
        }
        assert!(matches!(
            m.bind_named("x", &[0; 3]),
            Err(MachineError::LengthMismatch(_, 64, 3))
        ));
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_program_passing_shims_still_work() {
        let (p, _, _) = small_program();
        let xs: Vec<i16> = (0..64).collect();
        let mut m = MatrixMachine::new(FpgaDevice::selected(), &p).unwrap();
        m.bind(&p, "x", &xs).unwrap();
        let st = m.run(&p).unwrap();
        assert_eq!(st.waves, 2);
        let via_shim = m.read(&p, "o").unwrap();
        assert_eq!(via_shim, m.read_named("o").unwrap().to_vec());
        assert!(matches!(
            m.bind(&p, "nope", &[0]),
            Err(MachineError::UnknownBuffer(_, _))
        ));
    }

    #[test]
    fn invalid_program_rejected_at_construction() {
        let mut p = Program::new("bad", S);
        let x = p.buffer("x", 4, 1, BufKind::Input);
        p.steps.push(Step::Wave(Wave {
            op: Opcode::VectorAddition,
            vec_len: 9, // OOB
            lut: None,
            lanes: vec![LaneOp {
                a: View::all(x, 9),
                b: Some(View::all(x, 9)),
                out: View::all(x, 9),
            }],
        }));
        assert!(MatrixMachine::new(FpgaDevice::selected(), &p).is_err());
    }
}
